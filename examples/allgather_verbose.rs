//! The paper's Fig. 6 "verbose program": the same hybrid MPI+MPI allgather
//! as `allgather_wrapper.rs`, but written against the raw MPI-level API —
//! explicit two-level communicator splitting, window allocation,
//! recvcounts/displs bookkeeping, and hand-placed barriers.
//!
//! The point (paper §4.2, Table 1): without the wrappers the program is
//! longer, exposes every synchronization hazard to the user, and is
//! "prone to obscurity or even failure".
//!
//! Run: `cargo run --release --example allgather_verbose`

use hympi::coll::allgather::allgatherv;
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::mpi::comm::UNDEFINED;
use hympi::util::{cast_slice, to_bytes};

fn main() {
    let msg = 100usize; // doubles gathered from every rank
    let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
    let report = SimCluster::new(spec).run(move |env| {
        let comm = env.world();
        // [section: Communicator splitting]
        let shmem_comm = env.split_type_shared(&comm);
        let shmemcomm_rank = shmem_comm.rank();
        let leader = 0usize;
        let bridge_comm = env.split(
            &comm,
            if shmemcomm_rank == leader { 0 } else { UNDEFINED },
            comm.rank() as i64,
        );
        let shmemcomm_size = shmem_comm.size();
        let nprocs = comm.size();
        // [section: Shared memory allocation]
        let msg_size = if shmemcomm_rank == leader { msg * 8 * nprocs } else { 0 };
        let win = env.win_allocate_shared(&shmem_comm, msg_size);
        let r_buf = win.win.clone();
        // [section: Fill recvcounts and displs]
        let mut sharedmem_sizeset = vec![0usize; 0];
        let mut recvcounts = Vec::new();
        let mut displs = Vec::new();
        if let Some(bridge) = &bridge_comm {
            let mine = (shmemcomm_size as u64).to_le_bytes();
            let mut sizes = vec![0u8; 8 * bridge.size()];
            hympi::coll::allgather(env, bridge, &mine, &mut sizes, hympi::coll::AllgatherAlgo::Bruck);
            sharedmem_sizeset = sizes
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
                .collect();
            recvcounts = sharedmem_sizeset.iter().map(|&s| msg * 8 * s).collect();
            displs = vec![0usize; sharedmem_sizeset.len()];
            for i in 0..sharedmem_sizeset.len() {
                for j in 0..i {
                    displs[i] += recvcounts[j];
                }
            }
        }
        // [section: Get local pointer]
        let rank = comm.rank();
        let s_off = msg * 8 * rank;
        let s_buf: Vec<f64> = (0..msg).map(|i| i as f64).collect();
        // [section: Allgather]
        r_buf.write(s_off, to_bytes(&s_buf));
        env.charge_memcpy(msg * 8);
        if let Some(bridge) = &bridge_comm {
            env.barrier(&shmem_comm);
            let bidx = bridge.rank();
            let mine = r_buf.read_vec(displs[bidx], recvcounts[bidx]);
            let out = unsafe { r_buf.slice_mut(0, msg * 8 * nprocs) };
            allgatherv(env, bridge, &mine, &recvcounts, out);
            env.barrier(&shmem_comm);
        } else {
            env.barrier(&shmem_comm);
            env.barrier(&shmem_comm);
        }
        let gathered: Vec<f64> = cast_slice(&r_buf.read_vec(0, msg * 8 * nprocs));
        env.charge_memcpy(msg * 8 * nprocs);
        // [section: Deallocation]
        env.barrier(&shmem_comm);
        win.free(env, &shmem_comm);
        drop(sharedmem_sizeset);
        // [section: end]
        gathered.len()
    });
    assert!(report.outputs.iter().all(|&n| n == msg * 32));
    println!(
        "verbose program: every rank sees {} doubles; makespan {:.1} virtual us",
        report.outputs[0],
        report.max_vtime_us()
    );
}
