//! The paper's Fig. 5 "wrapper program": a complete hybrid MPI+MPI
//! allgather micro-benchmark written with the session API — one
//! [`HybridCtx`] plus a persistent [`HyColl`] handle.
//!
//! Compare with `allgather_verbose.rs` (the paper's Fig. 6) — Table 1 of
//! the reproduction (`hympi figures table1`) counts the section lines of
//! both files to reproduce the paper's productivity comparison.
//!
//! Run: `cargo run --release --example allgather_wrapper`

use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{HybridCtx, LeaderPolicy, RootPolicy, SyncScheme};
use hympi::util::{cast_slice, to_bytes};

fn main() {
    let msg = 100usize; // doubles gathered from every rank
    let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
    let report = SimCluster::new(spec).run(move |env| {
        let w = env.world();
        // [section: Communicator splitting]
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        // [section: Shared memory allocation]
        let mut ag = ctx.allgather_init(env, msg * 8, SyncScheme::Spin);
        // [section: Fill recvcounts and displs]
        assert_eq!(ctx.sizeset(env).iter().sum::<usize>(), w.size());
        // [section: Get local pointer]
        let s_buf: Vec<f64> = (0..msg).map(|i| i as f64).collect();
        // [section: Allgather]
        ag.start_allgather(env, to_bytes(&s_buf));
        ag.wait(env);
        let gathered: Vec<f64> =
            cast_slice(&ag.window().unwrap().load(env, 0, msg * 8 * w.size()));
        // [section: Deallocation]
        env.barrier(ctx.shmem());
        ag.free(env);
        // [section: end]
        gathered.len()
    });
    assert!(report.outputs.iter().all(|&n| n == msg * 32));
    println!(
        "wrapper program: every rank sees {} doubles; makespan {:.1} virtual us",
        report.outputs[0],
        report.max_vtime_us()
    );

    // The split-phase variant (DESIGN.md §5e): a pipelined Fixed-root
    // broadcast driven by `test()` polling — the caller folds its own
    // compute between `start` and completion instead of blocking in
    // `wait`, the MPI_Test shape.
    let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
    let report = SimCluster::new(spec).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        // [section: Split-phase init]  root baked in, bridge chunked ×4
        let mut bc = ctx.bcast_init_split(env, msg * 8, SyncScheme::Spin, RootPolicy::Fixed(0), 4);
        let payload: Vec<f64> = (0..msg).map(|i| (i * i) as f64).collect();
        // [section: Start]  root's bridge chunks go onto the wire here
        let arg = (w.rank() == 0).then(|| to_bytes(&payload));
        bc.start_bcast(env, 0, arg);
        // [section: Overlap]  poll; do useful work per negative poll
        let mut polls = 0u32;
        while !bc.test(env) {
            env.compute(1.0); // 1 µs of the caller's own work per poll
            polls += 1;
        }
        // [section: Read in place]
        let got: Vec<f64> = cast_slice(&bc.window().unwrap().load(env, 0, msg * 8));
        assert_eq!(got, payload);
        env.barrier(ctx.shmem());
        bc.free(env);
        polls
    });
    // Rank 0 (the root) completes inside `start`, so its poll count is
    // always 0 — report the busiest polling rank instead.
    println!(
        "split-phase program: broadcast verified on all ranks; makespan {:.1} virtual us \
         (busiest rank overlapped {} polls of compute)",
        report.max_vtime_us(),
        report.outputs.iter().max().unwrap()
    );
}
