//! The paper's Fig. 5 "wrapper program": a complete hybrid MPI+MPI
//! allgather micro-benchmark written with the wrapper primitives.
//!
//! Compare with `allgather_verbose.rs` (the paper's Fig. 6) — Table 1 of
//! the reproduction (`hympi figures table1`) counts the section lines of
//! both files to reproduce the paper's productivity comparison.
//!
//! Run: `cargo run --release --example allgather_wrapper`

use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{self, CommPackage, SyncScheme};
use hympi::util::{cast_slice, to_bytes};

fn main() {
    let msg = 100usize; // doubles gathered from every rank
    let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
    let report = SimCluster::new(spec).run(move |env| {
        let w = env.world();
        // [section: Communicator splitting]
        let pkg = CommPackage::create(env, &w);
        // [section: Shared memory allocation]
        let mut win = pkg.alloc_shared(env, msg * 8, 1, w.size());
        // [section: Fill recvcounts and displs]
        let sizeset = hybrid::sizeset_gather(env, &pkg);
        let param = hybrid::AllgatherParam::create(env, &pkg, msg * 8, &sizeset);
        // [section: Get local pointer]
        let s_buf: Vec<f64> = (0..msg).map(|i| i as f64).collect();
        let off = win.local_ptr(w.rank(), msg * 8);
        // [section: Allgather]
        win.store(env, off, to_bytes(&s_buf));
        hybrid::hy_allgather(env, &pkg, &mut win, &param, msg * 8, SyncScheme::Spin);
        let gathered: Vec<f64> = cast_slice(&win.load(env, 0, msg * 8 * w.size()));
        // [section: Deallocation]
        env.barrier(&pkg.shmem);
        win.free(env, &pkg);
        pkg.free(env);
        // [section: end]
        gathered.len()
    });
    assert!(report.outputs.iter().all(|&n| n == msg * 32));
    println!(
        "wrapper program: every rank sees {} doubles; makespan {:.1} virtual us",
        report.outputs[0],
        report.max_vtime_us()
    );
}
