//! The paper's Fig. 5 "wrapper program": a complete hybrid MPI+MPI
//! allgather micro-benchmark written with the session API — one
//! [`HybridCtx`] plus a persistent [`HyColl`] handle.
//!
//! Compare with `allgather_verbose.rs` (the paper's Fig. 6) — Table 1 of
//! the reproduction (`hympi figures table1`) counts the section lines of
//! both files to reproduce the paper's productivity comparison.
//!
//! Run: `cargo run --release --example allgather_wrapper`

use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{HybridCtx, LeaderPolicy, SyncScheme};
use hympi::util::{cast_slice, to_bytes};

fn main() {
    let msg = 100usize; // doubles gathered from every rank
    let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
    let report = SimCluster::new(spec).run(move |env| {
        let w = env.world();
        // [section: Communicator splitting]
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        // [section: Shared memory allocation]
        let mut ag = ctx.allgather_init(env, msg * 8, SyncScheme::Spin);
        // [section: Fill recvcounts and displs]
        assert_eq!(ctx.sizeset(env).iter().sum::<usize>(), w.size());
        // [section: Get local pointer]
        let s_buf: Vec<f64> = (0..msg).map(|i| i as f64).collect();
        // [section: Allgather]
        ag.start_allgather(env, to_bytes(&s_buf));
        ag.wait(env);
        let gathered: Vec<f64> =
            cast_slice(&ag.window().unwrap().load(env, 0, msg * 8 * w.size()));
        // [section: Deallocation]
        env.barrier(ctx.shmem());
        ag.free(env);
        // [section: end]
        gathered.len()
    });
    assert!(report.outputs.iter().all(|&n| n == msg * 32));
    println!(
        "wrapper program: every rank sees {} doubles; makespan {:.1} virtual us",
        report.outputs[0],
        report.max_vtime_us()
    );
}
