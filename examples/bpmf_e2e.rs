//! BPMF end-to-end (§5.3.3): the Gibbs sampler on synthetic
//! compound×target data over two simulated Hazel Hen nodes, all three
//! implementations, posterior batches through the PJRT artifact when
//! available. The three variants must produce bit-identical factors.
//!
//! Run: `make artifacts && cargo run --release --example bpmf_e2e`

use hympi::coordinator::{ClusterSpec, Preset};
use hympi::kernels::bpmf::{run, BpmfCfg};
use hympi::kernels::{Backend, Variant};

fn main() {
    let backend = Backend::auto();
    println!("BPMF: 4800 compounds x 240 targets, K=10, 10 iterations, backend = {}", backend.name());

    let mut checks = Vec::new();
    for variant in [Variant::PureMpi, Variant::HybridMpiMpi, Variant::MpiOpenMp] {
        let spec = if variant == Variant::MpiOpenMp {
            let mut s = ClusterSpec::preset(Preset::HazelHen, 2);
            s.nodes = vec![1; 2];
            s
        } else {
            ClusterSpec::preset(Preset::HazelHen, 2)
        };
        let cfg = BpmfCfg {
            compounds: 4800,
            targets: 240,
            k: 10,
            nnz: 32,
            iters: 10,
            variant,
            backend,
            threads: 24,
        };
        let rep = run(spec, cfg);
        println!(
            "{:>10}: comp {:>10.1} us | allgather {:>9.1} us | total {:>10.1} us | checksum {:+.6e} | wall {:?}",
            rep.variant.name(),
            rep.comp_us,
            rep.comm_us,
            rep.total_us,
            rep.checksum,
            rep.wall,
        );
        checks.push(rep.checksum);
    }
    let spread = checks.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - checks.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread.abs() < 1e-9, "variants disagree: {checks:?}");
    println!("all three variants computed identical factors ✓");
}
