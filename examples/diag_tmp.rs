fn main() {
    use hympi::coordinator::{ClusterSpec, Preset};
    use hympi::kernels::{poisson::*, Backend, Variant};
    let mut s = ClusterSpec::preset(Preset::VulcanSb, 2);
    s.nodes = vec![8, 8];
    let cfg = |variant| PoissonCfg { n: 32, tol: 0.0, max_iters: 50, variant, backend: Backend::Native, threads: 1 };
    let pure = run(s.clone(), cfg(Variant::PureMpi));
    let hy = run(s.clone(), cfg(Variant::HybridMpiMpi));
    println!("pure: comp={:.1} comm={:.1} total={:.1}", pure.comp_us, pure.comm_us, pure.total_us);
    println!("hy:   comp={:.1} comm={:.1} total={:.1}", hy.comp_us, hy.comm_us, hy.total_us);
}
