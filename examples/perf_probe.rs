//! L3 perf probe: decomposes the engine's real-time cost into thread
//! spawn/teardown, barrier storms, p2p message throughput and collective
//! throughput. Drives the §Perf iteration log in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example perf_probe`

use hympi::coll;
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use std::time::Instant;

fn timeit(label: &str, f: impl FnOnce() -> (u64, u64)) {
    let t0 = Instant::now();
    let (units, bytes) = f();
    let dt = t0.elapsed().as_secs_f64();
    let rate = units as f64 / dt;
    println!(
        "{label:<44} {dt:>7.3} s | {rate:>12.0} units/s | {:>8.1} MB/s",
        bytes as f64 / dt / 1e6
    );
}

fn main() {
    let ranks = 192; // 8 hazelhen nodes
    let spec = || ClusterSpec::preset(Preset::HazelHen, 8);

    timeit("spawn+join only (192 threads)", || {
        for _ in 0..10 {
            SimCluster::new(spec()).run(|_| ());
        }
        (10 * ranks as u64, 0)
    });

    timeit("barrier x50 (192 ranks)", || {
        SimCluster::new(spec()).run(|env| {
            let w = env.world();
            for _ in 0..50 {
                env.barrier(&w);
            }
        });
        (50 * ranks as u64, 0)
    });

    timeit("p2p pingpong x2000 (1 pair, 800 B)", || {
        SimCluster::new(spec()).run(|env| {
            let w = env.world();
            let t = hympi::mpi::USER_TAG_BASE;
            for _ in 0..2000 {
                if env.world_rank() == 0 {
                    env.send(&w, 100, t, &[1u8; 800]);
                    let _ = env.recv(&w, Some(100), t + 1);
                } else if env.world_rank() == 100 {
                    let _ = env.recv(&w, Some(0), t);
                    env.send(&w, 0, t + 1, &[1u8; 800]);
                }
            }
        });
        (4000, 4000 * 800)
    });

    timeit("bruck allgather x20 (192 ranks, 800 B)", || {
        SimCluster::new(spec()).run(|env| {
            let w = env.world();
            let mine = vec![1u8; 800];
            let mut out = vec![0u8; 800 * w.size()];
            for _ in 0..20 {
                coll::allgather(env, &w, &mine, &mut out, coll::AllgatherAlgo::Bruck);
            }
        });
        // bruck: ~log2(192)=8 rounds/rank/iter
        (20 * 8 * ranks as u64, 20 * 8 * 192 * 800)
    });

    timeit("binomial bcast x20 (192 ranks, 512 KB)", || {
        SimCluster::new(spec()).run(|env| {
            let w = env.world();
            let mut buf = vec![1u8; 512 * 1024];
            for _ in 0..20 {
                coll::bcast(env, &w, 0, &mut buf, coll::BcastAlgo::Binomial);
            }
        });
        (20 * ranks as u64, 20 * 191 * 512 * 1024)
    });
}
