//! 2D Poisson solver (§5.3.2) end-to-end: solves the Laplace problem on a
//! 256² grid over one simulated 16-core node, comparing the three
//! implementations and reporting the per-iteration allreduce cost.
//!
//! Run: `cargo run --release --example poisson_solver`

use hympi::coordinator::{ClusterSpec, Preset};
use hympi::kernels::poisson::{run, PoissonCfg};
use hympi::kernels::{Backend, Variant};

fn main() {
    let n = 256;
    let backend = Backend::auto();
    println!("Poisson {n}x{n}, tol 1e-4, backend = {}", backend.name());

    for variant in [Variant::PureMpi, Variant::HybridMpiMpi, Variant::MpiOpenMp] {
        let spec = if variant == Variant::MpiOpenMp {
            let mut s = ClusterSpec::preset(Preset::VulcanSb, 4);
            s.nodes = vec![1; 4];
            s
        } else {
            ClusterSpec::preset(Preset::VulcanSb, 1)
        };
        let cfg = PoissonCfg::paper(n, variant, backend, 16);
        let rep = run(spec, cfg);
        println!(
            "{:>10}: {} iters | comp {:>9.1} us | allreduce {:>8.1} us | total {:>9.1} us | residual-sum {:.3}",
            rep.variant.name(),
            rep.iters,
            rep.comp_us,
            rep.comm_us,
            rep.total_us,
            rep.checksum,
        );
    }
}
