//! Quickstart: stand up a simulated two-node cluster and compare one
//! collective in both worlds — the standard `MPI_Allreduce` and the
//! paper's `Wrapper_Hy_Allreduce`.
//!
//! Run: `cargo run --release --example quickstart`

use hympi::coll::{self, AllreduceAlgo};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{allreduce::alloc_allreduce_win, hy_allreduce, AllreduceMethod, CommPackage, SyncScheme};
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::{cast_slice, to_bytes};

fn main() {
    // Two "Vulcan" nodes, 16 ranks each, InfiniBand between them.
    let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
    println!("cluster: {} nodes x {} ranks", spec.nnodes(), spec.nodes[0]);

    let report = SimCluster::new(spec).run(|env| {
        let w = env.world();

        // ---- pure MPI ------------------------------------------------
        let mut buf = to_bytes(&[env.world_rank() as f64]).to_vec();
        let t0 = env.vclock();
        coll::allreduce(env, &w, Datatype::F64, ReduceOp::Sum, &mut buf, AllreduceAlgo::Auto);
        let pure_us = env.vclock() - t0;
        let pure_result = cast_slice::<f64>(&buf)[0];

        // ---- hybrid MPI+MPI (the paper's §4.4 design) ------------------
        let pkg = CommPackage::create(env, &w);
        let mut win = alloc_allreduce_win(env, &pkg, 8);
        env.harness_sync(&w);
        let t1 = env.vclock();
        let off = win.local_ptr(pkg.shmem.rank(), 8);
        win.store(env, off, to_bytes(&[env.world_rank() as f64]));
        let g = hy_allreduce(
            env,
            &pkg,
            &mut win,
            Datatype::F64,
            ReduceOp::Sum,
            8,
            AllreduceMethod::Tuned,
            SyncScheme::Spin,
        );
        let hy_us = env.vclock() - t1;
        let hy_result = cast_slice::<f64>(&win.load(env, g, 8))[0];

        env.barrier(&pkg.shmem);
        win.free(env, &pkg);
        assert_eq!(pure_result, hy_result, "both worlds must agree");
        (pure_result, pure_us, hy_us)
    });

    let (result, pure_us, hy_us) = report.outputs[0];
    println!("sum over 32 ranks = {result} (expected {})", (0..32).sum::<usize>());
    println!("MPI_Allreduce:        {pure_us:.2} virtual us");
    println!("Wrapper_Hy_Allreduce: {hy_us:.2} virtual us");
    println!("messages moved: {} ({} bytes)", report.msgs, report.bytes);
    println!("wall time: {:?}", report.wall);
}
