//! Quickstart: stand up a simulated two-node cluster and compare one
//! collective in both worlds — the standard `MPI_Allreduce` and the
//! paper's `Wrapper_Hy_Allreduce` — through the persistent-collective
//! engine: plan once, execute many.
//!
//! Run: `cargo run --release --example quickstart`

use hympi::coll::{CollOp, Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::SyncScheme;
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::{cast_slice, to_bytes};

fn main() {
    // Two "Vulcan" nodes, 16 ranks each, InfiniBand between them.
    let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
    println!("cluster: {} nodes x {} ranks", spec.nnodes(), spec.nodes[0]);

    let report = SimCluster::new(spec).run(|env| {
        let w = env.world();
        let mut plans = PlanCache::new();

        // Plan both flavors up front: the pure plan resolves its tuned
        // algorithm once; the hybrid plan pays the Table-2 one-offs
        // (communicator splits, shared window) exactly once.
        let hybrid = Flavor::hybrid(SyncScheme::Spin);
        for flavor in [Flavor::Pure, hybrid] {
            plans.plan(env, &w, CollOp::Allreduce, 8, Datatype::F64, Some(ReduceOp::Sum), flavor);
        }

        // ---- pure MPI ------------------------------------------------
        let mut buf = to_bytes(&[env.world_rank() as f64]).to_vec();
        let t0 = env.vclock();
        plans.allreduce(env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, &mut buf);
        let pure_us = env.vclock() - t0;
        let pure_result = cast_slice::<f64>(&buf)[0];

        // ---- hybrid MPI+MPI (the paper's §4.4 design) ----------------
        // `allreduce_windowed` leaves the result in the shared window
        // (the paper's in-place sharing), so the timed region matches
        // the §5.2 benchmark convention; the value is read afterwards
        // through the zero-copy view.
        let mut buf = to_bytes(&[env.world_rank() as f64]).to_vec();
        env.harness_sync(&w);
        let t1 = env.vclock();
        plans.allreduce_windowed(env, &w, hybrid, Datatype::F64, ReduceOp::Sum, &mut buf);
        let hy_us = env.vclock() - t1;
        let key = hympi::coll::PlanKey::new(
            &w, CollOp::Allreduce, 8, Datatype::F64, Some(ReduceOp::Sum), hybrid, 0,
        );
        let hy_result =
            cast_slice::<f64>(plans.get(&key).unwrap().result_view(8).unwrap())[0];

        // Executing again hits the cache: no re-planning, no new window.
        plans.allreduce(env, &w, hybrid, Datatype::F64, ReduceOp::Sum, &mut buf);
        let stats = (plans.hits(), plans.misses());

        plans.free(env);
        assert_eq!(pure_result, hy_result, "both worlds must agree");
        (pure_result, pure_us, hy_us, stats)
    });

    let (result, pure_us, hy_us, (hits, misses)) = report.outputs[0];
    println!("sum over 32 ranks = {result} (expected {})", (0..32).sum::<usize>());
    println!("MPI_Allreduce:        {pure_us:.2} virtual us");
    println!("Wrapper_Hy_Allreduce: {hy_us:.2} virtual us");
    println!("plan cache: {misses} plans built, {hits} cached executions");
    println!("messages moved: {} ({} bytes)", report.msgs, report.bytes);
    println!("wall time: {:?}", report.wall);
}
