//! End-to-end SUMMA driver: all three implementations of the paper's
//! §5.3.1 kernel on a simulated 4-node Vulcan partition, with the local
//! block multiplies executed through the **full AOT stack** (JAX/Pallas →
//! HLO text → PJRT) when artifacts are present.
//!
//! This is the repository's end-to-end proof that the three layers
//! compose: the rust coordinator (L3) drives the simulated cluster and
//! the hybrid collectives, and every compute phase executes the Pallas
//! matmul artifact (L1/L2) through PJRT. Results are cross-validated
//! against the analytic checksum.
//!
//! Run: `make artifacts && cargo run --release --example summa_e2e`

use hympi::coordinator::{ClusterSpec, Preset};
use hympi::kernels::summa::{expected_checksum, run, SummaCfg};
use hympi::kernels::{Backend, Variant};

fn main() {
    let n = 512; // 512x512 doubles, 8x8 grid over 4 nodes x 16 ranks
    let backend = Backend::auto();
    println!("SUMMA {n}x{n}, backend = {}", backend.name());
    if backend == Backend::Native {
        println!("(run `make artifacts` to exercise the PJRT path)");
    }
    let want = expected_checksum(n);

    for variant in [Variant::PureMpi, Variant::HybridMpiMpi, Variant::MpiOpenMp] {
        let spec = if variant == Variant::MpiOpenMp {
            let mut s = ClusterSpec::preset(Preset::VulcanSb, 4);
            s.nodes = vec![1; 4]; // one rank per node + 16 OpenMP threads
            s
        } else {
            ClusterSpec::preset(Preset::VulcanSb, 4)
        };
        let rep = run(spec, SummaCfg { n, variant, backend, threads: 16 });
        let ok = (rep.checksum - want).abs() < 1e-6 * want.abs();
        println!(
            "{:>10}: comp {:>10.1} us | bcast {:>8.1} us | total {:>10.1} us | checksum {} | wall {:?}",
            rep.variant.name(),
            rep.comp_us,
            rep.comm_us,
            rep.total_us,
            if ok { "OK" } else { "MISMATCH" },
            rep.wall,
        );
        assert!(ok, "checksum mismatch: {} vs {want}", rep.checksum);
    }
}
