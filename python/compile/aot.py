"""L2 -> HLO-text AOT pipeline.

Lowers each model function at each benchmark shape to HLO *text* (not a
serialized HloModuleProto: jax >= 0.5 emits 64-bit instruction ids that the
runtime's xla_extension 0.5.1 rejects; the text parser reassigns ids) and
writes a manifest so the rust runtime can discover artifacts by name.

Run: python -m compile.aot --out-dir ../artifacts     (from python/)
     make artifacts                                   (from the repo root)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F64 = jnp.float64


def spec(shape, dtype=F64):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_set():
    """name -> (fn, example args). Shapes cover every benchmark config:

    - summa block 256: all three Fig. 17 configs decompose to 256x256 local
      blocks (1024/4 = 2048/8 = 4096/16 = 256); summa64 serves tests.
    - poisson strips: (grid/ranks + 2 halo rows) x grid for the Fig. 18
      configs 256^2/16r, 512^2/64r, 1024^2/256r; plus a small test shape.
    - bpmf posterior: batch x nnz x K gathered-factor batches.
    """
    sets = {}
    for edge in (64, 256, 1024):
        sets[f"summa{edge}"] = (
            model.summa_block,
            (spec((edge, edge)), spec((edge, edge)), spec((edge, edge))),
        )
    for rows, n in ((16, 256), (8, 512), (4, 1024), (8, 64)):
        sets[f"poisson_r{rows}_n{n}"] = (
            model.poisson_step,
            (spec((rows + 2, n)),),
        )
    for batch, nnz, k in ((64, 32, 10), (32, 16, 10)):
        sets[f"bpmf_b{batch}_n{nnz}_k{k}"] = (
            model.bpmf_posterior,
            (
                spec((batch, nnz, k)),
                spec((batch, nnz)),
                spec(()),
                spec((k,)),
                spec((batch, k)),
            ),
        )
    return sets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    jax.config.update("jax_enable_x64", True)

    os.makedirs(args.out_dir, exist_ok=True)
    mpath = os.path.join(args.out_dir, "manifest.json")
    manifest = {}
    only = set(args.only.split(",")) if args.only else None
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)  # merge partial regenerations
    for name, (fn, specs) in artifact_set().items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
