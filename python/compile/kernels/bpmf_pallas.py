"""L1: batched Gram-accumulation Pallas kernel for BPMF (§5.3.3).

BPMF's Gibbs sampler computes, per item i, the posterior precision

    Lambda_i = Lambda_0 + alpha * sum_{j in obs(i)} v_j v_j^T

over the currently-sampled factors v_j of the opposite entity, plus the
matching linear term b_i = alpha * sum_j r_ij v_j. With a fixed
observations-per-item budget (nnz), the hot spot is the batched masked
outer-product accumulation — a (batch, nnz, K) x (batch, nnz, K) ->
(batch, K, K) contraction. K = 10 is tiny, so the TPU-shaped layout is
batch-parallel: one grid step per batch tile, factors resident in VMEM,
K x K accumulators register-resident — the Pallas analogue of the
per-thread-block accumulation a CUDA BPMF would use.

`interpret=True`: see matmul_pallas.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Items per grid step; benchmark batch sizes are multiples of this.
BATCH_TILE = 32


def _gram_kernel(v_ref, w_ref, o_ref, b_ref):
    """One batch tile.

    v_ref: (bt, nnz, k) gathered factors (already masked to 0 for padding)
    w_ref: (bt, nnz)    per-observation weights (rating * mask)
    o_ref: (bt, k, k)   Gram accumulation  sum_n v v^T
    b_ref: (bt, k)      weighted sum       sum_n w * v
    """
    v = v_ref[...]
    w = w_ref[...]
    o_ref[...] = jnp.einsum("bnk,bnl->bkl", v, v)
    b_ref[...] = jnp.einsum("bn,bnk->bk", w, v)


def gram_batch(v, w):
    """(sum_n v v^T, sum_n w v) per batch row, Pallas-tiled over the batch.

    v: (batch, nnz, k) — zero rows for padded observations.
    w: (batch, nnz)
    returns (gram (batch, k, k), lin (batch, k)).
    """
    batch, nnz, k = v.shape
    assert w.shape == (batch, nnz)
    bt = min(BATCH_TILE, batch)
    assert batch % bt == 0, f"batch {batch} must tile by {bt}"
    grid = (batch // bt,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, nnz, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, nnz), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, k, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, k, k), v.dtype),
            jax.ShapeDtypeStruct((batch, k), v.dtype),
        ],
        interpret=True,
    )(v, w)


gram_batch_jit = jax.jit(gram_batch)
