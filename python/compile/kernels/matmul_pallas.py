"""L1: tiled matmul-accumulate Pallas kernel — the SUMMA block multiply.

SUMMA's core phase (§5.3.1 of the paper) is `C += A_panel @ B_panel` on each
rank's local block. On the paper's CPU testbed this is a BLAS dgemm; here it
is re-thought for the TPU architecture per the hardware-adaptation rule:

- the MXU wants (128, 128) tiles; we tile the M/N/K space with BlockSpec so
  every grid step works on VMEM-resident tiles (3 * 128*128*8 B = 384 KiB
  for f64, well inside a ~16 MiB VMEM budget, double-buffered by Pallas);
- the K dimension is the innermost ("arbitrary") grid axis so the output
  tile stays resident while panels stream through — the HBM<->VMEM schedule
  that a GPU implementation would express with threadblock tiling.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on a real TPU the same kernel lowers natively (DESIGN.md §5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly tile edge. Benchmark shapes (256^2 blocks) are multiples.
TILE = 128


def _matmul_acc_kernel(a_ref, b_ref, c_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= a[i,k] @ b[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += a_ref[...] @ b_ref[...]


def matmul_acc(a, b, c, *, tile=TILE):
    """`c + a @ b` with an MXU-tiled Pallas kernel.

    a: (m, kk), b: (kk, n), c: (m, n); all dims must divide by `tile`
    (callers pad or pick benchmark shapes that already do).
    """
    m, kk = a.shape
    kk2, n = b.shape
    assert kk == kk2, f"contraction mismatch {kk} vs {kk2}"
    assert c.shape == (m, n)
    t = min(tile, m, n, kk)
    assert m % t == 0 and n % t == 0 and kk % t == 0, (
        f"shapes ({m},{kk})x({kk},{n}) must tile by {t}"
    )
    grid = (m // t, n // t, kk // t)
    return pl.pallas_call(
        _matmul_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j, k: (i, k)),
            pl.BlockSpec((t, t), lambda i, j, k: (k, j)),
            pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(a, b, c)


@functools.partial(jax.jit, static_argnames=("tile",))
def matmul_acc_jit(a, b, c, tile=TILE):
    return matmul_acc(a, b, c, tile=tile)
