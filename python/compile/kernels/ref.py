"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has its reference twin here; pytest (and the
hypothesis sweeps) assert allclose between the two across shapes/dtypes.
The references are deliberately naive — clarity over speed.
"""

import jax.numpy as jnp


def matmul_acc_ref(a, b, c):
    """c + a @ b."""
    return c + a @ b


def rb_sweep_ref(strip):
    """Red-black Gauss-Seidel sweep on a halo-padded strip.

    Point-wise definition, no vector tricks: red points (i+j even) update
    from old values; black points then update from the half-updated grid.
    Halo rows (0, r+1) and boundary columns (0, n-1) are untouched.
    """
    x = jnp.asarray(strip)
    rp2, n = x.shape

    def avg(u, i, j):
        return 0.25 * (u[i - 1, j] + u[i + 1, j] + u[i, j - 1] + u[i, j + 1])

    # Red pass.
    x1 = x
    for i in range(1, rp2 - 1):
        for j in range(1, n - 1):
            if (i + j) % 2 == 0:
                x1 = x1.at[i, j].set(avg(x, i, j))
    # Black pass (reads the red-updated grid).
    x2 = x1
    for i in range(1, rp2 - 1):
        for j in range(1, n - 1):
            if (i + j) % 2 == 1:
                x2 = x2.at[i, j].set(avg(x1, i, j))
    delta = jnp.max(jnp.abs(x2[1:-1, :] - x[1:-1, :]))
    return x2, delta


def gram_batch_ref(v, w):
    """Per-batch-row (sum_n v v^T, sum_n w v)."""
    gram = jnp.einsum("bnk,bnl->bkl", v, v)
    lin = jnp.einsum("bn,bnk->bk", w, v)
    return gram, lin
