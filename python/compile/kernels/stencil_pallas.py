"""L1: red-black Gauss-Seidel 5-point stencil Pallas kernel (2D Poisson).

The paper's 2D Poisson solver (§5.3.2) sweeps Gauss-Seidel over a square
grid decomposed by rows, exchanging halo rows with neighbors and
allreducing the maximum update delta each iteration.

Hardware adaptation: lexicographic Gauss-Seidel carries a wavefront
dependency that is hostile to any vector unit; the standard parallel
reformulation is **red-black coloring** — update all "red" points (i+j
even) from the old values, then all "black" points from the fresh red
values. Convergence behaviour matches what the paper relies on, and each
color update is a dense vectorizable map that the VPU handles as wide
lanes. The strip (rows+2 halo rows x N) fits VMEM as a single block for
every benchmark shape (<= 18 x 1024 f64 = 144 KiB).

`interpret=True`: see matmul_pallas.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rb_kernel(x_ref, o_ref):
    """One red-black sweep over a halo-padded strip.

    x_ref: (r+2, n) — rows 0 and r+1 are neighbor halos (or physical
    boundary), columns 0 and n-1 are fixed boundary.
    o_ref: same shape; halo rows are copied through unchanged.
    """
    x = x_ref[...]
    rp2, n = x.shape

    # Parity mask of interior points (i+j even = red), excluding boundary
    # columns and halo rows.
    rows = jax.lax.broadcasted_iota(jnp.int32, (rp2, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (rp2, n), 1)
    interior = (rows >= 1) & (rows <= rp2 - 2) & (cols >= 1) & (cols <= n - 2)
    red = ((rows + cols) % 2 == 0) & interior
    black = ((rows + cols) % 2 == 1) & interior

    def neighbor_avg(u):
        north = jnp.roll(u, 1, axis=0)
        south = jnp.roll(u, -1, axis=0)
        west = jnp.roll(u, 1, axis=1)
        east = jnp.roll(u, -1, axis=1)
        return 0.25 * (north + south + east + west)

    x_red = jnp.where(red, neighbor_avg(x), x)
    x_black = jnp.where(black, neighbor_avg(x_red), x_red)
    o_ref[...] = x_black


def rb_sweep(strip):
    """Red-black sweep; returns (new_strip, max_abs_delta_over_interior)."""
    new = pl.pallas_call(
        _rb_kernel,
        out_shape=jax.ShapeDtypeStruct(strip.shape, strip.dtype),
        interpret=True,
    )(strip)
    # Convergence metric over the owned rows only (halos belong to peers).
    delta = jnp.max(jnp.abs(new[1:-1, :] - strip[1:-1, :]))
    return new, delta


rb_sweep_jit = jax.jit(rb_sweep)
