"""L2: the JAX compute graphs behind the three case-study kernels.

Each function here is the unit the coordinator executes through PJRT: it is
jitted, calls the L1 Pallas kernels, and is lowered once by aot.py into an
HLO-text artifact per benchmark shape. Python never runs on the request
path — the rust workers execute the compiled artifacts.
"""

import jax
import jax.numpy as jnp

from .kernels.bpmf_pallas import gram_batch
from .kernels.matmul_pallas import matmul_acc
from .kernels.stencil_pallas import rb_sweep


def _cholesky_unrolled(a):
    """Batched Cholesky without LAPACK custom-calls.

    `jnp.linalg.cholesky` lowers to a typed-FFI lapack custom-call that the
    runtime's XLA 0.5.1 cannot execute; K is tiny and static, so the
    outer-product algorithm unrolls into plain HLO ops instead.
    a: (batch, k, k) SPD -> lower factor (batch, k, k).
    """
    k = a.shape[-1]
    idx = jnp.arange(k)
    chol = jnp.zeros_like(a)
    for j in range(k):
        d = jnp.sqrt(a[:, j, j])
        col = a[:, :, j] / d[:, None]
        col = jnp.where((idx >= j)[None, :], col, 0.0)
        chol = chol.at[:, :, j].set(col)
        a = a - col[:, :, None] * col[:, None, :]
    return chol


def _solve_lower(chol, b):
    """L y = b, unrolled forward substitution. b: (batch, k)."""
    k = b.shape[-1]
    ys = []
    for i in range(k):
        s = b[:, i]
        for j in range(i):
            s = s - chol[:, i, j] * ys[j]
        ys.append(s / chol[:, i, i])
    return jnp.stack(ys, axis=-1)


def _solve_upper_t(chol, b):
    """L^T x = b, unrolled back substitution. b: (batch, k)."""
    k = b.shape[-1]
    xs = [None] * k
    for i in reversed(range(k)):
        s = b[:, i]
        for j in range(i + 1, k):
            s = s - chol[:, j, i] * xs[j]
        xs[i] = s / chol[:, i, i]
    return jnp.stack(xs, axis=-1)


def summa_block(a, b, c):
    """SUMMA core phase: C += A_panel @ B_panel (Pallas MXU tiles)."""
    return (matmul_acc(a, b, c),)


def poisson_step(strip):
    """One red-black Gauss-Seidel sweep on a halo-padded strip.

    Returns (new_strip, local_max_delta) — the delta feeds the paper's
    8-byte allreduce convergence check (§5.3.2).
    """
    new, delta = rb_sweep(strip)
    return new, delta


def bpmf_posterior(v, w, alpha, lam0_diag, noise):
    """BPMF Gibbs posterior for a batch of items (§5.3.3).

    v:         (batch, nnz, K) gathered factors (zero-padded)
    w:         (batch, nnz)    rating * mask
    alpha:     ()              observation precision
    lam0_diag: (K,)            prior precision diagonal
    noise:     (batch, K)      standard normal draws

    Returns (batch, K) samples:  Lambda^-1 b + chol(Lambda)^-T eps, with
    Lambda = diag(lam0) + alpha * Gram, b = alpha * lin.
    The Gram hot spot is the Pallas kernel; the small K x K solves stay in
    the fused XLA graph.
    """
    gram, lin = gram_batch(v, w)
    lam = jnp.diag(lam0_diag)[None, :, :] + alpha * gram
    b = alpha * lin
    chol = _cholesky_unrolled(lam)
    # mu = Lambda^-1 b via two triangular solves; sample = mu + L^-T eps.
    mu = _solve_upper_t(chol, _solve_lower(chol, b))
    pert = _solve_upper_t(chol, noise)
    return (mu + pert,)
