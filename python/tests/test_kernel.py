"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Fixed-shape checks plus hypothesis sweeps over shapes/dtypes — the CORE
correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bpmf_pallas import gram_batch
from compile.kernels.matmul_pallas import matmul_acc
from compile.kernels.stencil_pallas import rb_sweep

jax.config.update("jax_enable_x64", True)


def rnd(rng, shape, dtype):
    x = rng.standard_normal(shape)
    return jnp.asarray(x, dtype=dtype)


# ---------------------------------------------------------------- matmul

@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 128), (256, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_matmul_acc_fixed(m, k, n, dtype):
    rng = np.random.default_rng(0)
    a, b, c = rnd(rng, (m, k), dtype), rnd(rng, (k, n), dtype), rnd(rng, (m, n), dtype)
    got = matmul_acc(a, b, c)
    want = ref.matmul_acc_ref(a, b, c)
    tol = 1e-10 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(
    mt=st.integers(1, 3),
    kt=st.integers(1, 3),
    nt=st.integers(1, 3),
    tile=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_acc_hypothesis(mt, kt, nt, tile, seed):
    rng = np.random.default_rng(seed)
    m, k, n = mt * tile, kt * tile, nt * tile
    a, b, c = rnd(rng, (m, k), jnp.float64), rnd(rng, (k, n), jnp.float64), rnd(rng, (m, n), jnp.float64)
    got = matmul_acc(a, b, c, tile=tile)
    want = ref.matmul_acc_ref(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_matmul_tile_mismatch_raises():
    rng = np.random.default_rng(1)
    a = rnd(rng, (100, 100), jnp.float64)  # 100 doesn't tile by 128->100? min() picks 100; 100%100==0 ok
    # A genuinely untileable case: 96x100
    b = rnd(rng, (100, 96), jnp.float64)
    c = rnd(rng, (100, 96), jnp.float64)
    with pytest.raises(AssertionError):
        matmul_acc(a, b, c, tile=64)


# ---------------------------------------------------------------- stencil

@pytest.mark.parametrize("rows,n", [(4, 16), (8, 64), (16, 256)])
def test_rb_sweep_fixed(rows, n):
    rng = np.random.default_rng(2)
    strip = rnd(rng, (rows + 2, n), jnp.float64)
    got, gd = rb_sweep(strip)
    want, wd = ref.rb_sweep_ref(strip)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(gd, wd, rtol=1e-12)


def test_rb_sweep_halo_untouched():
    rng = np.random.default_rng(3)
    strip = rnd(rng, (6, 32), jnp.float64)
    got, _ = rb_sweep(strip)
    np.testing.assert_array_equal(got[0], strip[0])
    np.testing.assert_array_equal(got[-1], strip[-1])
    np.testing.assert_array_equal(got[:, 0], strip[:, 0])
    np.testing.assert_array_equal(got[:, -1], strip[:, -1])


def test_rb_sweep_converges_to_laplace():
    # Fixed boundary = 1, interior 0: repeated sweeps approach u = 1.
    n = 16
    strip = jnp.ones((n, n), dtype=jnp.float64)
    strip = strip.at[1:-1, 1:-1].set(0.0)
    for _ in range(200):
        strip, delta = rb_sweep(strip)
    assert float(delta) < 1e-3
    np.testing.assert_allclose(strip, jnp.ones_like(strip), atol=2e-2)


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(2, 10), n=st.integers(4, 48), seed=st.integers(0, 2**31 - 1))
def test_rb_sweep_hypothesis(rows, n, seed):
    rng = np.random.default_rng(seed)
    strip = rnd(rng, (rows + 2, n), jnp.float64)
    got, gd = rb_sweep(strip)
    want, wd = ref.rb_sweep_ref(strip)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(gd, wd, rtol=1e-12)


# ---------------------------------------------------------------- bpmf gram

@pytest.mark.parametrize("batch,nnz,k", [(32, 16, 10), (64, 32, 10), (32, 8, 4)])
def test_gram_batch_fixed(batch, nnz, k):
    rng = np.random.default_rng(4)
    v = rnd(rng, (batch, nnz, k), jnp.float64)
    w = rnd(rng, (batch, nnz), jnp.float64)
    gg, gl = gram_batch(v, w)
    wg, wl = ref.gram_batch_ref(v, w)
    np.testing.assert_allclose(gg, wg, rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(gl, wl, rtol=1e-10, atol=1e-10)


def test_gram_batch_psd():
    # Gram matrices must be symmetric PSD.
    rng = np.random.default_rng(5)
    v = rnd(rng, (32, 16, 6), jnp.float64)
    w = jnp.ones((32, 16), dtype=jnp.float64)
    gg, _ = gram_batch(v, w)
    np.testing.assert_allclose(gg, jnp.swapaxes(gg, -1, -2), atol=1e-12)
    eigs = np.linalg.eigvalsh(np.asarray(gg))
    assert (eigs > -1e-9).all()


@settings(max_examples=15, deadline=None)
@given(
    bt=st.integers(1, 3),
    nnz=st.integers(1, 24),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_batch_hypothesis(bt, nnz, k, seed):
    rng = np.random.default_rng(seed)
    batch = bt * 32
    v = rnd(rng, (batch, nnz, k), jnp.float64)
    w = rnd(rng, (batch, nnz), jnp.float64)
    gg, gl = gram_batch(v, w)
    wg, wl = ref.gram_batch_ref(v, w)
    np.testing.assert_allclose(gg, wg, rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(gl, wl, rtol=1e-10, atol=1e-10)
