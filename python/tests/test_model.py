"""L2 correctness: model graphs (which call the Pallas kernels) against
straightforward jnp math, plus AOT manifest shape checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_enable_x64", True)


def test_summa_block_is_matmul_acc():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((64, 64)))
    b = jnp.asarray(rng.standard_normal((64, 64)))
    c = jnp.asarray(rng.standard_normal((64, 64)))
    (got,) = model.summa_block(a, b, c)
    np.testing.assert_allclose(got, c + a @ b, rtol=1e-10, atol=1e-10)


def test_poisson_step_shrinks_residual():
    n = 32
    strip = jnp.ones((n, n), dtype=jnp.float64)
    strip = strip.at[1:-1, 1:-1].set(0.0)
    _, d1 = model.poisson_step(strip)
    s2, _ = model.poisson_step(strip)
    for _ in range(50):
        s2, d2 = model.poisson_step(s2)
    assert float(d2) < float(d1)


def test_bpmf_posterior_recovers_mean_when_noise_zero():
    """With zero noise the sample equals Lambda^-1 b; check against numpy."""
    rng = np.random.default_rng(1)
    batch, nnz, k = 32, 8, 5
    v = jnp.asarray(rng.standard_normal((batch, nnz, k)))
    w = jnp.asarray(rng.standard_normal((batch, nnz)))
    alpha = jnp.asarray(2.0)
    lam0 = jnp.asarray(np.full(k, 1.5))
    noise = jnp.zeros((batch, k))
    (got,) = model.bpmf_posterior(v, w, alpha, lam0, noise)
    v_np, w_np = np.asarray(v), np.asarray(w)
    for i in range(batch):
        lam = np.diag(lam0) + 2.0 * v_np[i].T @ v_np[i]
        b = 2.0 * v_np[i].T @ w_np[i]
        mu = np.linalg.solve(lam, b)
        np.testing.assert_allclose(np.asarray(got[i]), mu, rtol=1e-8, atol=1e-8)


def test_bpmf_noise_perturbs_with_posterior_covariance():
    rng = np.random.default_rng(2)
    batch, nnz, k = 32, 8, 4
    v = jnp.asarray(rng.standard_normal((batch, nnz, k)))
    w = jnp.asarray(rng.standard_normal((batch, nnz)))
    alpha = jnp.asarray(1.0)
    lam0 = jnp.asarray(np.ones(k))
    eps = jnp.asarray(rng.standard_normal((batch, k)))
    (with_noise,) = model.bpmf_posterior(v, w, alpha, lam0, eps)
    (mean_only,) = model.bpmf_posterior(v, w, alpha, lam0, jnp.zeros((batch, k)))
    diff = np.asarray(with_noise - mean_only)
    assert np.abs(diff).max() > 1e-3  # noise actually flows through


def test_artifact_set_covers_benchmarks():
    names = set(aot.artifact_set().keys())
    assert {"summa256", "summa64", "poisson_r16_n256", "poisson_r8_n512",
            "poisson_r4_n1024", "bpmf_b64_n32_k10"} <= names


def test_artifact_lowering_produces_hlo_text():
    sets = aot.artifact_set()
    fn, specs = sets["summa64"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64" in text
