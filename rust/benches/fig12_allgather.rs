//! `cargo bench` target for Fig. 12 (allgather vs nodes).
//!
//! Two parts: (1) wall-clock of regenerating the figure's data (fast
//! mode — full paper scale runs via `hympi figures fig12`), and
//! (2) criterion-style micro timings of the hot collective(s) involved,
//! measured in real time on the simulated cluster engine.

use hympi::figures::{self, FigOpts};
use hympi::util::BenchRunner;

fn main() {
    std::env::set_var("HYMPI_BENCH_FAST", "1");
    let mut r = BenchRunner::new();
    let opts = FigOpts { out_dir: "reports/bench".into(), scale: 0.25, fast: true };
    r.run_once("fig12: regenerate (fast mode)", || {
        figures::run("fig12", &opts).expect("figure generation");
    });

    use hympi::coordinator::{ClusterSpec, Preset};
    use hympi::hybrid::SyncScheme;
    r.bench("fig12: hybrid allgather 800B @2 nodes (wall)", || {
        let spec = ClusterSpec::preset(Preset::HazelHen, 2);
        hympi::figures::common::hy_allgather(spec, 800, SyncScheme::Spin, true);
    });
}
