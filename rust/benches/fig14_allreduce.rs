//! `cargo bench` target for Fig. 14 (allreduce grid).
//!
//! Two parts: (1) wall-clock of regenerating the figure's data (fast
//! mode — full paper scale runs via `hympi figures fig14`), and
//! (2) criterion-style micro timings of the hot collective(s) involved,
//! measured in real time on the simulated cluster engine.

use hympi::figures::{self, FigOpts};
use hympi::util::BenchRunner;

fn main() {
    std::env::set_var("HYMPI_BENCH_FAST", "1");
    let mut r = BenchRunner::new();
    let opts = FigOpts { out_dir: "reports/bench".into(), scale: 0.25, fast: true };
    r.run_once("fig14: regenerate (fast mode)", || {
        figures::run("fig14", &opts).expect("figure generation");
    });

    use hympi::coordinator::{ClusterSpec, Preset};
    use hympi::hybrid::{AllreduceMethod, SyncScheme};
    r.bench("fig14: hybrid allreduce 4KB @4 nodes (wall)", || {
        let spec = ClusterSpec::preset(Preset::VulcanSb, 4);
        hympi::figures::common::hy_allreduce(spec, 4096, AllreduceMethod::Method1, SyncScheme::Barrier, true);
    });
}
