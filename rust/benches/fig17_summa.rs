//! `cargo bench` target for Fig. 17 (SUMMA).
//!
//! Two parts: (1) wall-clock of regenerating the figure's data (fast
//! mode — full paper scale runs via `hympi figures fig17`), and
//! (2) criterion-style micro timings of the hot collective(s) involved,
//! measured in real time on the simulated cluster engine.

use hympi::figures::{self, FigOpts};
use hympi::util::BenchRunner;

fn main() {
    std::env::set_var("HYMPI_BENCH_FAST", "1");
    let mut r = BenchRunner::new();
    let opts = FigOpts { out_dir: "reports/bench".into(), scale: 0.25, fast: true };
    r.run_once("fig17: regenerate (fast mode)", || {
        figures::run("fig17", &opts).expect("figure generation");
    });

    use hympi::coordinator::{ClusterSpec, Preset};
    use hympi::kernels::{summa, Backend, Variant};
    r.run_once("fig17: SUMMA 256^2 hybrid @1 node (wall)", || {
        let spec = ClusterSpec::preset(Preset::VulcanSb, 1);
        summa::run(spec, summa::SummaCfg { n: 256, variant: Variant::HybridMpiMpi, backend: Backend::auto(), threads: 16 });
    });
}
