//! `cargo bench` target for Fig. 18 (2D Poisson).
//!
//! Two parts: (1) wall-clock of regenerating the figure's data (fast
//! mode — full paper scale runs via `hympi figures fig18`), and
//! (2) criterion-style micro timings of the hot collective(s) involved,
//! measured in real time on the simulated cluster engine.

use hympi::figures::{self, FigOpts};
use hympi::util::BenchRunner;

fn main() {
    std::env::set_var("HYMPI_BENCH_FAST", "1");
    let mut r = BenchRunner::new();
    let opts = FigOpts { out_dir: "reports/bench".into(), scale: 0.25, fast: true };
    r.run_once("fig18: regenerate (fast mode)", || {
        figures::run("fig18", &opts).expect("figure generation");
    });

    use hympi::coordinator::{ClusterSpec, Preset};
    use hympi::kernels::{poisson, Backend, Variant};
    r.run_once("fig18: Poisson 64^2 hybrid, 40 iters (wall)", || {
        let spec = ClusterSpec::preset(Preset::VulcanSb, 1);
        let cfg = poisson::PoissonCfg { n: 64, tol: 0.0, max_iters: 40, variant: Variant::HybridMpiMpi, backend: Backend::auto(), threads: 16 };
        poisson::run(spec, cfg);
    });
}
