//! `cargo bench` target for Fig. 19 (BPMF strong scaling).
//!
//! Two parts: (1) wall-clock of regenerating the figure's data (fast
//! mode — full paper scale runs via `hympi figures fig19`), and
//! (2) criterion-style micro timings of the hot collective(s) involved,
//! measured in real time on the simulated cluster engine.

use hympi::figures::{self, FigOpts};
use hympi::util::BenchRunner;

fn main() {
    std::env::set_var("HYMPI_BENCH_FAST", "1");
    let mut r = BenchRunner::new();
    let opts = FigOpts { out_dir: "reports/bench".into(), scale: 0.25, fast: true };
    r.run_once("fig19: regenerate (fast mode)", || {
        figures::run("fig19", &opts).expect("figure generation");
    });

    use hympi::coordinator::{ClusterSpec, Preset};
    use hympi::kernels::{bpmf, Backend, Variant};
    r.run_once("fig19: BPMF tiny hybrid @2 nodes (wall)", || {
        let spec = ClusterSpec::preset(Preset::HazelHen, 2);
        let cfg = bpmf::BpmfCfg { compounds: 768, targets: 48, k: 10, nnz: 16, iters: 3, variant: Variant::HybridMpiMpi, backend: Backend::auto(), threads: 24 };
        bpmf::run(spec, cfg);
    });
}
