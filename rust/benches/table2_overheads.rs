//! `cargo bench` target for Table 2 (one-off wrapper overheads).
//!
//! Two parts: (1) wall-clock of regenerating the figure's data (fast
//! mode — full paper scale runs via `hympi figures table2`), and
//! (2) criterion-style micro timings of the hot collective(s) involved,
//! measured in real time on the simulated cluster engine.

use hympi::figures::{self, FigOpts};
use hympi::util::BenchRunner;

fn main() {
    std::env::set_var("HYMPI_BENCH_FAST", "1");
    let mut r = BenchRunner::new();
    let opts = FigOpts { out_dir: "reports/bench".into(), scale: 0.25, fast: true };
    r.run_once("table2: regenerate (fast mode)", || {
        figures::run("table2", &opts).expect("figure generation");
    });

    // Hot path: session creation mechanics at 64 ranks.
    r.bench("table2: HybridCtx::create @64 ranks (wall)", || {
        hympi::figures::table2::measure(64);
    });
}
