//! The stateless model-checking engine (DESIGN.md §6c).
//!
//! [`explore`] executes a [`Model`] — any explicit-state transition
//! system with enabled-transition semantics — over *every* interleaving
//! of its processes, in the stateless-model-checking tradition of
//! VeriSoft/loom-style checkers. Three reduction strategies:
//!
//! - [`Reduction::Exhaustive`] — full state enumeration with a
//!   state-hash visited set. Because the systems checked here are
//!   acyclic (every transition strictly advances a program counter or a
//!   monotone protocol counter), visiting every *distinct state* once is
//!   sound **and complete** for state-local properties (deadlock,
//!   co-enabled conflicts, per-process invariants): every reachable
//!   state is checked exactly once. This is the oracle mode the mutation
//!   tests cross-check the reduced modes against.
//! - [`Reduction::Dpor`] — classic Flanagan–Godefroid dynamic
//!   partial-order reduction: persistent sets built by dynamic
//!   backtrack-point insertion at the last dependent transition, plus
//!   sleep sets. Stateless (no visited set); sound for deadlocks and
//!   per-process local assertions by the standard DPOR theorems.
//! - [`Reduction::DporCached`] — `Dpor` plus a state-hash cache: a
//!   state revisited with a sleep set no smaller than a cached visit is
//!   pruned. Naive caching under DPOR is unsound (the pruned subtree can
//!   no longer contribute backtrack points to the *current* prefix — the
//!   stateful-DPOR problem), so every prune applies the conservative
//!   repair: all enabled transitions of every frame on the current path
//!   are added to that frame's backtrack set, which dominates any
//!   insertion the skipped subtree could have made. The cache therefore
//!   trades subtree re-execution for extra ancestor exploration and
//!   stays sound; the cache size is capped by [`Budget::max_states`].
//!
//! Requirements on a [`Model`]: the transition system must be **acyclic**
//! (explore does not detect cycles — a cyclic model diverges until the
//! budget trips), and [`Model::dependent`] must be reflexive over a
//! process (same-process actions are always dependent) and include
//! enabling ("a can enable or disable b" implies dependent).
//!
//! A failed check comes back as a [`Counterexample`]: the violation plus
//! the exact interleaving that produced it, shortened by a bounded BFS
//! over the trace's own per-process projections and re-executable with
//! [`replay`] (the tests assert every emitted trace reproduces its
//! violation).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};

/// What a model check can report. `Display` is the operator-facing text
/// `verify_schedules --explore` prints above the trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// No transition is enabled but some process has work left — and no
    /// process died, so the runtime would hang rather than surface
    /// `Err(RankFailed)`.
    Deadlock { blocked: Vec<String> },
    /// Two conflicting window accesses are enabled in the same state —
    /// nothing orders them (sound and complete under
    /// [`Reduction::Exhaustive`] on an acyclic model).
    Conflict { first: String, second: String },
    /// A protocol invariant broke (shrink-agreement checks).
    Protocol { detail: String },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::Deadlock { blocked } => {
                write!(f, "deadlock — blocked: {}", blocked.join("; "))
            }
            Violation::Conflict { first, second } => {
                write!(f, "unordered conflicting accesses: {first} / {second}")
            }
            Violation::Protocol { detail } => write!(f, "protocol violation: {detail}"),
        }
    }
}

/// An explicit-state transition system the engine can run.
pub trait Model {
    type State: Clone + Hash;
    type Action: Clone + PartialEq + fmt::Debug;

    fn initial(&self) -> Self::State;
    /// All transitions enabled in `s`. An empty result marks a terminal
    /// state ([`Model::check`] classifies it as clean or violating).
    fn enabled(&self, s: &Self::State) -> Vec<Self::Action>;
    /// Execute one enabled transition. Must be deterministic.
    fn step(&self, s: &Self::State, a: &Self::Action) -> Self::State;
    /// The process an action belongs to (the unit of interleaving).
    fn proc_of(&self, a: &Self::Action) -> usize;
    /// May `a` and `b` fail to commute (or enable/disable each other)?
    /// Must return `true` whenever `proc_of` agrees.
    fn dependent(&self, a: &Self::Action, b: &Self::Action) -> bool;
    /// Check `s` (with its enabled set, so terminal states are
    /// classifiable). Checks must be *state-local* — a function of `s`
    /// alone, not of the path that reached it.
    fn check(&self, s: &Self::State, enabled: &[Self::Action]) -> Option<Violation>;
    /// Human-readable action label for traces.
    fn describe(&self, a: &Self::Action) -> String;
}

/// Reduction strategy — see the module docs for the soundness story.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduction {
    Exhaustive,
    Dpor,
    DporCached,
}

/// Exploration bounds. `max_transitions` caps executed steps,
/// `max_states` caps the visited/cache set ([`Reduction::Exhaustive`]
/// stops at the cap; `DporCached` merely stops caching). A tripped
/// transition cap clears [`ExploreReport::complete`].
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    pub max_transitions: usize,
    pub max_states: usize,
}

impl Budget {
    /// The CI smoke budget (`verify_schedules --explore --smoke`).
    pub fn smoke() -> Budget {
        Budget { max_transitions: 400_000, max_states: 200_000 }
    }

    /// The full-sweep budget documented for toolchain'd runs.
    pub fn full() -> Budget {
        Budget { max_transitions: 8_000_000, max_states: 2_000_000 }
    }
}

/// A violation plus the interleaving that produced it: `trace` is
/// re-executable with [`replay`], `steps` the described transitions.
#[derive(Clone, Debug)]
pub struct Counterexample<A> {
    pub violation: Violation,
    pub trace: Vec<A>,
    pub steps: Vec<String>,
}

impl<A> fmt::Display for Counterexample<A> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.violation)?;
        writeln!(f, "minimal interleaving ({} steps):", self.steps.len())?;
        for (i, s) in self.steps.iter().enumerate() {
            writeln!(f, "  {i:3}. {s}")?;
        }
        Ok(())
    }
}

/// What an exploration did. `complete` means the stated bounds were not
/// tripped — together with a `None` counterexample that is the
/// exhaustiveness claim (under the mode's reduction) the CI gate rests
/// on.
#[derive(Clone, Debug)]
pub struct ExploreReport<A> {
    pub transitions: usize,
    /// Distinct state hashes seen (Exhaustive/DporCached) or states
    /// pushed (Dpor).
    pub states: usize,
    /// Maximal (terminal) states reached.
    pub terminals: usize,
    /// Branches cut by the state cache (`DporCached` only).
    pub dedup_prunes: usize,
    pub complete: bool,
    pub counterexample: Option<Counterexample<A>>,
}

fn hash_of<T: Hash>(t: &T) -> u64 {
    let mut h = DefaultHasher::new();
    t.hash(&mut h);
    h.finish()
}

struct Frame<S, A> {
    state: S,
    enabled: Vec<A>,
    /// Actions scheduled for exploration from this state (grows via
    /// dynamic backtrack-point insertion; Exhaustive schedules all).
    backtrack: Vec<A>,
    done: Vec<A>,
    sleep: Vec<A>,
    /// The action currently being explored (the edge to the frame
    /// above) — the trace is the chain of `chosen` down the stack.
    chosen: Option<A>,
}

/// Run `model` to completion (or budget) under `reduction`.
pub fn explore<M: Model>(model: &M, reduction: Reduction, budget: &Budget) -> ExploreReport<M::Action> {
    let mut report = ExploreReport {
        transitions: 0,
        states: 1,
        terminals: 0,
        dedup_prunes: 0,
        complete: true,
        counterexample: None,
    };
    // state hash -> sleep sets (as action-hash sets) it was visited with.
    // Exhaustive stores one empty entry per state and prunes every
    // revisit; DporCached prunes only sleep-superset revisits.
    let mut cache: HashMap<u64, Vec<Vec<u64>>> = HashMap::new();
    let dpor = matches!(reduction, Reduction::Dpor | Reduction::DporCached);
    let cached = matches!(reduction, Reduction::Exhaustive | Reduction::DporCached);

    let s0 = model.initial();
    let en0 = model.enabled(&s0);
    if let Some(v) = model.check(&s0, &en0) {
        report.counterexample = Some(Counterexample { violation: v, trace: Vec::new(), steps: Vec::new() });
        return report;
    }
    if en0.is_empty() {
        report.terminals = 1;
        return report;
    }
    if cached {
        cache.insert(hash_of(&s0), vec![Vec::new()]);
    }
    let bt0 = if dpor { vec![en0[0].clone()] } else { en0.clone() };
    let mut stack: Vec<Frame<M::State, M::Action>> =
        vec![Frame { state: s0, enabled: en0, backtrack: bt0, done: Vec::new(), sleep: Vec::new(), chosen: None }];

    'outer: while let Some(top) = stack.last() {
        let pick = top
            .backtrack
            .iter()
            .find(|a| !top.done.contains(a) && !top.sleep.contains(a))
            .cloned();
        let Some(a) = pick else {
            // This frame is exhausted: pop, and record its in-edge in the
            // parent's sleep set (its subtree is fully explored).
            stack.pop();
            if let Some(parent) = stack.last_mut() {
                let done = parent.chosen.take().expect("a popped frame has an in-edge");
                if dpor {
                    parent.sleep.push(done);
                }
            }
            continue;
        };
        if dpor {
            // Flanagan–Godefroid backtrack-point insertion: find the last
            // earlier transition dependent with `a` from another process
            // and schedule `a`'s process (or, if not enabled there,
            // everything) at that frame.
            let proc = model.proc_of(&a);
            for i in (0..stack.len() - 1).rev() {
                let dep = {
                    let ch = stack[i].chosen.as_ref().expect("inner frames have in-edges");
                    model.dependent(ch, &a) && model.proc_of(ch) != proc
                };
                if dep {
                    let fi = &mut stack[i];
                    let alts: Vec<M::Action> =
                        fi.enabled.iter().filter(|e| model.proc_of(e) == proc).cloned().collect();
                    let adds = if alts.is_empty() { fi.enabled.clone() } else { alts };
                    for e in adds {
                        if !fi.backtrack.contains(&e) {
                            fi.backtrack.push(e);
                        }
                    }
                    break;
                }
            }
        }
        if report.transitions >= budget.max_transitions {
            report.complete = false;
            break 'outer;
        }
        report.transitions += 1;
        let top = stack.last_mut().expect("loop guard holds the stack non-empty");
        let next = model.step(&top.state, &a);
        top.done.push(a.clone());
        top.chosen = Some(a.clone());
        let child_sleep: Vec<M::Action> = if dpor {
            top.sleep.iter().filter(|b| !model.dependent(b, &a)).cloned().collect()
        } else {
            Vec::new()
        };

        if cached {
            let h = hash_of(&next);
            let sleep_hashes: Vec<u64> = child_sleep.iter().map(hash_of).collect();
            let prune = cache.get(&h).is_some_and(|seen| {
                seen.iter().any(|s| s.iter().all(|x| sleep_hashes.contains(x)))
            });
            if prune {
                report.dedup_prunes += 1;
                if reduction == Reduction::DporCached {
                    // The skipped subtree can no longer insert backtrack
                    // points into this path — over-approximate them all.
                    for fi in stack.iter_mut() {
                        for e in fi.enabled.clone() {
                            if !fi.backtrack.contains(&e) {
                                fi.backtrack.push(e);
                            }
                        }
                    }
                }
                let top = stack.last_mut().expect("stack non-empty while pruning");
                let done = top.chosen.take().expect("prune follows an execution");
                if dpor {
                    top.sleep.push(done);
                }
                continue;
            }
            if cache.len() < budget.max_states {
                cache.entry(h).or_default().push(sleep_hashes);
                report.states = cache.len();
            } else if reduction == Reduction::Exhaustive {
                // Exhaustive soundness rests on the visited set; at the
                // cap the claim is gone, so stop rather than mislead.
                report.complete = false;
                break 'outer;
            }
        } else {
            report.states += 1;
        }

        let en = model.enabled(&next);
        if let Some(v) = model.check(&next, &en) {
            let mut trace: Vec<M::Action> =
                stack.iter().filter_map(|f| f.chosen.clone()).collect();
            trace = shorten(model, &trace, budget);
            let steps = trace.iter().map(|x| model.describe(x)).collect();
            let violation = replay(model, &trace).unwrap_or(v);
            report.counterexample = Some(Counterexample { violation, trace, steps });
            break 'outer;
        }
        if en.is_empty() {
            report.terminals += 1;
            let top = stack.last_mut().expect("stack non-empty at a terminal");
            let done = top.chosen.take().expect("terminal follows an execution");
            if dpor {
                top.sleep.push(done);
            }
            continue;
        }
        let bt = if dpor {
            match en.iter().find(|e| !child_sleep.contains(e)) {
                Some(first) => vec![first.clone()],
                None => {
                    // Every enabled transition is asleep: this trace is
                    // covered elsewhere — a sleep-blocked leaf.
                    let top = stack.last_mut().expect("stack non-empty at a leaf");
                    let done = top.chosen.take().expect("leaf follows an execution");
                    top.sleep.push(done);
                    continue;
                }
            }
        } else {
            en.clone()
        };
        stack.push(Frame {
            state: next,
            enabled: en,
            backtrack: bt,
            done: Vec::new(),
            sleep: child_sleep,
            chosen: None,
        });
    }
    report
}

/// Re-execute a recorded interleaving and return the violation its final
/// state (or any prefix state) checks to. Returns `None` — and thereby
/// fails the caller's assertion — if the trace no longer reproduces.
pub fn replay<M: Model>(model: &M, trace: &[M::Action]) -> Option<Violation> {
    let mut s = model.initial();
    let mut en = model.enabled(&s);
    if let Some(v) = model.check(&s, &en) {
        return Some(v);
    }
    for a in trace {
        if !en.contains(a) {
            return None;
        }
        s = model.step(&s, a);
        en = model.enabled(&s);
        if let Some(v) = model.check(&s, &en) {
            return Some(v);
        }
    }
    None
}

/// Shorten a violating trace to a minimal interleaving: BFS over the
/// per-process projections of the trace itself (each BFS node is a
/// vector of per-process prefix lengths), stopping at the first — hence
/// shortest — state that checks to a violation. The violating endpoint
/// is in this space, so within budget the result is never longer than
/// the input; on budget exhaustion the input comes back unchanged.
fn shorten<M: Model>(model: &M, trace: &[M::Action], budget: &Budget) -> Vec<M::Action> {
    use std::collections::{HashSet, VecDeque};
    if trace.is_empty() {
        return Vec::new();
    }
    let mut procs: Vec<usize> = trace.iter().map(|a| model.proc_of(a)).collect();
    procs.sort_unstable();
    procs.dedup();
    let proj: Vec<Vec<&M::Action>> = procs
        .iter()
        .map(|&p| trace.iter().filter(|a| model.proc_of(a) == p).collect())
        .collect();
    let mut queue: VecDeque<(Vec<usize>, M::State, Vec<M::Action>)> = VecDeque::new();
    let mut seen: HashSet<Vec<usize>> = HashSet::new();
    let start = vec![0usize; proj.len()];
    seen.insert(start.clone());
    queue.push_back((start, model.initial(), Vec::new()));
    let mut visited = 0usize;
    while let Some((idx, state, path)) = queue.pop_front() {
        visited += 1;
        if visited > budget.max_states {
            return trace.to_vec();
        }
        for (pi, pj) in proj.iter().enumerate() {
            let Some(&a) = pj.get(idx[pi]) else { continue };
            if !model.enabled(&state).contains(a) {
                continue;
            }
            let mut nidx = idx.clone();
            nidx[pi] += 1;
            if !seen.insert(nidx.clone()) {
                continue;
            }
            let ns = model.step(&state, a);
            let mut np = path.clone();
            np.push(a.clone());
            if model.check(&ns, &model.enabled(&ns)).is_some() {
                return np;
            }
            queue.push_back((nidx, ns, np));
        }
    }
    trace.to_vec()
}
