//! Exhaustive interleaving exploration of hybrid schedules and the
//! shrink/recovery protocol (DESIGN.md §6c).
//!
//! Two [`Model`]s for the [`dpor`](crate::analysis::dpor) engine:
//!
//! - [`ScheduleModel`] — executes exported [`RankSchedule`]s as a
//!   transition system. [`lower_program`] breaks every stage into
//!   single-rank [`MicroStep`]s whose enabled-predicates mirror the
//!   runtime primitives: `Arrive` registers and never blocks, `Await`
//!   blocks until the registered barrier generation closes, yellow
//!   `Post`/`Wait` is a one-way release, bridge messages are eager sends
//!   into per-`(comm, src, dst, tag)` FIFO channels with genuine
//!   match-order choice points (any non-empty channel's receive may fire
//!   in any interleaving), and nested collectives are rendezvous —
//!   nobody leaves an episode before everybody entered it. Optional
//!   fault choice points kill a rank before any of its remaining stages,
//!   drawn from a bounded kill-set. The checker proves deadlock-freedom
//!   (a stuck state with *no* dead rank — a stuck state behind a death
//!   is a *detected failure*, which the runtime surfaces as
//!   `Err(RankFailed)`, and is counted as a terminal, not a violation)
//!   and, under [`Reduction::Exhaustive`], absence of co-enabled
//!   conflicting window accesses.
//! - [`ShrinkModel`] — a protocol model of
//!   [`HybridCtx::shrink`](crate::hybrid::HybridCtx::shrink)'s
//!   epoch-tagged agreement (ISSUE 8): coordinator = lowest survivor,
//!   scope = [`shrink_scope_key`] over the survivor set, children
//!   send scope-tagged requests, the coordinator collects one per
//!   survivor and replies with the agreed comm id, scope-mismatched
//!   traffic is discarded on receipt, and any side whose scope went
//!   stale (a death registered) restarts the round. Checked invariants:
//!   no stale-scope message is ever *accepted*, no two survivors agree
//!   on the same scope with different comm ids (split-brain), every
//!   interleaving of ≤ `max_kills` overlapping deaths converges to
//!   agreement on the true survivor set, and — when a
//!   [`RootPolicy::Reelect`](crate::hybrid::RootPolicy) root is
//!   configured — the election hook lands on the lowest survivor of the
//!   dead root's node. [`ShrinkMutation`] knobs re-introduce the bugs
//!   the protocol exists to prevent, for counterexample tests.
//!
//! Both models are deliberately coarse where the verifier or the runtime
//! detector is the better tool — see DESIGN.md §6c "what is not
//! modeled".

use super::dpor::{Model, Violation};
use super::schedule::{lower_program, ChanId, FlagId, GroupId, MicroOp, MicroStep, RankSchedule};
use crate::hybrid::{shrink_scope_key, ElectRoot, Reelection};
use std::collections::BTreeMap;

// ====================================================================
// Schedule execution model
// ====================================================================

/// Barrier group runtime state as commutative monotone counters:
/// `arrived[p]` / `awaited[p]` count p's registrations and completions.
/// p's outstanding registration (the `arrived[p]`-th) completes once
/// every member's `arrived` reaches it — same-group arrivals by
/// different ranks therefore commute in *every* state, which is what
/// lets [`Model::dependent`] declare them independent and DPOR collapse
/// the `n!` arrival orders of an episode to one representative.
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
struct GroupSt {
    arrived: BTreeMap<usize, u32>,
    awaited: BTreeMap<usize, u32>,
}

/// Yellow flag state: cumulative posts, per-observer consumed waits.
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
struct FlagSt {
    posts: u32,
    waited: BTreeMap<usize, u32>,
}

/// Rendezvous state per nested-collective comm: per-proc episode entry
/// and leave counts.
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
struct CollSt {
    entered: BTreeMap<usize, u32>,
    left: BTreeMap<usize, u32>,
}

/// Global state of a schedule execution: per-proc program counters,
/// liveness, and every sync object's runtime state. Zero-count channel
/// entries are removed so equal behaviors hash equal.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SchedState {
    pc: Vec<u32>,
    alive: Vec<bool>,
    groups: BTreeMap<GroupId, GroupSt>,
    flags: BTreeMap<FlagId, FlagSt>,
    chans: BTreeMap<ChanId, u32>,
    colls: BTreeMap<u64, CollSt>,
}

/// One transition: execute a proc's next micro-op, or kill it at its
/// current position (a fault choice point). `pc` is carried so every
/// distinct choice point is a distinct action for the DPOR identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchedAction {
    Exec { proc: usize, pc: u32 },
    Die { proc: usize, pc: u32 },
}

/// The schedule transition system. Build with [`ScheduleModel::from_handle`]
/// or [`ScheduleModel::from_program`], opt into fault choice points with
/// [`ScheduleModel::with_kills`] and co-enabled conflict checking with
/// [`ScheduleModel::with_conflict_check`] (meaningful under
/// [`Reduction::Exhaustive`], where every reachable state is visited —
/// under DPOR reductions it is a heuristic, and k ≥ 2 exports
/// over-approximate striped leader accesses to full-range unions, so the
/// conflict check is reserved for k = 1 models; the runtime
/// happens-before detector owns exact race checking).
///
/// [`Reduction::Exhaustive`]: crate::analysis::dpor::Reduction::Exhaustive
pub struct ScheduleModel {
    ranks: Vec<usize>,
    progs: Vec<Vec<MicroStep>>,
    /// Per barrier group: the procs that arrive at it (its members, as
    /// lowered — a rank whose Arrive was dropped is *not* a member, so
    /// the others close without it and its Await deadlocks, which is
    /// exactly the dynamic consequence of the corruption).
    group_members: BTreeMap<GroupId, Vec<usize>>,
    coll_parts: BTreeMap<u64, Vec<usize>>,
    kill_set: Vec<usize>,
    max_kills: u8,
    check_conflicts: bool,
}

impl ScheduleModel {
    /// Model a program of overlapping in-flight handles (the
    /// [`verify_program`](super::schedule::verify_program) input shape).
    pub fn from_program(handles: &[&[RankSchedule]]) -> ScheduleModel {
        let lowered = lower_program(handles);
        let ranks: Vec<usize> = lowered.keys().copied().collect();
        let progs: Vec<Vec<MicroStep>> = lowered.into_values().collect();
        let mut coll_parts: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut group_members: BTreeMap<GroupId, Vec<usize>> = BTreeMap::new();
        for (p, prog) in progs.iter().enumerate() {
            for ms in prog {
                match ms.micro {
                    MicroOp::CollEnter { comm, .. } => {
                        let parts = coll_parts.entry(comm).or_default();
                        if !parts.contains(&p) {
                            parts.push(p);
                        }
                    }
                    MicroOp::Arrive { group, .. } => {
                        let mem = group_members.entry(group).or_default();
                        if !mem.contains(&p) {
                            mem.push(p);
                        }
                    }
                    _ => {}
                }
            }
        }
        ScheduleModel {
            ranks,
            progs,
            group_members,
            coll_parts,
            kill_set: Vec::new(),
            max_kills: 0,
            check_conflicts: false,
        }
    }

    /// Model one handle's all-rank schedule set.
    pub fn from_handle(ranks: &[RankSchedule]) -> ScheduleModel {
        ScheduleModel::from_program(&[ranks])
    }

    /// Enable fault choice points: any of `ranks` (schedule rank ids)
    /// may die before any of its remaining micro-ops, at most
    /// `max_kills` deaths per execution.
    pub fn with_kills(mut self, ranks: &[usize], max_kills: u8) -> ScheduleModel {
        self.kill_set = ranks
            .iter()
            .filter_map(|r| self.ranks.iter().position(|x| x == r))
            .collect();
        self.max_kills = max_kills;
        self
    }

    /// Also report co-enabled conflicting window accesses.
    pub fn with_conflict_check(mut self) -> ScheduleModel {
        self.check_conflicts = true;
        self
    }

    fn micro_enabled(&self, s: &SchedState, p: usize, m: &MicroOp) -> bool {
        let cnt = |m: &BTreeMap<usize, u32>, q: usize| m.get(&q).copied().unwrap_or(0);
        match *m {
            // No double registration while one is outstanding.
            MicroOp::Arrive { group, .. } => s
                .groups
                .get(&group)
                .map_or(true, |g| cnt(&g.arrived, p) == cnt(&g.awaited, p)),
            // My outstanding (`arrived[p]`-th) registration completes once
            // every member has arrived that often — the generation closed.
            MicroOp::AwaitGroup { group } => s.groups.get(&group).is_some_and(|g| {
                let a = cnt(&g.arrived, p);
                cnt(&g.awaited, p) < a
                    && self
                        .group_members
                        .get(&group)
                        .is_some_and(|mem| mem.iter().all(|&q| cnt(&g.arrived, q) >= a))
            }),
            MicroOp::WaitFlag { flag } => s
                .flags
                .get(&flag)
                .is_some_and(|f| f.posts > f.waited.get(&p).copied().unwrap_or(0)),
            MicroOp::Recv { chan } => s.chans.get(&chan).copied().unwrap_or(0) > 0,
            MicroOp::CollLeave { comm } => {
                let st = s.colls.get(&comm);
                let round = st.and_then(|c| c.left.get(&p)).copied().unwrap_or(0);
                self.coll_parts.get(&comm).is_some_and(|parts| {
                    parts.iter().all(|q| {
                        st.and_then(|c| c.entered.get(q)).copied().unwrap_or(0) > round
                    })
                })
            }
            MicroOp::Post { .. }
            | MicroOp::Send { .. }
            | MicroOp::CollEnter { .. }
            | MicroOp::Access { .. } => true,
        }
    }

    fn micro_of(&self, a: &SchedAction) -> Option<&MicroStep> {
        match *a {
            SchedAction::Exec { proc, pc } => Some(&self.progs[proc][pc as usize]),
            SchedAction::Die { .. } => None,
        }
    }

    fn conflicting(a: &MicroOp, b: &MicroOp) -> bool {
        if let (
            MicroOp::Access { win: w1, offset: o1, len: l1, write: wr1 },
            MicroOp::Access { win: w2, offset: o2, len: l2, write: wr2 },
        ) = (*a, *b)
        {
            w1 == w2 && (wr1 || wr2) && o1 < o2 + l2 && o2 < o1 + l1
        } else {
            false
        }
    }

    fn describe_step(&self, proc: usize, ms: &MicroStep) -> String {
        format!(
            "rank {} {} h{} stage {}: {:?}",
            self.ranks[proc], ms.op, ms.handle, ms.stage, ms.micro
        )
    }
}

impl Model for ScheduleModel {
    type State = SchedState;
    type Action = SchedAction;

    fn initial(&self) -> SchedState {
        SchedState {
            pc: vec![0; self.progs.len()],
            alive: vec![true; self.progs.len()],
            groups: BTreeMap::new(),
            flags: BTreeMap::new(),
            chans: BTreeMap::new(),
            colls: BTreeMap::new(),
        }
    }

    fn enabled(&self, s: &SchedState) -> Vec<SchedAction> {
        let mut execs = Vec::new();
        let mut dies = Vec::new();
        let kills = s.alive.iter().filter(|a| !**a).count() as u8;
        for p in 0..self.progs.len() {
            if !s.alive[p] {
                continue;
            }
            let pc = s.pc[p] as usize;
            if pc >= self.progs[p].len() {
                continue;
            }
            if self.micro_enabled(s, p, &self.progs[p][pc].micro) {
                execs.push(SchedAction::Exec { proc: p, pc: s.pc[p] });
            }
            if kills < self.max_kills && self.kill_set.contains(&p) {
                dies.push(SchedAction::Die { proc: p, pc: s.pc[p] });
            }
        }
        // A stuck state is terminal even with kill budget left: dying
        // there cannot un-stick anyone, and check() classifies it.
        if execs.is_empty() {
            return execs;
        }
        execs.extend(dies);
        execs
    }

    fn step(&self, s: &SchedState, a: &SchedAction) -> SchedState {
        let mut n = s.clone();
        match *a {
            SchedAction::Die { proc, .. } => {
                n.alive[proc] = false;
            }
            SchedAction::Exec { proc, pc } => {
                match self.progs[proc][pc as usize].micro {
                    MicroOp::Arrive { group, .. } => {
                        *n.groups.entry(group).or_default().arrived.entry(proc).or_insert(0) += 1;
                    }
                    MicroOp::AwaitGroup { group } => {
                        *n.groups.entry(group).or_default().awaited.entry(proc).or_insert(0) += 1;
                    }
                    MicroOp::Post { flag } => n.flags.entry(flag).or_default().posts += 1,
                    MicroOp::WaitFlag { flag } => {
                        *n.flags.entry(flag).or_default().waited.entry(proc).or_insert(0) += 1;
                    }
                    MicroOp::Send { chan } => *n.chans.entry(chan).or_insert(0) += 1,
                    MicroOp::Recv { chan } => {
                        let c = n.chans.get_mut(&chan).expect("recv enabled on a non-empty channel");
                        *c -= 1;
                        if *c == 0 {
                            n.chans.remove(&chan);
                        }
                    }
                    MicroOp::CollEnter { comm, .. } => {
                        *n.colls.entry(comm).or_default().entered.entry(proc).or_insert(0) += 1;
                    }
                    MicroOp::CollLeave { comm } => {
                        *n.colls.entry(comm).or_default().left.entry(proc).or_insert(0) += 1;
                    }
                    MicroOp::Access { .. } => {}
                }
                n.pc[proc] = pc + 1;
            }
        }
        n
    }

    fn proc_of(&self, a: &SchedAction) -> usize {
        match *a {
            SchedAction::Exec { proc, .. } | SchedAction::Die { proc, .. } => proc,
        }
    }

    fn dependent(&self, a: &SchedAction, b: &SchedAction) -> bool {
        if self.proc_of(a) == self.proc_of(b) {
            return true;
        }
        let (Some(ma), Some(mb)) = (self.micro_of(a), self.micro_of(b)) else {
            return true; // a death is dependent with everything
        };
        // Only genuine enabling/conflict pairs are dependent — the
        // commutative-counter state encoding makes same-side operations
        // (Arrive/Arrive, Post/Post, Await/Await, Enter/Enter, …) of
        // different ranks commute in every state, so DPOR explores one
        // representative order of each barrier episode instead of `n!`.
        match (&ma.micro, &mb.micro) {
            (MicroOp::Arrive { group: g1, .. }, MicroOp::AwaitGroup { group: g2 })
            | (MicroOp::AwaitGroup { group: g1 }, MicroOp::Arrive { group: g2, .. }) => g1 == g2,
            (MicroOp::Post { flag: f1 }, MicroOp::WaitFlag { flag: f2 })
            | (MicroOp::WaitFlag { flag: f1 }, MicroOp::Post { flag: f2 }) => f1 == f2,
            // Send enables Recv; two Recvs race for the same queued
            // message (one can disable the other). Send/Send commutes.
            (MicroOp::Send { chan: c1 }, MicroOp::Recv { chan: c2 })
            | (MicroOp::Recv { chan: c1 }, MicroOp::Send { chan: c2 })
            | (MicroOp::Recv { chan: c1 }, MicroOp::Recv { chan: c2 }) => c1 == c2,
            (MicroOp::CollEnter { comm: c1, .. }, MicroOp::CollLeave { comm: c2 })
            | (MicroOp::CollLeave { comm: c1 }, MicroOp::CollEnter { comm: c2, .. }) => c1 == c2,
            (acc1 @ MicroOp::Access { .. }, acc2 @ MicroOp::Access { .. }) => {
                ScheduleModel::conflicting(acc1, acc2)
            }
            _ => false,
        }
    }

    fn check(&self, s: &SchedState, enabled: &[SchedAction]) -> Option<Violation> {
        if self.check_conflicts {
            let accesses: Vec<(usize, &MicroStep)> = enabled
                .iter()
                .filter_map(|a| match *a {
                    SchedAction::Exec { proc, .. } => self
                        .micro_of(a)
                        .filter(|ms| matches!(ms.micro, MicroOp::Access { .. }))
                        .map(|ms| (proc, ms)),
                    SchedAction::Die { .. } => None,
                })
                .collect();
            for (i, &(p1, m1)) in accesses.iter().enumerate() {
                for &(p2, m2) in &accesses[i + 1..] {
                    if p1 != p2 && ScheduleModel::conflicting(&m1.micro, &m2.micro) {
                        return Some(Violation::Conflict {
                            first: self.describe_step(p1, m1),
                            second: self.describe_step(p2, m2),
                        });
                    }
                }
            }
        }
        if enabled.is_empty() {
            let stuck: Vec<usize> = (0..self.progs.len())
                .filter(|&p| s.alive[p] && (s.pc[p] as usize) < self.progs[p].len())
                .collect();
            if stuck.is_empty() {
                return None; // clean completion
            }
            if s.alive.iter().any(|a| !a) {
                // Stuck behind a death: the runtime detects this and
                // surfaces Err(RankFailed) — a terminal, not a hang.
                return None;
            }
            let blocked = stuck
                .iter()
                .map(|&p| {
                    let ms = &self.progs[p][s.pc[p] as usize];
                    self.describe_step(p, ms)
                })
                .collect();
            return Some(Violation::Deadlock { blocked });
        }
        None
    }

    fn describe(&self, a: &SchedAction) -> String {
        match *a {
            SchedAction::Exec { proc, pc } => {
                self.describe_step(proc, &self.progs[proc][pc as usize])
            }
            SchedAction::Die { proc, pc } => {
                format!("rank {} dies (before micro-op {pc})", self.ranks[proc])
            }
        }
    }
}

// ====================================================================
// Shrink-agreement protocol model
// ====================================================================

/// Mutation knobs re-introducing the bugs the protocol prevents —
/// each must produce a counterexample trace (tests/explore.rs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShrinkMutation {
    None,
    /// Children accept acknowledgements regardless of scope — the
    /// stale-epoch acceptance the scope filter exists to stop.
    AcceptStale,
    /// Nobody restarts a stale round — the restart-on-death edge the
    /// bounded-park expiry exists to provide.
    SkipRestart,
}

/// In-flight protocol message identity: `(is_req, src, dst, scope,
/// seq)`, all member indices. Keyed (not a queue) so concurrent sends by
/// different members commute — equal behaviors reach equal states.
type MsgKey = (bool, usize, usize, u64, u8);

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct MsgVal {
    cid: u32,
    consumed: bool,
}

/// Per-member protocol phase. `Done::own` records the member's *own*
/// round scope at acceptance — the no-stale-acceptance invariant is
/// `scope == own`, which the scope filter guarantees and
/// [`ShrinkMutation::AcceptStale`] breaks.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum Phase {
    Start,
    Coord { scope: u64, need: Vec<bool>, collected: Vec<bool> },
    WaitAck { scope: u64 },
    Done { scope: u64, own: u64, cid: u32 },
}

/// Global protocol state: the death registry, each member's phase, the
/// keyed message pool, and the comm-id allocator.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShrinkState {
    dead: Vec<bool>,
    phase: Vec<Phase>,
    msgs: BTreeMap<MsgKey, MsgVal>,
    next_cid: u32,
}

/// One protocol transition. `Enter` folds compute-role + initial send
/// (both local/eager in the implementation); `RecvReq`/`RecvAck` consume
/// one identified message; `Restart`/`Rejoin` are the stale-scope
/// re-derivation edges; `Die` is a fault choice point.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum ShrinkAction {
    Die { member: usize },
    Enter { member: usize },
    RecvReq { member: usize, msg: MsgKey },
    RecvAck { member: usize, msg: MsgKey },
    Resend { member: usize },
    Restart { member: usize },
    Rejoin { member: usize },
}

/// The shrink-agreement transition system. Construct from a live
/// session with
/// [`HybridCtx::export_shrink_model`](crate::hybrid::HybridCtx::export_shrink_model)
/// or directly with [`ShrinkModel::new`] for synthetic topologies.
pub struct ShrinkModel {
    members: Vec<usize>,
    nodes: Vec<usize>,
    initial_dead: Vec<usize>,
    kill_set: Vec<usize>,
    max_kills: u8,
    root: Option<usize>,
    elect: ElectRoot,
    mutation: ShrinkMutation,
}

impl ShrinkModel {
    /// `members` are parent-communicator world ranks in comm (ascending)
    /// order, `nodes` their topology nodes, `initial_dead` the world
    /// ranks already registered dead (the protocol requires at least
    /// one).
    pub fn new(members: &[usize], nodes: &[usize], initial_dead: &[usize]) -> ShrinkModel {
        assert_eq!(members.len(), nodes.len());
        assert!(!initial_dead.is_empty(), "shrink requires a registered death");
        let idx = |w: usize| {
            members.iter().position(|&m| m == w).expect("dead/kill ranks must be members")
        };
        ShrinkModel {
            members: members.to_vec(),
            nodes: nodes.to_vec(),
            initial_dead: initial_dead.iter().map(|&w| idx(w)).collect(),
            kill_set: Vec::new(),
            max_kills: 0,
            root: None,
            elect: crate::hybrid::default_reelect,
            mutation: ShrinkMutation::None,
        }
    }

    /// Allow up to `max_kills` additional overlapping deaths, drawn from
    /// `world_ranks`, at any point of the agreement.
    pub fn with_kills(mut self, world_ranks: &[usize], max_kills: u8) -> ShrinkModel {
        self.kill_set = world_ranks
            .iter()
            .map(|&w| self.members.iter().position(|&m| m == w).expect("kill ranks must be members"))
            .collect();
        self.max_kills = max_kills;
        self
    }

    /// Check root re-election for a `Reelect`-pinned root (world rank):
    /// at every terminal where it is dead, the election hook must land
    /// on the lowest survivor of its node (else the lowest survivor).
    pub fn with_root(mut self, world: usize) -> ShrinkModel {
        assert!(self.members.contains(&world));
        self.root = Some(world);
        self
    }

    /// Swap the election hook under check (for mutant tests).
    pub fn with_elect(mut self, elect: ElectRoot) -> ShrinkModel {
        self.elect = elect;
        self
    }

    pub fn with_mutation(mut self, mutation: ShrinkMutation) -> ShrinkModel {
        self.mutation = mutation;
        self
    }

    /// Surviving member indices, in member (ascending world) order.
    fn survivors(&self, dead: &[bool]) -> Vec<usize> {
        (0..self.members.len()).filter(|&i| !dead[i]).collect()
    }

    fn current_scope(&self, dead: &[bool]) -> u64 {
        let worlds: Vec<usize> =
            self.survivors(dead).iter().map(|&i| self.members[i]).collect();
        shrink_scope_key(&worlds)
    }

    /// Has every live member agreed on the current survivor set? (The
    /// death choice points switch off here — the protocol is over.)
    fn settled(&self, s: &ShrinkState) -> bool {
        let scope = self.current_scope(&s.dead);
        self.survivors(&s.dead)
            .iter()
            .all(|&m| matches!(s.phase[m], Phase::Done { scope: sc, .. } if sc == scope))
    }

    fn next_seq(&self, s: &ShrinkState, req: bool, src: usize, dst: usize, scope: u64) -> u8 {
        (0..=u8::MAX)
            .find(|&q| !s.msgs.contains_key(&(req, src, dst, scope, q)))
            .expect("bounded protocol rounds never exhaust sequence numbers")
    }
}

impl Model for ShrinkModel {
    type State = ShrinkState;
    type Action = ShrinkAction;

    fn initial(&self) -> ShrinkState {
        let mut dead = vec![false; self.members.len()];
        for &i in &self.initial_dead {
            dead[i] = true;
        }
        ShrinkState {
            dead,
            phase: vec![Phase::Start; self.members.len()],
            msgs: BTreeMap::new(),
            next_cid: 1,
        }
    }

    fn enabled(&self, s: &ShrinkState) -> Vec<ShrinkAction> {
        let mut out = Vec::new();
        let cur = self.current_scope(&s.dead);
        let kills = s.dead.iter().filter(|d| **d).count() - self.initial_dead.len();
        let surv = self.survivors(&s.dead);
        let restarts = self.mutation != ShrinkMutation::SkipRestart;
        for &m in &surv {
            match &s.phase[m] {
                Phase::Start => out.push(ShrinkAction::Enter { member: m }),
                Phase::Coord { scope, .. } => {
                    for (&k, v) in &s.msgs {
                        if k.0 && k.2 == m && !v.consumed {
                            out.push(ShrinkAction::RecvReq { member: m, msg: k });
                        }
                    }
                    if restarts && *scope != cur {
                        out.push(ShrinkAction::Restart { member: m });
                    }
                }
                Phase::WaitAck { scope } => {
                    for (&k, v) in &s.msgs {
                        if !k.0 && k.2 == m && !v.consumed {
                            out.push(ShrinkAction::RecvAck { member: m, msg: k });
                        }
                    }
                    if *scope != cur {
                        if restarts {
                            out.push(ShrinkAction::Restart { member: m });
                        }
                    } else {
                        // Bounded-park expiry resend, modeled only where
                        // it can make progress: the round coordinator is
                        // live and actively collecting at our scope, has
                        // not collected us, and no request of ours is in
                        // flight (see DESIGN.md §6c on this bound).
                        let coord = surv[0];
                        let active = coord != m
                            && matches!(
                                &s.phase[coord],
                                Phase::Coord { scope: cs, collected, .. }
                                    if *cs == cur && !collected[m]
                            );
                        let in_flight = s
                            .msgs
                            .iter()
                            .any(|(k, v)| k.0 && k.1 == m && k.3 == cur && !v.consumed);
                        if active && !in_flight {
                            out.push(ShrinkAction::Resend { member: m });
                        }
                    }
                }
                Phase::Done { scope, .. } => {
                    if restarts && *scope != cur {
                        out.push(ShrinkAction::Rejoin { member: m });
                    }
                }
            }
        }
        if !self.settled(s) && (kills as u8) < self.max_kills {
            for &m in &self.kill_set {
                if !s.dead[m] {
                    out.push(ShrinkAction::Die { member: m });
                }
            }
        }
        out
    }

    fn step(&self, s: &ShrinkState, a: &ShrinkAction) -> ShrinkState {
        let mut n = s.clone();
        match *a {
            ShrinkAction::Die { member } => n.dead[member] = true,
            ShrinkAction::Enter { member } => {
                let surv = self.survivors(&n.dead);
                let scope = self.current_scope(&n.dead);
                if surv[0] == member {
                    let mut need = vec![false; self.members.len()];
                    for &q in &surv[1..] {
                        need[q] = true;
                    }
                    n.phase[member] =
                        Phase::Coord { scope, need, collected: vec![false; self.members.len()] };
                    coord_try_finish(self, &mut n, member);
                } else {
                    let coord = surv[0];
                    let seq = self.next_seq(&n, true, member, coord, scope);
                    n.msgs.insert((true, member, coord, scope, seq), MsgVal { cid: 0, consumed: false });
                    n.phase[member] = Phase::WaitAck { scope };
                }
            }
            ShrinkAction::Resend { member } => {
                let Phase::WaitAck { scope } = n.phase[member] else {
                    unreachable!("resend only fires while awaiting an ack")
                };
                let coord = self.survivors(&n.dead)[0];
                let seq = self.next_seq(&n, true, member, coord, scope);
                n.msgs.insert((true, member, coord, scope, seq), MsgVal { cid: 0, consumed: false });
            }
            ShrinkAction::RecvReq { member, msg } => {
                n.msgs.get_mut(&msg).expect("recv of an existing message").consumed = true;
                let Phase::Coord { scope, collected, .. } = &mut n.phase[member] else {
                    unreachable!("recv-req only fires while coordinating")
                };
                if msg.3 == *scope {
                    collected[msg.1] = true; // scope match: collect
                } // else: stale epoch / foreign round — discard
                coord_try_finish(self, &mut n, member);
            }
            ShrinkAction::RecvAck { member, msg } => {
                let val = n.msgs.get_mut(&msg).expect("recv of an existing message");
                val.consumed = true;
                let cid = val.cid;
                let Phase::WaitAck { scope } = n.phase[member] else {
                    unreachable!("recv-ack only fires while awaiting an ack")
                };
                if msg.3 == scope || self.mutation == ShrinkMutation::AcceptStale {
                    n.phase[member] = Phase::Done { scope: msg.3, own: scope, cid };
                } // else: stale epoch — discard
            }
            ShrinkAction::Restart { member } | ShrinkAction::Rejoin { member } => {
                n.phase[member] = Phase::Start;
            }
        }
        n
    }

    fn proc_of(&self, a: &ShrinkAction) -> usize {
        match *a {
            ShrinkAction::Die { member }
            | ShrinkAction::Enter { member }
            | ShrinkAction::RecvReq { member, .. }
            | ShrinkAction::RecvAck { member, .. }
            | ShrinkAction::Resend { member }
            | ShrinkAction::Restart { member }
            | ShrinkAction::Rejoin { member } => member,
        }
    }

    fn dependent(&self, a: &ShrinkAction, b: &ShrinkAction) -> bool {
        if self.proc_of(a) == self.proc_of(b) {
            return true;
        }
        // Deaths touch the registry every transition reads; resends read
        // the coordinator's phase (cross-member enabledness).
        let global = |x: &ShrinkAction| {
            matches!(x, ShrinkAction::Die { .. } | ShrinkAction::Resend { .. })
        };
        if global(a) || global(b) {
            return true;
        }
        // A receive is dependent with the peer whose sends feed it
        // (send-enables-recv); everything else commutes.
        let feeds = |x: &ShrinkAction, y: &ShrinkAction| match *x {
            ShrinkAction::RecvReq { msg, .. } | ShrinkAction::RecvAck { msg, .. } => {
                msg.1 == self.proc_of(y)
            }
            _ => false,
        };
        feeds(a, b) || feeds(b, a)
    }

    fn check(&self, s: &ShrinkState, enabled: &[ShrinkAction]) -> Option<Violation> {
        let surv = self.survivors(&s.dead);
        // No stale-scope acceptance: a Done member's agreed scope must be
        // the scope of its own round at acceptance.
        for &m in &surv {
            if let Phase::Done { scope, own, .. } = s.phase[m] {
                if scope != own {
                    return Some(Violation::Protocol {
                        detail: format!(
                            "member {m} (world {}) accepted a stale-scope ack: agreed scope \
                             {scope:#x} but its round scope was {own:#x}",
                            self.members[m]
                        ),
                    });
                }
            }
        }
        // No split-brain: same scope, same comm id.
        for (i, &m1) in surv.iter().enumerate() {
            for &m2 in &surv[i + 1..] {
                if let (
                    Phase::Done { scope: s1, cid: c1, .. },
                    Phase::Done { scope: s2, cid: c2, .. },
                ) = (&s.phase[m1], &s.phase[m2])
                {
                    if s1 == s2 && c1 != c2 {
                        return Some(Violation::Protocol {
                            detail: format!(
                                "split-brain: members {m1} and {m2} agreed scope {s1:#x} \
                                 with different comm ids ({c1} vs {c2})"
                            ),
                        });
                    }
                }
            }
        }
        if enabled.is_empty() {
            // Terminal: every survivor must have converged on the true
            // survivor set's scope.
            let cur = self.current_scope(&s.dead);
            let stragglers: Vec<String> = surv
                .iter()
                .filter(|&&m| !matches!(s.phase[m], Phase::Done { scope, .. } if scope == cur))
                .map(|&m| {
                    format!(
                        "member {m} (world {}) stuck in {:?}",
                        self.members[m],
                        phase_name(&s.phase[m])
                    )
                })
                .collect();
            if !stragglers.is_empty() {
                return Some(Violation::Protocol {
                    detail: format!(
                        "agreement did not converge to the true survivor set: {}",
                        stragglers.join("; ")
                    ),
                });
            }
            // Root re-election: model-side spec computed independently of
            // the election hook under check.
            if let Some(rw) = self.root {
                let ri = self.members.iter().position(|&m| m == rw).expect("root is a member");
                if s.dead[ri] && !surv.is_empty() {
                    let survivors_world: Vec<usize> =
                        surv.iter().map(|&i| self.members[i]).collect();
                    let survivor_nodes: Vec<usize> =
                        surv.iter().map(|&i| self.nodes[i]).collect();
                    let expected = survivor_nodes
                        .iter()
                        .position(|&nd| nd == self.nodes[ri])
                        .unwrap_or(0);
                    let e = Reelection {
                        old_root_world: rw,
                        old_root_node: self.nodes[ri],
                        survivors_world: &survivors_world,
                        survivor_nodes: &survivor_nodes,
                    };
                    let chosen = (self.elect)(&e);
                    if chosen != expected {
                        return Some(Violation::Protocol {
                            detail: format!(
                                "re-election picked comm rank {chosen} (world {:?}) but the \
                                 lowest survivor of dead root {rw}'s node is comm rank \
                                 {expected} (world {})",
                                survivors_world.get(chosen),
                                survivors_world[expected]
                            ),
                        });
                    }
                }
            }
        }
        None
    }

    fn describe(&self, a: &ShrinkAction) -> String {
        let w = |m: usize| self.members[m];
        match *a {
            ShrinkAction::Die { member } => format!("world {} dies", w(member)),
            ShrinkAction::Enter { member } => {
                format!("world {} enters the round (derives survivors, sends/collects)", w(member))
            }
            ShrinkAction::RecvReq { member, msg } => format!(
                "world {} (coordinator) receives req from world {} scope {:#x}",
                w(member),
                w(msg.1),
                msg.3
            ),
            ShrinkAction::RecvAck { member, msg } => format!(
                "world {} receives ack from world {} scope {:#x}",
                w(member),
                w(msg.1),
                msg.3
            ),
            ShrinkAction::Resend { member } => {
                format!("world {} resends its request (bounded-park expiry)", w(member))
            }
            ShrinkAction::Restart { member } => {
                format!("world {} restarts the round (scope went stale)", w(member))
            }
            ShrinkAction::Rejoin { member } => {
                format!("world {} rejoins (agreed scope went stale)", w(member))
            }
        }
    }
}

fn phase_name(p: &Phase) -> &'static str {
    match p {
        Phase::Start => "Start",
        Phase::Coord { .. } => "Coord",
        Phase::WaitAck { .. } => "WaitAck",
        Phase::Done { .. } => "Done",
    }
}

/// If `member`'s coordinator round has collected every needed request,
/// allocate the comm id, emit the acknowledgements and finish.
fn coord_try_finish(model: &ShrinkModel, n: &mut ShrinkState, member: usize) {
    let Phase::Coord { scope, need, collected } = &n.phase[member] else {
        return;
    };
    let scope = *scope;
    if !need.iter().zip(collected).all(|(nd, c)| !*nd || *c) {
        return;
    }
    let children: Vec<usize> =
        need.iter().enumerate().filter_map(|(i, nd)| nd.then_some(i)).collect();
    let cid = n.next_cid;
    n.next_cid += 1;
    for c in children {
        let seq = model.next_seq(n, false, member, c, scope);
        n.msgs.insert((false, member, c, scope, seq), MsgVal { cid, consumed: false });
    }
    n.phase[member] = Phase::Done { scope, own: scope, cid };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::dpor::{explore, Budget, Reduction};
    use crate::analysis::schedule::{Access, StageModel};

    fn sched(rank: usize, stages: Vec<StageModel>) -> RankSchedule {
        RankSchedule { rank, node: rank, op: "test", root: None, win: 7, win_len: 64, stages }
    }

    fn clean_pair() -> Vec<RankSchedule> {
        let grp: GroupId = (7, 0);
        let flg: FlagId = (7, 0);
        vec![
            sched(
                0,
                vec![
                    StageModel::Arrive { group: grp, size: 2 },
                    StageModel::Await { group: grp, size: 2 },
                    StageModel::Work {
                        chunk: 0,
                        accesses: vec![Access { offset: 0, len: 32, write: true }],
                        msgs: vec![],
                        colls: vec![],
                    },
                    StageModel::Post { flag: flg },
                ],
            ),
            sched(
                1,
                vec![
                    StageModel::Arrive { group: grp, size: 2 },
                    StageModel::Await { group: grp, size: 2 },
                    StageModel::Wait { flag: flg },
                    StageModel::Work {
                        chunk: 0,
                        accesses: vec![Access { offset: 0, len: 32, write: false }],
                        msgs: vec![],
                        colls: vec![],
                    },
                ],
            ),
        ]
    }

    #[test]
    fn clean_pair_explores_clean_in_every_mode() {
        for red in [Reduction::Exhaustive, Reduction::Dpor, Reduction::DporCached] {
            let m = ScheduleModel::from_handle(&clean_pair()).with_conflict_check();
            let r = explore(&m, red, &Budget::smoke());
            assert!(r.complete, "{red:?} must finish in budget");
            assert!(r.counterexample.is_none(), "{red:?}: {:?}", r.counterexample);
            assert!(r.terminals >= 1);
        }
    }

    #[test]
    fn unsynchronized_writes_are_a_co_enabled_conflict() {
        let w = |rank| {
            sched(
                rank,
                vec![StageModel::Work {
                    chunk: 0,
                    accesses: vec![Access { offset: 0, len: 16, write: true }],
                    msgs: vec![],
                    colls: vec![],
                }],
            )
        };
        let m = ScheduleModel::from_handle(&[w(0), w(1)]).with_conflict_check();
        let r = explore(&m, Reduction::Exhaustive, &Budget::smoke());
        let cex = r.counterexample.expect("two unsynchronized writers must conflict");
        assert!(matches!(cex.violation, Violation::Conflict { .. }), "{:?}", cex.violation);
    }

    #[test]
    fn reductions_agree_on_a_deadlock() {
        // Rank 1 waits on a flag nobody posts.
        let flg: FlagId = (7, 3);
        let s = vec![sched(0, vec![]), sched(1, vec![StageModel::Wait { flag: flg }])];
        for red in [Reduction::Exhaustive, Reduction::Dpor, Reduction::DporCached] {
            let m = ScheduleModel::from_handle(&s);
            let r = explore(&m, red, &Budget::smoke());
            let cex = r.counterexample.unwrap_or_else(|| panic!("{red:?} must deadlock"));
            assert!(matches!(cex.violation, Violation::Deadlock { .. }));
        }
    }

    #[test]
    fn shrink_protocol_converges_exhaustively() {
        let m = ShrinkModel::new(&[0, 1, 2, 3], &[0, 0, 1, 1], &[3]);
        let r = explore(&m, Reduction::Exhaustive, &Budget::smoke());
        assert!(r.complete);
        assert!(r.counterexample.is_none(), "{:?}", r.counterexample);
        assert!(r.terminals >= 1);
    }

    #[test]
    fn shrink_death_choice_points_stay_convergent() {
        let m = ShrinkModel::new(&[0, 1, 2, 3], &[0, 0, 1, 1], &[3]).with_kills(&[0, 2], 2);
        let r = explore(&m, Reduction::Exhaustive, &Budget::smoke());
        assert!(r.complete);
        assert!(r.counterexample.is_none(), "{:?}", r.counterexample);
    }
}
