//! Correctness analysis for compiled hybrid collectives (DESIGN.md §6).
//!
//! Two cooperating passes over the split-phase machinery of §5e:
//!
//! 1. [`schedule`] — a **static schedule verifier**: ranks export their
//!    compiled stage chains
//!    ([`HyColl::export_schedule`](crate::hybrid::HyColl::export_schedule),
//!    [`PlanCache::verify`](crate::coll::PlanCache::verify)); the
//!    verifier rebuilds the cross-rank dependency graph (half-barrier
//!    pairs, yellow release edges, bridge chunk streams, nested
//!    collectives) and checks deadlock-freedom, barrier arity, orphaned
//!    sends/recvs, fixed-root consistency and window bounds. Run it over
//!    every committed shape with `cargo run --release --bin
//!    verify_schedules` (a CI gate).
//! 2. [`race`] — a **happens-before race detector**: vector clocks
//!    advanced at the sync primitives, byte-range access records on every
//!    [`SharedWindow`](crate::mpi::win::SharedWindow) operation, reports
//!    for conflicting unordered pairs with replay seed and stage names.
//!
//! The verifier proves the *compiled intent* sound; the detector checks
//! the *executed behavior* (including the op bodies' raw window views the
//! static model only over-approximates). Together they are the backstop
//! the engine-refactor roadmap items lean on.

pub mod race;
pub mod schedule;

pub use race::{RaceDetector, RaceReport};
pub use schedule::{
    verify_handle, verify_program, verify_rank_local, verify_survivors, Diagnostic, RankSchedule,
};
