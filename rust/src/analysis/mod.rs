//! Correctness analysis for compiled hybrid collectives (DESIGN.md §6).
//!
//! Two cooperating passes over the split-phase machinery of §5e:
//!
//! 1. [`schedule`] — a **static schedule verifier**: ranks export their
//!    compiled stage chains
//!    ([`HyColl::export_schedule`](crate::hybrid::HyColl::export_schedule),
//!    [`PlanCache::verify`](crate::coll::PlanCache::verify)); the
//!    verifier rebuilds the cross-rank dependency graph (half-barrier
//!    pairs, yellow release edges, bridge chunk streams, nested
//!    collectives) and checks deadlock-freedom, barrier arity, orphaned
//!    sends/recvs, fixed-root consistency and window bounds. Run it over
//!    every committed shape with `cargo run --release --bin
//!    verify_schedules` (a CI gate).
//! 2. [`race`] — a **happens-before race detector**: vector clocks
//!    advanced at the sync primitives, byte-range access records on every
//!    [`SharedWindow`](crate::mpi::win::SharedWindow) operation, reports
//!    for conflicting unordered pairs with replay seed and stage names.
//!
//! The verifier proves the *compiled intent* sound; the detector checks
//! the *executed behavior* (including the op bodies' raw window views the
//! static model only over-approximates). Together they are the backstop
//! the engine-refactor roadmap items lean on.
//!
//! A third pass closes the interleaving gap (DESIGN.md §6c): the
//! verifier checks one topological order, the detector one executed
//! trace — [`dpor`] + [`explore`] check **every** reachable interleaving
//! of a schedule (and of the shrink/recovery agreement) under dynamic
//! partial-order reduction, emitting minimal replayable counterexample
//! traces. Run with `verify_schedules --explore`.

pub mod dpor;
pub mod explore;
pub mod race;
pub mod schedule;

pub use dpor::{explore, Budget, Counterexample, ExploreReport, Model, Reduction, Violation};
pub use explore::{ScheduleModel, ShrinkModel, ShrinkMutation};
pub use race::{RaceDetector, RaceReport};
pub use schedule::{
    lower_handle, lower_program, verify_handle, verify_program, verify_rank_local,
    verify_survivors, Diagnostic, MicroOp, MicroStep, RankSchedule,
};
