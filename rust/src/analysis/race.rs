//! The happens-before race detector (DESIGN.md §6, pass 2).
//!
//! Shared windows make missing or misplaced syncs a *silent* hazard: a
//! child that loads before the leader's release still reads bytes — just
//! possibly stale ones — and pure-MPI semantics never exposes the bug.
//! This module checks the property directly, FastTrack-style: every
//! [`SharedWindow`](crate::mpi::win::SharedWindow) byte-range access is
//! recorded together with the accessing rank's **vector clock**, clocks
//! advance at exactly the sync events the hybrid protocols use, and any
//! two overlapping accesses from different ranks, at least one a write,
//! that are *unordered* by happens-before are reported as a race.
//!
//! ## Which sync primitives create edges
//!
//! - [`SyncGroup`](crate::mpi::sync::SyncGroup) arrive/finish (the red
//!   sync and the `Barrier`-scheme yellow sync): every participant
//!   publishes its clock at *arrive* and joins the accumulated clock of
//!   the whole generation at *finish* — a full barrier, edges both ways.
//! - [`SpinFlag`](crate::mpi::sync::SpinFlag) post/wait (the §4.5
//!   spinning yellow sync): the poster joins its clock into the flag and
//!   ticks; a waiter joins the flag's clock into its own. Edges flow
//!   **leader → children only** — yellow sync is a *release*, not a
//!   barrier, so a leader racing *ahead* past children is (correctly)
//!   still observable.
//!
//! Message-clock piggybacking is deliberately absent: windows are
//! node-local, and every same-node cross-rank ordering in the hybrid
//! protocols goes through one of the two primitives above (bridge
//! messages order *bridge* traffic, whose payloads each rank reads and
//! writes only in its own window ranges).
//!
//! ## Installation
//!
//! Detection is opt-in per OS thread (= per simulated rank):
//! [`install`] binds a thread to a shared [`RaceDetector`]; uninstalled
//! threads skip every hook through one relaxed atomic load, so parallel
//! test binaries and un-instrumented clusters pay ~nothing. Reports carry
//! the handle's run `seed` and the two offending stage labels (set by the
//! schedule interpreter via [`label`]) for deterministic replay.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A per-rank vector clock; component `r` counts rank `r`'s release
/// operations observed so far.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VClock(Vec<u64>);

impl VClock {
    pub fn new(nranks: usize) -> VClock {
        VClock(vec![0; nranks])
    }

    /// Pointwise maximum (the acquire half of a sync edge).
    pub fn join(&mut self, other: &VClock) {
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a = (*a).max(*b);
        }
    }

    /// Advance my own component (the release half).
    pub fn tick(&mut self, rank: usize) {
        self.0[rank] += 1;
    }

    pub fn get(&self, rank: usize) -> u64 {
        self.0[rank]
    }
}

/// One side of a reported conflicting pair.
#[derive(Clone, Debug)]
pub struct AccessInfo {
    pub rank: usize,
    /// The schedule stage executing when the access happened (set via
    /// [`label`] by the interpreter; "start"/"result" around it).
    pub stage: String,
    pub offset: usize,
    pub len: usize,
    pub write: bool,
}

impl fmt::Display for AccessInfo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rank {} {} [{}, {}) during \"{}\"",
            self.rank,
            if self.write { "write" } else { "read" },
            self.offset,
            self.offset + self.len,
            self.stage
        )
    }
}

/// A conflicting overlapping access pair unordered by happens-before.
#[derive(Clone, Debug)]
pub struct RaceReport {
    /// Window identity ([`SharedWindow::id`](crate::mpi::win::SharedWindow::id)).
    pub win: u64,
    /// The deterministic replay seed the detector was installed with.
    pub seed: u64,
    pub first: AccessInfo,
    pub second: AccessInfo,
}

impl fmt::Display for RaceReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "data race on window {}: {} is unordered with {} (replay seed {})",
            self.win, self.first, self.second, self.seed
        )
    }
}

impl RaceReport {
    /// A run-independent identity for this report: the two access sides
    /// in sorted order, *excluding* the window id (window ids come from a
    /// process-global counter, so the same logical configuration re-run
    /// in the same process allocates fresh ids) and the seed. What
    /// `verify_schedules --replay` compares across the two runs.
    pub fn canonical(&self) -> String {
        let side = |a: &AccessInfo| {
            format!(
                "rank {} {} [{}, {}) during \"{}\"",
                a.rank,
                if a.write { "write" } else { "read" },
                a.offset,
                a.offset + a.len,
                a.stage
            )
        };
        let (mut x, mut y) = (side(&self.first), side(&self.second));
        if x > y {
            std::mem::swap(&mut x, &mut y);
        }
        format!("{x} <-> {y}")
    }
}

/// The canonical, deterministically ordered fingerprint of a report set —
/// equal across replays of the same configuration iff the detector found
/// the same races.
pub fn canonical_reports(reports: &[RaceReport]) -> Vec<String> {
    let mut keys: Vec<String> = reports.iter().map(RaceReport::canonical).collect();
    keys.sort();
    keys.dedup();
    keys
}

struct Record {
    info: AccessInfo,
    clock: VClock,
}

#[derive(Default)]
struct DetState {
    /// Access history per window id.
    accesses: HashMap<u64, Vec<Record>>,
    /// Clock accumulator per (group id, generation): everyone publishes
    /// at arrive, everyone joins at finish.
    barriers: HashMap<(u64, usize), VClock>,
    /// Cumulative released clock per flag id (single-poster protocol; a
    /// cumulative clock is monotone, so late observers acquire a
    /// superset — never less — of what their post published).
    flags: HashMap<u64, VClock>,
    races: Vec<RaceReport>,
}

/// Shared detector state; one per instrumented cluster run.
pub struct RaceDetector {
    nranks: usize,
    seed: u64,
    state: Mutex<DetState>,
}

/// Cap on stored reports (the first few pinpoint the bug; an unsynced
/// loop would otherwise flood memory).
const MAX_REPORTS: usize = 64;

/// Access-history cap per window. Accesses older than this are almost
/// surely ordered before everything current; dropping them can only lose
/// reports in pathological schedules, never invent one.
const MAX_RECORDS: usize = 8192;

impl RaceDetector {
    /// `nranks` sizes the vector clocks; `seed` is echoed in every report
    /// so a failing configuration can be replayed deterministically.
    pub fn new(nranks: usize, seed: u64) -> Arc<RaceDetector> {
        Arc::new(RaceDetector { nranks, seed, state: Mutex::new(DetState::default()) })
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Races found so far.
    pub fn reports(&self) -> Vec<RaceReport> {
        self.state.lock().unwrap().races.clone()
    }

    pub fn is_clean(&self) -> bool {
        self.state.lock().unwrap().races.is_empty()
    }
}

struct RankCtx {
    det: Arc<RaceDetector>,
    rank: usize,
    clock: VClock,
    stage: String,
}

/// Count of threads with an installed context — the global fast gate
/// every hook checks first (uninstrumented runs pay one relaxed load).
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static CTX: RefCell<Option<RankCtx>> = const { RefCell::new(None) };
}

/// Bind the current OS thread (= one simulated rank) to `det` as `rank`.
/// Every [`SharedWindow`](crate::mpi::win::SharedWindow) access and sync
/// event on this thread is tracked until [`uninstall`].
pub fn install(det: &Arc<RaceDetector>, rank: usize) {
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        assert!(c.is_none(), "race context already installed on this thread");
        *c = Some(RankCtx {
            det: det.clone(),
            rank,
            clock: VClock::new(det.nranks),
            stage: "start".to_string(),
        });
    });
    ACTIVE.fetch_add(1, Ordering::SeqCst);
}

/// Detach the current thread from its detector (no-op if none).
pub fn uninstall() {
    let had = CTX.with(|c| c.borrow_mut().take().is_some());
    if had {
        ACTIVE.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Is any thread in this process instrumented? (Hook fast gate.)
#[inline]
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed) > 0
}

/// Set the current thread's stage label for subsequent access reports.
/// The closure only runs when this thread is instrumented, so callers may
/// pass a formatting closure with no cost on the common path.
pub fn label<F: FnOnce() -> String>(f: F) {
    if !enabled() {
        return;
    }
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.stage = f();
        }
    });
}

fn with_ctx(f: impl FnOnce(&mut RankCtx)) {
    if !enabled() {
        return;
    }
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            f(ctx);
        }
    });
}

/// Hook: a byte-range window access by the current thread.
pub(crate) fn on_access(win: u64, offset: usize, len: usize, write: bool) {
    if len == 0 {
        return;
    }
    with_ctx(|ctx| {
        let info = AccessInfo { rank: ctx.rank, stage: ctx.stage.clone(), offset, len, write };
        let mut st = ctx.det.state.lock().unwrap();
        let st = &mut *st;
        let recs = st.accesses.entry(win).or_default();
        for r in recs.iter() {
            if r.info.rank == ctx.rank || !(r.info.write || write) {
                continue;
            }
            let overlap = r.info.offset < offset + len && offset < r.info.offset + r.info.len;
            if !overlap {
                continue;
            }
            // Ordered iff one side's release component is contained in
            // the other's clock.
            let r_before_me = r.clock.get(r.info.rank) <= ctx.clock.get(r.info.rank);
            let me_before_r = ctx.clock.get(ctx.rank) <= r.clock.get(ctx.rank);
            if !(r_before_me || me_before_r) && st.races.len() < MAX_REPORTS {
                st.races.push(RaceReport {
                    win,
                    seed: ctx.det.seed,
                    first: r.info.clone(),
                    second: info.clone(),
                });
            }
        }
        if recs.len() >= MAX_RECORDS {
            recs.drain(..MAX_RECORDS / 2);
        }
        recs.push(Record { info, clock: ctx.clock.clone() });
    });
}

/// Hook: barrier arrival. Publishes my clock into the generation's
/// accumulator *before* the arrival count moves (the caller guarantees
/// ordering), then ticks my release component.
pub(crate) fn on_barrier_arrive(group: u64, generation: usize) {
    with_ctx(|ctx| {
        let mut st = ctx.det.state.lock().unwrap();
        let nranks = ctx.det.nranks;
        st.barriers
            .entry((group, generation))
            .or_insert_with(|| VClock::new(nranks))
            .join(&ctx.clock);
        drop(st);
        ctx.clock.tick(ctx.rank);
    });
}

/// Hook: barrier completion observed (poll success, blocking finish, or
/// — for the generation's releasing last arriver — arrive itself). Joins
/// the generation's accumulated clock; idempotent, since accumulation is
/// complete before any member can observe the release.
pub(crate) fn on_barrier_finish(group: u64, generation: usize) {
    with_ctx(|ctx| {
        let st = ctx.det.state.lock().unwrap();
        if let Some(acc) = st.barriers.get(&(group, generation)) {
            let acc = acc.clone();
            drop(st);
            ctx.clock.join(&acc);
        }
    });
}

/// Hook: spin-flag post (runs *before* the status increment). Release
/// half only: the poster's clock flows into the flag, nothing flows back.
pub(crate) fn on_flag_post(flag: u64) {
    with_ctx(|ctx| {
        let mut st = ctx.det.state.lock().unwrap();
        let nranks = ctx.det.nranks;
        st.flags.entry(flag).or_insert_with(|| VClock::new(nranks)).join(&ctx.clock);
        drop(st);
        ctx.clock.tick(ctx.rank);
    });
}

/// Hook: spin-flag wait satisfied. Acquire half: the flag's cumulative
/// released clock flows into the observer.
pub(crate) fn on_flag_acquire(flag: u64) {
    with_ctx(|ctx| {
        let st = ctx.det.state.lock().unwrap();
        if let Some(fc) = st.flags.get(&flag) {
            let fc = fc.clone();
            drop(st);
            ctx.clock.join(&fc);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::sync::{SpinFlag, SyncGroup};
    use crate::mpi::win::SharedWindow;

    #[test]
    fn vclock_join_and_tick() {
        let mut a = VClock::new(3);
        let mut b = VClock::new(3);
        a.tick(0);
        a.tick(0);
        b.tick(1);
        b.join(&a);
        assert_eq!(b.get(0), 2);
        assert_eq!(b.get(1), 1);
        assert_eq!(b.get(2), 0);
    }

    /// Run `f0`/`f1` as two instrumented rank threads over shared state.
    fn two_ranks<S: Send + Sync + 'static>(
        det: &Arc<RaceDetector>,
        shared: Arc<S>,
        f0: impl FnOnce(&S) + Send + 'static,
        f1: impl FnOnce(&S) + Send + 'static,
    ) {
        let spawn = |rank: usize, det: Arc<RaceDetector>, s: Arc<S>, f: Box<dyn FnOnce(&S) + Send>| {
            std::thread::spawn(move || {
                install(&det, rank);
                f(&s);
                uninstall();
            })
        };
        let h0 = spawn(0, det.clone(), shared.clone(), Box::new(f0));
        let h1 = spawn(1, det.clone(), shared, Box::new(f1));
        h0.join().unwrap();
        h1.join().unwrap();
    }

    #[test]
    fn unsynchronized_write_read_races() {
        let det = RaceDetector::new(2, 42);
        let win = Arc::new(SharedWindow::allocate(&[16]));
        two_ranks(
            &det,
            win,
            |w| w.write(0, &[1; 8]),
            |w| {
                let _ = w.read_vec(4, 8);
            },
        );
        let reports = det.reports();
        assert_eq!(reports.len(), 1, "exactly one conflicting pair: {reports:?}");
        assert_eq!(reports[0].seed, 42);
        let shown = reports[0].to_string();
        assert!(shown.contains("seed 42"), "{shown}");
    }

    #[test]
    fn disjoint_ranges_do_not_race() {
        let det = RaceDetector::new(2, 0);
        let win = Arc::new(SharedWindow::allocate(&[16]));
        two_ranks(&det, win, |w| w.write(0, &[1; 8]), |w| w.write(8, &[2; 8]));
        assert!(det.is_clean(), "{:?}", det.reports());
    }

    #[test]
    fn barrier_orders_write_before_read() {
        let det = RaceDetector::new(2, 0);
        struct S {
            win: SharedWindow,
            grp: SyncGroup,
        }
        let s = Arc::new(S { win: SharedWindow::allocate(&[16]), grp: SyncGroup::new(2) });
        two_ranks(
            &det,
            s,
            |s| {
                s.win.write(0, &[7; 16]);
                s.grp.arrive_and_wait(1.0);
            },
            |s| {
                s.grp.arrive_and_wait(2.0);
                let _ = s.win.read_vec(0, 16);
            },
        );
        assert!(det.is_clean(), "{:?}", det.reports());
    }

    #[test]
    fn flag_release_orders_write_before_read() {
        let det = RaceDetector::new(2, 0);
        struct S {
            win: SharedWindow,
            flag: SpinFlag,
        }
        let s = Arc::new(S { win: SharedWindow::allocate(&[16]), flag: SpinFlag::new() });
        two_ranks(
            &det,
            s,
            |s| {
                s.win.write(0, &[7; 16]);
                s.flag.post(1.0);
            },
            |s| {
                s.flag.wait_eq(1);
                let _ = s.win.read_vec(0, 16);
            },
        );
        assert!(det.is_clean(), "{:?}", det.reports());
    }

    #[test]
    fn ack_flag_closes_the_back_edge() {
        // A waiter can signal *back* through a second flag; the poster's
        // wait on it is an acquire, so the round trip is fully ordered
        // and must stay clean (contrast `poster_racing_ahead_is_caught`,
        // where the back edge is missing).
        let det = RaceDetector::new(2, 0);
        struct S {
            win: SharedWindow,
            go: SpinFlag,
            ack: SpinFlag,
        }
        let s = Arc::new(S {
            win: SharedWindow::allocate(&[16]),
            go: SpinFlag::new(),
            ack: SpinFlag::new(),
        });
        two_ranks(
            &det,
            s,
            |s| {
                s.go.post(1.0);
                s.ack.wait_eq(1);
                let _ = s.win.read_vec(0, 8);
            },
            |s| {
                s.go.wait_eq(1);
                s.win.write(0, &[9; 8]);
                s.ack.post(2.0);
            },
        );
        assert!(det.is_clean(), "{:?}", det.reports());
    }

    #[test]
    fn poster_racing_ahead_is_caught() {
        // The genuinely one-directional case: after posting, the leader
        // reads a range the child writes post-wait, with only wall-clock
        // (join) ordering between them — a real protocol bug the release
        // edge must NOT mask.
        let det = RaceDetector::new(2, 7);
        let win = Arc::new(SharedWindow::allocate(&[16]));
        struct S {
            win: Arc<SharedWindow>,
            go: SpinFlag,
        }
        let s = Arc::new(S { win: win.clone(), go: SpinFlag::new() });
        let det2 = det.clone();
        let s2 = s.clone();
        let child = std::thread::spawn(move || {
            install(&det2, 1);
            s2.go.wait_eq(1);
            s2.win.write(0, &[9; 8]);
            uninstall();
        });
        install(&det, 0);
        s.go.post(1.0);
        child.join().unwrap(); // real-time order, no happens-before edge
        let _ = s.win.read_vec(0, 8);
        uninstall();
        let reports = det.reports();
        assert_eq!(reports.len(), 1, "leader's post-release read races: {reports:?}");
        assert_eq!(reports[0].seed, 7);
    }

    #[test]
    fn uninstalled_threads_pay_nothing_and_record_nothing() {
        let det = RaceDetector::new(2, 0);
        let win = SharedWindow::allocate(&[8]);
        win.write(0, &[1; 8]); // no context on this thread: untracked
        let _ = win.read_vec(0, 8);
        assert!(det.is_clean());
        assert!(det.reports().is_empty());
    }
}
