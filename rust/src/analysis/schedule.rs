//! The static schedule verifier (DESIGN.md §6, pass 1).
//!
//! Since the split-phase redesign (§5e) every hybrid collective is
//! compiled into a per-rank [`Stage`](crate::hybrid) chain — *data*, not
//! control flow. This module gives that data a checkable model: each rank
//! exports its chain as a [`RankSchedule`] of [`StageModel`]s (via
//! [`HyColl::export_schedule`](crate::hybrid::HyColl::export_schedule)),
//! and [`verify_handle`]/[`verify_program`] rebuild the *cross-rank
//! dependency graph* those chains imply:
//!
//! - `Arrive`/`Await` half-barrier pairs on the handle's window-private
//!   [`SyncGroup`](crate::mpi::sync::SyncGroup)s (one episode per matched
//!   arrival round),
//! - `Post`/`Wait` release edges of the §4.5 yellow sync (leader →
//!   children, one-directional),
//! - bridge chunk-stream sends/recvs matched by `(comm, src, dst, tag)`
//!   in FIFO channel order,
//! - nested bridge/node collectives matched by per-communicator call
//!   sequence (a rendezvous: nobody leaves before everybody entered).
//!
//! On that graph the verifier checks deadlock-freedom (Kahn cycle
//! detection), barrier arity consistency, orphaned or mismatched
//! sends/recvs, missing releases, fixed-root consistency across ranks,
//! and window bounds on every `Work` access. Every [`Diagnostic`] names
//! the offending rank/stage pair where one exists.
//!
//! The model is deliberately *coarse on data, exact on synchronization*:
//! `Work` access ranges may over-approximate (a striped leader is modeled
//! as touching the union of its stripes), but every barrier, flag and
//! message the schedule executes appears exactly once — which is what the
//! graph-shaped checks need.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;

/// A window-private barrier group: (window id, sync slot). Slot 0 is the
/// node-level red/yellow sync, slot 1 the leader-set sync — the same
/// slots [`SharedWindow::sync_group`](crate::mpi::win::SharedWindow::sync_group)
/// hands out.
pub type GroupId = (u64, usize);

/// A window-resident spin flag: (window id, flag index).
pub type FlagId = (u64, usize);

/// One byte-range touched by a `Work` stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    pub offset: usize,
    pub len: usize,
    pub write: bool,
}

/// One bridge point-to-point message of a pipelined chunk stream.
/// `src`/`dst` are ranks *of that comm* (node indices on the bridges).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MsgModel {
    pub comm: u64,
    pub src: usize,
    pub dst: usize,
    pub tag: i64,
    /// `true` on the sender's schedule, `false` on the receiver's.
    pub send: bool,
}

/// One nested collective call (bridge allgatherv, node-level reduce, …)
/// a `Work` stage performs. Matched across ranks by per-comm sequence
/// position.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CollModel {
    pub comm: u64,
    pub kind: &'static str,
    /// The comm's size — every member must call, in the same order.
    pub size: usize,
}

/// One stage of a rank's schedule, resolved against that rank's role
/// (a stage the rank sits out exports as [`StageModel::Skip`]).
#[derive(Clone, Debug)]
pub enum StageModel {
    /// Register at a barrier group (never blocks).
    Arrive { group: GroupId, size: usize },
    /// Complete the matching `Arrive` (blocks until all `size` arrive).
    Await { group: GroupId, size: usize },
    /// An op work unit: window accesses, chunk-stream messages, nested
    /// collectives.
    Work { chunk: usize, accesses: Vec<Access>, msgs: Vec<MsgModel>, colls: Vec<CollModel> },
    /// Yellow release, poster side (never blocks).
    Post { flag: FlagId },
    /// Yellow release, observer side (blocks until the matching post).
    Wait { flag: FlagId },
    /// The rank does not participate in this stage.
    Skip,
}

impl StageModel {
    fn kind_name(&self) -> &'static str {
        match self {
            StageModel::Arrive { .. } => "Arrive",
            StageModel::Await { .. } => "Await",
            StageModel::Work { .. } => "Work",
            StageModel::Post { .. } => "Post",
            StageModel::Wait { .. } => "Wait",
            StageModel::Skip => "Skip",
        }
    }
}

/// One rank's exported schedule for one handle.
#[derive(Clone, Debug)]
pub struct RankSchedule {
    /// Rank in the session's parent communicator.
    pub rank: usize,
    /// The rank's node index (= bridge rank on leaders).
    pub node: usize,
    /// Operation name (diagnostics only).
    pub op: &'static str,
    /// The root this schedule was compiled/exported for (`None` on
    /// unrooted ops). [`verify_handle`] requires agreement across ranks.
    pub root: Option<usize>,
    /// Backing window identity ([`SharedWindow::id`](crate::mpi::win::SharedWindow::id)).
    pub win: u64,
    /// Window length in bytes — the bound every access is checked against.
    pub win_len: usize,
    pub stages: Vec<StageModel>,
}

/// A verifier finding. Display names the offending rank/stage pair
/// wherever one exists.
#[derive(Clone, Debug)]
pub enum Diagnostic {
    /// A `Work` access exceeds the window.
    OutOfWindow { rank: usize, stage: usize, offset: usize, len: usize, win_len: usize },
    /// An `Await` with no outstanding `Arrive` on that group.
    AwaitWithoutArrive { rank: usize, stage: usize, group: GroupId },
    /// An `Arrive` never completed by an `Await`.
    ArriveWithoutAwait { rank: usize, stage: usize, group: GroupId },
    /// A second `Arrive` on a group while one is outstanding (the
    /// half-barrier contract forbids it).
    OverlappingArrive { rank: usize, stage: usize, group: GroupId },
    /// Ranks disagree on a group's participant count.
    GroupSizeMismatch { group: GroupId, sizes: Vec<(usize, Vec<usize>)> },
    /// Participants or per-rank episode counts don't line up: some rank
    /// would wait forever at the barrier.
    BarrierArity { group: GroupId, expected: usize, participants: Vec<(usize, usize)> },
    /// A `Wait` episode with no corresponding `Post` anywhere.
    MissingRelease { flag: FlagId, rank: usize, stage: usize, episode: usize },
    /// A send no recv ever matches.
    UnmatchedSend { rank: usize, stage: usize, comm: u64, dst: usize, tag: i64 },
    /// A recv no send ever matches.
    UnmatchedRecv { rank: usize, stage: usize, comm: u64, src: usize, tag: i64 },
    /// Nested collective call sequences disagree across a comm's members.
    CollectiveMismatch { comm: u64, detail: String },
    /// Fixed-root handles compiled against different roots.
    RootMismatch { roots: Vec<(usize, usize)> },
    /// A post-shrink schedule set does not cover exactly the expected
    /// survivor ranks (a dead rank still exports, or a survivor is
    /// missing from the rebuilt session).
    SurvivorSetMismatch { expected: Vec<usize>, got: Vec<usize> },
    /// A rebuilt fixed-root schedule still names a root that is not a
    /// live member of the shrunken communicator — the root died and the
    /// rebuild neither remapped nor re-elected it
    /// ([`RootPolicy::Reelect`](crate::hybrid::RootPolicy::Reelect)).
    DeadRootRetained { rank: usize, root: usize },
    /// The cross-rank dependency graph has a cycle (or events stranded
    /// behind one); `blocked` names the first few stuck events.
    Deadlock { blocked: Vec<String> },
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Diagnostic::OutOfWindow { rank, stage, offset, len, win_len } => write!(
                f,
                "rank {rank} stage {stage}: access [{offset}, {}) exceeds window length {win_len}",
                offset.saturating_add(*len)
            ),
            Diagnostic::AwaitWithoutArrive { rank, stage, group } => write!(
                f,
                "rank {rank} stage {stage}: Await on group {group:?} without a matching Arrive"
            ),
            Diagnostic::ArriveWithoutAwait { rank, stage, group } => {
                write!(f, "rank {rank} stage {stage}: Arrive on group {group:?} never Awaited")
            }
            Diagnostic::OverlappingArrive { rank, stage, group } => write!(
                f,
                "rank {rank} stage {stage}: second Arrive on group {group:?} while one is outstanding"
            ),
            Diagnostic::GroupSizeMismatch { group, sizes } => {
                write!(f, "group {group:?}: declared sizes disagree (size -> ranks): {sizes:?}")
            }
            Diagnostic::BarrierArity { group, expected, participants } => write!(
                f,
                "group {group:?}: expected {expected} participants with equal episode counts, \
                 got (rank, episodes): {participants:?}"
            ),
            Diagnostic::MissingRelease { flag, rank, stage, episode } => write!(
                f,
                "flag {flag:?}: rank {rank} stage {stage} waits for release episode {episode} \
                 but no such post exists"
            ),
            Diagnostic::UnmatchedSend { rank, stage, comm, dst, tag } => write!(
                f,
                "rank {rank} stage {stage}: send on comm {comm} to {dst} tag {tag} never received"
            ),
            Diagnostic::UnmatchedRecv { rank, stage, comm, src, tag } => write!(
                f,
                "rank {rank} stage {stage}: recv on comm {comm} from {src} tag {tag} never sent"
            ),
            Diagnostic::CollectiveMismatch { comm, detail } => write!(f, "comm {comm}: {detail}"),
            Diagnostic::RootMismatch { roots } => {
                write!(f, "fixed-root schedules disagree on the root (rank, root): {roots:?}")
            }
            Diagnostic::SurvivorSetMismatch { expected, got } => write!(
                f,
                "post-shrink schedules cover ranks {got:?} but the survivor set is {expected:?}"
            ),
            Diagnostic::DeadRootRetained { rank, root } => write!(
                f,
                "rank {rank}: rebuilt fixed-root schedule names root {root}, \
                 not a live member of the shrunken communicator"
            ),
            Diagnostic::Deadlock { blocked } => {
                write!(f, "dependency cycle — blocked events: {}", blocked.join("; "))
            }
        }
    }
}

/// Rank-local checks on one schedule: window bounds on every access and
/// well-formed `Arrive`/`Await` pairing per group. The cross-rank checks
/// of [`verify_handle`] subsume these; exposed separately so a single
/// rank (e.g. [`PlanCache::verify`](crate::coll::PlanCache::verify)) can
/// self-check without its peers' schedules.
pub fn verify_rank_local(s: &RankSchedule) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut outstanding: BTreeMap<GroupId, usize> = BTreeMap::new();
    for (i, st) in s.stages.iter().enumerate() {
        match st {
            StageModel::Arrive { group, .. } => {
                if outstanding.insert(*group, i).is_some() {
                    out.push(Diagnostic::OverlappingArrive { rank: s.rank, stage: i, group: *group });
                }
            }
            StageModel::Await { group, .. } => {
                if outstanding.remove(group).is_none() {
                    out.push(Diagnostic::AwaitWithoutArrive { rank: s.rank, stage: i, group: *group });
                }
            }
            StageModel::Work { accesses, .. } => {
                for a in accesses {
                    let ok = match a.offset.checked_add(a.len) {
                        Some(end) => end <= s.win_len,
                        None => false,
                    };
                    if !ok {
                        out.push(Diagnostic::OutOfWindow {
                            rank: s.rank,
                            stage: i,
                            offset: a.offset,
                            len: a.len,
                            win_len: s.win_len,
                        });
                    }
                }
            }
            _ => {}
        }
    }
    let mut leftover: Vec<(usize, GroupId)> = outstanding.into_iter().map(|(g, i)| (i, g)).collect();
    leftover.sort_unstable();
    for (stage, group) in leftover {
        out.push(Diagnostic::ArriveWithoutAwait { rank: s.rank, stage, group });
    }
    out
}

/// Verify one handle's schedules across all ranks of its communicator.
pub fn verify_handle(ranks: &[RankSchedule]) -> Vec<Diagnostic> {
    verify_program(&[ranks])
}

/// Verify a *post-shrink* handle: the full [`verify_handle`] pass plus a
/// coverage check that the exported schedules come from exactly the
/// expected survivor ranks — no dead rank still exporting, no survivor
/// dropped by the rebuilt session — and a **live-root check**: every
/// rooted schedule must name a root that is itself an expected survivor
/// (a dead fixed root the rebuild failed to remap or re-elect surfaces
/// as [`Diagnostic::DeadRootRetained`]). `expected` is in the shrunken
/// comm's rank numbering (0..survivors), the same numbering
/// [`RankSchedule::rank`] and [`RankSchedule::root`] carry after a
/// [`HyColl::rebuild`](crate::hybrid::HyColl::rebuild).
pub fn verify_survivors(ranks: &[RankSchedule], expected: &[usize]) -> Vec<Diagnostic> {
    let mut got: Vec<usize> = ranks.iter().map(|s| s.rank).collect();
    got.sort_unstable();
    got.dedup();
    let mut want: Vec<usize> = expected.to_vec();
    want.sort_unstable();
    want.dedup();
    let mut out = Vec::new();
    if got != want {
        out.push(Diagnostic::SurvivorSetMismatch { expected: want, got });
    }
    for s in ranks {
        if let Some(r) = s.root {
            if !want.contains(&r) {
                out.push(Diagnostic::DeadRootRetained { rank: s.rank, root: r });
            }
        }
    }
    out.extend(verify_handle(ranks));
    out
}

/// Verify a *program* of overlapping in-flight handles: each inner slice
/// is one handle's all-rank schedule set, listed in the order the ranks
/// start them (the [`progress`](crate::hybrid::progress) ordering rule).
/// Handles own private windows and groups but share bridge comms, so
/// message/collective matching and cycle detection run over the
/// concatenated per-rank event streams.
pub fn verify_program(handles: &[&[RankSchedule]]) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    // -- rank-local checks; remember broken arrive/await pairings so the
    //    graph phase doesn't build barrier edges from malformed chains.
    let mut broken_pairing: BTreeSet<(usize, usize)> = BTreeSet::new();
    for (h, hs) in handles.iter().enumerate() {
        for s in hs.iter() {
            let local = verify_rank_local(s);
            if local.iter().any(|d| {
                matches!(
                    d,
                    Diagnostic::AwaitWithoutArrive { .. }
                        | Diagnostic::ArriveWithoutAwait { .. }
                        | Diagnostic::OverlappingArrive { .. }
                )
            }) {
                broken_pairing.insert((h, s.rank));
            }
            out.extend(local);
        }
    }

    // -- fixed-root consistency, per handle.
    for hs in handles.iter() {
        let roots: Vec<(usize, usize)> =
            hs.iter().filter_map(|s| s.root.map(|r| (s.rank, r))).collect();
        let mut distinct: Vec<usize> = roots.iter().map(|&(_, r)| r).collect();
        distinct.sort_unstable();
        distinct.dedup();
        if distinct.len() > 1 {
            out.push(Diagnostic::RootMismatch { roots });
        }
    }

    // -- flatten every rank's stages (all handles, start order) into one
    //    event list; program order within a rank is edge-implied.
    struct Ev<'a> {
        rank: usize,
        handle: usize,
        stage: usize,
        op: &'a str,
        kind: &'a StageModel,
    }
    let mut evs: Vec<Ev<'_>> = Vec::new();
    let mut per_rank: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    let mut rank_list: Vec<usize> =
        handles.iter().flat_map(|hs| hs.iter().map(|s| s.rank)).collect();
    rank_list.sort_unstable();
    rank_list.dedup();
    for &rank in &rank_list {
        for (h, hs) in handles.iter().enumerate() {
            for s in hs.iter().filter(|s| s.rank == rank) {
                for (i, st) in s.stages.iter().enumerate() {
                    let id = evs.len();
                    evs.push(Ev { rank, handle: h, stage: i, op: s.op, kind: st });
                    per_rank.entry(rank).or_default().push(id);
                }
            }
        }
    }
    let mut pred: Vec<Option<usize>> = vec![None; evs.len()];
    for ids in per_rank.values() {
        for w in ids.windows(2) {
            pred[w[1]] = Some(w[0]);
        }
    }

    // -- classify events.
    #[derive(Default)]
    struct GroupUse {
        /// handle owning the group's window (groups are window-private).
        handle: usize,
        sizes: BTreeMap<usize, Vec<usize>>,
        arrives: BTreeMap<usize, Vec<usize>>,
        awaits: BTreeMap<usize, Vec<usize>>,
    }
    let mut groups: BTreeMap<GroupId, GroupUse> = BTreeMap::new();
    let mut flag_posts: BTreeMap<FlagId, Vec<usize>> = BTreeMap::new();
    let mut flag_waits: BTreeMap<FlagId, BTreeMap<usize, Vec<usize>>> = BTreeMap::new();
    struct CollCall {
        kind: &'static str,
        size: usize,
        ev: usize,
    }
    let mut colls: BTreeMap<u64, BTreeMap<usize, Vec<CollCall>>> = BTreeMap::new();
    let mut sends: BTreeMap<(u64, usize, usize, i64), VecDeque<usize>> = BTreeMap::new();
    for (id, ev) in evs.iter().enumerate() {
        match ev.kind {
            StageModel::Arrive { group, size } => {
                let g = groups.entry(*group).or_default();
                g.handle = ev.handle;
                g.sizes.entry(*size).or_default().push(ev.rank);
                g.arrives.entry(ev.rank).or_default().push(id);
            }
            StageModel::Await { group, size } => {
                let g = groups.entry(*group).or_default();
                g.sizes.entry(*size).or_default().push(ev.rank);
                g.awaits.entry(ev.rank).or_default().push(id);
            }
            StageModel::Post { flag } => flag_posts.entry(*flag).or_default().push(id),
            StageModel::Wait { flag } => {
                flag_waits.entry(*flag).or_default().entry(ev.rank).or_default().push(id)
            }
            StageModel::Work { msgs, colls: cs, .. } => {
                for m in msgs.iter().filter(|m| m.send) {
                    sends.entry((m.comm, m.src, m.dst, m.tag)).or_default().push_back(id);
                }
                for c in cs {
                    colls
                        .entry(c.comm)
                        .or_default()
                        .entry(ev.rank)
                        .or_default()
                        .push(CollCall { kind: c.kind, size: c.size, ev: id });
                }
            }
            StageModel::Skip => {}
        }
    }

    let mut edges: Vec<(usize, usize)> = Vec::new();
    for ids in per_rank.values() {
        for w in ids.windows(2) {
            edges.push((w[0], w[1]));
        }
    }
    let mut next_node = evs.len();
    let mut new_vnode = || {
        let v = next_node;
        next_node += 1;
        v
    };

    // -- barrier episodes: the i-th matched arrival round per group.
    for (gid, gu) in &groups {
        if gu.sizes.len() > 1 {
            out.push(Diagnostic::GroupSizeMismatch {
                group: *gid,
                sizes: gu.sizes.iter().map(|(sz, rs)| (*sz, rs.clone())).collect(),
            });
            continue;
        }
        let size = *gu.sizes.keys().next().expect("a used group has a declared size");
        let counts: Vec<(usize, usize)> =
            gu.arrives.iter().map(|(r, v)| (*r, v.len())).collect();
        let nepisodes = counts.iter().map(|&(_, c)| c).max().unwrap_or(0);
        let arity_ok =
            gu.arrives.len() == size && counts.iter().all(|&(_, c)| c == nepisodes);
        if !arity_ok {
            out.push(Diagnostic::BarrierArity { group: *gid, expected: size, participants: counts });
            continue;
        }
        if gu.arrives.keys().any(|r| broken_pairing.contains(&(gu.handle, *r)))
            || !gu
                .arrives
                .iter()
                .all(|(r, v)| gu.awaits.get(r).is_some_and(|w| w.len() == v.len()))
        {
            continue; // pairing diagnostics already emitted above
        }
        for e in 0..nepisodes {
            let v = new_vnode();
            for (r, arr) in &gu.arrives {
                edges.push((arr[e], v));
                edges.push((v, gu.awaits[r][e]));
            }
        }
    }

    // -- yellow releases: wait episode i needs post episode i. Posts never
    //    block, so surplus posts are harmless; a missing one strands the
    //    waiter.
    for (fid, waits) in &flag_waits {
        let posts = flag_posts.get(fid).map(Vec::as_slice).unwrap_or(&[]);
        for (rank, wl) in waits {
            for (e, &wev) in wl.iter().enumerate() {
                match posts.get(e) {
                    Some(&pev) => edges.push((pev, wev)),
                    None => out.push(Diagnostic::MissingRelease {
                        flag: *fid,
                        rank: *rank,
                        stage: evs[wev].stage,
                        episode: e,
                    }),
                }
            }
        }
    }

    // -- chunk-stream messages: FIFO per (comm, src, dst, tag) channel.
    for (id, ev) in evs.iter().enumerate() {
        if let StageModel::Work { msgs, .. } = ev.kind {
            for m in msgs.iter().filter(|m| !m.send) {
                match sends.get_mut(&(m.comm, m.src, m.dst, m.tag)).and_then(VecDeque::pop_front) {
                    Some(sev) => edges.push((sev, id)),
                    None => out.push(Diagnostic::UnmatchedRecv {
                        rank: ev.rank,
                        stage: ev.stage,
                        comm: m.comm,
                        src: m.src,
                        tag: m.tag,
                    }),
                }
            }
        }
    }
    for (&(comm, _src, dst, tag), q) in &sends {
        for &sev in q {
            out.push(Diagnostic::UnmatchedSend {
                rank: evs[sev].rank,
                stage: evs[sev].stage,
                comm,
                dst,
                tag,
            });
        }
    }

    // -- nested collectives: rendezvous per per-comm sequence position.
    for (comm, parts) in &colls {
        let (r0, seq0) = parts.iter().next().expect("a used comm has a caller");
        let mut ok = true;
        for (r, seq) in parts.iter().skip(1) {
            if seq.len() != seq0.len()
                || seq.iter().zip(seq0.iter()).any(|(a, b)| a.kind != b.kind || a.size != b.size)
            {
                out.push(Diagnostic::CollectiveMismatch {
                    comm: *comm,
                    detail: format!(
                        "rank {r} calls [{}] but rank {r0} calls [{}]",
                        seq.iter().map(|c| c.kind).collect::<Vec<_>>().join(", "),
                        seq0.iter().map(|c| c.kind).collect::<Vec<_>>().join(", ")
                    ),
                });
                ok = false;
            }
        }
        if ok {
            for (e, c) in seq0.iter().enumerate() {
                if parts.len() != c.size {
                    out.push(Diagnostic::CollectiveMismatch {
                        comm: *comm,
                        detail: format!(
                            "{} episode {e} declares {} participants but {} ranks call it",
                            c.kind,
                            c.size,
                            parts.len()
                        ),
                    });
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        for e in 0..seq0.len() {
            // Rendezvous: the episode depends on every participant's
            // progress up to just before its call, and every call depends
            // on the episode — nobody completes before everybody entered.
            let v = new_vnode();
            for seq in parts.values() {
                let ev = seq[e].ev;
                if let Some(p) = pred[ev] {
                    edges.push((p, v));
                }
                edges.push((v, ev));
            }
        }
    }

    // -- Kahn topological sort: leftovers are deadlocked (in or behind a
    //    cycle).
    let nnodes = next_node;
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nnodes];
    let mut indeg = vec![0usize; nnodes];
    for &(a, b) in &edges {
        adj[a].push(b);
        indeg[b] += 1;
    }
    let mut q: VecDeque<usize> = (0..nnodes).filter(|&n| indeg[n] == 0).collect();
    let mut done = 0usize;
    while let Some(n) = q.pop_front() {
        done += 1;
        for &m in &adj[n] {
            indeg[m] -= 1;
            if indeg[m] == 0 {
                q.push_back(m);
            }
        }
    }
    if done < nnodes {
        let mut blocked: Vec<String> = (0..evs.len())
            .filter(|&n| indeg[n] > 0)
            .map(|n| {
                let e = &evs[n];
                format!("rank {} handle {} stage {} ({} {})", e.rank, e.handle, e.stage, e.op, e.kind.kind_name())
            })
            .collect();
        blocked.truncate(8);
        out.push(Diagnostic::Deadlock { blocked });
    }

    out
}

// ---- micro-op lowering (DESIGN.md §6c) --------------------------------
//
// The verifier above checks ONE topological order of the dependency
// graph. The model checker (`analysis::explore`) instead *executes* the
// schedules under every interleaving, which needs each stage broken into
// single-rank transitions with enabled-predicates: that is what a
// `MicroStep` is. The lowering is shared here so the verifier and the
// checker agree on what a schedule means.

/// A FIFO bridge channel identity: `(comm, src, dst, tag)` — the match
/// key of [`MsgModel`].
pub type ChanId = (u64, usize, usize, i64);

/// One single-rank transition of a lowered schedule. Each variant's
/// enabled-predicate mirrors the runtime primitive it models (see
/// `analysis::explore::ScheduleModel`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MicroOp {
    /// Register at a barrier group — never blocks.
    Arrive { group: GroupId, size: usize },
    /// Complete the outstanding arrival — enabled once the registered
    /// generation closed (all `size` arrived).
    AwaitGroup { group: GroupId },
    /// Yellow release, poster side — never blocks.
    Post { flag: FlagId },
    /// Yellow release, observer side — enabled once an unconsumed post
    /// exists for this observer.
    WaitFlag { flag: FlagId },
    /// Eagerly-buffered chunk-stream send — never blocks.
    Send { chan: ChanId },
    /// FIFO channel receive — enabled while the channel is non-empty.
    Recv { chan: ChanId },
    /// Enter a nested collective (post my arrival) — never blocks.
    CollEnter { comm: u64, kind: &'static str, size: usize },
    /// Leave the rendezvous — enabled once every participant entered
    /// this episode.
    CollLeave { comm: u64 },
    /// A window byte-range touch — never blocks, carries no sync.
    Access { win: u64, offset: usize, len: usize, write: bool },
}

/// One lowered transition with its provenance (rank, handle index in the
/// program, stage index, op name) — what violation traces print.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct MicroStep {
    pub rank: usize,
    pub handle: usize,
    pub stage: usize,
    pub op: &'static str,
    pub micro: MicroOp,
}

/// Lower a program of in-flight handles (same shape as
/// [`verify_program`]'s input) into per-rank micro-op sequences, keyed
/// by rank, in rank program order (handles in start order). `Skip`
/// stages lower to nothing; a `Work` stage lowers to its messages (in
/// schedule order — FIFO identity preserved), then its nested
/// collectives, then its accesses (accesses never block and are ordered
/// against peers by the surrounding sync stages, so their intra-stage
/// position is immaterial to every checked property).
pub fn lower_program(handles: &[&[RankSchedule]]) -> BTreeMap<usize, Vec<MicroStep>> {
    let mut out: BTreeMap<usize, Vec<MicroStep>> = BTreeMap::new();
    let mut rank_list: Vec<usize> =
        handles.iter().flat_map(|hs| hs.iter().map(|s| s.rank)).collect();
    rank_list.sort_unstable();
    rank_list.dedup();
    for &rank in &rank_list {
        let prog = out.entry(rank).or_default();
        for (h, hs) in handles.iter().enumerate() {
            for s in hs.iter().filter(|s| s.rank == rank) {
                for (i, st) in s.stages.iter().enumerate() {
                    let mut push = |micro: MicroOp| {
                        prog.push(MicroStep { rank, handle: h, stage: i, op: s.op, micro })
                    };
                    match st {
                        StageModel::Arrive { group, size } => {
                            push(MicroOp::Arrive { group: *group, size: *size })
                        }
                        StageModel::Await { group, .. } => {
                            push(MicroOp::AwaitGroup { group: *group })
                        }
                        StageModel::Post { flag } => push(MicroOp::Post { flag: *flag }),
                        StageModel::Wait { flag } => push(MicroOp::WaitFlag { flag: *flag }),
                        StageModel::Work { accesses, msgs, colls, .. } => {
                            for m in msgs {
                                let chan = (m.comm, m.src, m.dst, m.tag);
                                push(if m.send {
                                    MicroOp::Send { chan }
                                } else {
                                    MicroOp::Recv { chan }
                                });
                            }
                            for c in colls {
                                push(MicroOp::CollEnter { comm: c.comm, kind: c.kind, size: c.size });
                                push(MicroOp::CollLeave { comm: c.comm });
                            }
                            for a in accesses {
                                push(MicroOp::Access {
                                    win: s.win,
                                    offset: a.offset,
                                    len: a.len,
                                    write: a.write,
                                });
                            }
                        }
                        StageModel::Skip => {}
                    }
                }
            }
        }
    }
    out
}

/// [`lower_program`] for a single handle's all-rank schedule set.
pub fn lower_handle(ranks: &[RankSchedule]) -> BTreeMap<usize, Vec<MicroStep>> {
    lower_program(&[ranks])
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIN: u64 = 7;
    const GRP: GroupId = (WIN, 0);
    const FLG: FlagId = (WIN, 0);

    fn work(accesses: Vec<Access>, msgs: Vec<MsgModel>, colls: Vec<CollModel>) -> StageModel {
        StageModel::Work { chunk: 0, accesses, msgs, colls }
    }

    fn sched(rank: usize, root: Option<usize>, stages: Vec<StageModel>) -> RankSchedule {
        RankSchedule { rank, node: rank, op: "test", root, win: WIN, win_len: 64, stages }
    }

    /// Two ranks: barrier, leader write + yellow release, child read.
    fn two_rank_clean() -> Vec<RankSchedule> {
        vec![
            sched(
                0,
                None,
                vec![
                    StageModel::Arrive { group: GRP, size: 2 },
                    StageModel::Await { group: GRP, size: 2 },
                    work(vec![Access { offset: 0, len: 32, write: true }], vec![], vec![]),
                    StageModel::Post { flag: FLG },
                ],
            ),
            sched(
                1,
                None,
                vec![
                    StageModel::Arrive { group: GRP, size: 2 },
                    StageModel::Await { group: GRP, size: 2 },
                    StageModel::Wait { flag: FLG },
                ],
            ),
        ]
    }

    #[test]
    fn clean_schedule_passes() {
        let diags = verify_handle(&two_rank_clean());
        assert!(diags.is_empty(), "expected clean, got: {diags:?}");
    }

    #[test]
    fn dropped_arrive_is_flagged_with_rank_and_stage() {
        let mut s = two_rank_clean();
        s[0].stages[0] = StageModel::Skip; // rank 0 never arrives
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(
                d,
                Diagnostic::AwaitWithoutArrive { rank: 0, stage: 1, group } if *group == GRP
            )),
            "got: {diags:?}"
        );
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::BarrierArity { expected: 2, .. })),
            "arity must also fire: {diags:?}"
        );
    }

    #[test]
    fn skipped_release_is_flagged() {
        let mut s = two_rank_clean();
        s[0].stages[3] = StageModel::Skip; // leader forgets the post
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(
                d,
                Diagnostic::MissingRelease { rank: 1, stage: 2, episode: 0, flag } if *flag == FLG
            )),
            "got: {diags:?}"
        );
    }

    #[test]
    fn shrunk_window_is_flagged() {
        let mut s = two_rank_clean();
        s[0].win_len = 16; // Work writes [0, 32)
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(
                d,
                Diagnostic::OutOfWindow { rank: 0, stage: 2, offset: 0, len: 32, win_len: 16 }
            )),
            "got: {diags:?}"
        );
    }

    #[test]
    fn mismatched_tag_orphans_both_sides() {
        let send =
            |tag| MsgModel { comm: 9, src: 0, dst: 1, tag, send: true };
        let recv =
            |tag| MsgModel { comm: 9, src: 0, dst: 1, tag, send: false };
        let s = vec![
            sched(0, Some(0), vec![work(vec![], vec![send(5)], vec![])]),
            sched(1, Some(0), vec![work(vec![], vec![recv(6)], vec![])]),
        ];
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::UnmatchedSend { rank: 0, tag: 5, .. })),
            "got: {diags:?}"
        );
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::UnmatchedRecv { rank: 1, tag: 6, .. })),
            "got: {diags:?}"
        );
    }

    #[test]
    fn fixed_root_disagreement_is_flagged() {
        let s = vec![sched(0, Some(0), vec![]), sched(1, Some(2), vec![])];
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::RootMismatch { .. })),
            "got: {diags:?}"
        );
    }

    #[test]
    fn group_size_disagreement_is_flagged() {
        let s = vec![
            sched(
                0,
                None,
                vec![
                    StageModel::Arrive { group: GRP, size: 2 },
                    StageModel::Await { group: GRP, size: 2 },
                ],
            ),
            sched(
                1,
                None,
                vec![
                    StageModel::Arrive { group: GRP, size: 3 },
                    StageModel::Await { group: GRP, size: 3 },
                ],
            ),
        ];
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::GroupSizeMismatch { .. })),
            "got: {diags:?}"
        );
    }

    #[test]
    fn message_cycle_deadlocks() {
        // Each rank recvs from the other before sending to it: classic
        // rendezvous deadlock.
        let m = |src: usize, dst: usize, send: bool| MsgModel { comm: 9, src, dst, tag: 0, send };
        let s = vec![
            sched(
                0,
                None,
                vec![
                    work(vec![], vec![m(1, 0, false)], vec![]),
                    work(vec![], vec![m(0, 1, true)], vec![]),
                ],
            ),
            sched(
                1,
                None,
                vec![
                    work(vec![], vec![m(0, 1, false)], vec![]),
                    work(vec![], vec![m(1, 0, true)], vec![]),
                ],
            ),
        ];
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::Deadlock { .. })),
            "got: {diags:?}"
        );
    }

    #[test]
    fn collective_order_mismatch_is_flagged() {
        let c = |kind| CollModel { comm: 9, kind, size: 2 };
        let s = vec![
            sched(0, None, vec![work(vec![], vec![], vec![c("bcast"), c("reduce")])]),
            sched(1, None, vec![work(vec![], vec![], vec![c("reduce"), c("bcast")])]),
        ];
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::CollectiveMismatch { comm: 9, .. })),
            "got: {diags:?}"
        );
    }

    #[test]
    fn collective_missing_participant_is_flagged() {
        let c = CollModel { comm: 9, kind: "allgatherv", size: 2 };
        let s = vec![
            sched(0, None, vec![work(vec![], vec![], vec![c])]),
            sched(1, None, vec![]),
        ];
        let diags = verify_handle(&s);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::CollectiveMismatch { comm: 9, .. })),
            "got: {diags:?}"
        );
    }

    #[test]
    fn overlapping_in_flight_handles_verify_as_a_program() {
        // Handle B's barrier sits between handle A's arrive and await in
        // rank 0's stream — legal (private groups), must stay clean.
        let grp_b: GroupId = (8, 0);
        let a = two_rank_clean();
        let b = vec![
            RankSchedule {
                rank: 0,
                node: 0,
                op: "b",
                root: None,
                win: 8,
                win_len: 16,
                stages: vec![
                    StageModel::Arrive { group: grp_b, size: 2 },
                    StageModel::Await { group: grp_b, size: 2 },
                ],
            },
            RankSchedule {
                rank: 1,
                node: 1,
                op: "b",
                root: None,
                win: 8,
                win_len: 16,
                stages: vec![
                    StageModel::Arrive { group: grp_b, size: 2 },
                    StageModel::Await { group: grp_b, size: 2 },
                ],
            },
        ];
        let diags = verify_program(&[&a, &b]);
        assert!(diags.is_empty(), "expected clean program, got: {diags:?}");
    }

    #[test]
    fn survivor_coverage_passes_on_exact_match() {
        let diags = verify_survivors(&two_rank_clean(), &[0, 1]);
        assert!(diags.is_empty(), "expected clean, got: {diags:?}");
    }

    #[test]
    fn stale_or_missing_survivor_is_flagged() {
        // A schedule from a rank outside the survivor set (stale export
        // from before the shrink) and a missing survivor both surface.
        let diags = verify_survivors(&two_rank_clean(), &[0, 2]);
        assert!(
            diags.iter().any(|d| matches!(
                d,
                Diagnostic::SurvivorSetMismatch { expected, got }
                    if expected == &[0, 2] && got == &[0, 1]
            )),
            "got: {diags:?}"
        );
    }

    #[test]
    fn dead_root_retained_is_flagged() {
        // Mutation: a rebuilt rooted handle whose schedules still name
        // the pre-shrink root (rank 5 — not a survivor) must be flagged;
        // the same set with the root remapped onto a survivor is clean.
        let mut s = two_rank_clean();
        for r in &mut s {
            r.root = Some(5);
        }
        let diags = verify_survivors(&s, &[0, 1]);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::DeadRootRetained { root: 5, .. })),
            "got: {diags:?}"
        );
        for r in &mut s {
            r.root = Some(0);
        }
        let diags = verify_survivors(&s, &[0, 1]);
        assert!(diags.is_empty(), "expected clean after remap, got: {diags:?}");
    }

    #[test]
    fn diagnostics_display_names_rank_and_stage() {
        let d = Diagnostic::OutOfWindow { rank: 3, stage: 5, offset: 8, len: 16, win_len: 12 };
        let s = d.to_string();
        assert!(s.contains("rank 3") && s.contains("stage 5"), "{s}");
    }

    #[test]
    fn lowering_preserves_order_and_provenance() {
        let progs = lower_handle(&two_rank_clean());
        let r0 = &progs[&0];
        assert_eq!(
            r0.iter().map(|m| m.micro).collect::<Vec<_>>(),
            vec![
                MicroOp::Arrive { group: GRP, size: 2 },
                MicroOp::AwaitGroup { group: GRP },
                MicroOp::Access { win: WIN, offset: 0, len: 32, write: true },
                MicroOp::Post { flag: FLG },
            ]
        );
        // Provenance: the Access came from stage 2 of handle 0.
        assert_eq!((r0[2].handle, r0[2].stage, r0[2].op), (0, 2, "test"));
        let r1 = &progs[&1];
        assert!(matches!(r1.last().unwrap().micro, MicroOp::WaitFlag { flag } if flag == FLG));
    }

    #[test]
    fn lowering_orders_msgs_before_colls_within_a_stage() {
        let s = vec![sched(
            0,
            None,
            vec![work(
                vec![Access { offset: 0, len: 8, write: false }],
                vec![MsgModel { comm: 9, src: 0, dst: 1, tag: 3, send: true }],
                vec![CollModel { comm: 9, kind: "allgatherv", size: 1 }],
            )],
        )];
        let progs = lower_handle(&s);
        let kinds: Vec<&str> = progs[&0]
            .iter()
            .map(|m| match m.micro {
                MicroOp::Send { .. } => "send",
                MicroOp::CollEnter { .. } => "enter",
                MicroOp::CollLeave { .. } => "leave",
                MicroOp::Access { .. } => "access",
                _ => "other",
            })
            .collect();
        assert_eq!(kinds, vec!["send", "enter", "leave", "access"]);
    }
}
