//! `bench_all` — the tracked data-plane/fabric/session/overlap
//! performance baseline.
//!
//! PR 5 edition: the PR-3 fabric comparison and the PR-4 leader sweep
//! are kept, and two sections are added:
//!
//! - **irregular engine-scale cases** — the §5.2.2 partially-populated
//!   VulcanSb shapes (12 of 16 cores per node) at 512 and 1024 ranks,
//!   alongside the fully-populated cases they mirror;
//! - an **overlap sweep** — blocking vs split-phase (DESIGN.md §5e) for
//!   the micro probe (pipelined Fixed-root bcast against modeled
//!   compute), the SUMMA kernel (next panel's broadcasts prefetched
//!   under the dgemm) and the Poisson kernel (halo exchange hidden
//!   under the interior sweep), asserting split strictly below blocking
//!   where the panels are ≥ 256 KiB.
//!
//! Everything lands in `BENCH_PR5.json` at the repo root. Modeled
//! virtual time must not depend on the fabric (asserted per case); the
//! parity runs assert bit-identical result bytes and per-rank virtual
//! clocks across fabrics.
//!
//! PR 7 adds a **chaos sweep** (`--chaos`): the same session-API
//! allreduce workload under deterministic fault injection — skew, OS
//! noise, a 4× straggler — for both §4.5 sync schemes at k ∈ {1, 2},
//! reporting each scenario's vtime degradation over the clean run
//! (results are asserted bit-identical: faults perturb timing, never
//! bytes), plus a dead-rank scenario per configuration that kills the
//! last node's leader mid-run and must recover through
//! `HybridCtx::shrink` + `HyColl::rebuild`. Lands in
//! `BENCH_PR7.chaos.json`.
//!
//! PR 8 adds a **recovery matrix** (`--chaos-recovery`): kernel-shaped
//! drills (SUMMA panel broadcasts, the Poisson residual allreduce)
//! driven end-to-end by the self-healing retry driver
//! (`HybridCtx::run_resilient`) under seeded deaths — a dead fixed
//! root re-elected through `RootPolicy::Reelect`, a shrink-coordinator
//! death mid-agreement (restarting the epoch-tagged round), and two
//! overlapping deaths — each reporting the per-epoch
//! detect/shrink/rebuild vtime breakdown. The detection-cost model's
//! charges are asserted nonzero for every scenario. Lands in
//! `BENCH_PR8.recovery.json`; the `--chaos` dead-leader runs now ride
//! the same driver and report epochs + detection vtime too.
//!
//! ```text
//! cargo run --release --bin bench_all                      # full sweep, writes BENCH_PR5.json
//! cargo run --release --bin bench_all -- --smoke           # CI-sized sweep (same pipeline)
//! cargo run --release --bin bench_all -- --strict          # exit non-zero below the speedup targets
//! cargo run --release --bin bench_all -- --out P           # alternate output path
//! cargo run --release --bin bench_all -- --chaos           # fault-injection sweep only
//! cargo run --release --bin bench_all -- --chaos-recovery  # run_resilient recovery matrix
//! cargo run --release --bin bench_all -- --tuned           # tuned-vs-static selection sweep
//! ```
//!
//! PR 9 adds the **tuned sweep** (`--tuned`): per figure point, every
//! viable candidate from the selection registry is raced through the
//! real simulation (a `PinnedSelector` forces the choice) and the
//! winner is reported against the static Open MPI 4.0.1 tables, with
//! tuned ≤ static asserted per point. Lands in `BENCH_PR9.tuned.json`.
//! The `--bcast-small-max` flag family (mirroring the microbench CLI)
//! overrides any static threshold for the run.

use hympi::coll::{CollOp, Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::figures::common::{drive_report, overlap_probe};
use hympi::hybrid::{
    AllreduceMethod, EpochReport, HybridCtx, LeaderPolicy, Resilience, RetryPolicy, RootPolicy,
    SyncScheme,
};
use hympi::kernels::poisson::{recovery_drill as poisson_recovery_drill, run as poisson_run, PoissonCfg};
use hympi::kernels::summa::{recovery_drill as summa_recovery_drill, run as summa_run, SummaCfg};
use hympi::kernels::{Backend, DrillOutcome, Variant};
use hympi::mpi::env::ProcEnv;
use hympi::mpi::{Datatype, FaultPlan, ReduceOp};
use hympi::util::to_bytes;
use std::time::Instant;

struct Case {
    name: String,
    modeled_us: f64,
    wall_new_ms: f64,
    wall_old_ms: f64,
}

impl Case {
    fn speedup(&self) -> f64 {
        if self.wall_new_ms > 0.0 {
            self.wall_old_ms / self.wall_new_ms
        } else {
            0.0
        }
    }
}

fn report_case(case: &Case) {
    println!(
        "{:<36} modeled {:>12.2} us | wall new {:>9.1} ms | old fabric {:>9.1} ms | {:>5.2}x",
        case.name,
        case.modeled_us,
        case.wall_new_ms,
        case.wall_old_ms,
        case.speedup()
    );
}

/// One paired (new vs legacy message fabric) collective measurement.
fn coll_case(name: &str, spec: ClusterSpec, op: CollOp, bytes: usize, flavor: Flavor, fast: bool) -> Case {
    let t0 = Instant::now();
    let new = drive_report(spec.clone(), fast, op, bytes, flavor);
    let wall_new_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let old = drive_report(spec.with_legacy_fabric(true), fast, op, bytes, flavor);
    let wall_old_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!(
        (new.mean_us - old.mean_us).abs() < 1e-6,
        "{name}: modeled latency must not depend on the fabric ({} vs {})",
        new.mean_us,
        old.mean_us
    );
    let case = Case { name: name.to_string(), modeled_us: new.mean_us, wall_new_ms, wall_old_ms };
    report_case(&case);
    case
}

/// The fig17 SUMMA kernel (hybrid variant, modeled compute) on both fabrics.
fn summa_case(smoke: bool) -> Case {
    let (n, nodes) = if smoke { (128, 1) } else { (512, 4) };
    let cfg = || SummaCfg { n, variant: Variant::HybridMpiMpi, backend: Backend::Modeled, threads: 16 };
    let t0 = Instant::now();
    let new = summa_run(ClusterSpec::preset(Preset::VulcanSb, nodes), cfg());
    let wall_new_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let old = summa_run(ClusterSpec::preset(Preset::VulcanSb, nodes).with_legacy_fabric(true), cfg());
    let wall_old_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!(
        (new.total_us - old.total_us).abs() < 1e-6,
        "summa: modeled time must not depend on the fabric"
    );
    assert!(
        (new.checksum - old.checksum).abs() < 1e-12,
        "summa: results must not depend on the fabric"
    );
    let case = Case {
        name: format!("fig17_summa_n{n}_hybrid"),
        modeled_us: new.total_us,
        wall_new_ms,
        wall_old_ms,
    };
    report_case(&case);
    case
}

/// Result-level parity workload: pure + hybrid (single- and multi-leader)
/// collectives through cached plans; returns a digest of every result
/// plus the final virtual clock.
fn parity_workload(env: &mut ProcEnv) -> (Vec<u8>, f64) {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let mut cache = PlanCache::new();
    let fl = Flavor::hybrid(SyncScheme::Spin);
    let fl2 = Flavor::hybrid_k(SyncScheme::Spin, 2);
    let mut digest = Vec::new();
    for it in 0..3usize {
        let mine = vec![(me + it) as u8; 1024];
        let mut ag = vec![0u8; 1024 * p];
        cache.allgather(env, &w, Flavor::Pure, &mine, Some(&mut ag));
        digest.extend_from_slice(&ag[..ag.len().min(64)]);
        let mut hy = vec![0u8; 1024 * p];
        cache.allgather(env, &w, fl, &mine, Some(&mut hy));
        assert_eq!(ag, hy, "pure and hybrid allgather must agree");
        let mut hy2 = vec![0u8; 1024 * p];
        cache.allgather(env, &w, fl2, &mine, Some(&mut hy2));
        assert_eq!(ag, hy2, "pure and 2-leader hybrid allgather must agree");

        let vals: Vec<f64> = (0..128).map(|i| ((me + 1) * (i + it + 1)) as f64).collect();
        let mut ar = to_bytes(&vals).to_vec();
        cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut ar);
        digest.extend_from_slice(&ar[..64]);

        let mut bc = vec![it as u8; 2048];
        cache.bcast(env, &w, Flavor::Pure, 0, 2048, Some(&mut bc));
        digest.extend_from_slice(&bc[..64]);
    }
    env.barrier(&w);
    let v = env.vclock();
    cache.free(env);
    (digest, v)
}

/// Assert result bytes bit-identical and per-rank virtual clocks equal
/// across the two fabrics (the acceptance invariant of the PR).
fn fabric_parity(name: &str, spec: ClusterSpec) {
    let new = SimCluster::new(spec.clone()).run(parity_workload);
    let old = SimCluster::new(spec.with_legacy_fabric(true)).run(parity_workload);
    assert_eq!(new.outputs.len(), old.outputs.len());
    for (r, ((da, va), (db, vb))) in new.outputs.iter().zip(old.outputs.iter()).enumerate() {
        assert_eq!(da, db, "{name}: rank {r} result bytes must not depend on the fabric");
        assert!(
            (va - vb).abs() < 1e-9,
            "{name}: rank {r} modeled virtual time must not depend on the fabric ({va} vs {vb})"
        );
    }
    println!("parity {name}: result bytes + modeled vtimes identical on both fabrics");
}

/// One point of the leaders-per-node sweep (session API, new fabric).
struct LeaderCase {
    name: String,
    ranks: usize,
    leaders: usize,
    modeled_us: f64,
    wall_ms: f64,
}

/// Measure one hybrid collective at `leaders` leaders per node.
fn leader_case(
    base: &str,
    spec: ClusterSpec,
    op: CollOp,
    bytes: usize,
    leaders: usize,
    fast: bool,
) -> LeaderCase {
    let ranks = spec.world_size();
    let fl = Flavor::hybrid_k(SyncScheme::Spin, leaders);
    let t0 = Instant::now();
    let rep = drive_report(spec, fast, op, bytes, fl);
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let case = LeaderCase {
        name: format!("{base}_{ranks}r_k{leaders}"),
        ranks,
        leaders,
        modeled_us: rep.mean_us,
        wall_ms,
    };
    println!(
        "{:<36} modeled {:>12.2} us | wall {:>9.1} ms | k={}",
        case.name, case.modeled_us, case.wall_ms, case.leaders
    );
    case
}

/// Sweep k ∈ {1, 2, 4} for one (spec, op, bytes) configuration and
/// assert the PR-4 acceptance bound where it applies (`expect_gain`:
/// large bridge blocks → k = 2 strictly below k = 1).
fn leader_sweep(
    out: &mut Vec<LeaderCase>,
    base: &str,
    spec: &ClusterSpec,
    op: CollOp,
    bytes: usize,
    expect_gain: bool,
    fast: bool,
) {
    let ks = [1usize, 2, 4];
    let start = out.len();
    for &k in &ks {
        out.push(leader_case(base, spec.clone(), op, bytes, k, fast));
    }
    let k1 = out[start].modeled_us;
    let k2 = out[start + 1].modeled_us;
    if expect_gain {
        assert!(
            k2 < k1,
            "{base}: k=2 modeled vtime ({k2}) must be strictly below k=1 ({k1})"
        );
        println!("{base}: k=2 is {:.1}% below k=1 (modeled) [PASS]", (1.0 - k2 / k1) * 100.0);
    }
}

/// One blocking-vs-split-phase comparison point (modeled vtime is the
/// number under test; `gain` = 1 − split/blocking).
struct OverlapCase {
    name: String,
    blocking_us: f64,
    split_us: f64,
    wall_ms: f64,
}

impl OverlapCase {
    fn gain(&self) -> f64 {
        if self.blocking_us > 0.0 {
            1.0 - self.split_us / self.blocking_us
        } else {
            0.0
        }
    }
}

fn report_overlap(c: &OverlapCase) {
    println!(
        "{:<36} blocking {:>12.2} us | split {:>12.2} us | {:>5.1}% hidden | wall {:>8.1} ms",
        c.name,
        c.blocking_us,
        c.split_us,
        c.gain() * 100.0,
        c.wall_ms
    );
}

/// The split-phase micro probe: pipelined Fixed-root bcast vs modeled
/// compute, blocking and split legs through the same handle shape.
fn probe_case(name: &str, spec: ClusterSpec, bytes: usize, compute_us: f64, fast: bool) -> OverlapCase {
    let t0 = Instant::now();
    let (blocking_us, split_us) = overlap_probe(spec, bytes, compute_us, 4, fast);
    let case = OverlapCase {
        name: name.to_string(),
        blocking_us,
        split_us,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    };
    report_overlap(&case);
    case
}

/// SUMMA blocking-hybrid vs split-phase-overlap on one spec. `assert_win`
/// enforces the PR-5 acceptance bound (≥ 256 KiB panels: split strictly
/// below blocking) and the two variants' result parity.
fn summa_overlap_case(name: &str, spec: ClusterSpec, n: usize, backend: Backend, assert_win: bool) -> OverlapCase {
    let cfg = |variant| SummaCfg { n, variant, backend, threads: 1 };
    let t0 = Instant::now();
    let blocking = summa_run(spec.clone(), cfg(Variant::HybridMpiMpi));
    let split = summa_run(spec, cfg(Variant::HybridOverlap));
    assert!(
        (blocking.checksum - split.checksum).abs() <= 1e-9 * blocking.checksum.abs().max(1.0),
        "{name}: split-phase SUMMA must reproduce the blocking result"
    );
    if assert_win {
        assert!(
            split.total_us < blocking.total_us,
            "{name}: split-phase SUMMA ({}) must be strictly below blocking ({})",
            split.total_us,
            blocking.total_us
        );
    }
    let case = OverlapCase {
        name: name.to_string(),
        blocking_us: blocking.total_us,
        split_us: split.total_us,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    };
    report_overlap(&case);
    case
}

/// Poisson blocking-hybrid vs split-phase-overlap (fixed iteration count
/// so both variants run identical work).
fn poisson_overlap_case(name: &str, spec: ClusterSpec, n: usize, iters: usize, backend: Backend) -> OverlapCase {
    let cfg = |variant| PoissonCfg { n, tol: 0.0, max_iters: iters, variant, backend, threads: 1 };
    let t0 = Instant::now();
    let blocking = poisson_run(spec.clone(), cfg(Variant::HybridMpiMpi));
    let split = poisson_run(spec, cfg(Variant::HybridOverlap));
    if backend != Backend::Phantom {
        assert!(
            (blocking.checksum - split.checksum).abs() <= 1e-9 * blocking.checksum.abs().max(1.0),
            "{name}: split-phase Poisson must reproduce the blocking result"
        );
    }
    assert_eq!(blocking.iters, split.iters, "{name}: identical iteration counts");
    assert!(
        split.total_us < blocking.total_us,
        "{name}: split-phase Poisson ({}) must be strictly below blocking ({})",
        split.total_us,
        blocking.total_us
    );
    let case = OverlapCase {
        name: name.to_string(),
        blocking_us: blocking.total_us,
        split_us: split.total_us,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    };
    report_overlap(&case);
    case
}

// ---- chaos sweep (PR 7: fault injection + self-healing sessions) ----------

/// Master seed for every chaos scenario — fixed so the sweep is
/// reproducible run to run (the determinism the `fault` tests pin down).
const CHAOS_SEED: u64 = 0xC4A0_5EED;

/// One fault-injection measurement point.
struct ChaosCase {
    scheme: SyncScheme,
    k: usize,
    scenario: &'static str,
    modeled_us: f64,
    /// vtime relative to the same configuration's clean run (1.0 = clean).
    degradation: f64,
    wall_ms: f64,
}

/// One dead-rank recovery measurement: kill + detect + shrink + rebuild +
/// finish on the survivors, driven by `HybridCtx::run_resilient`.
struct DeadCase {
    scheme: SyncScheme,
    k: usize,
    victim: usize,
    /// Max recovery epochs any survivor ran.
    epochs: usize,
    /// Max per-survivor detection vtime charged by the fault plan's
    /// detection-cost model (nonzero is asserted).
    detect_us: f64,
    modeled_us: f64,
    wall_ms: f64,
}

fn chaos_spec(smoke: bool) -> ClusterSpec {
    if smoke {
        // The irregular two-node figure shape: leaders, children and an
        // uneven trailing node in 8 ranks.
        let mut s = ClusterSpec::preset(Preset::VulcanSb, 2);
        s.nodes = vec![5, 3];
        s
    } else {
        ClusterSpec::preset(Preset::VulcanSb, 4)
    }
}

/// `iters` persistent-handle allreduce rounds against fixed modeled
/// compute; returns (makespan, result digest). Faults may stretch the
/// makespan but must never touch the digest.
fn chaos_run(spec: ClusterSpec, scheme: SyncScheme, k: usize, iters: usize, count: usize) -> (f64, Vec<u8>) {
    let rep = SimCluster::new(spec).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            count,
            AllreduceMethod::Method1,
            scheme,
        );
        let vals: Vec<f64> = (0..count / 8).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
        let operand = to_bytes(&vals).to_vec();
        let mut digest = Vec::new();
        for _ in 0..iters {
            env.compute(50.0);
            h.start_allreduce(env, &operand);
            h.wait(env);
            let view = h.result_view(count).expect("hybrid handles are window-backed");
            digest.extend_from_slice(&view[..32.min(count)]);
        }
        env.barrier(&w);
        h.free(env);
        digest
    });
    let mut digests = rep.outputs.into_iter();
    let first = digests.next().expect("at least one rank");
    assert!(digests.all(|d| d == first), "allreduce digest must agree on every rank");
    (rep.max_vtime_us(), first)
}

/// The recovery scenario: the last node's primary leader dies mid-run;
/// survivors run the whole workload through the self-healing retry
/// driver (`HybridCtx::run_resilient`: detect → purge → shrink →
/// rebuild → restart) and finish all `iters` rounds, with the
/// detection cost charged to virtual time. Returns (makespan, worst
/// per-survivor epoch count, worst per-survivor detection vtime).
/// Panics if any survivor fails to complete.
fn chaos_dead_run(
    spec: ClusterSpec,
    scheme: SyncScheme,
    k: usize,
    iters: usize,
    count: usize,
    victim: usize,
) -> (f64, usize, f64) {
    let plan = FaultPlan::seeded(CHAOS_SEED).with_dead(victim, 0.0).with_detect_bound_us(2_000);
    let rep = SimCluster::new(spec.with_faults(plan)).run(move |env| {
        let w = env.world();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let mut h = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            count,
            AllreduceMethod::Method1,
            scheme,
        );
        let vals: Vec<f64> = (0..count / 8).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
        let operand = to_bytes(&vals).to_vec();
        // Persists across epochs: completed rounds are not redone — the
        // driver restarts the attempt, which resumes at `it` (safe for
        // allreduce: no rank can complete a round a survivor is missing
        // from, so survivors stay in lockstep).
        let mut it = 0usize;
        let out = ctx.run_resilient(
            env,
            &mut [&mut h],
            None,
            RetryPolicy::default(),
            |env, _cx, hs| {
                while it < iters {
                    if env.rank_dead() {
                        return Ok(None);
                    }
                    env.compute(50.0);
                    hs[0].start_allreduce(env, &operand);
                    hs[0].try_wait(env)?;
                    it += 1;
                }
                Ok(Some(it))
            },
        );
        match out {
            Resilience::Completed { ctx: fin, epochs, .. } => {
                env.barrier(fin.parent());
                h.free(env);
                let detect: f64 = epochs.iter().map(|e| e.detect_us).sum();
                Some((epochs.len(), detect))
            }
            Resilience::Died => None,
            Resilience::Exhausted { last, .. } => {
                panic!("chaos dead-leader run exhausted its retry budget: {last}")
            }
        }
    });
    let survivors: Vec<(usize, f64)> = rep.outputs.iter().filter_map(|o| *o).collect();
    assert_eq!(
        survivors.len(),
        rep.outputs.len() - 1,
        "every survivor must recover and finish; only the victim retires early"
    );
    let epochs = survivors.iter().map(|&(e, _)| e).max().unwrap_or(0);
    let detect = survivors.iter().map(|&(_, d)| d).fold(0.0, f64::max);
    assert!(detect > 0.0, "recovery must charge nonzero detection vtime");
    (rep.max_vtime_us(), epochs, detect)
}

/// The full chaos sweep: scheme × k × scenario grid plus a dead-rank
/// recovery per configuration, a best-tolerance summary, and its own
/// JSON artifact.
fn run_chaos(smoke: bool, out: &str) {
    let spec = chaos_spec(smoke);
    let (iters, count) = if smoke { (6, 4096) } else { (10, 16 * 1024) };
    let world = spec.world_size();
    let straggler = world / 2;
    let victim = world - spec.nodes.last().copied().expect("spec has nodes");
    let scenarios: &[(&str, Option<fn() -> FaultPlan>)] = &[
        ("clean", None),
        ("skew25", Some(|| FaultPlan::seeded(CHAOS_SEED).with_skew(0.25))),
        ("noise", Some(|| FaultPlan::seeded(CHAOS_SEED).with_noise(200.0, 25.0))),
        ("straggler4x", None), // filled in below (needs the rank)
    ];
    let mut sweep: Vec<ChaosCase> = Vec::new();
    let mut dead: Vec<DeadCase> = Vec::new();
    for &scheme in &[SyncScheme::Barrier, SyncScheme::Spin] {
        for &k in &[1usize, 2] {
            let mut clean_us = 0.0;
            let mut clean_digest = Vec::new();
            for (name, mk) in scenarios {
                let plan = match (*name, mk) {
                    ("straggler4x", _) => {
                        Some(FaultPlan::seeded(CHAOS_SEED).with_straggler(straggler, 4.0))
                    }
                    (_, Some(mk)) => Some(mk()),
                    (_, None) => None,
                };
                let s = match plan {
                    Some(p) => spec.clone().with_faults(p),
                    None => spec.clone(),
                };
                let t0 = Instant::now();
                let (vt, digest) = chaos_run(s, scheme, k, iters, count);
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                if *name == "clean" {
                    clean_us = vt;
                    clean_digest = digest;
                } else {
                    assert_eq!(
                        digest, clean_digest,
                        "chaos {name}: faults must perturb timing, never results"
                    );
                }
                let case = ChaosCase {
                    scheme,
                    k,
                    scenario: name,
                    modeled_us: vt,
                    degradation: vt / clean_us,
                    wall_ms,
                };
                println!(
                    "chaos {:>7?} k{} {:<12} modeled {:>12.2} us | {:>5.3}x clean | wall {:>7.1} ms",
                    case.scheme, case.k, case.scenario, case.modeled_us, case.degradation, case.wall_ms
                );
                sweep.push(case);
            }
            let t0 = Instant::now();
            let (vt, epochs, detect_us) = chaos_dead_run(spec.clone(), scheme, k, iters, count, victim);
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            println!(
                "chaos {scheme:>7?} k{k} dead-leader   modeled {vt:>12.2} us | {epochs} epoch(s), \
                 detect {detect_us:>9.1} us | wall {wall_ms:>7.1} ms"
            );
            dead.push(DeadCase { scheme, k, victim, epochs, detect_us, modeled_us: vt, wall_ms });
        }
    }
    // Which configuration tolerates faults best: lowest worst-case
    // degradation across the non-clean scenarios.
    let best = sweep
        .chunks(scenarios.len())
        .map(|grp| {
            let worst =
                grp.iter().filter(|c| c.scenario != "clean").map(|c| c.degradation).fold(0.0, f64::max);
            (grp[0].scheme, grp[0].k, worst)
        })
        .min_by(|a, b| a.2.total_cmp(&b.2))
        .expect("sweep is non-empty");
    println!(
        "chaos: best fault tolerance: {:?} k={} (worst-case degradation {:.3}x)",
        best.0, best.1, best.2
    );
    write_chaos_json(out, if smoke { "smoke" } else { "full" }, &sweep, &dead, best);
}

fn write_chaos_json(
    path: &str,
    mode: &str,
    sweep: &[ChaosCase],
    dead: &[DeadCase],
    best: (SyncScheme, usize, f64),
) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 7,\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str(&format!("  \"seed\": {CHAOS_SEED},\n"));
    s.push_str("  \"generated_by\": \"cargo run --release --bin bench_all -- --chaos\",\n");
    s.push_str(
        "  \"note\": \"sweep: persistent-handle allreduce rounds under deterministic fault \
         injection (FaultPlan); degradation = modeled vtime over the same configuration's clean \
         run; result digests are asserted bit-identical across scenarios. dead: the last node's \
         primary leader dies mid-run; survivors recover through HybridCtx::run_resilient (detect \
         -> purge -> shrink -> rebuild -> restart) and finish every round (asserted); detect_us \
         is the detection-cost model's vtime charge (asserted nonzero).\",\n",
    );
    s.push_str("  \"sweep\": [\n");
    for (i, c) in sweep.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scheme\": \"{:?}\", \"k\": {}, \"scenario\": \"{}\", \"modeled_us\": {:.3}, \
             \"degradation\": {:.4}, \"wall_ms\": {:.3}}}{}\n",
            c.scheme,
            c.k,
            c.scenario,
            c.modeled_us,
            c.degradation,
            if i + 1 < sweep.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"dead\": [\n");
    for (i, c) in dead.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scheme\": \"{:?}\", \"k\": {}, \"victim\": {}, \"epochs\": {}, \
             \"detect_us\": {:.3}, \"modeled_us\": {:.3}, \"recovered\": true, \
             \"wall_ms\": {:.3}}}{}\n",
            c.scheme,
            c.k,
            c.victim,
            c.epochs,
            c.detect_us,
            c.modeled_us,
            c.wall_ms,
            if i + 1 < dead.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"best\": {{\"scheme\": \"{:?}\", \"k\": {}, \"worst_degradation\": {:.4}}}\n",
        best.0, best.1, best.2
    ));
    s.push_str("}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote {path}");
}

// ---- recovery matrix (PR 8: the self-healing retry driver) ----------------

/// One `--chaos-recovery` scenario: a kernel-shaped drill run to
/// completion through `HybridCtx::run_resilient` under seeded deaths.
struct RecoveryCase {
    scenario: &'static str,
    workload: &'static str,
    world: usize,
    survivors: usize,
    /// Max recovery epochs any survivor ran.
    epochs: usize,
    /// Max across survivors of the per-rank vtime charged by the
    /// detection-cost model, summed over its epochs (nonzero asserted —
    /// the ISSUE-8 acceptance gate).
    detect_us: f64,
    shrink_us: f64,
    rebuild_us: f64,
    modeled_us: f64,
    wall_ms: f64,
    /// Per-epoch breakdown from the survivor that ran the most epochs.
    breakdown: Vec<EpochReport>,
}

/// Validate a drill's outcomes and fold them into a [`RecoveryCase`]:
/// exactly `expected_dead` casualties, checksum agreement across every
/// finishing rank, at least one recovery epoch, nonzero detection vtime.
fn recovery_case(
    scenario: &'static str,
    workload: &'static str,
    world: usize,
    expected_dead: usize,
    modeled_us: f64,
    outs: &[DrillOutcome],
    wall_ms: f64,
) -> RecoveryCase {
    let finished: Vec<&DrillOutcome> = outs.iter().filter(|o| o.finished).collect();
    assert_eq!(
        finished.len(),
        world - expected_dead,
        "{scenario}: every survivor must complete the drill"
    );
    let c0 = finished[0].checksum;
    assert!(
        finished.iter().all(|o| (o.checksum - c0).abs() < 1e-9),
        "{scenario}: survivors must agree bitwise on the drill checksum"
    );
    let per_rank_max = |f: fn(&EpochReport) -> f64| {
        finished.iter().map(|o| o.epochs.iter().map(f).sum::<f64>()).fold(0.0, f64::max)
    };
    let detect_us = per_rank_max(|e| e.detect_us);
    assert!(detect_us > 0.0, "{scenario}: recovery must charge nonzero detection vtime");
    let epochs = finished.iter().map(|o| o.epochs.len()).max().unwrap_or(0);
    assert!(epochs >= 1, "{scenario}: at least one recovery epoch must run");
    let breakdown =
        finished.iter().max_by_key(|o| o.epochs.len()).map(|o| o.epochs.clone()).unwrap_or_default();
    let case = RecoveryCase {
        scenario,
        workload,
        world,
        survivors: finished.len(),
        epochs,
        detect_us,
        shrink_us: per_rank_max(|e| e.shrink_us),
        rebuild_us: per_rank_max(|e| e.rebuild_us),
        modeled_us,
        wall_ms,
        breakdown,
    };
    println!(
        "recovery {:<16} [{}] {}->{} ranks | {} epoch(s) | detect {:>9.1} us, shrink {:>9.1} us, \
         rebuild {:>9.1} us | modeled {:>12.2} us | wall {:>7.1} ms",
        case.scenario,
        case.workload,
        case.world,
        case.survivors,
        case.epochs,
        case.detect_us,
        case.shrink_us,
        case.rebuild_us,
        case.modeled_us,
        case.wall_ms
    );
    case
}

/// The `--chaos-recovery` scenario matrix (ISSUE 8): kernel drills
/// driven end-to-end by `HybridCtx::run_resilient` under seeded
/// deaths — a dead fixed root (re-elected), a shrink-coordinator death
/// mid-agreement, two overlapping deaths, and the Poisson residual
/// loop. Every scenario must complete on the survivors with nonzero
/// charged detection vtime and bitwise-agreeing checksums.
fn run_chaos_recovery(smoke: bool, out: &str) {
    let spec = chaos_spec(smoke);
    let world = spec.world_size();
    let (phases, panel) = if smoke { (6, 4096) } else { (8, 16 * 1024) };
    let victim = world - spec.nodes.last().copied().expect("spec has nodes");
    // Detection charges (DETECT_COST vus per modeled round) dominate the
    // drills' collective + compute vtime, so vtime-scheduled deaths land
    // at chosen driver checkpoints (the tests/fault.rs technique): the
    // trigger victim dies at a phase boundary early in the steady state
    // (every phase charges >= 500 vus of modeled compute, so vclock
    // crosses 1_200 by phase 2), and the shrink coordinator's death time
    // sits above every pre-failure phase clock but below the first
    // post-detection clock — it retires *inside* the recovery path,
    // mid-agreement, and the survivors must restart the round under the
    // next coordinator.
    const DETECT_COST: f64 = 20_000.0;
    const TRIGGER_AT: f64 = 1_200.0;
    let base = || {
        FaultPlan::seeded(CHAOS_SEED).with_detect_bound_us(2_000).with_detect_cost_us(DETECT_COST)
    };
    let mut cases: Vec<RecoveryCase> = Vec::new();

    // 1. A fixed-root broadcast whose root dies mid-steady-state: the
    //    handle's Reelect hook must move the root to a live survivor
    //    (same node preferred) and the drill finishes from there.
    let t0 = Instant::now();
    let (vt, outs) = summa_recovery_drill(
        spec.clone().with_faults(base().with_dead(victim, TRIGGER_AT)),
        phases,
        panel,
        RootPolicy::reelect(victim),
    );
    cases.push(recovery_case(
        "dead-root",
        "summa-panel-bcast",
        world,
        1,
        vt,
        &outs,
        t0.elapsed().as_secs_f64() * 1e3,
    ));

    // 2. The shrink coordinator (rank 0, the lowest survivor) dies
    //    mid-agreement: its death clock lands after the survivors'
    //    first detection charge, so it retires inside the recovery path
    //    and the epoch-tagged round restarts under rank 1.
    let t0 = Instant::now();
    let (vt, outs) = summa_recovery_drill(
        spec.clone()
            .with_faults(base().with_dead(victim, TRIGGER_AT).with_dead(0, DETECT_COST / 2.0)),
        phases,
        panel,
        RootPolicy::PerStart,
    );
    cases.push(recovery_case(
        "mid-shrink-death",
        "summa-panel-bcast",
        world,
        2,
        vt,
        &outs,
        t0.elapsed().as_secs_f64() * 1e3,
    ));

    // 3. Two overlapping deaths in the same window (a remote leader and
    //    a node-0 child): the agreement must converge on the final
    //    survivor set whichever registration order the scheduler picks.
    let t0 = Instant::now();
    let (vt, outs) = summa_recovery_drill(
        spec.clone().with_faults(base().with_dead(victim, TRIGGER_AT).with_dead(1, TRIGGER_AT)),
        phases,
        panel,
        RootPolicy::PerStart,
    );
    cases.push(recovery_case(
        "double-death",
        "summa-panel-bcast",
        world,
        2,
        vt,
        &outs,
        t0.elapsed().as_secs_f64() * 1e3,
    ));

    // 4. The Poisson residual loop (8 B max-allreduce per round) under a
    //    leader death plus 25% skew — the solver-shaped drill.
    let t0 = Instant::now();
    let (vt, outs) = poisson_recovery_drill(
        spec.clone().with_faults(base().with_dead(victim, TRIGGER_AT).with_skew(0.25)),
        2 * phases,
    );
    cases.push(recovery_case(
        "poisson-residual",
        "poisson-allreduce",
        world,
        1,
        vt,
        &outs,
        t0.elapsed().as_secs_f64() * 1e3,
    ));

    write_recovery_json(out, if smoke { "smoke" } else { "full" }, &cases);
}

fn write_recovery_json(path: &str, mode: &str, cases: &[RecoveryCase]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 8,\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str(&format!("  \"seed\": {CHAOS_SEED},\n"));
    s.push_str("  \"generated_by\": \"cargo run --release --bin bench_all -- --chaos-recovery\",\n");
    s.push_str(
        "  \"note\": \"kernel-shaped drills driven by HybridCtx::run_resilient under seeded \
         deaths. Per scenario: survivors is the finishing rank count (asserted = world - deaths), \
         detect/shrink/rebuild_us are the worst per-rank recovery costs in virtual microseconds \
         (detect_us comes from the FaultPlan detection-cost model and is asserted nonzero), and \
         epoch_breakdown is the per-epoch cost split from the survivor that ran the most epochs. \
         Checksums are asserted bitwise-identical across all finishing ranks.\",\n",
    );
    s.push_str("  \"scenarios\": [\n");
    for (i, c) in cases.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"workload\": \"{}\", \"world\": {}, \"survivors\": {}, \
             \"epochs\": {}, \"detect_us\": {:.3}, \"shrink_us\": {:.3}, \"rebuild_us\": {:.3}, \
             \"modeled_us\": {:.3}, \"wall_ms\": {:.3}, \"epoch_breakdown\": [",
            c.scenario,
            c.workload,
            c.world,
            c.survivors,
            c.epochs,
            c.detect_us,
            c.shrink_us,
            c.rebuild_us,
            c.modeled_us,
            c.wall_ms,
        ));
        for (j, e) in c.breakdown.iter().enumerate() {
            s.push_str(&format!(
                "{{\"epoch\": {}, \"failed\": {}, \"detect_us\": {:.3}, \"shrink_us\": {:.3}, \
                 \"rebuild_us\": {:.3}}}{}",
                e.epoch,
                e.failed,
                e.detect_us,
                e.shrink_us,
                e.rebuild_us,
                if j + 1 < c.breakdown.len() { ", " } else { "" }
            ));
        }
        s.push_str(&format!("]}}{}\n", if i + 1 < cases.len() { "," } else { "" }));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote {path}");
}

// ---- tuned sweep (PR 9: the selection subsystem, raced end-to-end) --------

/// One tuned-vs-static figure point: every viable registry candidate is
/// raced through the real simulation (a `PinnedSelector` forces the
/// choice, `drive_report` measures modeled vtime) and the winner is
/// compared against what the static tables would have picked.
struct TunedCase {
    name: String,
    op: &'static str,
    static_algo: String,
    static_us: f64,
    tuned_algo: String,
    tuned_us: f64,
    /// Every candidate's (label, modeled vtime) — the race transcript.
    times: Vec<(String, f64)>,
}

impl TunedCase {
    fn gain(&self) -> f64 {
        if self.static_us > 0.0 {
            1.0 - self.tuned_us / self.static_us
        } else {
            0.0
        }
    }
}

/// Race one figure point: run the static tables, then every viable
/// candidate from the registry, and assert the winner is never slower
/// than static — which holds by construction (the static choice is
/// itself in the candidate set, and identical runs are deterministic).
fn tuned_point(
    name: &str,
    spec: &ClusterSpec,
    op: CollOp,
    bytes: usize,
    flavor: Flavor,
    fast: bool,
) -> TunedCase {
    use hympi::select::{self, registry, PinnedSelector, SelectPoint, Selector, StaticSelector};
    let p = spec.world_size();
    let rpn = spec.nodes.iter().copied().max().unwrap_or(1);
    let pt = SelectPoint::new(p, bytes, rpn);
    let net = spec.net.clone();
    let stat: std::sync::Arc<dyn select::Selector> =
        std::sync::Arc::new(StaticSelector::new(hympi::coll::Tuning::from_env()));

    let prev = select::install(stat.clone());
    let static_us = drive_report(spec.clone(), fast, op, bytes, flavor).mean_us;

    // (label, pinned selector) per viable candidate. The op string names
    // which Selector slot the race exercises.
    let t = hympi::coll::Tuning::from_env();
    let (op_name, pinned): (&'static str, Vec<(String, PinnedSelector)>) = match op {
        CollOp::Bcast => (
            "bcast",
            registry::bcast_candidates(&net, pt, &t)
                .iter()
                .map(|c| {
                    let (n, seg) = registry::bcast_name(c.algo);
                    let label = if seg > 0 { format!("{n}:{seg}") } else { n.to_string() };
                    (label, PinnedSelector::over(stat.clone()).pin_bcast(c.algo))
                })
                .collect(),
        ),
        CollOp::Allgather => (
            "allgather",
            registry::allgather_candidates(&net, pt)
                .iter()
                .map(|c| {
                    let n = registry::allgather_name(c.algo);
                    (n.to_string(), PinnedSelector::over(stat.clone()).pin_allgather(c.algo))
                })
                .collect(),
        ),
        CollOp::Allreduce if matches!(flavor, Flavor::Hybrid { .. }) => (
            "allreduce_method",
            registry::method_candidates(&net, spec.nodes.len(), rpn, bytes)
                .iter()
                .map(|c| {
                    let n = registry::method_name(c.algo);
                    (n.to_string(), PinnedSelector::over(stat.clone()).pin_method(c.algo))
                })
                .collect(),
        ),
        CollOp::Allreduce => (
            "allreduce",
            registry::allreduce_candidates(&net, pt)
                .iter()
                .map(|c| {
                    let n = registry::allreduce_name(c.algo);
                    (n.to_string(), PinnedSelector::over(stat.clone()).pin_allreduce(c.algo))
                })
                .collect(),
        ),
        _ => panic!("tuned sweep covers bcast/allgather/allreduce points"),
    };
    let static_algo = match op_name {
        "bcast" => {
            let (n, seg) = registry::bcast_name(stat.bcast_algo(p, bytes));
            if seg > 0 { format!("{n}:{seg}") } else { n.to_string() }
        }
        "allgather" => registry::allgather_name(stat.allgather_algo(p, bytes)).to_string(),
        "allreduce" => registry::allreduce_name(stat.allreduce_algo(p, bytes)).to_string(),
        _ => registry::method_name(stat.allreduce_method(bytes)).to_string(),
    };

    let mut times = Vec::new();
    for (label, sel) in pinned {
        select::install(std::sync::Arc::new(sel));
        let us = drive_report(spec.clone(), fast, op, bytes, flavor).mean_us;
        times.push((label, us));
    }
    select::install(prev);
    let outcome = select::race(times.clone());
    let (tuned_algo, tuned_us) = (outcome.winner_label().to_string(), outcome.winner_us());
    assert!(
        tuned_us <= static_us + 1e-9,
        "{name}: tuned ({tuned_algo}, {tuned_us:.3} us) must never be slower than static \
         ({static_algo}, {static_us:.3} us)"
    );
    let case = TunedCase {
        name: name.to_string(),
        op: op_name,
        static_algo,
        static_us,
        tuned_algo,
        tuned_us,
        times,
    };
    println!(
        "tuned {:<34} static {:<18} {:>10.2} us | tuned {:<18} {:>10.2} us | {:>5.1}% [{}]",
        case.name,
        case.static_algo,
        case.static_us,
        case.tuned_algo,
        case.tuned_us,
        case.gain() * 100.0,
        if case.tuned_algo == case.static_algo { "TIE" } else { "WIN" },
    );
    case
}

/// The `--tuned` sweep: tuned-vs-static across the figure points, one
/// race per point, with the per-point never-slower assertion (the
/// ISSUE-9 acceptance bound) and its own JSON artifact.
fn run_tuned(smoke: bool, out: &str) {
    let sb = Preset::VulcanSb;
    let hy = Flavor::hybrid(SyncScheme::Spin);
    let mut cases = Vec::new();
    let spec2 = ClusterSpec::preset(sb, 2);
    // CI-sized core grid: each pure op at a latency-bound and a
    // bandwidth-bound size, plus the §5.2.4 hybrid method cutoff probed
    // from both sides. 2 VulcanSb nodes = 32 ranks (power of two, so
    // the RD allgather candidate is in play).
    for (name, op, bytes, fl) in [
        ("fig13_bcast_1KiB", CollOp::Bcast, 1024, Flavor::Pure),
        ("fig13_bcast_64KiB", CollOp::Bcast, 64 * 1024, Flavor::Pure),
        ("fig12_allgather_1KiB", CollOp::Allgather, 1024, Flavor::Pure),
        ("fig12_allgather_64KiB", CollOp::Allgather, 64 * 1024, Flavor::Pure),
        ("fig14_allreduce_4KiB", CollOp::Allreduce, 4 * 1024, Flavor::Pure),
        ("fig14_allreduce_256KiB", CollOp::Allreduce, 256 * 1024, Flavor::Pure),
        ("fig15_method_1KiB_hybrid", CollOp::Allreduce, 1024, hy),
        ("fig15_method_64KiB_hybrid", CollOp::Allreduce, 64 * 1024, hy),
    ] {
        cases.push(tuned_point(name, &spec2, op, bytes, fl, true));
    }
    if !smoke {
        // Engine scale: 512 ranks, plus an irregular (non-pow2) shape
        // where the RD candidate must drop out of the race.
        let spec32 = ClusterSpec::preset(sb, 32);
        cases.push(tuned_point("fig16_allgather_2KiB_512r", &spec32, CollOp::Allgather, 2 * 1024, Flavor::Pure, true));
        cases.push(tuned_point("fig15_allreduce_8KiB_512r", &spec32, CollOp::Allreduce, 8 * 1024, Flavor::Pure, true));
        cases.push(tuned_point("fig13_bcast_512KiB_512r", &spec32, CollOp::Bcast, 512 * 1024, Flavor::Pure, true));
        let irr = ClusterSpec::preset_partial(sb, 96, 12);
        cases.push(tuned_point("fig16_allgather_2KiB_96r_irreg", &irr, CollOp::Allgather, 2 * 1024, Flavor::Pure, true));
    }
    let wins = cases.iter().filter(|c| c.tuned_us < c.static_us - 1e-9).count();
    println!(
        "tuned sweep: {wins}/{} points strictly below static, 0 regressions (asserted per point)",
        cases.len()
    );
    write_tuned_json(out, if smoke { "smoke" } else { "full" }, &cases);
}

fn write_tuned_json(path: &str, mode: &str, cases: &[TunedCase]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 9,\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"generated_by\": \"cargo run --release --bin bench_all -- --tuned\",\n");
    s.push_str(
        "  \"note\": \"tuned-vs-static per figure point: every viable registry candidate is \
         raced through the simulation (PinnedSelector forces the choice, drive_report measures \
         modeled vtime) and the winner is compared against the static Open MPI 4.0.1 tables. \
         tuned_us <= static_us is asserted per point (the static choice is in the candidate \
         set). times is the full race transcript.\",\n",
    );
    s.push_str("  \"points\": [\n");
    for (i, c) in cases.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"op\": \"{}\", \"static_algo\": \"{}\", \
             \"static_us\": {:.3}, \"tuned_algo\": \"{}\", \"tuned_us\": {:.3}, \
             \"gain_frac\": {:.4}, \"times\": [",
            c.name, c.op, c.static_algo, c.static_us, c.tuned_algo, c.tuned_us, c.gain(),
        ));
        for (j, (label, us)) in c.times.iter().enumerate() {
            s.push_str(&format!(
                "{{\"algo\": \"{label}\", \"modeled_us\": {us:.3}}}{}",
                if j + 1 < c.times.len() { ", " } else { "" }
            ));
        }
        s.push_str(&format!("]}}{}\n", if i + 1 < cases.len() { "," } else { "" }));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote {path}");
}

fn write_json(path: &str, mode: &str, cases: &[Case], sweep: &[LeaderCase], overlap: &[OverlapCase]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 5,\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"generated_by\": \"cargo run --release --bin bench_all\",\n");
    s.push_str(
        "  \"note\": \"cases: wall_ms_old re-runs the same workload on the emulated pre-PR3 \
         message fabric (ClusterSpec::legacy_fabric; a conservative baseline — see DESIGN.md §5c, \
         so wall_speedup is a lower bound) in the same process on the same machine; modeled_us is \
         asserted identical on both fabrics and the parity runs assert bit-identical result bytes. \
         '_irreg' cases run the §5.2.2 partially-populated VulcanSb shapes (12 of 16 cores per \
         node). leader_sweep: the same hybrid collective at k leaders per node through the \
         HybridCtx session API (multi-lane NIC model, DESIGN.md §5d). overlap: blocking vs \
         split-phase execution of the same hybrid workload (schedule/progress engine, DESIGN.md \
         §5e) — split_us strictly below blocking_us is asserted for the >=256 KiB SUMMA panels \
         and the Poisson halo overlap; kernel cases at engine scale use the phantom compute \
         backend (modeled charge, no host arithmetic).\",\n",
    );
    s.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"modeled_us\": {:.3}, \"wall_ms_new\": {:.3}, \
             \"wall_ms_old\": {:.3}, \"wall_speedup\": {:.3}}}{}\n",
            c.name,
            c.modeled_us,
            c.wall_new_ms,
            c.wall_old_ms,
            c.speedup(),
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"leader_sweep\": [\n");
    for (i, c) in sweep.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"ranks\": {}, \"leaders\": {}, \"modeled_us\": {:.3}, \
             \"wall_ms\": {:.3}}}{}\n",
            c.name,
            c.ranks,
            c.leaders,
            c.modeled_us,
            c.wall_ms,
            if i + 1 < sweep.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"overlap\": [\n");
    for (i, c) in overlap.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"blocking_us\": {:.3}, \"split_us\": {:.3}, \
             \"hidden_frac\": {:.4}, \"wall_ms\": {:.3}}}{}\n",
            c.name,
            c.blocking_us,
            c.split_us,
            c.gain(),
            c.wall_ms,
            if i + 1 < overlap.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote {path}");
}

/// Apply the `--bcast-small-max` family of threshold flags (the same
/// surface as the microbench CLI): if any is present, install a
/// `StaticSelector` over the overridden tables so every `Auto` dispatch
/// in the run uses them. Flags stack on top of `HYMPI_*` env overrides.
fn apply_tuning_flags(args: &[String]) {
    let opt = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse::<usize>().ok())
    };
    let mut t = hympi::coll::Tuning::from_env();
    let mut any = false;
    let mut set = |name: &str, slot: &mut usize| {
        if let Some(v) = opt(name) {
            *slot = v;
            any = true;
        }
    };
    set("--bcast-small-max", &mut t.bcast_small_max);
    set("--bcast-medium-max", &mut t.bcast_medium_max);
    set("--bcast-seg", &mut t.bcast_seg);
    set("--pipeline-seg", &mut t.pipeline_seg);
    set("--allreduce-small-max", &mut t.allreduce_small_max);
    set("--allgather-small-max", &mut t.allgather_small_max);
    set("--allreduce-method-max", &mut t.allreduce_method_max);
    if any {
        hympi::select::install(std::sync::Arc::new(hympi::select::StaticSelector::new(t)));
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let strict = args.iter().any(|a| a == "--strict");
    let chaos = args.iter().any(|a| a == "--chaos");
    let recovery = args.iter().any(|a| a == "--chaos-recovery");
    let tuned = args.iter().any(|a| a == "--tuned");
    apply_tuning_flags(&args);
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| {
            (if tuned {
                "BENCH_PR9.tuned.json"
            } else if recovery {
                "BENCH_PR8.recovery.json"
            } else if chaos {
                "BENCH_PR7.chaos.json"
            } else {
                "BENCH_PR5.json"
            })
            .to_string()
        });
    if tuned {
        run_tuned(smoke, &out);
        return;
    }
    if recovery {
        run_chaos_recovery(smoke, &out);
        return;
    }
    if chaos {
        run_chaos(smoke, &out);
        return;
    }
    let hy = Flavor::hybrid(SyncScheme::Spin);
    let sb = Preset::VulcanSb;
    let hh = Preset::HazelHen;
    let mut cases = Vec::new();
    let mut sweep = Vec::new();
    let mut overlap = Vec::new();

    // Result-level parity first: cheap, and a parity bug must fail the
    // run before any timing is reported.
    {
        let mut irregular = ClusterSpec::preset(sb, 2);
        irregular.nodes = vec![5, 3];
        fabric_parity("irregular_5+3", irregular);
        fabric_parity("vulcan_2n", ClusterSpec::preset(sb, 2));
    }

    if smoke {
        // CI-sized: exercises the full pipeline (both fabrics, parity
        // asserts, an engine-scale config, the JSON writer) in seconds.
        cases.push(coll_case(
            "fig12_allgather_64KiB_hybrid",
            ClusterSpec::preset(sb, 2),
            CollOp::Allgather,
            64 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig14_allreduce_64KiB_hybrid",
            ClusterSpec::preset(sb, 2),
            CollOp::Allreduce,
            64 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig16_allgather_2KiB_128r_pure",
            ClusterSpec::preset(sb, 8),
            CollOp::Allgather,
            2 * 1024,
            Flavor::Pure,
            true,
        ));
        cases.push(summa_case(true));
        // Leader sweep, CI-sized: 2 nodes, 256 KiB node blocks.
        leader_sweep(
            &mut sweep,
            "fig16_allgather_16KiBpr",
            &ClusterSpec::preset(sb, 2),
            CollOp::Allgather,
            16 * 1024,
            true,
            true,
        );
        // Irregular engine-scale, CI-sized: a §5.2.2 partially-populated
        // shape (12 of 16 cores) at 96 ranks.
        cases.push(coll_case(
            "fig16_allgather_2KiB_96r_irreg",
            ClusterSpec::preset_partial(sb, 96, 12),
            CollOp::Allgather,
            2 * 1024,
            hy,
            true,
        ));
        // Overlap sweep, CI-sized: micro probe at 256 KiB, a 36-rank
        // irregular SUMMA with >=256 KiB panels (phantom compute — the
        // win bound is asserted), and a 16-rank Poisson halo overlap.
        overlap.push(probe_case(
            "overlap_bcast_256KiB_2n",
            ClusterSpec::preset(sb, 2),
            256 * 1024,
            2_000.0,
            true,
        ));
        let mut irregular36 = ClusterSpec::preset(sb, 3);
        irregular36.nodes = vec![16, 16, 4];
        overlap.push(summa_overlap_case(
            "overlap_summa_n1092_36r",
            irregular36,
            1092, // 182x182 panels = 259 KiB
            Backend::Phantom,
            true,
        ));
        overlap.push(poisson_overlap_case(
            "overlap_poisson_n64_16r",
            ClusterSpec::preset(sb, 1),
            64,
            20,
            Backend::Modeled,
        ));
    } else {
        // The PR-2 acceptance pair (256 KiB hybrid, 2 nodes), now timed
        // across fabrics: the ≥1.2x satellite targets.
        cases.push(coll_case(
            "fig12_allgather_256KiB_hybrid",
            ClusterSpec::preset(hh, 2),
            CollOp::Allgather,
            256 * 1024,
            hy,
            false,
        ));
        cases.push(coll_case(
            "fig14_allreduce_256KiB_hybrid",
            ClusterSpec::preset(hh, 2),
            CollOp::Allreduce,
            256 * 1024,
            hy,
            false,
        ));
        // Engine scale (the paper's §5 largest configurations): small
        // payloads, so per-message fabric overhead — not byte motion —
        // dominates wall clock. fig15-style allreduce, fig16-style
        // allgather; pure and hybrid.
        cases.push(coll_case(
            "fig15_allreduce_8KiB_512r_hybrid",
            ClusterSpec::preset(sb, 32),
            CollOp::Allreduce,
            8 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig16_allgather_2KiB_512r_pure",
            ClusterSpec::preset(sb, 32),
            CollOp::Allgather,
            2 * 1024,
            Flavor::Pure,
            true,
        ));
        cases.push(coll_case(
            "fig15_allreduce_8KiB_1024r_pure",
            ClusterSpec::preset(sb, 64),
            CollOp::Allreduce,
            8 * 1024,
            Flavor::Pure,
            true,
        ));
        cases.push(coll_case(
            "fig15_allreduce_8KiB_1024r_hybrid",
            ClusterSpec::preset(sb, 64),
            CollOp::Allreduce,
            8 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig16_allgather_2KiB_1024r_hybrid",
            ClusterSpec::preset(sb, 64),
            CollOp::Allgather,
            2 * 1024,
            hy,
            true,
        ));
        // The §5.2.2 partially-populated VulcanSb shapes mirroring the
        // 512/1024-rank engine-scale cases above: 12 of 16 cores per
        // node, trailing node smaller still.
        cases.push(coll_case(
            "fig16_allgather_2KiB_512r_irreg",
            ClusterSpec::preset_partial(sb, 512, 12),
            CollOp::Allgather,
            2 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig15_allreduce_8KiB_512r_irreg",
            ClusterSpec::preset_partial(sb, 512, 12),
            CollOp::Allreduce,
            8 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig16_allgather_2KiB_1024r_irreg",
            ClusterSpec::preset_partial(sb, 1024, 12),
            CollOp::Allgather,
            2 * 1024,
            hy,
            true,
        ));
        cases.push(summa_case(false));
        // Leader sweep at engine scale (the ISSUE-4 satellite): 512 and
        // 1024 ranks, k ∈ {1, 2, 4}. The 16 KiB/rank allgather makes
        // 256 KiB node blocks — the regime where the multi-lane NIC
        // model pays; the fig15-style 8 KiB allreduce shows the
        // small-message end (latency-bound, little k gain expected).
        leader_sweep(
            &mut sweep,
            "fig16_allgather_16KiBpr",
            &ClusterSpec::preset(sb, 32),
            CollOp::Allgather,
            16 * 1024,
            true,
            true,
        );
        leader_sweep(
            &mut sweep,
            "fig16_allgather_4KiBpr",
            &ClusterSpec::preset(sb, 64),
            CollOp::Allgather,
            4 * 1024,
            false, // 64 KiB node blocks: partially latency-bound, no strict bound
            true,
        );
        leader_sweep(
            &mut sweep,
            "fig15_allreduce_8KiB",
            &ClusterSpec::preset(sb, 32),
            CollOp::Allreduce,
            8 * 1024,
            false,
            true,
        );
        // Overlap sweep at engine scale (the PR-5 acceptance bound): the
        // ~512-rank SUMMA shape is 484 = 22² ranks block-filled onto
        // 16-core nodes (30 full + one 4-rank node — irregular), with
        // 182×182 f64 panels (259 KiB ≥ 256 KiB); split-phase must be
        // strictly below blocking. Poisson runs the §5.2.2
        // partially-populated 512-rank shape. Both use phantom compute
        // (modeled charge, no host arithmetic) at this scale.
        overlap.push(probe_case(
            "overlap_bcast_256KiB_8n",
            ClusterSpec::preset(sb, 8),
            256 * 1024,
            2_000.0,
            true,
        ));
        overlap.push(summa_overlap_case(
            "overlap_summa_n4004_484r",
            ClusterSpec::preset_total_ranks(sb, 484),
            4004, // 22×22 grid, 182×182 panels = 259 KiB
            Backend::Phantom,
            true,
        ));
        overlap.push(poisson_overlap_case(
            "overlap_poisson_n2048_512r_irreg",
            ClusterSpec::preset_partial(sb, 512, 12),
            2048,
            20,
            Backend::Phantom,
        ));
    }
    write_json(&out, if smoke { "smoke" } else { "full" }, &cases, &sweep, &overlap);
    if !smoke {
        // The PR-3 acceptance headline: the lock-free fabric must beat
        // the old fabric ≥ 2x wall-clock on at least one 1024-rank case
        // and ≥ 1.2x on the 256 KiB hybrid pair. Numbers land in the
        // JSON either way; `--strict` turns a miss into a failing exit
        // for regression gating.
        let best_1024 = cases
            .iter()
            .filter(|c| c.name.contains("1024r"))
            .map(Case::speedup)
            .fold(0.0, f64::max);
        let mut below_target = best_1024 < 2.0;
        println!(
            "headline 1024-rank: best {best_1024:.2}x wall-clock vs old fabric [{}]",
            if best_1024 >= 2.0 { "PASS" } else { "BELOW TARGET" }
        );
        for name in ["fig12_allgather_256KiB_hybrid", "fig14_allreduce_256KiB_hybrid"] {
            let c = cases.iter().find(|c| c.name == name).expect("case ran");
            let pass = c.speedup() >= 1.2;
            below_target |= !pass;
            let verdict = if pass { "PASS" } else { "BELOW TARGET" };
            println!("headline {name}: {:.2}x wall-clock vs old fabric [{verdict}]", c.speedup());
        }
        if strict && below_target {
            eprintln!("--strict: headline speedup below target (2.0x @ 1024 ranks, 1.2x @ 256 KiB hybrid)");
            std::process::exit(1);
        }
    }
}
