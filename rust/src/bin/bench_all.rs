//! `bench_all` — the tracked data-plane/fabric/session performance
//! baseline.
//!
//! PR 4 edition: the PR-3 fabric comparison (every case runs twice in one
//! process — lock-free fabric vs the emulated pre-PR3
//! `ClusterSpec::legacy_fabric`) is kept, and a **leader sweep** is
//! added: fig15/fig16-style engine-scale cases (512 and 1024 ranks) run
//! the hybrid collectives at k ∈ {1, 2, 4} leaders per node through the
//! `HybridCtx` session API, recording modeled virtual time (the
//! multi-lane NIC model makes k > 1 genuinely cheaper on large bridge
//! blocks) and wall clock. Everything lands in `BENCH_PR4.json` at the
//! repo root.
//!
//! Modeled virtual time must not depend on the fabric (asserted per
//! case); the parity runs assert bit-identical result bytes and per-rank
//! virtual clocks across fabrics (now including a k = 2 multi-leader
//! collective); and the leader sweep asserts the PR-4 acceptance bound —
//! k = 2 modeled vtime strictly below k = 1 on a ≥256 KiB-node-block
//! allgather.
//!
//! ```text
//! cargo run --release --bin bench_all              # full sweep, writes BENCH_PR4.json
//! cargo run --release --bin bench_all -- --smoke   # CI-sized sweep (same pipeline)
//! cargo run --release --bin bench_all -- --strict  # exit non-zero below the speedup targets
//! cargo run --release --bin bench_all -- --out P   # alternate output path
//! ```

use hympi::coll::{CollOp, Flavor, PlanCache};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::figures::common::drive_report;
use hympi::hybrid::SyncScheme;
use hympi::kernels::summa::{run as summa_run, SummaCfg};
use hympi::kernels::{Backend, Variant};
use hympi::mpi::env::ProcEnv;
use hympi::mpi::{Datatype, ReduceOp};
use hympi::util::to_bytes;
use std::time::Instant;

struct Case {
    name: String,
    modeled_us: f64,
    wall_new_ms: f64,
    wall_old_ms: f64,
}

impl Case {
    fn speedup(&self) -> f64 {
        if self.wall_new_ms > 0.0 {
            self.wall_old_ms / self.wall_new_ms
        } else {
            0.0
        }
    }
}

fn report_case(case: &Case) {
    println!(
        "{:<36} modeled {:>12.2} us | wall new {:>9.1} ms | old fabric {:>9.1} ms | {:>5.2}x",
        case.name,
        case.modeled_us,
        case.wall_new_ms,
        case.wall_old_ms,
        case.speedup()
    );
}

/// One paired (new vs legacy message fabric) collective measurement.
fn coll_case(name: &str, spec: ClusterSpec, op: CollOp, bytes: usize, flavor: Flavor, fast: bool) -> Case {
    let t0 = Instant::now();
    let new = drive_report(spec.clone(), fast, op, bytes, flavor);
    let wall_new_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let old = drive_report(spec.with_legacy_fabric(true), fast, op, bytes, flavor);
    let wall_old_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!(
        (new.mean_us - old.mean_us).abs() < 1e-6,
        "{name}: modeled latency must not depend on the fabric ({} vs {})",
        new.mean_us,
        old.mean_us
    );
    let case = Case { name: name.to_string(), modeled_us: new.mean_us, wall_new_ms, wall_old_ms };
    report_case(&case);
    case
}

/// The fig17 SUMMA kernel (hybrid variant, modeled compute) on both fabrics.
fn summa_case(smoke: bool) -> Case {
    let (n, nodes) = if smoke { (128, 1) } else { (512, 4) };
    let cfg = || SummaCfg { n, variant: Variant::HybridMpiMpi, backend: Backend::Modeled, threads: 16 };
    let t0 = Instant::now();
    let new = summa_run(ClusterSpec::preset(Preset::VulcanSb, nodes), cfg());
    let wall_new_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let old = summa_run(ClusterSpec::preset(Preset::VulcanSb, nodes).with_legacy_fabric(true), cfg());
    let wall_old_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!(
        (new.total_us - old.total_us).abs() < 1e-6,
        "summa: modeled time must not depend on the fabric"
    );
    assert!(
        (new.checksum - old.checksum).abs() < 1e-12,
        "summa: results must not depend on the fabric"
    );
    let case = Case {
        name: format!("fig17_summa_n{n}_hybrid"),
        modeled_us: new.total_us,
        wall_new_ms,
        wall_old_ms,
    };
    report_case(&case);
    case
}

/// Result-level parity workload: pure + hybrid (single- and multi-leader)
/// collectives through cached plans; returns a digest of every result
/// plus the final virtual clock.
fn parity_workload(env: &mut ProcEnv) -> (Vec<u8>, f64) {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let mut cache = PlanCache::new();
    let fl = Flavor::hybrid(SyncScheme::Spin);
    let fl2 = Flavor::hybrid_k(SyncScheme::Spin, 2);
    let mut digest = Vec::new();
    for it in 0..3usize {
        let mine = vec![(me + it) as u8; 1024];
        let mut ag = vec![0u8; 1024 * p];
        cache.allgather(env, &w, Flavor::Pure, &mine, Some(&mut ag));
        digest.extend_from_slice(&ag[..ag.len().min(64)]);
        let mut hy = vec![0u8; 1024 * p];
        cache.allgather(env, &w, fl, &mine, Some(&mut hy));
        assert_eq!(ag, hy, "pure and hybrid allgather must agree");
        let mut hy2 = vec![0u8; 1024 * p];
        cache.allgather(env, &w, fl2, &mine, Some(&mut hy2));
        assert_eq!(ag, hy2, "pure and 2-leader hybrid allgather must agree");

        let vals: Vec<f64> = (0..128).map(|i| ((me + 1) * (i + it + 1)) as f64).collect();
        let mut ar = to_bytes(&vals).to_vec();
        cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut ar);
        digest.extend_from_slice(&ar[..64]);

        let mut bc = vec![it as u8; 2048];
        cache.bcast(env, &w, Flavor::Pure, 0, 2048, Some(&mut bc));
        digest.extend_from_slice(&bc[..64]);
    }
    env.barrier(&w);
    let v = env.vclock();
    cache.free(env);
    (digest, v)
}

/// Assert result bytes bit-identical and per-rank virtual clocks equal
/// across the two fabrics (the acceptance invariant of the PR).
fn fabric_parity(name: &str, spec: ClusterSpec) {
    let new = SimCluster::new(spec.clone()).run(parity_workload);
    let old = SimCluster::new(spec.with_legacy_fabric(true)).run(parity_workload);
    assert_eq!(new.outputs.len(), old.outputs.len());
    for (r, ((da, va), (db, vb))) in new.outputs.iter().zip(old.outputs.iter()).enumerate() {
        assert_eq!(da, db, "{name}: rank {r} result bytes must not depend on the fabric");
        assert!(
            (va - vb).abs() < 1e-9,
            "{name}: rank {r} modeled virtual time must not depend on the fabric ({va} vs {vb})"
        );
    }
    println!("parity {name}: result bytes + modeled vtimes identical on both fabrics");
}

/// One point of the leaders-per-node sweep (session API, new fabric).
struct LeaderCase {
    name: String,
    ranks: usize,
    leaders: usize,
    modeled_us: f64,
    wall_ms: f64,
}

/// Measure one hybrid collective at `leaders` leaders per node.
fn leader_case(
    base: &str,
    spec: ClusterSpec,
    op: CollOp,
    bytes: usize,
    leaders: usize,
    fast: bool,
) -> LeaderCase {
    let ranks = spec.world_size();
    let fl = Flavor::hybrid_k(SyncScheme::Spin, leaders);
    let t0 = Instant::now();
    let rep = drive_report(spec, fast, op, bytes, fl);
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let case = LeaderCase {
        name: format!("{base}_{ranks}r_k{leaders}"),
        ranks,
        leaders,
        modeled_us: rep.mean_us,
        wall_ms,
    };
    println!(
        "{:<36} modeled {:>12.2} us | wall {:>9.1} ms | k={}",
        case.name, case.modeled_us, case.wall_ms, case.leaders
    );
    case
}

/// Sweep k ∈ {1, 2, 4} for one (spec, op, bytes) configuration and
/// assert the PR-4 acceptance bound where it applies (`expect_gain`:
/// large bridge blocks → k = 2 strictly below k = 1).
fn leader_sweep(
    out: &mut Vec<LeaderCase>,
    base: &str,
    spec: &ClusterSpec,
    op: CollOp,
    bytes: usize,
    expect_gain: bool,
    fast: bool,
) {
    let ks = [1usize, 2, 4];
    let start = out.len();
    for &k in &ks {
        out.push(leader_case(base, spec.clone(), op, bytes, k, fast));
    }
    let k1 = out[start].modeled_us;
    let k2 = out[start + 1].modeled_us;
    if expect_gain {
        assert!(
            k2 < k1,
            "{base}: k=2 modeled vtime ({k2}) must be strictly below k=1 ({k1})"
        );
        println!("{base}: k=2 is {:.1}% below k=1 (modeled) [PASS]", (1.0 - k2 / k1) * 100.0);
    }
}

fn write_json(path: &str, mode: &str, cases: &[Case], sweep: &[LeaderCase]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 4,\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"generated_by\": \"cargo run --release --bin bench_all\",\n");
    s.push_str(
        "  \"note\": \"cases: wall_ms_old re-runs the same workload on the emulated pre-PR3 \
         message fabric (ClusterSpec::legacy_fabric; a conservative baseline — see DESIGN.md §5c, \
         so wall_speedup is a lower bound) in the same process on the same machine; modeled_us is \
         asserted identical on both fabrics and the parity runs assert bit-identical result bytes. \
         leader_sweep: the same hybrid collective at k leaders per node through the HybridCtx \
         session API (multi-lane NIC model, DESIGN.md §5d) — modeled_us is the number that moves \
         with k; k=2 is asserted strictly below k=1 on the large-block allgather.\",\n",
    );
    s.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"modeled_us\": {:.3}, \"wall_ms_new\": {:.3}, \
             \"wall_ms_old\": {:.3}, \"wall_speedup\": {:.3}}}{}\n",
            c.name,
            c.modeled_us,
            c.wall_new_ms,
            c.wall_old_ms,
            c.speedup(),
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"leader_sweep\": [\n");
    for (i, c) in sweep.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"ranks\": {}, \"leaders\": {}, \"modeled_us\": {:.3}, \
             \"wall_ms\": {:.3}}}{}\n",
            c.name,
            c.ranks,
            c.leaders,
            c.modeled_us,
            c.wall_ms,
            if i + 1 < sweep.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote {path}");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let strict = args.iter().any(|a| a == "--strict");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_PR4.json".to_string());
    let hy = Flavor::hybrid(SyncScheme::Spin);
    let sb = Preset::VulcanSb;
    let hh = Preset::HazelHen;
    let mut cases = Vec::new();
    let mut sweep = Vec::new();

    // Result-level parity first: cheap, and a parity bug must fail the
    // run before any timing is reported.
    {
        let mut irregular = ClusterSpec::preset(sb, 2);
        irregular.nodes = vec![5, 3];
        fabric_parity("irregular_5+3", irregular);
        fabric_parity("vulcan_2n", ClusterSpec::preset(sb, 2));
    }

    if smoke {
        // CI-sized: exercises the full pipeline (both fabrics, parity
        // asserts, an engine-scale config, the JSON writer) in seconds.
        cases.push(coll_case(
            "fig12_allgather_64KiB_hybrid",
            ClusterSpec::preset(sb, 2),
            CollOp::Allgather,
            64 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig14_allreduce_64KiB_hybrid",
            ClusterSpec::preset(sb, 2),
            CollOp::Allreduce,
            64 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig16_allgather_2KiB_128r_pure",
            ClusterSpec::preset(sb, 8),
            CollOp::Allgather,
            2 * 1024,
            Flavor::Pure,
            true,
        ));
        cases.push(summa_case(true));
        // Leader sweep, CI-sized: 2 nodes, 256 KiB node blocks.
        leader_sweep(
            &mut sweep,
            "fig16_allgather_16KiBpr",
            &ClusterSpec::preset(sb, 2),
            CollOp::Allgather,
            16 * 1024,
            true,
            true,
        );
    } else {
        // The PR-2 acceptance pair (256 KiB hybrid, 2 nodes), now timed
        // across fabrics: the ≥1.2x satellite targets.
        cases.push(coll_case(
            "fig12_allgather_256KiB_hybrid",
            ClusterSpec::preset(hh, 2),
            CollOp::Allgather,
            256 * 1024,
            hy,
            false,
        ));
        cases.push(coll_case(
            "fig14_allreduce_256KiB_hybrid",
            ClusterSpec::preset(hh, 2),
            CollOp::Allreduce,
            256 * 1024,
            hy,
            false,
        ));
        // Engine scale (the paper's §5 largest configurations): small
        // payloads, so per-message fabric overhead — not byte motion —
        // dominates wall clock. fig15-style allreduce, fig16-style
        // allgather; pure and hybrid.
        cases.push(coll_case(
            "fig15_allreduce_8KiB_512r_hybrid",
            ClusterSpec::preset(sb, 32),
            CollOp::Allreduce,
            8 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig16_allgather_2KiB_512r_pure",
            ClusterSpec::preset(sb, 32),
            CollOp::Allgather,
            2 * 1024,
            Flavor::Pure,
            true,
        ));
        cases.push(coll_case(
            "fig15_allreduce_8KiB_1024r_pure",
            ClusterSpec::preset(sb, 64),
            CollOp::Allreduce,
            8 * 1024,
            Flavor::Pure,
            true,
        ));
        cases.push(coll_case(
            "fig15_allreduce_8KiB_1024r_hybrid",
            ClusterSpec::preset(sb, 64),
            CollOp::Allreduce,
            8 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig16_allgather_2KiB_1024r_hybrid",
            ClusterSpec::preset(sb, 64),
            CollOp::Allgather,
            2 * 1024,
            hy,
            true,
        ));
        cases.push(summa_case(false));
        // Leader sweep at engine scale (the ISSUE-4 satellite): 512 and
        // 1024 ranks, k ∈ {1, 2, 4}. The 16 KiB/rank allgather makes
        // 256 KiB node blocks — the regime where the multi-lane NIC
        // model pays; the fig15-style 8 KiB allreduce shows the
        // small-message end (latency-bound, little k gain expected).
        leader_sweep(
            &mut sweep,
            "fig16_allgather_16KiBpr",
            &ClusterSpec::preset(sb, 32),
            CollOp::Allgather,
            16 * 1024,
            true,
            true,
        );
        leader_sweep(
            &mut sweep,
            "fig16_allgather_4KiBpr",
            &ClusterSpec::preset(sb, 64),
            CollOp::Allgather,
            4 * 1024,
            false, // 64 KiB node blocks: partially latency-bound, no strict bound
            true,
        );
        leader_sweep(
            &mut sweep,
            "fig15_allreduce_8KiB",
            &ClusterSpec::preset(sb, 32),
            CollOp::Allreduce,
            8 * 1024,
            false,
            true,
        );
    }
    write_json(&out, if smoke { "smoke" } else { "full" }, &cases, &sweep);
    if !smoke {
        // The PR-3 acceptance headline: the lock-free fabric must beat
        // the old fabric ≥ 2x wall-clock on at least one 1024-rank case
        // and ≥ 1.2x on the 256 KiB hybrid pair. Numbers land in the
        // JSON either way; `--strict` turns a miss into a failing exit
        // for regression gating.
        let best_1024 = cases
            .iter()
            .filter(|c| c.name.contains("1024r"))
            .map(Case::speedup)
            .fold(0.0, f64::max);
        let mut below_target = best_1024 < 2.0;
        println!(
            "headline 1024-rank: best {best_1024:.2}x wall-clock vs old fabric [{}]",
            if best_1024 >= 2.0 { "PASS" } else { "BELOW TARGET" }
        );
        for name in ["fig12_allgather_256KiB_hybrid", "fig14_allreduce_256KiB_hybrid"] {
            let c = cases.iter().find(|c| c.name == name).expect("case ran");
            let pass = c.speedup() >= 1.2;
            below_target |= !pass;
            let verdict = if pass { "PASS" } else { "BELOW TARGET" };
            println!("headline {name}: {:.2}x wall-clock vs old fabric [{verdict}]", c.speedup());
        }
        if strict && below_target {
            eprintln!("--strict: headline speedup below target (2.0x @ 1024 ranks, 1.2x @ 256 KiB hybrid)");
            std::process::exit(1);
        }
    }
}
