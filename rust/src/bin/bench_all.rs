//! `bench_all` — the tracked data-plane performance baseline.
//!
//! Runs reduced sweeps of the fig12 (allgather), fig13 (bcast), fig14
//! (allreduce) and fig17 (SUMMA) drivers twice in one process — once on
//! the pooled zero-copy data plane and once on the emulated legacy
//! allocating plane (`ClusterSpec::legacy_dataplane`) — and writes the
//! wall-clock + modeled numbers to `BENCH_PR2.json` at the repo root, so
//! subsequent PRs have a measured trajectory to beat. Modeled virtual
//! time must be identical between the two planes (asserted per case);
//! only wall-clock may differ.
//!
//! ```text
//! cargo run --release --bin bench_all              # full sweep, writes BENCH_PR2.json
//! cargo run --release --bin bench_all -- --smoke   # CI-sized sweep (same pipeline)
//! cargo run --release --bin bench_all -- --strict  # exit non-zero below the 1.5x target
//! cargo run --release --bin bench_all -- --out P   # alternate output path
//! ```

use hympi::coll::{CollOp, Flavor};
use hympi::coordinator::{ClusterSpec, Preset};
use hympi::figures::common::drive_report;
use hympi::hybrid::SyncScheme;
use hympi::kernels::summa::{run as summa_run, SummaCfg};
use hympi::kernels::{Backend, Variant};
use std::time::Instant;

struct Case {
    name: String,
    modeled_us: f64,
    wall_new_ms: f64,
    wall_legacy_ms: f64,
}

impl Case {
    fn speedup(&self) -> f64 {
        if self.wall_new_ms > 0.0 {
            self.wall_legacy_ms / self.wall_new_ms
        } else {
            0.0
        }
    }
}

fn report_case(case: &Case) {
    println!(
        "{:<34} modeled {:>12.2} us | wall new {:>9.1} ms | legacy {:>9.1} ms | {:>5.2}x",
        case.name,
        case.modeled_us,
        case.wall_new_ms,
        case.wall_legacy_ms,
        case.speedup()
    );
}

/// One paired (new vs legacy data plane) collective measurement.
fn coll_case(
    name: &str,
    preset: Preset,
    nodes: usize,
    op: CollOp,
    bytes: usize,
    flavor: Flavor,
    fast: bool,
) -> Case {
    let t0 = Instant::now();
    let new = drive_report(ClusterSpec::preset(preset, nodes), fast, op, bytes, flavor);
    let wall_new_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let legacy = drive_report(
        ClusterSpec::preset(preset, nodes).with_legacy_dataplane(true),
        fast,
        op,
        bytes,
        flavor,
    );
    let wall_legacy_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!(
        (new.mean_us - legacy.mean_us).abs() < 1e-6,
        "{name}: modeled latency must not depend on the data plane ({} vs {})",
        new.mean_us,
        legacy.mean_us
    );
    let case =
        Case { name: name.to_string(), modeled_us: new.mean_us, wall_new_ms, wall_legacy_ms };
    report_case(&case);
    case
}

/// The fig17 SUMMA kernel (hybrid variant, modeled compute) on both planes.
fn summa_case(smoke: bool) -> Case {
    let (n, nodes) = if smoke { (128, 1) } else { (512, 4) };
    let cfg = || SummaCfg { n, variant: Variant::HybridMpiMpi, backend: Backend::Modeled, threads: 16 };
    let t0 = Instant::now();
    let new = summa_run(ClusterSpec::preset(Preset::VulcanSb, nodes), cfg());
    let wall_new_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let legacy =
        summa_run(ClusterSpec::preset(Preset::VulcanSb, nodes).with_legacy_dataplane(true), cfg());
    let wall_legacy_ms = t1.elapsed().as_secs_f64() * 1e3;
    assert!(
        (new.total_us - legacy.total_us).abs() < 1e-6,
        "summa: modeled time must not depend on the data plane"
    );
    assert!(
        (new.checksum - legacy.checksum).abs() < 1e-12,
        "summa: results must not depend on the data plane"
    );
    let case = Case {
        name: format!("fig17_summa_n{n}_hybrid"),
        modeled_us: new.total_us,
        wall_new_ms,
        wall_legacy_ms,
    };
    report_case(&case);
    case
}

fn write_json(path: &str, mode: &str, cases: &[Case]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 2,\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"generated_by\": \"cargo run --release --bin bench_all\",\n");
    s.push_str(
        "  \"note\": \"wall_ms_legacy re-runs the same workload on the emulated pre-PR2 \
         allocating data plane (ClusterSpec::legacy_dataplane) in the same process on the same \
         machine; modeled_us is asserted identical on both planes.\",\n",
    );
    s.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"modeled_us\": {:.3}, \"wall_ms_new\": {:.3}, \
             \"wall_ms_legacy\": {:.3}, \"wall_speedup\": {:.3}}}{}\n",
            c.name,
            c.modeled_us,
            c.wall_new_ms,
            c.wall_legacy_ms,
            c.speedup(),
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote {path}");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let strict = args.iter().any(|a| a == "--strict");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_PR2.json".to_string());
    let hy = Flavor::hybrid(SyncScheme::Spin);
    let mut cases = Vec::new();
    if smoke {
        // CI-sized: exercises the full pipeline (both planes, parity
        // asserts, JSON writer) in seconds.
        cases.push(coll_case(
            "fig12_allgather_64KiB_hybrid",
            Preset::VulcanSb,
            2,
            CollOp::Allgather,
            64 * 1024,
            hy,
            true,
        ));
        cases.push(coll_case(
            "fig14_allreduce_64KiB_hybrid",
            Preset::VulcanSb,
            2,
            CollOp::Allreduce,
            64 * 1024,
            hy,
            true,
        ));
        cases.push(summa_case(true));
    } else {
        let hh = Preset::HazelHen;
        cases.push(coll_case("fig12_allgather_800B_hybrid", hh, 2, CollOp::Allgather, 800, hy, false));
        cases.push(coll_case(
            "fig12_allgather_256KiB_hybrid",
            hh,
            2,
            CollOp::Allgather,
            256 * 1024,
            hy,
            false,
        ));
        cases.push(coll_case(
            "fig12_allgather_256KiB_pure",
            hh,
            2,
            CollOp::Allgather,
            256 * 1024,
            Flavor::Pure,
            false,
        ));
        cases.push(coll_case(
            "fig13_bcast_512KiB_hybrid",
            hh,
            2,
            CollOp::Bcast,
            512 * 1024,
            hy,
            false,
        ));
        cases.push(coll_case("fig14_allreduce_800B_hybrid", hh, 2, CollOp::Allreduce, 800, hy, false));
        cases.push(coll_case(
            "fig14_allreduce_256KiB_hybrid",
            hh,
            2,
            CollOp::Allreduce,
            256 * 1024,
            hy,
            false,
        ));
        cases.push(coll_case(
            "fig14_allreduce_256KiB_pure",
            hh,
            2,
            CollOp::Allreduce,
            256 * 1024,
            Flavor::Pure,
            false,
        ));
        cases.push(summa_case(false));
    }
    write_json(&out, if smoke { "smoke" } else { "full" }, &cases);
    if !smoke {
        // The PR-2 acceptance headline: the pooled plane must beat the
        // allocating plane by ≥ 1.5× wall-clock on the large-message
        // hybrid paths. Numbers land in the JSON either way; `--strict`
        // turns a miss into a failing exit for regression gating.
        let mut below_target = false;
        for name in ["fig12_allgather_256KiB_hybrid", "fig14_allreduce_256KiB_hybrid"] {
            let c = cases.iter().find(|c| c.name == name).expect("case ran");
            let pass = c.speedup() >= 1.5;
            below_target |= !pass;
            let verdict = if pass { "PASS" } else { "BELOW TARGET" };
            println!("headline {name}: {:.2}x wall-clock vs legacy [{verdict}]", c.speedup());
        }
        if strict && below_target {
            eprintln!("--strict: headline speedup below the 1.5x target");
            std::process::exit(1);
        }
    }
}
