//! `tune_all` — sweep the selection registry and persist the winners
//! into the versioned tuning table (the UCC persisted-tuning shape).
//!
//! Two sources feed the table, in priority order (lookup is first match
//! wins, so raced entries outrank modeled ones):
//!
//! - **race** entries — `PlanCache::plan_raced` times every viable
//!   candidate on a persistent handle inside the real simulation
//!   (bit-identity across candidates and cross-rank winner agreement
//!   are asserted in-engine) at a few representative figure points;
//! - **model** entries — the closed-form α-β cost registry arg-min'd
//!   over a (p, bytes) grid, adjacent byte points with the same winner
//!   merged into range entries.
//!
//! ```text
//! cargo run --release --bin tune_all                  # full sweep, writes TUNING.json
//! cargo run --release --bin tune_all -- --smoke       # CI-sized sweep, writes TUNING.smoke.json
//! cargo run --release --bin tune_all -- --out PATH    # alternate output path
//! cargo run --release --bin tune_all -- --check PATH  # validate a committed table; exit 1 on drift
//! ```
//!
//! `--check` is the CI drift gate: the committed `TUNING.json` must
//! load under the current `TABLE_VERSION` and every entry must name an
//! op and algorithm the registry can parse.

use hympi::coll::{CollOp, PlanCache, Tuning};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::mpi::net::NetModel;
use hympi::mpi::{Datatype, ReduceOp};
use hympi::select::table::Entry;
use hympi::select::{registry, SelectPoint, TuningTable, TABLE_VERSION};
use std::path::Path;

/// Validate a committed table against the current schema and registry.
fn run_check(path: &str) -> i32 {
    match TuningTable::load(Path::new(path)) {
        Err(e) => {
            eprintln!("tune_all --check {path}: {e}");
            1
        }
        Ok(t) => match t.validate() {
            Ok(()) => {
                println!(
                    "{path}: ok — version {TABLE_VERSION}, {} entries, model \"{}\"",
                    t.entries.len(),
                    t.model
                );
                0
            }
            Err(errs) => {
                for e in &errs {
                    eprintln!("tune_all --check {path}: {e}");
                }
                1
            }
        },
    }
}

fn byte_grid(smoke: bool) -> Vec<usize> {
    if smoke {
        vec![256, 4 * 1024, 64 * 1024, 1 << 20]
    } else {
        (6..=22).map(|i| 1usize << i).collect() // 64 B .. 4 MiB
    }
}

fn p_grid(smoke: bool) -> Vec<usize> {
    if smoke {
        vec![8, 32]
    } else {
        vec![4, 8, 16, 24, 32, 64, 128, 256, 512, 1024]
    }
}

/// Arg-min the cost registry over the (p, bytes) grid; adjacent byte
/// points with the same winner merge into one range entry per p.
fn model_entries(net: &NetModel, smoke: bool) -> Vec<Entry> {
    let t = Tuning::from_env();
    let rpn = 16; // VulcanSb node population — the committed table's topology
    let mut out = Vec::new();
    for p in p_grid(smoke) {
        let mut push_runs = |op: &str, picks: &[(usize, String, usize)]| {
            let mut i = 0;
            while i < picks.len() {
                let j0 = i;
                while i + 1 < picks.len()
                    && picks[i + 1].1 == picks[j0].1
                    && picks[i + 1].2 == picks[j0].2
                {
                    i += 1;
                }
                out.push(Entry {
                    op: op.to_string(),
                    p_min: p,
                    p_max: p,
                    bytes_min: picks[j0].0,
                    bytes_max: picks[i].0,
                    algo: picks[j0].1.clone(),
                    seg: picks[j0].2,
                    source: "model".to_string(),
                });
                i += 1;
            }
        };
        let grid = byte_grid(smoke);
        let bcast: Vec<_> = grid
            .iter()
            .map(|&b| {
                let best = registry::best(&registry::bcast_candidates(
                    net,
                    SelectPoint::new(p, b, rpn),
                    &t,
                ));
                let (name, seg) = registry::bcast_name(best.algo);
                (b, name.to_string(), seg)
            })
            .collect();
        push_runs("bcast", &bcast);
        let ag: Vec<_> = grid
            .iter()
            .map(|&b| {
                let best =
                    registry::best(&registry::allgather_candidates(net, SelectPoint::new(p, b, rpn)));
                (b, registry::allgather_name(best.algo).to_string(), 0)
            })
            .collect();
        push_runs("allgather", &ag);
        let ar: Vec<_> = grid
            .iter()
            .map(|&b| {
                let best =
                    registry::best(&registry::allreduce_candidates(net, SelectPoint::new(p, b, rpn)));
                (b, registry::allreduce_name(best.algo).to_string(), 0)
            })
            .collect();
        push_runs("allreduce", &ar);
        if p > rpn {
            // §5.2.4 step-1 method: only meaningful when a bridge exists.
            let nnodes = p.div_ceil(rpn);
            let meth: Vec<_> = grid
                .iter()
                .map(|&b| {
                    let best = registry::best(&registry::method_candidates(net, nnodes, rpn, b));
                    (b, registry::method_name(best.algo).to_string(), 0)
                })
                .collect();
            push_runs("allreduce_method", &meth);
        }
    }
    out
}

fn spec_of(nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Empirically race candidates on persistent handles inside the
/// simulation at representative figure points. `plan_raced` asserts
/// bit-identical results across candidates and folds per-candidate
/// times with a Max-allreduce, so the recorded winner is the same on
/// every rank (a divergence would deadlock the simulation).
fn race_entries(smoke: bool) -> Vec<Entry> {
    let shapes: Vec<Vec<usize>> = if smoke {
        vec![vec![5, 3]]
    } else {
        vec![vec![5, 3], vec![16, 16]]
    };
    let points: Vec<(CollOp, usize, Datatype, Option<ReduceOp>)> = if smoke {
        vec![
            (CollOp::Allgather, 64, Datatype::U8, None),
            (CollOp::Allreduce, 64, Datatype::F64, Some(ReduceOp::Sum)),
        ]
    } else {
        vec![
            (CollOp::Allgather, 1024, Datatype::U8, None),
            (CollOp::Allgather, 64 * 1024, Datatype::U8, None),
            (CollOp::Bcast, 4 * 1024, Datatype::U8, None),
            (CollOp::Bcast, 512 * 1024, Datatype::U8, None),
            (CollOp::Allreduce, 4 * 1024, Datatype::F64, Some(ReduceOp::Sum)),
            (CollOp::Allreduce, 256 * 1024, Datatype::F64, Some(ReduceOp::Sum)),
        ]
    };
    let iters = if smoke { 2 } else { 3 };
    let mut entries = Vec::new();
    for nodes in &shapes {
        let p: usize = nodes.iter().sum();
        for &(op, count, dt, rop) in &points {
            let report = SimCluster::new(spec_of(nodes)).run(move |env| {
                let w = env.world();
                let mut cache = PlanCache::new();
                let (_, race) = cache.plan_raced(env, &w, op, count, dt, rop, iters);
                (race.winner, race.seg, race.times.len())
            });
            let (winner, seg, ncand) = report.outputs.into_iter().next().expect("rank 0 output");
            let op_name = match op {
                CollOp::Allgather => "allgather",
                CollOp::Bcast => "bcast",
                CollOp::Allreduce => "allreduce",
                _ => unreachable!("race points cover allgather/bcast/allreduce"),
            };
            let algo = winner.split(':').next().expect("non-empty label").to_string();
            println!("race {op_name:<10} p={p:<5} {count:>8} B -> {winner} ({ncand} candidates)");
            entries.push(Entry {
                op: op_name.to_string(),
                p_min: p,
                p_max: p,
                bytes_min: count,
                bytes_max: count,
                algo,
                seg,
                source: "race".to_string(),
            });
        }
    }
    entries
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opt = |name: &str| {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
    };
    if let Some(path) = opt("--check") {
        std::process::exit(run_check(&path));
    }
    let smoke = args.iter().any(|a| a == "--smoke");
    let out = opt("--out")
        .unwrap_or_else(|| (if smoke { "TUNING.smoke.json" } else { "TUNING.json" }).to_string());
    let net = NetModel::infiniband();
    let mut table = TuningTable::new(
        net.name,
        "swept by tune_all: raced entries precede modeled ones (lookup is first match wins); \
         points outside the swept grid fall back to the static tables",
    );
    let raced = race_entries(smoke);
    let n_raced = raced.len();
    for e in raced {
        table.entries.push(e);
    }
    for e in model_entries(&net, smoke) {
        table.entries.push(e);
    }
    if let Err(errs) = table.validate() {
        for e in &errs {
            eprintln!("tune_all: generated table invalid: {e}");
        }
        std::process::exit(1);
    }
    table.save(Path::new(&out)).unwrap_or_else(|e| {
        eprintln!("tune_all: {e}");
        std::process::exit(1);
    });
    println!("wrote {out}: {} entries ({n_raced} raced, version {TABLE_VERSION})", table.entries.len());
}
