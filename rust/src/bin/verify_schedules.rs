//! `verify_schedules` — the CI gate of the correctness-analysis subsystem
//! (DESIGN.md §6).
//!
//! Sweeps every committed collective shape — the figure/bench cluster
//! shapes, both §4.5 sync schemes, k ∈ {1, 2, 4} leaders per node, the
//! §5.2.4 allreduce methods (m1, m2, and the tuner-resolved `Tuned` —
//! the process-wide selector is the online autotuner for the whole
//! sweep), fixed and per-start roots, and pipelined bridge depths
//! {1, 2, 4} — compiles the persistent handles, exports
//! each rank's stage schedule ([`HyColl::export_schedule`]) and runs the
//! static verifier ([`verify_handle`] / [`verify_program`]) over the
//! cross-rank dependency graph. Any diagnostic fails the run (exit 1).
//!
//! A final pass drives a small instrumented cluster end-to-end under the
//! happens-before race detector and requires it to come back clean, so
//! the *executed* window accesses — not just the compiled intent — are
//! covered on every CI run.
//!
//! Modes and flags (DESIGN.md §6c):
//!
//! - `--shapes <filter>`: restrict the sweep to shapes whose name
//!   contains `<filter>` (substring match) — the CI shard key. The
//!   fixed-shape runtime-race and post-shrink passes only run on an
//!   unfiltered sweep.
//! - `--jobs <n>`: verify (shape, k) configurations on `n` worker
//!   threads instead of serially.
//! - `--explore [--smoke] [--trace-out <path>]`: run the exhaustive
//!   interleaving model checker instead of the static sweep — real
//!   exported shapes per scheme under [`Reduction::Exhaustive`] with
//!   co-enabled-conflict checking, DPOR cross-checks, fault choice
//!   points (≤ 2 kills), and the shrink-agreement protocol model.
//!   `--smoke` selects the bounded CI budget; violations print a minimal
//!   replayable interleaving trace and are also written to
//!   `--trace-out` for artifact upload. An exhausted budget fails the
//!   run — the gate's claim is exhaustiveness under the stated bounds.
//! - `--replay <seed>`: re-run the canonical instrumented race
//!   configuration twice under `<seed>` and assert the detector
//!   reproduces the identical report set (the replay contract every
//!   `RaceReport` advertises).

use hympi::analysis::dpor::{explore, Budget, ExploreReport, Reduction};
use hympi::analysis::explore::{ScheduleModel, ShrinkModel};
use hympi::analysis::race;
use hympi::analysis::{
    verify_handle, verify_program, verify_survivors, Diagnostic, RaceDetector, RankSchedule,
};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{AllreduceMethod, HybridCtx, LeaderPolicy, RootPolicy, SyncScheme};
use hympi::mpi::{Datatype, FaultPlan, ReduceOp};
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The swept cluster shapes: the irregular figure shapes, a single node,
/// and a regular two-node bench shape.
const SHAPES: &[(&str, Preset, &[usize])] = &[
    ("vulcan-sb 5+3", Preset::VulcanSb, &[5, 3]),
    ("vulcan-hsw 3+4+2", Preset::VulcanHsw, &[3, 4, 2]),
    ("vulcan-sb single-node 6", Preset::VulcanSb, &[6]),
    ("vulcan-sb 8+8", Preset::VulcanSb, &[8, 8]),
];

const LEADER_COUNTS: &[usize] = &[1, 2, 4];
const SCHEMES: &[SyncScheme] = &[SyncScheme::Barrier, SyncScheme::Spin];
const DEPTHS: &[usize] = &[1, 2, 4];

/// The exploration shapes: small enough that full state enumeration of
/// each exported handle fits the smoke budget.
const EXPLORE_NODES: &[usize] = &[2, 1];
const EXPLORE_NODES_K2: &[usize] = &[2, 2];

fn spec(p: Preset, nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(p, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Build every handle flavor on one session and export each rank's
/// schedule. Returned per rank, in handle-creation order (= the program
/// order the ranks would start them in).
fn export_all(nodes: &'static [usize], preset: Preset, k: usize) -> Vec<Vec<(String, RankSchedule)>> {
    let report = SimCluster::new(spec(preset, nodes)).run(move |env| {
        let w = env.world();
        let p = w.size();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let root = p - 1; // a child on the last node
        let mut handles = Vec::new();
        for &scheme in SCHEMES {
            let tag = |name: &str| format!("{name} {scheme:?}");
            handles.push((tag("allgather"), 0, ctx.allgather_init(env, 64, scheme)));
            handles.push((tag("bcast perstart"), root, ctx.bcast_init(env, 96, scheme)));
            for &d in DEPTHS {
                handles.push((
                    tag(&format!("bcast fixed d{d}")),
                    root,
                    ctx.bcast_init_split(env, 96, scheme, RootPolicy::Fixed(root), d),
                ));
                handles.push((
                    tag(&format!("scatter fixed d{d}")),
                    root,
                    ctx.scatter_init_split(env, 48, scheme, RootPolicy::Fixed(root), d),
                ));
            }
            // Root 0 is the primary leader of node 0: the fixed-root
            // compile drops the root-node red sync entirely at k = 1.
            handles.push((
                tag("bcast fixed root0 d2"),
                0,
                ctx.bcast_init_split(env, 96, scheme, RootPolicy::Fixed(0), 2),
            ));
            // "mt" resolves through the installed tuner-backed selector
            // (see main), so the verifier sweeps tuner-chosen plans too.
            for (mname, method) in [
                ("m1", AllreduceMethod::Method1),
                ("m2", AllreduceMethod::Method2),
                ("mt", AllreduceMethod::Tuned),
            ] {
                handles.push((
                    tag(&format!("allreduce {mname}")),
                    0,
                    ctx.allreduce_init(env, Datatype::F64, ReduceOp::Sum, 64, method, scheme),
                ));
                handles.push((
                    tag(&format!("reduce_scatter {mname}")),
                    0,
                    ctx.reduce_scatter_init(env, Datatype::F64, ReduceOp::Sum, 32, method, scheme),
                ));
            }
            handles.push((
                tag("gather fixed"),
                root,
                ctx.gather_init_split(env, 48, scheme, RootPolicy::Fixed(root)),
            ));
            handles.push((tag("gather perstart"), 0, ctx.gather_init(env, 48, scheme)));
            handles.push((tag("scatter perstart"), root, ctx.scatter_init(env, 48, scheme)));
        }
        let exports: Vec<(String, RankSchedule)> =
            handles.iter().map(|(name, root, h)| (name.clone(), h.export_schedule(*root))).collect();
        env.barrier(&w);
        for (_, _, h) in handles.iter_mut() {
            h.free(env);
        }
        exports
    });
    report.outputs
}

/// Group the per-rank exports by handle name (rank order preserved).
fn by_handle(per_rank: &[Vec<(String, RankSchedule)>]) -> Vec<(String, Vec<RankSchedule>)> {
    let mut out: Vec<(String, Vec<RankSchedule>)> = Vec::new();
    for (i, (name, _)) in per_rank[0].iter().enumerate() {
        let set: Vec<RankSchedule> = per_rank.iter().map(|r| r[i].1.clone()).collect();
        out.push((name.clone(), set));
    }
    out
}

fn report(label: &str, diags: &[Diagnostic]) -> usize {
    for d in diags {
        eprintln!("FAIL [{label}]: {d}");
    }
    diags.len()
}

/// Verify one (shape, k) configuration: every handle flavor plus the
/// two-in-flight overlap program. Returns (failures, handles checked).
fn sweep_one(shape_name: &str, preset: Preset, nodes: &'static [usize], k: usize) -> (usize, usize) {
    let mut failures = 0usize;
    let per_rank = export_all(nodes, preset, k);
    let grouped = by_handle(&per_rank);
    for (name, set) in &grouped {
        failures += report(&format!("{shape_name} k{k} {name}"), &verify_handle(set));
    }
    // Two handles in flight at once (the overlap idiom): their
    // concatenated per-rank streams must still be acyclic.
    let a = &grouped[0].1; // allgather
    let b = grouped
        .iter()
        .find(|(n, _)| n.starts_with("allreduce m1"))
        .map(|(_, s)| s)
        .expect("sweep builds an allreduce handle");
    failures += report(
        &format!("{shape_name} k{k} overlap allgather+allreduce"),
        &verify_program(&[a, b]),
    );
    (failures, grouped.len())
}

/// Drive a small instrumented cluster end-to-end: both schemes, two
/// epochs per handle, children reading results in place — the detector
/// must come back clean.
fn runtime_race_pass() -> usize {
    let seed = 0xC0FFEE;
    let nodes: &[usize] = &[3, 2];
    let cluster = SimCluster::new(spec(Preset::VulcanSb, nodes));
    let world: usize = nodes.iter().sum();
    let det = RaceDetector::new(world, seed);
    let det2 = det.clone();
    cluster.run(move |env| {
        let w = env.world();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut handles = Vec::new();
        for &scheme in SCHEMES {
            handles.push(ctx.allgather_init(env, 32, scheme));
            handles.push(ctx.allreduce_init(
                env,
                Datatype::F64,
                ReduceOp::Sum,
                64,
                AllreduceMethod::Method1,
                scheme,
            ));
            handles.push(ctx.bcast_init(env, 48, scheme));
        }
        race::install(&det2, me);
        let operand = vec![me as u8; 64];
        let block = vec![me as u8; 32];
        let payload = vec![7u8; 48];
        for epoch in 0..2 {
            for h in handles.iter_mut() {
                match h.count() {
                    32 => h.start_allgather(env, &block),
                    64 => h.start_allreduce(env, &operand),
                    48 => h.start_bcast(env, 0, (me == 0).then_some(&payload[..])),
                    _ => unreachable!(),
                }
                h.wait(env);
                // In-place result read — the §4 sharing the detector must
                // prove ordered behind the handle's own sync. Final epoch
                // only: the window discipline makes a result view valid
                // until the *next* start, and a start's operand staging
                // precedes its opening sync — reading a stale epoch while
                // a peer re-stages is exactly the hazard the detector
                // exists to flag (tests/verify.rs asserts it fires).
                if epoch == 1 {
                    let view =
                        h.result_view(h.count()).expect("hybrid handles are window-backed");
                    std::hint::black_box(view[0]);
                }
            }
        }
        race::uninstall();
        env.barrier(&w);
        for h in handles.iter_mut() {
            h.free(env);
        }
    });
    let reports = det.reports();
    for r in &reports {
        eprintln!("FAIL [runtime race pass]: {r}");
    }
    reports.len()
}

/// The ISSUE-7 post-shrink gate: kill a non-root leader mid-steady-state,
/// recover with [`HybridCtx::shrink`] + [`HyColl::rebuild`] on every
/// survivor, and verify the rebuilt handles' exported schedules — both
/// the full cross-rank dependency-graph pass and coverage of exactly the
/// survivor set ([`verify_survivors`]).
///
/// ISSUE 8 adds a dead-root handle: the victim is also the pinned root
/// of a `RootPolicy::Reelect` broadcast, so the rebuild must re-elect a
/// live root (lowest survivor on the dead root's node) and the
/// verifier's `DeadRootRetained` check must find every rebuilt rooted
/// schedule naming a live member.
///
/// [`HyColl::rebuild`]: hympi::hybrid::HyColl::rebuild
fn post_shrink_pass() -> usize {
    const VICTIM: usize = 5; // node 1's (k = 1) leader on the 5+3 shape
    let nodes: &[usize] = &[5, 3];
    let plan = FaultPlan::seeded(0x5EED).with_dead(VICTIM, 0.0).with_detect_bound_us(2_000);
    let cluster = SimCluster::new(spec(Preset::VulcanSb, nodes).with_faults(plan));
    let run = cluster.run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ar = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            64,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        let mut bc = ctx.bcast_init_split(env, 96, SyncScheme::Barrier, RootPolicy::Fixed(7), 2);
        let mut rb =
            ctx.bcast_init_split(env, 96, SyncScheme::Barrier, RootPolicy::reelect(VICTIM), 1);
        if env.rank_dead() {
            return None; // the victim stops participating here
        }
        let operand = vec![w.rank() as u8; 64];
        ar.start_allreduce(env, &operand);
        let err = ar.try_wait(env).expect_err("a dead leader must surface, not hang");
        assert_eq!(err.world_rank, VICTIM, "detection must name the victim");
        let ctx = ctx.shrink(env);
        ar.rebuild(env, &ctx);
        bc.rebuild(env, &ctx);
        rb.rebuild(env, &ctx);
        let root = ctx.parent().rank_of_world(7).expect("world rank 7 survives");
        // The victim was the Reelect root: the rebuild must have moved it
        // onto a live survivor of the dead root's node (world rank 6).
        let eroot = rb.root_policy().fixed_root().expect("reelect handles stay fixed-root");
        assert_eq!(
            ctx.parent().world_of(eroot),
            6,
            "re-election must pick the lowest survivor on the dead root's node"
        );
        let exports = vec![
            ("allreduce".to_string(), ar.export_schedule(0)),
            ("bcast fixed".to_string(), bc.export_schedule(root)),
            ("bcast reelected".to_string(), rb.export_schedule(eroot)),
        ];
        // One live invocation each: the rebuilt schedules must also drive.
        ar.start_allreduce(env, &operand);
        ar.try_wait(env).expect("post-shrink allreduce completes on survivors");
        let payload = vec![9u8; 96];
        let me = ctx.parent().rank();
        bc.start_bcast(env, root, (me == root).then_some(&payload[..]));
        bc.try_wait(env).expect("post-shrink bcast completes on survivors");
        rb.start_bcast(env, eroot, (me == eroot).then_some(&payload[..]));
        rb.try_wait(env).expect("post-shrink re-elected bcast completes on survivors");
        env.barrier(ctx.parent());
        ar.free(env);
        bc.free(env);
        rb.free(env);
        Some(exports)
    });
    let sets: Vec<Vec<(String, RankSchedule)>> = run.outputs.into_iter().flatten().collect();
    let survivors: Vec<usize> = (0..7).collect(); // shrunken-comm numbering
    let mut failures = 0usize;
    for i in 0..sets[0].len() {
        let name = &sets[0][i].0;
        let set: Vec<RankSchedule> = sets.iter().map(|s| s[i].1.clone()).collect();
        failures += report(&format!("post-shrink {name}"), &verify_survivors(&set, &survivors));
    }
    failures
}

// ====================================================================
// --replay: deterministic race-report reproduction
// ====================================================================

/// Drive the canonical racy configuration (the stale-epoch in-place read
/// racing a peer's re-staging — the same scenario tests/verify.rs pins)
/// under `seed` and return the canonical report set.
fn racy_run(seed: u64) -> Vec<String> {
    let det = RaceDetector::new(5, seed);
    let det2 = det.clone();
    SimCluster::new(spec(Preset::VulcanSb, &[3, 2])).run(move |env| {
        let w = env.world();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ag = ctx.allgather_init(env, 32, SyncScheme::Spin);
        race::install(&det2, me);
        let block = vec![me as u8; 32];
        ag.start_allgather(env, &block);
        ag.wait(env);
        if me == 1 {
            // Epoch-1 in-place read of rank 0's block while rank 0 —
            // already released by the yellow post — re-stages below.
            std::hint::black_box(ag.result_view(32).unwrap()[0]);
        }
        ag.start_allgather(env, &block); // rank 0 rewrites block 0
        ag.wait(env);
        race::uninstall();
        env.barrier(&w);
        ag.free(env);
    });
    let reports = det.reports();
    for r in &reports {
        if r.seed != seed {
            eprintln!("FAIL [replay]: report does not echo the requested seed: {r}");
        }
    }
    race::canonical_reports(&reports)
}

/// `--replay <seed>`: the same instrumented configuration, run twice,
/// must produce the identical (canonicalized) report set — and a
/// non-empty one, since the configuration is the known-racy scenario.
fn replay_pass(seed: u64) -> usize {
    let first = racy_run(seed);
    let second = racy_run(seed);
    if first.is_empty() {
        eprintln!("FAIL [replay seed {seed:#x}]: the known-racy configuration produced no report");
        return 1;
    }
    if first != second {
        eprintln!(
            "FAIL [replay seed {seed:#x}]: reports did not reproduce\n  first:  {first:?}\n  second: {second:?}"
        );
        return 1;
    }
    println!("replay seed {seed:#x}: {} race report(s) reproduced identically:", first.len());
    for r in &first {
        println!("  {r}");
    }
    0
}

// ====================================================================
// --explore: exhaustive interleaving checking
// ====================================================================

/// The handle flavors explored per scheme (base names without the scheme
/// suffix). The smoke set covers each sync-primitive family: half-barrier
/// episodes + yellow releases (allgather), the pipelined bridge chunk
/// stream (bcast fixed d2), and the nested-collective rendezvous
/// (allreduce m1).
const EXPLORE_OPS_SMOKE: &[&str] = &["allgather", "bcast fixed d2", "allreduce m1"];
const EXPLORE_OPS_FULL: &[&str] = &[
    "allgather",
    "bcast fixed d2",
    "allreduce m1",
    "scatter fixed d1",
    "gather fixed",
    "reduce_scatter m1",
];

/// Account one exploration: violations print (and collect for
/// `--trace-out`) their minimal replayable interleaving; an exhausted
/// budget is a failure because the gate's claim is exhaustiveness.
fn judge<A>(label: &str, r: &ExploreReport<A>, traces: &mut String) -> usize {
    if let Some(cex) = &r.counterexample {
        eprintln!("FAIL [explore {label}]: {cex}");
        traces.push_str(&format!("[{label}]\n{cex}\n"));
        return 1;
    }
    if !r.complete {
        eprintln!(
            "FAIL [explore {label}]: budget exhausted before exhaustive coverage \
             ({} transitions, {} states)",
            r.transitions, r.states
        );
        traces.push_str(&format!("[{label}] budget exhausted\n"));
        return 1;
    }
    println!(
        "explore [{label}]: clean — {} transitions, {} states, {} terminals, {} cache prunes",
        r.transitions, r.states, r.terminals, r.dedup_prunes
    );
    0
}

/// `--explore`: prove deadlock-freedom and yellow-release safety over
/// *every* interleaving of real exported shapes (per scheme), absence of
/// co-enabled conflicting accesses (exhaustive mode, k = 1), liveness
/// under fault choice points, and the shrink-agreement invariants under
/// ≤ 2 overlapping deaths.
fn explore_pass(smoke: bool, trace_out: Option<&Path>) -> usize {
    let budget = if smoke { Budget::smoke() } else { Budget::full() };
    let ops = if smoke { EXPLORE_OPS_SMOKE } else { EXPLORE_OPS_FULL };
    let mut failures = 0usize;
    let mut traces = String::new();

    // Real exported shapes, k = 1, full state enumeration + co-enabled
    // conflict check, with a cached-DPOR cross-check of each model.
    let grouped = by_handle(&export_all(EXPLORE_NODES, Preset::VulcanSb, 1));
    for (name, set) in &grouped {
        let base = name.rsplit_once(' ').map_or(name.as_str(), |(b, _)| b);
        if !ops.contains(&base) {
            continue;
        }
        let m = ScheduleModel::from_handle(set).with_conflict_check();
        failures += judge(
            &format!("[2,1] k1 {name} exhaustive"),
            &explore(&m, Reduction::Exhaustive, &budget),
            &mut traces,
        );
        let m = ScheduleModel::from_handle(set);
        failures += judge(
            &format!("[2,1] k1 {name} dpor"),
            &explore(&m, Reduction::DporCached, &budget),
            &mut traces,
        );
        // Fault choice points: the leader of node 0 or the remote rank
        // may die before any of its remaining micro-ops (≤ 2 kills). A
        // stuck state behind a death is a detected failure (terminal);
        // only a death-free stuck state is a deadlock.
        let m = ScheduleModel::from_handle(set).with_kills(&[0, 2], 2);
        failures += judge(
            &format!("[2,1] k1 {name} faults(≤2)"),
            &explore(&m, Reduction::Exhaustive, &budget),
            &mut traces,
        );
    }

    // Striped leaders (k = 2): cached DPOR keeps the larger rank count
    // tractable; no conflict check here — k ≥ 2 exports over-approximate
    // striped leader accesses to full-range unions (DESIGN.md §6c).
    let grouped = by_handle(&export_all(EXPLORE_NODES_K2, Preset::VulcanSb, 2));
    for (name, set) in &grouped {
        let base = name.rsplit_once(' ').map_or(name.as_str(), |(b, _)| b);
        if base != "allgather" && base != "bcast fixed d2" {
            continue;
        }
        let m = ScheduleModel::from_handle(set);
        failures += judge(
            &format!("[2,2] k2 {name} dpor"),
            &explore(&m, Reduction::DporCached, &budget),
            &mut traces,
        );
    }

    // The shrink-agreement protocol model (exhaustive — its split-brain
    // invariant is a cross-member predicate, outside DPOR's guarantees).
    // 3+2 members, one registered death; then the same with the dead
    // rank being a Reelect-pinned root and ≤2 overlapping deaths drawn
    // from {coordinator, reelection target}.
    let m = ShrinkModel::new(&[0, 1, 2], &[0, 1, 1], &[0]);
    failures +=
        judge("shrink 1+2, coordinator dead", &explore(&m, Reduction::Exhaustive, &budget), &mut traces);
    let m = ShrinkModel::new(&[0, 1, 2, 3, 4], &[0, 0, 0, 1, 1], &[3]).with_root(3);
    failures +=
        judge("shrink 3+2, dead root 3", &explore(&m, Reduction::Exhaustive, &budget), &mut traces);
    let m = ShrinkModel::new(&[0, 1, 2, 3, 4], &[0, 0, 0, 1, 1], &[3])
        .with_root(3)
        .with_kills(&[0, 4], 2);
    failures += judge(
        "shrink 3+2, dead root 3, ≤2 overlapping kills {0,4}",
        &explore(&m, Reduction::Exhaustive, &budget),
        &mut traces,
    );

    if let Some(path) = trace_out {
        if !traces.is_empty() {
            if let Err(e) = std::fs::write(path, &traces) {
                eprintln!("FAIL [explore]: cannot write trace artifact {}: {e}", path.display());
                failures += 1;
            } else {
                eprintln!("explore: violation traces written to {}", path.display());
            }
        }
    }
    failures
}

// ====================================================================
// CLI
// ====================================================================

struct Cli {
    shapes: Option<String>,
    jobs: usize,
    explore: bool,
    smoke: bool,
    replay: Option<u64>,
    trace_out: Option<PathBuf>,
}

const USAGE: &str = "usage: verify_schedules [--shapes <filter>] [--jobs <n>] \
[--explore [--smoke] [--trace-out <path>]] [--replay <seed>]";

fn parse_cli() -> Result<Cli, String> {
    let mut cli = Cli { shapes: None, jobs: 1, explore: false, smoke: false, replay: None, trace_out: None };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut val = |flag: &str| args.next().ok_or_else(|| format!("{flag} needs a value"));
        match a.as_str() {
            "--shapes" => cli.shapes = Some(val("--shapes")?),
            "--jobs" => {
                cli.jobs = val("--jobs")?
                    .parse::<usize>()
                    .map_err(|e| format!("--jobs: {e}"))?
                    .max(1);
            }
            "--explore" => cli.explore = true,
            "--smoke" => cli.smoke = true,
            "--replay" => {
                let v = val("--replay")?;
                let parsed = match v.strip_prefix("0x") {
                    Some(hex) => u64::from_str_radix(hex, 16),
                    None => v.parse::<u64>(),
                };
                cli.replay = Some(parsed.map_err(|e| format!("--replay: {e}"))?);
            }
            "--trace-out" => cli.trace_out = Some(PathBuf::from(val("--trace-out")?)),
            "--help" | "-h" => return Err(USAGE.to_string()),
            other => return Err(format!("unknown flag {other}\n{USAGE}")),
        }
    }
    Ok(cli)
}

fn main() -> ExitCode {
    let cli = match parse_cli() {
        Ok(c) => c,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::FAILURE;
        }
    };
    // Route every Auto/Tuned resolution in the sweep through the online
    // autotuner (cost-model mode, seeded from the committed table when
    // one is present) — the "mt" handles below then carry tuner-chosen
    // methods, and the verifier covers the tuner's choices end to end.
    {
        use hympi::mpi::net::NetModel;
        use hympi::select::{self, table, Autotuner, TuneMode, TuningTable};
        let tuner = Autotuner::new(NetModel::infiniband(), 16, TuneMode::CostModel);
        let tuner = match TuningTable::load(&table::default_path()) {
            Ok(t) => tuner.seed(t),
            Err(_) => tuner,
        };
        select::install(std::sync::Arc::new(tuner));
    }

    // Dedicated modes: exploration and replay run instead of the sweep.
    if let Some(seed) = cli.replay {
        let failures = replay_pass(seed);
        return if failures == 0 { ExitCode::SUCCESS } else { ExitCode::FAILURE };
    }
    if cli.explore {
        let failures = explore_pass(cli.smoke, cli.trace_out.as_deref());
        return if failures == 0 {
            println!("verify_schedules --explore: all explorations exhaustively clean");
            ExitCode::SUCCESS
        } else {
            eprintln!("verify_schedules --explore: {failures} failure(s)");
            ExitCode::FAILURE
        };
    }

    let work: Vec<(&str, Preset, &'static [usize], usize)> = SHAPES
        .iter()
        .filter(|(name, _, _)| cli.shapes.as_deref().map_or(true, |f| name.contains(f)))
        .flat_map(|&(name, preset, nodes)| {
            LEADER_COUNTS.iter().map(move |&k| (name, preset, nodes, k))
        })
        .collect();
    if work.is_empty() {
        eprintln!("verify_schedules: --shapes filter matched no shape");
        return ExitCode::FAILURE;
    }
    let failures = AtomicUsize::new(0);
    let handles_checked = AtomicUsize::new(0);
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..cli.jobs.min(work.len()) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                let Some(&(name, preset, nodes, k)) = work.get(i) else { break };
                let (f, h) = sweep_one(name, preset, nodes, k);
                failures.fetch_add(f, Ordering::SeqCst);
                handles_checked.fetch_add(h, Ordering::SeqCst);
            });
        }
    });
    let mut failures = failures.into_inner();
    let handles_checked = handles_checked.into_inner();
    // The fixed-shape end-to-end passes belong to the full gate only — a
    // sharded (filtered) invocation would run them redundantly per shard.
    let extra = if cli.shapes.is_none() {
        failures += runtime_race_pass();
        failures += post_shrink_pass();
        "; runtime race pass clean; post-shrink pass clean"
    } else {
        ""
    };
    if failures == 0 {
        println!("verify_schedules: {handles_checked} handle configurations verified clean{extra}");
        ExitCode::SUCCESS
    } else {
        eprintln!("verify_schedules: {failures} diagnostic(s)");
        ExitCode::FAILURE
    }
}
