//! `verify_schedules` — the CI gate of the correctness-analysis subsystem
//! (DESIGN.md §6).
//!
//! Sweeps every committed collective shape — the figure/bench cluster
//! shapes, both §4.5 sync schemes, k ∈ {1, 2, 4} leaders per node, the
//! §5.2.4 allreduce methods (m1, m2, and the tuner-resolved `Tuned` —
//! the process-wide selector is the online autotuner for the whole
//! sweep), fixed and per-start roots, and pipelined bridge depths
//! {1, 2, 4} — compiles the persistent handles, exports
//! each rank's stage schedule ([`HyColl::export_schedule`]) and runs the
//! static verifier ([`verify_handle`] / [`verify_program`]) over the
//! cross-rank dependency graph. Any diagnostic fails the run (exit 1).
//!
//! A final pass drives a small instrumented cluster end-to-end under the
//! happens-before race detector and requires it to come back clean, so
//! the *executed* window accesses — not just the compiled intent — are
//! covered on every CI run.

use hympi::analysis::race;
use hympi::analysis::{
    verify_handle, verify_program, verify_survivors, Diagnostic, RaceDetector, RankSchedule,
};
use hympi::coordinator::{ClusterSpec, Preset, SimCluster};
use hympi::hybrid::{AllreduceMethod, HybridCtx, LeaderPolicy, RootPolicy, SyncScheme};
use hympi::mpi::{Datatype, FaultPlan, ReduceOp};
use std::process::ExitCode;

/// The swept cluster shapes: the irregular figure shapes, a single node,
/// and a regular two-node bench shape.
const SHAPES: &[(&str, Preset, &[usize])] = &[
    ("vulcan-sb 5+3", Preset::VulcanSb, &[5, 3]),
    ("vulcan-hsw 3+4+2", Preset::VulcanHsw, &[3, 4, 2]),
    ("vulcan-sb single-node 6", Preset::VulcanSb, &[6]),
    ("vulcan-sb 8+8", Preset::VulcanSb, &[8, 8]),
];

const LEADER_COUNTS: &[usize] = &[1, 2, 4];
const SCHEMES: &[SyncScheme] = &[SyncScheme::Barrier, SyncScheme::Spin];
const DEPTHS: &[usize] = &[1, 2, 4];

fn spec(p: Preset, nodes: &[usize]) -> ClusterSpec {
    let mut s = ClusterSpec::preset(p, nodes.len());
    s.nodes = nodes.to_vec();
    s
}

/// Build every handle flavor on one session and export each rank's
/// schedule. Returned per rank, in handle-creation order (= the program
/// order the ranks would start them in).
fn export_all(nodes: &'static [usize], preset: Preset, k: usize) -> Vec<Vec<(String, RankSchedule)>> {
    let report = SimCluster::new(spec(preset, nodes)).run(move |env| {
        let w = env.world();
        let p = w.size();
        let eff = HybridCtx::effective_leaders(env, &w, k);
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let ctx = HybridCtx::create(env, &w, policy);
        let root = p - 1; // a child on the last node
        let mut handles = Vec::new();
        for &scheme in SCHEMES {
            let tag = |name: &str| format!("{name} {scheme:?}");
            handles.push((tag("allgather"), 0, ctx.allgather_init(env, 64, scheme)));
            handles.push((tag("bcast perstart"), root, ctx.bcast_init(env, 96, scheme)));
            for &d in DEPTHS {
                handles.push((
                    tag(&format!("bcast fixed d{d}")),
                    root,
                    ctx.bcast_init_split(env, 96, scheme, RootPolicy::Fixed(root), d),
                ));
                handles.push((
                    tag(&format!("scatter fixed d{d}")),
                    root,
                    ctx.scatter_init_split(env, 48, scheme, RootPolicy::Fixed(root), d),
                ));
            }
            // Root 0 is the primary leader of node 0: the fixed-root
            // compile drops the root-node red sync entirely at k = 1.
            handles.push((
                tag("bcast fixed root0 d2"),
                0,
                ctx.bcast_init_split(env, 96, scheme, RootPolicy::Fixed(0), 2),
            ));
            // "mt" resolves through the installed tuner-backed selector
            // (see main), so the verifier sweeps tuner-chosen plans too.
            for (mname, method) in [
                ("m1", AllreduceMethod::Method1),
                ("m2", AllreduceMethod::Method2),
                ("mt", AllreduceMethod::Tuned),
            ] {
                handles.push((
                    tag(&format!("allreduce {mname}")),
                    0,
                    ctx.allreduce_init(env, Datatype::F64, ReduceOp::Sum, 64, method, scheme),
                ));
                handles.push((
                    tag(&format!("reduce_scatter {mname}")),
                    0,
                    ctx.reduce_scatter_init(env, Datatype::F64, ReduceOp::Sum, 32, method, scheme),
                ));
            }
            handles.push((
                tag("gather fixed"),
                root,
                ctx.gather_init_split(env, 48, scheme, RootPolicy::Fixed(root)),
            ));
            handles.push((tag("gather perstart"), 0, ctx.gather_init(env, 48, scheme)));
            handles.push((tag("scatter perstart"), root, ctx.scatter_init(env, 48, scheme)));
        }
        let exports: Vec<(String, RankSchedule)> =
            handles.iter().map(|(name, root, h)| (name.clone(), h.export_schedule(*root))).collect();
        env.barrier(&w);
        for (_, _, h) in handles.iter_mut() {
            h.free(env);
        }
        exports
    });
    report.outputs
}

/// Group the per-rank exports by handle name (rank order preserved).
fn by_handle(per_rank: &[Vec<(String, RankSchedule)>]) -> Vec<(String, Vec<RankSchedule>)> {
    let mut out: Vec<(String, Vec<RankSchedule>)> = Vec::new();
    for (i, (name, _)) in per_rank[0].iter().enumerate() {
        let set: Vec<RankSchedule> = per_rank.iter().map(|r| r[i].1.clone()).collect();
        out.push((name.clone(), set));
    }
    out
}

fn report(label: &str, diags: &[Diagnostic]) -> usize {
    for d in diags {
        eprintln!("FAIL [{label}]: {d}");
    }
    diags.len()
}

/// Drive a small instrumented cluster end-to-end: both schemes, two
/// epochs per handle, children reading results in place — the detector
/// must come back clean.
fn runtime_race_pass() -> usize {
    let seed = 0xC0FFEE;
    let nodes: &[usize] = &[3, 2];
    let cluster = SimCluster::new(spec(Preset::VulcanSb, nodes));
    let world: usize = nodes.iter().sum();
    let det = RaceDetector::new(world, seed);
    let det2 = det.clone();
    cluster.run(move |env| {
        let w = env.world();
        let me = w.rank();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut handles = Vec::new();
        for &scheme in SCHEMES {
            handles.push(ctx.allgather_init(env, 32, scheme));
            handles.push(ctx.allreduce_init(
                env,
                Datatype::F64,
                ReduceOp::Sum,
                64,
                AllreduceMethod::Method1,
                scheme,
            ));
            handles.push(ctx.bcast_init(env, 48, scheme));
        }
        race::install(&det2, me);
        let operand = vec![me as u8; 64];
        let block = vec![me as u8; 32];
        let payload = vec![7u8; 48];
        for epoch in 0..2 {
            for h in handles.iter_mut() {
                match h.count() {
                    32 => h.start_allgather(env, &block),
                    64 => h.start_allreduce(env, &operand),
                    48 => h.start_bcast(env, 0, (me == 0).then_some(&payload[..])),
                    _ => unreachable!(),
                }
                h.wait(env);
                // In-place result read — the §4 sharing the detector must
                // prove ordered behind the handle's own sync. Final epoch
                // only: the window discipline makes a result view valid
                // until the *next* start, and a start's operand staging
                // precedes its opening sync — reading a stale epoch while
                // a peer re-stages is exactly the hazard the detector
                // exists to flag (tests/verify.rs asserts it fires).
                if epoch == 1 {
                    let view =
                        h.result_view(h.count()).expect("hybrid handles are window-backed");
                    std::hint::black_box(view[0]);
                }
            }
        }
        race::uninstall();
        env.barrier(&w);
        for h in handles.iter_mut() {
            h.free(env);
        }
    });
    let reports = det.reports();
    for r in &reports {
        eprintln!("FAIL [runtime race pass]: {r}");
    }
    reports.len()
}

/// The ISSUE-7 post-shrink gate: kill a non-root leader mid-steady-state,
/// recover with [`HybridCtx::shrink`] + [`HyColl::rebuild`] on every
/// survivor, and verify the rebuilt handles' exported schedules — both
/// the full cross-rank dependency-graph pass and coverage of exactly the
/// survivor set ([`verify_survivors`]).
///
/// ISSUE 8 adds a dead-root handle: the victim is also the pinned root
/// of a `RootPolicy::Reelect` broadcast, so the rebuild must re-elect a
/// live root (lowest survivor on the dead root's node) and the
/// verifier's `DeadRootRetained` check must find every rebuilt rooted
/// schedule naming a live member.
///
/// [`HyColl::rebuild`]: hympi::hybrid::HyColl::rebuild
fn post_shrink_pass() -> usize {
    const VICTIM: usize = 5; // node 1's (k = 1) leader on the 5+3 shape
    let nodes: &[usize] = &[5, 3];
    let plan = FaultPlan::seeded(0x5EED).with_dead(VICTIM, 0.0).with_detect_bound_us(2_000);
    let cluster = SimCluster::new(spec(Preset::VulcanSb, nodes).with_faults(plan));
    let run = cluster.run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut ar = ctx.allreduce_init(
            env,
            Datatype::F64,
            ReduceOp::Sum,
            64,
            AllreduceMethod::Method1,
            SyncScheme::Barrier,
        );
        let mut bc = ctx.bcast_init_split(env, 96, SyncScheme::Barrier, RootPolicy::Fixed(7), 2);
        let mut rb =
            ctx.bcast_init_split(env, 96, SyncScheme::Barrier, RootPolicy::reelect(VICTIM), 1);
        if env.rank_dead() {
            return None; // the victim stops participating here
        }
        let operand = vec![w.rank() as u8; 64];
        ar.start_allreduce(env, &operand);
        let err = ar.try_wait(env).expect_err("a dead leader must surface, not hang");
        assert_eq!(err.world_rank, VICTIM, "detection must name the victim");
        let ctx = ctx.shrink(env);
        ar.rebuild(env, &ctx);
        bc.rebuild(env, &ctx);
        rb.rebuild(env, &ctx);
        let root = ctx.parent().rank_of_world(7).expect("world rank 7 survives");
        // The victim was the Reelect root: the rebuild must have moved it
        // onto a live survivor of the dead root's node (world rank 6).
        let eroot = rb.root_policy().fixed_root().expect("reelect handles stay fixed-root");
        assert_eq!(
            ctx.parent().world_of(eroot),
            6,
            "re-election must pick the lowest survivor on the dead root's node"
        );
        let exports = vec![
            ("allreduce".to_string(), ar.export_schedule(0)),
            ("bcast fixed".to_string(), bc.export_schedule(root)),
            ("bcast reelected".to_string(), rb.export_schedule(eroot)),
        ];
        // One live invocation each: the rebuilt schedules must also drive.
        ar.start_allreduce(env, &operand);
        ar.try_wait(env).expect("post-shrink allreduce completes on survivors");
        let payload = vec![9u8; 96];
        let me = ctx.parent().rank();
        bc.start_bcast(env, root, (me == root).then_some(&payload[..]));
        bc.try_wait(env).expect("post-shrink bcast completes on survivors");
        rb.start_bcast(env, eroot, (me == eroot).then_some(&payload[..]));
        rb.try_wait(env).expect("post-shrink re-elected bcast completes on survivors");
        env.barrier(ctx.parent());
        ar.free(env);
        bc.free(env);
        rb.free(env);
        Some(exports)
    });
    let sets: Vec<Vec<(String, RankSchedule)>> = run.outputs.into_iter().flatten().collect();
    let survivors: Vec<usize> = (0..7).collect(); // shrunken-comm numbering
    let mut failures = 0usize;
    for i in 0..sets[0].len() {
        let name = &sets[0][i].0;
        let set: Vec<RankSchedule> = sets.iter().map(|s| s[i].1.clone()).collect();
        failures += report(&format!("post-shrink {name}"), &verify_survivors(&set, &survivors));
    }
    failures
}

fn main() -> ExitCode {
    // Route every Auto/Tuned resolution in the sweep through the online
    // autotuner (cost-model mode, seeded from the committed table when
    // one is present) — the "mt" handles below then carry tuner-chosen
    // methods, and the verifier covers the tuner's choices end to end.
    {
        use hympi::mpi::net::NetModel;
        use hympi::select::{self, table, Autotuner, TuneMode, TuningTable};
        let tuner = Autotuner::new(NetModel::infiniband(), 16, TuneMode::CostModel);
        let tuner = match TuningTable::load(&table::default_path()) {
            Ok(t) => tuner.seed(t),
            Err(_) => tuner,
        };
        select::install(std::sync::Arc::new(tuner));
    }
    let mut failures = 0usize;
    let mut handles_checked = 0usize;
    for &(shape_name, preset, nodes) in SHAPES {
        for &k in LEADER_COUNTS {
            let per_rank = export_all(nodes, preset, k);
            let grouped = by_handle(&per_rank);
            for (name, set) in &grouped {
                failures += report(&format!("{shape_name} k{k} {name}"), &verify_handle(set));
                handles_checked += 1;
            }
            // Two handles in flight at once (the overlap idiom): their
            // concatenated per-rank streams must still be acyclic.
            let a = &grouped[0].1; // allgather
            let b = grouped
                .iter()
                .find(|(n, _)| n.starts_with("allreduce m1"))
                .map(|(_, s)| s)
                .expect("sweep builds an allreduce handle");
            failures += report(
                &format!("{shape_name} k{k} overlap allgather+allreduce"),
                &verify_program(&[a, b]),
            );
        }
    }
    failures += runtime_race_pass();
    failures += post_shrink_pass();
    if failures == 0 {
        println!("verify_schedules: {handles_checked} handle configurations verified clean; runtime race pass clean; post-shrink pass clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("verify_schedules: {failures} diagnostic(s)");
        ExitCode::FAILURE
    }
}
