//! Allgather algorithms (`MPI_Allgather` / `MPI_Allgatherv` baselines).
//!
//! - [`AllgatherAlgo::Bruck`] — ⌈log2 p⌉ rounds, any p, best for small
//!   messages (the 800 B regime of Fig. 12);
//! - [`AllgatherAlgo::RecursiveDoubling`] — power-of-two communicators;
//! - [`AllgatherAlgo::Ring`] — p−1 neighbor steps, bandwidth-optimal for
//!   large messages; also the basis of [`allgatherv`], the irregular
//!   variant the hybrid layer runs over node leaders (whose per-node
//!   counts differ on irregularly-populated clusters, §5.2.2 — and whose
//!   latency is governed by the *maximum* per-node contribution, the
//!   penalty the paper cites from Träff's analysis).

use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::Communicator;

/// Allgather algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllgatherAlgo {
    Bruck,
    RecursiveDoubling,
    Ring,
    Auto,
}

/// Gather `mine` from every rank into `out` (rank-major order).
/// `out.len()` must equal `mine.len() * comm.size()`.
pub fn allgather(env: &mut ProcEnv, comm: &Communicator, mine: &[u8], out: &mut [u8], algo: AllgatherAlgo) {
    let p = comm.size();
    let m = mine.len();
    assert_eq!(out.len(), m * p, "allgather output buffer size");
    if p == 1 {
        out.copy_from_slice(mine);
        return;
    }
    let algo = match algo {
        // Auto routes through the installed process-wide selector;
        // sanitize defensively (a stale table entry naming recursive
        // doubling off powers of two degrades to ring, not an abort).
        AllgatherAlgo::Auto => {
            crate::select::sanitize_allgather(crate::select::global().allgather_algo(p, m), p)
        }
        a => a,
    };
    match algo {
        AllgatherAlgo::Bruck => bruck(env, comm, mine, out),
        AllgatherAlgo::RecursiveDoubling => {
            assert!(p.is_power_of_two(), "recursive doubling requires power-of-two ranks");
            recursive_doubling(env, comm, mine, out)
        }
        AllgatherAlgo::Ring => ring(env, comm, mine, out),
        AllgatherAlgo::Auto => unreachable!(),
    }
}

/// Bruck's algorithm: blocks accumulate in me-relative order, rotated back
/// into rank order at the end.
fn bruck(env: &mut ProcEnv, comm: &Communicator, mine: &[u8], out: &mut [u8]) {
    let p = comm.size();
    let m = mine.len();
    let me = comm.rank();
    let tag = env.next_coll_tag(comm, opcode::ALLGATHER);

    // tmp holds blocks in me-relative order: block i = data of rank (me+i)%p.
    let mut tmp = env.take_buf(m * p);
    tmp[..m].copy_from_slice(mine);
    // Round k: distance `have` = 2^k; send the first min(have, p−have)
    // blocks to (me − have), receive the same count from (me + have).
    let mut have = 1usize;
    while have < p {
        let nsend = have.min(p - have);
        let dst = (me + p - have) % p;
        let src = (me + have) % p;
        env.send(comm, dst, tag, &tmp[..nsend * m]);
        let (lo, hi) = (have * m, (have + nsend) * m);
        env.recv_into(comm, Some(src), tag, &mut tmp[lo..hi]);
        have += nsend;
    }
    debug_assert_eq!(have, p);
    // Rotate into rank order: out[(me+i)%p] = tmp[i].
    for i in 0..p {
        let r = (me + i) % p;
        out[r * m..(r + 1) * m].copy_from_slice(&tmp[i * m..(i + 1) * m]);
    }
}

/// Recursive doubling (p = 2^k): round k exchanges the accumulated 2^k-block
/// range with partner `me ^ 2^k`.
fn recursive_doubling(env: &mut ProcEnv, comm: &Communicator, mine: &[u8], out: &mut [u8]) {
    let p = comm.size();
    let m = mine.len();
    let me = comm.rank();
    let tag = env.next_coll_tag(comm, opcode::ALLGATHER);
    out[me * m..(me + 1) * m].copy_from_slice(mine);
    let mut k = 1usize;
    while k < p {
        let partner = me ^ k;
        let my_start = (me / k) * k; // my k-aligned accumulated range
        let their_start = (partner / k) * k;
        env.send(comm, partner, tag, &out[my_start * m..(my_start + k) * m]);
        env.recv_into(comm, Some(partner), tag, &mut out[their_start * m..(their_start + k) * m]);
        k <<= 1;
    }
}

/// Ring: p−1 steps passing one block to the right neighbor.
fn ring(env: &mut ProcEnv, comm: &Communicator, mine: &[u8], out: &mut [u8]) {
    let p = comm.size();
    let m = mine.len();
    let me = comm.rank();
    let tag = env.next_coll_tag(comm, opcode::ALLGATHER);
    out[me * m..(me + 1) * m].copy_from_slice(mine);
    let right = (me + 1) % p;
    let left = (me + p - 1) % p;
    for step in 0..p - 1 {
        let send_block = (me + p - step) % p;
        let recv_block = (me + p - step - 1) % p;
        env.send(comm, right, tag, &out[send_block * m..(send_block + 1) * m]);
        env.recv_into(comm, Some(left), tag, &mut out[recv_block * m..(recv_block + 1) * m]);
    }
}

/// Irregular allgather (`MPI_Allgatherv`), ring algorithm: rank r
/// contributes `counts[r]` bytes; `out` is the concatenation in rank order
/// (displacements are the running sum of counts, as in the paper's Fig. 6).
pub fn allgatherv(env: &mut ProcEnv, comm: &Communicator, mine: &[u8], counts: &[usize], out: &mut [u8]) {
    let me = comm.rank();
    assert_eq!(counts.len(), comm.size(), "one count per rank");
    assert_eq!(mine.len(), counts[me], "my contribution must match counts[me]");
    let displ = super::displs_of(counts);
    out[displ[me]..displ[me] + counts[me]].copy_from_slice(mine);
    allgatherv_inplace(env, comm, counts, out);
}

/// [`allgatherv`] without the self-copy: `out` already holds the calling
/// rank's contribution at its displacement. The hybrid leaders run this
/// directly on the shared window — every ring step borrows its outgoing
/// block from `out`, so no per-step temporaries are built.
pub fn allgatherv_inplace(env: &mut ProcEnv, comm: &Communicator, counts: &[usize], out: &mut [u8]) {
    let total: usize = counts.iter().sum();
    assert_eq!(out.len(), total, "allgatherv output buffer size");
    let displ = super::displs_of(counts);
    allgatherv_offsets(env, comm, counts, &displ, out);
}

/// [`allgatherv_inplace`] generalized to explicit per-rank block offsets
/// into `region`: rank `r`'s block lives at
/// `region[offsets[r]..offsets[r] + counts[r]]` and blocks must be
/// disjoint. With running-sum offsets over a tight region this *is*
/// `allgatherv_inplace` (same ring schedule, same messages). The striped
/// multi-leader hybrid bridge needs the general form: leader `j`
/// exchanges stripe `j` of every node block, and those stripes are not
/// contiguous in the shared window.
pub fn allgatherv_offsets(
    env: &mut ProcEnv,
    comm: &Communicator,
    counts: &[usize],
    offsets: &[usize],
    region: &mut [u8],
) {
    let p = comm.size();
    let me = comm.rank();
    assert_eq!(counts.len(), p, "one count per rank");
    assert_eq!(offsets.len(), p, "one offset per rank");
    for r in 0..p {
        assert!(offsets[r] + counts[r] <= region.len(), "allgatherv block {r} out of region");
    }
    // Debug builds also enforce the disjointness the ring depends on
    // (an overlapping stripe table would corrupt blocks mid-exchange).
    #[cfg(debug_assertions)]
    {
        let mut ranges: Vec<(usize, usize)> =
            offsets.iter().zip(counts.iter()).map(|(&o, &c)| (o, c)).collect();
        ranges.sort_unstable();
        for pair in ranges.windows(2) {
            debug_assert!(
                pair[0].0 + pair[0].1 <= pair[1].0,
                "allgatherv blocks overlap: {pair:?}"
            );
        }
    }
    if p == 1 {
        return;
    }
    let tag = env.next_coll_tag(comm, opcode::ALLGATHERV);
    let right = (me + 1) % p;
    let left = (me + p - 1) % p;
    for step in 0..p - 1 {
        let send_block = (me + p - step) % p;
        let recv_block = (me + p - step - 1) % p;
        env.send(comm, right, tag, &region[offsets[send_block]..offsets[send_block] + counts[send_block]]);
        env.recv_into(
            comm,
            Some(left),
            tag,
            &mut region[offsets[recv_block]..offsets[recv_block] + counts[recv_block]],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};

    fn expected(p: usize, m: usize) -> Vec<u8> {
        (0..p).flat_map(|r| payload(r, m)).collect()
    }

    fn check(nodes: &[usize], m: usize, algo: AllgatherAlgo) {
        let p: usize = nodes.iter().sum();
        let expect = expected(p, m);
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let mine = payload(w.rank(), m);
            let mut out = vec![0u8; m * w.size()];
            allgather(env, &w, &mine, &mut out, algo);
            out
        });
        for (r, got) in out.iter().enumerate() {
            assert_eq!(got, &expect, "algo {algo:?} nodes {nodes:?} m {m} rank {r}");
        }
    }

    #[test]
    fn bruck_any_p() {
        for nodes in [&[5usize, 3][..], &[3, 3, 1][..], &[1][..], &[2][..], &[4, 4][..]] {
            check(nodes, 16, AllgatherAlgo::Bruck);
        }
        check(&[5, 3], 1, AllgatherAlgo::Bruck);
    }

    #[test]
    fn recursive_doubling_pow2() {
        check(&[4, 4], 24, AllgatherAlgo::RecursiveDoubling);
        check(&[2, 2], 7, AllgatherAlgo::RecursiveDoubling);
        check(&[8, 8], 3, AllgatherAlgo::RecursiveDoubling);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn recursive_doubling_rejects_odd_p() {
        check(&[5, 3][..1], 8, AllgatherAlgo::RecursiveDoubling);
    }

    #[test]
    fn ring_any_p() {
        for nodes in [&[5usize, 3][..], &[3, 2, 2][..], &[2][..]] {
            check(nodes, 33, AllgatherAlgo::Ring);
        }
    }

    #[test]
    fn auto_correct() {
        check(&[5, 3], 800, AllgatherAlgo::Auto);
        check(&[4, 4], 20_000, AllgatherAlgo::Auto);
        check(&[5, 4], 20_000, AllgatherAlgo::Auto);
    }

    #[test]
    fn allgatherv_irregular_counts() {
        // Per-rank contribution r+1 bytes (rank 7 → 8 bytes).
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let counts: Vec<usize> = (0..w.size()).map(|r| r + 1).collect();
            let mine = payload(w.rank(), w.rank() + 1);
            let total: usize = counts.iter().sum();
            let mut out = vec![0u8; total];
            allgatherv(env, &w, &mine, &counts, &mut out);
            out
        });
        let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, r + 1)).collect();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn allgatherv_offsets_noncontiguous_region() {
        // Blocks scattered across a larger region with gaps (the striped
        // multi-leader bridge layout): every rank ends with every block
        // in place and the gaps untouched.
        let out = run_nodes(&[4], |env| {
            let w = env.world();
            let p = w.size();
            let counts = vec![4usize; p];
            let offsets: Vec<usize> = (0..p).map(|r| r * 10 + 3).collect();
            let mut region = vec![0u8; 40];
            let me = w.rank();
            region[offsets[me]..offsets[me] + 4].copy_from_slice(&payload(me, 4));
            allgatherv_offsets(env, &w, &counts, &offsets, &mut region);
            region
        });
        for got in out {
            for r in 0..4 {
                assert_eq!(&got[r * 10 + 3..r * 10 + 7], &payload(r, 4)[..], "block {r}");
                assert_eq!(got[r * 10], 0, "gap before block {r} untouched");
            }
        }
    }

    #[test]
    fn allgatherv_zero_count_ranks() {
        // Some ranks contribute nothing (bridge-comm edge case).
        let out = run_nodes(&[4], |env| {
            let w = env.world();
            let counts = vec![4usize, 0, 4, 0];
            let mine = if w.rank() % 2 == 0 { payload(w.rank(), 4) } else { vec![] };
            let mut out = vec![0u8; 8];
            allgatherv(env, &w, &mine, &counts, &mut out);
            out
        });
        let expect: Vec<u8> = [payload(0, 4), payload(2, 4)].concat();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn bruck_matches_ring_vtime_order() {
        // Small message: log-round Bruck must beat linear ring in vtime.
        let m = 64;
        let vt = |algo: AllgatherAlgo| {
            run_nodes(&[8, 8], move |env| {
                let w = env.world();
                let mine = payload(w.rank(), m);
                let mut out = vec![0u8; m * w.size()];
                let t0 = env.vclock();
                allgather(env, &w, &mine, &mut out, algo);
                env.vclock() - t0
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        assert!(vt(AllgatherAlgo::Bruck) < vt(AllgatherAlgo::Ring));
    }
}
