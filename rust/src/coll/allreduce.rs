//! Allreduce algorithms (`MPI_Allreduce` baselines).
//!
//! - [`AllreduceAlgo::RecursiveDoubling`] — log2(p) full-buffer exchanges;
//!   latency-optimal, the small-message choice (≤ ~9 KB in Open MPI
//!   4.0.1, §5.2.4);
//! - [`AllreduceAlgo::Rabenseifner`] — reduce-scatter (recursive halving)
//!   followed by allgather (recursive doubling); bandwidth-optimal, the
//!   large-message choice.
//!
//! Non-power-of-two communicators use the standard fold: the first
//! `2·(p − 2^⌊log2 p⌋)` ranks pre-combine pairwise so a power-of-two core
//! set runs the main algorithm, then the folded ranks receive the result.

use super::pow2_le;
use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::{Communicator, Datatype, ReduceOp};

/// Allreduce algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllreduceAlgo {
    RecursiveDoubling,
    Rabenseifner,
    Auto,
}

/// In-place allreduce of `buf` across the communicator.
pub fn allreduce(
    env: &mut ProcEnv,
    comm: &Communicator,
    dtype: Datatype,
    op: ReduceOp,
    buf: &mut [u8],
    algo: AllreduceAlgo,
) {
    let p = comm.size();
    assert_eq!(buf.len() % dtype.size(), 0);
    if p == 1 || buf.is_empty() {
        return;
    }
    let algo = match algo {
        // Auto routes through the installed process-wide selector (the
        // static tables by default; see `crate::select`).
        AllreduceAlgo::Auto => crate::select::global().allreduce_algo(p, buf.len()),
        a => a,
    };
    let tag = env.next_coll_tag(comm, opcode::ALLREDUCE);

    // ---- non-power-of-two fold (shared by both algorithms) -------------
    let me = comm.rank();
    let pof2 = pow2_le(p);
    let rem = p - pof2;
    // Ranks < 2*rem pair up: evens send to odds and drop out; odds combine
    // and take new_rank = me/2; ranks ≥ 2*rem take new_rank = me − rem.
    let new_rank: Option<usize> = if me < 2 * rem {
        if me % 2 == 0 {
            env.send(comm, me + 1, tag, buf);
            None
        } else {
            let mut other = env.take_buf(buf.len());
            env.recv_into(comm, Some(me - 1), tag, &mut other);
            op.apply(dtype, buf, &other);
            env.charge_reduce(buf.len());
            Some(me / 2)
        }
    } else {
        Some(me - rem)
    };

    if let Some(nr) = new_rank {
        // Map new-rank space back to communicator ranks.
        let to_comm = |r: usize| if r < rem { 2 * r + 1 } else { r + rem };
        match algo {
            AllreduceAlgo::RecursiveDoubling => {
                recursive_doubling_core(env, comm, dtype, op, buf, tag, nr, pof2, &to_comm)
            }
            AllreduceAlgo::Rabenseifner => {
                rabenseifner_core(env, comm, dtype, op, buf, tag, nr, pof2, &to_comm)
            }
            AllreduceAlgo::Auto => unreachable!(),
        }
    }

    // Deliver results back to the folded-out even ranks.
    if me < 2 * rem {
        if me % 2 == 0 {
            env.recv_into(comm, Some(me + 1), tag + (1 << 40), buf);
        } else {
            env.send(comm, me - 1, tag + (1 << 40), buf);
        }
    }
}

/// Core recursive doubling over a power-of-two new-rank set.
#[allow(clippy::too_many_arguments)]
fn recursive_doubling_core(
    env: &mut ProcEnv,
    comm: &Communicator,
    dtype: Datatype,
    op: ReduceOp,
    buf: &mut [u8],
    tag: i64,
    nr: usize,
    pof2: usize,
    to_comm: &dyn Fn(usize) -> usize,
) {
    // One pooled round buffer reused across all log2(p) exchanges.
    let mut other = env.take_buf(buf.len());
    let mut mask = 1usize;
    while mask < pof2 {
        let partner = to_comm(nr ^ mask);
        env.send(comm, partner, tag, buf);
        env.recv_into(comm, Some(partner), tag, &mut other);
        op.apply(dtype, buf, &other);
        env.charge_reduce(buf.len());
        mask <<= 1;
    }
}

/// Core Rabenseifner over a power-of-two new-rank set: recursive-halving
/// reduce-scatter, then recursive-doubling allgather (element-aligned).
#[allow(clippy::too_many_arguments)]
fn rabenseifner_core(
    env: &mut ProcEnv,
    comm: &Communicator,
    dtype: Datatype,
    op: ReduceOp,
    buf: &mut [u8],
    tag: i64,
    nr: usize,
    pof2: usize,
    to_comm: &dyn Fn(usize) -> usize,
) {
    let esz = dtype.size();
    let n = buf.len() / esz;
    if n < pof2 {
        // Too few elements to scatter one per rank — fall back.
        recursive_doubling_core(env, comm, dtype, op, buf, tag, nr, pof2, to_comm);
        return;
    }
    // Element ranges per (new) rank block: split as evenly as possible.
    let bounds = |blocks: usize, i: usize| -> usize {
        // boundary before block i of `blocks` equal-ish element blocks
        (n * i) / blocks
    };

    // --- reduce-scatter by recursive halving --------------------------
    // Invariant: I own the element range [lo, hi) of the fully-reduced
    // (so-far) vector; each round halves my range. One pooled scratch
    // buffer (sized for the first, largest round) serves every round.
    let mut scratch = env.take_buf(n.div_ceil(2) * esz);
    let mut lo = 0usize;
    let mut hi = n;
    let mut mask = pof2 / 2;
    let mut group_base = 0usize; // first new-rank of my current group
    while mask >= 1 {
        let partner = nr ^ mask;
        let mid_block = group_base + mask;
        let mid = bounds(pof2, mid_block);
        // The group [group_base, group_base+2*mask) owns [lo, hi); lower
        // half keeps [lo, mid), upper half keeps [mid, hi).
        let (keep_lo, keep_hi, send_lo, send_hi) = if nr < mid_block {
            (lo, mid, mid, hi)
        } else {
            (mid, hi, lo, mid)
        };
        env.send(comm, to_comm(partner), tag, &buf[send_lo * esz..send_hi * esz]);
        let other = &mut scratch[..(keep_hi - keep_lo) * esz];
        env.recv_into(comm, Some(to_comm(partner)), tag, other);
        op.apply(dtype, &mut buf[keep_lo * esz..keep_hi * esz], other);
        env.charge_reduce(other.len());
        lo = keep_lo;
        hi = keep_hi;
        if nr >= mid_block {
            group_base = mid_block;
        }
        mask >>= 1;
    }
    debug_assert_eq!(lo, bounds(pof2, nr));
    debug_assert_eq!(hi, bounds(pof2, nr + 1));

    // --- allgather by recursive doubling ------------------------------
    let mut mask = 1usize;
    while mask < pof2 {
        let partner = nr ^ mask;
        // My accumulated block range (in new-rank blocks).
        let my_first = (nr / mask) * mask;
        let their_first = (partner / mask) * mask;
        let (slo, shi) = (bounds(pof2, my_first), bounds(pof2, my_first + mask));
        let (rlo, rhi) = (bounds(pof2, their_first), bounds(pof2, their_first + mask));
        env.send(comm, to_comm(partner), tag, &buf[slo * esz..shi * esz]);
        env.recv_into(comm, Some(to_comm(partner)), tag, &mut buf[rlo * esz..rhi * esz]);
        mask <<= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::util::{cast_slice, to_bytes};

    fn check(nodes: &[usize], n: usize, algo: AllreduceAlgo) {
        let p: usize = nodes.iter().sum();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let vals: Vec<f64> = (0..n).map(|i| ((w.rank() + 1) * (i + 1)) as f64).collect();
            let mut buf = to_bytes(&vals).to_vec();
            allreduce(env, &w, Datatype::F64, ReduceOp::Sum, &mut buf, algo);
            buf
        });
        let ranks_sum: f64 = (1..=p).map(|r| r as f64).sum();
        for (r, got) in out.into_iter().enumerate() {
            let vals: Vec<f64> = cast_slice(&got);
            for (i, &v) in vals.iter().enumerate() {
                let expect = ranks_sum * (i + 1) as f64;
                assert!((v - expect).abs() < 1e-9, "algo {algo:?} nodes {nodes:?} rank {r} elem {i}: {v} vs {expect}");
            }
        }
    }

    #[test]
    fn recursive_doubling_pow2_and_not() {
        check(&[4, 4], 10, AllreduceAlgo::RecursiveDoubling);
        check(&[5, 3], 10, AllreduceAlgo::RecursiveDoubling);
        check(&[3, 3, 1], 1, AllreduceAlgo::RecursiveDoubling);
        check(&[2], 5, AllreduceAlgo::RecursiveDoubling);
        check(&[1], 5, AllreduceAlgo::RecursiveDoubling);
    }

    #[test]
    fn rabenseifner_pow2_and_not() {
        check(&[4, 4], 64, AllreduceAlgo::Rabenseifner);
        check(&[5, 3], 64, AllreduceAlgo::Rabenseifner);
        check(&[3, 3, 2], 123, AllreduceAlgo::Rabenseifner);
        check(&[4, 4], 7, AllreduceAlgo::Rabenseifner); // n < p fallback path? (7 < 8)
        check(&[2, 2], 4, AllreduceAlgo::Rabenseifner);
    }

    #[test]
    fn auto_switches_at_9kb() {
        check(&[5, 3], 100, AllreduceAlgo::Auto); // 800 B -> recursive doubling
        check(&[5, 3], 2000, AllreduceAlgo::Auto); // 16 KB -> Rabenseifner
    }

    #[test]
    fn max_op_irregular() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let vals = [w.rank() as f64, -(w.rank() as f64)];
            let mut buf = to_bytes(&vals).to_vec();
            allreduce(env, &w, Datatype::F64, ReduceOp::Max, &mut buf, AllreduceAlgo::RecursiveDoubling);
            buf
        });
        for got in out {
            let v: Vec<f64> = cast_slice(&got);
            assert_eq!(v, vec![7.0, 0.0]);
        }
    }

    #[test]
    fn rabenseifner_cheaper_than_recdoubling_for_large() {
        let n = 64 * 1024; // 512 KB of f64
        let vt = |algo: AllreduceAlgo| {
            run_nodes(&[8, 8], move |env| {
                let w = env.world();
                let vals: Vec<f64> = vec![1.0; n];
                let mut buf = to_bytes(&vals).to_vec();
                let t0 = env.vclock();
                allreduce(env, &w, Datatype::F64, ReduceOp::Sum, &mut buf, algo);
                env.vclock() - t0
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        let rd = vt(AllreduceAlgo::RecursiveDoubling);
        let rab = vt(AllreduceAlgo::Rabenseifner);
        assert!(rab < rd, "rabenseifner {rab} should beat recursive doubling {rd} at 512 KB");
    }
}
