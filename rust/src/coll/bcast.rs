//! Broadcast algorithms (`MPI_Bcast` baselines).
//!
//! Open MPI 4.0.1's tuned broadcast switches between algorithms on message
//! size (§5.2.3 of the paper: thresholds 2 KB and ~362 KB). Implemented
//! here:
//!
//! - [`BcastAlgo::Binomial`] — binomial tree, small messages;
//! - [`BcastAlgo::SplitBinary`] — the split-binary tree (the message is
//!   halved; each half flows segmented down one subtree of a binary tree;
//!   subtree pairs then exchange halves), medium messages;
//! - [`BcastAlgo::Pipeline`] — segmented chain, the classic large-message
//!   algorithm;
//! - [`BcastAlgo::ScatterAllgather`] — van de Geijn scatter + ring
//!   allgather. Under block placement our α-β model makes a flat chain
//!   strictly worse than trees (hardware store-and-forward pipelining is
//!   not expressible in α-β), so the tuned decision uses this for the
//!   >362 KB regime on multi-node runs to reproduce the published "large
//!   message dip" of Fig. 13 (documented substitution, DESIGN.md §9).

use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::Communicator;

/// Broadcast algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BcastAlgo {
    Binomial,
    /// Segment size in bytes.
    SplitBinary { seg: usize },
    /// Segment size in bytes.
    Pipeline { seg: usize },
    ScatterAllgather,
    /// Tuned decision from message size (Open MPI 4.0.1 thresholds).
    Auto,
}

/// Broadcast `buf` from communicator rank `root` to all members.
pub fn bcast(env: &mut ProcEnv, comm: &Communicator, root: usize, buf: &mut [u8], algo: BcastAlgo) {
    let p = comm.size();
    if p <= 1 || buf.is_empty() {
        return;
    }
    assert!(root < p, "root {root} out of range for comm of size {p}");
    let algo = match algo {
        // Auto routes through the installed process-wide selector (the
        // static tables by default; see `crate::select`).
        BcastAlgo::Auto => crate::select::global().bcast_algo(p, buf.len()),
        a => a,
    };
    match algo {
        BcastAlgo::Binomial => binomial(env, comm, root, buf),
        BcastAlgo::SplitBinary { seg } => split_binary(env, comm, root, buf, seg),
        BcastAlgo::Pipeline { seg } => pipeline(env, comm, root, buf, seg),
        BcastAlgo::ScatterAllgather => scatter_allgather(env, comm, root, buf),
        BcastAlgo::Auto => unreachable!(),
    }
}

/// Binomial tree: `⌈log2 p⌉` rounds; rank r (root-relative) receives from
/// `r - lowbit(r)` and forwards to `r + 2^k` for descending `k`.
fn binomial(env: &mut ProcEnv, comm: &Communicator, root: usize, buf: &mut [u8]) {
    let p = comm.size();
    let me = comm.rank();
    let tag = env.next_coll_tag(comm, opcode::BCAST);
    let vrank = (me + p - root) % p;
    let mut mask = 1usize;
    while mask < p {
        if vrank & mask != 0 {
            let src = (vrank - mask + root) % p;
            env.recv_into(comm, Some(src), tag, buf);
            break;
        }
        mask <<= 1;
    }
    mask >>= 1;
    // One pooled shared payload for all forwards (fan-out copies are
    // refcount bumps); leaves skip the staging entirely.
    let mut shared: Option<crate::mpi::Payload> = None;
    while mask > 0 {
        if vrank + mask < p {
            let dst = (vrank + mask + root) % p;
            if shared.is_none() {
                shared = Some(env.payload_from(buf));
            }
            env.send_shared(comm, dst, tag, shared.as_ref().expect("staged above"));
        }
        mask >>= 1;
    }
}

/// Segmented chain: vrank i receives each segment from i−1 and forwards to
/// i+1. Pipelining emerges because sends are eager.
fn pipeline(env: &mut ProcEnv, comm: &Communicator, root: usize, buf: &mut [u8], seg: usize) {
    let p = comm.size();
    let me = comm.rank();
    let seg = seg.max(1);
    let tag = env.next_coll_tag(comm, opcode::BCAST);
    let vrank = (me + p - root) % p;
    let prev = (me + p - 1) % p;
    let next = (me + 1) % p;
    let mut off = 0usize;
    while off < buf.len() {
        let end = (off + seg).min(buf.len());
        if vrank > 0 {
            env.recv_into(comm, Some(prev), tag, &mut buf[off..end]);
        }
        if vrank + 1 < p {
            env.send(comm, next, tag, &buf[off..end]);
        }
        off = end;
    }
}

/// Heap-layout binary tree over root-relative vranks: parent of v is
/// `(v-1)/2`, children `2v+1`, `2v+2`.
#[inline]
fn heap_children(v: usize, p: usize) -> (Option<usize>, Option<usize>) {
    let l = 2 * v + 1;
    let r = 2 * v + 2;
    (if l < p { Some(l) } else { None }, if r < p { Some(r) } else { None })
}

/// Which half of the message vrank `v` carries: the subtree of root-child 1
/// carries half 0, the subtree of child 2 carries half 1.
fn subtree_half(mut v: usize) -> usize {
    debug_assert!(v > 0);
    while v > 2 {
        v = (v - 1) / 2;
    }
    v - 1
}

/// Split-binary tree broadcast (Open MPI's medium-message algorithm).
fn split_binary(env: &mut ProcEnv, comm: &Communicator, root: usize, buf: &mut [u8], seg: usize) {
    let p = comm.size();
    let me = comm.rank();
    if p == 2 {
        // Degenerate: direct send.
        let tag = env.next_coll_tag(comm, opcode::BCAST);
        if me == root {
            env.send(comm, 1 - root, tag, buf);
        } else {
            env.recv_into(comm, Some(root), tag, buf);
        }
        return;
    }
    let seg = seg.max(1);
    let tag = env.next_coll_tag(comm, opcode::BCAST);
    let xtag = tag + (1 << 32); // exchange phase
    let vrank = (me + p - root) % p;
    let to_comm = |v: usize| (v + root) % p;

    let mid = buf.len() / 2;
    let ranges = [(0usize, mid), (mid, buf.len())]; // half 0, half 1

    if vrank == 0 {
        // Root: send half h to child 1+h, segmented.
        for h in 0..2usize {
            let child = 1 + h;
            if child >= p {
                continue;
            }
            let (lo, hi) = ranges[h];
            let mut off = lo;
            while off < hi {
                let end = (off + seg).min(hi);
                env.send(comm, to_comm(child), tag, &buf[off..end]);
                off = end;
            }
        }
    } else {
        // Internal/leaf: receive my half from parent, forward to children.
        let h = subtree_half(vrank);
        let (lo, hi) = ranges[h];
        let parent = (vrank - 1) / 2;
        let (cl, cr) = heap_children(vrank, p);
        let mut off = lo;
        while off < hi {
            let end = (off + seg).min(hi);
            env.recv_into(comm, Some(to_comm(parent)), tag, &mut buf[off..end]);
            for c in [cl, cr].into_iter().flatten() {
                env.send(comm, to_comm(c), tag, &buf[off..end]);
            }
            off = end;
        }
    }

    // Exchange phase: pair left-subtree nodes with right-subtree nodes
    // (BFS order); leftovers get their missing half from the root.
    let mut left = Vec::new();
    let mut right = Vec::new();
    for v in 1..p {
        if subtree_half(v) == 0 {
            left.push(v);
        } else {
            right.push(v);
        }
    }
    let paired = left.len().min(right.len());
    if vrank == 0 {
        // Root serves every unpaired node its missing half.
        for &v in left.iter().skip(paired) {
            let (lo, hi) = ranges[1];
            env.send(comm, to_comm(v), xtag, &buf[lo..hi]);
        }
        for &v in right.iter().skip(paired) {
            let (lo, hi) = ranges[0];
            env.send(comm, to_comm(v), xtag, &buf[lo..hi]);
        }
    } else {
        let h = subtree_half(vrank);
        let (list, other) = if h == 0 { (&left, &right) } else { (&right, &left) };
        let idx = list.iter().position(|&v| v == vrank).unwrap();
        let (mlo, mhi) = ranges[1 - h]; // missing half
        if idx < paired {
            let partner = other[idx];
            let (olo, ohi) = ranges[h]; // the half I own
            env.send(comm, to_comm(partner), xtag, &buf[olo..ohi]);
            env.recv_into(comm, Some(to_comm(partner)), xtag, &mut buf[mlo..mhi]);
        } else {
            env.recv_into(comm, Some(to_comm(0)), xtag, &mut buf[mlo..mhi]);
        }
    }
}

/// van de Geijn: binomial scatter of `p` chunks + ring allgather of chunks.
fn scatter_allgather(env: &mut ProcEnv, comm: &Communicator, root: usize, buf: &mut [u8]) {
    let p = comm.size();
    let me = comm.rank();
    let m = buf.len();
    let tag = env.next_coll_tag(comm, opcode::BCAST);
    let rtag = tag + (1 << 32);
    let vrank = (me + p - root) % p;
    let to_comm = |v: usize| (v + root) % p;
    let s = m.div_ceil(p);
    let chunk = |v: usize| -> (usize, usize) {
        let lo = (v * s).min(m);
        let hi = ((v + 1) * s).min(m);
        (lo, hi)
    };

    // Binomial scatter in vrank space: at descending mask, owners of a
    // range [v, v+2*mask) send the upper half [v+mask, v+2*mask) on.
    let mut mask = super::pow2_ge(p) / 2;
    // Receive once: my lowest set bit determines my parent.
    if vrank != 0 {
        let low = vrank & vrank.wrapping_neg();
        let parent = vrank - low;
        let (lo, _) = chunk(vrank);
        let hi = chunk((vrank + low).min(p) - 1).1.max(lo);
        if hi > lo {
            env.recv_into(comm, Some(to_comm(parent)), tag, &mut buf[lo..hi]);
        } else {
            // Zero-length range still needs the matching message.
            env.recv_into(comm, Some(to_comm(parent)), tag, &mut []);
        }
    }
    while mask > 0 {
        if vrank & (mask - 1) == 0 && vrank & mask == 0 {
            let dst = vrank + mask;
            if dst < p {
                let (lo, _) = chunk(dst);
                let hi = chunk((dst + mask).min(p) - 1).1.max(lo);
                env.send(comm, to_comm(dst), tag, &buf[lo..hi]);
            }
        }
        mask >>= 1;
    }

    // Ring allgather of chunks in vrank space.
    let right = to_comm((vrank + 1) % p);
    let left = to_comm((vrank + p - 1) % p);
    for step in 0..p.saturating_sub(1) {
        let send_v = (vrank + p - step) % p;
        let recv_v = (vrank + p - step - 1) % p;
        let (slo, shi) = chunk(send_v);
        let (rlo, rhi) = chunk(recv_v);
        env.send(comm, right, rtag, &buf[slo..shi]);
        env.recv_into(comm, Some(left), rtag, &mut buf[rlo..rhi]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run8, run_nodes};
    use crate::coll::tuning::Tuning;

    fn check_all_algos(nodes: &[usize], m: usize, root: usize) {
        for algo in [
            BcastAlgo::Binomial,
            BcastAlgo::SplitBinary { seg: 7 },
            BcastAlgo::Pipeline { seg: 13 },
            BcastAlgo::ScatterAllgather,
            BcastAlgo::Auto,
        ] {
            let expect = payload(root, m);
            let out = run_nodes(nodes, move |env| {
                let w = env.world();
                let mut buf = if w.rank() == root { payload(root, m) } else { vec![0u8; m] };
                bcast(env, &w, root, &mut buf, algo);
                buf
            });
            for (r, got) in out.iter().enumerate() {
                assert_eq!(got, &expect, "algo {algo:?} nodes {nodes:?} m {m} root {root} rank {r}");
            }
        }
    }

    #[test]
    fn correct_for_various_shapes_and_roots() {
        check_all_algos(&[5, 3], 64, 0);
        check_all_algos(&[5, 3], 64, 5);
        check_all_algos(&[5, 3], 1, 7);
        check_all_algos(&[4, 4], 100, 3);
        check_all_algos(&[1], 33, 0);
        check_all_algos(&[2], 33, 1);
        check_all_algos(&[3, 3, 3], 97, 4);
    }

    #[test]
    fn odd_sizes_and_segments() {
        // Message smaller than one segment; message not divisible by p.
        check_all_algos(&[5, 3], 5, 2);
        check_all_algos(&[5, 3], 101, 6);
    }

    #[test]
    fn auto_picks_by_size() {
        let t = Tuning::default();
        assert_eq!(t.bcast_algo(64, 512), BcastAlgo::Binomial);
        assert!(matches!(t.bcast_algo(64, 64 * 1024), BcastAlgo::SplitBinary { .. }));
        assert_eq!(t.bcast_algo(64, 512 * 1024), BcastAlgo::ScatterAllgather);
        // Tiny communicators stay binomial regardless of size.
        assert_eq!(t.bcast_algo(2, 512 * 1024), BcastAlgo::Binomial);
    }

    #[test]
    fn vtime_binomial_scales_logarithmically() {
        // 8 ranks: depth 3; 2 ranks: depth 1. Virtual time should reflect it.
        let m = 1024;
        let t8 = run8(move |env| {
            let w = env.world();
            let mut buf = vec![1u8; m];
            let t0 = env.vclock();
            bcast(env, &w, 0, &mut buf, BcastAlgo::Binomial);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let t2 = run_nodes(&[2], move |env| {
            let w = env.world();
            let mut buf = vec![1u8; m];
            let t0 = env.vclock();
            bcast(env, &w, 0, &mut buf, BcastAlgo::Binomial);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(t8 > t2 * 1.5, "depth scaling missing: t8={t8} t2={t2}");
        assert!(t8 < t2 * 16.0, "binomial should not be linear in p");
    }
}
