//! Rooted gather (`MPI_Gather` / `MPI_Gatherv` baselines).
//!
//! [`gather`] is a binomial tree (⌈log2 p⌉ rounds): vrank `v` accumulates
//! the contiguous vrank-block range of its subtree and forwards it upward
//! in one message — the standard tree gather of production MPI libraries.
//! [`gatherv`] is the irregular linear variant used over small bridge
//! communicators (one message per non-root member), where the root's
//! ingest — not tree depth — bounds latency.

use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::Communicator;

/// Gather `mine` from every rank into `out` at `root` (rank-major order;
/// `out.len() = mine.len() * comm.size()`, significant only at the root —
/// pass `None` elsewhere).
pub fn gather(
    env: &mut ProcEnv,
    comm: &Communicator,
    root: usize,
    mine: &[u8],
    out: Option<&mut [u8]>,
) {
    let p = comm.size();
    let me = comm.rank();
    let m = mine.len();
    assert!(root < p);
    if p == 1 {
        out.expect("root must supply an output buffer").copy_from_slice(mine);
        return;
    }
    let tag = env.next_coll_tag(comm, opcode::GATHER);
    let vrank = (me + p - root) % p;
    let to_comm = |v: usize| (v + root) % p;

    // acc holds the blocks of vranks [vrank, vrank + width) in vrank
    // order. The subtree width is known up front (the lowest set bit of
    // vrank bounds how many rounds absorb children), so one pooled buffer
    // of the final size replaces the old grow-by-extend vector.
    let low = if vrank == 0 { super::pow2_ge(p) } else { vrank & vrank.wrapping_neg() };
    let width = low.min(p - vrank);
    let mut acc = env.take_buf(width * m);
    acc[..m].copy_from_slice(mine);
    let mut mask = 1usize;
    while mask < p {
        if vrank & mask != 0 {
            // My subtree is complete: ship it to the parent and leave.
            let parent = vrank - mask;
            env.send(comm, to_comm(parent), tag, &acc);
            break;
        }
        let child = vrank + mask;
        if child < p {
            let nblocks = mask.min(p - child);
            env.recv_into(comm, Some(to_comm(child)), tag, &mut acc[mask * m..(mask + nblocks) * m]);
        }
        mask <<= 1;
    }

    if me == root {
        let out = out.expect("root must supply an output buffer");
        assert_eq!(out.len(), m * p, "gather output buffer size");
        debug_assert_eq!(acc.len(), m * p);
        // acc is in vrank order; rotate back to communicator-rank order.
        for v in 0..p {
            let r = to_comm(v);
            out[r * m..(r + 1) * m].copy_from_slice(&acc[v * m..(v + 1) * m]);
        }
    }
}

/// Irregular linear gather: rank `r` contributes `counts[r]` bytes; the
/// root receives the concatenation in rank order. Used over leader/bridge
/// communicators whose per-node block sizes differ (§5.2.2 irregularity).
///
/// `mine: None` is the explicit **in-place root mode**: the root's block
/// already sits in `out` at its displacement (the hybrid gather ingests
/// straight into the shared window this way). Non-root ranks must pass
/// `Some` — their contribution length is still hard-asserted.
pub fn gatherv(
    env: &mut ProcEnv,
    comm: &Communicator,
    root: usize,
    counts: &[usize],
    mine: Option<&[u8]>,
    out: Option<&mut [u8]>,
) {
    if comm.rank() == root {
        let out = out.expect("root must supply an output buffer");
        let total: usize = counts.iter().sum();
        assert_eq!(out.len(), total, "gatherv output buffer size");
        let displ = super::displs_of(counts);
        gatherv_offsets(env, comm, root, counts, &displ, mine, Some(out));
    } else {
        let displ = super::displs_of(counts);
        gatherv_offsets(env, comm, root, counts, &displ, mine, None);
    }
}

/// [`gatherv`] generalized to explicit per-rank landing offsets into the
/// root's `region`: the block of rank `r` lands at
/// `region[offsets[r]..offsets[r] + counts[r]]`. Same message pattern and
/// charging as `gatherv` (one any-source ingest loop at the root); the
/// striped multi-leader hybrid gather needs the general form because
/// stripe `j` of every node block is not contiguous in the root node's
/// shared window.
pub fn gatherv_offsets(
    env: &mut ProcEnv,
    comm: &Communicator,
    root: usize,
    counts: &[usize],
    offsets: &[usize],
    mine: Option<&[u8]>,
    region: Option<&mut [u8]>,
) {
    let p = comm.size();
    let me = comm.rank();
    assert_eq!(counts.len(), p, "one count per rank");
    assert_eq!(offsets.len(), p, "one offset per rank");
    if me == root {
        let region = region.expect("root must supply an output region");
        for r in 0..p {
            assert!(offsets[r] + counts[r] <= region.len(), "gatherv block {r} out of region");
        }
        if let Some(mine) = mine {
            assert_eq!(mine.len(), counts[me], "my contribution must match counts[me]");
            region[offsets[me]..offsets[me] + counts[me]].copy_from_slice(mine);
        }
        // (None: in-place mode — the root's block is already in place.)
        if p == 1 {
            return;
        }
        let tag = env.next_coll_tag(comm, opcode::GATHER);
        for _ in 0..p - 1 {
            // Any-source: arrivals identify their slot by sender rank.
            let (src, data) = env.recv_payload(comm, None, tag);
            assert_eq!(data.len(), counts[src]);
            region[offsets[src]..offsets[src] + counts[src]].copy_from_slice(&data);
            env.count_copy(counts[src]);
        }
    } else {
        let mine = mine.expect("non-root ranks must supply their contribution");
        assert_eq!(mine.len(), counts[me], "my contribution must match counts[me]");
        let tag = env.next_coll_tag(comm, opcode::GATHER);
        env.send(comm, root, tag, mine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};

    fn check(nodes: &[usize], m: usize, root: usize) {
        let p: usize = nodes.iter().sum();
        let expect: Vec<u8> = (0..p).flat_map(|r| payload(r, m)).collect();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let mine = payload(w.rank(), m);
            let mut buf = vec![0u8; m * w.size()];
            let is_root = w.rank() == root;
            gather(env, &w, root, &mine, if is_root { Some(&mut buf) } else { None });
            (is_root, buf)
        });
        for (r, (is_root, buf)) in out.into_iter().enumerate() {
            if is_root {
                assert_eq!(buf, expect, "nodes {nodes:?} m {m} root {root} rank {r}");
            }
        }
    }

    #[test]
    fn binomial_various_shapes_and_roots() {
        check(&[5, 3], 16, 0);
        check(&[5, 3], 16, 6);
        check(&[5, 3, 4], 9, 11);
        check(&[4, 4], 1, 3);
        check(&[2], 33, 1);
        check(&[1], 8, 0);
        check(&[3, 3, 1], 5, 2);
    }

    #[test]
    fn gatherv_irregular() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let counts: Vec<usize> = (0..w.size()).map(|r| 2 * r + 1).collect();
            let mine = payload(w.rank(), counts[w.rank()]);
            let total: usize = counts.iter().sum();
            let mut buf = vec![0u8; total];
            let is_root = w.rank() == 3;
            gatherv(env, &w, 3, &counts, Some(&mine), if is_root { Some(&mut buf) } else { None });
            (is_root, buf)
        });
        let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 2 * r + 1)).collect();
        assert_eq!(out[3].1, expect);
    }

    #[test]
    fn gatherv_zero_counts() {
        let out = run_nodes(&[4], |env| {
            let w = env.world();
            let counts = vec![4usize, 0, 4, 0];
            let mine = if w.rank() % 2 == 0 { payload(w.rank(), 4) } else { vec![] };
            let mut buf = vec![0u8; 8];
            let is_root = w.rank() == 0;
            gatherv(env, &w, 0, &counts, Some(&mine), if is_root { Some(&mut buf) } else { None });
            buf
        });
        assert_eq!(out[0], [payload(0, 4), payload(2, 4)].concat());
    }

    #[test]
    fn binomial_beats_linear_vtime_at_scale() {
        // Tree depth log p must beat the root's linear ingest of p−1
        // messages for small blocks.
        let m = 64;
        let tree = run_nodes(&[8, 8], move |env| {
            let w = env.world();
            let mine = payload(w.rank(), m);
            let mut buf = vec![0u8; m * w.size()];
            let is_root = w.rank() == 0;
            let t0 = env.vclock();
            gather(env, &w, 0, &mine, if is_root { Some(&mut buf) } else { None });
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let linear = run_nodes(&[8, 8], move |env| {
            let w = env.world();
            let counts = vec![m; w.size()];
            let mine = payload(w.rank(), m);
            let mut buf = vec![0u8; m * w.size()];
            let is_root = w.rank() == 0;
            let t0 = env.vclock();
            gatherv(env, &w, 0, &counts, Some(&mine), if is_root { Some(&mut buf) } else { None });
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(tree < linear, "binomial {tree} must beat linear {linear} at 16 ranks");
    }
}
