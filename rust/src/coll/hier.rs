//! SMP-aware hierarchical pure-MPI collectives (§2's "hierarchical
//! algorithm", the structure cray-mpich and modern Open MPI modules use
//! internally).
//!
//! These remain **pure MPI** semantically: every rank ends with its own
//! replicated copy of the result, and all on-node hops are point-to-point
//! messages through the library's staging buffers (double copy) — the two
//! costs the paper's hybrid collectives eliminate. The hierarchy only
//! reorganizes *who* talks across the fabric (node leaders), like a real
//! library would.
//!
//! The internal node/bridge communicators model structures the MPI library
//! builds once at `MPI_Init`/communicator creation — they are constructed
//! over the uncharged control plane (see [`HierCtx::create`]).

use super::allgather::allgatherv_inplace;
use super::allreduce::{allreduce, AllreduceAlgo};
use super::bcast::{bcast, BcastAlgo};
use super::reduce::reduce;
use crate::mpi::env::ProcEnv;
use crate::mpi::{Communicator, Datatype, ReduceOp};

/// Library-internal hierarchy handles for one communicator.
pub struct HierCtx {
    /// The original communicator.
    pub comm: Communicator,
    /// On-node sub-communicator (every rank is a member).
    pub node: Communicator,
    /// Leaders-only communicator (`None` on children).
    pub bridge: Option<Communicator>,
    /// Per-bridge-rank on-node sizes (leaders only, bridge-rank order).
    pub node_sizes: Vec<usize>,
    /// World→(node rank, node size) of every member, used to compute
    /// result placement. Indexed by `comm` rank: (bridge index of its
    /// node, rank within node).
    pub node_of_rank: Vec<(usize, usize)>,
}

impl HierCtx {
    /// Build the hierarchy for `comm`.
    ///
    /// Both splits run through the normal *charged* path, even though the
    /// pure-MPI baseline pays the equivalent setup inside `MPI_Init`,
    /// outside any measured region. Rebating the charge here is not
    /// possible: the splits synchronize the group, and subtracting
    /// virtual time after a synchronization would break clock
    /// monotonicity across ranks. It is also unnecessary: every harness
    /// builds its `HierCtx` once, in the un-timed setup phase (the
    /// [`PlanCache`](crate::coll::PlanCache) shares it per communicator),
    /// so no measured figure includes this cost.
    pub fn create(env: &mut ProcEnv, comm: &Communicator) -> HierCtx {
        let node = env.split_type_shared(comm);
        let is_leader = node.rank() == 0;
        let bridge = env.split(comm, if is_leader { 0 } else { crate::mpi::comm::UNDEFINED }, comm.rank() as i64);

        // Every rank learns the node layout via the topology (the library
        // knows it natively).
        let topo = env.topo().clone();
        let mut leaders: Vec<usize> = (0..topo.nnodes()).map(|n| topo.leader_of_node(n)).collect();
        leaders.sort_unstable();
        // Restrict to nodes that actually host members of `comm`.
        let mut node_ids: Vec<usize> = comm.members().iter().map(|&w| topo.node_of(w)).collect();
        node_ids.sort_unstable();
        node_ids.dedup();
        let node_sizes: Vec<usize> = node_ids
            .iter()
            .map(|&n| comm.members().iter().filter(|&&w| topo.node_of(w) == n).count())
            .collect();
        let node_of_rank: Vec<(usize, usize)> = comm
            .members()
            .iter()
            .map(|&w| {
                let n = topo.node_of(w);
                let bridge_idx = node_ids.iter().position(|&x| x == n).unwrap();
                let node_rank = comm
                    .members()
                    .iter()
                    .filter(|&&v| topo.node_of(v) == n && v < w)
                    .count();
                (bridge_idx, node_rank)
            })
            .collect();
        HierCtx { comm: comm.clone(), node, bridge, node_sizes, node_of_rank }
    }

    pub fn is_leader(&self) -> bool {
        self.node.rank() == 0
    }
}

/// Hierarchical broadcast: root → its node leader → bridge bcast → node
/// bcast. Every hop is real p2p (on-node hops pay the staging double copy).
pub fn hier_bcast(env: &mut ProcEnv, ctx: &HierCtx, root: usize, buf: &mut [u8]) {
    // Move the payload from the root to its node leader.
    let (root_node, root_node_rank) = ctx.node_of_rank[root];
    let me = ctx.comm.rank();
    let tag = env.next_coll_tag(&ctx.comm, crate::mpi::env::opcode::BCAST);
    if root_node_rank != 0 {
        if me == root {
            // send up to my node leader (node rank 0)
            env.send(&ctx.node, 0, tag, buf);
        } else if ctx.is_leader() && ctx.node_of_rank[me].0 == root_node {
            env.recv_into(&ctx.node, Some(root_node_rank), tag, buf);
        }
    }
    // Bridge broadcast among leaders, rooted at the root's node.
    if let Some(bridge) = &ctx.bridge {
        bcast(env, bridge, root_node, buf, BcastAlgo::Auto);
    }
    // Node broadcast from each leader.
    bcast(env, &ctx.node, 0, buf, BcastAlgo::Auto);
}

/// Hierarchical allgather: node gather → bridge allgatherv → node bcast.
/// Result is in `comm`-rank order (block placement ⇒ node-major layout).
pub fn hier_allgather(env: &mut ProcEnv, ctx: &HierCtx, mine: &[u8], out: &mut [u8]) {
    let m = mine.len();
    let p = ctx.comm.size();
    assert_eq!(out.len(), m * p);
    let tag = env.next_coll_tag(&ctx.comm, crate::mpi::env::opcode::GATHER);
    let node_p = ctx.node.size();
    let my_node = ctx.node_of_rank[ctx.comm.rank()].0;
    // Displacement of my node's block in the full result.
    let node_displ: Vec<usize> = ctx
        .node_sizes
        .iter()
        .scan(0usize, |acc, &c| {
            let d = *acc;
            *acc += c * m;
            Some(d)
        })
        .collect();

    if ctx.is_leader() {
        // Gather the node's contributions (p2p, staging copies included).
        let base = node_displ[my_node];
        out[base..base + m].copy_from_slice(mine);
        for r in 1..node_p {
            env.recv_into(&ctx.node, Some(r), tag, &mut out[base + r * m..base + (r + 1) * m]);
        }
        // Exchange node blocks across the bridge, in place: my node's
        // block already sits at its displacement in `out`.
        if let Some(bridge) = &ctx.bridge {
            let counts: Vec<usize> = ctx.node_sizes.iter().map(|&c| c * m).collect();
            allgatherv_inplace(env, bridge, &counts, out);
        }
        // Fan the full result back out on the node.
        bcast(env, &ctx.node, 0, out, BcastAlgo::Auto);
    } else {
        env.send(&ctx.node, 0, tag, mine);
        bcast(env, &ctx.node, 0, out, BcastAlgo::Auto);
    }
}

/// Hierarchical allreduce: node reduce → bridge allreduce → node bcast.
pub fn hier_allreduce(env: &mut ProcEnv, ctx: &HierCtx, dtype: Datatype, op: ReduceOp, buf: &mut [u8]) {
    let node_p = ctx.node.size();
    if node_p > 1 {
        let mut contrib = env.take_buf(buf.len());
        contrib.copy_from_slice(buf);
        let out = if ctx.is_leader() { Some(&mut *buf) } else { None };
        reduce(env, &ctx.node, 0, dtype, op, &contrib, out);
    }
    if let Some(bridge) = &ctx.bridge {
        allreduce(env, bridge, dtype, op, buf, AllreduceAlgo::Auto);
    }
    bcast(env, &ctx.node, 0, buf, BcastAlgo::Auto);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::util::{cast_slice, to_bytes};

    #[test]
    fn hier_bcast_any_root() {
        for root in [0usize, 3, 5, 7] {
            let out = run_nodes(&[5, 3], move |env| {
                let w = env.world();
                let ctx = HierCtx::create(env, &w);
                let mut buf = if w.rank() == root { payload(root, 50) } else { vec![0u8; 50] };
                hier_bcast(env, &ctx, root, &mut buf);
                buf
            });
            let expect = payload(root, 50);
            for got in out {
                assert_eq!(got, expect, "root {root}");
            }
        }
    }

    #[test]
    fn hier_allgather_matches_flat() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let ctx = HierCtx::create(env, &w);
            let mine = payload(w.rank(), 24);
            let mut out = vec![0u8; 24 * w.size()];
            hier_allgather(env, &ctx, &mine, &mut out);
            out
        });
        let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 24)).collect();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn hier_allreduce_sums() {
        let out = run_nodes(&[5, 3, 2], |env| {
            let w = env.world();
            let ctx = HierCtx::create(env, &w);
            let vals = [w.rank() as f64, 1.0];
            let mut buf = to_bytes(&vals).to_vec();
            hier_allreduce(env, &ctx, Datatype::F64, ReduceOp::Sum, &mut buf);
            buf
        });
        for got in out {
            let v: Vec<f64> = cast_slice(&got);
            assert_eq!(v, vec![45.0, 10.0]);
        }
    }

    #[test]
    fn single_node_degenerates_cleanly() {
        let out = run_nodes(&[4], |env| {
            let w = env.world();
            let ctx = HierCtx::create(env, &w);
            let mine = payload(w.rank(), 8);
            let mut out = vec![0u8; 8 * 4];
            hier_allgather(env, &ctx, &mine, &mut out);
            let mut red = to_bytes(&[w.rank() as f64]).to_vec();
            hier_allreduce(env, &ctx, Datatype::F64, ReduceOp::Sum, &mut red);
            (out, red)
        });
        let expect: Vec<u8> = (0..4).flat_map(|r| payload(r, 8)).collect();
        for (ag, red) in out {
            assert_eq!(ag, expect);
            assert_eq!(cast_slice::<f64>(&red), vec![6.0]);
        }
    }
}
