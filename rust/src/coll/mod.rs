//! Pure-MPI collective baselines — the algorithms an MPI library (Open MPI
//! 4.0.1 / cray-mpich, §5.1) would run, implemented over the substrate's
//! point-to-point layer so their cost structure (tree depths, pipelining,
//! intra- vs inter-node hops) emerges from the same model as everything
//! else.
//!
//! The tuned entry points ([`bcast`], [`allgather`], [`allreduce`]) switch
//! algorithms at the message-size thresholds the paper reports for Open
//! MPI 4.0.1 (§5.2.3: 2 KB and ~362 KB for broadcast; §5.2.4: ~9 KB for
//! allreduce). [`hier`] adds SMP-aware hierarchical variants (gather →
//! bridge → broadcast), the flavor cray-mpich applies — still *pure MPI*:
//! every rank keeps its own replicated result buffer and on-node transfers
//! pay the library's staging double copy.

pub mod allgather;
pub mod allreduce;
pub mod bcast;
pub mod gather;
pub mod hier;
pub mod plan;
pub mod reduce;
pub mod reduce_scatter;
pub mod scatter;
pub mod tuning;

pub use allgather::{allgather, allgatherv, allgatherv_inplace, allgatherv_offsets, AllgatherAlgo};
pub use allreduce::{allreduce, AllreduceAlgo};
pub use bcast::{bcast, BcastAlgo};
pub use gather::{gather, gatherv, gatherv_offsets};
pub use plan::{CollIo, CollOp, CollPlan, Flavor, PlanCache, PlanKey, RaceReport};
pub use reduce::reduce;
pub use reduce_scatter::{reduce_scatter, reduce_scatterv, reduce_scatterv_offsets};
pub use scatter::{scatter, scatterv, scatterv_offsets};
pub use tuning::Tuning;

/// Largest power of two ≤ `p` (`p ≥ 1`).
pub(crate) fn pow2_le(p: usize) -> usize {
    debug_assert!(p >= 1);
    1 << (usize::BITS - 1 - p.leading_zeros())
}

/// Byte displacements of per-rank counts (exclusive prefix sums) — the
/// `displs` of every irregular collective (the paper's Fig. 6 pattern).
pub(crate) fn displs_of(counts: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(counts.len());
    let mut acc = 0usize;
    for &c in counts {
        out.push(acc);
        acc += c;
    }
    out
}

/// Smallest power of two ≥ `p`.
pub(crate) fn pow2_ge(p: usize) -> usize {
    p.next_power_of_two()
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared helpers for collective correctness tests.

    use crate::coordinator::{ClusterSpec, Preset, SimCluster};
    use crate::mpi::env::ProcEnv;

    /// Run `f` on a small irregular two-node cluster (5+3 ranks — uniform
    /// shapes hide rank-math bugs) and return per-rank outputs.
    pub fn run8<T: Send + 'static>(f: impl Fn(&mut ProcEnv) -> T + Send + Sync + 'static) -> Vec<T> {
        run_nodes(&[5, 3], f)
    }

    /// Run on nodes with the given per-node rank counts.
    pub fn run_nodes<T: Send + 'static>(
        nodes: &[usize],
        f: impl Fn(&mut ProcEnv) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let mut spec = ClusterSpec::preset(Preset::VulcanSb, nodes.len().max(1));
        spec.nodes = nodes.to_vec();
        SimCluster::new(spec).run(f).outputs
    }

    /// Payload for rank `r`, `m` bytes, deterministic and rank-unique.
    pub fn payload(r: usize, m: usize) -> Vec<u8> {
        (0..m).map(|i| (r.wrapping_mul(131) ^ i.wrapping_mul(29)) as u8).collect()
    }

    #[test]
    fn pow2_helpers() {
        assert_eq!(super::pow2_le(1), 1);
        assert_eq!(super::pow2_le(7), 4);
        assert_eq!(super::pow2_le(8), 8);
        assert_eq!(super::pow2_ge(5), 8);
        assert_eq!(super::pow2_ge(8), 8);
    }
}
