//! The persistent-collective engine: plan once, execute many.
//!
//! The paper's §4.1 wrapper primitives are already "init once, invoke
//! many" objects (`AllgatherParam::create`, `TransTables::create`, the
//! shared windows); the pure-MPI baselines resolve their tuned algorithm
//! from `(p, bytes)` on every call. This module unifies both behind one
//! abstraction:
//!
//! - [`CollPlan`] — a planned collective: all one-off state (resolved
//!   algorithm, communicator splits, shared windows, translation tables,
//!   recvcounts/displs) is bound at plan time; [`CollPlan::execute`] runs
//!   one invocation against caller buffers ([`CollIo`]).
//! - [`PlanCache`] — a per-rank cache keyed by
//!   [`PlanKey`]`(comm, op, count, dtype, algo-flavor)`. Repeated
//!   invocations — the inner loops of SUMMA/Poisson/BPMF — hit the cache
//!   and skip re-planning, re-deriving translation tables and
//!   re-allocating shared windows entirely. Per-communicator one-off
//!   state (the [`HybridCtx`](crate::hybrid::HybridCtx) session with its
//!   cached size sets and translation tables, and the library-internal
//!   [`HierCtx`]) is shared across all plans on that communicator; a
//!   hybrid plan is a thin adapter over a persistent
//!   [`HyColl`](crate::hybrid::HyColl) handle.
//!
//! Three flavors implement every operation (where meaningful):
//! [`Flavor::Pure`] (tuned Open-MPI-style baselines), [`Flavor::Hier`]
//! (SMP-aware hierarchical pure MPI, the cray-mpich shape) and
//! [`Flavor::Hybrid`] (the paper's MPI+MPI wrappers, parameterized by the
//! §4.5 sync scheme and the §5.2.4 step-1 method).
//!
//! Planning is collective: like every MPI collective, all members of a
//! communicator must create and execute plans in the same order. Window
//! teardown is collective too — call [`PlanCache::free`] symmetrically.
//!
//! Steady-state executions are **allocation-free in the payload path**:
//! message staging and per-round scratch come from the rank's recycled
//! slab pool ([`crate::mpi::pool`]), and window-backed plans reduce,
//! gather and scatter in place on the shared window (DESIGN.md §5b).
//! The `zerocopy` integration test pins post-warm-up pool misses to
//! zero.
//!
//! Steady-state executions are also **registry-lock-free** (DESIGN.md
//! §5c): planning resolves each communicator's synchronization slot
//! ([`crate::mpi::state::CommCore`]) into the rank-private `ProcEnv`
//! memo, so the barriers, spin syncs and window operations inside
//! `execute` perform zero `HashMap` lookups under a lock, and messages
//! ride the sharded lock-free mailbox fabric ([`crate::mpi::msg`]).

use super::allgather::{allgather, AllgatherAlgo};
use super::allreduce::{allreduce, AllreduceAlgo};
use super::bcast::{bcast, BcastAlgo};
use super::gather::gather;
use super::hier::{hier_allgather, hier_allreduce, hier_bcast, HierCtx};
use super::reduce::reduce;
use super::reduce_scatter::reduce_scatter;
use super::scatter::scatter;
use crate::analysis::schedule::{verify_rank_local, Diagnostic, RankSchedule};
use crate::hybrid::allreduce::AllreduceMethod;
use crate::hybrid::ctx::{HyColl, HybridCtx, LeaderPolicy};
use crate::hybrid::shmem::HyWin;
use crate::hybrid::sync::SyncScheme;
use crate::mpi::env::ProcEnv;
use crate::mpi::{Communicator, Datatype, ReduceOp};
use crate::select::{registry, SelectPoint, Selector};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

/// Which collective operation a plan executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollOp {
    Allgather,
    Bcast,
    Allreduce,
    Reduce,
    ReduceScatter,
    Gather,
    Scatter,
}

/// Which engine executes a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Flavor {
    /// Tuned flat pure-MPI algorithm (Open MPI 4.0.1-style switch
    /// points), resolved once at plan time.
    Pure,
    /// SMP-aware hierarchical pure MPI (node gather → bridge → node
    /// fan-out; the cray-mpich shape). Allgather/Bcast/Allreduce only.
    Hier,
    /// The paper's hybrid MPI+MPI collectives, on the
    /// [`HybridCtx`](crate::hybrid::HybridCtx) session API.
    Hybrid {
        /// §4.5 yellow-sync implementation.
        scheme: SyncScheme,
        /// §5.2.4 step-1 method (allreduce / reduce-scatter family).
        method: AllreduceMethod,
        /// Leaders per node (arXiv 2007.06892 multi-leader bridges;
        /// clamped to the smallest node population at session creation).
        leaders: usize,
    },
}

impl Flavor {
    /// Hybrid with the paper's final configuration (tuned method cutoff,
    /// single leader per node).
    pub fn hybrid(scheme: SyncScheme) -> Flavor {
        Flavor::Hybrid { scheme, method: AllreduceMethod::Tuned, leaders: 1 }
    }

    /// [`Flavor::hybrid`] with `leaders` leaders per node striping the
    /// bridge step across NIC lanes.
    pub fn hybrid_k(scheme: SyncScheme, leaders: usize) -> Flavor {
        Flavor::Hybrid { scheme, method: AllreduceMethod::Tuned, leaders: leaders.max(1) }
    }
}

/// Cache key: one plan per `(communicator, op, payload size, dtype,
/// reduce-op, flavor, tag)`. `count` is the op's natural per-rank unit in
/// bytes (allgather/gather/scatter block, bcast payload, allreduce
/// operand, reduce-scatter result block). `tag` disambiguates plans that
/// would otherwise collide but must not share a window (e.g. BPMF's two
/// factor tables of equal size).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub comm: u64,
    pub op: CollOp,
    pub count: usize,
    pub dtype: Datatype,
    pub rop: Option<ReduceOp>,
    pub flavor: Flavor,
    pub tag: u32,
}

impl PlanKey {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        comm: &Communicator,
        op: CollOp,
        count: usize,
        dtype: Datatype,
        rop: Option<ReduceOp>,
        flavor: Flavor,
        tag: u32,
    ) -> PlanKey {
        PlanKey { comm: comm.id(), op, count, dtype, rop, flavor, tag }
    }
}

/// Buffer roles of one plan invocation. Ops not listed for a plan's
/// [`CollOp`] panic — the plan/io pairing is a programming error, not a
/// runtime condition.
pub enum CollIo<'a> {
    /// `send`: my `count`-byte block; `recv`: the rank-ordered
    /// concatenation (`count·p` bytes). `recv: None` is allowed for
    /// window-backed plans — the result stays in the shared window
    /// (read it with [`CollPlan::result_view`], the paper's in-place
    /// sharing).
    Allgather { send: &'a [u8], recv: Option<&'a mut [u8]> },
    /// `buf` holds the payload at `root` on entry, and on return the
    /// broadcast payload on every rank that passed `Some`. Non-root ranks
    /// of window-backed plans may pass `None` and read in place.
    Bcast { root: usize, buf: Option<&'a mut [u8]> },
    /// In-place reduction of `buf` (`count` bytes). `fetch: false` lets
    /// window-backed plans leave the result in slot `G` (read it with
    /// [`CollPlan::result_view`] — the §4.4 visible-change sharing, and
    /// what the paper's micro-benchmark times); pure plans always
    /// deliver into `buf`.
    Allreduce { buf: &'a mut [u8], fetch: bool },
    /// Rooted reduction: `send` everywhere, `recv` significant (and
    /// required) at `root`.
    Reduce { root: usize, send: &'a [u8], recv: Option<&'a mut [u8]> },
    /// `send`: my full `count·p`-byte vector; `recv`: my reduced
    /// `count`-byte block.
    ReduceScatter { send: &'a [u8], recv: &'a mut [u8] },
    /// `send`: my `count`-byte block; `recv` significant at `root`
    /// (`count·p` bytes; `None` lets a window-backed root read in place).
    Gather { root: usize, send: &'a [u8], recv: Option<&'a mut [u8]> },
    /// `send` significant at `root` (`count·p` bytes); `recv`: my block.
    Scatter { root: usize, send: Option<&'a [u8]>, recv: &'a mut [u8] },
}

/// A planned collective: init-once state bound, invoke-many execution.
pub trait CollPlan {
    /// The key this plan was built under.
    fn key(&self) -> &PlanKey;

    /// Run one invocation. All communicator members must call `execute`
    /// on their matching plan in the same order (MPI collective rule).
    fn execute(&mut self, env: &mut ProcEnv, io: CollIo<'_>);

    /// Zero-copy view of the result region for window-backed (hybrid)
    /// plans: allgather/bcast/gather read at window offset 0, allreduce
    /// reads slot `G`, reduce-scatter and scatter read the caller's own
    /// block. `None` for pure plans — their result lives in caller
    /// buffers. Valid after `execute` returns and until the next
    /// `execute` on this plan.
    fn result_view(&self, len: usize) -> Option<&[u8]> {
        let _ = len;
        None
    }

    /// Window-backed plans: the backing shared window (the paper's
    /// `Wrapper_Get_localpointer` surface, e.g. for in-place
    /// initialization of a gathered table).
    fn window(&self) -> Option<&HyWin> {
        None
    }

    /// Split-phase adapter: the persistent [`HyColl`] request behind a
    /// hybrid plan, for callers that want to drive it through the
    /// [`HyReq`](crate::hybrid::HyReq) surface (`start_*` → overlap
    /// compute → `test`/`wait`) instead of the blocking
    /// [`CollPlan::execute`]. `None` for pure/hier plans — they have no
    /// nonblocking form.
    fn split_handle(&mut self) -> Option<&mut HyColl> {
        None
    }

    /// Collective teardown (frees shared windows). Called by
    /// [`PlanCache::free`] in plan-creation order on every rank.
    fn teardown(&mut self, env: &mut ProcEnv) {
        let _ = env;
    }

    /// Static-analysis export: this rank's compiled stage schedule as a
    /// [`RankSchedule`] model for the [`crate::analysis::schedule`]
    /// verifier. `root` names the rooted op's root (ignored by rootless
    /// ops). `None` for pure/hier plans — they have no stage schedule.
    fn export_schedule(&self, root: usize) -> Option<RankSchedule> {
        let _ = root;
        None
    }

    /// World ranks of the communicator this plan is collective over, in
    /// communicator-rank order — what [`PlanCache::purge_failed`]
    /// consults against the dead-rank registry.
    fn members(&self) -> &[usize];

    /// One-line description for reports and debugging.
    fn describe(&self) -> String;
}

// ---------------------------------------------------------------------
// Pure plans: the tuned algorithm is resolved once, at plan time.
// ---------------------------------------------------------------------

struct PurePlan {
    key: PlanKey,
    comm: Communicator,
    ag_algo: AllgatherAlgo,
    bc_algo: BcastAlgo,
    ar_algo: AllreduceAlgo,
}

impl PurePlan {
    /// Resolve the three tuned choices once, at plan time, through the
    /// cache's selector (the static tables unless a tuned table or
    /// autotuner is in play — see [`crate::select`]).
    fn new(key: PlanKey, comm: &Communicator, sel: &dyn Selector) -> PurePlan {
        let p = comm.size();
        PurePlan {
            ag_algo: crate::select::sanitize_allgather(sel.allgather_algo(p, key.count), p),
            bc_algo: sel.bcast_algo(p, key.count),
            ar_algo: sel.allreduce_algo(p, key.count),
            key,
            comm: comm.clone(),
        }
    }

    /// Bind explicit algorithms (the race path: the winner of a
    /// [`PlanCache::plan_raced`] sweep becomes the cached plan).
    fn with_algos(
        key: PlanKey,
        comm: &Communicator,
        ag_algo: AllgatherAlgo,
        bc_algo: BcastAlgo,
        ar_algo: AllreduceAlgo,
    ) -> PurePlan {
        PurePlan { ag_algo, bc_algo, ar_algo, key, comm: comm.clone() }
    }
}

impl CollPlan for PurePlan {
    fn key(&self) -> &PlanKey {
        &self.key
    }

    fn execute(&mut self, env: &mut ProcEnv, io: CollIo<'_>) {
        match (self.key.op, io) {
            (CollOp::Allgather, CollIo::Allgather { send, recv }) => {
                let recv = recv.expect("pure allgather requires a recv buffer");
                allgather(env, &self.comm, send, recv, self.ag_algo);
            }
            (CollOp::Bcast, CollIo::Bcast { root, buf }) => {
                let buf = buf.expect("pure bcast requires a buffer on every rank");
                bcast(env, &self.comm, root, buf, self.bc_algo);
            }
            (CollOp::Allreduce, CollIo::Allreduce { buf, .. }) => {
                let (dtype, rop) = (self.key.dtype, self.key.rop.expect("allreduce plan binds an op"));
                allreduce(env, &self.comm, dtype, rop, buf, self.ar_algo);
            }
            (CollOp::Reduce, CollIo::Reduce { root, send, recv }) => {
                let (dtype, rop) = (self.key.dtype, self.key.rop.expect("reduce plan binds an op"));
                reduce(env, &self.comm, root, dtype, rop, send, recv);
            }
            (CollOp::ReduceScatter, CollIo::ReduceScatter { send, recv }) => {
                let (dtype, rop) =
                    (self.key.dtype, self.key.rop.expect("reduce_scatter plan binds an op"));
                reduce_scatter(env, &self.comm, dtype, rop, send, recv);
            }
            (CollOp::Gather, CollIo::Gather { root, send, recv }) => {
                gather(env, &self.comm, root, send, recv);
            }
            (CollOp::Scatter, CollIo::Scatter { root, send, recv }) => {
                scatter(env, &self.comm, root, send, recv);
            }
            _ => panic!("{}: incompatible CollIo", self.describe()),
        }
    }

    fn members(&self) -> &[usize] {
        self.comm.members()
    }

    fn describe(&self) -> String {
        format!("pure {:?} on comm {} ({} B)", self.key.op, self.key.comm, self.key.count)
    }
}

// ---------------------------------------------------------------------
// Hierarchical pure-MPI plans (library-internal SMP awareness).
// ---------------------------------------------------------------------

struct HierPlan {
    key: PlanKey,
    ctx: Rc<HierCtx>,
}

impl CollPlan for HierPlan {
    fn key(&self) -> &PlanKey {
        &self.key
    }

    fn execute(&mut self, env: &mut ProcEnv, io: CollIo<'_>) {
        match (self.key.op, io) {
            (CollOp::Allgather, CollIo::Allgather { send, recv }) => {
                let recv = recv.expect("hier allgather requires a recv buffer");
                hier_allgather(env, &self.ctx, send, recv);
            }
            (CollOp::Bcast, CollIo::Bcast { root, buf }) => {
                let buf = buf.expect("hier bcast requires a buffer on every rank");
                hier_bcast(env, &self.ctx, root, buf);
            }
            (CollOp::Allreduce, CollIo::Allreduce { buf, .. }) => {
                let (dtype, rop) = (self.key.dtype, self.key.rop.expect("allreduce plan binds an op"));
                hier_allreduce(env, &self.ctx, dtype, rop, buf);
            }
            _ => panic!("{}: incompatible CollIo", self.describe()),
        }
    }

    fn members(&self) -> &[usize] {
        self.ctx.comm.members()
    }

    fn describe(&self) -> String {
        format!("hier {:?} on comm {} ({} B)", self.key.op, self.key.comm, self.key.count)
    }
}

// ---------------------------------------------------------------------
// Hybrid plans: a persistent session handle (HyColl) owned by the plan.
// ---------------------------------------------------------------------

struct HybridPlan {
    key: PlanKey,
    /// The persistent handle: window, bridge params, stripe tables,
    /// translation tables, resolved method and scheme — all bound at
    /// plan time by `HybridCtx::*_init`.
    coll: HyColl,
}

impl CollPlan for HybridPlan {
    fn key(&self) -> &PlanKey {
        &self.key
    }

    fn execute(&mut self, env: &mut ProcEnv, io: CollIo<'_>) {
        let count = self.key.count;
        let coll = &mut self.coll;
        let me = coll.ctx().parent().rank();
        let p = coll.ctx().parent().size();
        match (self.key.op, io) {
            (CollOp::Allgather, CollIo::Allgather { send, recv }) => {
                coll.start_allgather(env, send);
                coll.wait(env);
                if let Some(recv) = recv {
                    assert_eq!(recv.len(), count * p);
                    let win = coll.window().expect("plan already freed");
                    win.win.read_into(0, recv);
                    env.charge_memcpy(recv.len());
                }
            }
            (CollOp::Bcast, CollIo::Bcast { root, buf }) => {
                let is_root = me == root;
                coll.start_bcast(env, root, if is_root { buf.as_deref() } else { None });
                coll.wait(env);
                if !is_root {
                    if let Some(out) = buf {
                        assert_eq!(out.len(), count);
                        let win = coll.window().expect("plan already freed");
                        win.win.read_into(0, out);
                        env.charge_memcpy(count);
                    }
                }
            }
            (CollOp::Allreduce, CollIo::Allreduce { buf, fetch }) => {
                coll.start_allreduce(env, buf);
                let g = coll.wait(env);
                if fetch {
                    let win = coll.window().expect("plan already freed");
                    win.win.read_into(g, buf);
                    env.charge_memcpy(count);
                }
            }
            (CollOp::ReduceScatter, CollIo::ReduceScatter { send, recv }) => {
                assert_eq!(recv.len(), count);
                coll.start_reduce_scatter(env, send);
                let off = coll.wait(env);
                let win = coll.window().expect("plan already freed");
                win.win.read_into(off, recv);
                env.charge_memcpy(count);
            }
            (CollOp::Gather, CollIo::Gather { root, send, recv }) => {
                coll.start_gather(env, root, send);
                coll.wait(env);
                if me == root {
                    if let Some(recv) = recv {
                        assert_eq!(recv.len(), count * p);
                        let win = coll.window().expect("plan already freed");
                        win.win.read_into(0, recv);
                        env.charge_memcpy(recv.len());
                    }
                }
            }
            (CollOp::Scatter, CollIo::Scatter { root, send, recv }) => {
                assert_eq!(recv.len(), count);
                coll.start_scatter(env, root, send);
                let off = coll.wait(env);
                let win = coll.window().expect("plan already freed");
                win.win.read_into(off, recv);
                env.charge_memcpy(count);
            }
            _ => panic!("hybrid plan: incompatible CollIo"),
        }
    }

    fn result_view(&self, len: usize) -> Option<&[u8]> {
        self.coll.result_view(len)
    }

    fn window(&self) -> Option<&HyWin> {
        self.coll.window()
    }

    fn split_handle(&mut self) -> Option<&mut HyColl> {
        Some(&mut self.coll)
    }

    fn teardown(&mut self, env: &mut ProcEnv) {
        self.coll.free(env);
    }

    fn export_schedule(&self, root: usize) -> Option<RankSchedule> {
        Some(self.coll.export_schedule(root))
    }

    fn members(&self) -> &[usize] {
        self.coll.ctx().parent().members()
    }

    fn describe(&self) -> String {
        format!(
            "hybrid {:?} on comm {} ({} B, {:?})",
            self.key.op, self.key.comm, self.key.count, self.key.flavor
        )
    }
}

// ---------------------------------------------------------------------
// Per-communicator one-off wrapper state, shared across plans.
// ---------------------------------------------------------------------

#[derive(Default)]
struct CommCtx {
    /// Hybrid session per leader count (the session itself caches size
    /// sets and translation tables across all plans on the communicator).
    hybrid: HashMap<usize, Rc<HybridCtx>>,
    hier: Option<Rc<HierCtx>>,
}

/// Outcome of one [`PlanCache::plan_raced`] sweep: the winning
/// algorithm (and its segment size, 0 if unsegmented) plus the
/// cross-rank agreed per-candidate mean times.
#[derive(Clone, Debug)]
pub struct RaceReport {
    pub winner: String,
    pub seg: usize,
    pub times: Vec<(String, f64)>,
}

/// The per-rank plan cache. See the module docs for the contract; in
/// short: identical call sequences on every member rank, like any MPI
/// collective, and a symmetric [`PlanCache::free`] at the end if hybrid
/// plans were created.
#[derive(Default)]
pub struct PlanCache {
    entries: Vec<(PlanKey, Box<dyn CollPlan>)>,
    index: HashMap<PlanKey, usize>,
    comms: HashMap<u64, CommCtx>,
    hits: u64,
    misses: u64,
    /// Explicit selector for plan-time algorithm resolution; `None`
    /// falls back to the process-wide [`crate::select::global`].
    selector: Option<Arc<dyn Selector>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// A cache whose pure plans resolve through `selector` instead of
    /// the process-wide one — how tests and drivers thread a tuned
    /// selector without mutating global state.
    pub fn with_selector(selector: Arc<dyn Selector>) -> PlanCache {
        PlanCache { selector: Some(selector), ..PlanCache::default() }
    }

    fn selector(&self) -> Arc<dyn Selector> {
        self.selector.clone().unwrap_or_else(crate::select::global)
    }

    /// Cache hits so far (executions that reused an existing plan).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cache misses so far (= number of plans built).
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Number of live plans.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The shared hybrid session for `comm` at `leaders` leaders per
    /// node, if any hybrid plan created one. `leaders` is clamped the
    /// same way planning clamps it, so the count that was passed to
    /// [`Flavor::hybrid_k`] always finds its session.
    pub fn hybrid_ctx(
        &self,
        env: &ProcEnv,
        comm: &Communicator,
        leaders: usize,
    ) -> Option<Rc<HybridCtx>> {
        let eff = HybridCtx::effective_leaders(env, comm, leaders);
        self.comms.get(&comm.id())?.hybrid.get(&eff).cloned()
    }

    fn hybrid(&mut self, env: &mut ProcEnv, comm: &Communicator, leaders: usize) -> Rc<HybridCtx> {
        // Key sessions by the *effective* (clamped) leader count — the
        // same rule `HybridCtx::create` applies — so requested counts
        // that clamp to the same k (e.g. k = 2 and k = 4 on 2-rank
        // nodes) share one session: one set of collective splits, one
        // cached sizeset/translation-table pair. (Plans themselves still
        // key by the requested flavor; only the expensive session state
        // is deduplicated.)
        let eff = HybridCtx::effective_leaders(env, comm, leaders);
        if let Some(h) = self.comms.entry(comm.id()).or_default().hybrid.get(&eff) {
            return h.clone();
        }
        let policy = if eff == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(eff) };
        let h = HybridCtx::create(env, comm, policy);
        self.comms.entry(comm.id()).or_default().hybrid.insert(eff, h.clone());
        h
    }

    fn hier(&mut self, env: &mut ProcEnv, comm: &Communicator) -> Rc<HierCtx> {
        let ctx = self.comms.entry(comm.id()).or_default();
        if ctx.hier.is_none() {
            ctx.hier = Some(Rc::new(HierCtx::create(env, comm)));
        }
        ctx.hier.clone().unwrap()
    }

    /// Get-or-build the plan for a key; returns its index. Building a
    /// hybrid plan is collective (splits/windows/params) — all member
    /// ranks must plan in the same order.
    #[allow(clippy::too_many_arguments)]
    pub fn plan(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        op: CollOp,
        count: usize,
        dtype: Datatype,
        rop: Option<ReduceOp>,
        flavor: Flavor,
    ) -> usize {
        self.plan_tagged(env, comm, op, count, dtype, rop, flavor, 0)
    }

    /// [`PlanCache::plan`] with an explicit disambiguation tag.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_tagged(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        op: CollOp,
        count: usize,
        dtype: Datatype,
        rop: Option<ReduceOp>,
        flavor: Flavor,
        tag: u32,
    ) -> usize {
        let key = PlanKey::new(comm, op, count, dtype, rop, flavor, tag);
        if let Some(&i) = self.index.get(&key) {
            self.hits += 1;
            return i;
        }
        self.misses += 1;
        let plan: Box<dyn CollPlan> = match flavor {
            Flavor::Pure => Box::new(PurePlan::new(key, comm, self.selector().as_ref())),
            Flavor::Hier => {
                assert!(
                    matches!(op, CollOp::Allgather | CollOp::Bcast | CollOp::Allreduce),
                    "no hierarchical plan for {op:?}"
                );
                Box::new(HierPlan { key, ctx: self.hier(env, comm) })
            }
            Flavor::Hybrid { scheme, method, leaders } => {
                let ctx = self.hybrid(env, comm, leaders);
                let coll = match op {
                    CollOp::Allgather => ctx.allgather_init(env, count, scheme),
                    CollOp::Bcast => ctx.bcast_init(env, count, scheme),
                    CollOp::Allreduce => ctx.allreduce_init(
                        env,
                        dtype,
                        rop.expect("allreduce plan binds an op"),
                        count,
                        method,
                        scheme,
                    ),
                    CollOp::ReduceScatter => ctx.reduce_scatter_init(
                        env,
                        dtype,
                        rop.expect("reduce_scatter plan binds an op"),
                        count,
                        method,
                        scheme,
                    ),
                    CollOp::Gather => ctx.gather_init(env, count, scheme),
                    CollOp::Scatter => ctx.scatter_init(env, count, scheme),
                    CollOp::Reduce => panic!("no hybrid plan for Reduce (use Allreduce or Gather)"),
                };
                Box::new(HybridPlan { key, coll })
            }
        };
        self.entries.push((key, plan));
        let i = self.entries.len() - 1;
        self.index.insert(key, i);
        i
    }

    /// Empirically race every viable candidate algorithm for a pure
    /// collective and cache the winner — the measurement half of the
    /// autotuner (`TuneMode::Race`), amortized exactly as UCC's
    /// repetitive-collective model intends: a few timed warm-up
    /// invocations at plan time buy the best algorithm for every later
    /// execute.
    ///
    /// Collective: all member ranks must call with identical arguments.
    /// Each candidate runs `iters` timed invocations on scratch buffers
    /// (virtual-time deltas between two harness syncs); per-candidate
    /// means are then **max-reduced across the communicator** (an exact
    /// reduction — no float-association drift) so every rank folds
    /// identical times and the first-index-tie-break arg-min picks the
    /// same winner everywhere. Divergent winners would deadlock later
    /// executes, so agreement is structural, not hoped-for.
    ///
    /// Results are asserted bit-identical across candidates on every
    /// rank. Raced allreduce therefore requires `Datatype::F64` and
    /// uses integer-valued payloads (exact under Sum/Max/Min; Prod is
    /// seeded so the product stays a small power of two) — candidate
    /// algorithms may associate differently, and only integer values
    /// make every association bitwise equal.
    pub fn plan_raced(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        op: CollOp,
        count: usize,
        dtype: Datatype,
        rop: Option<ReduceOp>,
        iters: usize,
    ) -> (usize, RaceReport) {
        enum Cand {
            Ag(AllgatherAlgo),
            Bc(BcastAlgo),
            Ar(AllreduceAlgo),
        }
        assert!(iters >= 1, "race needs at least one timed invocation");
        let p = comm.size();
        let rpn = env.topo().ranks_on(0).len();
        let pt = SelectPoint::new(p, count, rpn);
        let net = env.net().clone();
        let tuning = super::tuning::Tuning::from_env();

        // Viable candidates at this point, labelled for the report.
        let cands: Vec<(String, usize, Cand)> = match op {
            CollOp::Allgather => registry::allgather_candidates(&net, pt)
                .into_iter()
                .map(|c| (registry::allgather_name(c.algo).to_string(), 0, Cand::Ag(c.algo)))
                .collect(),
            CollOp::Bcast => registry::bcast_candidates(&net, pt, &tuning)
                .into_iter()
                .map(|c| {
                    let (name, seg) = registry::bcast_name(c.algo);
                    let label =
                        if seg > 0 { format!("{name}:{seg}") } else { name.to_string() };
                    (label, seg, Cand::Bc(c.algo))
                })
                .collect(),
            CollOp::Allreduce => {
                assert_eq!(dtype, Datatype::F64, "raced allreduce uses f64 payloads");
                assert_eq!(count % dtype.size(), 0);
                registry::allreduce_candidates(&net, pt)
                    .into_iter()
                    .map(|c| (registry::allreduce_name(c.algo).to_string(), 0, Cand::Ar(c.algo)))
                    .collect()
            }
            other => panic!("plan_raced covers the tuned pure collectives, not {other:?}"),
        };

        // Deterministic, integer-valued scratch payloads.
        let me = comm.rank();
        let rop_v = rop.unwrap_or(ReduceOp::Sum);
        let fill_bytes = |buf: &mut [u8], salt: usize| {
            for (i, b) in buf.iter_mut().enumerate() {
                *b = ((salt * 31 + i * 7) % 251) as u8;
            }
        };
        let fill_f64 = |buf: &mut [u8]| {
            for (i, chunk) in buf.chunks_exact_mut(8).enumerate() {
                let v = match rop_v {
                    // Keep the product a small power of two: exact.
                    ReduceOp::Prod => {
                        if me == 0 {
                            2.0
                        } else {
                            1.0
                        }
                    }
                    _ => (1 + (me + i) % 7) as f64,
                };
                chunk.copy_from_slice(&v.to_le_bytes());
            }
        };

        // Time each candidate: iters invocations between harness syncs.
        let mut local_means: Vec<f64> = Vec::with_capacity(cands.len());
        let mut reference: Option<Vec<u8>> = None;
        let mut mine = vec![0u8; count];
        let mut out = vec![0u8; count * p.max(1)];
        for (label, _seg, cand) in &cands {
            let mut result: Vec<u8> = Vec::new();
            env.harness_sync(comm);
            let t0 = env.vclock();
            for _ in 0..iters {
                match cand {
                    Cand::Ag(a) => {
                        fill_bytes(&mut mine, me + 1);
                        allgather(env, comm, &mine, &mut out, *a);
                        result.clear();
                        result.extend_from_slice(&out);
                    }
                    Cand::Bc(a) => {
                        if me == 0 {
                            fill_bytes(&mut mine, 0xB0);
                        }
                        bcast(env, comm, 0, &mut mine, *a);
                        result.clear();
                        result.extend_from_slice(&mine);
                    }
                    Cand::Ar(a) => {
                        fill_f64(&mut mine);
                        allreduce(env, comm, dtype, rop_v, &mut mine, *a);
                        result.clear();
                        result.extend_from_slice(&mine);
                    }
                }
            }
            env.harness_sync(comm);
            local_means.push((env.vclock() - t0) / iters as f64);
            // Acceptance gate: every candidate must produce the same
            // bits — an algorithm that "wins" by computing something
            // else is a bug, not a winner.
            match &reference {
                None => reference = Some(result),
                Some(first) => assert_eq!(
                    first, &result,
                    "candidate {label} diverges bitwise from {}",
                    cands[0].0
                ),
            }
        }

        // Cross-rank agreement: max-reduce the per-candidate means so
        // every rank sees the same (worst-rank) time per candidate.
        // Max over f64 is order-exact, so explicit recursive doubling
        // is safe on any p (the non-pow2 fold is handled inside).
        let mut agreed: Vec<u8> = local_means.iter().flat_map(|t| t.to_le_bytes()).collect();
        allreduce(
            env,
            comm,
            Datatype::F64,
            ReduceOp::Max,
            &mut agreed,
            AllreduceAlgo::RecursiveDoubling,
        );
        let times: Vec<(String, f64)> = cands
            .iter()
            .zip(agreed.chunks_exact(8))
            .map(|((label, _, _), b)| {
                (label.clone(), f64::from_le_bytes(b.try_into().expect("8-byte chunk")))
            })
            .collect();
        let outcome = crate::select::race(times);

        // Bind the winner into a plan and cache it under the normal
        // pure key (re-racing an existing key rebinds it in place).
        let sel = self.selector();
        let mut ag = crate::select::sanitize_allgather(sel.allgather_algo(p, count), p);
        let mut bc = sel.bcast_algo(p, count);
        let mut ar = sel.allreduce_algo(p, count);
        let (winner_label, winner_seg, winner_cand) = &cands[outcome.winner];
        match winner_cand {
            Cand::Ag(a) => ag = *a,
            Cand::Bc(a) => bc = *a,
            Cand::Ar(a) => ar = *a,
        }
        let key = PlanKey::new(comm, op, count, dtype, rop, Flavor::Pure, 0);
        let plan = Box::new(PurePlan::with_algos(key, comm, ag, bc, ar));
        let idx = if let Some(&i) = self.index.get(&key) {
            self.entries[i].1 = plan;
            i
        } else {
            self.misses += 1;
            self.entries.push((key, plan));
            self.index.insert(key, self.entries.len() - 1);
            self.entries.len() - 1
        };
        let report =
            RaceReport { winner: winner_label.clone(), seg: *winner_seg, times: outcome.times };
        (idx, report)
    }

    /// Look up a live plan by key.
    pub fn get(&self, key: &PlanKey) -> Option<&dyn CollPlan> {
        self.index.get(key).map(|&i| self.entries[i].1.as_ref())
    }

    /// Static-analysis export: this rank's compiled stage schedule for
    /// every window-backed (hybrid) plan in creation order. Pure/hier
    /// plans have no stage schedule and are skipped. Collect the exports
    /// of all member ranks for a key and hand them to
    /// [`crate::analysis::verify_handle`] for the cross-rank checks.
    pub fn export_schedules(&self, root: usize) -> Vec<(PlanKey, RankSchedule)> {
        self.entries
            .iter()
            .filter_map(|(key, plan)| plan.export_schedule(root).map(|s| (*key, s)))
            .collect()
    }

    /// Rank-local verification of every window-backed plan in the cache:
    /// window-segment bounds on each `Work` stage, Arrive/Await pairing
    /// and yellow release/acquire pairing on this rank's own schedule.
    /// (The cross-rank properties — barrier arity, bridge send/recv
    /// matching, deadlock-freedom, root consistency — need all ranks'
    /// schedules; gather those via [`PlanCache::export_schedules`] and
    /// run [`crate::analysis::verify_handle`].) Returns every diagnostic
    /// found; empty means clean.
    pub fn verify(&self, root: usize) -> Vec<Diagnostic> {
        self.export_schedules(root)
            .iter()
            .flat_map(|(_, sched)| verify_rank_local(sched))
            .collect()
    }

    /// Split-phase adapter: plan-or-hit a *hybrid* plan for `key`'s shape
    /// and return its persistent [`HyColl`] request, ready for
    /// `start_* → test/progress → wait` driving (the nonblocking face of
    /// [`CollPlan::execute`]). Panics if `key.flavor` is not hybrid —
    /// pure plans have no split-phase form.
    pub fn split_plan(&mut self, env: &mut ProcEnv, comm: &Communicator, key: PlanKey) -> &mut HyColl {
        assert!(
            matches!(key.flavor, Flavor::Hybrid { .. }),
            "split-phase execution requires a hybrid flavor"
        );
        let i = self.plan_tagged(env, comm, key.op, key.count, key.dtype, key.rop, key.flavor, key.tag);
        self.entries[i]
            .1
            .split_handle()
            .expect("hybrid plans always carry a split-phase handle")
    }

    // ---- typed execute helpers (plan-or-hit, then run) ---------------

    pub fn allgather(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        send: &[u8],
        recv: Option<&mut [u8]>,
    ) {
        self.allgather_tagged(env, comm, flavor, 0, send, recv);
    }

    pub fn allgather_tagged(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        tag: u32,
        send: &[u8],
        recv: Option<&mut [u8]>,
    ) {
        let i = self.plan_tagged(
            env, comm, CollOp::Allgather, send.len(), Datatype::U8, None, flavor, tag,
        );
        self.entries[i].1.execute(env, CollIo::Allgather { send, recv });
    }

    /// `len` is the payload size (needed because non-root hybrid ranks
    /// may pass `buf: None` and read the window in place).
    pub fn bcast(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        root: usize,
        len: usize,
        buf: Option<&mut [u8]>,
    ) {
        let i = self.plan(env, comm, CollOp::Bcast, len, Datatype::U8, None, flavor);
        self.entries[i].1.execute(env, CollIo::Bcast { root, buf });
    }

    pub fn allreduce(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        dtype: Datatype,
        rop: ReduceOp,
        buf: &mut [u8],
    ) {
        let i = self.plan(env, comm, CollOp::Allreduce, buf.len(), dtype, Some(rop), flavor);
        self.entries[i].1.execute(env, CollIo::Allreduce { buf, fetch: true });
    }

    /// Allreduce whose result stays in the shared window for
    /// window-backed plans (`buf` is the operand only; read the result
    /// with [`CollPlan::result_view`]) — the §4.4 visible-change sharing
    /// the paper's micro-benchmark times. Pure plans still deliver into
    /// `buf`.
    pub fn allreduce_windowed(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        dtype: Datatype,
        rop: ReduceOp,
        buf: &mut [u8],
    ) {
        let i = self.plan(env, comm, CollOp::Allreduce, buf.len(), dtype, Some(rop), flavor);
        self.entries[i].1.execute(env, CollIo::Allreduce { buf, fetch: false });
    }

    #[allow(clippy::too_many_arguments)]
    pub fn reduce(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        dtype: Datatype,
        rop: ReduceOp,
        root: usize,
        send: &[u8],
        recv: Option<&mut [u8]>,
    ) {
        let i = self.plan(env, comm, CollOp::Reduce, send.len(), dtype, Some(rop), flavor);
        self.entries[i].1.execute(env, CollIo::Reduce { root, send, recv });
    }

    #[allow(clippy::too_many_arguments)]
    pub fn reduce_scatter(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        dtype: Datatype,
        rop: ReduceOp,
        send: &[u8],
        recv: &mut [u8],
    ) {
        let i = self.plan(env, comm, CollOp::ReduceScatter, recv.len(), dtype, Some(rop), flavor);
        self.entries[i].1.execute(env, CollIo::ReduceScatter { send, recv });
    }

    pub fn gather(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        root: usize,
        send: &[u8],
        recv: Option<&mut [u8]>,
    ) {
        let i = self.plan(env, comm, CollOp::Gather, send.len(), Datatype::U8, None, flavor);
        self.entries[i].1.execute(env, CollIo::Gather { root, send, recv });
    }

    pub fn scatter(
        &mut self,
        env: &mut ProcEnv,
        comm: &Communicator,
        flavor: Flavor,
        root: usize,
        send: Option<&[u8]>,
        recv: &mut [u8],
    ) {
        let i = self.plan(env, comm, CollOp::Scatter, recv.len(), Datatype::U8, None, flavor);
        self.entries[i].1.execute(env, CollIo::Scatter { root, send, recv });
    }

    // ---- zero-copy result access (window-backed plans) ---------------

    /// In-place view of the last allgather result (`len ≤ count·p`).
    pub fn allgather_view(
        &self,
        comm: &Communicator,
        flavor: Flavor,
        count: usize,
        len: usize,
    ) -> Option<&[u8]> {
        self.allgather_view_tagged(comm, flavor, 0, count, len)
    }

    pub fn allgather_view_tagged(
        &self,
        comm: &Communicator,
        flavor: Flavor,
        tag: u32,
        count: usize,
        len: usize,
    ) -> Option<&[u8]> {
        let key = PlanKey::new(comm, CollOp::Allgather, count, Datatype::U8, None, flavor, tag);
        self.get(&key)?.result_view(len)
    }

    /// In-place view of the last bcast payload.
    pub fn bcast_view(&self, comm: &Communicator, flavor: Flavor, len: usize) -> Option<&[u8]> {
        let key = PlanKey::new(comm, CollOp::Bcast, len, Datatype::U8, None, flavor, 0);
        self.get(&key)?.result_view(len)
    }

    /// Backing window of a plan (e.g. for in-place table initialization).
    pub fn window_of(&self, key: &PlanKey) -> Option<&HyWin> {
        self.get(key)?.window()
    }

    /// Collective teardown: frees every window-backed plan in creation
    /// order (identical on all ranks), then drops the cache.
    pub fn free(mut self, env: &mut ProcEnv) {
        for (_, plan) in self.entries.iter_mut() {
            plan.teardown(env);
        }
    }

    /// Drop every plan whose communicator contains a registered-dead
    /// rank, plus the per-communicator session state (hybrid sessions,
    /// hierarchy contexts) of those communicators — after a failure,
    /// re-planning on a shrunken communicator must not resurrect a
    /// session whose group includes the dead rank. Windows of purged
    /// plans are abandoned *without* a collective free (the ULFM-revoke
    /// analogue: their group can no longer meet to free them; the
    /// registry entries leak deliberately). Not collective — every
    /// survivor reaches the identical decision from the shared dead
    /// registry. Returns the number of plans dropped; free on clean runs
    /// (one relaxed load).
    pub fn purge_failed(&mut self, env: &ProcEnv) -> usize {
        if !env.state().any_dead() {
            return 0;
        }
        let dead = env.state().dead_ranks();
        let before = self.entries.len();
        let mut doomed_comms = Vec::new();
        self.entries.retain(|(key, plan)| {
            let doomed = plan.members().iter().any(|w| dead.contains(w));
            if doomed {
                doomed_comms.push(key.comm);
            }
            !doomed
        });
        for c in doomed_comms {
            self.comms.remove(&c);
        }
        self.index.clear();
        for (i, (key, _)) in self.entries.iter().enumerate() {
            self.index.insert(*key, i);
        }
        before - self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::util::{cast_slice, to_bytes};

    #[test]
    fn pure_plans_resolve_algorithms_once() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            let mine = payload(w.rank(), 64);
            let mut out = vec![0u8; 64 * w.size()];
            for _ in 0..4 {
                cache.allgather(env, &w, Flavor::Pure, &mine, Some(&mut out));
            }
            (cache.hits(), cache.misses(), out)
        });
        let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 64)).collect();
        for (hits, misses, got) in out {
            assert_eq!(misses, 1, "one plan built");
            assert_eq!(hits, 3, "three reuses");
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn raced_plans_agree_across_ranks_and_cache_the_winner() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            // Race two ops; every rank must fold to the same winner
            // (divergent winners would deadlock later executes).
            let (_i, ag_report) =
                cache.plan_raced(env, &w, CollOp::Allgather, 64, Datatype::U8, None, 2);
            let (_j, ar_report) = cache.plan_raced(
                env, &w, CollOp::Allreduce, 4 * 8, Datatype::F64, Some(ReduceOp::Sum), 2,
            );
            assert!(ag_report.times.len() >= 2, "multiple candidates raced");
            assert!(ag_report.times.iter().all(|t| t.1.is_finite() && t.1 > 0.0));
            // The winner is cached under the normal pure key: the next
            // typed call is a hit and executes correctly.
            let mine = payload(w.rank(), 64);
            let mut got = vec![0u8; 64 * w.size()];
            cache.allgather(env, &w, Flavor::Pure, &mine, Some(&mut got));
            assert_eq!(cache.hits(), 1, "raced plan reused, not re-planned");
            (ag_report.winner, ar_report.winner, got)
        });
        let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 64)).collect();
        let (ag0, ar0) = (out[0].0.clone(), out[0].1.clone());
        for (w_ag, w_ar, got) in out {
            assert_eq!(w_ag, ag0, "allgather winner agreed on every rank");
            assert_eq!(w_ar, ar0, "allreduce winner agreed on every rank");
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn hybrid_plans_share_comm_state_and_reuse_windows() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            let fl = Flavor::hybrid(SyncScheme::Spin);

            // Two different ops on one comm: one comm_package, two windows.
            let mine = payload(w.rank(), 32);
            let mut ag = vec![0u8; 32 * w.size()];
            cache.allgather(env, &w, fl, &mine, Some(&mut ag));
            let mut vals = to_bytes(&[(w.rank() + 1) as f64]).to_vec();
            cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut vals);

            // Window identity must be stable across repeated executions.
            let w0 = cache
                .allgather_view(&w, fl, 32, 1)
                .map(|s| s.as_ptr() as usize)
                .unwrap();
            for _ in 0..3 {
                cache.allgather(env, &w, fl, &mine, None);
            }
            let w1 = cache
                .allgather_view(&w, fl, 32, 1)
                .map(|s| s.as_ptr() as usize)
                .unwrap();

            let stats = (cache.hits(), cache.misses(), cache.len(), w0 == w1);
            let sum = cast_slice::<f64>(&vals)[0];
            let shmem = cache.hybrid_ctx(env, &w, 1).unwrap().shmem().clone();
            env.barrier(&shmem);
            cache.free(env);
            (stats, ag, sum)
        });
        let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 32)).collect();
        for ((hits, misses, len, stable), ag, sum) in out {
            assert_eq!(misses, 2, "two plans built");
            assert_eq!(hits, 3, "three window-reusing executions");
            assert_eq!(len, 2);
            assert!(stable, "window must not be reallocated between executions");
            assert_eq!(ag, expect);
            assert_eq!(sum, 36.0);
        }
    }

    #[test]
    fn all_ops_route_through_plans_pure_vs_hybrid() {
        // One program runs every op in both flavors and cross-checks.
        let out = run_nodes(&[3, 2, 4], |env| {
            let w = env.world();
            let p = w.size();
            let me = w.rank();
            let mut cache = PlanCache::new();
            let fl = Flavor::hybrid(SyncScheme::Spin);
            let n = 3usize; // doubles per rank

            // allgather
            let mine: Vec<f64> = (0..n).map(|i| (me * n + i) as f64).collect();
            let mut pure_ag = vec![0u8; n * 8 * p];
            cache.allgather(env, &w, Flavor::Pure, to_bytes(&mine), Some(&mut pure_ag));
            let mut hy_ag = vec![0u8; n * 8 * p];
            cache.allgather(env, &w, fl, to_bytes(&mine), Some(&mut hy_ag));
            assert_eq!(pure_ag, hy_ag);

            // bcast (root = child rank 7)
            let msg = payload(7, 40);
            let mut pure_bc = if me == 7 { msg.clone() } else { vec![0u8; 40] };
            cache.bcast(env, &w, Flavor::Pure, 7, 40, Some(&mut pure_bc));
            let mut hy_bc = if me == 7 { msg.clone() } else { vec![0u8; 40] };
            cache.bcast(env, &w, fl, 7, 40, Some(&mut hy_bc));
            assert_eq!(pure_bc, hy_bc);

            // allreduce
            let vals = [(me + 1) as f64, (me * me) as f64];
            let mut pure_ar = to_bytes(&vals).to_vec();
            cache.allreduce(env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, &mut pure_ar);
            let mut hy_ar = to_bytes(&vals).to_vec();
            cache.allreduce(env, &w, fl, Datatype::F64, ReduceOp::Sum, &mut hy_ar);
            assert_eq!(pure_ar, hy_ar);

            // reduce_scatter
            let full: Vec<f64> = (0..n * p).map(|e| ((me + 1) * (e + 1)) as f64).collect();
            let mut pure_rs = vec![0u8; n * 8];
            cache.reduce_scatter(
                env, &w, Flavor::Pure, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut pure_rs,
            );
            let mut hy_rs = vec![0u8; n * 8];
            cache.reduce_scatter(
                env, &w, fl, Datatype::F64, ReduceOp::Sum, to_bytes(&full), &mut hy_rs,
            );
            assert_eq!(pure_rs, hy_rs);

            // gather to 4, scatter from 4
            let blk = payload(me, 16);
            let mut pure_g = vec![0u8; 16 * p];
            let root_buf = (me == 4).then_some(&mut pure_g[..]);
            cache.gather(env, &w, Flavor::Pure, 4, &blk, root_buf);
            let mut hy_g = vec![0u8; 16 * p];
            let root_buf = (me == 4).then_some(&mut hy_g[..]);
            cache.gather(env, &w, fl, 4, &blk, root_buf);
            if me == 4 {
                assert_eq!(pure_g, hy_g);
            }

            let full_sc: Vec<u8> = (0..p).flat_map(|r| payload(r ^ 1, 16)).collect();
            let mut pure_sc = vec![0u8; 16];
            cache.scatter(env, &w, Flavor::Pure, 4, (me == 4).then_some(&full_sc[..]), &mut pure_sc);
            let mut hy_sc = vec![0u8; 16];
            cache.scatter(env, &w, fl, 4, (me == 4).then_some(&full_sc[..]), &mut hy_sc);
            assert_eq!(pure_sc, hy_sc);

            env.barrier(&w);
            cache.free(env);
            (pure_ag, pure_ar, pure_rs)
        });
        assert_eq!(out.len(), 9);
    }

    #[test]
    fn split_plan_adapter_drives_the_persistent_handle() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            let fl = Flavor::hybrid(SyncScheme::Spin);
            let key = PlanKey::new(&w, CollOp::Allgather, 16, Datatype::U8, None, fl, 0);
            let mine = payload(w.rank(), 16);
            // First access plans (collective); start/wait through the
            // split-phase face of the same handle.
            {
                let h = cache.split_plan(env, &w, key);
                h.start_allgather(env, &mine);
                h.wait(env);
            }
            let got = cache.allgather_view(&w, fl, 16, 16 * w.size()).unwrap().to_vec();
            // Second access must hit the cache (same handle, no re-plan)
            // and interoperate with the blocking execute path.
            let misses_before = cache.misses();
            let mut blocking = vec![0u8; 16 * w.size()];
            cache.allgather(env, &w, fl, &mine, Some(&mut blocking));
            assert_eq!(cache.misses(), misses_before, "split_plan and execute share one plan");
            assert_eq!(got, blocking);
            let shmem = cache.hybrid_ctx(env, &w, 1).unwrap().shmem().clone();
            env.barrier(&shmem);
            cache.free(env);
            got
        });
        let expect: Vec<u8> = (0..8).flat_map(|r| payload(r, 16)).collect();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    #[should_panic(expected = "split-phase execution requires a hybrid flavor")]
    fn split_plan_rejects_pure_flavor() {
        run_nodes(&[2], |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            let key = PlanKey::new(&w, CollOp::Allgather, 8, Datatype::U8, None, Flavor::Pure, 0);
            cache.split_plan(env, &w, key);
        });
    }

    #[test]
    #[should_panic(expected = "incompatible CollIo")]
    fn mismatched_io_panics() {
        run_nodes(&[2], |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            let i = cache.plan(env, &w, CollOp::Allgather, 8, Datatype::U8, None, Flavor::Pure);
            // Wrong io for an allgather plan.
            let mut buf = vec![0u8; 8];
            cache.entries[i].1.execute(env, CollIo::Allreduce { buf: &mut buf, fetch: true });
        });
    }

    #[test]
    fn hier_flavor_matches_pure() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            let mine = payload(w.rank(), 24);
            let mut pure = vec![0u8; 24 * w.size()];
            cache.allgather(env, &w, Flavor::Pure, &mine, Some(&mut pure));
            let mut hier = vec![0u8; 24 * w.size()];
            cache.allgather(env, &w, Flavor::Hier, &mine, Some(&mut hier));
            assert_eq!(cache.misses(), 2);
            (pure, hier)
        });
        for (pure, hier) in out {
            assert_eq!(pure, hier);
        }
    }
}
