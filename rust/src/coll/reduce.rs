//! Rooted reduction (`MPI_Reduce`), binomial tree.
//!
//! Used directly by the hybrid allreduce's *method 1* (§4.4/§4.5: an
//! `MPI_Reduce` over the node communicator brings the node's intermediate
//! result to the leader — implying MPI-internal buffer copies, which is
//! exactly the overhead method 2 avoids).

use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::{Communicator, Datatype, ReduceOp};

/// Reduce `contrib` element-wise across the communicator into `out` at
/// `root` (ignored elsewhere; pass `None`). Reduction order follows the
/// binomial combine order — valid for the commutative+associative
/// predefined ops (§4.4).
pub fn reduce(
    env: &mut ProcEnv,
    comm: &Communicator,
    root: usize,
    dtype: Datatype,
    op: ReduceOp,
    contrib: &[u8],
    out: Option<&mut [u8]>,
) {
    let p = comm.size();
    let me = comm.rank();
    assert!(root < p);
    assert_eq!(contrib.len() % dtype.size(), 0);
    if p == 1 {
        out.expect("root must supply an output buffer").copy_from_slice(contrib);
        return;
    }
    let tag = env.next_coll_tag(comm, opcode::REDUCE);
    let vrank = (me + p - root) % p;
    // Pooled accumulator + one pooled child buffer reused every round.
    let mut acc = env.take_buf(contrib.len());
    acc.copy_from_slice(contrib);
    let mut child = env.take_buf(contrib.len());
    let mut mask = 1usize;
    // Binomial gather-with-combine: at round k, vranks with bit k set send
    // their accumulator to (vrank − 2^k) and leave; others absorb.
    while mask < p {
        if vrank & mask != 0 {
            let dst = (vrank - mask + root) % p;
            env.send(comm, dst, tag, &acc);
            break;
        } else if vrank + mask < p {
            let src = (vrank + mask + root) % p;
            env.recv_into(comm, Some(src), tag, &mut child);
            op.apply(dtype, &mut acc, &child);
            env.charge_reduce(acc.len());
        }
        mask <<= 1;
    }
    if me == root {
        out.expect("root must supply an output buffer").copy_from_slice(&acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::util::{cast_slice, to_bytes};

    fn check_sum(nodes: &[usize], n: usize, root: usize) {
        let p: usize = nodes.iter().sum();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let contrib: Vec<f64> = (0..n).map(|i| (w.rank() * n + i) as f64).collect();
            let mut result = vec![0u8; n * 8];
            let is_root = w.rank() == root;
            reduce(
                env,
                &w,
                root,
                Datatype::F64,
                ReduceOp::Sum,
                to_bytes(&contrib),
                if is_root { Some(&mut result) } else { None },
            );
            (is_root, result)
        });
        for (r, (is_root, result)) in out.into_iter().enumerate() {
            if is_root {
                let got: Vec<f64> = cast_slice(&result);
                for (i, &g) in got.iter().enumerate() {
                    let expect: f64 = (0..p).map(|rk| (rk * n + i) as f64).sum();
                    assert!((g - expect).abs() < 1e-9, "rank {r} elem {i}: {g} vs {expect}");
                }
            }
        }
    }

    #[test]
    fn sum_to_various_roots() {
        check_sum(&[5, 3], 10, 0);
        check_sum(&[5, 3], 10, 6);
        check_sum(&[4], 1, 3);
        check_sum(&[1], 4, 0);
        check_sum(&[3, 3, 2], 17, 5);
    }

    #[test]
    fn max_reduces() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let contrib = [(w.rank() as i64) * 7 % 5, w.rank() as i64];
            let mut result = vec![0u8; 16];
            let is_root = w.rank() == 0;
            reduce(
                env,
                &w,
                0,
                Datatype::I64,
                ReduceOp::Max,
                to_bytes(&contrib),
                if is_root { Some(&mut result) } else { None },
            );
            result
        });
        let got: Vec<i64> = cast_slice(&out[0]);
        assert_eq!(got, vec![4, 7]); // max(r*7 mod 5) = 4, max(r) = 7
    }
}
