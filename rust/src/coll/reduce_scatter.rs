//! Reduce-scatter (`MPI_Reduce_scatter` / `_block` baselines), ring
//! algorithm.
//!
//! Every rank contributes a full vector; rank `r` ends with the fully
//! reduced block `r`. The ring formulation (the reduce-scatter phase of
//! ring allreduce) works for **any** communicator size and any per-rank
//! block counts — p−1 neighbor steps, each passing one partial block to
//! the right while folding the incoming partial into the local copy.
//! Bandwidth-optimal: every byte of the result crosses each link once.
//!
//! This is one of the two collectives the follow-up work on multi-core
//! clusters (arXiv:2007.06892) adds to the §4 wrapper set; the hybrid
//! counterpart lives in [`crate::hybrid::reduce_scatter`].

use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::{Communicator, Datatype, ReduceOp};

/// Irregular reduce-scatter: `counts[r]` bytes land on rank `r`.
///
/// `sendbuf` is the rank's full contribution (`Σ counts` bytes, blocks in
/// rank order at the running-sum displacements); `recvbuf` receives the
/// reduced block of the calling rank (`counts[rank]` bytes).
pub fn reduce_scatterv(
    env: &mut ProcEnv,
    comm: &Communicator,
    dtype: Datatype,
    op: ReduceOp,
    counts: &[usize],
    sendbuf: &[u8],
    recvbuf: &mut [u8],
) {
    let total: usize = counts.iter().sum();
    assert_eq!(sendbuf.len(), total, "reduce_scatter input size");
    let displ = super::displs_of(counts);
    reduce_scatterv_offsets(env, comm, dtype, op, counts, &displ, sendbuf, recvbuf);
}

/// [`reduce_scatterv`] generalized to explicit per-rank block offsets
/// into `region` (the calling rank's contribution for block `r` lives at
/// `region[offsets[r]..offsets[r] + counts[r]]`). The blocks are staged
/// into one contiguous pooled working vector — exactly what the
/// contiguous variant does with its input copy — and then run the same
/// ring schedule, so costs and results are identical when the offsets
/// are the running sums. The striped multi-leader hybrid reduce-scatter
/// needs the general form: leader `j` reduces stripe `j` of every node
/// block of the shared window's `L` vector, which is not contiguous.
#[allow(clippy::too_many_arguments)]
pub fn reduce_scatterv_offsets(
    env: &mut ProcEnv,
    comm: &Communicator,
    dtype: Datatype,
    op: ReduceOp,
    counts: &[usize],
    offsets: &[usize],
    region: &[u8],
    recvbuf: &mut [u8],
) {
    let p = comm.size();
    let me = comm.rank();
    assert_eq!(counts.len(), p, "one count per rank");
    assert_eq!(offsets.len(), p, "one offset per rank");
    for &c in counts {
        assert_eq!(c % dtype.size(), 0, "partial element in a reduce_scatter block");
    }
    for r in 0..p {
        assert!(offsets[r] + counts[r] <= region.len(), "reduce_scatter block {r} out of region");
    }
    let displ = super::displs_of(counts);
    assert_eq!(recvbuf.len(), counts[me], "reduce_scatter output size");
    if p == 1 {
        recvbuf.copy_from_slice(&region[offsets[0]..offsets[0] + counts[0]]);
        return;
    }
    let tag = env.next_coll_tag(comm, opcode::REDSCAT);
    let right = (me + 1) % p;
    let left = (me + p - 1) % p;

    // Block b enters the ring at rank b+1 and travels rightward, folding
    // each host's contribution, until it completes at rank b after p−1
    // hops. At step s, rank `me` forwards the partial for block
    // (me−1−s) mod p and folds the incoming partial for block (me−2−s).
    // The working vector and the per-step staging buffer are pooled;
    // outgoing partials are borrowed straight from the working vector.
    let total: usize = counts.iter().sum();
    let mut work = env.take_buf(total);
    for r in 0..p {
        work[displ[r]..displ[r] + counts[r]].copy_from_slice(&region[offsets[r]..offsets[r] + counts[r]]);
    }
    let max_count = counts.iter().copied().max().unwrap_or(0);
    let mut incoming = env.take_buf(max_count);
    for s in 0..p - 1 {
        let sb = (me + 2 * p - 1 - s) % p;
        let rb = (me + 2 * p - 2 - s) % p;
        env.send(comm, right, tag, &work[displ[sb]..displ[sb] + counts[sb]]);
        let stage = &mut incoming[..counts[rb]];
        env.recv_into(comm, Some(left), tag, stage);
        op.apply(dtype, &mut work[displ[rb]..displ[rb] + counts[rb]], stage);
        env.charge_reduce(counts[rb]);
    }
    recvbuf.copy_from_slice(&work[displ[me]..displ[me] + counts[me]]);
}

/// Regular reduce-scatter (`MPI_Reduce_scatter_block`): every rank
/// receives `recvbuf.len()` bytes; `sendbuf.len()` must equal
/// `recvbuf.len() * comm.size()`.
pub fn reduce_scatter(
    env: &mut ProcEnv,
    comm: &Communicator,
    dtype: Datatype,
    op: ReduceOp,
    sendbuf: &[u8],
    recvbuf: &mut [u8],
) {
    let counts = vec![recvbuf.len(); comm.size()];
    reduce_scatterv(env, comm, dtype, op, &counts, sendbuf, recvbuf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::util::{cast_slice, to_bytes};

    fn check_block(nodes: &[usize], n_per_rank: usize) {
        let p: usize = nodes.iter().sum();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let me = w.rank();
            // Element e of the full vector = (rank+1)*(e+1); all integers,
            // so every reduction order is exact.
            let vals: Vec<f64> =
                (0..n_per_rank * w.size()).map(|e| ((me + 1) * (e + 1)) as f64).collect();
            let mut recv = vec![0u8; n_per_rank * 8];
            reduce_scatter(env, &w, Datatype::F64, ReduceOp::Sum, to_bytes(&vals), &mut recv);
            cast_slice::<f64>(&recv)
        });
        let rank_sum: f64 = (1..=p).map(|r| r as f64).sum();
        for (r, got) in out.into_iter().enumerate() {
            for (i, &v) in got.iter().enumerate() {
                let e = r * n_per_rank + i;
                let expect = rank_sum * (e + 1) as f64;
                assert_eq!(v, expect, "nodes {nodes:?} rank {r} elem {i}");
            }
        }
    }

    #[test]
    fn block_various_shapes() {
        check_block(&[5, 3], 4);
        check_block(&[4, 4], 7);
        check_block(&[3, 3, 1], 1);
        check_block(&[2], 5);
        check_block(&[1], 3);
        check_block(&[5, 3, 4], 2);
    }

    #[test]
    fn irregular_counts() {
        // Rank r receives r+1 doubles.
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let me = w.rank();
            let counts_e: Vec<usize> = (0..w.size()).map(|r| r + 1).collect();
            let total_e: usize = counts_e.iter().sum();
            let vals: Vec<f64> = (0..total_e).map(|e| ((me + 1) * (e + 1)) as f64).collect();
            let counts: Vec<usize> = counts_e.iter().map(|&c| c * 8).collect();
            let mut recv = vec![0u8; counts[me]];
            reduce_scatterv(env, &w, Datatype::F64, ReduceOp::Sum, &counts, to_bytes(&vals), &mut recv);
            cast_slice::<f64>(&recv)
        });
        let rank_sum: f64 = (1..=8).map(|r| r as f64).sum();
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got.len(), r + 1);
            let displ_e: usize = (0..r).map(|x| x + 1).sum();
            for (i, &v) in got.iter().enumerate() {
                let e = displ_e + i;
                assert_eq!(v, rank_sum * (e + 1) as f64, "rank {r} elem {i}");
            }
        }
    }

    #[test]
    fn zero_count_ranks() {
        let out = run_nodes(&[4], |env| {
            let w = env.world();
            let counts = vec![8usize, 0, 8, 0];
            let vals = [1.0f64, 2.0];
            let mut recv = vec![0u8; counts[w.rank()]];
            reduce_scatterv(env, &w, Datatype::F64, ReduceOp::Sum, &counts, to_bytes(&vals), &mut recv);
            cast_slice::<f64>(&recv)
        });
        assert_eq!(out[0], vec![4.0]);
        assert_eq!(out[1], Vec::<f64>::new());
        assert_eq!(out[2], vec![8.0]);
    }

    #[test]
    fn max_op() {
        let out = run_nodes(&[3, 2], |env| {
            let w = env.world();
            let me = w.rank() as f64;
            let vals = [me, -me, me * 2.0, 1.0, me, me, me, 10.0 - me, me, me];
            let mut recv = vec![0u8; 2 * 8];
            reduce_scatter(env, &w, Datatype::F64, ReduceOp::Max, to_bytes(&vals), &mut recv);
            cast_slice::<f64>(&recv)
        });
        assert_eq!(out[0], vec![4.0, 0.0]);
        assert_eq!(out[1], vec![8.0, 1.0]);
        assert_eq!(out[3], vec![4.0, 10.0]);
    }

    #[test]
    fn cheaper_than_allreduce_for_large_vectors() {
        // Bandwidth claim: scattering the result must beat replicating it.
        let n = 32 * 1024; // 256 KB of f64
        let rs = run_nodes(&[8, 8], move |env| {
            let w = env.world();
            let vals = vec![1.0f64; n];
            let mut recv = vec![0u8; n * 8 / w.size()];
            let t0 = env.vclock();
            reduce_scatter(env, &w, Datatype::F64, ReduceOp::Sum, to_bytes(&vals), &mut recv);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let ar = run_nodes(&[8, 8], move |env| {
            let w = env.world();
            let mut buf = to_bytes(&vec![1.0f64; n]).to_vec();
            let t0 = env.vclock();
            crate::coll::allreduce(
                env,
                &w,
                Datatype::F64,
                ReduceOp::Sum,
                &mut buf,
                crate::coll::AllreduceAlgo::Auto,
            );
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(rs < ar, "reduce_scatter {rs} must undercut allreduce {ar}");
    }
}
