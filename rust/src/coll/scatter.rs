//! Rooted scatter (`MPI_Scatter` / `MPI_Scatterv` baselines).
//!
//! [`scatter`] is the binomial tree: the root ships each child the whole
//! contiguous vrank-block range of that child's subtree, halving the
//! carried range every round — the mirror image of the tree gather and
//! the scatter half of van de Geijn broadcast
//! ([`crate::coll::bcast::BcastAlgo::ScatterAllgather`]).
//! [`scatterv`] is the irregular linear variant used over small bridge
//! communicators.

use super::pow2_ge;
use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::{Communicator, PoolBuf};

/// Scatter `send` (rank-major, `recv.len() * comm.size()` bytes,
/// significant only at `root` — pass `None` elsewhere) so rank `r`
/// receives block `r` into `recv`.
pub fn scatter(
    env: &mut ProcEnv,
    comm: &Communicator,
    root: usize,
    send: Option<&[u8]>,
    recv: &mut [u8],
) {
    let p = comm.size();
    let me = comm.rank();
    let m = recv.len();
    assert!(root < p);
    if p == 1 {
        recv.copy_from_slice(send.expect("root must supply the send buffer"));
        return;
    }
    let tag = env.next_coll_tag(comm, opcode::SCATTER);
    let vrank = (me + p - root) % p;
    let to_comm = |v: usize| (v + root) % p;

    // stage holds the blocks of vranks [vrank, vrank + width) in vrank
    // order; the root starts with everything, everyone else receives its
    // subtree range from the parent in one message. Pooled, reused across
    // invocations; forwards borrow subranges of it.
    let stage: PoolBuf;
    let mut mask: usize;
    if vrank == 0 {
        let s = send.expect("root must supply the send buffer");
        assert_eq!(s.len(), m * p, "scatter send buffer size");
        let mut rot = env.take_buf(m * p);
        for v in 0..p {
            let r = to_comm(v);
            rot[v * m..(v + 1) * m].copy_from_slice(&s[r * m..(r + 1) * m]);
        }
        stage = rot;
        mask = pow2_ge(p) / 2;
    } else {
        let low = vrank & vrank.wrapping_neg();
        let parent = vrank - low;
        let width = low.min(p - vrank);
        let mut sub = env.take_buf(width * m);
        env.recv_into(comm, Some(to_comm(parent)), tag, &mut sub);
        stage = sub;
        mask = low / 2;
    }
    while mask >= 1 {
        let child = vrank + mask;
        if child < p {
            let w = mask.min(p - child);
            let off = (child - vrank) * m;
            env.send(comm, to_comm(child), tag, &stage[off..off + w * m]);
        }
        mask >>= 1;
    }
    recv.copy_from_slice(&stage[..m]);
}

/// Irregular linear scatter: rank `r` receives `counts[r]` bytes of the
/// root's concatenated buffer (rank-order displacements).
pub fn scatterv(
    env: &mut ProcEnv,
    comm: &Communicator,
    root: usize,
    counts: &[usize],
    send: Option<&[u8]>,
    recv: &mut [u8],
) {
    if comm.rank() == root {
        let s = send.expect("root must supply the send buffer");
        let total: usize = counts.iter().sum();
        assert_eq!(s.len(), total, "scatterv send buffer size");
    }
    let displ = super::displs_of(counts);
    scatterv_offsets(env, comm, root, counts, &displ, send, Some(recv));
}

/// [`scatterv`] generalized to explicit per-rank source offsets into the
/// root's `send` region, with an explicit **in-place mode** on both ends:
/// the root may pass `recv: None` when its own block is already in place
/// (the hybrid scatter's shared-window root), and the root's outgoing
/// blocks are borrowed straight from `send[offsets[r]..]`. Same message
/// pattern as `scatterv`; the striped multi-leader hybrid scatter needs
/// the general form because stripe `j` of every node block is not
/// contiguous in the root node's shared window.
pub fn scatterv_offsets(
    env: &mut ProcEnv,
    comm: &Communicator,
    root: usize,
    counts: &[usize],
    offsets: &[usize],
    send: Option<&[u8]>,
    recv: Option<&mut [u8]>,
) {
    let p = comm.size();
    let me = comm.rank();
    assert_eq!(counts.len(), p, "one count per rank");
    assert_eq!(offsets.len(), p, "one offset per rank");
    if me == root {
        let s = send.expect("root must supply the send region");
        for r in 0..p {
            assert!(offsets[r] + counts[r] <= s.len(), "scatterv block {r} out of region");
        }
        if p > 1 {
            let tag = env.next_coll_tag(comm, opcode::SCATTER);
            for r in 0..p {
                if r != root {
                    env.send(comm, r, tag, &s[offsets[r]..offsets[r] + counts[r]]);
                }
            }
        }
        if let Some(recv) = recv {
            assert_eq!(recv.len(), counts[me], "my block must match counts[me]");
            recv.copy_from_slice(&s[offsets[me]..offsets[me] + counts[me]]);
        }
        // (None: in-place mode — the root's block is already in place.)
    } else {
        let recv = recv.expect("non-root ranks must supply a receive buffer");
        assert_eq!(recv.len(), counts[me], "my block must match counts[me]");
        let tag = env.next_coll_tag(comm, opcode::SCATTER);
        env.recv_into(comm, Some(root), tag, recv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};

    fn check(nodes: &[usize], m: usize, root: usize) {
        let p: usize = nodes.iter().sum();
        let full: Vec<u8> = (0..p).flat_map(|r| payload(r, m)).collect();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let full: Vec<u8> = (0..w.size()).flat_map(|r| payload(r, m)).collect();
            let mut recv = vec![0u8; m];
            let arg = (w.rank() == root).then_some(&full[..]);
            scatter(env, &w, root, arg, &mut recv);
            recv
        });
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got, full[r * m..(r + 1) * m], "nodes {nodes:?} m {m} root {root} rank {r}");
        }
    }

    #[test]
    fn binomial_various_shapes_and_roots() {
        check(&[5, 3], 16, 0);
        check(&[5, 3], 16, 6);
        check(&[5, 3, 4], 9, 11);
        check(&[4, 4], 1, 3);
        check(&[2], 33, 1);
        check(&[1], 8, 0);
        check(&[3, 3, 1], 5, 2);
    }

    #[test]
    fn scatterv_irregular() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let counts: Vec<usize> = (0..w.size()).map(|r| 3 * r + 2).collect();
            let full: Vec<u8> = (0..w.size()).flat_map(|r| payload(r, counts[r])).collect();
            let mut recv = vec![0u8; counts[w.rank()]];
            let arg = (w.rank() == 5).then_some(&full[..]);
            scatterv(env, &w, 5, &counts, arg, &mut recv);
            recv
        });
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got, payload(r, 3 * r + 2), "rank {r}");
        }
    }

    #[test]
    fn scatter_then_gather_roundtrips() {
        let out = run_nodes(&[5, 3, 4], |env| {
            let w = env.world();
            let m = 24;
            let full: Vec<u8> = (0..w.size()).flat_map(|r| payload(r, m)).collect();
            let mut block = vec![0u8; m];
            let arg = (w.rank() == 2).then_some(&full[..]);
            scatter(env, &w, 2, arg, &mut block);
            let mut back = vec![0u8; m * w.size()];
            let is_root = w.rank() == 9;
            crate::coll::gather(env, &w, 9, &block, if is_root { Some(&mut back) } else { None });
            (is_root, back, full)
        });
        for (is_root, back, full) in out {
            if is_root {
                assert_eq!(back, full);
            }
        }
    }
}
