//! Tuned decision rules — which algorithm a production MPI picks at a
//! given (communicator size, message size), after Open MPI 4.0.1's fixed
//! decision tables as observed by the paper (§5.2.3, §5.2.4).
//!
//! Since PR 9 this module is the **static-fallback provider** of the
//! selection subsystem: [`crate::select::StaticSelector`] puts these
//! tables behind the [`crate::select::Selector`] trait, the process-wide
//! default consults them whenever the persisted tuning table has no
//! entry, and the thresholds are no longer compile-time constants —
//! [`Tuning::from_env`] (`HYMPI_*` variables) and the microbench /
//! `bench_all` CLI flags (`--bcast-small-max`, …) override any of them
//! per run, so static-table experiments don't require recompiles.

use super::allgather::AllgatherAlgo;
use super::allreduce::AllreduceAlgo;
use super::bcast::BcastAlgo;
use crate::hybrid::allreduce::{AllreduceMethod, METHOD_CUTOFF_BYTES};

/// Message-size thresholds (bytes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tuning {
    /// Broadcast: ≤ this → binomial (paper: 2 KB).
    pub bcast_small_max: usize,
    /// Broadcast: ≤ this → split-binary tree (paper: ~362 KB).
    pub bcast_medium_max: usize,
    /// Split-binary segment size.
    pub bcast_seg: usize,
    /// Pipeline segment size.
    pub pipeline_seg: usize,
    /// Allreduce: ≤ this → recursive doubling (paper: ~9 KB).
    pub allreduce_small_max: usize,
    /// Allgather: ≤ this per-rank message size → Bruck (log-round,
    /// latency-bound — Open MPI's small-message choice).
    pub allgather_small_max: usize,
    /// Hybrid allreduce family: ≤ this → §5.2.4 method 2, above →
    /// method 1 (the Fig. 15 cutoff).
    pub allreduce_method_max: usize,
}

impl Default for Tuning {
    fn default() -> Tuning {
        Tuning {
            bcast_small_max: 2 * 1024,
            bcast_medium_max: 362 * 1024,
            bcast_seg: 32 * 1024,
            pipeline_seg: 128 * 1024,
            allreduce_small_max: 9 * 1024,
            allgather_small_max: 2 * 1024,
            allreduce_method_max: METHOD_CUTOFF_BYTES,
        }
    }
}

impl Tuning {
    /// The defaults with any `HYMPI_*` environment overrides applied
    /// (read once; unparseable values fall back silently so a typo'd
    /// experiment degrades to the published tables, not a crash).
    pub fn from_env() -> Tuning {
        Tuning::from_lookup(|k| std::env::var(k).ok())
    }

    /// [`Tuning::from_env`] with the lookup injected — tests override
    /// thresholds without mutating process environment (env mutation
    /// races parallel `cargo test` threads).
    pub fn from_lookup(get: impl Fn(&str) -> Option<String>) -> Tuning {
        let mut t = Tuning::default();
        let mut set = |key: &str, slot: &mut usize| {
            if let Some(v) = get(key).and_then(|v| v.trim().parse::<usize>().ok()) {
                *slot = v;
            }
        };
        set("HYMPI_BCAST_SMALL_MAX", &mut t.bcast_small_max);
        set("HYMPI_BCAST_MEDIUM_MAX", &mut t.bcast_medium_max);
        set("HYMPI_BCAST_SEG", &mut t.bcast_seg);
        set("HYMPI_PIPELINE_SEG", &mut t.pipeline_seg);
        set("HYMPI_ALLREDUCE_SMALL_MAX", &mut t.allreduce_small_max);
        set("HYMPI_ALLGATHER_SMALL_MAX", &mut t.allgather_small_max);
        set("HYMPI_ALLREDUCE_METHOD_MAX", &mut t.allreduce_method_max);
        t
    }

    /// Broadcast decision.
    ///
    /// Above `bcast_medium_max` Open MPI switches to its pipeline; in our
    /// α-β model a flat chain cannot express the hardware pipelining that
    /// makes it win on real fabrics, so multi-rank large broadcasts use
    /// van de Geijn scatter-allgather — same published switch point, same
    /// qualitative effect (the Fig. 13 dip at 512 KB). See DESIGN.md §9.
    pub fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo {
        if p <= 2 || bytes <= self.bcast_small_max {
            BcastAlgo::Binomial
        } else if bytes <= self.bcast_medium_max {
            BcastAlgo::SplitBinary { seg: self.bcast_seg }
        } else if p <= 8 {
            BcastAlgo::Pipeline { seg: self.pipeline_seg }
        } else {
            BcastAlgo::ScatterAllgather
        }
    }

    /// Allgather decision (`bytes` = per-rank contribution).
    pub fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo {
        if p == 1 {
            return AllgatherAlgo::Ring;
        }
        if bytes <= self.allgather_small_max {
            AllgatherAlgo::Bruck
        } else if p.is_power_of_two() {
            AllgatherAlgo::RecursiveDoubling
        } else {
            AllgatherAlgo::Ring
        }
    }

    /// Allreduce decision.
    pub fn allreduce_algo(&self, _p: usize, bytes: usize) -> AllreduceAlgo {
        if bytes <= self.allreduce_small_max {
            AllreduceAlgo::RecursiveDoubling
        } else {
            AllreduceAlgo::Rabenseifner
        }
    }

    /// §5.2.4 step-1 method decision for the hybrid allreduce family
    /// (`bytes` = what the bridge moves per node).
    pub fn allreduce_method(&self, bytes: usize) -> AllreduceMethod {
        if bytes <= self.allreduce_method_max {
            AllreduceMethod::Method2
        } else {
            AllreduceMethod::Method1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop as props;

    #[test]
    fn bcast_thresholds_match_paper() {
        let t = Tuning::default();
        assert_eq!(t.bcast_algo(256, 2048), BcastAlgo::Binomial);
        assert!(matches!(t.bcast_algo(256, 2049), BcastAlgo::SplitBinary { .. }));
        assert!(matches!(t.bcast_algo(256, 362 * 1024), BcastAlgo::SplitBinary { .. }));
        assert_eq!(t.bcast_algo(256, 362 * 1024 + 1), BcastAlgo::ScatterAllgather);
    }

    #[test]
    fn allreduce_threshold_matches_paper() {
        let t = Tuning::default();
        assert_eq!(t.allreduce_algo(64, 9 * 1024), AllreduceAlgo::RecursiveDoubling);
        assert_eq!(t.allreduce_algo(64, 9 * 1024 + 1), AllreduceAlgo::Rabenseifner);
    }

    #[test]
    fn allgather_decision_shapes() {
        let t = Tuning::default();
        assert_eq!(t.allgather_algo(768, 800), AllgatherAlgo::Bruck);
        assert_eq!(t.allgather_algo(64, 64 * 1024), AllgatherAlgo::RecursiveDoubling);
        assert_eq!(t.allgather_algo(24, 64 * 1024), AllgatherAlgo::Ring);
    }

    #[test]
    fn method_cutoff_matches_fig15() {
        let t = Tuning::default();
        assert_eq!(t.allreduce_method(0), AllreduceMethod::Method2);
        assert_eq!(t.allreduce_method(METHOD_CUTOFF_BYTES), AllreduceMethod::Method2);
        assert_eq!(t.allreduce_method(METHOD_CUTOFF_BYTES + 1), AllreduceMethod::Method1);
    }

    #[test]
    fn exact_threshold_bytes_sit_on_the_small_side() {
        // Every cutoff is inclusive: `bytes == threshold` takes the
        // smaller-message algorithm, `threshold + 1` switches.
        let t = Tuning::default();
        assert_eq!(t.bcast_algo(64, t.bcast_small_max), BcastAlgo::Binomial);
        assert!(matches!(t.bcast_algo(64, t.bcast_small_max + 1), BcastAlgo::SplitBinary { .. }));
        assert!(matches!(t.bcast_algo(64, t.bcast_medium_max), BcastAlgo::SplitBinary { .. }));
        assert_eq!(t.bcast_algo(64, t.bcast_medium_max + 1), BcastAlgo::ScatterAllgather);
        assert_eq!(t.bcast_algo(8, t.bcast_medium_max + 1), BcastAlgo::Pipeline { seg: t.pipeline_seg });
        assert_eq!(t.allgather_algo(24, t.allgather_small_max), AllgatherAlgo::Bruck);
        assert_eq!(t.allgather_algo(24, t.allgather_small_max + 1), AllgatherAlgo::Ring);
        assert_eq!(t.allgather_algo(32, t.allgather_small_max + 1), AllgatherAlgo::RecursiveDoubling);
        assert_eq!(t.allreduce_algo(4, t.allreduce_small_max), AllreduceAlgo::RecursiveDoubling);
        assert_eq!(t.allreduce_algo(4, t.allreduce_small_max + 1), AllreduceAlgo::Rabenseifner);
    }

    #[test]
    fn tiny_communicators_degenerate_correctly() {
        let t = Tuning::default();
        // p <= 2: every broadcast is binomial regardless of size (a
        // 2-rank "tree" is one send; segmentation buys nothing).
        for bytes in [0, 1, 2 * 1024, 362 * 1024, 1 << 24] {
            assert_eq!(t.bcast_algo(1, bytes), BcastAlgo::Binomial);
            assert_eq!(t.bcast_algo(2, bytes), BcastAlgo::Binomial);
        }
        // p == 1 allgather is the ring no-op; p == 2 follows the tables
        // (2 is a power of two, so large messages take RD).
        assert_eq!(t.allgather_algo(1, 1 << 24), AllgatherAlgo::Ring);
        assert_eq!(t.allgather_algo(2, 16), AllgatherAlgo::Bruck);
        assert_eq!(t.allgather_algo(2, 1 << 24), AllgatherAlgo::RecursiveDoubling);
        // Zero-byte edge: always the small-message algorithm.
        assert_eq!(t.allgather_algo(24, 0), AllgatherAlgo::Bruck);
        assert_eq!(t.allreduce_algo(2, 0), AllreduceAlgo::RecursiveDoubling);
    }

    #[test]
    fn from_lookup_overrides_only_parseable_keys() {
        let t = Tuning::from_lookup(|k| match k {
            "HYMPI_BCAST_SMALL_MAX" => Some("4096".to_string()),
            "HYMPI_ALLREDUCE_METHOD_MAX" => Some(" 1024 ".to_string()),
            "HYMPI_PIPELINE_SEG" => Some("not-a-number".to_string()),
            _ => None,
        });
        assert_eq!(t.bcast_small_max, 4096);
        assert!(matches!(t.bcast_algo(64, 4096), BcastAlgo::Binomial));
        assert_eq!(t.allreduce_method(1024), AllreduceMethod::Method2);
        assert_eq!(t.allreduce_method(1025), AllreduceMethod::Method1);
        // Garbage value: silently keeps the default.
        assert_eq!(t.pipeline_seg, Tuning::default().pipeline_seg);
        // No overrides at all: identical to the published tables.
        assert_eq!(Tuning::from_lookup(|_| None), Tuning::default());
    }

    #[test]
    fn every_point_maps_to_exactly_one_algorithm_static_and_tuned() {
        // The ISSUE-9 satellite property: under both the static tables
        // and the tuned (model) selector, every (p, bytes) point yields
        // exactly one bound, viable algorithm per op — no Auto leaks,
        // no RD allgather off powers of two. Exhaustive over the
        // decision structure is impossible; random points + the exact
        // thresholds (±1) cover every region boundary.
        use crate::select::{ModelSelector, Selector, StaticSelector};
        let selectors: [&dyn Selector; 2] = [
            &StaticSelector::default(),
            &ModelSelector::new(crate::mpi::net::NetModel::infiniband(), 16),
        ];
        let t = Tuning::default();
        let edges = [
            t.bcast_small_max, t.bcast_medium_max, t.allgather_small_max,
            t.allreduce_small_max, t.allreduce_method_max,
        ];
        props::run(
            "one-algorithm-per-point",
            props::default_cases(),
            |r| {
                let p = 1 + r.below(1024);
                let bytes = if r.below(2) == 0 {
                    // Half the cases land exactly on a threshold ± 1.
                    let e = edges[r.below(edges.len())];
                    (e + r.below(3)).saturating_sub(1)
                } else {
                    r.below(1 << 22)
                };
                (p, bytes)
            },
            |&(p, bytes)| {
                for s in selectors {
                    let who = s.describe();
                    if matches!(s.bcast_algo(p, bytes), BcastAlgo::Auto) {
                        return Err(format!("{who}: bcast Auto at ({p},{bytes})"));
                    }
                    let ag = s.allgather_algo(p, bytes);
                    if matches!(ag, AllgatherAlgo::Auto) {
                        return Err(format!("{who}: allgather Auto at ({p},{bytes})"));
                    }
                    if ag == AllgatherAlgo::RecursiveDoubling && !p.is_power_of_two() {
                        return Err(format!("{who}: RD at non-pow2 p={p}"));
                    }
                    if matches!(s.allreduce_algo(p, bytes), AllreduceAlgo::Auto) {
                        return Err(format!("{who}: allreduce Auto at ({p},{bytes})"));
                    }
                    if matches!(s.allreduce_method(bytes), AllreduceMethod::Tuned) {
                        return Err(format!("{who}: method Tuned at {bytes}"));
                    }
                }
                Ok(())
            },
        );
    }
}
