//! Tuned decision rules — which algorithm a production MPI picks at a
//! given (communicator size, message size), after Open MPI 4.0.1's fixed
//! decision tables as observed by the paper (§5.2.3, §5.2.4).

use super::allgather::AllgatherAlgo;
use super::allreduce::AllreduceAlgo;
use super::bcast::BcastAlgo;

/// Message-size thresholds (bytes).
#[derive(Clone, Copy, Debug)]
pub struct Tuning {
    /// Broadcast: ≤ this → binomial (paper: 2 KB).
    pub bcast_small_max: usize,
    /// Broadcast: ≤ this → split-binary tree (paper: ~362 KB).
    pub bcast_medium_max: usize,
    /// Split-binary segment size.
    pub bcast_seg: usize,
    /// Pipeline segment size.
    pub pipeline_seg: usize,
    /// Allreduce: ≤ this → recursive doubling (paper: ~9 KB).
    pub allreduce_small_max: usize,
    /// Allgather: ≤ this per-rank message size → Bruck (log-round,
    /// latency-bound — Open MPI's small-message choice).
    pub allgather_small_max: usize,
}

impl Default for Tuning {
    fn default() -> Tuning {
        Tuning {
            bcast_small_max: 2 * 1024,
            bcast_medium_max: 362 * 1024,
            bcast_seg: 32 * 1024,
            pipeline_seg: 128 * 1024,
            allreduce_small_max: 9 * 1024,
            allgather_small_max: 2 * 1024,
        }
    }
}

impl Tuning {
    /// Broadcast decision.
    ///
    /// Above `bcast_medium_max` Open MPI switches to its pipeline; in our
    /// α-β model a flat chain cannot express the hardware pipelining that
    /// makes it win on real fabrics, so multi-rank large broadcasts use
    /// van de Geijn scatter-allgather — same published switch point, same
    /// qualitative effect (the Fig. 13 dip at 512 KB). See DESIGN.md §9.
    pub fn bcast_algo(&self, p: usize, bytes: usize) -> BcastAlgo {
        if p <= 2 || bytes <= self.bcast_small_max {
            BcastAlgo::Binomial
        } else if bytes <= self.bcast_medium_max {
            BcastAlgo::SplitBinary { seg: self.bcast_seg }
        } else if p <= 8 {
            BcastAlgo::Pipeline { seg: self.pipeline_seg }
        } else {
            BcastAlgo::ScatterAllgather
        }
    }

    /// Allgather decision (`bytes` = per-rank contribution).
    pub fn allgather_algo(&self, p: usize, bytes: usize) -> AllgatherAlgo {
        if p == 1 {
            return AllgatherAlgo::Ring;
        }
        if bytes <= self.allgather_small_max {
            AllgatherAlgo::Bruck
        } else if p.is_power_of_two() {
            AllgatherAlgo::RecursiveDoubling
        } else {
            AllgatherAlgo::Ring
        }
    }

    /// Allreduce decision.
    pub fn allreduce_algo(&self, _p: usize, bytes: usize) -> AllreduceAlgo {
        if bytes <= self.allreduce_small_max {
            AllreduceAlgo::RecursiveDoubling
        } else {
            AllreduceAlgo::Rabenseifner
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bcast_thresholds_match_paper() {
        let t = Tuning::default();
        assert_eq!(t.bcast_algo(256, 2048), BcastAlgo::Binomial);
        assert!(matches!(t.bcast_algo(256, 2049), BcastAlgo::SplitBinary { .. }));
        assert!(matches!(t.bcast_algo(256, 362 * 1024), BcastAlgo::SplitBinary { .. }));
        assert_eq!(t.bcast_algo(256, 362 * 1024 + 1), BcastAlgo::ScatterAllgather);
    }

    #[test]
    fn allreduce_threshold_matches_paper() {
        let t = Tuning::default();
        assert_eq!(t.allreduce_algo(64, 9 * 1024), AllreduceAlgo::RecursiveDoubling);
        assert_eq!(t.allreduce_algo(64, 9 * 1024 + 1), AllreduceAlgo::Rabenseifner);
    }

    #[test]
    fn allgather_decision_shapes() {
        let t = Tuning::default();
        assert_eq!(t.allgather_algo(768, 800), AllgatherAlgo::Bruck);
        assert_eq!(t.allgather_algo(64, 64 * 1024), AllgatherAlgo::RecursiveDoubling);
        assert_eq!(t.allgather_algo(24, 64 * 1024), AllgatherAlgo::Ring);
    }
}
