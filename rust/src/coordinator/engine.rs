//! The thread-per-rank execution engine.
//!
//! [`SimCluster::run`] spawns one OS thread per MPI rank, hands each a
//! [`ProcEnv`], runs the supplied rank program, and collects per-rank
//! outputs + final virtual clocks. Stacks are kept small (1 MiB) so the
//! paper's largest configurations (1024 ranks) fit comfortably.

use super::spec::ClusterSpec;
use crate::mpi::env::ProcEnv;
use crate::mpi::state::ClusterState;
use crate::mpi::topo::Topology;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Result of one cluster run.
pub struct RunReport<T> {
    /// Per-rank outputs, indexed by world rank.
    pub outputs: Vec<T>,
    /// Per-rank final virtual clocks (µs).
    pub vtimes: Vec<f64>,
    /// Real wall time of the whole run.
    pub wall: Duration,
    /// Total data-plane messages / bytes moved.
    pub msgs: u64,
    pub bytes: u64,
}

impl<T> RunReport<T> {
    /// The cluster's makespan: max over ranks of the final virtual clock.
    pub fn max_vtime_us(&self) -> f64 {
        self.vtimes.iter().copied().fold(0.0, f64::max)
    }
}

/// A simulated cluster, ready to run rank programs.
pub struct SimCluster {
    spec: ClusterSpec,
}

impl SimCluster {
    pub fn new(spec: ClusterSpec) -> SimCluster {
        SimCluster { spec }
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Run `f` as the rank program on every rank; block until all finish.
    ///
    /// Panics in any rank propagate (with the rank id) after all threads
    /// are joined — a failed collective must fail the run, not hang it.
    pub fn run<T, F>(&self, f: F) -> RunReport<T>
    where
        T: Send + 'static,
        F: Fn(&mut ProcEnv) -> T + Send + Sync + 'static,
    {
        // Apply the spec's park-bound choice (wall-clock wakeup latency
        // only; 0 = auto-tune from the host core count) and the fault
        // plan's failure-detection knobs. The detection bound is
        // stretched by the plan's worst straggler factor so a
        // slow-but-alive rank never trips the cascade escape; the
        // cascade-round count comes straight from the plan.
        crate::mpi::sync::set_park_bound_us(self.spec.knobs.park_bound_us.unwrap_or(0));
        let fault = self.spec.knobs.fault.as_ref();
        crate::mpi::fault::set_detect_bound_us(
            fault
                .map(crate::mpi::FaultPlan::scaled_detect_bound_us)
                .unwrap_or(crate::mpi::fault::DEFAULT_DETECT_BOUND_US),
        );
        crate::mpi::fault::set_cascade_rounds(
            fault.map(|f| f.cascade_rounds).unwrap_or(crate::mpi::fault::DEFAULT_CASCADE_ROUNDS),
        );
        let topo = Topology::new(&self.spec.nodes, self.spec.placement);
        let world = topo.world_size();
        let state = ClusterState::with_knobs(
            topo,
            self.spec.net.clone(),
            self.spec.mgmt.clone(),
            self.spec.knobs.clone(),
        );
        let f = Arc::new(f);
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(world);
        for rank in 0..world {
            let state = state.clone();
            let f = f.clone();
            let h = std::thread::Builder::new()
                .name(format!("rank-{rank}"))
                .stack_size(1 << 20)
                .spawn(move || {
                    let mut env = ProcEnv::new(state, rank);
                    let out = f(&mut env);
                    (out, env.vclock())
                })
                .expect("spawn rank thread");
            handles.push(h);
        }
        let mut outputs = Vec::with_capacity(world);
        let mut vtimes = Vec::with_capacity(world);
        let mut panic: Option<(usize, Box<dyn std::any::Any + Send>)> = None;
        for (rank, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok((out, vt)) => {
                    outputs.push(out);
                    vtimes.push(vt);
                }
                Err(e) => {
                    if panic.is_none() {
                        panic = Some((rank, e));
                    }
                }
            }
        }
        if let Some((rank, e)) = panic {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| (*s).to_string()))
                // The failure detector panics with a typed payload; name
                // the dead rank rather than printing "<non-string panic>".
                .or_else(|| {
                    e.downcast_ref::<crate::mpi::fault::RankFailed>().map(|rf| rf.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".to_string());
            std::panic::panic_any(format!("rank {rank} panicked: {msg}"));
        }
        RunReport {
            outputs,
            vtimes,
            wall: t0.elapsed(),
            msgs: state.traffic.msgs.load(Ordering::Relaxed),
            bytes: state.traffic.bytes.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::Preset;
    use crate::mpi::USER_TAG_BASE;

    #[test]
    fn runs_all_ranks_and_collects_in_order() {
        let cluster = SimCluster::new(ClusterSpec::preset(Preset::VulcanSb, 2));
        let report = cluster.run(|env| env.world_rank() * 10);
        assert_eq!(report.outputs.len(), 32);
        for (r, &o) in report.outputs.iter().enumerate() {
            assert_eq!(o, r * 10);
        }
    }

    #[test]
    fn traffic_counters_flow_through() {
        let cluster = SimCluster::new(ClusterSpec::preset(Preset::VulcanSb, 1));
        let report = cluster.run(|env| {
            let w = env.world();
            if env.world_rank() == 0 {
                for dst in 1..w.size() {
                    env.send(&w, dst, USER_TAG_BASE, &[0u8; 64]);
                }
            } else {
                let _ = env.recv(&w, Some(0), USER_TAG_BASE);
            }
        });
        assert_eq!(report.msgs, 15);
        assert_eq!(report.bytes, 15 * 64);
        assert!(report.max_vtime_us() > 0.0);
    }

    #[test]
    #[should_panic(expected = "rank 2 panicked")]
    fn rank_panic_propagates_with_id() {
        let cluster = SimCluster::new(ClusterSpec::preset(Preset::VulcanSb, 1));
        cluster.run(|env| {
            if env.world_rank() == 2 {
                panic!("boom at rank {}", env.world_rank());
            }
            // Other ranks must not block forever on a dead peer here;
            // they simply finish.
        });
    }

    #[test]
    fn hundreds_of_ranks_complete() {
        // A scale smoke test: 256 rank threads on this host.
        let cluster = SimCluster::new(ClusterSpec::preset(Preset::VulcanHsw, 11)); // 264 ranks
        let report = cluster.run(|env| {
            let w = env.world();
            env.barrier(&w);
            env.vclock()
        });
        assert_eq!(report.outputs.len(), 264);
    }
}
