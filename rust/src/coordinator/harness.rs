//! OSU-style latency measurement harness (§5: "micro-benchmarks were
//! developed according to the OSU benchmark and averaged over 10,000
//! executions").
//!
//! Per iteration: align virtual clocks (uncharged harness sync), run the
//! operation under test, and record each rank's elapsed virtual time. The
//! reported latency of an iteration is the **max across ranks** (a
//! collective is complete when its slowest participant finishes); the
//! figure value is the mean over iterations, exactly as OSU reports it.

use super::engine::SimCluster;
use super::spec::ClusterSpec;
use crate::mpi::env::ProcEnv;
use crate::util::Summary;

/// Iteration policy. The paper uses 10 000 iterations on real silicon; the
/// simulator is deterministic (no OS noise in virtual time), so far fewer
/// iterations give identical means — iteration count only has to cover
/// protocol warm-up effects.
#[derive(Clone, Copy, Debug)]
pub struct MeasureConfig {
    pub warmup: usize,
    pub iters: usize,
}

impl MeasureConfig {
    /// Scale iterations down with world size to bound real wall time on the
    /// single-core host (documented deviation; virtual time is unaffected).
    pub fn auto(world: usize) -> MeasureConfig {
        let iters = (2000 / world.max(1)).clamp(5, 100);
        MeasureConfig { warmup: 2, iters }
    }

    pub fn fixed(iters: usize) -> MeasureConfig {
        MeasureConfig { warmup: 2, iters }
    }
}

/// Measure a collective operation's latency on a cluster.
///
/// `op(env, iter)` runs the operation under test once. Setup that should
/// not be timed (windows, parameter structures) belongs in `setup`, which
/// runs once per rank and may return state threaded into `op`.
pub fn measure_collective<S, F, G>(spec: ClusterSpec, cfg: MeasureConfig, setup: G, op: F) -> Summary
where
    S: 'static,
    G: Fn(&mut ProcEnv) -> S + Send + Sync + 'static,
    F: Fn(&mut ProcEnv, &mut S, usize) -> () + Send + Sync + 'static,
{
    let cluster = SimCluster::new(spec);
    let report = cluster.run(move |env| {
        let world = env.world();
        let mut st = setup(env);
        let total = cfg.warmup + cfg.iters;
        let mut elapsed = Vec::with_capacity(cfg.iters);
        for it in 0..total {
            env.harness_sync(&world);
            let t0 = env.vclock();
            op(env, &mut st, it);
            let dt = env.vclock() - t0;
            if it >= cfg.warmup {
                elapsed.push(dt);
            }
        }
        elapsed
    });
    // Per-iteration max across ranks, then summarize.
    let per_rank = report.outputs;
    let iters = per_rank[0].len();
    let mut maxima = Vec::with_capacity(iters);
    for i in 0..iters {
        maxima.push(per_rank.iter().map(|v| v[i]).fold(0.0, f64::max));
    }
    Summary::of(&maxima)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::Preset;
    use crate::mpi::USER_TAG_BASE;

    #[test]
    fn measures_a_pingpong_deterministically() {
        let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
        let s = measure_collective(
            spec,
            MeasureConfig { warmup: 1, iters: 5 },
            |_| (),
            |env, _, _| {
                let w = env.world();
                // rank 0 <-> rank 16 (cross-node) ping-pong
                if env.world_rank() == 0 {
                    env.send(&w, 16, USER_TAG_BASE, &[0u8; 1024]);
                    let _ = env.recv(&w, Some(16), USER_TAG_BASE + 1);
                } else if env.world_rank() == 16 {
                    let _ = env.recv(&w, Some(0), USER_TAG_BASE);
                    env.send(&w, 0, USER_TAG_BASE + 1, &[0u8; 1024]);
                }
            },
        );
        assert_eq!(s.n, 5);
        // Deterministic virtual time: zero variance across iterations.
        assert!(s.stddev < 1e-9, "stddev {}", s.stddev);
        // Two cross-node messages of 1 KiB: sanity-band the latency.
        assert!(s.mean > 2.0 && s.mean < 50.0, "mean {}", s.mean);
    }

    #[test]
    fn auto_config_bounds() {
        assert_eq!(MeasureConfig::auto(16).iters, 100);
        assert_eq!(MeasureConfig::auto(1024).iters, 5);
    }
}
