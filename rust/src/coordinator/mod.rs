//! Cluster coordination: presets, the thread-per-rank engine, the
//! OSU-style measurement harness, and report output.

pub mod engine;
pub mod harness;
pub mod report;
pub mod spec;

pub use engine::{RunReport, SimCluster};
pub use harness::{measure_collective, MeasureConfig};
pub use report::Table;
pub use spec::{ClusterSpec, Preset};
