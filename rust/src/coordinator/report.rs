//! Plain-text/markdown/CSV table output for the figure generators.

use std::fmt::Write as _;
use std::path::Path;

/// A titled table with named columns — one per paper table/figure series.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table { title: title.into(), headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Markdown rendering (also the `Display` form).
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                let _ = write!(s, " {c:<w$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &width));
        let mut sep = String::from("|");
        for w in &width {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &width));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write `<stem>.md` and `<stem>.csv` under `dir`, creating it.
    pub fn save(&self, dir: impl AsRef<Path>, stem: &str) -> std::io::Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_markdown())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Latency", &["size", "mpi (us)", "hybrid (us)"]);
        t.row(vec!["32".into(), "5.1".into(), "3.2".into()]);
        t.row(vec!["4096".into(), "55.0".into(), "21.9".into()]);
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("### Latency"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
        assert!(md.contains("| 32 "));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1,5".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"1,5\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        Table::new("x", &["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn save_writes_both_files() {
        let dir = std::env::temp_dir().join(format!("hympi-report-test-{}", std::process::id()));
        sample().save(&dir, "t").unwrap();
        assert!(dir.join("t.md").exists());
        assert!(dir.join("t.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
