//! Cluster specifications and the paper's testbed presets (§5.1).

use crate::mpi::fault::FaultPlan;
use crate::mpi::net::NetModel;
use crate::mpi::state::{Knobs, MgmtCosts};
use crate::mpi::topo::Placement;

/// The paper's experimental platforms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// NEC "Vulcan", SandyBridge partition: 16-core nodes, InfiniBand,
    /// Open MPI 4.0.1 (used for SUMMA and the 2D Poisson solver).
    VulcanSb,
    /// NEC "Vulcan", Haswell partition: 24-core nodes, InfiniBand
    /// (used for the micro-benchmarks of §5.2).
    VulcanHsw,
    /// Cray XC40 "Hazel Hen": 24-core Haswell nodes, Aries dragonfly,
    /// cray-mpich (used for BPMF and the §5.2.2/§5.2.4 comparisons).
    HazelHen,
}

impl Preset {
    pub fn cores_per_node(&self) -> usize {
        match self {
            Preset::VulcanSb => 16,
            Preset::VulcanHsw | Preset::HazelHen => 24,
        }
    }

    pub fn net(&self) -> NetModel {
        match self {
            Preset::VulcanSb | Preset::VulcanHsw => NetModel::infiniband(),
            Preset::HazelHen => NetModel::aries(),
        }
    }

    pub fn mgmt(&self) -> MgmtCosts {
        match self {
            Preset::VulcanSb | Preset::VulcanHsw => MgmtCosts::vulcan(),
            Preset::HazelHen => MgmtCosts::hazelhen(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Preset::VulcanSb => "vulcan-sb",
            Preset::VulcanHsw => "vulcan-hsw",
            Preset::HazelHen => "hazelhen",
        }
    }

    pub fn parse(s: &str) -> Option<Preset> {
        match s {
            "vulcan-sb" => Some(Preset::VulcanSb),
            "vulcan-hsw" => Some(Preset::VulcanHsw),
            "hazelhen" => Some(Preset::HazelHen),
            _ => None,
        }
    }
}

/// A concrete simulated cluster: node shapes + cost model + placement.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Ranks per node (node count = `nodes.len()`). Nodes may be
    /// irregularly populated (§5.2.2).
    pub nodes: Vec<usize>,
    pub net: NetModel,
    pub mgmt: MgmtCosts,
    pub placement: Placement,
    pub preset_name: &'static str,
    /// Behavioral knobs, gathered behind one struct ([`Knobs`]) so call
    /// sites stop churning every time a mode is added:
    ///
    /// - `compute_scale` — host-CPU-time → virtual-compute-time multiplier;
    /// - `legacy_dataplane` — emulate the pre-refactor allocating data
    ///   plane (no slab recycling, window materialization through copies;
    ///   identical virtual time, `bench_all` measures the wall-clock gap);
    /// - `legacy_fabric` — emulate the pre-PR3 mutex+condvar message
    ///   fabric (a conservative stand-in: identical messages, results and
    ///   virtual time; see
    ///   [`ClusterState::legacy_fabric`](crate::mpi::state::ClusterState));
    /// - `park_bound_us` — park-timeout bound for blocked rank threads
    ///   (wall-clock knob only; `None` keeps the auto-tuned default,
    ///   [`crate::mpi::sync::park_bound`]);
    /// - `fault` — deterministic fault-injection plan (skew, noise,
    ///   stragglers, dead ranks; [`FaultPlan`]).
    ///
    /// Prefer the chainable `with_*` builders over direct field pokes.
    pub knobs: Knobs,
}

impl ClusterSpec {
    /// `nnodes` fully-populated nodes of a preset platform.
    pub fn preset(p: Preset, nnodes: usize) -> ClusterSpec {
        assert!(nnodes > 0);
        ClusterSpec {
            nodes: vec![p.cores_per_node(); nnodes],
            net: p.net(),
            mgmt: p.mgmt(),
            placement: Placement::Block,
            preset_name: p.name(),
            knobs: Knobs::default(),
        }
    }

    /// Request `total` ranks on `per_node`-way partially-populated nodes
    /// of a preset platform — the §5.2.2 configuration where an
    /// application deliberately under-fills nodes (e.g. for memory per
    /// rank), leaving every node irregular relative to the hardware and
    /// the trailing node irregular relative to its siblings.
    pub fn preset_partial(p: Preset, total: usize, per_node: usize) -> ClusterSpec {
        assert!(total > 0 && per_node > 0 && per_node <= p.cores_per_node());
        let full = total / per_node;
        let rem = total % per_node;
        let mut nodes = vec![per_node; full];
        if rem > 0 {
            nodes.push(rem);
        }
        ClusterSpec { nodes, ..ClusterSpec::preset(p, 1) }
    }

    /// Request `total` ranks on a preset platform, filling whole nodes
    /// block-style — the Hazel Hen situation of §5.2.2: 24-core nodes and a
    /// power-of-two rank request leave the last node partially populated
    /// (an *irregular* problem for allgather).
    pub fn preset_total_ranks(p: Preset, total: usize) -> ClusterSpec {
        assert!(total > 0);
        let per = p.cores_per_node();
        let full = total / per;
        let rem = total % per;
        let mut nodes = vec![per; full];
        if rem > 0 {
            nodes.push(rem);
        }
        ClusterSpec { nodes, ..ClusterSpec::preset(p, 1) }
    }

    pub fn world_size(&self) -> usize {
        self.nodes.iter().sum()
    }

    pub fn nnodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn with_placement(mut self, placement: Placement) -> ClusterSpec {
        self.placement = placement;
        self
    }

    pub fn with_compute_scale(mut self, s: f64) -> ClusterSpec {
        self.knobs.compute_scale = s;
        self
    }

    pub fn with_legacy_dataplane(mut self, legacy: bool) -> ClusterSpec {
        self.knobs.legacy_dataplane = legacy;
        self
    }

    pub fn with_legacy_fabric(mut self, legacy: bool) -> ClusterSpec {
        self.knobs.legacy_fabric = legacy;
        self
    }

    pub fn with_park_bound_us(mut self, us: u64) -> ClusterSpec {
        self.knobs.park_bound_us = Some(us);
        self
    }

    /// Attach a deterministic fault-injection plan (skew, noise,
    /// stragglers, dead ranks — [`FaultPlan`]).
    pub fn with_faults(mut self, plan: FaultPlan) -> ClusterSpec {
        self.knobs.fault = Some(plan);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_shapes() {
        let s = ClusterSpec::preset(Preset::VulcanSb, 4);
        assert_eq!(s.world_size(), 64);
        assert_eq!(s.nnodes(), 4);
        let s = ClusterSpec::preset(Preset::HazelHen, 2);
        assert_eq!(s.world_size(), 48);
    }

    #[test]
    fn irregular_hazelhen_population() {
        // 256 ranks on 24-core nodes: 10 full nodes + one with 16.
        let s = ClusterSpec::preset_total_ranks(Preset::HazelHen, 256);
        assert_eq!(s.nnodes(), 11);
        assert_eq!(s.world_size(), 256);
        assert_eq!(*s.nodes.last().unwrap(), 16);
    }

    #[test]
    fn exact_fit_has_no_partial_node() {
        let s = ClusterSpec::preset_total_ranks(Preset::VulcanSb, 64);
        assert_eq!(s.nnodes(), 4);
        assert!(s.nodes.iter().all(|&c| c == 16));
    }

    #[test]
    fn partial_population_shapes() {
        // 512 ranks at 12 of 16 cores per VulcanSb node: 42 nodes of 12
        // plus a trailing node of 8 — every node partially populated.
        let s = ClusterSpec::preset_partial(Preset::VulcanSb, 512, 12);
        assert_eq!(s.world_size(), 512);
        assert_eq!(s.nnodes(), 43);
        assert!(s.nodes[..42].iter().all(|&c| c == 12));
        assert_eq!(*s.nodes.last().unwrap(), 8);
        assert!(s.knobs.park_bound_us.is_none(), "auto park bound by default");
        assert_eq!(s.with_park_bound_us(250).knobs.park_bound_us, Some(250));
    }

    #[test]
    fn knob_builders_compose() {
        let s = ClusterSpec::preset(Preset::VulcanSb, 2)
            .with_compute_scale(2.0)
            .with_legacy_fabric(true)
            .with_faults(FaultPlan::seeded(7).with_skew(0.1).with_dead(3, 500.0));
        assert_eq!(s.knobs.compute_scale, 2.0);
        assert!(s.knobs.legacy_fabric && !s.knobs.legacy_dataplane);
        let f = s.knobs.fault.as_ref().unwrap();
        assert_eq!(f.seed, 7);
        assert_eq!(f.dead, vec![(3, 500.0)]);
    }

    #[test]
    fn preset_roundtrip_names() {
        for p in [Preset::VulcanSb, Preset::VulcanHsw, Preset::HazelHen] {
            assert_eq!(Preset::parse(p.name()), Some(p));
        }
        assert_eq!(Preset::parse("nonesuch"), None);
    }
}
