//! Shared measurement drivers for the micro-benchmark figures
//! (Figs. 12–16): paired pure-MPI vs hybrid-MPI+MPI collective latency on
//! a given cluster spec, OSU-style.
//!
//! Every driver goes through the persistent-collective engine
//! ([`crate::coll::PlanCache`]): the plan — communicator splits, shared
//! window, translation tables, recvcounts/displs, tuned-algorithm
//! resolution — is built once in the harness setup phase (the paper's
//! Table-2 one-offs, excluded from §5.2.2–§5.2.4 latency numbers), and
//! the timed operation is pure plan execution.

use crate::coll::{CollOp, Flavor, PlanCache};
use crate::coordinator::{measure_collective, ClusterSpec, MeasureConfig};
use crate::hybrid::{AllreduceMethod, HyColl, HybridCtx, LeaderPolicy, RootPolicy, SyncScheme};
use crate::mpi::{Datatype, ReduceOp};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn cfg_for(spec: &ClusterSpec, fast: bool) -> MeasureConfig {
    let mut c = MeasureConfig::auto(spec.world_size());
    if fast {
        c.iters = c.iters.min(5);
    }
    c
}

/// Measurement state: the plan cache plus the operand buffers.
struct St {
    cache: PlanCache,
    data: Vec<u8>,
    out: Vec<u8>,
}

/// One microbench measurement plus engine statistics (plan-cache counters
/// read from rank 0's cache after the last iteration — cache
/// effectiveness is part of every reported run).
pub struct DriveReport {
    /// Mean modeled latency (µs; per-iteration max across ranks).
    pub mean_us: f64,
    /// Rank 0's plan-cache hits (executions that reused a plan).
    pub plan_hits: u64,
    /// Rank 0's plan-cache misses (plans built — 1 in steady state).
    pub plan_misses: u64,
}

/// Generic driver: build the plan for `(op, flavor)` in setup, execute it
/// per iteration.
fn drive(spec: ClusterSpec, fast: bool, op: CollOp, bytes: usize, flavor: Flavor) -> f64 {
    drive_report(spec, fast, op, bytes, flavor).mean_us
}

/// [`drive`] with the plan-cache statistics included.
pub fn drive_report(
    spec: ClusterSpec,
    fast: bool,
    op: CollOp,
    bytes: usize,
    flavor: Flavor,
) -> DriveReport {
    let cfg = cfg_for(&spec, fast);
    let world = spec.world_size();
    let hits = Arc::new(AtomicU64::new(0));
    let misses = Arc::new(AtomicU64::new(0));
    let (hits2, misses2) = (hits.clone(), misses.clone());
    let summary = measure_collective(
        spec,
        cfg,
        move |env| {
            let w = env.world();
            let mut cache = PlanCache::new();
            let (dtype, rop) = match op {
                CollOp::Allreduce | CollOp::ReduceScatter | CollOp::Reduce => {
                    (Datatype::F64, Some(ReduceOp::Sum))
                }
                _ => (Datatype::U8, None),
            };
            let count = match op {
                // `bytes` is the full-vector size for the reduce family.
                CollOp::ReduceScatter => ((bytes / world).max(8)) / 8 * 8,
                CollOp::Allreduce | CollOp::Reduce => (bytes - bytes % 8).max(8),
                _ => bytes,
            };
            cache.plan(env, &w, op, count, dtype, rop, flavor);
            let (send_len, out_len) = match op {
                CollOp::Allgather | CollOp::Gather => (count, count * world),
                CollOp::Bcast => (count, 0),
                CollOp::Allreduce => (count, 0),
                CollOp::ReduceScatter => (count * world, count),
                CollOp::Scatter => (count * world, count),
                CollOp::Reduce => (count, count),
            };
            St { cache, data: vec![1u8; send_len], out: vec![0u8; out_len] }
        },
        move |env, st, _| {
            let w = env.world();
            match op {
                CollOp::Allgather => {
                    let recv = match flavor {
                        // Hybrid: result stays in the shared window — the
                        // paper's benchmark measures store + collective.
                        Flavor::Hybrid { .. } => None,
                        _ => Some(&mut st.out[..]),
                    };
                    st.cache.allgather(env, &w, flavor, &st.data, recv);
                }
                CollOp::Bcast => {
                    let root = 0;
                    let len = st.data.len();
                    let buf = if w.rank() == root || !matches!(flavor, Flavor::Hybrid { .. }) {
                        Some(&mut st.data[..])
                    } else {
                        // Hybrid children read the shared copy in place.
                        None
                    };
                    st.cache.bcast(env, &w, flavor, root, len, buf);
                }
                CollOp::Allreduce => {
                    // Window-backed plans leave the result in slot G (the
                    // §4.4 in-place sharing the paper's benchmark times);
                    // pure plans reduce in place either way.
                    st.cache.allreduce_windowed(
                        env, &w, flavor, Datatype::F64, ReduceOp::Sum, &mut st.data,
                    );
                }
                CollOp::ReduceScatter => {
                    st.cache.reduce_scatter(
                        env, &w, flavor, Datatype::F64, ReduceOp::Sum, &st.data, &mut st.out,
                    );
                }
                CollOp::Gather => {
                    let recv = (w.rank() == 0).then_some(&mut st.out[..]);
                    st.cache.gather(env, &w, flavor, 0, &st.data, recv);
                }
                CollOp::Scatter => {
                    let send = (w.rank() == 0).then_some(&st.data[..]);
                    st.cache.scatter(env, &w, flavor, 0, send, &mut st.out);
                }
                CollOp::Reduce => {
                    let recv = (w.rank() == 0).then_some(&mut st.out[..]);
                    st.cache.reduce(
                        env, &w, flavor, Datatype::F64, ReduceOp::Sum, 0, &st.data, recv,
                    );
                }
            }
            if env.world_rank() == 0 {
                hits2.store(st.cache.hits(), Ordering::Relaxed);
                misses2.store(st.cache.misses(), Ordering::Relaxed);
            }
        },
    );
    DriveReport {
        mean_us: summary.mean,
        plan_hits: hits.load(Ordering::Relaxed),
        plan_misses: misses.load(Ordering::Relaxed),
    }
}

/// Pure `MPI_Bcast` latency (tuned algorithm), root 0, `bytes` payload.
pub fn pure_bcast(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Bcast, bytes, Flavor::Pure)
}

/// `Wrapper_Hy_Bcast` latency (excludes the one-off wrapper setup, as the
/// paper's §5.2.2–§5.2.4 measurements do; Table 2 reports the one-offs).
pub fn hy_bcast(spec: ClusterSpec, bytes: usize, scheme: SyncScheme, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Bcast, bytes, Flavor::hybrid(scheme))
}

/// Pure `MPI_Allgather` latency, `bytes` per rank.
pub fn pure_allgather(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Allgather, bytes, Flavor::Pure)
}

/// `Wrapper_Hy_Allgather` latency (store + collective, per the paper's
/// benchmark in Fig. 5).
pub fn hy_allgather(spec: ClusterSpec, bytes: usize, scheme: SyncScheme, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Allgather, bytes, Flavor::hybrid(scheme))
}

/// Pure `MPI_Allreduce` latency (tuned), `bytes` payload (f64 sum).
pub fn pure_allreduce(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Allreduce, bytes, Flavor::Pure)
}

/// `Wrapper_Hy_Allreduce` latency with an explicit method/sync choice.
pub fn hy_allreduce(
    spec: ClusterSpec,
    bytes: usize,
    method: AllreduceMethod,
    scheme: SyncScheme,
    fast: bool,
) -> f64 {
    drive(spec, fast, CollOp::Allreduce, bytes, Flavor::Hybrid { scheme, method, leaders: 1 })
}

/// Hybrid allgather latency at `leaders` leaders per node (the
/// arXiv 2007.06892 multi-leader bridge; `leaders = 1` reproduces
/// [`hy_allgather`] exactly).
pub fn hy_allgather_k(
    spec: ClusterSpec,
    bytes: usize,
    scheme: SyncScheme,
    leaders: usize,
    fast: bool,
) -> f64 {
    drive(spec, fast, CollOp::Allgather, bytes, Flavor::hybrid_k(scheme, leaders))
}

/// Hybrid allreduce latency at `leaders` leaders per node.
pub fn hy_allreduce_k(
    spec: ClusterSpec,
    bytes: usize,
    scheme: SyncScheme,
    leaders: usize,
    fast: bool,
) -> f64 {
    drive(spec, fast, CollOp::Allreduce, bytes, Flavor::hybrid_k(scheme, leaders))
}

/// Split-phase overlap micro-probe (DESIGN.md §5e): one `bytes`-byte
/// hybrid broadcast from a [`RootPolicy::Fixed`] root with `depth`
/// pipelined bridge chunks, against `compute_us` of modeled per-rank
/// compute. Returns `(blocking_us, split_us)` per iteration: the
/// blocking leg completes the broadcast *then* computes (`start; wait;
/// compute`); the split leg computes between `start` and `wait`, so the
/// root-side chunks injected inside `start` and the release flag overlap
/// the compute — `split ≤ blocking` always, strictly below once the
/// bridge has anything to hide.
pub fn overlap_probe(
    spec: ClusterSpec,
    bytes: usize,
    compute_us: f64,
    depth: usize,
    fast: bool,
) -> (f64, f64) {
    struct St {
        h: HyColl,
        data: Vec<u8>,
    }
    let leg = |spec: ClusterSpec, split: bool| {
        let cfg = cfg_for(&spec, fast);
        measure_collective(
            spec,
            cfg,
            move |env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
                let h = ctx.bcast_init_split(env, bytes, SyncScheme::Spin, RootPolicy::Fixed(0), depth);
                St { h, data: vec![0xA5u8; bytes] }
            },
            move |env, st, _| {
                let w = env.world();
                let arg = (w.rank() == 0).then_some(&st.data[..]);
                if split {
                    st.h.start_bcast(env, 0, arg);
                    env.compute(compute_us);
                    st.h.wait(env);
                } else {
                    st.h.start_bcast(env, 0, arg);
                    st.h.wait(env);
                    env.compute(compute_us);
                }
            },
        )
        .mean
    };
    (leg(spec.clone(), false), leg(spec, true))
}

/// Pure ring reduce-scatter latency; `bytes` = full input vector.
pub fn pure_reduce_scatter(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    drive(spec, fast, CollOp::ReduceScatter, bytes, Flavor::Pure)
}

/// `Wrapper_Hy_Reduce_scatter` latency; `bytes` = full input vector.
pub fn hy_reduce_scatter(spec: ClusterSpec, bytes: usize, scheme: SyncScheme, fast: bool) -> f64 {
    drive(spec, fast, CollOp::ReduceScatter, bytes, Flavor::hybrid(scheme))
}

/// Pure binomial gather latency, `bytes` per rank, root 0.
pub fn pure_gather(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Gather, bytes, Flavor::Pure)
}

/// `Wrapper_Hy_Gather` latency, `bytes` per rank, root 0.
pub fn hy_gather(spec: ClusterSpec, bytes: usize, scheme: SyncScheme, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Gather, bytes, Flavor::hybrid(scheme))
}

/// Pure binomial scatter latency, `bytes` per rank, root 0.
pub fn pure_scatter(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Scatter, bytes, Flavor::Pure)
}

/// `Wrapper_Hy_Scatter` latency, `bytes` per rank, root 0.
pub fn hy_scatter(spec: ClusterSpec, bytes: usize, scheme: SyncScheme, fast: bool) -> f64 {
    drive(spec, fast, CollOp::Scatter, bytes, Flavor::hybrid(scheme))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Preset;

    #[test]
    fn hybrid_beats_pure_on_the_headline_points() {
        // Fig. 12 (allgather 800 B) and Fig. 13 (bcast 512 KB) at 2 nodes.
        let spec = || ClusterSpec::preset(Preset::HazelHen, 2);
        let pure = pure_allgather(spec(), 800, true);
        let hy = hy_allgather(spec(), 800, SyncScheme::Spin, true);
        assert!(hy < pure, "allgather: hybrid {hy} vs pure {pure}");
        let pure = pure_bcast(spec(), 512 * 1024, true);
        let hy = hy_bcast(spec(), 512 * 1024, SyncScheme::Spin, true);
        assert!(hy < pure, "bcast: hybrid {hy} vs pure {pure}");
    }

    #[test]
    fn drive_report_surfaces_cache_stats() {
        let spec = ClusterSpec::preset(Preset::VulcanSb, 2);
        let r = drive_report(spec, true, CollOp::Allgather, 256, Flavor::Pure);
        assert_eq!(r.plan_misses, 1, "one plan built");
        assert!(r.plan_hits >= 5, "every later iteration reused it (got {})", r.plan_hits);
        assert!(r.mean_us > 0.0);
    }

    #[test]
    fn two_leaders_beat_one_at_256kib_node_blocks() {
        // The PR-4 acceptance bound through the figure driver: 16 KiB per
        // rank on 16-rank nodes = 256 KiB bridge blocks; k = 2 must be
        // strictly faster in modeled vtime, and k = 1 must be identical
        // to the plain hybrid driver.
        let spec = || ClusterSpec::preset(Preset::VulcanSb, 2);
        let one = hy_allgather_k(spec(), 16 * 1024, SyncScheme::Spin, 1, true);
        let two = hy_allgather_k(spec(), 16 * 1024, SyncScheme::Spin, 2, true);
        assert!(two < one, "k=2 ({two}) must beat k=1 ({one})");
        let parity = hy_allgather(spec(), 16 * 1024, SyncScheme::Spin, true);
        assert!((one - parity).abs() < 1e-9, "k=1 ({one}) must equal the 1-leader driver ({parity})");
        // Same bound for the allreduce family: a 256 KiB operand is deep
        // in the method-1 regime, where the bridge exchange (and the
        // L→G move) stripes across the leader set.
        let ar1 = hy_allreduce_k(spec(), 256 * 1024, SyncScheme::Spin, 1, true);
        let ar2 = hy_allreduce_k(spec(), 256 * 1024, SyncScheme::Spin, 2, true);
        assert!(ar2 < ar1, "allreduce k=2 ({ar2}) must beat k=1 ({ar1}) at 256 KiB");
    }

    #[test]
    fn new_ops_have_sane_latencies() {
        let spec = || ClusterSpec::preset(Preset::VulcanSb, 2);
        for (pure, hy) in [
            (pure_reduce_scatter(spec(), 64 * 1024, true), hy_reduce_scatter(spec(), 64 * 1024, SyncScheme::Spin, true)),
            (pure_gather(spec(), 800, true), hy_gather(spec(), 800, SyncScheme::Spin, true)),
            (pure_scatter(spec(), 800, true), hy_scatter(spec(), 800, SyncScheme::Spin, true)),
        ] {
            assert!(pure > 0.0 && hy > 0.0);
            assert!(pure.is_finite() && hy.is_finite());
        }
    }
}
