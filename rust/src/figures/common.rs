//! Shared measurement drivers for the micro-benchmark figures
//! (Figs. 12–16): paired pure-MPI vs hybrid-MPI+MPI collective latency on
//! a given cluster spec, OSU-style.

use crate::coll;
use crate::coordinator::{measure_collective, ClusterSpec, MeasureConfig};
use crate::hybrid::{self, AllreduceMethod, CommPackage, HyWin, SyncScheme, TransTables};
use crate::mpi::{Datatype, ReduceOp};

fn cfg_for(spec: &ClusterSpec, fast: bool) -> MeasureConfig {
    let mut c = MeasureConfig::auto(spec.world_size());
    if fast {
        c.iters = c.iters.min(5);
    }
    c
}

/// Pure `MPI_Bcast` latency (tuned algorithm), root 0, `bytes` payload.
pub fn pure_bcast(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    let cfg = cfg_for(&spec, fast);
    measure_collective(
        spec,
        cfg,
        move |_| vec![0u8; bytes],
        move |env, buf, _| {
            let w = env.world();
            coll::bcast(env, &w, 0, buf, coll::BcastAlgo::Auto);
        },
    )
    .mean
}

/// `Wrapper_Hy_Bcast` latency (excludes the one-off wrapper setup, as the
/// paper's §5.2.2–§5.2.4 measurements do; Table 2 reports the one-offs).
pub fn hy_bcast(spec: ClusterSpec, bytes: usize, scheme: SyncScheme, fast: bool) -> f64 {
    let cfg = cfg_for(&spec, fast);
    struct St {
        pkg: CommPackage,
        win: HyWin,
        tables: TransTables,
        data: Vec<u8>,
    }
    measure_collective(
        spec,
        cfg,
        move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let win = pkg.alloc_shared(env, bytes, 1, 1);
            let tables = TransTables::create(env, &pkg);
            St { pkg, win, tables, data: vec![7u8; bytes] }
        },
        move |env, st, _| {
            let root = 0;
            let arg = (env.world().rank() == root).then_some(&st.data[..]);
            hybrid::hy_bcast(env, &st.pkg, &mut st.win, &st.tables, root, arg, bytes, scheme);
        },
    )
    .mean
}

/// Pure `MPI_Allgather` latency, `bytes` per rank.
pub fn pure_allgather(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    let cfg = cfg_for(&spec, fast);
    let world = spec.world_size();
    measure_collective(
        spec,
        cfg,
        move |_| (vec![1u8; bytes], vec![0u8; bytes * world]),
        move |env, (mine, out), _| {
            let w = env.world();
            coll::allgather(env, &w, mine, out, coll::AllgatherAlgo::Auto);
        },
    )
    .mean
}

/// `Wrapper_Hy_Allgather` latency (store + collective, per the paper's
/// benchmark in Fig. 5).
pub fn hy_allgather(spec: ClusterSpec, bytes: usize, scheme: SyncScheme, fast: bool) -> f64 {
    let cfg = cfg_for(&spec, fast);
    struct St {
        pkg: CommPackage,
        win: HyWin,
        param: hybrid::AllgatherParam,
        data: Vec<u8>,
    }
    measure_collective(
        spec,
        cfg,
        move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let win = pkg.alloc_shared(env, bytes, 1, w.size());
            let sizeset = hybrid::sizeset_gather(env, &pkg);
            let param = hybrid::AllgatherParam::create(env, &pkg, bytes, &sizeset);
            St { pkg, win, param, data: vec![3u8; bytes] }
        },
        move |env, st, _| {
            let off = st.win.local_ptr(env.world().rank(), bytes);
            st.win.store(env, off, &st.data);
            hybrid::hy_allgather(env, &st.pkg, &mut st.win, &st.param, bytes, scheme);
        },
    )
    .mean
}

/// Pure `MPI_Allreduce` latency (tuned), `bytes` payload (f64 sum).
pub fn pure_allreduce(spec: ClusterSpec, bytes: usize, fast: bool) -> f64 {
    let cfg = cfg_for(&spec, fast);
    measure_collective(
        spec,
        cfg,
        move |_| vec![1u8; bytes - bytes % 8],
        move |env, buf, _| {
            let w = env.world();
            coll::allreduce(env, &w, Datatype::F64, ReduceOp::Sum, buf, coll::AllreduceAlgo::Auto);
        },
    )
    .mean
}

/// `Wrapper_Hy_Allreduce` latency with an explicit method/sync choice.
pub fn hy_allreduce(
    spec: ClusterSpec,
    bytes: usize,
    method: AllreduceMethod,
    scheme: SyncScheme,
    fast: bool,
) -> f64 {
    let cfg = cfg_for(&spec, fast);
    let bytes = bytes - bytes % 8;
    struct St {
        pkg: CommPackage,
        win: HyWin,
        data: Vec<u8>,
    }
    measure_collective(
        spec,
        cfg,
        move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let win = hybrid::allreduce::alloc_allreduce_win(env, &pkg, bytes);
            St { pkg, win, data: vec![1u8; bytes] }
        },
        move |env, st, _| {
            let off = st.win.local_ptr(st.pkg.shmem.rank(), bytes);
            st.win.store(env, off, &st.data);
            hybrid::hy_allreduce(
                env,
                &st.pkg,
                &mut st.win,
                Datatype::F64,
                ReduceOp::Sum,
                bytes,
                method,
                scheme,
            );
        },
    )
    .mean
}
