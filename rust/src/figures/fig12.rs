//! Fig. 12 — `Wrapper_Hy_Allgather` vs `MPI_Allgather` on Hazel Hen,
//! 2–32 nodes × 24 ranks, 800 B gathered from every process.

use super::common;
use super::{us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::hybrid::SyncScheme;

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 12 — allgather latency, Hazel Hen, 24 ranks/node, 800 B/rank (us)",
        &["nodes", "ranks", "MPI_Allgather", "Wrapper_Hy_Allgather", "hybrid wins"],
    );
    let node_counts: &[usize] = if opts.fast { &[2, 4] } else { &[2, 4, 8, 16, 32] };
    for &nodes in node_counts {
        let spec = || ClusterSpec::preset(Preset::HazelHen, nodes);
        let pure = common::pure_allgather(spec(), 800, opts.fast);
        let hy = common::hy_allgather(spec(), 800, SyncScheme::Spin, opts.fast);
        t.row(vec![
            nodes.to_string(),
            (nodes * 24).to_string(),
            us(pure),
            us(hy),
            (hy < pure).to_string(),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_wins_at_every_node_count() {
        // The paper's Fig. 12 claim: constant lower latencies for the
        // hybrid allgather. Checked at the two cheapest points.
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        for row in &t.rows {
            assert_eq!(row[4], "true", "hybrid must win at {} nodes", row[0]);
        }
    }
}
