//! Fig. 13 — `Wrapper_Hy_Bcast` vs `MPI_Bcast` on Vulcan: 16/64/256/1024
//! cores × {32 B, 4 KB, 128 KB, 512 KB} (2², 2⁹, 2¹⁴, 2¹⁶ doubles).
//!
//! The published shape: hybrid wins everywhere except small messages on
//! few cores (sync overhead dominates the tiny transfer); the 512 KB
//! column sits below the extrapolated trend because the tuned broadcast
//! switches algorithm above ~362 KB (§5.2.3).

use super::common;
use super::{us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::hybrid::SyncScheme;

pub const SIZES: [usize; 4] = [32, 4 * 1024, 128 * 1024, 512 * 1024];

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 13 — broadcast latency, Vulcan (us)",
        &["cores", "bytes", "MPI_Bcast", "Wrapper_Hy_Bcast", "hybrid wins"],
    );
    let cores: &[usize] = if opts.fast { &[16, 64] } else { &[16, 64, 256, 1024] };
    for &c in cores {
        for &bytes in &SIZES {
            let spec = || ClusterSpec::preset(Preset::VulcanSb, c / 16);
            let pure = common::pure_bcast(spec(), bytes, opts.fast);
            // The Fig. 13 variant uses the barrier sync (§5.2.3: "the
            // current version of Wrapper_Hy_Bcast replaces the
            // synchronization point with a barrier operation").
            let hy = common::hy_bcast(spec(), bytes, SyncScheme::Barrier, opts.fast);
            t.row(vec![c.to_string(), bytes.to_string(), us(pure), us(hy), (hy < pure).to_string()]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_wins_for_medium_and_large() {
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        for row in &t.rows {
            let bytes: usize = row[1].parse().unwrap();
            let cores: usize = row[0].parse().unwrap();
            if bytes >= 4 * 1024 && cores > 16 {
                assert_eq!(row[4], "true", "hybrid must win at {cores} cores / {bytes} B");
            }
        }
    }

    #[test]
    fn single_node_hybrid_bcast_is_flat_in_size() {
        // §5.2.3: on 16 cores (one node) the hybrid broadcast is just a
        // store + sync; latency nearly constant across message sizes.
        let spec = || ClusterSpec::preset(Preset::VulcanSb, 1);
        let small = common::hy_bcast(spec(), 32, SyncScheme::Barrier, true);
        let large = common::hy_bcast(spec(), 512 * 1024, SyncScheme::Barrier, true);
        // A 16384x size increase should cost well under 100x (the paper
        // shows an almost flat line; ours grows only by the root's store).
        assert!(large < small * 100.0, "small {small} large {large}");
    }
}
