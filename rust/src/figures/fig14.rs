//! Fig. 14 — `Wrapper_Hy_Allreduce` vs `MPI_Allreduce` on Vulcan:
//! 16/64/256/1024 cores × {32 B, 4 KB, 256 KB, 1 MB}.
//!
//! This is the *initial* hybrid version of §5.2.4: step 1 = method 1
//! (`MPI_Reduce`), step-2 sync = barrier. The published speedups range
//! 27.2–82.5% except small messages on 16 cores.

use super::common;
use super::{pct, us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::hybrid::{AllreduceMethod, SyncScheme};

pub const SIZES: [usize; 4] = [32, 4 * 1024, 256 * 1024, 1024 * 1024];

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 14 — allreduce latency, Vulcan, hybrid = method 1 + barrier (us)",
        &["cores", "bytes", "MPI_Allreduce", "Wrapper_Hy_Allreduce", "speedup"],
    );
    let cores: &[usize] = if opts.fast { &[16, 64] } else { &[16, 64, 256, 1024] };
    for &c in cores {
        for &bytes in &SIZES {
            let spec = || ClusterSpec::preset(Preset::VulcanSb, c / 16);
            let pure = common::pure_allreduce(spec(), bytes, opts.fast);
            let hy = common::hy_allreduce(
                spec(),
                bytes,
                AllreduceMethod::Method1,
                SyncScheme::Barrier,
                opts.fast,
            );
            t.row(vec![
                c.to_string(),
                bytes.to_string(),
                us(pure),
                us(hy),
                pct((pure - hy) / pure * 100.0),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_wins_beyond_small_single_node() {
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        for row in &t.rows {
            let cores: usize = row[0].parse().unwrap();
            let bytes: usize = row[1].parse().unwrap();
            let pure: f64 = row[2].parse().unwrap();
            let hy: f64 = row[3].parse().unwrap();
            // §5.2.4: "our allreduce fails to significantly outperform the
            // standard one for small messages on 16 cores. Otherwise,
            // speedups ... can be achieved anywhere."
            if cores > 16 && bytes > 32 {
                assert!(hy < pure, "{cores} cores {bytes} B: hy {hy} pure {pure}");
            }
        }
    }
}
