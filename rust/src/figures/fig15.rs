//! Fig. 15 — `Hy-allreduce1` vs `Hy-allreduce2` vs `MPI_Allreduce` for
//! small messages (8 B – 8 KB) on one 16-core node, Vulcan and Hazel Hen.
//! The published cutoff between the two step-1 methods is 2 KB.

use super::common;
use super::{us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::hybrid::{AllreduceMethod, SyncScheme};

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut out = Vec::new();
    for preset in [Preset::VulcanSb, Preset::HazelHen] {
        let mut t = Table::new(
            format!("Fig. 15 — step-1 method cutoff, single node (16 cores), {} (us)", preset.name()),
            &["bytes", "MPI_Allreduce", "Hy-allreduce1", "Hy-allreduce2", "method2 wins"],
        );
        let mut bytes = 8usize;
        while bytes <= 8 * 1024 {
            let spec = || {
                let mut s = ClusterSpec::preset(preset, 1);
                s.nodes = vec![16]; // 16 ranks on one node on both machines
                s
            };
            let pure = common::pure_allreduce(spec(), bytes, opts.fast);
            let m1 = common::hy_allreduce(spec(), bytes, AllreduceMethod::Method1, SyncScheme::Spin, opts.fast);
            let m2 = common::hy_allreduce(spec(), bytes, AllreduceMethod::Method2, SyncScheme::Spin, opts.fast);
            t.row(vec![bytes.to_string(), us(pure), us(m1), us(m2), (m2 < m1).to_string()]);
            bytes *= 2;
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method2_wins_small_method1_wins_large() {
        let opts = FigOpts { fast: true, ..Default::default() };
        for t in generate(&opts) {
            let row8 = &t.rows[0]; // 8 B
            let row8k = t.rows.last().unwrap(); // 8 KB
            assert_eq!(row8[4], "true", "method 2 must win at 8 B ({})", t.title);
            assert_eq!(row8k[4], "false", "method 1 must win at 8 KB ({})", t.title);
        }
    }

    #[test]
    fn cutoff_lies_between_512b_and_8kb() {
        // The crossover (paper: 2 KB) must exist and sit in the plausible
        // band — the model is calibrated, not hand-placed per point.
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        let mut crossover = None;
        for row in &t.rows {
            if row[4] == "false" {
                crossover = Some(row[0].parse::<usize>().unwrap());
                break;
            }
        }
        let c = crossover.expect("a crossover must exist");
        assert!((512..=8192).contains(&c), "crossover at {c} B");
    }
}
