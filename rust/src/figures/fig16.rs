//! Fig. 16 — performance gap between `MPI_Allreduce` and the *optimized*
//! `Wrapper_Hy_Allreduce` (tuned method + spinning sync) on Hazel Hen at
//! 64/256/1024 cores. Positive gap = hybrid slower. The published shape:
//! the standard allreduce still wins slightly at 8 B and 32 B; the gap
//! turns negative from 128 B on.

use super::common;
use super::{pct, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::hybrid::{AllreduceMethod, SyncScheme};

pub const SIZES: [usize; 6] = [8, 32, 128, 512, 2 * 1024, 8 * 1024];

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 16 — gap = (hybrid − MPI)/MPI, optimized allreduce, Hazel Hen",
        &["cores", "8B", "32B", "128B", "512B", "2KB", "8KB"],
    );
    let cores: &[usize] = if opts.fast { &[64] } else { &[64, 256, 1024] };
    for &c in cores {
        let mut cells = vec![c.to_string()];
        for &bytes in &SIZES {
            // Hazel Hen 24-core nodes at power-of-two core counts leave the
            // last node partially populated (§5.2.2's irregular layout).
            let spec = || ClusterSpec::preset_total_ranks(Preset::HazelHen, c);
            let pure = common::pure_allreduce(spec(), bytes, opts.fast);
            let hy = common::hy_allreduce(spec(), bytes, AllreduceMethod::Tuned, SyncScheme::Spin, opts.fast);
            cells.push(pct((hy - pure) / pure * 100.0));
        }
        t.row(cells);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gap_turns_negative_for_larger_messages() {
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        let row = &t.rows[0]; // 64 cores
        let parse = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        // From 512 B on, the hybrid must win (paper: from 128 B on; we
        // allow one octave of calibration slack at the boundary).
        assert!(parse(&row[4]) < 0.0, "512 B gap {}", row[4]);
        assert!(parse(&row[5]) < 0.0, "2 KB gap {}", row[5]);
        assert!(parse(&row[6]) < 0.0, "8 KB gap {}", row[6]);
    }
}
