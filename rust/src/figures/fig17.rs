//! Fig. 17 — SUMMA, three implementations on Vulcan (SB nodes):
//! 1024²/16 cores/1 node, 2048²/64 cores/4 nodes, 4096²/256 cores/16
//! nodes (512 KB-class broadcasts). Published hybrid-vs-pure improvements:
//! 3%, 6%, 10%.

use super::{pct, us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::kernels::summa::{run, SummaCfg};
use crate::kernels::{Backend, Variant};

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 17 — SUMMA core-phase time on Vulcan (us; total = comp + bcast)",
        &["n", "cores", "variant", "comp", "bcast", "total", "vs pure"],
    );
    let configs: &[(usize, usize)] = if opts.fast {
        &[(256, 16), (512, 64)]
    } else {
        &[(1024, 16), (2048, 64), (4096, 256)]
    };
    for &(n_paper, cores) in configs {
        let n = ((n_paper as f64 * opts.scale) as usize).max(64).next_multiple_of(64);
        let nodes = cores / 16;
        let mut pure_total = 0.0;
        for variant in [Variant::PureMpi, Variant::HybridMpiMpi, Variant::MpiOpenMp] {
            let spec = if variant == Variant::MpiOpenMp {
                // One rank per node, 16 OpenMP threads each.
                let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes);
                s.nodes = vec![1; nodes];
                s
            } else {
                ClusterSpec::preset(Preset::VulcanSb, nodes)
            };
            // The MPI+OpenMP grid must also be square.
            let grid_ok = {
                let p = spec.world_size();
                let q = (p as f64).sqrt().round() as usize;
                q * q == p && n % q == 0
            };
            if !grid_ok {
                continue;
            }
            // Deterministic modeled compute: every variant is charged the
            // same flop model (the paper's equal-parallelism premise) and
            // host scheduling noise cannot leak into the comparison. Real
            // compute still runs (checksums stay validated); the PJRT path
            // is exercised by the e2e examples and runtime tests.
            let backend = Backend::Modeled;
            let rep = run(spec, SummaCfg { n, variant, backend, threads: 16 });
            if variant == Variant::PureMpi {
                pure_total = rep.total_us;
            }
            let improv = (pure_total - rep.total_us) / pure_total * 100.0;
            t.row(vec![
                n.to_string(),
                cores.to_string(),
                variant.name().to_string(),
                us(rep.comp_us),
                us(rep.comm_us),
                us(rep.total_us),
                if variant == Variant::PureMpi { "-".into() } else { pct(improv) },
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_beats_pure_in_fast_mode() {
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        // Group rows by (n, cores); hybrid total < pure total.
        let mut pure = std::collections::HashMap::new();
        for row in &t.rows {
            let key = (row[0].clone(), row[1].clone());
            let total: f64 = row[5].parse().unwrap();
            match row[2].as_str() {
                "pure-mpi" => {
                    pure.insert(key, total);
                }
                "mpi+mpi" => {
                    let p = pure[&key];
                    assert!(total < p, "hybrid {total} must beat pure {p} at {key:?}");
                }
                _ => {}
            }
        }
    }
}
