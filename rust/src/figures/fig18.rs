//! Fig. 18 — 2D Poisson solver, three implementations on Vulcan (SB):
//! 256²/16 cores/1 node, 512²/64 cores/4 nodes, 1024²/256 cores/16 nodes.
//! The allreduce operand is always 8 B (the global max delta); published
//! hybrid-vs-pure improvements: 2%, 1%, 10%.

use super::{pct, us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::kernels::poisson::{run, PoissonCfg};
use crate::kernels::{Backend, Variant};

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        "Fig. 18 — Poisson solver time on Vulcan (us; total = comp + allreduce)",
        &["grid", "cores", "variant", "comp", "allreduce", "total", "iters", "vs pure"],
    );
    let configs: &[(usize, usize)] = if opts.fast {
        &[(64, 16), (128, 64)]
    } else {
        &[(256, 16), (512, 64), (1024, 256)]
    };
    for &(n, cores) in configs {
        let nodes = cores / 16;
        let max_iters = if opts.fast { 40 } else { 200 };
        let mut pure_total = 0.0;
        for variant in [Variant::PureMpi, Variant::HybridMpiMpi, Variant::MpiOpenMp] {
            let spec = if variant == Variant::MpiOpenMp {
                let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes);
                s.nodes = vec![1; nodes];
                s
            } else {
                ClusterSpec::preset(Preset::VulcanSb, nodes)
            };
            if n % spec.world_size() != 0 {
                continue;
            }
            // Deterministic modeled compute — see fig17.rs.
            let backend = Backend::Modeled;
            let cfg = PoissonCfg { n, tol: 1e-4, max_iters, variant, backend, threads: 16 };
            let rep = run(spec, cfg);
            if variant == Variant::PureMpi {
                pure_total = rep.total_us;
            }
            let improv = (pure_total - rep.total_us) / pure_total * 100.0;
            t.row(vec![
                format!("{n}x{n}"),
                cores.to_string(),
                variant.name().to_string(),
                us(rep.comp_us),
                us(rep.comm_us),
                us(rep.total_us),
                rep.iters.to_string(),
                if variant == Variant::PureMpi { "-".into() } else { pct(improv) },
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_allreduce_bar_is_smaller() {
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        let mut pure_comm = std::collections::HashMap::new();
        for row in &t.rows {
            let key = (row[0].clone(), row[1].clone());
            let comm: f64 = row[4].parse().unwrap();
            match row[2].as_str() {
                "pure-mpi" => {
                    pure_comm.insert(key, comm);
                }
                "mpi+mpi" => {
                    let p = pure_comm[&key];
                    assert!(comm < p, "hybrid allreduce {comm} must beat pure {p} at {key:?}");
                }
                _ => {}
            }
        }
    }
}
