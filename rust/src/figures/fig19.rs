//! Fig. 19 — BPMF strong scaling on Hazel Hen, 1–32 nodes × 24 ranks,
//! 20 sampling iterations. Published shape: hybrid MPI+MPI constantly
//! best; MPI+OpenMP worst (gap shrinking with scale); pure MPI and hybrid
//! degrade from 16 → 32 nodes as allgather cost overrides compute; the
//! hybrid's edge over pure grows to 10.3% at 32 nodes.

use super::{pct, us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, Table};
use crate::kernels::bpmf::{run, BpmfCfg};
use crate::kernels::{Backend, Variant};

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        format!(
            "Fig. 19 — BPMF 20-iteration time on Hazel Hen (us), workload scale {}",
            opts.scale
        ),
        &["nodes", "cores", "variant", "comp", "allgather", "total", "vs pure"],
    );
    let node_counts: &[usize] = if opts.fast { &[1, 2] } else { &[1, 2, 4, 8, 16, 32] };
    for &nodes in node_counts {
        let mut pure_total = 0.0;
        for variant in [Variant::PureMpi, Variant::HybridMpiMpi, Variant::MpiOpenMp] {
            let spec = if variant == Variant::MpiOpenMp {
                let mut s = ClusterSpec::preset(Preset::HazelHen, nodes);
                s.nodes = vec![1; nodes];
                s
            } else {
                ClusterSpec::preset(Preset::HazelHen, nodes)
            };
            // Deterministic modeled compute — see fig17.rs.
            let mut cfg = BpmfCfg::paper(opts.scale, variant, Backend::Modeled, 24);
            if opts.fast {
                cfg = BpmfCfg { compounds: 384, targets: 48, k: 6, nnz: 8, iters: 3, ..cfg };
            }
            let rep = run(spec, cfg);
            if variant == Variant::PureMpi {
                pure_total = rep.total_us;
            }
            let improv = (pure_total - rep.total_us) / pure_total * 100.0;
            t.row(vec![
                nodes.to_string(),
                (nodes * 24).to_string(),
                variant.name().to_string(),
                us(rep.comp_us),
                us(rep.comm_us),
                us(rep.total_us),
                if variant == Variant::PureMpi { "-".into() } else { pct(improv) },
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_best_and_openmp_worst() {
        let opts = FigOpts { fast: true, ..Default::default() };
        let t = &generate(&opts)[0];
        let mut cell: std::collections::HashMap<(String, String), (f64, f64)> = Default::default();
        for row in &t.rows {
            cell.insert(
                (row[0].clone(), row[2].clone()),
                (row[4].parse().unwrap(), row[5].parse().unwrap()), // (comm, total)
            );
        }
        for nodes in ["1", "2"] {
            let pure = cell[&(nodes.to_string(), "pure-mpi".into())];
            let hy = cell[&(nodes.to_string(), "mpi+mpi".into())];
            let omp = cell[&(nodes.to_string(), "mpi+openmp".into())];
            // The robust claims: the hybrid allgather bar is much smaller
            // (deterministic virtual time), and MPI+OpenMP's total is
            // clearly worst (its compute penalty is far above the noise).
            assert!(hy.0 < pure.0 * 0.7, "{nodes} nodes: hybrid comm {} vs pure {}", hy.0, pure.0);
            assert!(omp.1 > hy.1, "{nodes} nodes: openmp {} vs hybrid {}", omp.1, hy.1);
        }
        // The paper's total-time win is asserted at 2 nodes, where the
        // margin (24% here) is far beyond host-compute noise; at 1 node it
        // is ~1% ("insignificant on a smaller number of nodes", §6).
        let pure2 = cell[&("2".to_string(), "pure-mpi".into())].1;
        let hy2 = cell[&("2".to_string(), "mpi+mpi".into())].1;
        assert!(hy2 < pure2, "2 nodes: hybrid {hy2} vs pure {pure2}");
    }
}
