//! Reproduction generators — one per table/figure of the paper's
//! evaluation section (§5). Each generator runs the relevant benchmark on
//! the simulated cluster and emits a [`Table`] whose rows mirror the
//! series the paper plots; `hympi figures all` regenerates everything into
//! `reports/` (see EXPERIMENTS.md for paper-vs-measured commentary).

pub mod common;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod fig17;
pub mod fig18;
pub mod fig19;
pub mod table1;
pub mod table2;

use crate::coordinator::Table;

/// Generator options.
#[derive(Clone, Debug)]
pub struct FigOpts {
    /// Output directory for `.md`/`.csv` (also printed to stdout).
    pub out_dir: String,
    /// Workload scale factor for the kernel figures (1.0 = paper size).
    pub scale: f64,
    /// Fast mode: fewer repetitions, smaller largest configs (for CI and
    /// `cargo bench` smoke runs).
    pub fast: bool,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts { out_dir: "reports".into(), scale: 1.0, fast: false }
    }
}

/// All generators by name.
pub fn registry() -> Vec<(&'static str, fn(&FigOpts) -> Vec<Table>)> {
    vec![
        ("table1", table1::generate as fn(&FigOpts) -> Vec<Table>),
        ("table2", table2::generate),
        ("fig12", fig12::generate),
        ("fig13", fig13::generate),
        ("fig14", fig14::generate),
        ("fig15", fig15::generate),
        ("fig16", fig16::generate),
        ("fig17", fig17::generate),
        ("fig18", fig18::generate),
        ("fig19", fig19::generate),
    ]
}

/// Run one generator by name, saving and printing its tables.
pub fn run(name: &str, opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let gen = registry()
        .into_iter()
        .find(|(n, _)| *n == name)
        .ok_or_else(|| crate::err!("unknown figure '{name}'"))?
        .1;
    let tables = gen(opts);
    for (i, t) in tables.iter().enumerate() {
        let stem = if tables.len() == 1 { name.to_string() } else { format!("{name}_{i}") };
        t.save(&opts.out_dir, &stem)?;
        println!("{t}");
    }
    Ok(tables)
}

/// Run every generator.
pub fn run_all(opts: &FigOpts) -> crate::Result<()> {
    for (name, _) in registry() {
        println!("==== {name} ====");
        run(name, opts)?;
    }
    Ok(())
}

/// Helper: format µs with 2 decimals.
pub(crate) fn us(v: f64) -> String {
    format!("{v:.2}")
}

/// Helper: format a percentage.
pub(crate) fn pct(v: f64) -> String {
    format!("{v:+.1}%")
}
