//! Table 1 — the wrapper-vs-verbose productivity comparison (§4.2).
//!
//! The paper maps each functionality of the allgather micro-benchmark to
//! its line ranges in the "wrapper program" (Fig. 5) and the "verbose
//! program" (Fig. 6). We reproduce it mechanically: the two example
//! programs (`examples/allgather_wrapper.rs` / `allgather_verbose.rs`)
//! carry `[section: …]` markers, and this generator counts the effective
//! (non-blank, non-comment) lines per section of each.

use super::FigOpts;
use crate::coordinator::Table;
use std::collections::BTreeMap;

const WRAPPER_SRC: &str = include_str!("../../../examples/allgather_wrapper.rs");
const VERBOSE_SRC: &str = include_str!("../../../examples/allgather_verbose.rs");

/// The paper's functionality rows, in presentation order.
pub const SECTIONS: [&str; 6] = [
    "Communicator splitting",
    "Shared memory allocation",
    "Fill recvcounts and displs",
    "Get local pointer",
    "Allgather",
    "Deallocation",
];

/// Count effective lines per `[section: …]` region.
pub fn section_loc(src: &str) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    let mut current: Option<String> = None;
    for line in src.lines() {
        let trimmed = line.trim();
        if let Some(rest) = trimmed.strip_prefix("// [section: ") {
            let name = rest.trim_end_matches(']').to_string();
            current = (name != "end").then_some(name);
            continue;
        }
        if trimmed.is_empty() || trimmed.starts_with("//") {
            continue;
        }
        if let Some(sec) = &current {
            *out.entry(sec.clone()).or_insert(0) += 1;
        }
    }
    out
}

pub fn generate(_opts: &FigOpts) -> Vec<Table> {
    let wrapper = section_loc(WRAPPER_SRC);
    let verbose = section_loc(VERBOSE_SRC);
    let mut t = Table::new(
        "Table 1 — functionality LOC: wrapper program (Fig. 5) vs verbose program (Fig. 6)",
        &["functionality", "wrapper LOC", "verbose LOC"],
    );
    let mut tw = 0;
    let mut tv = 0;
    for sec in SECTIONS {
        let w = wrapper.get(sec).copied().unwrap_or(0);
        let v = verbose.get(sec).copied().unwrap_or(0);
        tw += w;
        tv += v;
        t.row(vec![sec.to_string(), w.to_string(), v.to_string()]);
    }
    t.row(vec!["TOTAL".into(), tw.to_string(), tv.to_string()]);
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_section_present_in_both_programs() {
        let wrapper = section_loc(WRAPPER_SRC);
        let verbose = section_loc(VERBOSE_SRC);
        for sec in SECTIONS {
            assert!(wrapper.contains_key(sec), "wrapper missing [{sec}]");
            assert!(verbose.contains_key(sec), "verbose missing [{sec}]");
        }
    }

    #[test]
    fn wrapper_program_is_shorter_in_every_bookkeeping_section() {
        // The paper's productivity claim, checked mechanically.
        let wrapper = section_loc(WRAPPER_SRC);
        let verbose = section_loc(VERBOSE_SRC);
        let total_w: usize = SECTIONS.iter().map(|s| wrapper[*s]).sum();
        let total_v: usize = SECTIONS.iter().map(|s| verbose[*s]).sum();
        assert!(total_w < total_v, "wrapper {total_w} lines vs verbose {total_v}");
        for sec in ["Communicator splitting", "Fill recvcounts and displs", "Allgather"] {
            assert!(wrapper[sec] < verbose[sec], "[{sec}] wrapper {} vs verbose {}", wrapper[sec], verbose[sec]);
        }
    }
}
