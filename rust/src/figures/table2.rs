//! Table 2 — one-off implementation overheads of the wrapper primitives
//! (Communicator creation, shared-memory Allocate, Bcast_transtable,
//! Allgather_param) at 16/64/256/1024 cores on Vulcan.

use super::{us, FigOpts};
use crate::coordinator::{ClusterSpec, Preset, SimCluster, Table};
use crate::hybrid::{AllgatherParam, HybridCtx, LeaderPolicy};

/// Paper values for the Mean (µs) rows (Vulcan).
pub const PAPER: [(usize, [f64; 4]); 4] = [
    (16, [64.8, 188.3, 0.7, 0.3]),
    (64, [170.9, 262.5, 9.2, 2.9]),
    (256, [413.7, 307.1, 95.9, 7.1]),
    (1024, [1098.7, 311.8, 1462.8, 19.9]),
];

/// Measure the four one-off overheads at one core count.
pub fn measure(cores: usize) -> [f64; 4] {
    let spec = ClusterSpec::preset(Preset::VulcanSb, cores / 16);
    let report = SimCluster::new(spec).run(|env| {
        let w = env.world();
        let t0 = env.vclock();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let t1 = env.vclock();
        let win = ctx.alloc_shared(env, 800, 1, w.size());
        let t2 = env.vclock();
        let tables = ctx.tables(env);
        let t3 = env.vclock();
        let sizeset = ctx.sizeset(env);
        let param = AllgatherParam::create(env, &ctx, 800, &sizeset);
        let t4 = env.vclock();
        std::hint::black_box((&tables, &param));
        env.barrier(ctx.shmem());
        win.free(env, &ctx);
        [t1 - t0, t2 - t1, t3 - t2, t4 - t3]
    });
    let mut out = [0.0f64; 4];
    for o in &report.outputs {
        for i in 0..4 {
            out[i] = out[i].max(o[i]);
        }
    }
    out
}

pub fn generate(opts: &FigOpts) -> Vec<Table> {
    let mut t = Table::new(
        "Table 2 — one-off overheads of the hybrid wrapper primitives (Vulcan model), mean us",
        &["cores", "Communicator", "(paper)", "Allocate", "(paper)", "Bcast_transtable", "(paper)", "Allgather_param", "(paper)"],
    );
    let counts: &[usize] = if opts.fast { &[16, 64] } else { &[16, 64, 256, 1024] };
    for &cores in counts {
        let m = measure(cores);
        let paper = PAPER.iter().find(|(c, _)| *c == cores).map(|(_, v)| *v).unwrap_or([0.0; 4]);
        t.row(vec![
            cores.to_string(),
            us(m[0]),
            us(paper[0]),
            us(m[1]),
            us(paper[1]),
            us(m[2]),
            us(paper[2]),
            us(m[3]),
            us(paper[3]),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overheads_land_within_2x_of_paper() {
        // 16 and 64 cores are cheap enough for a unit test.
        for &(cores, paper) in PAPER.iter().take(2) {
            let m = measure(cores);
            // Communicator, Allocate: tight bands.
            for i in [0usize, 1] {
                assert!(
                    m[i] / paper[i] > 0.4 && m[i] / paper[i] < 2.5,
                    "cores {cores} col {i}: {} vs paper {}",
                    m[i],
                    paper[i]
                );
            }
        }
    }

    #[test]
    fn scaling_directions_match_paper() {
        let m16 = measure(16);
        let m64 = measure(64);
        assert!(m64[0] > m16[0], "Communicator grows with cores");
        assert!(m64[1] > m16[1], "Allocate grows (saturating)");
        assert!(m64[2] > m16[2], "transtable grows quadratically");
    }
}
