//! `Wrapper_Hy_Allgather` (§4.2) and its parameter wrappers.
//!
//! Design: each rank writes its contribution into the slot of the node's
//! shared window with affinity to it (one shared copy per node, *zero*
//! on-node messages); after a red sync, the node **leaders** exchange whole
//! node blocks with `MPI_Allgatherv` over the bridge (block counts differ
//! on irregularly-populated nodes — the §5.2.2 irregular problem); a
//! yellow sync then releases the children to read the full result in
//! place.
//!
//! Requires block-style rank placement (§4: consecutive ranks fill each
//! node), so a node's contributions are contiguous in the result.

use super::package::CommPackage;
use super::shmem::HyWin;
use super::sync::{await_release, red_sync, release, SyncScheme};
use crate::coll::allgather::{allgatherv, allgatherv_inplace};
use crate::mpi::env::ProcEnv;
use crate::mpi::topo::Placement;

/// `struct allgather_param`: per-node receive counts and displacements for
/// the bridge `MPI_Allgatherv` (bytes).
#[derive(Clone, Debug)]
pub struct AllgatherParam {
    pub recvcounts: Vec<usize>,
    pub displs: Vec<usize>,
}

/// `Wrapper_ShmemcommSizeset_gather`: collect every node's shared-memory
/// communicator size. Leaders allgather over the bridge; children compute
/// the same set from the parent group (they hold the same information —
/// the wrapper hides where it comes from).
pub fn sizeset_gather(env: &mut ProcEnv, pkg: &CommPackage) -> Vec<usize> {
    if let Some(bridge) = &pkg.bridge {
        let mine = (pkg.shmem_size as u64).to_le_bytes();
        let mut out = vec![0u8; 8 * bridge.size()];
        crate::coll::allgather(env, bridge, &mine, &mut out, crate::coll::AllgatherAlgo::Bruck);
        out.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect()
    } else {
        // Children: derive from topology (same values, no traffic).
        let topo = env.topo();
        let mut nodes: Vec<usize> = pkg.parent.members().iter().map(|&w| topo.node_of(w)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
            .iter()
            .map(|&n| pkg.parent.members().iter().filter(|&&w| topo.node_of(w) == n).count())
            .collect()
    }
}

impl AllgatherParam {
    /// `Wrapper_Create_Allgather_param`: build `recvcounts`/`displs` from
    /// the per-node sizes for a per-rank message of `msg` bytes. One-off
    /// cost: the Table-2 "Allgather_param" law.
    pub fn create(env: &mut ProcEnv, pkg: &CommPackage, msg: usize, sizeset: &[usize]) -> AllgatherParam {
        let recvcounts: Vec<usize> = sizeset.iter().map(|&s| s * msg).collect();
        let displs = crate::coll::displs_of(&recvcounts);
        let mgmt = env.state().mgmt.clone();
        env.advance(mgmt.allgather_param_us(pkg.bridge_size));
        AllgatherParam { recvcounts, displs }
    }
}

/// `Wrapper_Hy_Allgather`: complete the allgather across the cluster. Every
/// rank must already have stored its `msg`-byte contribution at its
/// affinity slot (`win.local_ptr(parent_rank, msg)`); afterwards the full
/// gathered result (parent-rank order) is readable by every rank at offset
/// 0 of the node's shared window.
pub fn hy_allgather(
    env: &mut ProcEnv,
    pkg: &CommPackage,
    win: &mut HyWin,
    param: &AllgatherParam,
    msg: usize,
    scheme: SyncScheme,
) {
    assert_eq!(
        env.topo().placement(),
        Placement::Block,
        "Wrapper_Hy_Allgather assumes block-style rank placement (§4); \
         see [20] for the measures other placements require"
    );
    // Red sync: all on-node contributions must be in the window.
    red_sync(env, pkg);
    if let Some(bridge) = &pkg.bridge {
        // Exchange node blocks in place over the bridge. The leader works
        // directly on the shared window (its node's block is already
        // contiguous at its displacement under block placement, so every
        // ring step borrows straight out of the window) —
        // protocol-exclusive during this phase.
        let full_len: usize = param.recvcounts.iter().sum();
        if env.legacy_dataplane() {
            // Pre-refactor path: materialize the node block first.
            let bidx = bridge.rank();
            let (lo, count) = (param.displs[bidx], param.recvcounts[bidx]);
            let mine = win.win.read_vec(lo, count);
            env.count_copy(count);
            let out = unsafe { win.win.slice_mut(0, full_len) };
            allgatherv(env, bridge, &mine, &param.recvcounts, out);
        } else {
            let out = unsafe { win.win.slice_mut(0, full_len) };
            allgatherv_inplace(env, bridge, &param.recvcounts, out);
        }
        let _ = msg;
        release(env, pkg, win, scheme);
    } else {
        await_release(env, pkg, win, scheme);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::util::{cast_slice, to_bytes};

    fn run_allgather(nodes: &'static [usize], n_elems: usize, scheme: SyncScheme) -> Vec<Vec<f64>> {
        run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let msg = n_elems * 8;
            let mut win = pkg.alloc_shared(env, msg, 1, w.size());
            let sizeset = sizeset_gather(env, &pkg);
            let param = AllgatherParam::create(env, &pkg, msg, &sizeset);
            let mine: Vec<f64> = (0..n_elems).map(|i| (w.rank() * n_elems + i) as f64).collect();
            let off = win.local_ptr(w.rank(), msg);
            win.store(env, off, to_bytes(&mine));
            hy_allgather(env, &pkg, &mut win, &param, msg, scheme);
            let all = win.load(env, 0, msg * w.size());
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            cast_slice::<f64>(&all)
        })
    }

    #[test]
    fn gathers_in_rank_order_regular() {
        for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
            let out = run_allgather(&[4, 4], 5, scheme);
            let expect: Vec<f64> = (0..40).map(|x| x as f64).collect();
            for (r, got) in out.into_iter().enumerate() {
                assert_eq!(got, expect, "scheme {scheme:?} rank {r}");
            }
        }
    }

    #[test]
    fn gathers_irregular_nodes() {
        // The §5.2.2 irregular problem: different ranks per node.
        let out = run_allgather(&[5, 3], 3, SyncScheme::Spin);
        let expect: Vec<f64> = (0..24).map(|x| x as f64).collect();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn three_nodes_spin() {
        let out = run_allgather(&[3, 4, 2], 2, SyncScheme::Spin);
        let expect: Vec<f64> = (0..18).map(|x| x as f64).collect();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn single_node_needs_no_bridge() {
        let out = run_allgather(&[6], 4, SyncScheme::Spin);
        let expect: Vec<f64> = (0..24).map(|x| x as f64).collect();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn sizeset_agrees_between_leaders_and_children() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            sizeset_gather(env, &pkg)
        });
        for got in out {
            assert_eq!(got, vec![5, 3]);
        }
    }

    #[test]
    fn hybrid_beats_pure_mpi_allgather_vtime() {
        // Fig. 12's claim at micro scale: hybrid < pure for the same layout.
        let nodes: &'static [usize] = &[8, 8];
        let n = 100; // 800 B per rank, the Fig. 12 message size
        let hybrid = run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let msg = n * 8;
            let mut win = pkg.alloc_shared(env, msg, 1, w.size());
            let sizeset = sizeset_gather(env, &pkg);
            let param = AllgatherParam::create(env, &pkg, msg, &sizeset);
            let data = vec![1u8; msg];
            env.harness_sync(&w);
            let t0 = env.vclock();
            win.store(env, win.local_ptr(w.rank(), msg), &data);
            hy_allgather(env, &pkg, &mut win, &param, msg, SyncScheme::Spin);
            let dt = env.vclock() - t0;
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            dt
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let pure = run_nodes(nodes, move |env| {
            let w = env.world();
            let mine = vec![1u8; n * 8];
            let mut out = vec![0u8; n * 8 * w.size()];
            env.harness_sync(&w);
            let t0 = env.vclock();
            crate::coll::allgather(env, &w, &mine, &mut out, crate::coll::AllgatherAlgo::Auto);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(hybrid < pure, "hybrid {hybrid} must beat pure {pure} at 800 B");
    }
}
