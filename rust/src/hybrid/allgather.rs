//! The hybrid allgather (§4.2) behind
//! [`HybridCtx::allgather_init`](super::ctx::HybridCtx::allgather_init),
//! and the bridge parameter wrapper.
//!
//! Design: each rank writes its contribution into the slot of the node's
//! shared window with affinity to it (one shared copy per node, *zero*
//! on-node messages); after a red sync, the node **leaders** exchange
//! node blocks over the bridge — with `k > 1` leaders each leader `j`
//! moves stripe `j` of every node block over its own same-index bridge,
//! bound to NIC lane `j`, so the stripes overlap on the wire (block
//! counts differ on irregularly-populated nodes — the §5.2.2 irregular
//! problem; stripes inherit the irregularity); a yellow sync then
//! releases the children to read the full result in place.
//!
//! Requires block-style rank placement (§4: consecutive ranks fill each
//! node), so a node's contributions are contiguous in the result.

use super::ctx::{HybridCtx, StripeTable};
use super::shmem::HyWin;
#[cfg(test)]
use super::sync::SyncScheme;
use crate::coll::allgather::{allgatherv, allgatherv_inplace, allgatherv_offsets};
use crate::mpi::env::ProcEnv;

/// `struct allgather_param`: per-node receive counts and displacements for
/// the bridge exchange (bytes).
#[derive(Clone, Debug)]
pub struct AllgatherParam {
    pub recvcounts: Vec<usize>,
    pub displs: Vec<usize>,
}

impl AllgatherParam {
    /// `Wrapper_Create_Allgather_param`: build `recvcounts`/`displs` from
    /// the per-node sizes for a per-rank message of `msg` bytes. One-off
    /// cost: the Table-2 "Allgather_param" law.
    pub fn create(env: &mut ProcEnv, ctx: &HybridCtx, msg: usize, sizeset: &[usize]) -> AllgatherParam {
        let recvcounts: Vec<usize> = sizeset.iter().map(|&s| s * msg).collect();
        let displs = crate::coll::displs_of(&recvcounts);
        let mgmt = env.state().mgmt.clone();
        env.advance(mgmt.allgather_param_us(ctx.nnodes()));
        AllgatherParam { recvcounts, displs }
    }
}

/// The leaders' bridge exchange — the `Work` stage of the allgather
/// schedule, executed between the red sync and the yellow release. With
/// `k = 1` (empty `stripes`) this is byte- and vtime-identical to the
/// pre-session `Wrapper_Hy_Allgather` bridge step.
pub(crate) fn bridge(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    param: &AllgatherParam,
    stripes: &[StripeTable],
) {
    if let Some(j) = ctx.leader_index() {
        let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
        let full_len: usize = param.recvcounts.iter().sum();
        if stripes.is_empty() {
            // Single leader: exchange whole node blocks in place over the
            // bridge (the leader works directly on the shared window —
            // its node's block is already contiguous at its displacement
            // under block placement) — protocol-exclusive in this phase.
            if env.legacy_dataplane() {
                // Pre-refactor path: materialize the node block first.
                let bidx = bridge.rank();
                let (lo, count) = (param.displs[bidx], param.recvcounts[bidx]);
                let mine = win.win.read_vec(lo, count);
                env.count_copy(count);
                let out = unsafe { win.win.slice_mut(0, full_len) };
                allgatherv(env, &bridge, &mine, &param.recvcounts, out);
            } else {
                let out = unsafe { win.win.slice_mut(0, full_len) };
                allgatherv_inplace(env, &bridge, &param.recvcounts, out);
            }
        } else {
            // Leader j moves stripe j of every node block over bridge j,
            // injecting on its own NIC lane so same-node leaders overlap.
            let st = &stripes[j];
            let out = unsafe { win.win.slice_mut(0, full_len) };
            env.with_nic_lane(j, |env| {
                allgatherv_offsets(env, &bridge, &st.counts, &st.offsets, out);
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::hybrid::LeaderPolicy;
    use crate::util::{cast_slice, to_bytes};

    fn run_allgather(
        nodes: &'static [usize],
        n_elems: usize,
        k: usize,
        scheme: SyncScheme,
    ) -> Vec<Vec<f64>> {
        run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let msg = n_elems * 8;
            let mut ag = ctx.allgather_init(env, msg, scheme);
            let mine: Vec<f64> = (0..n_elems).map(|i| (w.rank() * n_elems + i) as f64).collect();
            ag.start_allgather(env, to_bytes(&mine));
            ag.wait(env);
            let all = ag.window().unwrap().load(env, 0, msg * w.size());
            env.barrier(ctx.shmem());
            ag.free(env);
            cast_slice::<f64>(&all)
        })
    }

    #[test]
    fn gathers_in_rank_order_regular() {
        for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
            for k in [1, 2, 4] {
                let out = run_allgather(&[4, 4], 5, k, scheme);
                let expect: Vec<f64> = (0..40).map(|x| x as f64).collect();
                for (r, got) in out.into_iter().enumerate() {
                    assert_eq!(got, expect, "scheme {scheme:?} k {k} rank {r}");
                }
            }
        }
    }

    #[test]
    fn gathers_irregular_nodes() {
        // The §5.2.2 irregular problem: different ranks per node.
        for k in [1, 2, 3] {
            let out = run_allgather(&[5, 3], 3, k, SyncScheme::Spin);
            let expect: Vec<f64> = (0..24).map(|x| x as f64).collect();
            for got in out {
                assert_eq!(got, expect, "k {k}");
            }
        }
    }

    #[test]
    fn three_nodes_spin() {
        for k in [1, 2] {
            let out = run_allgather(&[3, 4, 2], 2, k, SyncScheme::Spin);
            let expect: Vec<f64> = (0..18).map(|x| x as f64).collect();
            for got in out {
                assert_eq!(got, expect, "k {k}");
            }
        }
    }

    #[test]
    fn single_node_needs_no_bridge() {
        let out = run_allgather(&[6], 4, 2, SyncScheme::Spin);
        let expect: Vec<f64> = (0..24).map(|x| x as f64).collect();
        for got in out {
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn hybrid_beats_pure_mpi_allgather_vtime() {
        // Fig. 12's claim at micro scale: hybrid < pure for the same layout.
        let nodes: &'static [usize] = &[8, 8];
        let n = 100; // 800 B per rank, the Fig. 12 message size
        let hybrid = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let msg = n * 8;
            let mut ag = ctx.allgather_init(env, msg, SyncScheme::Spin);
            let data = vec![1u8; msg];
            env.harness_sync(&w);
            let t0 = env.vclock();
            ag.start_allgather(env, &data);
            ag.wait(env);
            let dt = env.vclock() - t0;
            env.barrier(ctx.shmem());
            ag.free(env);
            dt
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let pure = run_nodes(nodes, move |env| {
            let w = env.world();
            let mine = vec![1u8; n * 8];
            let mut out = vec![0u8; n * 8 * w.size()];
            env.harness_sync(&w);
            let t0 = env.vclock();
            crate::coll::allgather(env, &w, &mine, &mut out, crate::coll::AllgatherAlgo::Auto);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(hybrid < pure, "hybrid {hybrid} must beat pure {pure} at 800 B");
    }

    #[test]
    fn two_leaders_beat_one_on_large_bridge_blocks() {
        // The multi-lane acceptance bound: at a ≥256 KiB node block the
        // striped k = 2 bridge must be strictly faster in modeled vtime
        // than the single-leader exchange.
        let nodes: &'static [usize] = &[16, 16];
        let msg = 16 * 1024; // 16 KiB/rank → 256 KiB node blocks
        let vt = |k: usize| {
            run_nodes(nodes, move |env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
                let mut ag = ctx.allgather_init(env, msg, SyncScheme::Spin);
                let data = vec![3u8; msg];
                env.harness_sync(&w);
                let t0 = env.vclock();
                ag.start_allgather(env, &data);
                ag.wait(env);
                let dt = env.vclock() - t0;
                env.barrier(ctx.shmem());
                ag.free(env);
                dt
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        let (one, two) = (vt(1), vt(2));
        assert!(two < one, "k=2 ({two}) must be strictly below k=1 ({one})");
    }
}
