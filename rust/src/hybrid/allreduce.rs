//! `Wrapper_Hy_Allreduce` (§4.4) with both step-1 methods and the
//! message-size cutoff tuning of §5.2.4.
//!
//! Window layout (leader allocates `(shmem_size + 2) · msize` bytes):
//! input slot per local rank at `local_rank · msize`, then the two-element
//! output vector of Fig. 8 — slot `L` (node-local reduction) at
//! `shmem_size · msize` and slot `G` (global result) after it.
//!
//! - **Step 1** (node-level reduction into `L`):
//!   - *method 1* — `MPI_Reduce` over the node communicator: simple and
//!     synchronizing by itself, but pays the library's internal staging
//!     copies;
//!   - *method 2* — a red sync, then the leader serially reduces the input
//!     slots straight out of the shared window (no message copies, but the
//!     children idle and an extra sync is needed).
//! - **Step 2**: standard allreduce over the bridge (leaders), result into
//!   `G`, then a yellow sync; children read `G` in place — the result is
//!   *not* broadcast (visible-change sharing, §1).
//!
//! The optimized wrapper ([`AllreduceMethod::Tuned`]) uses method 2 below
//! the 2 KB cutoff (Fig. 15) and method 1 above it, with the spinning
//! yellow sync (§5.2.4's final configuration).

use super::package::CommPackage;
use super::shmem::HyWin;
use super::sync::{await_release, red_sync, release, SyncScheme};
use crate::coll::allreduce::{allreduce, AllreduceAlgo};
use crate::coll::reduce::reduce;
use crate::mpi::env::ProcEnv;
use crate::mpi::{Datatype, ReduceOp};

/// Step-1 implementation choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AllreduceMethod {
    /// `MPI_Reduce` on the node communicator.
    Method1,
    /// Red sync + leader-serial reduction from the shared window.
    Method2,
    /// §5.2.4 optimized: method 2 iff `msize ≤` the 2 KB cutoff.
    Tuned,
}

/// The Fig. 15 cutoff (bytes): below → method 2, above → method 1.
pub const METHOD_CUTOFF_BYTES: usize = 2 * 1024;

/// Allocate the allreduce window for `msize`-byte operands
/// (`(shmem_size + 2) · msize` on the leader).
pub fn alloc_allreduce_win(env: &mut ProcEnv, pkg: &CommPackage, msize: usize) -> HyWin {
    pkg.alloc_shared(env, msize, 1, pkg.shmem_size + 2)
}

/// Offsets of the L and G slots.
fn slots(pkg: &CommPackage, msize: usize) -> (usize, usize) {
    (pkg.shmem_size * msize, (pkg.shmem_size + 1) * msize)
}

/// `Wrapper_Hy_Allreduce`: reduce the per-rank operands (already stored at
/// `win.local_ptr(shmem_rank, msize)`) across the parent communicator.
/// Afterwards every rank can read the global result at the returned window
/// offset (slot `G`) — one shared copy per node.
pub fn hy_allreduce(
    env: &mut ProcEnv,
    pkg: &CommPackage,
    win: &mut HyWin,
    dtype: Datatype,
    op: ReduceOp,
    msize: usize,
    method: AllreduceMethod,
    scheme: SyncScheme,
) -> usize {
    assert_eq!(msize % dtype.size(), 0);
    let (l_off, g_off) = slots(pkg, msize);
    let method = match method {
        AllreduceMethod::Tuned => {
            if msize <= METHOD_CUTOFF_BYTES {
                AllreduceMethod::Method2
            } else {
                AllreduceMethod::Method1
            }
        }
        m => m,
    };

    // ---- step 1: node-level reduction into L -------------------------
    match method {
        AllreduceMethod::Method1 => {
            // MPI_Reduce over the node communicator; operands read from
            // each rank's own window slot (its private data — no sync
            // needed before a rank reads what it wrote). The operand is
            // borrowed straight out of the window, and the leader's
            // result lands in slot L in place (the `charge_memcpy` keeps
            // the modeled store cost identical to the legacy round-trip).
            let my_off = win.local_ptr(pkg.shmem.rank(), msize);
            if env.legacy_dataplane() {
                let contrib = win.win.read_vec(my_off, msize);
                env.count_copy(msize);
                if pkg.is_leader() {
                    let mut out = vec![0u8; msize];
                    reduce(env, &pkg.shmem, 0, dtype, op, &contrib, Some(&mut out));
                    win.store(env, l_off, &out);
                } else {
                    reduce(env, &pkg.shmem, 0, dtype, op, &contrib, None);
                }
            } else {
                let contrib = unsafe { win.win.slice(my_off, msize) };
                if pkg.is_leader() {
                    let out = unsafe { win.win.slice_mut(l_off, msize) };
                    reduce(env, &pkg.shmem, 0, dtype, op, contrib, Some(out));
                    env.charge_memcpy(msize);
                } else {
                    reduce(env, &pkg.shmem, 0, dtype, op, contrib, None);
                }
            }
        }
        AllreduceMethod::Method2 => {
            // Red sync so every input slot is visible, then the leader
            // reduces serially straight out of the shared window into
            // slot L (slot 0 seeds L; slots 1.. fold into it — the same
            // combine order as the legacy accumulator, so results are
            // bit-identical).
            red_sync(env, pkg);
            if pkg.is_leader() {
                if env.legacy_dataplane() {
                    let mut acc = win.win.read_vec(0, msize);
                    env.count_copy(msize);
                    for r in 1..pkg.shmem_size {
                        let operand = unsafe { win.win.slice(r * msize, msize) };
                        op.apply(dtype, &mut acc, operand);
                    }
                    env.charge_reduce(msize * pkg.shmem_size);
                    win.win.write(l_off, &acc);
                    env.charge_memcpy(msize);
                } else {
                    win.win.copy_within(0, l_off, msize);
                    let l = unsafe { win.win.slice_mut(l_off, msize) };
                    for r in 1..pkg.shmem_size {
                        let operand = unsafe { win.win.slice(r * msize, msize) };
                        op.apply(dtype, l, operand);
                    }
                    env.charge_reduce(msize * pkg.shmem_size);
                    env.charge_memcpy(msize);
                }
            }
        }
        AllreduceMethod::Tuned => unreachable!(),
    }

    // ---- step 2: bridge allreduce into G + yellow sync ----------------
    if let Some(bridge) = &pkg.bridge {
        // G := L (slot-to-slot move inside the window), then allreduce G
        // in place across the leaders.
        if env.legacy_dataplane() {
            let l = win.win.read_vec(l_off, msize);
            env.count_copy(msize);
            win.win.write(g_off, &l);
        } else {
            win.win.copy_within(l_off, g_off, msize);
        }
        env.charge_memcpy(msize);
        if bridge.size() > 1 {
            let g = unsafe { win.win.slice_mut(g_off, msize) };
            allreduce(env, bridge, dtype, op, g, AllreduceAlgo::Auto);
        }
        release(env, pkg, win, scheme);
    } else {
        await_release(env, pkg, win, scheme);
    }
    g_off
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::util::{cast_slice, to_bytes};

    fn check(nodes: &'static [usize], n: usize, method: AllreduceMethod, scheme: SyncScheme) {
        let p: usize = nodes.iter().sum();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let msize = n * 8;
            let mut win = alloc_allreduce_win(env, &pkg, msize);
            let vals: Vec<f64> = (0..n).map(|i| ((w.rank() + 1) * (i + 2)) as f64).collect();
            let off = win.local_ptr(pkg.shmem.rank(), msize);
            win.store(env, off, to_bytes(&vals));
            let g = hy_allreduce(env, &pkg, &mut win, Datatype::F64, ReduceOp::Sum, msize, method, scheme);
            let result = win.load(env, g, msize);
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            cast_slice::<f64>(&result)
        });
        let rank_sum: f64 = (1..=p).map(|r| r as f64).sum();
        for (r, got) in out.into_iter().enumerate() {
            for (i, &v) in got.iter().enumerate() {
                let expect = rank_sum * (i + 2) as f64;
                assert!((v - expect).abs() < 1e-9, "method {method:?} rank {r} elem {i}: {v} vs {expect}");
            }
        }
    }

    #[test]
    fn both_methods_all_schemes() {
        for method in [AllreduceMethod::Method1, AllreduceMethod::Method2] {
            for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
                check(&[5, 3], 4, method, scheme);
            }
        }
    }

    #[test]
    fn tuned_picks_correctly_and_stays_correct() {
        check(&[5, 3], 1, AllreduceMethod::Tuned, SyncScheme::Spin); // 8 B -> method 2
        check(&[5, 3], 512, AllreduceMethod::Tuned, SyncScheme::Spin); // 4 KB -> method 1
    }

    #[test]
    fn irregular_three_nodes_and_single_node() {
        check(&[3, 4, 2], 8, AllreduceMethod::Method2, SyncScheme::Spin);
        check(&[6], 8, AllreduceMethod::Method1, SyncScheme::Spin);
        check(&[6], 8, AllreduceMethod::Method2, SyncScheme::Barrier);
    }

    #[test]
    fn max_op() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let mut win = alloc_allreduce_win(env, &pkg, 8);
            let v = [(w.rank() as f64) * if w.rank() % 2 == 0 { 1.0 } else { -1.0 }];
            let off = win.local_ptr(pkg.shmem.rank(), 8);
            win.store(env, off, to_bytes(&v));
            let g = hy_allreduce(
                env, &pkg, &mut win, Datatype::F64, ReduceOp::Max, 8,
                AllreduceMethod::Method2, SyncScheme::Spin,
            );
            let result = win.load(env, g, 8);
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            cast_slice::<f64>(&result)[0]
        });
        for got in out {
            assert_eq!(got, 6.0);
        }
    }

    #[test]
    fn method2_beats_method1_below_cutoff_and_loses_above() {
        // The Fig. 15 crossover, asserted in virtual time.
        let vt = |n_elems: usize, method: AllreduceMethod| {
            run_nodes(&[16], move |env| {
                let w = env.world();
                let pkg = CommPackage::create(env, &w);
                let msize = n_elems * 8;
                let mut win = alloc_allreduce_win(env, &pkg, msize);
                let vals = vec![1.0f64; n_elems];
                let off = win.local_ptr(pkg.shmem.rank(), msize);
                env.harness_sync(&w);
                let t0 = env.vclock();
                win.store(env, off, to_bytes(&vals));
                hy_allreduce(env, &pkg, &mut win, Datatype::F64, ReduceOp::Sum, msize, method, SyncScheme::Spin);
                let dt = env.vclock() - t0;
                env.barrier(&pkg.shmem);
                win.free(env, &pkg);
                dt
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        // 8 B: method 2 wins (no staging copies).
        assert!(vt(1, AllreduceMethod::Method2) < vt(1, AllreduceMethod::Method1));
        // 8 KB: method 1 wins (parallel tree beats the serial leader).
        assert!(vt(1024, AllreduceMethod::Method1) < vt(1024, AllreduceMethod::Method2));
    }
}
