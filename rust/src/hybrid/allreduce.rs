//! The hybrid allreduce (§4.4) behind
//! [`HybridCtx::allreduce_init`](super::ctx::HybridCtx::allreduce_init),
//! with both step-1 methods and the message-size cutoff tuning of §5.2.4.
//!
//! Window layout (primary leader allocates `(shmem_size + 2) · msize`
//! bytes): input slot per local rank at `local_rank · msize`, then the
//! two-element output vector of Fig. 8 — slot `L` (node-local reduction)
//! at `shmem_size · msize` and slot `G` (global result) after it.
//!
//! - **Step 1** (node-level reduction into `L`):
//!   - *method 1* — `MPI_Reduce` over the node communicator: simple and
//!     synchronizing by itself, but pays the library's internal staging
//!     copies;
//!   - *method 2* — a red sync, then the leaders reduce the input slots
//!     straight out of the shared window (no message copies, but the
//!     children idle and an extra sync is needed). With `k > 1` leaders
//!     each leader serially folds its own element-aligned stripe — the
//!     serial step-1 bottleneck parallelizes along with the bridge.
//! - **Step 2**: allreduce over the bridge(s), result into `G` — leader
//!   `j` reduces stripe `j` over bridge `j` on NIC lane `j` — then a
//!   yellow sync; children read `G` in place (visible-change sharing,
//!   §1).
//!
//! The optimized configuration uses method 2 below the 2 KB cutoff
//! (Fig. 15) and method 1 above it, with the spinning yellow sync
//! (§5.2.4's final configuration); [`AllreduceMethod::Tuned`] resolves
//! the cutoff once, at `*_init` time.

use super::ctx::HybridCtx;
use super::shmem::HyWin;
#[cfg(test)]
use super::sync::SyncScheme;
use crate::coll::allreduce::{allreduce, AllreduceAlgo};
use crate::coll::reduce::reduce;
use crate::mpi::env::ProcEnv;
use crate::mpi::{Datatype, ReduceOp};

/// Step-1 implementation choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AllreduceMethod {
    /// `MPI_Reduce` on the node communicator.
    Method1,
    /// Red sync + leader-serial reduction from the shared window.
    Method2,
    /// §5.2.4 optimized: method 2 iff the operand is at most the 2 KB
    /// cutoff (resolved once at `*_init`).
    Tuned,
}

/// The Fig. 15 cutoff (bytes): below → method 2, above → method 1.
pub const METHOD_CUTOFF_BYTES: usize = 2 * 1024;

/// Offsets of the L and G slots.
fn slots(ctx: &HybridCtx, msize: usize) -> (usize, usize) {
    (ctx.shmem_size() * msize, (ctx.shmem_size() + 1) * msize)
}

/// Step 1 — the node-level reduction into `L` (the first `Work` stage of
/// the allreduce schedule). Method 1 runs on *every* rank (the
/// `MPI_Reduce` over the node communicator); method 2 runs on leaders
/// only, *after* the schedule's red sync. The method-1 leader barrier and
/// the method-2 red sync live in the schedule, not here. With `k = 1`
/// (empty `vec_stripes`) every branch is byte- and vtime-identical to
/// the pre-session `Wrapper_Hy_Allreduce` step 1; `method` arrives
/// resolved (never [`AllreduceMethod::Tuned`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn step1(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    dtype: Datatype,
    op: ReduceOp,
    msize: usize,
    method: AllreduceMethod,
    vec_stripes: &[(usize, usize)],
) {
    let (l_off, _) = slots(ctx, msize);
    let shmem_size = ctx.shmem_size();
    match method {
        AllreduceMethod::Method1 => {
            // MPI_Reduce over the node communicator; operands read from
            // each rank's own window slot (its private data — no sync
            // needed before a rank reads what it wrote). The operand is
            // borrowed straight out of the window, and the primary
            // leader's result lands in slot L in place (the
            // `charge_memcpy` keeps the modeled store cost identical to
            // the legacy round-trip).
            let my_off = win.local_ptr(ctx.shmem().rank(), msize);
            if env.legacy_dataplane() {
                let contrib = win.win.read_vec(my_off, msize);
                env.count_copy(msize);
                if ctx.is_leader() {
                    let mut out = vec![0u8; msize];
                    reduce(env, ctx.shmem(), 0, dtype, op, &contrib, Some(&mut out));
                    win.store(env, l_off, &out);
                } else {
                    reduce(env, ctx.shmem(), 0, dtype, op, &contrib, None);
                }
            } else {
                let contrib = unsafe { win.win.slice(my_off, msize) };
                if ctx.is_leader() {
                    let out = unsafe { win.win.slice_mut(l_off, msize) };
                    reduce(env, ctx.shmem(), 0, dtype, op, contrib, Some(out));
                    env.charge_memcpy(msize);
                } else {
                    reduce(env, ctx.shmem(), 0, dtype, op, contrib, None);
                }
            }
            // Leaders 1..k read L, which only leader 0 holds so far: the
            // schedule synchronizes the leader group right after this
            // stage, before the striped step 2.
        }
        AllreduceMethod::Method2 => {
            // The schedule's red sync has made every input slot visible;
            // the leaders reduce serially straight out of the shared
            // window into slot L (slot 0 seeds L; slots 1.. fold into it
            // — the same combine order as the legacy accumulator, so
            // results are bit-identical). With k > 1 each leader folds
            // only its own stripe — disjoint L ranges, no leader sync
            // needed here.
            if let Some(j) = ctx.leader_index() {
                let (off, len) = if vec_stripes.is_empty() {
                    (0, msize)
                } else {
                    vec_stripes[j]
                };
                if len > 0 {
                    if env.legacy_dataplane() && vec_stripes.is_empty() {
                        let mut acc = win.win.read_vec(0, msize);
                        env.count_copy(msize);
                        for r in 1..shmem_size {
                            let operand = unsafe { win.win.slice(r * msize, msize) };
                            op.apply(dtype, &mut acc, operand);
                        }
                        env.charge_reduce(msize * shmem_size);
                        win.win.write(l_off, &acc);
                        env.charge_memcpy(msize);
                    } else {
                        win.win.copy_within(off, l_off + off, len);
                        let l = unsafe { win.win.slice_mut(l_off + off, len) };
                        for r in 1..shmem_size {
                            let operand = unsafe { win.win.slice(r * msize + off, len) };
                            op.apply(dtype, l, operand);
                        }
                        env.charge_reduce(len * shmem_size);
                        env.charge_memcpy(len);
                    }
                }
            }
        }
        AllreduceMethod::Tuned => unreachable!("Tuned resolves at *_init"),
    }
}

/// Step 2 — `G := L` plus the (striped) bridge allreduce into `G`
/// (leaders only; the second `Work` stage). The yellow release follows
/// in the schedule. Byte- and vtime-identical to the pre-session step 2
/// for `k = 1`.
pub(crate) fn step2(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    dtype: Datatype,
    op: ReduceOp,
    msize: usize,
    vec_stripes: &[(usize, usize)],
) {
    let (l_off, g_off) = slots(ctx, msize);
    if let Some(j) = ctx.leader_index() {
        let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
        let (off, len) = if vec_stripes.is_empty() { (0, msize) } else { vec_stripes[j] };
        if len > 0 {
            // G := L (slot-to-slot move inside the window), then
            // allreduce G in place across the same-index leaders.
            if env.legacy_dataplane() && vec_stripes.is_empty() {
                let l = win.win.read_vec(l_off, msize);
                env.count_copy(msize);
                win.win.write(g_off, &l);
            } else {
                win.win.copy_within(l_off + off, g_off + off, len);
            }
            env.charge_memcpy(len);
            if bridge.size() > 1 {
                if vec_stripes.is_empty() {
                    let g = unsafe { win.win.slice_mut(g_off, msize) };
                    allreduce(env, &bridge, dtype, op, g, AllreduceAlgo::Auto);
                } else {
                    let g = unsafe { win.win.slice_mut(g_off + off, len) };
                    env.with_nic_lane(j, |env| {
                        allreduce(env, &bridge, dtype, op, g, AllreduceAlgo::Auto);
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::hybrid::LeaderPolicy;
    use crate::util::{cast_slice, to_bytes};

    fn check(nodes: &'static [usize], n: usize, k: usize, method: AllreduceMethod, scheme: SyncScheme) {
        let p: usize = nodes.iter().sum();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let msize = n * 8;
            let mut ar = ctx.allreduce_init(env, Datatype::F64, ReduceOp::Sum, msize, method, scheme);
            let vals: Vec<f64> = (0..n).map(|i| ((w.rank() + 1) * (i + 2)) as f64).collect();
            ar.start_allreduce(env, to_bytes(&vals));
            let g = ar.wait(env);
            let result = ar.window().unwrap().load(env, g, msize);
            env.barrier(ctx.shmem());
            ar.free(env);
            cast_slice::<f64>(&result)
        });
        let rank_sum: f64 = (1..=p).map(|r| r as f64).sum();
        for (r, got) in out.into_iter().enumerate() {
            for (i, &v) in got.iter().enumerate() {
                let expect = rank_sum * (i + 2) as f64;
                assert!(
                    (v - expect).abs() < 1e-9,
                    "method {method:?} k {k} rank {r} elem {i}: {v} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn both_methods_all_schemes() {
        for method in [AllreduceMethod::Method1, AllreduceMethod::Method2] {
            for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
                for k in [1, 2, 3] {
                    check(&[5, 3], 4, k, method, scheme);
                }
            }
        }
    }

    #[test]
    fn tuned_picks_correctly_and_stays_correct() {
        check(&[5, 3], 1, 1, AllreduceMethod::Tuned, SyncScheme::Spin); // 8 B -> method 2
        check(&[5, 3], 512, 1, AllreduceMethod::Tuned, SyncScheme::Spin); // 4 KB -> method 1
        check(&[5, 3], 512, 2, AllreduceMethod::Tuned, SyncScheme::Spin);
    }

    #[test]
    fn irregular_three_nodes_and_single_node() {
        check(&[3, 4, 2], 8, 1, AllreduceMethod::Method2, SyncScheme::Spin);
        check(&[3, 4, 2], 8, 2, AllreduceMethod::Method2, SyncScheme::Spin);
        check(&[6], 8, 1, AllreduceMethod::Method1, SyncScheme::Spin);
        check(&[6], 8, 2, AllreduceMethod::Method2, SyncScheme::Barrier);
    }

    #[test]
    fn max_op() {
        for k in [1, 2] {
            let out = run_nodes(&[5, 3], move |env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
                let mut ar = ctx.allreduce_init(
                    env, Datatype::F64, ReduceOp::Max, 8, AllreduceMethod::Method2, SyncScheme::Spin,
                );
                let v = [(w.rank() as f64) * if w.rank() % 2 == 0 { 1.0 } else { -1.0 }];
                ar.start_allreduce(env, to_bytes(&v));
                let g = ar.wait(env);
                let result = ar.window().unwrap().load(env, g, 8);
                env.barrier(ctx.shmem());
                ar.free(env);
                cast_slice::<f64>(&result)[0]
            });
            for got in out {
                assert_eq!(got, 6.0, "k {k}");
            }
        }
    }

    #[test]
    fn method2_beats_method1_below_cutoff_and_loses_above() {
        // The Fig. 15 crossover, asserted in virtual time.
        let vt = |n_elems: usize, method: AllreduceMethod| {
            run_nodes(&[16], move |env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
                let msize = n_elems * 8;
                let mut ar =
                    ctx.allreduce_init(env, Datatype::F64, ReduceOp::Sum, msize, method, SyncScheme::Spin);
                let vals = vec![1.0f64; n_elems];
                env.harness_sync(&w);
                let t0 = env.vclock();
                ar.start_allreduce(env, to_bytes(&vals));
                ar.wait(env);
                let dt = env.vclock() - t0;
                env.barrier(ctx.shmem());
                ar.free(env);
                dt
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        // 8 B: method 2 wins (no staging copies).
        assert!(vt(1, AllreduceMethod::Method2) < vt(1, AllreduceMethod::Method1));
        // 8 KB: method 1 wins (parallel tree beats the serial leader).
        assert!(vt(1024, AllreduceMethod::Method1) < vt(1024, AllreduceMethod::Method2));
    }
}
