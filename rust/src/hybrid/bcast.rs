//! The hybrid broadcast (§4.3) behind
//! [`HybridCtx::bcast_init`](super::ctx::HybridCtx::bcast_init), and the
//! rank-translation tables.
//!
//! One shared region per node stores the broadcast payload; only the root
//! may alter it (MPI broadcast semantics). The across-node broadcast runs
//! over the *leaders* — with `k > 1` each leader `j` broadcasts stripe
//! `j` of the payload over its same-index bridge on its own NIC lane —
//! then one yellow sync releases each node's children to read the shared
//! copy, replacing the pure-MPI fan-out to every rank and its per-rank
//! buffer replication.
//!
//! Because broadcast is *rooted* and any rank can be the root, the
//! session needs the root's rank translated into both sub-communicators —
//! the two absolute-to-relative translation tables of
//! `Wrapper_Get_transtable` (their one-off build cost is the quadratic
//! Table-2 "Bcast_transtable" law), cached on the [`HybridCtx`].

use super::ctx::HybridCtx;
use super::shmem::HyWin;
use super::sync::{complete, red_sync, SyncScheme};
use crate::coll::bcast::{bcast, BcastAlgo};
use crate::mpi::env::ProcEnv;

/// The two translation tables, indexed by parent-communicator rank:
/// `shmem[r]` = r's rank within *its own* node communicator;
/// `bridge[r]` = the bridge rank of r's node (same value for the whole
/// node — what the leaders' broadcast needs as its root).
#[derive(Clone, Debug)]
pub struct TransTables {
    pub shmem: Vec<usize>,
    pub bridge: Vec<usize>,
}

impl TransTables {
    /// `Wrapper_Get_transtable`. One-off cost: quadratic in the parent
    /// size (naive per-rank group scans — the measured Table-2 behaviour).
    /// Prefer the cached [`HybridCtx::tables`].
    pub fn create(env: &mut ProcEnv, ctx: &HybridCtx) -> TransTables {
        let topo = env.topo();
        let members = ctx.parent().members();
        let mut nodes: Vec<usize> = members.iter().map(|&w| topo.node_of(w)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        let mut shmem = Vec::with_capacity(members.len());
        let mut bridge = Vec::with_capacity(members.len());
        for &w in members {
            let n = topo.node_of(w);
            // Naive scans (the quadratic behaviour the paper measured).
            let node_rank = members.iter().filter(|&&v| topo.node_of(v) == n && v < w).count();
            let bridge_idx = nodes.iter().position(|&x| x == n).unwrap();
            shmem.push(node_rank);
            bridge.push(bridge_idx);
        }
        let mgmt = env.state().mgmt.clone();
        env.advance(mgmt.transtable_us(ctx.parent().size()));
        TransTables { shmem, bridge }
    }
}

/// Complete a started broadcast (payload already stored at offset 0 of
/// the root's node window); afterwards every rank can read the payload at
/// offset 0 of its node's shared window. With `k = 1` (empty
/// `vec_stripes`) this is byte- and vtime-identical to the pre-session
/// `Wrapper_Hy_Bcast`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    tables: &TransTables,
    vec_stripes: &[(usize, usize)],
    root: usize,
    len: usize,
    scheme: SyncScheme,
) {
    let root_node = tables.bridge[root];
    let root_is_primary = tables.shmem[root] == 0;
    let k = ctx.leaders_per_node();

    // The root's node leaders must observe the payload before forwarding
    // across the bridge: red sync on the root's node whenever the root is
    // a child — or whenever k > 1 (leaders 1..k read what the root, even
    // root = leader 0, stored).
    if (!root_is_primary || k > 1) && ctx.node_index() == root_node {
        red_sync(env, ctx);
    }
    // Leaders broadcast across the bridge, rooted at the root's node.
    if let Some(j) = ctx.leader_index() {
        let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
        if bridge.size() > 1 {
            if vec_stripes.is_empty() {
                let buf = unsafe { win.win.slice_mut(0, len) };
                bcast(env, &bridge, root_node, buf, BcastAlgo::Auto);
            } else {
                let (off, slen) = vec_stripes[j];
                if slen > 0 {
                    let buf = unsafe { win.win.slice_mut(off, slen) };
                    env.with_nic_lane(j, |env| {
                        bcast(env, &bridge, root_node, buf, BcastAlgo::Auto);
                    });
                }
            }
        }
    }
    complete(env, ctx, win, scheme);
    // All ranks may now read the single shared copy (children perform no
    // explicit copy here — they read in place via the local pointer).
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::hybrid::LeaderPolicy;

    fn check_bcast(nodes: &'static [usize], len: usize, root: usize, k: usize, scheme: SyncScheme) {
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let mut bc = ctx.bcast_init(env, len, scheme);
            let data = payload(root, len);
            let arg = (w.rank() == root).then_some(&data[..]);
            bc.start_bcast(env, root, arg);
            bc.wait(env);
            let got = bc.window().unwrap().load(env, 0, len);
            env.barrier(ctx.shmem());
            bc.free(env);
            got
        });
        let expect = payload(root, len);
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got, expect, "nodes {nodes:?} root {root} k {k} rank {r}");
        }
    }

    #[test]
    fn roots_leader_and_child() {
        check_bcast(&[5, 3], 64, 0, 1, SyncScheme::Spin); // root = leader of node 0
        check_bcast(&[5, 3], 64, 5, 1, SyncScheme::Spin); // root = leader of node 1
        check_bcast(&[5, 3], 64, 2, 1, SyncScheme::Spin); // root = child on node 0
        check_bcast(&[5, 3], 64, 7, 1, SyncScheme::Spin); // root = child on node 1
        check_bcast(&[5, 3], 64, 7, 1, SyncScheme::Barrier);
    }

    #[test]
    fn multi_leader_roots_everywhere() {
        for root in [0usize, 1, 4, 7] {
            check_bcast(&[5, 3], 64, root, 2, SyncScheme::Spin);
            check_bcast(&[5, 3], 64, root, 3, SyncScheme::Barrier);
        }
    }

    #[test]
    fn three_nodes_and_large_payload() {
        check_bcast(&[3, 3, 2], 300 * 1024, 4, 1, SyncScheme::Spin);
        check_bcast(&[3, 3, 2], 300 * 1024, 4, 2, SyncScheme::Spin);
    }

    #[test]
    fn single_node() {
        check_bcast(&[4], 128, 2, 1, SyncScheme::Spin);
        check_bcast(&[4], 128, 0, 2, SyncScheme::Barrier);
    }

    #[test]
    fn transtables_shape() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let t = ctx.tables(env);
            (t.shmem.clone(), t.bridge.clone())
        });
        for (shmem, bridge) in out {
            assert_eq!(shmem, vec![0, 1, 2, 3, 4, 0, 1, 2]);
            assert_eq!(bridge, vec![0, 0, 0, 0, 0, 1, 1, 1]);
        }
    }

    #[test]
    fn hybrid_beats_pure_bcast_at_512kb() {
        // Fig. 13/17's regime: 512 KB broadcast, hybrid must win.
        let nodes: &'static [usize] = &[8, 8];
        let len = 512 * 1024;
        let hybrid = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let mut bc = ctx.bcast_init(env, len, SyncScheme::Spin);
            let data = vec![7u8; len];
            env.harness_sync(&w);
            let t0 = env.vclock();
            let arg = (w.rank() == 0).then_some(&data[..]);
            bc.start_bcast(env, 0, arg);
            bc.wait(env);
            let dt = env.vclock() - t0;
            env.barrier(ctx.shmem());
            bc.free(env);
            dt
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let pure = run_nodes(nodes, move |env| {
            let w = env.world();
            let mut buf = vec![7u8; len];
            env.harness_sync(&w);
            let t0 = env.vclock();
            bcast(env, &w, 0, &mut buf, BcastAlgo::Auto);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(hybrid < pure, "hybrid {hybrid} must beat pure {pure} at 512 KB");
    }
}
