//! The hybrid broadcast (§4.3) behind
//! [`HybridCtx::bcast_init`](super::ctx::HybridCtx::bcast_init), and the
//! rank-translation tables.
//!
//! One shared region per node stores the broadcast payload; only the root
//! may alter it (MPI broadcast semantics). The across-node broadcast runs
//! over the *leaders* — with `k > 1` each leader `j` broadcasts stripe
//! `j` of the payload over its same-index bridge on its own NIC lane —
//! then one yellow sync releases each node's children to read the shared
//! copy, replacing the pure-MPI fan-out to every rank and its per-rank
//! buffer replication.
//!
//! Because broadcast is *rooted* and any rank can be the root, the
//! session needs the root's rank translated into both sub-communicators —
//! the two absolute-to-relative translation tables of
//! `Wrapper_Get_transtable` (their one-off build cost is the quadratic
//! Table-2 "Bcast_transtable" law), cached on the [`HybridCtx`].

use super::ctx::{chunk_bounds, HybridCtx};
use super::shmem::HyWin;
#[cfg(test)]
use super::sync::SyncScheme;
use crate::coll::bcast::{bcast, BcastAlgo};
use crate::mpi::env::ProcEnv;

/// The two translation tables, indexed by parent-communicator rank:
/// `shmem[r]` = r's rank within *its own* node communicator;
/// `bridge[r]` = the bridge rank of r's node (same value for the whole
/// node — what the leaders' broadcast needs as its root).
#[derive(Clone, Debug)]
pub struct TransTables {
    pub shmem: Vec<usize>,
    pub bridge: Vec<usize>,
}

impl TransTables {
    /// `Wrapper_Get_transtable`. One-off cost: quadratic in the parent
    /// size (naive per-rank group scans — the measured Table-2 behaviour).
    /// Prefer the cached [`HybridCtx::tables`].
    pub fn create(env: &mut ProcEnv, ctx: &HybridCtx) -> TransTables {
        let topo = env.topo();
        let members = ctx.parent().members();
        let mut nodes: Vec<usize> = members.iter().map(|&w| topo.node_of(w)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        let mut shmem = Vec::with_capacity(members.len());
        let mut bridge = Vec::with_capacity(members.len());
        for &w in members {
            let n = topo.node_of(w);
            // Naive scans (the quadratic behaviour the paper measured).
            let node_rank = members.iter().filter(|&&v| topo.node_of(v) == n && v < w).count();
            let bridge_idx = nodes.iter().position(|&x| x == n).unwrap();
            shmem.push(node_rank);
            bridge.push(bridge_idx);
        }
        let mgmt = env.state().mgmt.clone();
        env.advance(mgmt.transtable_us(ctx.parent().size()));
        TransTables { shmem, bridge }
    }
}

/// The leaders' across-node broadcast — the (single, `depth = 1`) `Work`
/// stage of the bcast schedule, executed after the root-node red sync and
/// before the yellow release. With `k = 1` (empty `vec_stripes`) this is
/// byte- and vtime-identical to the pre-session `Wrapper_Hy_Bcast`
/// bridge step. (All ranks may read the single shared copy after the
/// release — children perform no explicit copy; they read in place via
/// the local pointer.)
pub(crate) fn bridge(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    vec_stripes: &[(usize, usize)],
    root_node: usize,
    len: usize,
) {
    // Leaders broadcast across the bridge, rooted at the root's node.
    if let Some(j) = ctx.leader_index() {
        let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
        if bridge.size() > 1 {
            if vec_stripes.is_empty() {
                let buf = unsafe { win.win.slice_mut(0, len) };
                bcast(env, &bridge, root_node, buf, BcastAlgo::Auto);
            } else {
                let (off, slen) = vec_stripes[j];
                if slen > 0 {
                    let buf = unsafe { win.win.slice_mut(off, slen) };
                    env.with_nic_lane(j, |env| {
                        bcast(env, &bridge, root_node, buf, BcastAlgo::Auto);
                    });
                }
            }
        }
    }
}

/// One pipelined bridge sub-step (`depth > 1` handles, DESIGN.md §5e):
/// chunk `c` of `nchunks` over leader `j`'s payload range, moved by a
/// flat per-start fan-out instead of the tree — the root-node leader
/// *sends* its chunk to every other node's same-index leader (eager, so
/// it can run inside `start`, before any non-root rank arrives:
/// root-side pipelining), and each receiving leader drains chunks in
/// FIFO order (one tag per start; chunk identity is positional). The
/// message pattern deliberately differs from the `depth = 1` tree — a
/// documented property of the opt-in pipelined mode, traded for
/// launch-at-start overlap.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bridge_chunk(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    vec_stripes: &[(usize, usize)],
    root_node: usize,
    len: usize,
    chunk: usize,
    nchunks: usize,
    tag: i64,
) {
    let Some(j) = ctx.leader_index() else { return };
    let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
    if bridge.size() <= 1 {
        return;
    }
    // Zero-length chunks are still sent/received: chunk identity is
    // positional in the FIFO stream, so receivers (and their probes)
    // must see one message per chunk regardless of the split.
    let (base_off, base_len) = if vec_stripes.is_empty() { (0, len) } else { vec_stripes[j] };
    let (lo, clen) = chunk_bounds(base_len, nchunks, chunk);
    let off = base_off + lo;
    env.with_nic_lane(j, |env| {
        if bridge.rank() == root_node {
            let data = unsafe { win.win.slice(off, clen) };
            for r in 0..bridge.size() {
                if r != root_node {
                    env.send(&bridge, r, tag, data);
                }
            }
        } else {
            let out = unsafe { win.win.slice_mut(off, clen) };
            env.recv_into(&bridge, Some(root_node), tag, out);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::hybrid::LeaderPolicy;

    fn check_bcast(nodes: &'static [usize], len: usize, root: usize, k: usize, scheme: SyncScheme) {
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let mut bc = ctx.bcast_init(env, len, scheme);
            let data = payload(root, len);
            let arg = (w.rank() == root).then_some(&data[..]);
            bc.start_bcast(env, root, arg);
            bc.wait(env);
            let got = bc.window().unwrap().load(env, 0, len);
            env.barrier(ctx.shmem());
            bc.free(env);
            got
        });
        let expect = payload(root, len);
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got, expect, "nodes {nodes:?} root {root} k {k} rank {r}");
        }
    }

    #[test]
    fn roots_leader_and_child() {
        check_bcast(&[5, 3], 64, 0, 1, SyncScheme::Spin); // root = leader of node 0
        check_bcast(&[5, 3], 64, 5, 1, SyncScheme::Spin); // root = leader of node 1
        check_bcast(&[5, 3], 64, 2, 1, SyncScheme::Spin); // root = child on node 0
        check_bcast(&[5, 3], 64, 7, 1, SyncScheme::Spin); // root = child on node 1
        check_bcast(&[5, 3], 64, 7, 1, SyncScheme::Barrier);
    }

    #[test]
    fn multi_leader_roots_everywhere() {
        for root in [0usize, 1, 4, 7] {
            check_bcast(&[5, 3], 64, root, 2, SyncScheme::Spin);
            check_bcast(&[5, 3], 64, root, 3, SyncScheme::Barrier);
        }
    }

    #[test]
    fn three_nodes_and_large_payload() {
        check_bcast(&[3, 3, 2], 300 * 1024, 4, 1, SyncScheme::Spin);
        check_bcast(&[3, 3, 2], 300 * 1024, 4, 2, SyncScheme::Spin);
    }

    #[test]
    fn single_node() {
        check_bcast(&[4], 128, 2, 1, SyncScheme::Spin);
        check_bcast(&[4], 128, 0, 2, SyncScheme::Barrier);
    }

    #[test]
    fn transtables_shape() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let t = ctx.tables(env);
            (t.shmem.clone(), t.bridge.clone())
        });
        for (shmem, bridge) in out {
            assert_eq!(shmem, vec![0, 1, 2, 3, 4, 0, 1, 2]);
            assert_eq!(bridge, vec![0, 0, 0, 0, 0, 1, 1, 1]);
        }
    }

    #[test]
    fn hybrid_beats_pure_bcast_at_512kb() {
        // Fig. 13/17's regime: 512 KB broadcast, hybrid must win.
        let nodes: &'static [usize] = &[8, 8];
        let len = 512 * 1024;
        let hybrid = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let mut bc = ctx.bcast_init(env, len, SyncScheme::Spin);
            let data = vec![7u8; len];
            env.harness_sync(&w);
            let t0 = env.vclock();
            let arg = (w.rank() == 0).then_some(&data[..]);
            bc.start_bcast(env, 0, arg);
            bc.wait(env);
            let dt = env.vclock() - t0;
            env.barrier(ctx.shmem());
            bc.free(env);
            dt
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let pure = run_nodes(nodes, move |env| {
            let w = env.world();
            let mut buf = vec![7u8; len];
            env.harness_sync(&w);
            let t0 = env.vclock();
            bcast(env, &w, 0, &mut buf, BcastAlgo::Auto);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(hybrid < pure, "hybrid {hybrid} must beat pure {pure} at 512 KB");
    }
}
