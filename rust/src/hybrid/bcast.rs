//! `Wrapper_Hy_Bcast` (§4.3) and the rank-translation tables.
//!
//! One shared region per node stores the broadcast payload; only the root
//! may alter it (MPI broadcast semantics). The across-node broadcast runs
//! over the *leaders* (message size unchanged vs pure MPI), then one
//! yellow sync releases each node's children to read the shared copy —
//! replacing the pure-MPI fan-out to every rank and its per-rank buffer
//! replication.
//!
//! Because broadcast is *rooted* and any rank can be the root, the wrapper
//! needs the root's rank translated into both sub-communicators — the two
//! absolute-to-relative translation tables of `Wrapper_Get_transtable`
//! (their one-off build cost is the quadratic Table-2 "Bcast_transtable"
//! law).

use super::package::CommPackage;
use super::shmem::HyWin;
use super::sync::{await_release, red_sync, release, SyncScheme};
use crate::coll::bcast::{bcast, BcastAlgo};
use crate::mpi::env::ProcEnv;

/// The two translation tables, indexed by parent-communicator rank:
/// `shmem[r]` = r's rank within *its own* node communicator;
/// `bridge[r]` = the bridge rank of r's node (same value for the whole
/// node — what the leaders' broadcast needs as its root).
#[derive(Clone, Debug)]
pub struct TransTables {
    pub shmem: Vec<usize>,
    pub bridge: Vec<usize>,
}

impl TransTables {
    /// `Wrapper_Get_transtable`. One-off cost: quadratic in the parent
    /// size (naive per-rank group scans — the measured Table-2 behaviour).
    pub fn create(env: &mut ProcEnv, pkg: &CommPackage) -> TransTables {
        let topo = env.topo();
        let members = pkg.parent.members();
        let mut nodes: Vec<usize> = members.iter().map(|&w| topo.node_of(w)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        let mut shmem = Vec::with_capacity(members.len());
        let mut bridge = Vec::with_capacity(members.len());
        for &w in members {
            let n = topo.node_of(w);
            // Naive scans (the quadratic behaviour the paper measured).
            let node_rank = members.iter().filter(|&&v| topo.node_of(v) == n && v < w).count();
            let bridge_idx = nodes.iter().position(|&x| x == n).unwrap();
            shmem.push(node_rank);
            bridge.push(bridge_idx);
        }
        let mgmt = env.state().mgmt.clone();
        env.advance(mgmt.transtable_us(pkg.parent.size()));
        TransTables { shmem, bridge }
    }
}

/// `Wrapper_Hy_Bcast`: broadcast `data` (present only at `root`, a parent
/// rank) to all ranks. After the call every rank can read the payload at
/// offset 0 of the node's shared window (the returned `bcast_addr` of the
/// paper's interface); `len` is the payload size in bytes.
pub fn hy_bcast(
    env: &mut ProcEnv,
    pkg: &CommPackage,
    win: &mut HyWin,
    tables: &TransTables,
    root: usize,
    data: Option<&[u8]>,
    len: usize,
    scheme: SyncScheme,
) {
    let me = pkg.parent.rank();
    let root_node = tables.bridge[root];
    let root_is_leader = tables.shmem[root] == 0;

    // The root stores the payload into its node's shared region (only the
    // root is eligible to alter the broadcast data, §4.3).
    if me == root {
        let d = data.expect("root must supply the broadcast payload");
        assert_eq!(d.len(), len);
        win.store(env, 0, d);
    }
    // If the root is a child, its leader must observe the payload before
    // forwarding across the bridge: red sync on the root's node.
    if !root_is_leader && tables.bridge[me] == root_node {
        red_sync(env, pkg);
    }
    // Leaders broadcast across the bridge, rooted at the root's node.
    if let Some(bridge) = &pkg.bridge {
        if bridge.size() > 1 {
            let buf = unsafe { win.win.slice_mut(0, len) };
            bcast(env, bridge, root_node, buf, BcastAlgo::Auto);
        }
        release(env, pkg, win, scheme);
    } else {
        await_release(env, pkg, win, scheme);
    }
    // All ranks may now read the single shared copy (children perform no
    // explicit copy here — they read in place via the local pointer).
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};

    fn check_bcast(nodes: &'static [usize], len: usize, root: usize, scheme: SyncScheme) {
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let mut win = pkg.alloc_shared(env, len, 1, 1);
            let tables = TransTables::create(env, &pkg);
            let data = payload(root, len);
            let arg = if w.rank() == root { Some(&data[..]) } else { None };
            hy_bcast(env, &pkg, &mut win, &tables, root, arg, len, scheme);
            let got = win.load(env, 0, len);
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            got
        });
        let expect = payload(root, len);
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got, expect, "nodes {nodes:?} root {root} rank {r}");
        }
    }

    #[test]
    fn roots_leader_and_child() {
        check_bcast(&[5, 3], 64, 0, SyncScheme::Spin); // root = leader of node 0
        check_bcast(&[5, 3], 64, 5, SyncScheme::Spin); // root = leader of node 1
        check_bcast(&[5, 3], 64, 2, SyncScheme::Spin); // root = child on node 0
        check_bcast(&[5, 3], 64, 7, SyncScheme::Spin); // root = child on node 1
        check_bcast(&[5, 3], 64, 7, SyncScheme::Barrier);
    }

    #[test]
    fn three_nodes_and_large_payload() {
        check_bcast(&[3, 3, 2], 300 * 1024, 4, SyncScheme::Spin);
    }

    #[test]
    fn single_node() {
        check_bcast(&[4], 128, 2, SyncScheme::Spin);
        check_bcast(&[4], 128, 0, SyncScheme::Barrier);
    }

    #[test]
    fn transtables_shape() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let t = TransTables::create(env, &pkg);
            (t.shmem, t.bridge)
        });
        for (shmem, bridge) in out {
            assert_eq!(shmem, vec![0, 1, 2, 3, 4, 0, 1, 2]);
            assert_eq!(bridge, vec![0, 0, 0, 0, 0, 1, 1, 1]);
        }
    }

    #[test]
    fn hybrid_beats_pure_bcast_at_512kb() {
        // Fig. 13/17's regime: 512 KB broadcast, hybrid must win.
        let nodes: &'static [usize] = &[8, 8];
        let len = 512 * 1024;
        let hybrid = run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let mut win = pkg.alloc_shared(env, len, 1, 1);
            let tables = TransTables::create(env, &pkg);
            let data = vec![7u8; len];
            env.harness_sync(&w);
            let t0 = env.vclock();
            let arg = if w.rank() == 0 { Some(&data[..]) } else { None };
            hy_bcast(env, &pkg, &mut win, &tables, 0, arg, len, SyncScheme::Spin);
            let dt = env.vclock() - t0;
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            dt
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        let pure = run_nodes(nodes, move |env| {
            let w = env.world();
            let mut buf = vec![7u8; len];
            env.harness_sync(&w);
            let t0 = env.vclock();
            bcast(env, &w, 0, &mut buf, BcastAlgo::Auto);
            env.vclock() - t0
        })
        .into_iter()
        .fold(0.0f64, f64::max);
        assert!(hybrid < pure, "hybrid {hybrid} must beat pure {pure} at 512 KB");
    }
}
