//! The hybrid-collective **session** API: one context object, persistent
//! per-collective handles.
//!
//! The paper's §4 wrappers grew here as a pile of free functions, each
//! with its own setup object (`comm_package`, `AllgatherParam`,
//! `TransTables`, `alloc_*_win`) — exactly the leaked design detail §4
//! warns the user-facing API against. [`HybridCtx`] folds all of it
//! behind two calls:
//!
//! ```text
//! let ctx = HybridCtx::create(env, &comm, LeaderPolicy::Leaders(2));
//! let mut ag = ctx.allgather_init(env, msg, SyncScheme::Spin);   // one-off
//! loop {
//!     ag.start_allgather(env, my_block);   // stage operand (local)
//!     ag.wait(env);                        // sync + bridge + release
//!     // read the gathered result in place: ag.result_view(..)
//! }
//! ag.free(env);
//! ```
//!
//! `*_init` is collective and binds *everything* one-off: communicator
//! splits (done once per context), the shared window, size sets,
//! translation tables, bridge recvcounts/displs, sync-scheme and step-1
//! method selection — the `MPI_Allreduce_init` persistent-collective
//! shape. `start/wait` is the steady-state pair the paper measures.
//!
//! ## Multi-leader bridges (arXiv 2007.06892)
//!
//! A context owns a generalized **leader set**: `k ≥ 1` leaders per node
//! (the `k` lowest node-local ranks; `k` is clamped to the smallest node
//! population so every bridge has exactly one member per node). Leader
//! `j` joins bridge communicator `j` — over the `j`-th leaders of every
//! node — and every hybrid collective's bridge step stripes its per-node
//! payload across the leader set: leader `j` moves stripe `j` of each
//! node block, bound to NIC lane `j % nic_lanes` so the stripes genuinely
//! overlap on the wire ([`NetModel::nic_lanes`]). With `k = 1` every code
//! path, message and virtual-time charge is bit-identical to the
//! pre-session single-leader implementation.
//!
//! [`NetModel::nic_lanes`]: crate::mpi::net::NetModel::nic_lanes

use super::allgather::AllgatherParam;
use super::allreduce::AllreduceMethod;
use super::bcast::TransTables;
use super::progress::{self, HyReq, RootPolicy, Scope, Schedule, Stage};
use super::shmem::HyWin;
use super::sync::SyncScheme;
use crate::analysis::race;
use crate::analysis::schedule::{Access, CollModel, MsgModel, RankSchedule, StageModel};
use crate::mpi::comm::UNDEFINED;
use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::fault::{self, RankFailed};
use crate::mpi::topo::Placement;
use crate::mpi::{Communicator, Datatype, ReduceOp};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

/// How many leaders each node contributes to the bridge step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LeaderPolicy {
    /// One leader per node — the paper's §4 configuration.
    Single,
    /// `k` leaders per node (clamped to the smallest node population of
    /// the parent communicator, so every same-index bridge has exactly
    /// one member per node even on §5.2.2 irregular shapes).
    Leaders(usize),
}

impl LeaderPolicy {
    /// The requested leader count (≥ 1, before clamping).
    pub fn requested(self) -> usize {
        match self {
            LeaderPolicy::Single => 1,
            LeaderPolicy::Leaders(k) => k.max(1),
        }
    }
}

/// One leader's view of the striped bridge layout: for each node `i`,
/// `counts[i]` bytes of that node's block starting at window offset
/// `offsets[i]`. Built once at `*_init` time, indexed by bridge rank.
pub(crate) struct StripeTable {
    pub(crate) counts: Vec<usize>,
    pub(crate) offsets: Vec<usize>,
}

/// Chunk `c` of `n` over a `len`-byte range: `(offset, len)` with
/// balanced integer division (the pipelined bridge sub-steps of
/// `depth > 1` handles; byte-granular — chunk boundaries never split a
/// reduction element because only the copy-only rooted ops chunk).
pub(crate) fn chunk_bounds(len: usize, n: usize, c: usize) -> (usize, usize) {
    let lo = len * c / n;
    let hi = len * (c + 1) / n;
    (lo, hi - lo)
}

/// Stripe `j` of `k` over `len` bytes in `align`-byte units:
/// `(offset, len)` with balanced integer division (the last stripe
/// absorbs the remainder; `len` must be a multiple of `align`).
pub(crate) fn stripe_bounds(len: usize, k: usize, j: usize, align: usize) -> (usize, usize) {
    debug_assert_eq!(len % align, 0);
    let units = len / align;
    let lo = units * j / k * align;
    let hi = units * (j + 1) / k * align;
    (lo, hi - lo)
}

/// FNV-1a over the sorted survivor world-rank set: the shrink round's
/// scope key. Same survivors ⇒ same key on every participant,
/// regardless of which parent communicator they derived the set from;
/// different sessions' concurrent agreements (disjoint or overlapping
/// member sets) collide only if their survivor sets are identical — in
/// which case the agreements are interchangeable anyway. Public so the
/// exploration model ([`analysis::explore::ShrinkModel`]) tags its
/// protocol messages with the *same* scope the implementation computes.
///
/// [`analysis::explore::ShrinkModel`]: crate::analysis::explore::ShrinkModel
pub fn shrink_scope_key(survivors: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in survivors {
        for b in (w as u64).to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
    }
    h
}

/// The hybrid session context: the two-level (node + `k` bridges)
/// communicator split of one parent communicator, plus the cached one-off
/// wrapper state every persistent collective on it shares.
pub struct HybridCtx {
    parent: Communicator,
    shmem: Communicator,
    /// Effective leaders per node (requested, clamped ≥1 and ≤ smallest
    /// node population).
    k: usize,
    /// My leader index `j` (= my node-local rank) if I am one of the
    /// node's `k` leaders.
    my_leader: Option<usize>,
    /// My same-index bridge communicator (`Some` on leaders only; its
    /// rank is my node's index among the parent's nodes).
    bridge: Option<Communicator>,
    /// Node-local leader group (`Some` on leaders only, and only when
    /// `k > 1` — the `k = 1` session charges exactly the pre-session
    /// two splits).
    leaders: Option<Communicator>,
    shmem_size: usize,
    /// Number of nodes hosting members of `parent` (= every bridge's
    /// size; known on children too, unlike raw MPI).
    bridge_size: usize,
    /// My node's index among the parent's nodes (= my bridge rank on
    /// leaders; valid on children too).
    my_node_index: usize,
    /// Per-node parent populations in node-index order, derived from the
    /// topology (uncharged — the library knows the layout natively).
    populations: Vec<usize>,
    /// Cached `Wrapper_ShmemcommSizeset_gather` result (charged once).
    sizeset: RefCell<Option<Rc<Vec<usize>>>>,
    /// Cached `Wrapper_Get_transtable` result (charged once).
    tables: RefCell<Option<Rc<TransTables>>>,
}

impl HybridCtx {
    /// Create the session: split `parent` into the node-level
    /// communicator and `k` same-index bridge communicators (plus, for
    /// `k > 1`, the node-local leader group). Collective over `parent`.
    ///
    /// One-off cost: `1 + k` `MPI_Comm_split`s (the Table-2
    /// "Communicator" law per split; `k = 1` charges exactly the two
    /// splits of the paper's `Wrapper_MPI_ShmemBridgeComm_create`), plus
    /// one more split for the leader group when `k > 1`.
    pub fn create(env: &mut ProcEnv, parent: &Communicator, policy: LeaderPolicy) -> Rc<HybridCtx> {
        let shmem = env.split_type_shared(parent);
        let (populations, my_node_index) = {
            let topo = env.topo();
            let my_node = topo.node_of(env.world_rank());
            let mut nodes: Vec<usize> =
                parent.members().iter().map(|&w| topo.node_of(w)).collect();
            nodes.sort_unstable();
            nodes.dedup();
            let pops: Vec<usize> = nodes
                .iter()
                .map(|&n| parent.members().iter().filter(|&&w| topo.node_of(w) == n).count())
                .collect();
            let idx = nodes.iter().position(|&n| n == my_node).expect("my node hosts me");
            (pops, idx)
        };
        // Same rule as `effective_leaders`, reusing the populations
        // already derived above instead of a second member scan.
        let k = clamp_leaders(
            policy.requested(),
            *populations.iter().min().expect("nodes are non-empty"),
        );
        let my_leader = (shmem.rank() < k).then_some(shmem.rank());
        let mut bridge = None;
        for j in 0..k {
            let color = if shmem.rank() == j { 0 } else { UNDEFINED };
            let c = env.split(parent, color, parent.rank() as i64);
            if shmem.rank() == j {
                bridge = c;
            }
        }
        let leaders = if k > 1 {
            let color = if shmem.rank() < k { my_node_index as i64 } else { UNDEFINED };
            env.split(parent, color, parent.rank() as i64)
        } else {
            None
        };
        Rc::new(HybridCtx {
            parent: parent.clone(),
            shmem_size: shmem.size(),
            bridge_size: populations.len(),
            shmem,
            k,
            my_leader,
            bridge,
            leaders,
            my_node_index,
            populations,
            sizeset: RefCell::new(None),
            tables: RefCell::new(None),
        })
    }

    /// The effective (clamped) leader count a session over `comm` uses
    /// for `requested` leaders per node: at least 1, at most the
    /// smallest per-node population of `comm`'s members. What
    /// [`HybridCtx::create`] applies and what the plan cache keys its
    /// sessions by.
    pub fn effective_leaders(env: &ProcEnv, comm: &Communicator, requested: usize) -> usize {
        let topo = env.topo();
        let mut pops: HashMap<usize, usize> = HashMap::new();
        for &w in comm.members() {
            *pops.entry(topo.node_of(w)).or_insert(0) += 1;
        }
        clamp_leaders(requested, pops.values().copied().min().unwrap_or(1))
    }

    /// ULFM-style `MPI_Comm_shrink` over the session: build a fresh
    /// session over the parent's *survivors* — every member not in the
    /// dead registry — preserving survivor rank order and the leader
    /// policy (the current effective `k`, re-clamped against the shrunken
    /// node populations).
    ///
    /// ## Epoch-tagged restartable agreement (ISSUE 8, DESIGN.md §6b)
    ///
    /// The old parent's collectives are unusable (a member is dead), so
    /// agreement runs over the control plane — and because the
    /// *coordinator itself* may die mid-agreement, every wait is a
    /// bounded park ([`ProcEnv::oob_recv_deadline`]) and the whole
    /// protocol is a restartable round:
    ///
    /// - **Round state.** Each participant derives, from the shared dead
    ///   registry, the survivor set (`parent ∖ dead`), the **epoch**
    ///   (global dead-rank count — monotone, so a later round always
    ///   carries a strictly higher epoch), and the **scope** (FNV-1a
    ///   hash of the sorted survivor world-rank set — the agreement's
    ///   identity, shared even by survivors whose `parent` comms differ
    ///   after a death *during rebuild* left some of them one session
    ///   ahead).
    /// - **Coordinator** = lowest survivor. It collects one
    ///   `[epoch, scope, vclock]` request per child, allocates the new
    ///   context id, and answers all children with
    ///   `[epoch, scope, id, max-clock]` — the arrival-max rule the
    ///   barrier inside `MPI_Comm_split` would have applied.
    /// - **Stale messages** — requests or replies whose scope does not
    ///   match the receiver's current round (traffic from a lower epoch,
    ///   or from a concurrent shrink of a *different* session) — are
    ///   discarded on receipt, and leftovers are swept by
    ///   [`ProcEnv::oob_drain`] once agreement completes.
    /// - **Restart.** On a deadline expiry each side re-derives the
    ///   survivor set; if it changed (a death registered — including the
    ///   coordinator's own), the round restarts under the higher epoch
    ///   with the next-lowest survivor coordinating. Unchanged-set
    ///   expiries merely resend (children) or re-arm (coordinator), so a
    ///   slow survivor is never falsely abandoned.
    ///
    /// Cascading deaths therefore converge to the final survivor set:
    /// any prefix of the protocol invalidated by a new death is
    /// discarded wholesale by the scope check and rebuilt from the
    /// registry. After agreement every survivor charges the
    /// [detection-cost model](ProcEnv::charge_detection) for the newly
    /// shrunk-out members, synchronizes to the agreed clock and charges
    /// the Table-2 split law for the shrunken group before the new
    /// session's own splits run. Collective over the survivors only; a
    /// registered-dead rank must not call this. Old windows and handles
    /// on `self` are *not* freed here — rebuild the handles you still
    /// need with [`HyColl::rebuild`] (see [`HybridCtx::run_resilient`]
    /// for the full detect → shrink → rebuild → retry driver) and
    /// abandon the rest.
    pub fn shrink(self: &Rc<Self>, env: &mut ProcEnv) -> Rc<HybridCtx> {
        let world = env.world();
        let me = env.world_rank();
        let parent = &self.parent;
        let survivors_now = |env: &ProcEnv| -> (Vec<usize>, u64, u64) {
            let s: Vec<usize> = parent
                .members()
                .iter()
                .copied()
                .filter(|&w| !env.state().is_dead(w))
                .collect();
            let epoch = env.state().dead_ranks().len() as u64;
            let scope = shrink_scope_key(&s);
            (s, epoch, scope)
        };
        let (id, vmax, survivors) = 'round: loop {
            let (survivors, epoch, scope) = survivors_now(env);
            assert!(
                survivors.len() < parent.size(),
                "shrink without a registered death on the parent communicator"
            );
            let my_idx = survivors
                .iter()
                .position(|&w| w == me)
                .expect("a registered-dead rank must not call shrink");
            if my_idx == 0 {
                // Coordinator: one directed bounded receive per child (not
                // ANY_SOURCE — directed re-arming never consults the
                // dead-containing member list, so a slow survivor cannot
                // be falsely declared failed).
                let mut vmax = env.vclock();
                for &w in &survivors[1..] {
                    loop {
                        let deadline = Instant::now() + fault::detect_bound();
                        match env.oob_recv_deadline(&world, Some(w), opcode::CTRL_SHRINK, deadline)
                        {
                            Some((_, data)) if data.len() >= 24 => {
                                let m_scope = u64::from_le_bytes(data[8..16].try_into().unwrap());
                                if m_scope != scope {
                                    continue; // stale epoch / foreign session: discard
                                }
                                let v = f64::from_le_bytes(data[16..24].try_into().unwrap());
                                vmax = vmax.max(v);
                                break;
                            }
                            Some(_) => continue, // malformed: discard
                            None => {
                                let (_, _, s2) = survivors_now(env);
                                if s2 != scope {
                                    continue 'round; // a death registered: restart
                                }
                                // Unchanged set: the child is slow, not
                                // dead — re-arm and keep waiting.
                            }
                        }
                    }
                }
                let cid = env.state().alloc_comm_id();
                let mut reply = Vec::with_capacity(32);
                reply.extend_from_slice(&epoch.to_le_bytes());
                reply.extend_from_slice(&scope.to_le_bytes());
                reply.extend_from_slice(&cid.to_le_bytes());
                reply.extend_from_slice(&vmax.to_le_bytes());
                for &w in &survivors[1..] {
                    env.oob_send(&world, w, opcode::CTRL_SHRINK_ACK, &reply);
                }
                break (cid, vmax, survivors);
            } else {
                let coord = survivors[0];
                let mut req = Vec::with_capacity(24);
                req.extend_from_slice(&epoch.to_le_bytes());
                req.extend_from_slice(&scope.to_le_bytes());
                req.extend_from_slice(&env.vclock().to_le_bytes());
                env.oob_send(&world, coord, opcode::CTRL_SHRINK, &req);
                loop {
                    let deadline = Instant::now() + fault::detect_bound();
                    match env.oob_recv_deadline(&world, Some(coord), opcode::CTRL_SHRINK_ACK, deadline)
                    {
                        Some((_, data)) if data.len() >= 32 => {
                            let m_scope = u64::from_le_bytes(data[8..16].try_into().unwrap());
                            if m_scope != scope {
                                continue; // stale epoch / foreign session: discard
                            }
                            let cid = u64::from_le_bytes(data[16..24].try_into().unwrap());
                            let v = f64::from_le_bytes(data[24..32].try_into().unwrap());
                            break 'round (cid, v, survivors);
                        }
                        Some(_) => continue, // malformed: discard
                        None => {
                            let (_, _, s2) = survivors_now(env);
                            if s2 != scope {
                                continue 'round; // coordinator (or peer) died: restart
                            }
                            // Unchanged set: the request (or its reply)
                            // may be racing a coordinator restart — resend
                            // so a restarted round cannot strand us.
                            env.oob_send(&world, coord, opcode::CTRL_SHRINK, &req);
                        }
                    }
                }
            }
        };
        // Post-agreement hygiene: duplicate requests re-sent during the
        // bounded-park loop (and replies a restarted coordinator
        // superseded) must never alias a later epoch's traffic. A foreign
        // session's early request swept here is re-sent by its owner's
        // own bounded-park loop, so the drain is always safe.
        env.oob_drain(&world, None, opcode::CTRL_SHRINK);
        env.oob_drain(&world, None, opcode::CTRL_SHRINK_ACK);
        let my_rank = survivors
            .iter()
            .position(|&w| w == me)
            .expect("agreement preserves my membership");
        let spans = {
            let topo = env.topo();
            let node0 = topo.node_of(survivors[0]);
            survivors.iter().any(|&w| topo.node_of(w) != node0)
        };
        // Synchronize to the agreed clock and charge the Table-2 split
        // law — identical on every survivor, so the shrunken session
        // starts from a common virtual time — then charge the
        // detection-cost model for the members shrunk out this epoch
        // (ISSUE 8: recovery vtime includes time-to-detect).
        let dv = (vmax - env.vclock()).max(0.0);
        let cost = env.state().mgmt.comm_split_us(survivors.len());
        env.advance(dv + cost);
        env.charge_detection((parent.size() - survivors.len()) as f64);
        let shrunk = Communicator::new(id, Arc::new(survivors), my_rank, spans);
        let policy =
            if self.k == 1 { LeaderPolicy::Single } else { LeaderPolicy::Leaders(self.k) };
        HybridCtx::create(env, &shrunk, policy)
    }

    // ---- identity ---------------------------------------------------------

    /// The parent communicator this session was derived from.
    pub fn parent(&self) -> &Communicator {
        &self.parent
    }

    /// Export the [`shrink`](HybridCtx::shrink) agreement this session
    /// would run — its parent members, their topology nodes and the
    /// currently registered deaths — as a checkable protocol model for
    /// the exhaustive explorer (DESIGN.md §6c). Like `shrink` itself,
    /// this requires at least one registered death. Layer fault choice
    /// points, a `Reelect` root or a mutation onto the returned model
    /// with its builder methods.
    pub fn export_shrink_model(&self, env: &ProcEnv) -> crate::analysis::explore::ShrinkModel {
        let members: Vec<usize> = self.parent.members().to_vec();
        let topo = env.topo();
        let nodes: Vec<usize> = members.iter().map(|&w| topo.node_of(w)).collect();
        let dead: Vec<usize> =
            members.iter().copied().filter(|&w| env.state().is_dead(w)).collect();
        crate::analysis::explore::ShrinkModel::new(&members, &nodes, &dead)
    }

    /// Node-level communicator (`MPI_Comm_split_type(…SHARED…)`).
    pub fn shmem(&self) -> &Communicator {
        &self.shmem
    }

    /// My same-index bridge communicator (`Some` on leaders only).
    pub fn bridge(&self) -> Option<&Communicator> {
        self.bridge.as_ref()
    }

    /// The node-local leader group (`Some` on leaders when `k > 1`).
    pub(crate) fn leaders(&self) -> Option<&Communicator> {
        self.leaders.as_ref()
    }

    /// Effective leaders per node (requested, clamped to the smallest
    /// node population).
    pub fn leaders_per_node(&self) -> usize {
        self.k
    }

    /// My leader index `j ∈ 0..k`, or `None` on children.
    pub fn leader_index(&self) -> Option<usize> {
        self.my_leader
    }

    /// Am I the node's *primary* leader (leader 0 — the rank that
    /// allocates windows and posts the yellow-sync release)?
    pub fn is_leader(&self) -> bool {
        self.my_leader == Some(0)
    }

    /// `shmemcomm_size`.
    pub fn shmem_size(&self) -> usize {
        self.shmem_size
    }

    /// Number of nodes hosting members of the parent (= bridge size).
    pub fn nnodes(&self) -> usize {
        self.bridge_size
    }

    /// My node's index among the parent's nodes (= my bridge rank on
    /// leaders; valid on children too).
    pub fn node_index(&self) -> usize {
        self.my_node_index
    }

    // ---- cached one-off wrapper state -------------------------------------

    /// `Wrapper_ShmemcommSizeset_gather`, cached: every node's
    /// shared-memory communicator size. The primary leaders pay one real
    /// bridge allgather the first time (the wrapper's traffic); everyone
    /// else derives the identical values from the topology.
    pub fn sizeset(&self, env: &mut ProcEnv) -> Rc<Vec<usize>> {
        if let Some(s) = self.sizeset.borrow().as_ref() {
            return s.clone();
        }
        let s = Rc::new(if self.my_leader == Some(0) {
            let bridge = self.bridge.as_ref().expect("leaders hold a bridge");
            let mine = (self.shmem_size as u64).to_le_bytes();
            let mut out = vec![0u8; 8 * bridge.size()];
            crate::coll::allgather(env, bridge, &mine, &mut out, crate::coll::AllgatherAlgo::Bruck);
            out.chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
                .collect()
        } else {
            self.populations.clone()
        });
        *self.sizeset.borrow_mut() = Some(s.clone());
        s
    }

    /// `Wrapper_Get_transtable`, cached: the absolute→relative rank
    /// translation tables of the rooted collectives (one-off cost: the
    /// quadratic Table-2 law, charged on first use).
    pub fn tables(&self, env: &mut ProcEnv) -> Rc<TransTables> {
        if let Some(t) = self.tables.borrow().as_ref() {
            return t.clone();
        }
        let t = Rc::new(TransTables::create(env, self));
        *self.tables.borrow_mut() = Some(t.clone());
        t
    }

    /// `Wrapper_MPI_Sharedmemory_alloc(msize, bsize, flag, …)`: the
    /// primary leader allocates `msize·bsize·flag` bytes shared by the
    /// node; everyone else attaches. One-off cost: the Table-2 "Allocate"
    /// law (base charge from the window allocation itself; the
    /// multi-node saturation term charged here).
    pub fn alloc_shared(&self, env: &mut ProcEnv, msize: usize, bsize: usize, flag: usize) -> HyWin {
        let total = msize * bsize * flag;
        let my_contrib = if self.is_leader() { total } else { 0 };
        let raw = env.win_allocate_shared(&self.shmem, my_contrib);
        let mgmt = env.state().mgmt.clone();
        let extra = mgmt.alloc_us(self.bridge_size) - mgmt.alloc_us(1);
        env.advance(extra.max(0.0));
        HyWin::new(raw, total)
    }

    // ---- stripe planning --------------------------------------------------

    /// Per-leader stripe tables over the per-node blocks described by
    /// `param` (empty for `k = 1`, which runs the unstriped legacy
    /// bridge). `align` keeps reduction stripes element-aligned.
    fn node_stripes(&self, param: &AllgatherParam, align: usize) -> Vec<StripeTable> {
        if self.k == 1 {
            return Vec::new();
        }
        (0..self.k)
            .map(|j| {
                let mut counts = Vec::with_capacity(self.bridge_size);
                let mut offsets = Vec::with_capacity(self.bridge_size);
                for i in 0..self.bridge_size {
                    let (lo, len) = stripe_bounds(param.recvcounts[i], self.k, j, align);
                    counts.push(len);
                    offsets.push(param.displs[i] + lo);
                }
                StripeTable { counts, offsets }
            })
            .collect()
    }

    /// Per-leader `(offset, len)` stripes over one `len`-byte vector
    /// (empty for `k = 1`).
    fn vec_stripes(&self, len: usize, align: usize) -> Vec<(usize, usize)> {
        if self.k == 1 {
            return Vec::new();
        }
        (0..self.k).map(|j| stripe_bounds(len, self.k, j, align)).collect()
    }

    /// Extra one-off bookkeeping for the additional `k − 1` stripe
    /// tables, charged per the same Table-2 parameter law as the first.
    fn charge_stripe_tables(&self, env: &mut ProcEnv) {
        if self.k > 1 {
            let mgmt = env.state().mgmt.clone();
            env.advance(mgmt.allgather_param_us(self.bridge_size) * (self.k - 1) as f64);
        }
    }

    // ---- persistent-collective inits --------------------------------------

    /// Persistent hybrid allgather: every `start` stages the caller's
    /// `count`-byte block at its parent-rank slot; `wait` completes the
    /// collective and leaves the rank-ordered result at window offset 0.
    pub fn allgather_init(self: &Rc<Self>, env: &mut ProcEnv, count: usize, scheme: SyncScheme) -> HyColl {
        assert_block_placement(env, "allgather");
        let sizeset = self.sizeset(env);
        let param = AllgatherParam::create(env, self, count, &sizeset);
        let win = self.alloc_shared(env, count, 1, self.parent.size());
        let stripes = self.node_stripes(&param, 1);
        self.charge_stripe_tables(env);
        self.build(
            HyOp::Allgather,
            count,
            Datatype::U8,
            None,
            scheme,
            AllreduceMethod::Method1,
            win,
            Some(param),
            None,
            Vec::new(),
            stripes,
            Vec::new(),
            RootPolicy::PerStart,
            1,
        )
    }

    /// Persistent hybrid broadcast of `len`-byte payloads. The root is
    /// bound per `start` (the window and translation tables are
    /// root-independent — a documented deviation from `MPI_Bcast_init`,
    /// which SUMMA's rotating-root phases rely on). For the strict
    /// `MPI_Bcast_init` shape — and the root-side pipelining it enables —
    /// see [`HybridCtx::bcast_init_split`].
    pub fn bcast_init(self: &Rc<Self>, env: &mut ProcEnv, len: usize, scheme: SyncScheme) -> HyColl {
        self.bcast_init_split(env, len, scheme, RootPolicy::PerStart, 1)
    }

    /// [`HybridCtx::bcast_init`] with an explicit [`RootPolicy`] and a
    /// bridge pipelining `depth`. With `depth > 1` the leaders' bridge
    /// step becomes `depth` chunked sub-steps over a flat per-start
    /// fan-out, so the root's node can inject chunks inside `start` —
    /// before any non-root rank has arrived — and receivers drain them
    /// chunk-by-chunk via probes (`HyReq::test`). `depth = 1` keeps the
    /// tree bridge of the blocking path (bit- and vtime-identical).
    pub fn bcast_init_split(
        self: &Rc<Self>,
        env: &mut ProcEnv,
        len: usize,
        scheme: SyncScheme,
        policy: RootPolicy,
        depth: usize,
    ) -> HyColl {
        assert!(depth >= 1, "pipelining depth must be at least 1");
        let tables = self.tables(env);
        let win = self.alloc_shared(env, len, 1, 1);
        let vec_stripes = self.vec_stripes(len, 1);
        self.build(
            HyOp::Bcast,
            len,
            Datatype::U8,
            None,
            scheme,
            AllreduceMethod::Method1,
            win,
            None,
            Some(tables),
            Vec::new(),
            Vec::new(),
            vec_stripes,
            policy,
            depth,
        )
    }

    /// Persistent hybrid allreduce of `msize`-byte operands. `method`
    /// selects the §5.2.4 step-1 implementation; [`AllreduceMethod::Tuned`]
    /// resolves the 2 KB cutoff here, once.
    pub fn allreduce_init(
        self: &Rc<Self>,
        env: &mut ProcEnv,
        dtype: Datatype,
        rop: ReduceOp,
        msize: usize,
        method: AllreduceMethod,
        scheme: SyncScheme,
    ) -> HyColl {
        assert_eq!(msize % dtype.size(), 0);
        let method = resolve_method(method, msize);
        let win = self.alloc_shared(env, msize, 1, self.shmem_size + 2);
        let vec_stripes = self.vec_stripes(msize, dtype.size());
        self.build(
            HyOp::Allreduce,
            msize,
            dtype,
            Some(rop),
            scheme,
            method,
            win,
            None,
            None,
            Vec::new(),
            Vec::new(),
            vec_stripes,
            RootPolicy::PerStart,
            1,
        )
    }

    /// Persistent hybrid reduce-scatter with `count`-byte result blocks.
    pub fn reduce_scatter_init(
        self: &Rc<Self>,
        env: &mut ProcEnv,
        dtype: Datatype,
        rop: ReduceOp,
        count: usize,
        method: AllreduceMethod,
        scheme: SyncScheme,
    ) -> HyColl {
        assert_block_placement(env, "reduce_scatter");
        assert_eq!(count % dtype.size(), 0);
        let total = count * self.parent.size();
        let method = resolve_method(method, total);
        let sizeset = self.sizeset(env);
        let win = self.alloc_shared(env, total, 1, self.shmem_size + 2);
        // Per-node bridge blocks: node i contributes sizeset[i]·count.
        let node_counts: Vec<usize> = sizeset.iter().map(|&s| s * count).collect();
        let param = AllgatherParam {
            displs: crate::coll::displs_of(&node_counts),
            recvcounts: node_counts,
        };
        let stripes = self.node_stripes(&param, dtype.size());
        let vec_stripes = self.vec_stripes(total, dtype.size());
        self.charge_stripe_tables(env);
        self.build(
            HyOp::ReduceScatter,
            count,
            dtype,
            Some(rop),
            scheme,
            method,
            win,
            Some(param),
            None,
            sizeset.to_vec(),
            stripes,
            vec_stripes,
            RootPolicy::PerStart,
            1,
        )
    }

    /// Persistent hybrid gather of `count`-byte blocks (root bound per
    /// `start`, like [`HybridCtx::bcast_init`]; pass
    /// [`RootPolicy::Fixed`] via [`HybridCtx::gather_init_split`] for the
    /// strict persistent shape).
    pub fn gather_init(self: &Rc<Self>, env: &mut ProcEnv, count: usize, scheme: SyncScheme) -> HyColl {
        self.gather_init_split(env, count, scheme, RootPolicy::PerStart)
    }

    /// [`HybridCtx::gather_init`] with an explicit [`RootPolicy`].
    /// (Gather's bridge converges *on* the root, so there is no send-side
    /// pipelining to chunk — the red sync gates every leader.)
    pub fn gather_init_split(
        self: &Rc<Self>,
        env: &mut ProcEnv,
        count: usize,
        scheme: SyncScheme,
        policy: RootPolicy,
    ) -> HyColl {
        assert_block_placement(env, "gather");
        let sizeset = self.sizeset(env);
        let param = AllgatherParam::create(env, self, count, &sizeset);
        let tables = self.tables(env);
        let win = self.alloc_shared(env, count, 1, self.parent.size());
        let stripes = self.node_stripes(&param, 1);
        self.charge_stripe_tables(env);
        self.build(
            HyOp::Gather,
            count,
            Datatype::U8,
            None,
            scheme,
            AllreduceMethod::Method1,
            win,
            Some(param),
            Some(tables),
            Vec::new(),
            stripes,
            Vec::new(),
            policy,
            1,
        )
    }

    /// Persistent hybrid scatter of `count`-byte blocks (root bound per
    /// `start`).
    pub fn scatter_init(self: &Rc<Self>, env: &mut ProcEnv, count: usize, scheme: SyncScheme) -> HyColl {
        self.scatter_init_split(env, count, scheme, RootPolicy::PerStart, 1)
    }

    /// [`HybridCtx::scatter_init`] with an explicit [`RootPolicy`] and
    /// bridge pipelining `depth` (the mirror of
    /// [`HybridCtx::bcast_init_split`]: `depth > 1` turns the root
    /// leaders' bridge scatter into chunked flat sends that launch inside
    /// `start`).
    pub fn scatter_init_split(
        self: &Rc<Self>,
        env: &mut ProcEnv,
        count: usize,
        scheme: SyncScheme,
        policy: RootPolicy,
        depth: usize,
    ) -> HyColl {
        assert!(depth >= 1, "pipelining depth must be at least 1");
        assert_block_placement(env, "scatter");
        let sizeset = self.sizeset(env);
        let param = AllgatherParam::create(env, self, count, &sizeset);
        let tables = self.tables(env);
        let win = self.alloc_shared(env, count, 1, self.parent.size());
        let stripes = self.node_stripes(&param, 1);
        self.charge_stripe_tables(env);
        self.build(
            HyOp::Scatter,
            count,
            Datatype::U8,
            None,
            scheme,
            AllreduceMethod::Method1,
            win,
            Some(param),
            Some(tables),
            Vec::new(),
            stripes,
            Vec::new(),
            policy,
            depth,
        )
    }

    /// Assemble a handle: bind the one-off state and compile the per-rank
    /// stage [`Schedule`] once (the tentpole of DESIGN.md §5e).
    #[allow(clippy::too_many_arguments)]
    fn build(
        self: &Rc<Self>,
        op: HyOp,
        count: usize,
        dtype: Datatype,
        rop: Option<ReduceOp>,
        scheme: SyncScheme,
        method: AllreduceMethod,
        win: HyWin,
        param: Option<AllgatherParam>,
        tables: Option<Rc<TransTables>>,
        sizeset: Vec<usize>,
        stripes: Vec<StripeTable>,
        vec_stripes: Vec<(usize, usize)>,
        policy: RootPolicy,
        depth: usize,
    ) -> HyColl {
        let sched = Schedule::new(compile_stages(self, op, scheme, method, depth, policy, tables.as_deref()));
        HyColl {
            ctx: self.clone(),
            op,
            count,
            dtype,
            rop,
            scheme,
            method,
            win: Some(win),
            param,
            tables,
            sizeset,
            stripes,
            vec_stripes,
            started: false,
            pending_root: 0,
            policy,
            depth,
            sched,
            fail_check: None,
        }
    }
}

/// Compile the per-rank stage chain of one persistent collective — the
/// schedule is built once at `*_init` and re-armed by every `start`.
/// Drive-to-completion executes exactly the old monolithic `wait` body;
/// see the [`progress`] module docs for the parity argument.
fn compile_stages(
    ctx: &HybridCtx,
    op: HyOp,
    scheme: SyncScheme,
    method: AllreduceMethod,
    depth: usize,
    policy: RootPolicy,
    tables: Option<&TransTables>,
) -> Vec<Stage> {
    let leader = ctx.leader_index().is_some();
    let k = ctx.leaders_per_node();
    let mut s = Vec::new();
    let red = |s: &mut Vec<Stage>| {
        s.push(Stage::Arrive(Scope::Node));
        s.push(Stage::Await(Scope::Node));
    };
    // Conditional red sync on the root's node (bcast/scatter): under
    // `Fixed` the condition resolves here, at compile time; under
    // `PerStart` a `RootNode`-scoped pair stays in the schedule and
    // resolves against the pending root at run time.
    let root_sync = |s: &mut Vec<Stage>| match policy {
        RootPolicy::Fixed(root) | RootPolicy::Reelect(root, _) => {
            let t = tables.expect("rooted ops bind translation tables");
            let on_root_node = ctx.node_index() == t.bridge[root];
            let root_is_primary = t.shmem[root] == 0;
            if on_root_node && (!root_is_primary || k > 1) {
                red(s);
            }
        }
        RootPolicy::PerStart => {
            s.push(Stage::Arrive(Scope::RootNode));
            s.push(Stage::Await(Scope::RootNode));
        }
    };
    let leader_barrier = |s: &mut Vec<Stage>| {
        if leader && k > 1 {
            s.push(Stage::Arrive(Scope::Leaders));
            s.push(Stage::Await(Scope::Leaders));
        }
    };
    let work = |s: &mut Vec<Stage>, chunk: usize| {
        s.push(Stage::Work { chunk });
    };

    match op {
        HyOp::Allgather | HyOp::Gather => {
            red(&mut s);
            if leader {
                work(&mut s, 0);
            }
        }
        HyOp::Bcast | HyOp::Scatter => {
            root_sync(&mut s);
            if leader {
                for c in 0..depth {
                    work(&mut s, c);
                }
            }
        }
        HyOp::Allreduce => {
            match method {
                AllreduceMethod::Method1 => {
                    work(&mut s, 0); // MPI_Reduce over the node comm: everyone
                    leader_barrier(&mut s); // leaders 1..k read leader 0's L
                }
                AllreduceMethod::Method2 => {
                    red(&mut s);
                    if leader {
                        work(&mut s, 0); // striped serial fold into L
                    }
                }
                AllreduceMethod::Tuned => unreachable!("Tuned resolves at *_init"),
            }
            if leader {
                work(&mut s, 1); // L→G + bridge allreduce
            }
        }
        HyOp::ReduceScatter => {
            match method {
                AllreduceMethod::Method1 => work(&mut s, 0),
                AllreduceMethod::Method2 => {
                    red(&mut s);
                    if leader {
                        work(&mut s, 0);
                    }
                }
                AllreduceMethod::Tuned => unreachable!("Tuned resolves at *_init"),
            }
            // Step-1 and step-2 stripes partition L differently: every
            // leader must see the complete L (both methods, k > 1).
            leader_barrier(&mut s);
            if leader {
                work(&mut s, 1);
            }
        }
    }

    // Yellow release.
    match scheme {
        SyncScheme::Barrier => red(&mut s),
        SyncScheme::Spin => {
            if leader {
                leader_barrier(&mut s);
                s.push(Stage::YellowPost);
            } else {
                s.push(Stage::YellowWait);
            }
        }
    }
    s
}

/// The one clamp rule: ≥ 1, ≤ the smallest node population.
fn clamp_leaders(requested: usize, min_population: usize) -> usize {
    requested.max(1).min(min_population.max(1))
}

fn assert_block_placement(env: &ProcEnv, op: &str) {
    assert_eq!(
        env.topo().placement(),
        Placement::Block,
        "hybrid {op} assumes block-style rank placement (§4); \
         see [20] for the measures other placements require"
    );
}

fn resolve_method(method: AllreduceMethod, bytes: usize) -> AllreduceMethod {
    match method {
        // Tuned resolves once at `*_init` through the installed
        // process-wide selector (static tables → the Fig. 15
        // `METHOD_CUTOFF_BYTES`; a tuned table or autotuner may move
        // the cutoff). The resolved method is bound into the compiled
        // schedule; later selector swaps never change a live handle.
        AllreduceMethod::Tuned => {
            let m = crate::select::global().allreduce_method(bytes);
            debug_assert!(m != AllreduceMethod::Tuned, "selector returned an unbound method");
            m
        }
        m => m,
    }
}

/// Which collective a [`HyColl`] executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HyOp {
    Allgather,
    Bcast,
    Allreduce,
    ReduceScatter,
    Gather,
    Scatter,
}

/// A persistent hybrid collective handle (the `MPI_Allreduce_init`
/// shape): all one-off state — shared window, bridge parameters, stripe
/// tables, translation tables, resolved step-1 method, sync scheme, and
/// the compiled stage schedule — is bound at `*_init`; each
/// invocation is a [`start_*`](HyColl::start_allgather) (stage operands
/// into the window and launch every locally-runnable stage) followed by
/// either the blocking [`HyColl::wait`] (drive the schedule to
/// completion — bit- and vtime-identical to the PR-4 monolithic wait) or
/// the split-phase [`HyReq`] surface ([`HyColl::test`] /
/// [`HyColl::progress`]) that overlaps the remaining stages with caller
/// compute. Teardown with [`HyColl::free`] — collective, like
/// `MPI_Request_free` on a persistent collective.
pub struct HyColl {
    ctx: Rc<HybridCtx>,
    op: HyOp,
    /// The op's natural per-rank unit in bytes (block size, payload
    /// size, operand size, or result-block size).
    count: usize,
    dtype: Datatype,
    rop: Option<ReduceOp>,
    scheme: SyncScheme,
    /// Resolved step-1 method (reduce family; never `Tuned` here).
    method: AllreduceMethod,
    win: Option<HyWin>,
    /// Bridge recvcounts/displs: per-rank blocks (allgather/gather/
    /// scatter) or per-node blocks (reduce_scatter).
    param: Option<AllgatherParam>,
    tables: Option<Rc<TransTables>>,
    sizeset: Vec<usize>,
    /// Per-leader per-node bridge stripes (empty for `k = 1`).
    stripes: Vec<StripeTable>,
    /// Per-leader stripes over the operand vector / payload (empty for
    /// `k = 1`).
    vec_stripes: Vec<(usize, usize)>,
    started: bool,
    pending_root: usize,
    /// Root binding mode (rooted ops; [`RootPolicy::PerStart`] elsewhere).
    policy: RootPolicy,
    /// Bridge pipelining depth (`1` = the blocking-parity tree bridge).
    depth: usize,
    /// The compiled per-rank stage chain plus its invocation cursor.
    sched: Schedule,
    /// Armed by a stalled [`HyColl::try_test`] while the dead registry is
    /// non-empty: polls never park, so detection needs a handle-local
    /// deadline instead of the bounded-park timeout.
    fail_check: Option<Instant>,
}

/// How far one `HyColl::drive` call may go (see the determinism
/// discussion in the [`progress`] module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Drive {
    /// `start`-time push: arrivals, send-side chunks, local releases —
    /// stages whose *eligibility is rank-static*, so the launch point
    /// (and every charge) is deterministic.
    Local,
    /// `test`/`progress`: additionally complete stages whose readiness a
    /// probe confirms (barrier released, flag posted, chunk arrived).
    Poll,
    /// `wait`: execute everything, blocking where needed.
    Block,
}

impl HyColl {
    /// The session this handle belongs to.
    pub fn ctx(&self) -> &Rc<HybridCtx> {
        &self.ctx
    }

    /// The op's per-rank unit size in bytes.
    pub fn count(&self) -> usize {
        self.count
    }

    /// The §4.5 yellow-sync scheme this handle was compiled for.
    pub fn scheme(&self) -> SyncScheme {
        self.scheme
    }

    /// The handle's root binding mode.
    pub fn root_policy(&self) -> RootPolicy {
        self.policy
    }

    /// Bridge pipelining depth (`1` = the blocking-parity tree bridge).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The backing shared window (the paper's `Wrapper_Get_localpointer`
    /// surface, e.g. for in-place initialization of a gathered table).
    pub fn window(&self) -> Option<&HyWin> {
        self.win.as_ref()
    }

    fn win_mut(&mut self) -> &mut HyWin {
        self.win.as_mut().expect("HyColl already freed")
    }

    fn begin(&mut self, op: HyOp) {
        assert_eq!(self.op, op, "HyColl start/op mismatch");
        assert!(!self.started, "HyColl started twice without wait");
        self.started = true;
        if race::enabled() {
            race::label(move || format!("{op:?} start (operand staging)"));
        }
    }

    fn check_root(&self, root: usize) {
        if let Some(r) = self.policy.fixed_root() {
            assert_eq!(
                root, r,
                "fixed-root handle started with a different root (after a rebuild, query \
                 root_policy().fixed_root() — a Reelect root may have moved)"
            );
        }
    }

    /// Arm the schedule and launch every locally-runnable stage: barrier
    /// arrivals (timestamped *now*, so the sync overlaps caller compute)
    /// and — on pipelined handles — the root side's eager bridge chunks.
    /// Deterministic: only stages whose eligibility is rank-static run
    /// here (see [`Drive::Local`]).
    fn launch(&mut self, env: &mut ProcEnv) {
        self.sched.reset();
        if self.depth > 1 {
            // One tag per start for the chunk stream; FIFO per
            // (src, tag, comm) makes chunk identity positional. Leaders
            // allocate in lockstep (every rank starts in program order).
            let bridge = self.ctx.bridge().cloned();
            if let Some(bridge) = bridge {
                let opc = if self.op == HyOp::Bcast { opcode::BCAST } else { opcode::SCATTER };
                self.sched.bridge_tag = env.next_coll_tag(&bridge, opc);
            }
        }
        self.drive(env, Drive::Local, usize::MAX)
            .expect("Drive::Local never blocks, so it never consults the failure detector");
    }

    // ---- start: stage operands (local stores only) ------------------------

    /// Stage my `count`-byte allgather block at my parent-rank slot.
    pub fn start_allgather(&mut self, env: &mut ProcEnv, send: &[u8]) {
        self.begin(HyOp::Allgather);
        assert_eq!(send.len(), self.count);
        let me = self.ctx.parent().rank();
        let count = self.count;
        let win = self.win_mut();
        let off = win.local_ptr(me, count);
        win.store(env, off, send);
        self.launch(env);
    }

    /// Stage the broadcast payload (`Some` at `root`, `None` elsewhere).
    pub fn start_bcast(&mut self, env: &mut ProcEnv, root: usize, data: Option<&[u8]>) {
        self.begin(HyOp::Bcast);
        self.check_root(root);
        self.pending_root = root;
        if self.ctx.parent().rank() == root {
            let d = data.expect("root must supply the broadcast payload");
            assert_eq!(d.len(), self.count);
            self.win_mut().store(env, 0, d);
        }
        self.launch(env);
    }

    /// Stage my allreduce operand at my node-local slot.
    pub fn start_allreduce(&mut self, env: &mut ProcEnv, operand: &[u8]) {
        self.begin(HyOp::Allreduce);
        assert_eq!(operand.len(), self.count);
        let slot = self.ctx.shmem().rank();
        let count = self.count;
        let win = self.win_mut();
        let off = win.local_ptr(slot, count);
        win.store(env, off, operand);
        self.launch(env);
    }

    /// Stage my full reduce-scatter vector (`count·p` bytes) at my
    /// node-local slot.
    pub fn start_reduce_scatter(&mut self, env: &mut ProcEnv, send: &[u8]) {
        self.begin(HyOp::ReduceScatter);
        let total = self.count * self.ctx.parent().size();
        assert_eq!(send.len(), total);
        let slot = self.ctx.shmem().rank();
        let win = self.win_mut();
        let off = win.local_ptr(slot, total);
        win.store(env, off, send);
        self.launch(env);
    }

    /// Stage my `count`-byte gather block at my parent-rank slot.
    pub fn start_gather(&mut self, env: &mut ProcEnv, root: usize, send: &[u8]) {
        self.begin(HyOp::Gather);
        self.check_root(root);
        self.pending_root = root;
        assert_eq!(send.len(), self.count);
        let me = self.ctx.parent().rank();
        let count = self.count;
        let win = self.win_mut();
        let off = win.local_ptr(me, count);
        win.store(env, off, send);
        self.launch(env);
    }

    /// Stage the scatter send buffer (`Some`, `count·p` bytes, at `root`;
    /// `None` elsewhere).
    pub fn start_scatter(&mut self, env: &mut ProcEnv, root: usize, send: Option<&[u8]>) {
        self.begin(HyOp::Scatter);
        self.check_root(root);
        self.pending_root = root;
        if self.ctx.parent().rank() == root {
            let d = send.expect("root must supply the scatter payload");
            assert_eq!(d.len(), self.count * self.ctx.parent().size());
            self.win_mut().store(env, 0, d);
        }
        self.launch(env);
    }

    // ---- split-phase execution: the schedule interpreter ------------------

    /// Execute up to `max` stages under `drive` discipline; `Ok(true)`
    /// iff the schedule completed. See [`compile_stages`] for the per-op
    /// chains and the [`progress`] module docs for the blocking-parity
    /// argument.
    ///
    /// Under a fault plan, blocking stages park with a deadline
    /// ([`fault::detect_bound`]); on expiry the dead registry is
    /// consulted — a registered death surfaces as `Err(RankFailed)` (the
    /// handle stays `started`; recover with [`HybridCtx::shrink`] +
    /// [`HyColl::rebuild`]), while a clean-but-slow group simply
    /// re-parks. `Local`/`Poll` drives never block, so they never error.
    fn drive(&mut self, env: &mut ProcEnv, drive: Drive, max: usize) -> Result<bool, RankFailed> {
        let HyColl {
            ctx,
            op,
            count,
            dtype,
            rop,
            method,
            win,
            param,
            tables,
            sizeset,
            stripes,
            vec_stripes,
            pending_root,
            depth,
            sched,
            ..
        } = self;
        let ctx = &**ctx;
        let win = win.as_mut().expect("HyColl already freed");
        let count = *count;
        let root = *pending_root;
        let tables = tables.as_deref();
        let mut executed = 0usize;
        while !sched.complete() && executed < max {
            if race::enabled() {
                // Name the stage for the race detector: a report's two
                // sides carry these labels, so "which stages conflict" is
                // readable straight off the diagnostic.
                let (o, i, st) = (*op, sched.next, sched.stages[sched.next]);
                race::label(move || format!("{o:?} stage {i}: {st:?}"));
            }
            match sched.stages[sched.next] {
                Stage::Arrive(scope) => {
                    if let Some((group, _)) = resolve_scope(ctx, win, tables, scope, root) {
                        sched.ticket = Some(group.arrive(env.vclock()));
                    }
                }
                Stage::Await(scope) => {
                    if let Some((group, size)) = resolve_scope(ctx, win, tables, scope, root) {
                        if drive == Drive::Local {
                            return Ok(false);
                        }
                        let t = sched.ticket.expect("Await without a matching Arrive");
                        let vmax = if drive == Drive::Block {
                            if env.state().fault.is_some() {
                                let fuse = 2 * fault::cascade_rounds();
                                let mut expiries = 0u32;
                                loop {
                                    let dl = Instant::now() + fault::detect_bound();
                                    match group.finish_deadline(&t, dl) {
                                        Some(v) => break v,
                                        None => {
                                            expiries += 1;
                                            if let Some(r) = env.failed_peer(ctx.parent()) {
                                                env.charge_detection(1.0);
                                                return Err(RankFailed { world_rank: r });
                                            }
                                            // Cascade escape: a member that
                                            // retreated into a recovery epoch
                                            // (death during rebuild elsewhere)
                                            // never arrives.
                                            if expiries >= fuse && env.state().any_dead() {
                                                let r = env.state().dead_ranks()[0];
                                                env.charge_detection(f64::from(fuse));
                                                return Err(RankFailed { world_rank: r });
                                            }
                                        }
                                    }
                                }
                            } else {
                                group.finish(&t)
                            }
                        } else {
                            match group.poll(&t) {
                                Some(v) => v,
                                None => return Ok(false),
                            }
                        };
                        sched.ticket = None;
                        // Same charge law as `ProcEnv::barrier`; all
                        // handle-private groups are node-local.
                        env.finish_group_barrier(vmax, size, false);
                    }
                }
                Stage::Work { chunk } => {
                    if !work_ready(env, ctx, *op, *depth, drive, root, tables, sched.bridge_tag) {
                        return Ok(false);
                    }
                    let fault_run = env.state().fault.is_some();
                    let run = std::panic::AssertUnwindSafe(|| {
                        exec_work(
                            env,
                            ctx,
                            win,
                            *op,
                            chunk,
                            *depth,
                            sched.bridge_tag,
                            count,
                            *dtype,
                            *rop,
                            *method,
                            root,
                            param.as_ref(),
                            tables,
                            sizeset,
                            stripes,
                            vec_stripes,
                        );
                    });
                    if fault_run {
                        // A work unit's nested pure-MPI traffic (bridge
                        // chunk streams, bridge/node collectives) signals
                        // a detected failure by panicking with a typed
                        // `RankFailed` payload — catch exactly that here
                        // and turn it into the session layer's recoverable
                        // error. Anything else is a genuine bug: rethrow.
                        // Unwind safety: the handle is only reusable after
                        // a `rebuild`, which replaces every piece of state
                        // the aborted work unit may have left half-written.
                        if let Err(payload) = std::panic::catch_unwind(run) {
                            match payload.downcast::<RankFailed>() {
                                Ok(rf) => {
                                    // Charge the rounds the failing wait
                                    // noted (or one detection round if the
                                    // panic site predates the model).
                                    env.flush_detection(1.0);
                                    return Err(*rf);
                                }
                                Err(p) => std::panic::resume_unwind(p),
                            }
                        }
                    } else {
                        run.0();
                    }
                }
                Stage::YellowPost => {
                    win.epoch += 1;
                    if ctx.is_leader() {
                        env.spin_post(&win.win, 0);
                    }
                }
                Stage::YellowWait => {
                    if drive == Drive::Local {
                        return Ok(false);
                    }
                    let target = match sched.yellow_target {
                        Some(t) => t,
                        None => {
                            win.epoch += 1;
                            sched.yellow_target = Some(win.epoch);
                            win.epoch
                        }
                    };
                    if drive == Drive::Block {
                        if env.state().fault.is_some() {
                            let fuse = 2 * fault::cascade_rounds();
                            let mut expiries = 0u32;
                            loop {
                                let dl = Instant::now() + fault::detect_bound();
                                if env.spin_wait_deadline(&win.win, 0, target, dl) {
                                    break;
                                }
                                expiries += 1;
                                if let Some(r) = env.failed_peer(ctx.parent()) {
                                    env.charge_detection(1.0);
                                    return Err(RankFailed { world_rank: r });
                                }
                                if expiries >= fuse && env.state().any_dead() {
                                    let r = env.state().dead_ranks()[0];
                                    env.charge_detection(f64::from(fuse));
                                    return Err(RankFailed { world_rank: r });
                                }
                            }
                        } else {
                            env.spin_wait(&win.win, 0, target);
                        }
                    } else if !env.spin_try_wait(&win.win, 0, target) {
                        return Ok(false);
                    }
                    sched.yellow_target = None;
                }
            }
            sched.next += 1;
            executed += 1;
        }
        Ok(sched.complete())
    }

    // ---- wait/test/progress: completing a started collective --------------

    /// Complete the started collective (drive the compiled schedule to
    /// completion — blocking, bit- and vtime-identical to the pre-split
    /// monolithic wait); returns the window byte offset of this rank's
    /// result (offset 0 for allgather/bcast/gather, slot `G` for
    /// allreduce, my reduced block for reduce-scatter, my block for
    /// scatter).
    pub fn wait(&mut self, env: &mut ProcEnv) -> usize {
        match self.try_wait(env) {
            Ok(off) => off,
            Err(e) => panic!("HyColl::wait: {e} (use try_wait + HybridCtx::shrink to recover)"),
        }
    }

    /// Fault-aware [`HyColl::wait`]: identical on clean runs (bit- and
    /// vtime-identical completion), but a peer death detected at a
    /// bounded park surfaces as `Err(`[`RankFailed`]`)` instead of
    /// hanging (or panicking, as the plain `wait` does). On error the
    /// handle stays `started` and must not be waited again — recover by
    /// [`HybridCtx::shrink`]ing the session and [`HyColl::rebuild`]ing
    /// the handle on the survivors.
    pub fn try_wait(&mut self, env: &mut ProcEnv) -> Result<usize, RankFailed> {
        assert!(self.started, "HyColl wait without start");
        self.drive(env, Drive::Block, usize::MAX)?;
        self.started = false;
        self.fail_check = None;
        if race::enabled() {
            let op = self.op;
            race::label(move || format!("{op:?} complete (result reads)"));
        }
        Ok(self.result_offset())
    }

    /// Split-phase completion probe (`MPI_Test` shape): advance every
    /// stage that can run without blocking; `true` exactly once, when the
    /// started collective completed (the handle then returns to inactive
    /// — a further `test`/`wait` without a new `start` panics). Read the
    /// result at [`HyColl::result_offset`] / [`HyColl::result_view`].
    pub fn test(&mut self, env: &mut ProcEnv) -> bool {
        match self.try_test(env) {
            Ok(done) => done,
            Err(e) => panic!("HyColl::test: {e} (use try_test + HybridCtx::shrink to recover)"),
        }
    }

    /// Fault-aware [`HyColl::test`]. Polls never park, so the bounded-park
    /// detector cannot fire here; instead a poll that moves *nothing*
    /// while the dead registry is non-empty arms a handle-local deadline
    /// ([`fault::detect_bound`]), and only after that expires — with the
    /// op still stuck and a parent member registered dead — does the
    /// death surface as `Err(`[`RankFailed`]`)`. Any progress (or a
    /// clean registry) re-arms, so a merely slow peer never trips it.
    pub fn try_test(&mut self, env: &mut ProcEnv) -> Result<bool, RankFailed> {
        assert!(self.started, "HyColl test without start (or after completion)");
        let before = self.sched.next;
        if self.drive(env, Drive::Poll, usize::MAX)? {
            self.started = false;
            self.fail_check = None;
            return Ok(true);
        }
        if self.sched.next != before || !env.state().any_dead() {
            self.fail_check = None;
            return Ok(false);
        }
        match self.fail_check {
            None => {
                self.fail_check = Some(Instant::now() + fault::detect_bound());
                Ok(false)
            }
            Some(at) if Instant::now() < at => Ok(false),
            Some(_) => match env.failed_peer(self.ctx.parent()) {
                Some(r) => {
                    env.charge_detection(1.0);
                    Err(RankFailed { world_rank: r })
                }
                None => {
                    self.fail_check = None;
                    Ok(false)
                }
            },
        }
    }

    /// Pre-start probe for fault-injected runs: `Err` if a member of the
    /// parent communicator is already registered dead — a collective
    /// started now could never complete, so don't start it. Free on
    /// clean runs (one relaxed load).
    pub fn start_ok(&self, env: &ProcEnv) -> Result<(), RankFailed> {
        match env.failed_peer(self.ctx.parent()) {
            Some(r) => Err(RankFailed { world_rank: r }),
            None => Ok(()),
        }
    }

    /// Advance every non-blocking stage; `true` iff anything moved. No-op
    /// on an inactive handle (unlike [`HyColl::test`], which treats that
    /// as a protocol error).
    pub fn progress(&mut self, env: &mut ProcEnv) -> bool {
        if !self.started {
            return false;
        }
        let before = self.sched.next;
        self.drive(env, Drive::Poll, usize::MAX).expect("Drive::Poll never blocks");
        self.sched.next != before
    }

    /// The window byte offset of this rank's result — the value
    /// [`HyColl::wait`] returns, available without consuming completion
    /// (e.g. after [`HyColl::test`] returned `true`).
    pub fn result_offset(&self) -> usize {
        match self.op {
            HyOp::Allgather | HyOp::Bcast | HyOp::Gather => 0,
            HyOp::Scatter => self.ctx.parent().rank() * self.count,
            HyOp::Allreduce => (self.ctx.shmem_size() + 1) * self.count,
            HyOp::ReduceScatter => {
                let total = self.count * self.ctx.parent().size();
                (self.ctx.shmem_size() + 1) * total + self.ctx.parent().rank() * self.count
            }
        }
    }

    /// Zero-copy view of the result region (valid after [`HyColl::wait`]
    /// returns and until the next `start` on this handle):
    /// allgather/bcast/gather read at window offset 0, allreduce reads
    /// slot `G`, reduce-scatter and scatter read the caller's own block.
    pub fn result_view(&self, len: usize) -> Option<&[u8]> {
        let win = self.win.as_ref()?;
        let off = match self.op {
            HyOp::Allgather | HyOp::Bcast | HyOp::Gather => 0,
            HyOp::Scatter => self.ctx.parent().rank() * self.count,
            HyOp::Allreduce => (self.ctx.shmem_size() + 1) * self.count,
            HyOp::ReduceScatter => {
                let total = self.count * self.ctx.parent().size();
                (self.ctx.shmem_size() + 1) * total + self.ctx.parent().rank() * self.count
            }
        };
        // Safety: protocol-level — callers read between the handle's
        // yellow sync and the next start, per the window discipline.
        Some(unsafe { win.win.slice(off, len) })
    }

    // ---- static-schedule export (the analysis subsystem's input) ----------

    /// Export this rank's compiled schedule as the static model the
    /// [`analysis`](crate::analysis) verifier consumes: each [`Stage`]
    /// resolved against this rank's role (stages the rank sits out export
    /// as [`StageModel::Skip`]), with barrier groups keyed by
    /// `(window id, slot)`, the yellow flag by `(window id, 0)`, and the
    /// `Work` stages expanded into their window accesses, pipelined
    /// chunk-stream messages and nested collectives. `root` is the root
    /// the next `start` will bind (ignored by unrooted ops; must equal
    /// the baked root on [`RootPolicy::Fixed`] handles). Collect one
    /// schedule per rank and hand the set to
    /// [`verify_handle`](crate::analysis::verify_handle) — or the
    /// concatenation across in-flight handles to
    /// [`verify_program`](crate::analysis::verify_program).
    pub fn export_schedule(&self, root: usize) -> RankSchedule {
        let ctx = &*self.ctx;
        let win = self.win.as_ref().expect("HyColl already freed");
        let win_id = win.win.id();
        let tables = self.tables.as_deref();
        let rooted = matches!(self.op, HyOp::Bcast | HyOp::Scatter | HyOp::Gather);
        if let Some(r) = self.policy.fixed_root() {
            assert_eq!(root, r, "export root must match the handle's fixed root");
        }
        let stages = self
            .sched
            .stages
            .iter()
            .map(|st| match *st {
                Stage::Arrive(scope) => match model_scope(ctx, tables, scope, root) {
                    Some((slot, size)) => StageModel::Arrive { group: (win_id, slot), size },
                    None => StageModel::Skip,
                },
                Stage::Await(scope) => match model_scope(ctx, tables, scope, root) {
                    Some((slot, size)) => StageModel::Await { group: (win_id, slot), size },
                    None => StageModel::Skip,
                },
                Stage::Work { chunk } => self.model_work(chunk, root),
                // Only the primary leader posts the flag; leaders 1..k
                // merely bump their local epoch (ordered by the leader
                // barrier the schedule placed before this stage).
                Stage::YellowPost => {
                    if ctx.is_leader() {
                        StageModel::Post { flag: (win_id, 0) }
                    } else {
                        StageModel::Skip
                    }
                }
                Stage::YellowWait => StageModel::Wait { flag: (win_id, 0) },
            })
            .collect();
        RankSchedule {
            rank: ctx.parent().rank(),
            node: ctx.node_index(),
            op: op_name(self.op),
            root: rooted.then_some(root),
            win: win_id,
            win_len: win.len(),
            stages,
        }
    }

    /// The model of one `Work` stage — *coarse on data, exact on
    /// synchronization*: every nested collective and every pipelined
    /// chunk message the stage performs appears exactly once (mirroring
    /// the op bodies' guards, including the zero-length-chunks-still-flow
    /// rule of the pipelined streams), while window accesses may
    /// over-approximate to union ranges (the verifier only bounds-checks
    /// them; exact byte ranges are the *runtime* detector's job).
    fn model_work(&self, chunk: usize, root: usize) -> StageModel {
        let ctx = &*self.ctx;
        let mut accesses = Vec::new();
        let mut msgs = Vec::new();
        let mut colls = Vec::new();
        let count = self.count;
        let shmem_size = ctx.shmem_size();
        let j = ctx.leader_index();
        // Leader j's stripe of one `len`-byte vector ((0, len) for k = 1).
        let stripe_of = |len: usize| {
            if self.vec_stripes.is_empty() {
                (0, len)
            } else {
                self.vec_stripes[j.expect("striped work runs on leaders")]
            }
        };
        match self.op {
            HyOp::Allgather => {
                let bridge = ctx.bridge().expect("allgather work runs on leaders");
                let param = self.param.as_ref().expect("allgather binds params");
                let full: usize = param.recvcounts.iter().sum();
                accesses.push(Access { offset: 0, len: full, write: true });
                colls.push(CollModel { comm: bridge.id(), kind: "allgatherv", size: bridge.size() });
            }
            HyOp::Gather => {
                let bridge = ctx.bridge().expect("gather work runs on leaders");
                if bridge.size() > 1 {
                    let param = self.param.as_ref().expect("gather binds params");
                    let t = tables_of(self);
                    let me = ctx.node_index();
                    if me == t.bridge[root] {
                        let full: usize = param.recvcounts.iter().sum();
                        accesses.push(Access { offset: 0, len: full, write: true });
                    } else if self.stripes.is_empty() {
                        accesses.push(Access {
                            offset: param.displs[me],
                            len: param.recvcounts[me],
                            write: false,
                        });
                    } else {
                        let st = &self.stripes[j.expect("striped work runs on leaders")];
                        accesses.push(Access { offset: st.offsets[me], len: st.counts[me], write: false });
                    }
                    colls.push(CollModel { comm: bridge.id(), kind: "gatherv", size: bridge.size() });
                }
            }
            HyOp::Bcast => {
                let bridge = ctx.bridge().expect("bcast work runs on leaders");
                if bridge.size() > 1 {
                    let t = tables_of(self);
                    let root_node = t.bridge[root];
                    let me = ctx.node_index();
                    let on_root = me == root_node;
                    let (base_off, base_len) = stripe_of(count);
                    if self.depth == 1 {
                        if self.vec_stripes.is_empty() || base_len > 0 {
                            accesses.push(Access { offset: base_off, len: base_len, write: !on_root });
                            colls.push(CollModel { comm: bridge.id(), kind: "bcast", size: bridge.size() });
                        }
                    } else {
                        let (lo, clen) = chunk_bounds(base_len, self.depth, chunk);
                        accesses.push(Access { offset: base_off + lo, len: clen, write: !on_root });
                        let tag = self.sched.bridge_tag;
                        if on_root {
                            for r in 0..bridge.size() {
                                if r != root_node {
                                    msgs.push(MsgModel { comm: bridge.id(), src: me, dst: r, tag, send: true });
                                }
                            }
                        } else {
                            msgs.push(MsgModel { comm: bridge.id(), src: root_node, dst: me, tag, send: false });
                        }
                    }
                }
            }
            HyOp::Scatter => {
                let bridge = ctx.bridge().expect("scatter work runs on leaders");
                if bridge.size() > 1 {
                    let param = self.param.as_ref().expect("scatter binds params");
                    let t = tables_of(self);
                    let root_node = t.bridge[root];
                    let me = ctx.node_index();
                    let full: usize = param.recvcounts.iter().sum();
                    // Leader j's (offset, len) range of node i's block.
                    let node_range = |i: usize| {
                        if self.stripes.is_empty() {
                            (param.displs[i], param.recvcounts[i])
                        } else {
                            let st = &self.stripes[j.expect("striped work runs on leaders")];
                            (st.offsets[i], st.counts[i])
                        }
                    };
                    let tag = self.sched.bridge_tag;
                    if me == root_node {
                        accesses.push(Access { offset: 0, len: full, write: false });
                        if self.depth > 1 {
                            for r in 0..bridge.size() {
                                if r != root_node {
                                    msgs.push(MsgModel { comm: bridge.id(), src: me, dst: r, tag, send: true });
                                }
                            }
                        }
                    } else {
                        let (off, len) = node_range(me);
                        if self.depth == 1 {
                            accesses.push(Access { offset: off, len, write: true });
                        } else {
                            let (lo, clen) = chunk_bounds(len, self.depth, chunk);
                            accesses.push(Access { offset: off + lo, len: clen, write: true });
                            msgs.push(MsgModel { comm: bridge.id(), src: root_node, dst: me, tag, send: false });
                        }
                    }
                    if self.depth == 1 {
                        colls.push(CollModel { comm: bridge.id(), kind: "scatterv", size: bridge.size() });
                    }
                }
            }
            HyOp::Allreduce => {
                let msize = count;
                let l_off = shmem_size * msize;
                let g_off = (shmem_size + 1) * msize;
                if chunk == 0 {
                    match self.method {
                        AllreduceMethod::Method1 => {
                            accesses.push(Access {
                                offset: ctx.shmem().rank() * msize,
                                len: msize,
                                write: false,
                            });
                            if ctx.is_leader() {
                                accesses.push(Access { offset: l_off, len: msize, write: true });
                            }
                            colls.push(CollModel { comm: ctx.shmem().id(), kind: "reduce", size: shmem_size });
                        }
                        AllreduceMethod::Method2 => {
                            let (off, len) = stripe_of(msize);
                            if len > 0 {
                                accesses.push(Access { offset: 0, len: shmem_size * msize, write: false });
                                accesses.push(Access { offset: l_off + off, len, write: true });
                            }
                        }
                        AllreduceMethod::Tuned => unreachable!("Tuned resolves at *_init"),
                    }
                } else {
                    let (off, len) = stripe_of(msize);
                    if len > 0 {
                        accesses.push(Access { offset: l_off + off, len, write: false });
                        accesses.push(Access { offset: g_off + off, len, write: true });
                        let bridge = ctx.bridge().expect("allreduce step 2 runs on leaders");
                        if bridge.size() > 1 {
                            colls.push(CollModel { comm: bridge.id(), kind: "allreduce", size: bridge.size() });
                        }
                    }
                }
            }
            HyOp::ReduceScatter => {
                let total = count * ctx.parent().size();
                let l_off = shmem_size * total;
                let g_off = (shmem_size + 1) * total;
                if chunk == 0 {
                    match self.method {
                        AllreduceMethod::Method1 => {
                            accesses.push(Access {
                                offset: ctx.shmem().rank() * total,
                                len: total,
                                write: false,
                            });
                            if ctx.is_leader() {
                                accesses.push(Access { offset: l_off, len: total, write: true });
                            }
                            colls.push(CollModel { comm: ctx.shmem().id(), kind: "reduce", size: shmem_size });
                        }
                        AllreduceMethod::Method2 => {
                            let (off, len) = stripe_of(total);
                            if len > 0 {
                                accesses.push(Access { offset: 0, len: shmem_size * total, write: false });
                                accesses.push(Access { offset: l_off + off, len, write: true });
                            }
                        }
                        AllreduceMethod::Tuned => unreachable!("Tuned resolves at *_init"),
                    }
                } else {
                    let bridge = ctx.bridge().expect("reduce_scatter step 2 runs on leaders");
                    if bridge.size() > 1 {
                        let param = self.param.as_ref().expect("reduce_scatter binds params");
                        let me = ctx.node_index();
                        accesses.push(Access { offset: l_off, len: total, write: false });
                        let (woff, wlen) = if self.stripes.is_empty() {
                            (param.displs[me], param.recvcounts[me])
                        } else {
                            let st = &self.stripes[j.expect("striped work runs on leaders")];
                            (st.offsets[me], st.counts[me])
                        };
                        accesses.push(Access { offset: g_off + woff, len: wlen, write: true });
                        colls.push(CollModel {
                            comm: bridge.id(),
                            kind: "reduce_scatterv",
                            size: bridge.size(),
                        });
                    } else {
                        let (off, len) = stripe_of(total);
                        if len > 0 {
                            accesses.push(Access { offset: l_off + off, len, write: false });
                            accesses.push(Access { offset: g_off + off, len, write: true });
                        }
                    }
                }
            }
        }
        StageModel::Work { chunk, accesses, msgs, colls }
    }

    /// Collective teardown: frees the shared window (call symmetrically
    /// on every member of the parent communicator). Panics on a handle
    /// with a started-but-unwaited operation — the split-phase analogue
    /// of freeing an active `MPI_Request`.
    pub fn free(&mut self, env: &mut ProcEnv) {
        assert!(!self.started, "HyColl freed with a started operation pending (forgotten wait)");
        if let Some(win) = self.win.take() {
            let ctx = self.ctx.clone();
            win.free(env, &ctx);
        }
    }

    /// Rebuild this handle on a shrunken session — the recovery half of
    /// [`HybridCtx::shrink`]. Re-runs the matching `*_init` on `new_ctx`
    /// with the same shape parameters (count, dtype, reduce op, resolved
    /// method, sync scheme, pipelining depth), producing a fresh window,
    /// fresh stripe/translation tables and a freshly compiled stage
    /// schedule over the survivors. A [`RootPolicy::Fixed`] root is
    /// remapped through world ranks; if the root itself died this panics
    /// — picking a replacement root is an application decision, not a
    /// library one. A [`RootPolicy::Reelect`] root is remapped the same
    /// way while the root lives, and **re-elected** through the handle's
    /// election hook when it died: the hook sees the dead root's former
    /// world rank and node plus the survivor set, and the default
    /// ([`progress::default_reelect`]) picks the lowest-ranked survivor
    /// on the dead root's former node — preserving the root's shared
    /// window locality — falling back to the lowest survivor when that
    /// node lost every member.
    ///
    /// The old window is abandoned *without* a collective free (the
    /// ULFM-revoke analogue): the old group can no longer meet to free
    /// it, so its registry entry is leaked deliberately. Any
    /// started-but-unfinished invocation is discarded — re-`start` after
    /// rebuilding and the collective runs on the new group. Collective
    /// over `new_ctx`'s parent.
    pub fn rebuild(&mut self, env: &mut ProcEnv, new_ctx: &Rc<HybridCtx>) {
        let old = self.ctx.parent().clone();
        let remap = |r: usize| {
            new_ctx
                .parent()
                .rank_of_world(old.world_of(r))
                .expect("rebuild with a dead fixed root: choose a new root and a new handle")
        };
        let policy = match self.policy {
            RootPolicy::Fixed(r) => RootPolicy::Fixed(remap(r)),
            RootPolicy::Reelect(r, elect) => {
                let old_world = old.world_of(r);
                let new_root = match new_ctx.parent().rank_of_world(old_world) {
                    Some(nr) => nr, // root survived: plain remap
                    None => {
                        // Dead root: re-elect among the survivors.
                        let survivors_world = new_ctx.parent().members();
                        let topo = env.topo();
                        let survivor_nodes: Vec<usize> =
                            survivors_world.iter().map(|&w| topo.node_of(w)).collect();
                        let e = progress::Reelection {
                            old_root_world: old_world,
                            old_root_node: topo.node_of(old_world),
                            survivors_world,
                            survivor_nodes: &survivor_nodes,
                        };
                        let nr = elect(&e);
                        assert!(
                            nr < survivors_world.len(),
                            "re-elected root {nr} out of range for {} survivors",
                            survivors_world.len()
                        );
                        nr
                    }
                };
                RootPolicy::Reelect(new_root, elect)
            }
            RootPolicy::PerStart => RootPolicy::PerStart,
        };
        *self = match self.op {
            HyOp::Allgather => new_ctx.allgather_init(env, self.count, self.scheme),
            HyOp::Bcast => {
                new_ctx.bcast_init_split(env, self.count, self.scheme, policy, self.depth)
            }
            HyOp::Allreduce => new_ctx.allreduce_init(
                env,
                self.dtype,
                self.rop.expect("allreduce binds an op"),
                self.count,
                self.method,
                self.scheme,
            ),
            HyOp::ReduceScatter => new_ctx.reduce_scatter_init(
                env,
                self.dtype,
                self.rop.expect("reduce_scatter binds an op"),
                self.count,
                self.method,
                self.scheme,
            ),
            HyOp::Gather => new_ctx.gather_init_split(env, self.count, self.scheme, policy),
            HyOp::Scatter => {
                new_ctx.scatter_init_split(env, self.count, self.scheme, policy, self.depth)
            }
        };
    }
}

impl HyReq for HyColl {
    fn test(&mut self, env: &mut ProcEnv) -> bool {
        HyColl::test(self, env)
    }

    fn progress(&mut self, env: &mut ProcEnv) -> bool {
        HyColl::progress(self, env)
    }

    fn wait(&mut self, env: &mut ProcEnv) -> usize {
        HyColl::wait(self, env)
    }

    fn step_blocking(&mut self, env: &mut ProcEnv) {
        if self.started && !self.sched.complete() {
            if let Err(e) = self.drive(env, Drive::Block, 1) {
                panic!("HyColl::step_blocking: {e} (use try_wait + HybridCtx::shrink to recover)");
            }
        }
    }

    fn is_idle(&self) -> bool {
        !self.started
    }
}

impl HybridCtx {
    /// Block until one of `reqs` (heterogeneous started handles)
    /// completes; returns its index. See [`progress::wait_any`] for the
    /// fairness and ordering contract.
    pub fn wait_any(env: &mut ProcEnv, reqs: &mut [&mut dyn HyReq]) -> usize {
        progress::wait_any(env, reqs)
    }

    /// Drive every started handle to completion; returns the per-handle
    /// result offsets, index-aligned with `reqs`.
    pub fn wait_all(env: &mut ProcEnv, reqs: &mut [&mut dyn HyReq]) -> Vec<usize> {
        progress::wait_all(env, reqs)
    }
}

// ---- self-healing retry driver (ISSUE 8) ----------------------------------

/// How [`HybridCtx::run_resilient`] paces its recovery epochs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Give up ([`Resilience::Exhausted`]) after this many recovery
    /// epochs — each epoch is one detect → purge → shrink → rebuild →
    /// restart cycle.
    pub max_epochs: usize,
    /// Virtual microseconds charged before the first recovery epoch's
    /// shrink (0 = retry immediately). Models the grace period a real
    /// runtime inserts so a transient stall is not escalated instantly.
    pub backoff_us: f64,
    /// Multiplier applied to the backoff after every epoch
    /// (exponential backoff; 1.0 = constant).
    pub backoff_factor: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_epochs: 8, backoff_us: 0.0, backoff_factor: 2.0 }
    }
}

/// Per-epoch recovery cost breakdown from [`HybridCtx::run_resilient`],
/// in virtual microseconds. `detect_us` is the detection-cost model's
/// charge ([`ProcEnv::detection_vtime_us`] delta: bounded-park rounds at
/// the failing wait plus any cascade rounds inside the shrink
/// agreement); `shrink_us` / `rebuild_us` are the wall-clock-free vclock
/// deltas of the agreement + session rebuild and the handle re-inits.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpochReport {
    /// 1-based recovery epoch index.
    pub epoch: usize,
    /// World rank whose death (or abandonment) triggered this epoch.
    pub failed: usize,
    pub detect_us: f64,
    pub shrink_us: f64,
    pub rebuild_us: f64,
}

/// Outcome of [`HybridCtx::run_resilient`].
pub enum Resilience<T> {
    /// The attempt completed. `ctx` is the session it completed on (the
    /// original if no fault fired, the latest shrunken session
    /// otherwise); `epochs` records every recovery cycle that ran.
    Completed { value: T, ctx: Rc<HybridCtx>, epochs: Vec<EpochReport> },
    /// *This rank* is the casualty: it observed its own scheduled death
    /// (`ProcEnv::rank_dead`) and must stop participating. Survivors
    /// keep running and will shrink around it.
    Died,
    /// `max_epochs` recovery cycles did not yield a completed attempt.
    Exhausted { last: RankFailed, epochs: Vec<EpochReport> },
}

impl HybridCtx {
    /// The self-healing retry driver: run `attempt` until it completes,
    /// looping **detect → purge → shrink → rebuild → restart** around
    /// every detected failure, with per-epoch backoff and a `max_epochs`
    /// bound.
    ///
    /// `attempt` receives the *current* session and the handle set
    /// (freshly rebuilt each epoch) and returns:
    /// - `Ok(Some(v))` — completed; `run_resilient` returns
    ///   [`Resilience::Completed`] with `v` and the final session.
    /// - `Ok(None)` — this rank observed its own scheduled death and
    ///   retired cooperatively (it already called
    ///   [`ProcEnv::rank_dead`]); maps to [`Resilience::Died`].
    /// - `Err(RankFailed)` — a peer failure surfaced from a bounded
    ///   park ([`HyColl::try_wait`] / [`HyColl::try_test`] /
    ///   [`HyColl::start_ok`]); the driver recovers and retries.
    ///   `RankFailed` *panics* escaping `attempt` (from plain `wait` or
    ///   raw pure-MPI calls) are caught and treated identically.
    ///
    /// Recovery epoch: charge the policy backoff, purge doomed plans
    /// from `cache` ([`PlanCache::purge_failed`](crate::coll::PlanCache::purge_failed)),
    /// [`HybridCtx::shrink`] (itself restartable — a death racing the
    /// agreement or the session rebuild panics back here and the shrink
    /// is simply re-entered), then [`HyColl::rebuild`] every handle on
    /// the shrunken session. A death observed *between* shrink and
    /// rebuild retires this rank ([`Resilience::Died`]) while its
    /// survivors' next epoch shrinks around it — the death-during-rebuild
    /// case. Each epoch's detect/shrink/rebuild virtual-time split is
    /// recorded in an [`EpochReport`].
    ///
    /// The attempt must be **restartable from its inputs**: it is
    /// re-invoked from the top after every recovery, so any partial
    /// results it wrote must be recomputed or idempotent.
    pub fn run_resilient<T>(
        self: &Rc<Self>,
        env: &mut ProcEnv,
        handles: &mut [&mut HyColl],
        mut cache: Option<&mut crate::coll::PlanCache>,
        policy: RetryPolicy,
        mut attempt: impl FnMut(
            &mut ProcEnv,
            &Rc<HybridCtx>,
            &mut [&mut HyColl],
        ) -> Result<Option<T>, RankFailed>,
    ) -> Resilience<T> {
        let mut ctx = self.clone();
        let mut epochs: Vec<EpochReport> = Vec::new();
        let mut backoff = policy.backoff_us;
        loop {
            if env.rank_dead() {
                return Resilience::Died;
            }
            let detect0 = env.detection_vtime_us();
            // Run one attempt, converting a RankFailed *panic* escaping
            // it (plain waits, raw pure-MPI traffic) into the same
            // recoverable error the try_* surface returns. Unwind
            // safety: every handle is rebuilt before reuse, and the
            // attempt contract requires restartability from inputs.
            let outcome = {
                let att = std::panic::AssertUnwindSafe(|| attempt(env, &ctx, &mut *handles));
                match std::panic::catch_unwind(att) {
                    Ok(res) => res,
                    Err(payload) => match payload.downcast::<RankFailed>() {
                        Ok(rf) => {
                            env.flush_detection(1.0);
                            Err(*rf)
                        }
                        Err(p) => std::panic::resume_unwind(p),
                    },
                }
            };
            let failed = match outcome {
                Ok(Some(value)) => return Resilience::Completed { value, ctx, epochs },
                Ok(None) => return Resilience::Died,
                Err(f) => f,
            };
            if epochs.len() >= policy.max_epochs {
                return Resilience::Exhausted { last: failed, epochs };
            }
            if backoff > 0.0 {
                env.advance(backoff);
                backoff *= policy.backoff_factor;
            }
            if let Some(c) = cache.as_deref_mut() {
                c.purge_failed(env);
            }
            let v0 = env.vclock();
            // Shrink, re-entering the (restartable) agreement if another
            // death lands during it or during the session rebuild.
            let new_ctx = loop {
                if env.rank_dead() {
                    return Resilience::Died;
                }
                let sh = std::panic::AssertUnwindSafe(|| ctx.shrink(env));
                match std::panic::catch_unwind(sh) {
                    Ok(c) => break c,
                    Err(payload) => match payload.downcast::<RankFailed>() {
                        Ok(_) => env.flush_detection(1.0),
                        Err(p) => std::panic::resume_unwind(p),
                    },
                }
            };
            let shrink_us = env.vclock() - v0;
            let detect_us = env.detection_vtime_us() - detect0;
            // Cooperative-death checkpoint between shrink and rebuild:
            // a rank dying *here* completed the agreement but never
            // joins the handle re-inits — its survivors' create/rebuild
            // collectives abandon via their bounded parks and the next
            // epoch shrinks around it.
            if env.rank_dead() {
                return Resilience::Died;
            }
            let v1 = env.vclock();
            let rb = std::panic::AssertUnwindSafe(|| {
                for h in handles.iter_mut() {
                    h.rebuild(env, &new_ctx);
                }
            });
            let rebuilt = match std::panic::catch_unwind(rb) {
                Ok(()) => true,
                Err(payload) => match payload.downcast::<RankFailed>() {
                    Ok(_) => {
                        env.flush_detection(1.0);
                        false
                    }
                    Err(p) => std::panic::resume_unwind(p),
                },
            };
            epochs.push(EpochReport {
                epoch: epochs.len() + 1,
                failed: failed.world_rank,
                detect_us,
                shrink_us,
                rebuild_us: env.vclock() - v1,
            });
            // A rebuild aborted by a racing death leaves the handle set
            // half re-initialized; adopting the shrunken session anyway
            // is safe because the next attempt fails fast (its parent
            // has a registered-dead member) and the following epoch
            // re-inits every handle on the next survivor set.
            let _ = rebuilt;
            ctx = new_ctx;
        }
    }
}

/// Resolve a sync scope against this rank's role: the participating
/// barrier group and its size, or `None` when the rank sits the stage
/// out. Handle-private groups live on the shared window (slot 0 = node,
/// slot 1 = leader set) so in-flight arrivals never interleave with user
/// barriers on the communicator's shared group.
fn resolve_scope(
    ctx: &HybridCtx,
    win: &HyWin,
    tables: Option<&TransTables>,
    scope: Scope,
    root: usize,
) -> Option<(std::sync::Arc<crate::mpi::sync::SyncGroup>, usize)> {
    match scope {
        Scope::Node => Some((win.win.sync_group(0, ctx.shmem_size()), ctx.shmem_size())),
        Scope::RootNode => {
            let t = tables.expect("rooted ops bind translation tables");
            let on_root_node = ctx.node_index() == t.bridge[root];
            let needs = t.shmem[root] != 0 || ctx.leaders_per_node() > 1;
            (on_root_node && needs)
                .then(|| (win.win.sync_group(0, ctx.shmem_size()), ctx.shmem_size()))
        }
        Scope::Leaders => {
            let k = ctx.leaders_per_node();
            (ctx.leader_index().is_some() && k > 1).then(|| (win.win.sync_group(1, k), k))
        }
    }
}

/// The static-model twin of [`resolve_scope`]: the window sync-group
/// *slot* (0 = node, 1 = leader set) and participant count this rank
/// uses for `scope`, or `None` when it sits the stage out. Must stay in
/// lockstep with [`resolve_scope`] — the verifier checks what this
/// reports, the engine executes what that resolves.
fn model_scope(
    ctx: &HybridCtx,
    tables: Option<&TransTables>,
    scope: Scope,
    root: usize,
) -> Option<(usize, usize)> {
    match scope {
        Scope::Node => Some((0, ctx.shmem_size())),
        Scope::RootNode => {
            let t = tables.expect("rooted ops bind translation tables");
            let on_root_node = ctx.node_index() == t.bridge[root];
            let needs = t.shmem[root] != 0 || ctx.leaders_per_node() > 1;
            (on_root_node && needs).then_some((0, ctx.shmem_size()))
        }
        Scope::Leaders => {
            let k = ctx.leaders_per_node();
            (ctx.leader_index().is_some() && k > 1).then_some((1, k))
        }
    }
}

fn tables_of(h: &HyColl) -> &TransTables {
    h.tables.as_deref().expect("rooted ops bind translation tables")
}

fn op_name(op: HyOp) -> &'static str {
    match op {
        HyOp::Allgather => "allgather",
        HyOp::Bcast => "bcast",
        HyOp::Allreduce => "allreduce",
        HyOp::ReduceScatter => "reduce_scatter",
        HyOp::Gather => "gather",
        HyOp::Scatter => "scatter",
    }
}

/// May this rank's next `Work` stage run under `drive`? Blocking drives
/// always may; otherwise only the pipelined (`depth > 1`) bcast/scatter
/// chunks qualify — the send side unconditionally (eager sends, and the
/// rank-static classification keeps `start`-time launches deterministic),
/// the receive side when a mailbox probe proves the chunk deliverable
/// (`Poll` only).
#[allow(clippy::too_many_arguments)]
fn work_ready(
    env: &ProcEnv,
    ctx: &HybridCtx,
    op: HyOp,
    depth: usize,
    drive: Drive,
    root: usize,
    tables: Option<&TransTables>,
    tag: i64,
) -> bool {
    if drive == Drive::Block {
        return true;
    }
    if depth <= 1 || !matches!(op, HyOp::Bcast | HyOp::Scatter) {
        return false;
    }
    let Some(bridge) = ctx.bridge() else { return false };
    if bridge.size() <= 1 {
        return true;
    }
    let root_node = tables.expect("rooted ops bind translation tables").bridge[root];
    if bridge.rank() == root_node {
        return true; // send side: eager, never blocks
    }
    drive == Drive::Poll && env.probe(bridge, Some(root_node), tag)
}

/// Execute one op-specific work unit. With `depth = 1` these are exactly
/// the pre-split bridge/step bodies — the blocking-parity invariant.
#[allow(clippy::too_many_arguments)]
fn exec_work(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    op: HyOp,
    chunk: usize,
    depth: usize,
    tag: i64,
    count: usize,
    dtype: Datatype,
    rop: Option<ReduceOp>,
    method: AllreduceMethod,
    root: usize,
    param: Option<&AllgatherParam>,
    tables: Option<&TransTables>,
    sizeset: &[usize],
    stripes: &[StripeTable],
    vec_stripes: &[(usize, usize)],
) {
    match op {
        HyOp::Allgather => {
            let param = param.expect("allgather binds params");
            super::allgather::bridge(env, ctx, win, param, stripes);
        }
        HyOp::Gather => {
            let param = param.expect("gather binds params");
            let tables = tables.expect("gather binds tables");
            super::gather::bridge(env, ctx, win, param, tables, stripes, root, count);
        }
        HyOp::Bcast => {
            let tables = tables.expect("bcast binds tables");
            let root_node = tables.bridge[root];
            if depth == 1 {
                super::bcast::bridge(env, ctx, win, vec_stripes, root_node, count);
            } else {
                super::bcast::bridge_chunk(env, ctx, win, vec_stripes, root_node, count, chunk, depth, tag);
            }
        }
        HyOp::Scatter => {
            let param = param.expect("scatter binds params");
            let tables = tables.expect("scatter binds tables");
            let root_node = tables.bridge[root];
            if depth == 1 {
                super::scatter::bridge(env, ctx, win, param, stripes, root_node);
            } else {
                super::scatter::bridge_chunk(env, ctx, win, param, stripes, root_node, chunk, depth, tag);
            }
        }
        HyOp::Allreduce => {
            let rop = rop.expect("allreduce binds an op");
            if chunk == 0 {
                super::allreduce::step1(env, ctx, win, dtype, rop, count, method, vec_stripes);
            } else {
                super::allreduce::step2(env, ctx, win, dtype, rop, count, vec_stripes);
            }
        }
        HyOp::ReduceScatter => {
            let rop = rop.expect("reduce_scatter binds an op");
            if chunk == 0 {
                super::reduce_scatter::step1(env, ctx, win, dtype, rop, count, method, vec_stripes);
            } else {
                super::reduce_scatter::step2(env, ctx, win, sizeset, dtype, rop, count, stripes, vec_stripes);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;

    #[test]
    fn leader_set_shapes_and_clamping() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(4));
            (
                env.world_rank(),
                ctx.leaders_per_node(),
                ctx.leader_index(),
                ctx.bridge().map(|b| (b.size(), b.rank())),
                ctx.node_index(),
                ctx.shmem_size(),
            )
        });
        for (wr, k, j, bridge, node_idx, shm) in out {
            assert_eq!(k, 3, "clamped to the smallest node population");
            let local = if wr < 5 { wr } else { wr - 5 };
            if local < 3 {
                assert_eq!(j, Some(local));
                let (bsz, brank) = bridge.expect("leaders hold a bridge");
                assert_eq!(bsz, 2);
                assert_eq!(brank, if wr < 5 { 0 } else { 1 });
            } else {
                assert_eq!(j, None);
                assert!(bridge.is_none());
            }
            assert_eq!(node_idx, if wr < 5 { 0 } else { 1 });
            assert_eq!(shm, if wr < 5 { 5 } else { 3 });
        }
    }

    #[test]
    fn single_policy_matches_paper_shape() {
        let out = run_nodes(&[4, 4], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            (ctx.leaders_per_node(), ctx.is_leader(), ctx.leaders().is_none())
        });
        for (r, (k, leader, no_group)) in out.into_iter().enumerate() {
            assert_eq!(k, 1);
            assert_eq!(leader, r % 4 == 0);
            assert!(no_group, "k = 1 builds no leader group (vtime parity)");
        }
    }

    #[test]
    fn stripe_bounds_cover_and_align() {
        for (len, k, align) in [(100usize, 3usize, 1usize), (128, 4, 8), (24, 5, 8), (7, 2, 1)] {
            let mut covered = 0usize;
            for j in 0..k {
                let (lo, n) = stripe_bounds(len / align * align, k, j, align);
                assert_eq!(lo % align, 0);
                assert_eq!(n % align, 0);
                assert_eq!(lo, covered);
                covered += n;
            }
            assert_eq!(covered, len / align * align);
        }
    }

    #[test]
    fn sizeset_agrees_between_leaders_and_children() {
        for policy in [LeaderPolicy::Single, LeaderPolicy::Leaders(2)] {
            let out = run_nodes(&[5, 3], move |env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, policy);
                ctx.sizeset(env).to_vec()
            });
            for got in out {
                assert_eq!(got, vec![5, 3]);
            }
        }
    }

    #[test]
    fn derived_communicator_supported() {
        // Session over a sub-communicator (even world ranks only) — the
        // §4.1 "complex use cases".
        let out = run_nodes(&[4, 4], |env| {
            let w = env.world();
            let even = env.split(&w, (w.rank() % 2) as i64, w.rank() as i64).unwrap();
            let ctx = HybridCtx::create(env, &even, LeaderPolicy::Leaders(2));
            (ctx.shmem_size(), ctx.nnodes(), ctx.leader_index())
        });
        for (r, (shm, nn, j)) in out.into_iter().enumerate() {
            assert_eq!(shm, 2, "rank {r}: 2 same-parity ranks per node");
            assert_eq!(nn, 2);
            // Both same-parity ranks on each node lead (k = 2 over
            // 2-rank node groups).
            assert_eq!(j, Some(if r < 4 { r / 2 } else { (r - 4) / 2 }), "rank {r}");
        }
    }

    #[test]
    fn persistent_handle_reuse_has_zero_resetup() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(2));
            let mut ag = ctx.allgather_init(env, 64, SyncScheme::Spin);
            let w0 = ag.window().map(|h| h.win.as_ref() as *const _ as usize).unwrap();
            let mine = vec![w.rank() as u8; 64];
            let mut dts = Vec::new();
            for _ in 0..3 {
                env.harness_sync(&w);
                let t0 = env.vclock();
                ag.start_allgather(env, &mine);
                ag.wait(env);
                dts.push(env.vclock() - t0);
            }
            let w1 = ag.window().map(|h| h.win.as_ref() as *const _ as usize).unwrap();
            env.barrier(ctx.shmem());
            ag.free(env);
            (w0 == w1, dts)
        });
        for (stable, dts) in out {
            assert!(stable, "window must survive across start/wait cycles");
            // Steady state: iterations 2 and 3 charge identical virtual
            // time — nothing is re-set-up per invocation.
            assert!((dts[1] - dts[2]).abs() < 1e-9, "re-setup cost detected: {dts:?}");
        }
    }
}
