//! `Wrapper_Hy_Gather` — hybrid MPI+MPI rooted gather.
//!
//! The §4.2 allgather design minus the full replication: every rank
//! stores its block at its affinity slot of the node's shared window
//! (zero on-node messages), a red sync publishes the node's
//! contributions, and the **leaders** run an irregular gatherv over the
//! bridge rooted at the root's node — so the complete rank-ordered result
//! materializes only in the root node's shared window, where the root
//! (leader or child) reads it after the yellow sync. Non-root nodes move
//! exactly one bridge message; their windows keep only their own blocks.

use super::allgather::AllgatherParam;
use super::bcast::TransTables;
use super::package::CommPackage;
use super::shmem::HyWin;
use super::sync::{await_release, red_sync, release, SyncScheme};
use crate::coll::gather::gatherv;
use crate::mpi::env::ProcEnv;
use crate::mpi::topo::Placement;

/// `Wrapper_Hy_Gather`: complete the gather across the cluster. Every
/// rank must already have stored its `msg`-byte block at its affinity
/// slot (`win.local_ptr(parent_rank, msg)`); afterwards the root can read
/// the full rank-ordered result at offset 0 of its node's window.
pub fn hy_gather(
    env: &mut ProcEnv,
    pkg: &CommPackage,
    win: &mut HyWin,
    param: &AllgatherParam,
    tables: &TransTables,
    root: usize,
    msg: usize,
    scheme: SyncScheme,
) {
    assert_eq!(
        env.topo().placement(),
        Placement::Block,
        "Wrapper_Hy_Gather assumes block-style rank placement (§4)"
    );
    assert_eq!(
        param.recvcounts.iter().sum::<usize>(),
        msg * pkg.parent.size(),
        "allgather params must match the gather block size"
    );
    let root_node = tables.bridge[root];
    // Red sync: all on-node contributions must be in the window.
    red_sync(env, pkg);
    if let Some(bridge) = &pkg.bridge {
        let bidx = bridge.rank();
        let (lo, count) = (param.displs[bidx], param.recvcounts[bidx]);
        if bridge.size() > 1 {
            if bidx == root_node {
                // Root's leader ingests every other node's block straight
                // into the shared window at its global displacement (the
                // node's own block is already in place — gatherv's
                // explicit in-place root mode, `mine: None`).
                let full_len: usize = param.recvcounts.iter().sum();
                if env.legacy_dataplane() {
                    let mine = win.win.read_vec(lo, count);
                    env.count_copy(count);
                    let out = unsafe { win.win.slice_mut(0, full_len) };
                    gatherv(env, bridge, root_node, &param.recvcounts, Some(&mine), Some(out));
                } else {
                    let out = unsafe { win.win.slice_mut(0, full_len) };
                    gatherv(env, bridge, root_node, &param.recvcounts, None, Some(out));
                }
            } else if env.legacy_dataplane() {
                let mine = win.win.read_vec(lo, count);
                env.count_copy(count);
                gatherv(env, bridge, root_node, &param.recvcounts, Some(&mine), None);
            } else {
                // Non-root leaders send their node block borrowed
                // straight from the window.
                let mine = unsafe { win.win.slice(lo, count) };
                gatherv(env, bridge, root_node, &param.recvcounts, Some(mine), None);
            }
        }
        release(env, pkg, win, scheme);
    } else {
        await_release(env, pkg, win, scheme);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::hybrid::allgather::sizeset_gather;

    fn check(nodes: &'static [usize], m: usize, root: usize, scheme: SyncScheme) {
        let p: usize = nodes.iter().sum();
        let expect: Vec<u8> = (0..p).flat_map(|r| payload(r, m)).collect();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let mut win = pkg.alloc_shared(env, m, 1, w.size());
            let sizeset = sizeset_gather(env, &pkg);
            let param = AllgatherParam::create(env, &pkg, m, &sizeset);
            let tables = TransTables::create(env, &pkg);
            let mine = payload(w.rank(), m);
            win.store(env, win.local_ptr(w.rank(), m), &mine);
            hy_gather(env, &pkg, &mut win, &param, &tables, root, m, scheme);
            let got = if w.rank() == root { win.load(env, 0, m * w.size()) } else { Vec::new() };
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            (w.rank() == root, got)
        });
        for (r, (is_root, got)) in out.into_iter().enumerate() {
            if is_root {
                assert_eq!(got, expect, "nodes {nodes:?} m {m} root {root} rank {r}");
            }
        }
    }

    #[test]
    fn roots_on_every_kind_of_rank() {
        check(&[5, 3], 16, 0, SyncScheme::Spin); // leader of node 0
        check(&[5, 3], 16, 5, SyncScheme::Spin); // leader of node 1
        check(&[5, 3], 16, 2, SyncScheme::Spin); // child on node 0
        check(&[5, 3], 16, 7, SyncScheme::Barrier); // child on node 1
    }

    #[test]
    fn irregular_three_nodes_and_single_node() {
        check(&[5, 3, 4], 24, 9, SyncScheme::Spin);
        check(&[6], 8, 3, SyncScheme::Spin);
        check(&[1], 8, 0, SyncScheme::Barrier);
    }
}
