//! The hybrid rooted gather behind
//! [`HybridCtx::gather_init`](super::ctx::HybridCtx::gather_init).
//!
//! The §4.2 allgather design minus the full replication: every rank
//! stores its block at its affinity slot of the node's shared window
//! (zero on-node messages), a red sync publishes the node's
//! contributions, and the **leaders** run an irregular gatherv over the
//! bridge(s) rooted at the root's node — leader `j` ships stripe `j` of
//! its node block over bridge `j` on NIC lane `j` — so the complete
//! rank-ordered result materializes only in the root node's shared
//! window, where the root (leader or child) reads it after the yellow
//! sync. Non-root nodes move exactly `k` bridge messages; their windows
//! keep only their own blocks.

use super::allgather::AllgatherParam;
use super::bcast::TransTables;
use super::ctx::{HybridCtx, StripeTable};
use super::shmem::HyWin;
#[cfg(test)]
use super::sync::SyncScheme;
use crate::coll::gather::{gatherv, gatherv_offsets};
use crate::mpi::env::ProcEnv;

/// The leaders' bridge gatherv — the `Work` stage of the gather
/// schedule, executed after the red sync (all on-node contributions in
/// the window) and before the yellow release; afterwards the root can
/// read the full rank-ordered result at offset 0 of its node's window.
/// With `k = 1` (empty `stripes`) this is byte- and vtime-identical to
/// the pre-session `Wrapper_Hy_Gather` bridge step.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bridge(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    param: &AllgatherParam,
    tables: &TransTables,
    stripes: &[StripeTable],
    root: usize,
    msg: usize,
) {
    assert_eq!(
        param.recvcounts.iter().sum::<usize>(),
        msg * ctx.parent().size(),
        "allgather params must match the gather block size"
    );
    let root_node = tables.bridge[root];
    if let Some(j) = ctx.leader_index() {
        let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
        let bidx = bridge.rank();
        if bridge.size() > 1 {
            if stripes.is_empty() {
                let (lo, count) = (param.displs[bidx], param.recvcounts[bidx]);
                if bidx == root_node {
                    // Root's leader ingests every other node's block
                    // straight into the shared window at its global
                    // displacement (the node's own block is already in
                    // place — gatherv's explicit in-place root mode,
                    // `mine: None`).
                    let full_len: usize = param.recvcounts.iter().sum();
                    if env.legacy_dataplane() {
                        let mine = win.win.read_vec(lo, count);
                        env.count_copy(count);
                        let out = unsafe { win.win.slice_mut(0, full_len) };
                        gatherv(env, &bridge, root_node, &param.recvcounts, Some(&mine), Some(out));
                    } else {
                        let out = unsafe { win.win.slice_mut(0, full_len) };
                        gatherv(env, &bridge, root_node, &param.recvcounts, None, Some(out));
                    }
                } else if env.legacy_dataplane() {
                    let mine = win.win.read_vec(lo, count);
                    env.count_copy(count);
                    gatherv(env, &bridge, root_node, &param.recvcounts, Some(&mine), None);
                } else {
                    // Non-root leaders send their node block borrowed
                    // straight from the window.
                    let mine = unsafe { win.win.slice(lo, count) };
                    gatherv(env, &bridge, root_node, &param.recvcounts, Some(mine), None);
                }
            } else {
                // Leader j ships/ingests stripe j of every node block.
                let st = &stripes[j];
                env.with_nic_lane(j, |env| {
                    if bidx == root_node {
                        let full_len: usize = param.recvcounts.iter().sum();
                        let out = unsafe { win.win.slice_mut(0, full_len) };
                        gatherv_offsets(
                            env, &bridge, root_node, &st.counts, &st.offsets, None, Some(out),
                        );
                    } else {
                        let mine = unsafe { win.win.slice(st.offsets[bidx], st.counts[bidx]) };
                        gatherv_offsets(
                            env, &bridge, root_node, &st.counts, &st.offsets, Some(mine), None,
                        );
                    }
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::hybrid::LeaderPolicy;

    fn check(nodes: &'static [usize], m: usize, root: usize, k: usize, scheme: SyncScheme) {
        let p: usize = nodes.iter().sum();
        let expect: Vec<u8> = (0..p).flat_map(|r| payload(r, m)).collect();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let mut g = ctx.gather_init(env, m, scheme);
            let mine = payload(w.rank(), m);
            g.start_gather(env, root, &mine);
            g.wait(env);
            let got = if w.rank() == root {
                g.window().unwrap().load(env, 0, m * w.size())
            } else {
                Vec::new()
            };
            env.barrier(ctx.shmem());
            g.free(env);
            (w.rank() == root, got)
        });
        for (r, (is_root, got)) in out.into_iter().enumerate() {
            if is_root {
                assert_eq!(got, expect, "nodes {nodes:?} m {m} root {root} k {k} rank {r}");
            }
        }
    }

    #[test]
    fn roots_on_every_kind_of_rank() {
        check(&[5, 3], 16, 0, 1, SyncScheme::Spin); // leader of node 0
        check(&[5, 3], 16, 5, 1, SyncScheme::Spin); // leader of node 1
        check(&[5, 3], 16, 2, 1, SyncScheme::Spin); // child on node 0
        check(&[5, 3], 16, 7, 1, SyncScheme::Barrier); // child on node 1
    }

    #[test]
    fn multi_leader_roots_everywhere() {
        for root in [0usize, 1, 6, 7] {
            check(&[5, 3], 16, root, 2, SyncScheme::Spin);
            check(&[5, 3], 16, root, 3, SyncScheme::Barrier);
        }
    }

    #[test]
    fn irregular_three_nodes_and_single_node() {
        check(&[5, 3, 4], 24, 9, 1, SyncScheme::Spin);
        check(&[5, 3, 4], 24, 9, 2, SyncScheme::Spin);
        check(&[6], 8, 3, 2, SyncScheme::Spin);
        check(&[1], 8, 0, 1, SyncScheme::Barrier);
    }
}
