//! The paper's contribution: hybrid MPI+MPI context-based collectives.
//!
//! In the hybrid MPI+MPI model (§3.2), one *leader* rank per node (the
//! lowest rank on the node under block placement) joins the *bridge*
//! communicator that carries all inter-node traffic; its on-node *children*
//! share one copy of every collective result inside an MPI-3 shared-memory
//! window and access it with plain load/store — eliminating both the
//! per-rank result replication and the library's on-node staging copies
//! that the pure-MPI collectives pay.
//!
//! Module map (paper primitive → here):
//!
//! | paper (§4) | here |
//! |---|---|
//! | `struct comm_package` | [`package::CommPackage`] |
//! | `Wrapper_MPI_ShmemBridgeComm_create` | [`package::CommPackage::create`] |
//! | `Wrapper_MPI_Sharedmemory_alloc` | [`shmem::CommPackage_alloc` → `package::CommPackage::alloc_shared`] |
//! | `Wrapper_Get_localpointer` | [`shmem::HyWin::local_ptr`] |
//! | `Wrapper_Comm_free` | [`package::CommPackage::free`] |
//! | `Wrapper_ShmemcommSizeset_gather` | [`allgather::sizeset_gather`] |
//! | `Wrapper_Create_Allgather_param` | [`allgather::AllgatherParam::create`] |
//! | `Wrapper_Hy_Allgather` | [`allgather::hy_allgather`] |
//! | `Wrapper_Get_transtable` | [`bcast::TransTables::create`] |
//! | `Wrapper_Hy_Bcast` | [`bcast::hy_bcast`] |
//! | `Wrapper_Hy_Allreduce` | [`allreduce::hy_allreduce`] |
//! | §4.5 sync schemes | [`sync::SyncScheme`] |
//!
//! Beyond the paper's three collectives, the wrapper set carries the
//! extra operations the follow-up work on multi-core clusters
//! (arXiv:2007.06892) shows matter for hybrid codes:
//! [`reduce_scatter::hy_reduce_scatter`], [`gather::hy_gather`] and
//! [`scatter::hy_scatter`] — same window/red-sync/bridge/yellow-sync
//! skeleton, rooted or scattered result placement.

pub mod allgather;
pub mod allreduce;
pub mod bcast;
pub mod gather;
pub mod package;
pub mod reduce_scatter;
pub mod scatter;
pub mod shmem;
pub mod sync;

pub use allgather::{hy_allgather, sizeset_gather, AllgatherParam};
pub use allreduce::{hy_allreduce, AllreduceMethod};
pub use bcast::{hy_bcast, TransTables};
pub use gather::hy_gather;
pub use package::CommPackage;
pub use reduce_scatter::{alloc_reduce_scatter_win, hy_reduce_scatter};
pub use scatter::hy_scatter;
pub use shmem::HyWin;
pub use sync::SyncScheme;
