//! The paper's contribution: hybrid MPI+MPI context-based collectives,
//! as a **session API** with persistent per-collective handles.
//!
//! In the hybrid MPI+MPI model (§3.2), *leader* ranks per node join
//! *bridge* communicators that carry all inter-node traffic; their
//! on-node *children* share one copy of every collective result inside an
//! MPI-3 shared-memory window and access it with plain load/store —
//! eliminating both the per-rank result replication and the library's
//! on-node staging copies that the pure-MPI collectives pay.
//!
//! Everything the user touches is two types:
//!
//! - [`HybridCtx`] — the session: created once per parent communicator
//!   with a [`LeaderPolicy`] (`k ≥ 1` leaders per node, each on its own
//!   same-index bridge communicator and NIC lane — the multi-leader
//!   design of arXiv 2007.06892). Owns and caches every one-off wrapper
//!   object the paper's §4 describes (communicator splits, size sets,
//!   translation tables).
//! - [`HyColl`] — a persistent collective handle in the
//!   `MPI_Allreduce_init` style: `ctx.allgather_init(…)` binds window,
//!   parameters, stripe tables, sync scheme and step-1 method once;
//!   `start_*`/`wait` is the steady-state invocation pair.
//!
//! Correspondence with the paper's §4 primitives (all folded behind the
//! session now — the old free functions are gone):
//!
//! | paper (§4) | here |
//! |---|---|
//! | `struct comm_package` | [`HybridCtx`] |
//! | `Wrapper_MPI_ShmemBridgeComm_create` | [`HybridCtx::create`] |
//! | `Wrapper_MPI_Sharedmemory_alloc` | [`HybridCtx::alloc_shared`] (inside every `*_init`) |
//! | `Wrapper_Get_localpointer` | [`shmem::HyWin::local_ptr`] / [`HyColl::result_view`] |
//! | `Wrapper_Comm_free` | drop the session / [`HyColl::free`] |
//! | `Wrapper_ShmemcommSizeset_gather` | [`HybridCtx::sizeset`] |
//! | `Wrapper_Create_Allgather_param` | [`allgather::AllgatherParam::create`] (inside `*_init`) |
//! | `Wrapper_Hy_Allgather` | [`HybridCtx::allgather_init`] → [`HyColl`] |
//! | `Wrapper_Get_transtable` | [`HybridCtx::tables`] |
//! | `Wrapper_Hy_Bcast` | [`HybridCtx::bcast_init`] → [`HyColl`] |
//! | `Wrapper_Hy_Allreduce` | [`HybridCtx::allreduce_init`] → [`HyColl`] |
//! | §4.5 sync schemes | [`sync::SyncScheme`] |
//!
//! Beyond the paper's three collectives, the session carries the extra
//! operations the multi-core-cluster follow-up (arXiv:2007.06892) shows
//! matter for hybrid codes: [`HybridCtx::reduce_scatter_init`],
//! [`HybridCtx::gather_init`] and [`HybridCtx::scatter_init`] — same
//! window/red-sync/bridge/yellow-sync skeleton, rooted or scattered
//! result placement, all striped across the leader set.

//! ## Split-phase execution (DESIGN.md §5e)
//!
//! `HyColl` handles are **nonblocking requests**: `*_init` compiles a
//! per-rank stage schedule ([`progress`] module), `start_*` stages
//! operands and launches every locally-runnable stage (barrier arrivals,
//! the root side's eager pipelined bridge chunks), and completion is
//! driven either by the blocking [`HyColl::wait`] (bit- and
//! vtime-identical to the PR-4 monolithic wait) or the split-phase
//! [`HyReq`] surface — `test`/`progress` between which the caller
//! overlaps its own compute, plus [`HybridCtx::wait_any`] /
//! [`HybridCtx::wait_all`] over heterogeneous handles. Rooted ops accept
//! a [`RootPolicy`] (`Fixed` = the strict `MPI_Bcast_init` shape that
//! enables root-side bridge pipelining) and, for bcast/scatter, a
//! pipelining depth that chunks the bridge into per-start sub-steps.
//!
//! ## Fault tolerance (DESIGN.md fault model)
//!
//! Under a [`FaultPlan`](crate::mpi::FaultPlan) the session degrades
//! gracefully instead of hanging: blocking completions park with a
//! deadline and consult the dead-rank registry on expiry, so a peer
//! death surfaces as `Err(`[`RankFailed`](crate::mpi::RankFailed)`)`
//! from [`HyColl::try_wait`] / [`HyColl::try_test`] within the
//! configured detection bound. Recovery is ULFM-shaped:
//! [`HybridCtx::shrink`] rebuilds the session (leader set, bridge
//! communicators, stripe tables) over the survivors through an
//! epoch-tagged restartable agreement (deaths *during* the agreement —
//! the coordinator's included — restart the round under a higher
//! epoch), and [`HyColl::rebuild`] re-initializes a handle — including
//! its compiled stage schedule — on the shrunken session, re-electing a
//! dead fixed root when the handle carries a [`RootPolicy::Reelect`]
//! hook. [`HybridCtx::run_resilient`] wraps the whole detect → purge →
//! shrink → rebuild → restart cycle into a self-healing retry driver
//! with configurable backoff ([`RetryPolicy`]) and per-epoch recovery
//! cost reports ([`EpochReport`]); detection time is charged to virtual
//! time by the fault plan's detection-cost model, so chaos benchmarks
//! include time-to-detect.

pub mod allgather;
pub mod allreduce;
pub mod bcast;
pub mod ctx;
pub mod gather;
pub mod progress;
pub mod reduce_scatter;
pub mod scatter;
pub mod shmem;
pub mod sync;

pub use allgather::AllgatherParam;
pub use allreduce::{AllreduceMethod, METHOD_CUTOFF_BYTES};
pub use bcast::TransTables;
pub use ctx::{
    shrink_scope_key, EpochReport, HyColl, HyOp, HybridCtx, LeaderPolicy, Resilience, RetryPolicy,
};
pub use progress::{default_reelect, wait_all, wait_any, ElectRoot, HyReq, Reelection, RootPolicy};
pub use shmem::HyWin;
pub use sync::SyncScheme;
