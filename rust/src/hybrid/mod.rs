//! The paper's contribution: hybrid MPI+MPI context-based collectives.
//!
//! In the hybrid MPI+MPI model (§3.2), one *leader* rank per node (the
//! lowest rank on the node under block placement) joins the *bridge*
//! communicator that carries all inter-node traffic; its on-node *children*
//! share one copy of every collective result inside an MPI-3 shared-memory
//! window and access it with plain load/store — eliminating both the
//! per-rank result replication and the library's on-node staging copies
//! that the pure-MPI collectives pay.
//!
//! Module map (paper primitive → here):
//!
//! | paper (§4) | here |
//! |---|---|
//! | `struct comm_package` | [`package::CommPackage`] |
//! | `Wrapper_MPI_ShmemBridgeComm_create` | [`package::CommPackage::create`] |
//! | `Wrapper_MPI_Sharedmemory_alloc` | [`shmem::CommPackage_alloc` → `package::CommPackage::alloc_shared`] |
//! | `Wrapper_Get_localpointer` | [`shmem::HyWin::local_ptr`] |
//! | `Wrapper_Comm_free` | [`package::CommPackage::free`] |
//! | `Wrapper_ShmemcommSizeset_gather` | [`allgather::sizeset_gather`] |
//! | `Wrapper_Create_Allgather_param` | [`allgather::AllgatherParam::create`] |
//! | `Wrapper_Hy_Allgather` | [`allgather::hy_allgather`] |
//! | `Wrapper_Get_transtable` | [`bcast::TransTables::create`] |
//! | `Wrapper_Hy_Bcast` | [`bcast::hy_bcast`] |
//! | `Wrapper_Hy_Allreduce` | [`allreduce::hy_allreduce`] |
//! | §4.5 sync schemes | [`sync::SyncScheme`] |

pub mod allgather;
pub mod allreduce;
pub mod bcast;
pub mod package;
pub mod shmem;
pub mod sync;

pub use allgather::{hy_allgather, sizeset_gather, AllgatherParam};
pub use allreduce::{hy_allreduce, AllreduceMethod};
pub use bcast::{hy_bcast, TransTables};
pub use package::CommPackage;
pub use shmem::HyWin;
pub use sync::SyncScheme;
