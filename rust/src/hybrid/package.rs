//! The `comm_package` wrapper (§4.1): two-level communicator splitting.

use crate::mpi::comm::UNDEFINED;
use crate::mpi::env::ProcEnv;
use crate::mpi::Communicator;

/// The paper's `struct comm_package`: the shared-memory (node) and bridge
/// (leaders-only) communicators plus their sizes.
pub struct CommPackage {
    /// The parent this package was derived from.
    pub parent: Communicator,
    /// Node-level communicator (`MPI_Comm_split_type(…SHARED…)`).
    pub shmem: Communicator,
    /// Bridge communicator — `Some` only on node leaders.
    pub bridge: Option<Communicator>,
    /// `shmemcomm_size`.
    pub shmem_size: usize,
    /// `bridgecomm_size` (number of nodes hosting members of `parent`;
    /// known on children too, unlike in raw MPI where only leaders see it).
    pub bridge_size: usize,
}

impl CommPackage {
    /// `Wrapper_MPI_ShmemBridgeComm_create`: split `parent` into the
    /// node-level communicator and the bridge over node leaders (lowest
    /// rank per node leads). Communicators other than `MPI_COMM_WORLD` are
    /// supported (§4.1 "complex use cases").
    ///
    /// One-off cost: two `MPI_Comm_split`s — the Table-2 "Communicator"
    /// row — charged by the split mechanics themselves.
    pub fn create(env: &mut ProcEnv, parent: &Communicator) -> CommPackage {
        let shmem = env.split_type_shared(parent);
        let is_leader = shmem.rank() == 0;
        let bridge = env.split(parent, if is_leader { 0 } else { UNDEFINED }, parent.rank() as i64);
        // Node count of the parent group (= bridge size), computable from
        // the topology on every rank.
        let topo = env.topo();
        let mut nodes: Vec<usize> = parent.members().iter().map(|&w| topo.node_of(w)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        CommPackage {
            parent: parent.clone(),
            shmem_size: shmem.size(),
            bridge_size: nodes.len(),
            shmem,
            bridge,
        }
    }

    /// Am I my node's leader?
    pub fn is_leader(&self) -> bool {
        self.shmem.rank() == 0
    }

    /// My bridge rank = the index of my node among the parent's nodes
    /// (valid on children too; equals `bridge.rank()` on leaders).
    pub fn bridge_index(&self, env: &ProcEnv) -> usize {
        let topo = env.topo();
        let my_node = topo.node_of(env.world_rank());
        let mut nodes: Vec<usize> = self.parent.members().iter().map(|&w| topo.node_of(w)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes.iter().position(|&n| n == my_node).expect("my node hosts me")
    }

    /// `Wrapper_Comm_free`: release both sub-communicators. (Handles are
    /// reference-counted here, so this is semantic bookkeeping — the
    /// paper's point is that the *user* never touches the raw handles.)
    pub fn free(self, _env: &mut ProcEnv) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;

    #[test]
    fn leaders_get_bridge_children_do_not() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            (
                env.world_rank(),
                pkg.is_leader(),
                pkg.bridge.as_ref().map(|b| (b.size(), b.rank())),
                pkg.shmem_size,
                pkg.bridge_size,
                pkg.bridge_index(env),
            )
        });
        for (wr, leader, bridge, shm_size, bridge_size, bidx) in out {
            assert_eq!(bridge_size, 2);
            if wr == 0 || wr == 5 {
                assert!(leader);
                let (bsz, brank) = bridge.unwrap();
                assert_eq!(bsz, 2);
                assert_eq!(brank, if wr == 0 { 0 } else { 1 });
            } else {
                assert!(!leader);
                assert!(bridge.is_none());
            }
            assert_eq!(shm_size, if wr < 5 { 5 } else { 3 });
            assert_eq!(bidx, if wr < 5 { 0 } else { 1 });
        }
    }

    #[test]
    fn derived_communicator_supported() {
        // Package over a sub-communicator (even world ranks only).
        let out = run_nodes(&[4, 4], |env| {
            let w = env.world();
            let even = env.split(&w, (w.rank() % 2) as i64, w.rank() as i64).unwrap();
            if w.rank() % 2 == 0 {
                let pkg = CommPackage::create(env, &even);
                Some((pkg.shmem_size, pkg.bridge_size, pkg.is_leader()))
            } else {
                // Odd ranks also got a comm (color 1) — build a package on
                // it to keep the collective call pattern aligned.
                let pkg = CommPackage::create(env, &even);
                Some((pkg.shmem_size, pkg.bridge_size, pkg.is_leader()))
            }
        });
        for (r, v) in out.into_iter().enumerate() {
            let (shm, bridge, leader) = v.unwrap();
            assert_eq!(shm, 2, "rank {r}: 2 same-parity ranks per node");
            assert_eq!(bridge, 2);
            // Leaders = lowest world rank of each parity on each node:
            // ranks 0, 1 (node 0) and 4, 5 (node 1).
            assert_eq!(leader, r % 4 < 2, "rank {r}");
        }
    }
}
