//! **Deprecated shim**: the paper's `struct comm_package` (§4.1) as a thin
//! wrapper over the `k = 1` session context.
//!
//! The free-function wrapper API this type anchored (PRs 0–3) is gone —
//! every hybrid collective now lives on [`HybridCtx`] as a persistent
//! handle pair (`*_init` → `start`/`wait`). `CommPackage` remains only
//! for source compatibility with the paper's §4.1 naming: it *is* a
//! `HybridCtx` with [`LeaderPolicy::Single`] (same two communicator
//! splits, same charges — creation virtual time is identical), and
//! exposes the underlying session via [`CommPackage::ctx`]. New code
//! should call [`HybridCtx::create`] directly.

use super::ctx::{HybridCtx, LeaderPolicy};
use super::shmem::HyWin;
use crate::mpi::env::ProcEnv;
use crate::mpi::Communicator;
use std::rc::Rc;

/// The paper's `struct comm_package`: the shared-memory (node) and bridge
/// (leaders-only) communicators plus their sizes. Deprecated — a frozen
/// `k = 1` view of [`HybridCtx`].
#[deprecated(
    since = "0.1.0",
    note = "use the session API: HybridCtx::create(env, parent, LeaderPolicy::Single) and the \
            persistent *_init → start/wait (or split-phase HyReq test/progress) handles"
)]
pub struct CommPackage {
    /// The parent this package was derived from.
    pub parent: Communicator,
    /// Node-level communicator (`MPI_Comm_split_type(…SHARED…)`).
    pub shmem: Communicator,
    /// Bridge communicator — `Some` only on node leaders.
    pub bridge: Option<Communicator>,
    /// `shmemcomm_size`.
    pub shmem_size: usize,
    /// `bridgecomm_size` (number of nodes hosting members of `parent`;
    /// known on children too, unlike in raw MPI where only leaders see it).
    pub bridge_size: usize,
    ctx: Rc<HybridCtx>,
}

#[allow(deprecated)]
impl CommPackage {
    /// `Wrapper_MPI_ShmemBridgeComm_create`: split `parent` into the
    /// node-level communicator and the bridge over node leaders (lowest
    /// rank per node leads). Identical mechanics and virtual-time charge
    /// to `HybridCtx::create(env, parent, LeaderPolicy::Single)` — which
    /// is what it runs.
    pub fn create(env: &mut ProcEnv, parent: &Communicator) -> CommPackage {
        let ctx = HybridCtx::create(env, parent, LeaderPolicy::Single);
        CommPackage {
            parent: ctx.parent().clone(),
            shmem: ctx.shmem().clone(),
            bridge: ctx.bridge().cloned(),
            shmem_size: ctx.shmem_size(),
            bridge_size: ctx.nnodes(),
            ctx,
        }
    }

    /// The session context backing this shim.
    pub fn ctx(&self) -> &Rc<HybridCtx> {
        &self.ctx
    }

    /// Am I my node's leader?
    pub fn is_leader(&self) -> bool {
        self.ctx.is_leader()
    }

    /// My bridge rank = the index of my node among the parent's nodes
    /// (valid on children too; equals `bridge.rank()` on leaders).
    pub fn bridge_index(&self, _env: &ProcEnv) -> usize {
        self.ctx.node_index()
    }

    /// `Wrapper_MPI_Sharedmemory_alloc` pass-through.
    pub fn alloc_shared(&self, env: &mut ProcEnv, msize: usize, bsize: usize, flag: usize) -> HyWin {
        self.ctx.alloc_shared(env, msize, bsize, flag)
    }

    /// `Wrapper_Comm_free`: release both sub-communicators. (Handles are
    /// reference-counted here, so this is semantic bookkeeping — the
    /// paper's point is that the *user* never touches the raw handles.)
    pub fn free(self, _env: &mut ProcEnv) {}
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;

    #[test]
    fn leaders_get_bridge_children_do_not() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            (
                env.world_rank(),
                pkg.is_leader(),
                pkg.bridge.as_ref().map(|b| (b.size(), b.rank())),
                pkg.shmem_size,
                pkg.bridge_size,
                pkg.bridge_index(env),
            )
        });
        for (wr, leader, bridge, shm_size, bridge_size, bidx) in out {
            assert_eq!(bridge_size, 2);
            if wr == 0 || wr == 5 {
                assert!(leader);
                let (bsz, brank) = bridge.unwrap();
                assert_eq!(bsz, 2);
                assert_eq!(brank, if wr == 0 { 0 } else { 1 });
            } else {
                assert!(!leader);
                assert!(bridge.is_none());
            }
            assert_eq!(shm_size, if wr < 5 { 5 } else { 3 });
            assert_eq!(bidx, if wr < 5 { 0 } else { 1 });
        }
    }

    #[test]
    fn shim_mirrors_its_session_exactly() {
        // The acceptance invariant: the shim *is* HybridCtx k = 1 — same
        // communicators, same creation vtime as a directly-created
        // single-leader session.
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            env.harness_sync(&w);
            let t0 = env.vclock();
            let pkg = CommPackage::create(env, &w);
            let shim_dt = env.vclock() - t0;
            env.harness_sync(&w);
            let t1 = env.vclock();
            let ctx = crate::hybrid::HybridCtx::create(env, &w, crate::hybrid::LeaderPolicy::Single);
            let ctx_dt = env.vclock() - t1;
            let same_shape = pkg.shmem_size == ctx.shmem_size()
                && pkg.bridge_size == ctx.nnodes()
                && pkg.is_leader() == ctx.is_leader()
                && pkg.bridge.is_some() == ctx.bridge().is_some()
                && pkg.ctx().leaders_per_node() == 1;
            (shim_dt, ctx_dt, same_shape)
        });
        for (shim_dt, ctx_dt, same_shape) in out {
            assert!(same_shape);
            assert!(
                (shim_dt - ctx_dt).abs() < 1e-9,
                "shim creation must charge exactly the k=1 session: {shim_dt} vs {ctx_dt}"
            );
        }
    }
}
