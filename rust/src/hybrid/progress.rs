//! The split-phase **progress engine** (DESIGN.md §5e): compiled stage
//! schedules for the hybrid collectives, advanced non-collectively.
//!
//! PR 4's session handles were persistent but *blocking*: `start_*`
//! staged operands, and one monolithic `wait` performed the node sync,
//! the striped bridge step and the release in a single call — so a
//! kernel could never hide bridge latency behind its own computation.
//! This module redesigns execution into the `MPI_Iallreduce` shape the
//! follow-up work (arXiv 2007.06892; "MPI×Threads", 2024) identifies as
//! the payoff of finer-grained communication:
//!
//! - every [`HyColl`](super::ctx::HyColl) compiles, once at `*_init`, a
//!   `Schedule` — the linear chain of stages its rank executes per
//!   invocation (operand staging → node sync → per-leader bridge
//!   sub-steps, optionally chunked for pipelining → yellow release);
//! - the public [`HyReq`] surface advances that schedule: `test` and
//!   `progress` run every stage that can complete *without blocking*
//!   (barrier arrivals, send-side bridge chunks, probe-confirmed
//!   receive chunks, posted spin flags), `wait` drives the remainder to
//!   completion;
//! - [`wait_any`]/[`wait_all`] multiplex heterogeneous handles.
//!
//! ## Why the blocking path stays bit- and vtime-identical
//!
//! `HyColl::wait` is now literally "drive the schedule to completion":
//! each stage executes the *same primitives in the same order* as the
//! old monolith (the op modules' bridge bodies are unchanged for
//! `depth = 1`), barriers charge through
//! [`ProcEnv::finish_group_barrier`] (the same `vmax + dissemination`
//! law as [`ProcEnv::barrier`]), and the spin release charges one
//! `spin_poll_us` at observation exactly as before. A `start` followed
//! immediately by `wait` therefore charges the identical virtual time
//! and produces identical bytes — asserted by every pre-existing hybrid
//! test, which now runs on the schedule path.
//!
//! ## Where the overlap win comes from
//!
//! Virtual time in this simulator is *arrival-max* based: a receiver's
//! clock advances to `max(own_clock, sent_at + wire)`. A split-phase
//! caller that computes between `start` and `wait` therefore hides
//! in-flight traffic under its own compute: eager sends posted at
//! `start` (root-side pipelining), barrier arrivals registered at
//! `start` ([`SyncGroup::arrive`]), and the leader's release flag are
//! all timestamped *before* the compute, so the `wait`-side charges
//! collapse to `max(compute, communication)` instead of their sum.
//!
//! ## Determinism discipline
//!
//! Modeled virtual time stays deterministic as long as stages execute at
//! fixed program points — `start` and `wait` (plus `test`/`progress`
//! calls whose outcome is pinned by real synchronization, as in the
//! `overlap.rs` tests). Kernels and benches follow this discipline;
//! free-running `test` polling is MPI-faithful but lets host scheduling
//! choose *when* a stage's charge lands, like a real `MPI_Test` loop.
//!
//! ## Ordering contract
//!
//! Per-handle syncs run on *window-private* barrier groups
//! ([`SharedWindow::sync_group`](crate::mpi::win::SharedWindow::sync_group)),
//! so in-flight handles never interleave with user barriers or with each
//! other. The one rule carried over from MPI: all members of a session's
//! communicator must start handles, and fall back to blocking stages
//! (`wait`, or the [`wait_any`] fallback), in the same program order.
//!
//! ## Analyzability
//!
//! A compiled schedule is also a *checkable artifact*:
//! [`HyColl::export_schedule`](super::ctx::HyColl::export_schedule)
//! lowers it into the [`analysis`](crate::analysis) model — coarse on
//! data, exact on synchronization — which the static verifier checks
//! across ranks for deadlock-freedom, barrier arity, bridge send/recv
//! matching and window bounds (DESIGN.md §6; the `verify_schedules`
//! binary sweeps every committed shape in CI).
//!
//! ## Fault-aware driving (PR 7)
//!
//! Under a [`FaultPlan`](crate::mpi::FaultPlan) every blocking stage
//! parks with a deadline, so a dead peer surfaces as
//! `Err(`[`RankFailed`](crate::mpi::RankFailed)`)` from
//! [`HyColl::try_wait`](super::ctx::HyColl::try_wait) /
//! [`try_test`](super::ctx::HyColl::try_test) instead of a hang. The
//! infallible `HyReq` surface stays infallible: `wait`/`test` (and the
//! [`wait_any`]/[`wait_all`] multiplexers, via `step_blocking`) panic
//! with the typed error and a recovery hint — callers that want to
//! *survive* a failure drive the handle through the fallible methods and
//! recover with [`HybridCtx::shrink`](super::ctx::HybridCtx::shrink) +
//! [`HyColl::rebuild`](super::ctx::HyColl::rebuild). `progress` and
//! poll-mode `test` never park, so on clean stalls they simply report no
//! movement.
//!
//! [`ProcEnv::finish_group_barrier`]: crate::mpi::env::ProcEnv::finish_group_barrier
//! [`ProcEnv::barrier`]: crate::mpi::env::ProcEnv::barrier
//! [`SyncGroup::arrive`]: crate::mpi::sync::SyncGroup::arrive

use crate::mpi::env::ProcEnv;

/// How a rooted persistent collective binds its root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RootPolicy {
    /// The root is an argument of every `start_*` — the PR-4 behaviour
    /// (a documented deviation from `MPI_Bcast_init`) that lets SUMMA's
    /// rotating roots reuse one window.
    PerStart,
    /// The strict `MPI_Bcast_init` mode: the root is baked at `*_init`,
    /// every `start_*` must name the same rank, and the root-side
    /// schedule is compiled root-aware — which is what lets the root's
    /// bridge sub-steps launch inside `start`, before any non-root rank
    /// has arrived (root-side pipelining; closes the ROADMAP
    /// "root-bound persistent handles" item). If the root dies,
    /// [`HyColl::rebuild`](super::ctx::HyColl::rebuild) panics — picking
    /// a replacement is an application decision; opt into one with
    /// [`RootPolicy::Reelect`].
    Fixed(usize),
    /// [`RootPolicy::Fixed`] with failover (ISSUE 8): compiles and
    /// drives exactly like `Fixed(root)`, but if the root is dead at
    /// [`HyColl::rebuild`](super::ctx::HyColl::rebuild) the election
    /// hook picks a successor among the survivors instead of panicking.
    /// Construct with [`RootPolicy::reelect`] for the default rule
    /// (lowest-ranked survivor on the dead root's former node, else the
    /// lowest survivor); a plain `fn` keeps the policy `Copy`/`Eq`.
    Reelect(usize, ElectRoot),
}

/// A root-election hook: given the election context, return the new
/// root's rank *in the shrunken communicator*. Must be deterministic in
/// its arguments — every survivor runs the election independently and
/// they must all pick the same rank.
pub type ElectRoot = fn(&Reelection<'_>) -> usize;

/// What a root election gets to look at. `survivors_world` is the
/// shrunken communicator's membership in rank order (ascending world
/// rank), `survivor_nodes` the topology node of each entry.
#[derive(Debug)]
pub struct Reelection<'a> {
    /// World rank of the dead root.
    pub old_root_world: usize,
    /// Topology node the dead root lived on.
    pub old_root_node: usize,
    /// Survivor world ranks, indexed by new communicator rank.
    pub survivors_world: &'a [usize],
    /// Topology node of each survivor, index-aligned with
    /// `survivors_world`.
    pub survivor_nodes: &'a [usize],
}

/// The default election rule: the lowest-ranked survivor on the dead
/// root's former node (its shared window and on-node data layout are the
/// closest match to the old root's), else the lowest survivor overall.
pub fn default_reelect(e: &Reelection<'_>) -> usize {
    e.survivor_nodes.iter().position(|&n| n == e.old_root_node).unwrap_or(0)
}

impl RootPolicy {
    /// `Fixed(root)` semantics with the default re-election rule on root
    /// death (see [`default_reelect`]).
    pub fn reelect(root: usize) -> RootPolicy {
        RootPolicy::Reelect(root, default_reelect)
    }

    /// The currently bound root of a `Fixed`/`Reelect` handle.
    pub fn fixed_root(&self) -> Option<usize> {
        match *self {
            RootPolicy::Fixed(r) | RootPolicy::Reelect(r, _) => Some(r),
            RootPolicy::PerStart => None,
        }
    }
}

/// A nonblocking persistent-collective request — the split-phase face of
/// [`HyColl`](super::ctx::HyColl) (which is currently the only
/// implementor; the trait exists so heterogeneous handles can be driven
/// through one [`wait_any`]/[`wait_all`] surface).
pub trait HyReq {
    /// Advance every stage that can complete without blocking; return
    /// `true` iff the started operation completed. Completion is
    /// consumed: the handle becomes inactive (`test` again panics, like
    /// operating on an inactive persistent request), so `true` is
    /// observed exactly once per `start`.
    fn test(&mut self, env: &mut ProcEnv) -> bool;

    /// Advance every non-blocking stage; `true` iff anything moved.
    /// No-op (returning `false`) on an inactive handle, so progress
    /// loops over mixed handle sets need no bookkeeping.
    fn progress(&mut self, env: &mut ProcEnv) -> bool;

    /// Drive the schedule to completion and return the result's window
    /// byte offset (the same value the blocking `HyColl::wait` returns).
    fn wait(&mut self, env: &mut ProcEnv) -> usize;

    /// Execute exactly one stage, blocking if it must — the fallback
    /// step [`wait_any`] uses when no handle can progress otherwise.
    /// No-op on an inactive or completed handle.
    fn step_blocking(&mut self, env: &mut ProcEnv);

    /// Is the handle inactive (completed or never started)?
    fn is_idle(&self) -> bool;
}

/// Block until one of `reqs` completes; returns its index. All requests
/// must be started. Fairness: each pass polls every request
/// non-blockingly (so an already-satisfiable handle completes no matter
/// where it sits in the slice); only when a full pass makes no progress
/// does the engine execute one *blocking* stage of the first incomplete
/// request — every member rank must therefore pass its requests in the
/// same order, the usual MPI collective-ordering rule.
pub fn wait_any(env: &mut ProcEnv, reqs: &mut [&mut dyn HyReq]) -> usize {
    assert!(!reqs.is_empty(), "wait_any over an empty request set");
    for r in reqs.iter() {
        assert!(!r.is_idle(), "wait_any requires every request to be started");
    }
    loop {
        let mut moved = false;
        for (i, r) in reqs.iter_mut().enumerate() {
            if r.test(env) {
                return i;
            }
            moved |= r.progress(env);
        }
        if !moved {
            // Nobody can move without blocking: drive one stage of the
            // first incomplete request. Deterministic across ranks (same
            // request order), so all members converge on the same
            // collective and no cross-handle deadlock can form.
            reqs[0].step_blocking(env);
            if reqs[0].test(env) {
                return 0;
            }
        }
    }
}

/// Drive every request to completion (in slice order — the usual MPI
/// collective-ordering rule applies across ranks); returns the
/// per-request result offsets, index-aligned with `reqs`.
pub fn wait_all(env: &mut ProcEnv, reqs: &mut [&mut dyn HyReq]) -> Vec<usize> {
    let mut offs = vec![0usize; reqs.len()];
    for i in 0..reqs.len() {
        // Opportunistically push every still-active request before each
        // blocking drive so later requests' eager stages are in flight.
        for r in reqs.iter_mut() {
            if !r.is_idle() {
                r.progress(env);
            }
        }
        offs[i] = reqs[i].wait(env);
    }
    offs
}

/// Which participants a sync stage involves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Scope {
    /// All ranks of the node communicator (the red sync, and the
    /// `Barrier`-scheme yellow sync).
    Node,
    /// The node communicator of the *pending root's* node only (the
    /// conditional red sync of bcast/scatter under `RootPolicy::PerStart`;
    /// `Fixed` handles compile it down to `Node` or omit it).
    RootNode,
    /// The node's leader set (`k > 1` only).
    Leaders,
}

/// One stage of a compiled schedule. Stages execute strictly in order —
/// the chain *is* the per-rank dependency structure of the §4 wrappers.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Stage {
    /// Register at the scope's window-private barrier group
    /// (never blocks).
    Arrive(Scope),
    /// Complete the matching [`Stage::Arrive`]: poll non-blockingly or
    /// park-wait, then charge the dissemination-barrier law.
    Await(Scope),
    /// Op-specific work unit `chunk` of the handle's `depth` (step-1
    /// reductions, bridge sub-steps, slot moves). Blocking-only unless
    /// the op classifies it send-side eligible or a mailbox probe proves
    /// the inbound chunk deliverable.
    Work { chunk: usize },
    /// Yellow release, leader side: bump the handle epoch; the primary
    /// leader posts the spin flag.
    YellowPost,
    /// Yellow release, child side: bump the epoch and observe the flag.
    YellowWait,
}

/// A compiled per-rank schedule plus its invocation cursor.
#[derive(Debug, Default)]
pub(crate) struct Schedule {
    pub(crate) stages: Vec<Stage>,
    /// Next stage to execute (= `stages.len()` when complete).
    pub(crate) next: usize,
    /// Outstanding barrier ticket of the last executed `Arrive`.
    pub(crate) ticket: Option<crate::mpi::sync::BarrierTicket>,
    /// Spin-flag target of the in-progress `YellowWait` (set on first
    /// attempt so the epoch bumps exactly once).
    pub(crate) yellow_target: Option<u32>,
    /// Per-start bridge tag of the pipelined (`depth > 1`) chunk stream
    /// (leaders only).
    pub(crate) bridge_tag: i64,
}

impl Schedule {
    pub(crate) fn new(stages: Vec<Stage>) -> Schedule {
        Schedule { next: stages.len(), stages, ticket: None, yellow_target: None, bridge_tag: 0 }
    }

    /// Arm the cursor for a fresh invocation.
    pub(crate) fn reset(&mut self) {
        self.next = 0;
        self.ticket = None;
        self.yellow_target = None;
        self.bridge_tag = 0;
    }

    pub(crate) fn complete(&self) -> bool {
        self.next >= self.stages.len()
    }
}
