//! `Wrapper_Hy_Reduce_scatter` — hybrid MPI+MPI reduce-scatter, following
//! the §4.4 allreduce design (the op the follow-up work on multi-core
//! clusters, arXiv:2007.06892, adds to the wrapper set).
//!
//! Window layout for `count`-byte result blocks over a parent of `p`
//! ranks (`T = count·p`): one `T`-byte input slot per local rank, then
//! slot `L` (the node-level partial vector) at `shmem_size·T`, then the
//! node result region `G` at `(shmem_size+1)·T` (only the node's own
//! block range of it is written).
//!
//! - **Step 1** reuses the §5.2.4 method cutoff
//!   ([`METHOD_CUTOFF_BYTES`]): below it the leader serially folds the
//!   input slots straight out of the shared window after a red sync
//!   (method 2); above it an `MPI_Reduce` over the node communicator
//!   brings the partial to the leader (method 1).
//! - **Step 2**: the leaders run an *irregular* reduce-scatter over the
//!   bridge — node `i`'s block is the concatenation of its ranks' blocks
//!   (contiguous under block placement), so the per-node counts differ on
//!   irregularly-populated clusters. The leader lands its node's reduced
//!   range in `G`; a yellow sync releases the children to read their own
//!   `count`-byte block in place.

use super::allreduce::{AllreduceMethod, METHOD_CUTOFF_BYTES};
use super::package::CommPackage;
use super::shmem::HyWin;
use super::sync::{await_release, red_sync, release, SyncScheme};
use crate::coll::reduce::reduce;
use crate::coll::reduce_scatter::reduce_scatterv;
use crate::mpi::env::ProcEnv;
use crate::mpi::topo::Placement;
use crate::mpi::{Communicator, Datatype, ReduceOp};

/// Allocate the reduce-scatter window for `count`-byte result blocks
/// (`(shmem_size + 2) · count · p` bytes on the leader).
pub fn alloc_reduce_scatter_win(env: &mut ProcEnv, pkg: &CommPackage, count: usize) -> HyWin {
    let total = count * pkg.parent.size();
    pkg.alloc_shared(env, total, 1, pkg.shmem_size + 2)
}

/// `Wrapper_Hy_Reduce_scatter`: reduce the per-rank full vectors (already
/// stored at `win.local_ptr(shmem_rank, count·p)`) across the parent
/// communicator and scatter the result blocks; afterwards every rank can
/// read its own reduced `count`-byte block at the returned window offset.
#[allow(clippy::too_many_arguments)]
pub fn hy_reduce_scatter(
    env: &mut ProcEnv,
    pkg: &CommPackage,
    win: &mut HyWin,
    sizeset: &[usize],
    dtype: Datatype,
    op: ReduceOp,
    count: usize,
    method: AllreduceMethod,
    scheme: SyncScheme,
) -> usize {
    assert_eq!(
        env.topo().placement(),
        Placement::Block,
        "Wrapper_Hy_Reduce_scatter assumes block-style rank placement (§4)"
    );
    assert_eq!(count % dtype.size(), 0);
    let p = pkg.parent.size();
    let total = count * p;
    let l_off = pkg.shmem_size * total;
    let g_off = (pkg.shmem_size + 1) * total;
    let method = match method {
        AllreduceMethod::Tuned => {
            if total <= METHOD_CUTOFF_BYTES {
                AllreduceMethod::Method2
            } else {
                AllreduceMethod::Method1
            }
        }
        m => m,
    };

    // ---- step 1: node-level reduction of the full vectors into L ------
    match method {
        AllreduceMethod::Method1 => {
            // Operands are borrowed straight out of the window; the
            // leader's result lands in slot L in place (same modeled
            // store cost as the legacy round-trip).
            let my_off = win.local_ptr(pkg.shmem.rank(), total);
            if env.legacy_dataplane() {
                let contrib = win.win.read_vec(my_off, total);
                env.count_copy(total);
                if pkg.is_leader() {
                    let mut out = vec![0u8; total];
                    reduce(env, &pkg.shmem, 0, dtype, op, &contrib, Some(&mut out));
                    win.store(env, l_off, &out);
                } else {
                    reduce(env, &pkg.shmem, 0, dtype, op, &contrib, None);
                }
            } else {
                let contrib = unsafe { win.win.slice(my_off, total) };
                if pkg.is_leader() {
                    let out = unsafe { win.win.slice_mut(l_off, total) };
                    reduce(env, &pkg.shmem, 0, dtype, op, contrib, Some(out));
                    env.charge_memcpy(total);
                } else {
                    reduce(env, &pkg.shmem, 0, dtype, op, contrib, None);
                }
            }
        }
        AllreduceMethod::Method2 => {
            red_sync(env, pkg);
            if pkg.is_leader() {
                if env.legacy_dataplane() {
                    let mut acc = win.win.read_vec(0, total);
                    env.count_copy(total);
                    for r in 1..pkg.shmem_size {
                        let operand = unsafe { win.win.slice(r * total, total) };
                        op.apply(dtype, &mut acc, operand);
                    }
                    env.charge_reduce(total * pkg.shmem_size);
                    win.win.write(l_off, &acc);
                    env.charge_memcpy(total);
                } else {
                    // Slot 0 seeds L in place; slots 1.. fold into it
                    // (legacy combine order, bit-identical results).
                    win.win.copy_within(0, l_off, total);
                    let l = unsafe { win.win.slice_mut(l_off, total) };
                    for r in 1..pkg.shmem_size {
                        let operand = unsafe { win.win.slice(r * total, total) };
                        op.apply(dtype, l, operand);
                    }
                    env.charge_reduce(total * pkg.shmem_size);
                    env.charge_memcpy(total);
                }
            }
        }
        AllreduceMethod::Tuned => unreachable!(),
    }

    // ---- step 2: bridge reduce-scatter of node blocks into G ----------
    // Node i's block range is its ranks' blocks, contiguous in parent
    // order under block placement. (Children skip this entirely — their
    // block offset needs only the parent rank.)
    if let Some(bridge) = &pkg.bridge {
        let bidx = bridge.rank();
        if bridge.size() > 1 {
            let node_counts: Vec<usize> = sizeset.iter().map(|&s| s * count).collect();
            let my_node_displ: usize = node_counts[..bidx].iter().sum();
            if env.legacy_dataplane() {
                let l = win.win.read_vec(l_off, total);
                env.count_copy(total);
                let mut mine = vec![0u8; node_counts[bidx]];
                reduce_scatterv(env, bridge, dtype, op, &node_counts, &l, &mut mine);
                win.win.write(g_off + my_node_displ, &mine);
            } else {
                // L is consumed in place; the reduced node range lands
                // directly in G (disjoint window regions).
                let l = unsafe { win.win.slice(l_off, total) };
                let mine = unsafe { win.win.slice_mut(g_off + my_node_displ, node_counts[bidx]) };
                reduce_scatterv(env, bridge, dtype, op, &node_counts, l, mine);
            }
            env.charge_memcpy(node_counts[bidx]);
        } else {
            // Single node: L is already the full result; land the node's
            // (= whole) range in G.
            if env.legacy_dataplane() {
                let l = win.win.read_vec(l_off, total);
                env.count_copy(total);
                win.win.write(g_off, &l);
            } else {
                win.win.copy_within(l_off, g_off, total);
            }
            env.charge_memcpy(total);
        }
        release(env, pkg, win, scheme);
    } else {
        await_release(env, pkg, win, scheme);
    }

    // My block: G + my parent-rank displacement.
    g_off + pkg.parent.rank() * count
}

/// Convenience wrapper mirroring the pure signature: stores `send`
/// (`count·p` bytes), runs the wrapper, copies my reduced block out into
/// `recv` (`count` bytes). `comm` must be the package's parent.
#[allow(clippy::too_many_arguments)]
pub fn hy_reduce_scatter_into(
    env: &mut ProcEnv,
    pkg: &CommPackage,
    win: &mut HyWin,
    sizeset: &[usize],
    comm: &Communicator,
    dtype: Datatype,
    op: ReduceOp,
    send: &[u8],
    recv: &mut [u8],
    scheme: SyncScheme,
) {
    let count = recv.len();
    assert_eq!(send.len(), count * comm.size());
    let slot = win.local_ptr(pkg.shmem.rank(), send.len());
    win.store(env, slot, send);
    let off =
        hy_reduce_scatter(env, pkg, win, sizeset, dtype, op, count, AllreduceMethod::Tuned, scheme);
    win.win.read_into(off, recv);
    env.charge_memcpy(count);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::hybrid::allgather::sizeset_gather;
    use crate::util::{cast_slice, to_bytes};

    fn check(nodes: &'static [usize], n_per_rank: usize, method: AllreduceMethod, scheme: SyncScheme) {
        let p: usize = nodes.iter().sum();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let count = n_per_rank * 8;
            let mut win = alloc_reduce_scatter_win(env, &pkg, count);
            let sizeset = sizeset_gather(env, &pkg);
            let me = w.rank();
            let vals: Vec<f64> =
                (0..n_per_rank * w.size()).map(|e| ((me + 1) * (e + 1)) as f64).collect();
            let slot = win.local_ptr(pkg.shmem.rank(), count * w.size());
            win.store(env, slot, to_bytes(&vals));
            let off =
                hy_reduce_scatter(env, &pkg, &mut win, &sizeset, Datatype::F64, ReduceOp::Sum, count, method, scheme);
            let mine = win.load(env, off, count);
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            cast_slice::<f64>(&mine)
        });
        let rank_sum: f64 = (1..=p).map(|r| r as f64).sum();
        for (r, got) in out.into_iter().enumerate() {
            for (i, &v) in got.iter().enumerate() {
                let e = r * n_per_rank + i;
                assert_eq!(v, rank_sum * (e + 1) as f64, "method {method:?} rank {r} elem {i}");
            }
        }
    }

    #[test]
    fn both_methods_both_schemes_irregular() {
        for method in [AllreduceMethod::Method1, AllreduceMethod::Method2] {
            for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
                check(&[5, 3], 2, method, scheme);
            }
        }
    }

    #[test]
    fn three_irregular_nodes_and_single_node() {
        check(&[5, 3, 4], 3, AllreduceMethod::Tuned, SyncScheme::Spin);
        check(&[6], 2, AllreduceMethod::Method2, SyncScheme::Spin);
        check(&[6], 2, AllreduceMethod::Method1, SyncScheme::Barrier);
        check(&[1], 4, AllreduceMethod::Tuned, SyncScheme::Spin);
    }

    #[test]
    fn matches_pure_reference_bitwise() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let me = w.rank();
            let n = 4usize;
            let vals: Vec<f64> = (0..n * w.size()).map(|e| ((me + 2) * (e + 1)) as f64).collect();
            let mut pure = vec![0u8; n * 8];
            crate::coll::reduce_scatter(
                env, &w, Datatype::F64, ReduceOp::Sum, to_bytes(&vals), &mut pure,
            );

            let pkg = CommPackage::create(env, &w);
            let mut win = alloc_reduce_scatter_win(env, &pkg, n * 8);
            let sizeset = sizeset_gather(env, &pkg);
            let mut hy = vec![0u8; n * 8];
            hy_reduce_scatter_into(
                env, &pkg, &mut win, &sizeset, &w, Datatype::F64, ReduceOp::Sum,
                to_bytes(&vals), &mut hy, SyncScheme::Spin,
            );
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            (cast_slice::<f64>(&pure), cast_slice::<f64>(&hy))
        });
        for (pure, hy) in out {
            assert_eq!(pure, hy);
        }
    }
}
