//! The hybrid reduce-scatter behind
//! [`HybridCtx::reduce_scatter_init`](super::ctx::HybridCtx::reduce_scatter_init),
//! following the §4.4 allreduce design (the op the follow-up work on
//! multi-core clusters, arXiv:2007.06892, adds to the wrapper set).
//!
//! Window layout for `count`-byte result blocks over a parent of `p`
//! ranks (`T = count·p`): one `T`-byte input slot per local rank, then
//! slot `L` (the node-level partial vector) at `shmem_size·T`, then the
//! node result region `G` at `(shmem_size+1)·T` (only the node's own
//! block range of it is written).
//!
//! - **Step 1** reuses the §5.2.4 method cutoff
//!   ([`METHOD_CUTOFF_BYTES`](super::allreduce::METHOD_CUTOFF_BYTES)):
//!   below it the leaders serially fold the input slots straight out of
//!   the shared window after a red sync (method 2, striped per leader for
//!   `k > 1`); above it an `MPI_Reduce` over the node communicator brings
//!   the partial to the primary leader (method 1).
//! - **Step 2**: the leaders run an *irregular* reduce-scatter over the
//!   bridge(s) — node `i`'s block is the concatenation of its ranks'
//!   blocks (contiguous under block placement), so the per-node counts
//!   differ on irregularly-populated clusters; leader `j` reduces stripe
//!   `j` of every node block over bridge `j` on NIC lane `j`. Each leader
//!   lands its stripe of the node's reduced range in `G`; a yellow sync
//!   releases the children to read their own `count`-byte block in place.

use super::allreduce::AllreduceMethod;
use super::ctx::{HybridCtx, StripeTable};
use super::shmem::HyWin;
#[cfg(test)]
use super::sync::SyncScheme;
use crate::coll::reduce::reduce;
use crate::coll::reduce_scatter::{reduce_scatterv, reduce_scatterv_offsets};
use crate::mpi::env::ProcEnv;
use crate::mpi::{Datatype, ReduceOp};

/// Step 1 — the node-level reduction of the full vectors into `L` (the
/// first `Work` stage of the reduce-scatter schedule; method-1 runs on
/// every rank, method-2 on leaders only after the schedule's red sync —
/// the sync itself, and the inter-method leader barrier, live in the
/// schedule). With `k = 1` (empty stripe tables) every branch is byte-
/// and vtime-identical to the pre-session `Wrapper_Hy_Reduce_scatter`
/// step 1; `method` arrives resolved.
#[allow(clippy::too_many_arguments)]
pub(crate) fn step1(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    dtype: Datatype,
    op: ReduceOp,
    count: usize,
    method: AllreduceMethod,
    vec_stripes: &[(usize, usize)],
) {
    let p = ctx.parent().size();
    let shmem_size = ctx.shmem_size();
    let total = count * p;
    let l_off = shmem_size * total;

    match method {
        AllreduceMethod::Method1 => {
            // Operands are borrowed straight out of the window; the
            // primary leader's result lands in slot L in place (same
            // modeled store cost as the legacy round-trip).
            let my_off = win.local_ptr(ctx.shmem().rank(), total);
            if env.legacy_dataplane() {
                let contrib = win.win.read_vec(my_off, total);
                env.count_copy(total);
                if ctx.is_leader() {
                    let mut out = vec![0u8; total];
                    reduce(env, ctx.shmem(), 0, dtype, op, &contrib, Some(&mut out));
                    win.store(env, l_off, &out);
                } else {
                    reduce(env, ctx.shmem(), 0, dtype, op, &contrib, None);
                }
            } else {
                let contrib = unsafe { win.win.slice(my_off, total) };
                if ctx.is_leader() {
                    let out = unsafe { win.win.slice_mut(l_off, total) };
                    reduce(env, ctx.shmem(), 0, dtype, op, contrib, Some(out));
                    env.charge_memcpy(total);
                } else {
                    reduce(env, ctx.shmem(), 0, dtype, op, contrib, None);
                }
            }
        }
        AllreduceMethod::Method2 => {
            // The schedule's red sync precedes this stage.
            if let Some(j) = ctx.leader_index() {
                let (off, len) =
                    if vec_stripes.is_empty() { (0, total) } else { vec_stripes[j] };
                if len > 0 {
                    if env.legacy_dataplane() && vec_stripes.is_empty() {
                        let mut acc = win.win.read_vec(0, total);
                        env.count_copy(total);
                        for r in 1..shmem_size {
                            let operand = unsafe { win.win.slice(r * total, total) };
                            op.apply(dtype, &mut acc, operand);
                        }
                        env.charge_reduce(total * shmem_size);
                        win.win.write(l_off, &acc);
                        env.charge_memcpy(total);
                    } else {
                        // Slot 0 seeds L in place; slots 1.. fold into it
                        // (legacy combine order, bit-identical results).
                        win.win.copy_within(off, l_off + off, len);
                        let l = unsafe { win.win.slice_mut(l_off + off, len) };
                        for r in 1..shmem_size {
                            let operand = unsafe { win.win.slice(r * total + off, len) };
                            op.apply(dtype, l, operand);
                        }
                        env.charge_reduce(len * shmem_size);
                        env.charge_memcpy(len);
                    }
                }
            }
        }
        AllreduceMethod::Tuned => unreachable!("Tuned resolves at *_init"),
    }
    // Step-1 stripes (over the whole T vector) and step-2 stripes (per
    // node block) partition L differently: with k > 1 every leader must
    // see the complete L before reading step-2 ranges that cross step-1
    // stripe boundaries — the schedule's leader barrier between the two
    // Work stages provides exactly that.
}

/// Step 2 — the leaders' (striped) bridge reduce-scatter of node blocks
/// into `G` (the second `Work` stage; the yellow release follows in the
/// schedule). Node i's block range is its ranks' blocks, contiguous in
/// parent order under block placement. (Children skip this entirely —
/// their block offset needs only the parent rank.)
#[allow(clippy::too_many_arguments)]
pub(crate) fn step2(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    sizeset: &[usize],
    dtype: Datatype,
    op: ReduceOp,
    count: usize,
    node_stripes: &[StripeTable],
    vec_stripes: &[(usize, usize)],
) {
    let p = ctx.parent().size();
    let shmem_size = ctx.shmem_size();
    let total = count * p;
    let l_off = shmem_size * total;
    let g_off = (shmem_size + 1) * total;
    if let Some(j) = ctx.leader_index() {
        let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
        let bidx = bridge.rank();
        if bridge.size() > 1 {
            let node_counts: Vec<usize> = sizeset.iter().map(|&s| s * count).collect();
            let my_node_displ: usize = node_counts[..bidx].iter().sum();
            if node_stripes.is_empty() {
                if env.legacy_dataplane() {
                    let l = win.win.read_vec(l_off, total);
                    env.count_copy(total);
                    let mut mine = vec![0u8; node_counts[bidx]];
                    reduce_scatterv(env, &bridge, dtype, op, &node_counts, &l, &mut mine);
                    win.win.write(g_off + my_node_displ, &mine);
                } else {
                    // L is consumed in place; the reduced node range lands
                    // directly in G (disjoint window regions).
                    let l = unsafe { win.win.slice(l_off, total) };
                    let mine =
                        unsafe { win.win.slice_mut(g_off + my_node_displ, node_counts[bidx]) };
                    reduce_scatterv(env, &bridge, dtype, op, &node_counts, l, mine);
                }
                env.charge_memcpy(node_counts[bidx]);
            } else {
                // Leader j reduces stripe j of every node block over
                // bridge j; its own reduced stripe lands in G at the
                // same node-relative offset.
                let st = &node_stripes[j];
                let my_stripe_off = st.offsets[bidx];
                let my_stripe_len = st.counts[bidx];
                let l = unsafe { win.win.slice(l_off, total) };
                let mine = unsafe { win.win.slice_mut(g_off + my_stripe_off, my_stripe_len) };
                env.with_nic_lane(j, |env| {
                    reduce_scatterv_offsets(env, &bridge, dtype, op, &st.counts, &st.offsets, l, mine);
                });
                env.charge_memcpy(my_stripe_len);
            }
        } else {
            // Single node: L is already the full result; land the node's
            // (= whole) range in G, striped per leader when k > 1.
            let (off, len) = if vec_stripes.is_empty() { (0, total) } else { vec_stripes[j] };
            if env.legacy_dataplane() && vec_stripes.is_empty() {
                let l = win.win.read_vec(l_off, total);
                env.count_copy(total);
                win.win.write(g_off, &l);
            } else {
                win.win.copy_within(l_off + off, g_off + off, len);
            }
            env.charge_memcpy(len);
        }
    }
    // My block (what `HyColl::result_offset` reports):
    // G + my parent-rank displacement.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::hybrid::LeaderPolicy;
    use crate::util::{cast_slice, to_bytes};

    fn check(
        nodes: &'static [usize],
        n_per_rank: usize,
        k: usize,
        method: AllreduceMethod,
        scheme: SyncScheme,
    ) {
        let p: usize = nodes.iter().sum();
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let count = n_per_rank * 8;
            let mut rs =
                ctx.reduce_scatter_init(env, Datatype::F64, ReduceOp::Sum, count, method, scheme);
            let me = w.rank();
            let vals: Vec<f64> =
                (0..n_per_rank * w.size()).map(|e| ((me + 1) * (e + 1)) as f64).collect();
            rs.start_reduce_scatter(env, to_bytes(&vals));
            let off = rs.wait(env);
            let mine = rs.window().unwrap().load(env, off, count);
            env.barrier(ctx.shmem());
            rs.free(env);
            cast_slice::<f64>(&mine)
        });
        let rank_sum: f64 = (1..=p).map(|r| r as f64).sum();
        for (r, got) in out.into_iter().enumerate() {
            for (i, &v) in got.iter().enumerate() {
                let e = r * n_per_rank + i;
                assert_eq!(
                    v,
                    rank_sum * (e + 1) as f64,
                    "method {method:?} k {k} rank {r} elem {i}"
                );
            }
        }
    }

    #[test]
    fn both_methods_both_schemes_irregular() {
        for method in [AllreduceMethod::Method1, AllreduceMethod::Method2] {
            for scheme in [SyncScheme::Barrier, SyncScheme::Spin] {
                for k in [1, 2, 3] {
                    check(&[5, 3], 2, k, method, scheme);
                }
            }
        }
    }

    #[test]
    fn three_irregular_nodes_and_single_node() {
        check(&[5, 3, 4], 3, 1, AllreduceMethod::Tuned, SyncScheme::Spin);
        check(&[5, 3, 4], 3, 2, AllreduceMethod::Tuned, SyncScheme::Spin);
        check(&[6], 2, 2, AllreduceMethod::Method2, SyncScheme::Spin);
        check(&[6], 2, 1, AllreduceMethod::Method1, SyncScheme::Barrier);
        check(&[1], 4, 1, AllreduceMethod::Tuned, SyncScheme::Spin);
    }

    #[test]
    fn matches_pure_reference_bitwise() {
        for k in [1usize, 2] {
            let out = run_nodes(&[5, 3], move |env| {
                let w = env.world();
                let me = w.rank();
                let n = 4usize;
                let vals: Vec<f64> = (0..n * w.size()).map(|e| ((me + 2) * (e + 1)) as f64).collect();
                let mut pure = vec![0u8; n * 8];
                crate::coll::reduce_scatter(
                    env, &w, Datatype::F64, ReduceOp::Sum, to_bytes(&vals), &mut pure,
                );

                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
                let mut rs = ctx.reduce_scatter_init(
                    env, Datatype::F64, ReduceOp::Sum, n * 8, AllreduceMethod::Tuned, SyncScheme::Spin,
                );
                rs.start_reduce_scatter(env, to_bytes(&vals));
                let off = rs.wait(env);
                let hy = rs.window().unwrap().load(env, off, n * 8);
                env.barrier(ctx.shmem());
                rs.free(env);
                (cast_slice::<f64>(&pure), cast_slice::<f64>(&hy))
            });
            for (pure, hy) in out {
                assert_eq!(pure, hy, "k {k}");
            }
        }
    }
}
