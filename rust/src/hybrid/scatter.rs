//! The hybrid rooted scatter behind
//! [`HybridCtx::scatter_init`](super::ctx::HybridCtx::scatter_init).
//!
//! Mirror of the hybrid gather: the root stores its whole rank-ordered
//! send buffer into its node's shared window, a red sync on the root's
//! node publishes it to the node's leaders, and the **leaders** run an
//! irregular scatterv over the bridge(s) — leader `j` of the root's node
//! sends stripe `j` of each node block over bridge `j` on NIC lane `j`;
//! each receiving leader lands its stripe in the node window at the same
//! global displacement — so after the yellow sync every rank reads its
//! own `msg`-byte block in place at `win.local_ptr(parent_rank, msg)`.
//! `k` bridge messages per non-root node, zero on-node messages.

use super::allgather::AllgatherParam;
use super::ctx::{chunk_bounds, HybridCtx, StripeTable};
use super::shmem::HyWin;
#[cfg(test)]
use super::sync::SyncScheme;
use crate::coll::scatter::{scatterv, scatterv_offsets};
use crate::mpi::env::ProcEnv;

/// The leaders' bridge scatterv — the (single, `depth = 1`) `Work` stage
/// of the scatter schedule, executed after the root-node red sync (the
/// root's stored send buffer visible to its node's leaders) and before
/// the yellow release; afterwards every rank reads its block at
/// `win.local_ptr(parent_rank, msg)`. With `k = 1` (empty `stripes`)
/// this is byte- and vtime-identical to the pre-session
/// `Wrapper_Hy_Scatter` bridge step.
pub(crate) fn bridge(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    param: &AllgatherParam,
    stripes: &[StripeTable],
    root_node: usize,
) {
    if let Some(j) = ctx.leader_index() {
        let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
        let bidx = bridge.rank();
        if bridge.size() > 1 {
            if stripes.is_empty() {
                let (lo, count) = (param.displs[bidx], param.recvcounts[bidx]);
                if bidx == root_node {
                    let full_len: usize = param.recvcounts.iter().sum();
                    if env.legacy_dataplane() {
                        let full = win.win.read_vec(0, full_len);
                        env.count_copy(full_len);
                        let mut keep = vec![0u8; count];
                        scatterv(env, &bridge, root_node, &param.recvcounts, Some(&full), &mut keep);
                    } else {
                        // Outgoing node ranges are borrowed straight from
                        // the window; `keep` only absorbs the root's own
                        // (already in-place) range, via a pooled scratch.
                        let full = unsafe { win.win.slice(0, full_len) };
                        let mut keep = env.take_buf(count);
                        scatterv(env, &bridge, root_node, &param.recvcounts, Some(full), &mut keep);
                    }
                    // The root node's own range is already in place.
                } else {
                    let out = unsafe { win.win.slice_mut(lo, count) };
                    scatterv(env, &bridge, root_node, &param.recvcounts, None, out);
                }
            } else {
                // Leader j moves stripe j of every node block.
                let st = &stripes[j];
                env.with_nic_lane(j, |env| {
                    if bidx == root_node {
                        let full_len: usize = param.recvcounts.iter().sum();
                        let full = unsafe { win.win.slice(0, full_len) };
                        // In-place root mode: the root node's stripe is
                        // already in place, no self-copy.
                        scatterv_offsets(
                            env, &bridge, root_node, &st.counts, &st.offsets, Some(full), None,
                        );
                    } else {
                        let out =
                            unsafe { win.win.slice_mut(st.offsets[bidx], st.counts[bidx]) };
                        scatterv_offsets(
                            env, &bridge, root_node, &st.counts, &st.offsets, None, Some(out),
                        );
                    }
                });
            }
        }
    }
}

/// One pipelined bridge sub-step (`depth > 1` handles): the mirror of
/// [`super::bcast::bridge_chunk`] — the root-node leader `j` flat-sends
/// chunk `c` of every *other* node's stripe range (eager, so the whole
/// stream can launch inside `start`); each receiving leader drains its
/// chunks in FIFO order into the window at the node's global
/// displacement. The root node's own range is already in place — no
/// self-copy, one of the documented deviations of the opt-in pipelined
/// mode from the `depth = 1` tree scatterv.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bridge_chunk(
    env: &mut ProcEnv,
    ctx: &HybridCtx,
    win: &mut HyWin,
    param: &AllgatherParam,
    stripes: &[StripeTable],
    root_node: usize,
    chunk: usize,
    nchunks: usize,
    tag: i64,
) {
    let Some(j) = ctx.leader_index() else { return };
    let bridge = ctx.bridge().expect("leaders hold a bridge").clone();
    if bridge.size() <= 1 {
        return;
    }
    // Leader j's (offset, len) range of node i's block.
    let node_range = |i: usize| -> (usize, usize) {
        if stripes.is_empty() {
            (param.displs[i], param.recvcounts[i])
        } else {
            (stripes[j].offsets[i], stripes[j].counts[i])
        }
    };
    env.with_nic_lane(j, |env| {
        if bridge.rank() == root_node {
            for i in 0..bridge.size() {
                if i == root_node {
                    continue;
                }
                let (off, len) = node_range(i);
                let (lo, clen) = chunk_bounds(len, nchunks, chunk);
                // Zero-length chunks still flow: chunk identity is
                // positional in the FIFO stream.
                let data = unsafe { win.win.slice(off + lo, clen) };
                env.send(&bridge, i, tag, data);
            }
        } else {
            let (off, len) = node_range(bridge.rank());
            let (lo, clen) = chunk_bounds(len, nchunks, chunk);
            let out = unsafe { win.win.slice_mut(off + lo, clen) };
            env.recv_into(&bridge, Some(root_node), tag, out);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::hybrid::LeaderPolicy;

    fn check(nodes: &'static [usize], m: usize, root: usize, k: usize, scheme: SyncScheme) {
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
            let mut sc = ctx.scatter_init(env, m, scheme);
            let full: Vec<u8> = (0..w.size()).flat_map(|r| payload(r, m)).collect();
            let arg = (w.rank() == root).then_some(&full[..]);
            sc.start_scatter(env, root, arg);
            let off = sc.wait(env);
            let got = sc.window().unwrap().load(env, off, m);
            env.barrier(ctx.shmem());
            sc.free(env);
            got
        });
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got, payload(r, m), "nodes {nodes:?} m {m} root {root} k {k} rank {r}");
        }
    }

    #[test]
    fn roots_on_every_kind_of_rank() {
        check(&[5, 3], 16, 0, 1, SyncScheme::Spin); // leader of node 0
        check(&[5, 3], 16, 5, 1, SyncScheme::Spin); // leader of node 1
        check(&[5, 3], 16, 2, 1, SyncScheme::Spin); // child on node 0
        check(&[5, 3], 16, 7, 1, SyncScheme::Barrier); // child on node 1
    }

    #[test]
    fn multi_leader_roots_everywhere() {
        for root in [0usize, 1, 6, 7] {
            check(&[5, 3], 16, root, 2, SyncScheme::Spin);
            check(&[5, 3], 16, root, 3, SyncScheme::Barrier);
        }
    }

    #[test]
    fn irregular_three_nodes_and_single_node() {
        check(&[5, 3, 4], 24, 9, 1, SyncScheme::Spin);
        check(&[5, 3, 4], 24, 9, 2, SyncScheme::Spin);
        check(&[6], 8, 3, 2, SyncScheme::Spin);
        check(&[1], 8, 0, 1, SyncScheme::Barrier);
    }

    #[test]
    fn roundtrips_with_hy_gather() {
        // scatter from rank 2, then gather back to rank 9 — both hybrid,
        // both leader counts.
        for k in [1usize, 2] {
            let out = run_nodes(&[5, 3, 4], move |env| {
                let w = env.world();
                let m = 24usize;
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(k));
                let mut sc = ctx.scatter_init(env, m, SyncScheme::Spin);
                let full: Vec<u8> = (0..w.size()).flat_map(|r| payload(r, m)).collect();
                let arg = (w.rank() == 2).then_some(&full[..]);
                sc.start_scatter(env, 2, arg);
                let off = sc.wait(env);
                let block = sc.window().unwrap().load(env, off, m);
                // A fresh handle for the gather keeps the phases independent.
                let mut g = ctx.gather_init(env, m, SyncScheme::Spin);
                g.start_gather(env, 9, &block);
                g.wait(env);
                let back = if w.rank() == 9 {
                    g.window().unwrap().load(env, 0, m * w.size())
                } else {
                    Vec::new()
                };
                env.barrier(ctx.shmem());
                sc.free(env);
                g.free(env);
                (w.rank() == 9, back, full)
            });
            for (is_root, back, full) in out {
                if is_root {
                    assert_eq!(back, full, "k {k}");
                }
            }
        }
    }
}
