//! `Wrapper_Hy_Scatter` — hybrid MPI+MPI rooted scatter.
//!
//! Mirror of [`hy_gather`](super::gather::hy_gather): the root stores its
//! whole rank-ordered send buffer into its node's shared window, a red
//! sync on the root's node publishes it to the node leader, and the
//! **leaders** run an irregular scatterv over the bridge — each leader
//! receives exactly its node's block range and lands it in the node
//! window at the same global displacement, so after the yellow sync every
//! rank reads its own `msg`-byte block in place at
//! `win.local_ptr(parent_rank, msg)`. One bridge message per non-root
//! node, zero on-node messages.

use super::allgather::AllgatherParam;
use super::bcast::TransTables;
use super::package::CommPackage;
use super::shmem::HyWin;
use super::sync::{await_release, red_sync, release, SyncScheme};
use crate::coll::scatter::scatterv;
use crate::mpi::env::ProcEnv;
use crate::mpi::topo::Placement;

/// `Wrapper_Hy_Scatter`: distribute `data` (present only at `root`, in
/// parent-rank order, `msg` bytes per rank) so every rank can read its
/// block at `win.local_ptr(parent_rank, msg)` after the call.
#[allow(clippy::too_many_arguments)]
pub fn hy_scatter(
    env: &mut ProcEnv,
    pkg: &CommPackage,
    win: &mut HyWin,
    param: &AllgatherParam,
    tables: &TransTables,
    root: usize,
    data: Option<&[u8]>,
    msg: usize,
    scheme: SyncScheme,
) {
    assert_eq!(
        env.topo().placement(),
        Placement::Block,
        "Wrapper_Hy_Scatter assumes block-style rank placement (§4)"
    );
    let me = pkg.parent.rank();
    let root_node = tables.bridge[root];
    let root_is_leader = tables.shmem[root] == 0;

    // The root stores the full send buffer into its node's window.
    if me == root {
        let d = data.expect("root must supply the scatter payload");
        assert_eq!(d.len(), msg * pkg.parent.size());
        win.store(env, 0, d);
    }
    // If the root is a child, its leader must observe the payload before
    // the bridge scatter: red sync on the root's node only.
    if !root_is_leader && tables.bridge[me] == root_node {
        red_sync(env, pkg);
    }
    if let Some(bridge) = &pkg.bridge {
        let bidx = bridge.rank();
        if bridge.size() > 1 {
            let (lo, count) = (param.displs[bidx], param.recvcounts[bidx]);
            if bidx == root_node {
                let full_len: usize = param.recvcounts.iter().sum();
                if env.legacy_dataplane() {
                    let full = win.win.read_vec(0, full_len);
                    env.count_copy(full_len);
                    let mut keep = vec![0u8; count];
                    scatterv(env, bridge, root_node, &param.recvcounts, Some(&full), &mut keep);
                } else {
                    // Outgoing node ranges are borrowed straight from the
                    // window; `keep` only absorbs the root's own (already
                    // in-place) range, via a pooled scratch.
                    let full = unsafe { win.win.slice(0, full_len) };
                    let mut keep = env.take_buf(count);
                    scatterv(env, bridge, root_node, &param.recvcounts, Some(full), &mut keep);
                }
                // The root node's own range is already in place.
            } else {
                let out = unsafe { win.win.slice_mut(lo, count) };
                scatterv(env, bridge, root_node, &param.recvcounts, None, out);
            }
        }
        release(env, pkg, win, scheme);
    } else {
        await_release(env, pkg, win, scheme);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::{payload, run_nodes};
    use crate::hybrid::allgather::sizeset_gather;

    fn check(nodes: &'static [usize], m: usize, root: usize, scheme: SyncScheme) {
        let out = run_nodes(nodes, move |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let mut win = pkg.alloc_shared(env, m, 1, w.size());
            let sizeset = sizeset_gather(env, &pkg);
            let param = AllgatherParam::create(env, &pkg, m, &sizeset);
            let tables = TransTables::create(env, &pkg);
            let full: Vec<u8> = (0..w.size()).flat_map(|r| payload(r, m)).collect();
            let arg = (w.rank() == root).then_some(&full[..]);
            hy_scatter(env, &pkg, &mut win, &param, &tables, root, arg, m, scheme);
            let got = win.load(env, win.local_ptr(w.rank(), m), m);
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            got
        });
        for (r, got) in out.into_iter().enumerate() {
            assert_eq!(got, payload(r, m), "nodes {nodes:?} m {m} root {root} rank {r}");
        }
    }

    #[test]
    fn roots_on_every_kind_of_rank() {
        check(&[5, 3], 16, 0, SyncScheme::Spin); // leader of node 0
        check(&[5, 3], 16, 5, SyncScheme::Spin); // leader of node 1
        check(&[5, 3], 16, 2, SyncScheme::Spin); // child on node 0
        check(&[5, 3], 16, 7, SyncScheme::Barrier); // child on node 1
    }

    #[test]
    fn irregular_three_nodes_and_single_node() {
        check(&[5, 3, 4], 24, 9, SyncScheme::Spin);
        check(&[6], 8, 3, SyncScheme::Spin);
        check(&[1], 8, 0, SyncScheme::Barrier);
    }

    #[test]
    fn roundtrips_with_hy_gather() {
        // scatter from rank 2, then gather back to rank 9 — both hybrid.
        let out = run_nodes(&[5, 3, 4], |env| {
            let w = env.world();
            let m = 24usize;
            let pkg = CommPackage::create(env, &w);
            let mut win = pkg.alloc_shared(env, m, 1, w.size());
            let sizeset = sizeset_gather(env, &pkg);
            let param = AllgatherParam::create(env, &pkg, m, &sizeset);
            let tables = TransTables::create(env, &pkg);
            let full: Vec<u8> = (0..w.size()).flat_map(|r| payload(r, m)).collect();
            let arg = (w.rank() == 2).then_some(&full[..]);
            hy_scatter(env, &pkg, &mut win, &param, &tables, 2, arg, m, SyncScheme::Spin);
            let block = win.load(env, win.local_ptr(w.rank(), m), m);
            // A fresh window for the gather keeps the phases independent.
            let mut win2 = pkg.alloc_shared(env, m, 1, w.size());
            win2.store(env, win2.local_ptr(w.rank(), m), &block);
            crate::hybrid::gather::hy_gather(
                env, &pkg, &mut win2, &param, &tables, 9, m, SyncScheme::Spin,
            );
            let back = if w.rank() == 9 { win2.load(env, 0, m * w.size()) } else { Vec::new() };
            env.barrier(&pkg.shmem);
            win.free(env, &pkg);
            win2.free(env, &pkg);
            (w.rank() == 9, back, full)
        });
        for (is_root, back, full) in out {
            if is_root {
                assert_eq!(back, full);
            }
        }
    }
}
