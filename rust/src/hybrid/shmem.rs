//! Shared-region handles (§4.1): the storage half of
//! `Wrapper_MPI_Sharedmemory_alloc` + `Wrapper_Get_localpointer`.
//!
//! Allocation itself lives on the session
//! ([`HybridCtx::alloc_shared`](super::ctx::HybridCtx::alloc_shared));
//! this module holds the window handle the session and its persistent
//! collectives ([`HyColl`](super::ctx::HyColl)) operate on.

use super::ctx::HybridCtx;
use crate::mpi::env::{ProcEnv, Win};
use crate::mpi::win::SharedWindow;
use std::sync::Arc;

/// A hybrid shared window: the node's single shared result region.
///
/// The *primary leader* contributed the full `msize·bsize·flag` bytes;
/// everyone else contributed zero and attaches via
/// `MPI_Win_shared_query` — exactly the paper's allocation pattern
/// (Fig. 6 lines 12–16).
pub struct HyWin {
    pub win: Arc<SharedWindow>,
    raw: Option<Win>,
    /// Per-rank epoch for the §4.5 spinning protocol (how many releases
    /// this rank has observed/posted on flag 0).
    pub epoch: u32,
    total: usize,
}

impl HyWin {
    pub(crate) fn new(raw: Win, total: usize) -> HyWin {
        HyWin { win: raw.win.clone(), raw: Some(raw), epoch: 0, total }
    }

    /// Total shared region size in bytes.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// `Wrapper_Get_localpointer`: byte offset of the region with affinity
    /// to `rank` (each of `rank`'s `dsize`-byte slots lives at
    /// `rank * dsize`, matching the paper's `r_buf + msg*rank`).
    pub fn local_ptr(&self, rank: usize, dsize: usize) -> usize {
        let off = rank * dsize;
        assert!(off + dsize <= self.total, "affinity slot out of window");
        off
    }

    /// Store `data` at `offset` (single on-node copy — charged at
    /// `β_mem`, *not* the pure-MPI staging double copy).
    pub fn store(&self, env: &mut ProcEnv, offset: usize, data: &[u8]) {
        self.win.write(offset, data);
        env.charge_memcpy(data.len());
    }

    /// Load `len` bytes at `offset` (single on-node copy).
    pub fn load(&self, env: &mut ProcEnv, offset: usize, len: usize) -> Vec<u8> {
        let v = self.win.read_vec(offset, len);
        env.charge_memcpy(len);
        v
    }

    /// Zero-copy read view (for compute kernels that consume the shared
    /// region in place; virtual cost is charged by the kernel's own
    /// compute accounting).
    ///
    /// # Safety
    /// Caller must be ordered after the writers' release sync.
    pub unsafe fn view(&self, offset: usize, len: usize) -> &[u8] {
        self.win.slice(offset, len)
    }

    /// Collective free (`MPI_Win_free` inside the wrapper).
    pub fn free(mut self, env: &mut ProcEnv, ctx: &HybridCtx) {
        if let Some(raw) = self.raw.take() {
            raw.free(env, ctx.shmem());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::hybrid::LeaderPolicy;

    #[test]
    fn leader_allocates_children_attach() {
        let out = run_nodes(&[5, 3], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let win = ctx.alloc_shared(env, 10, 8, w.size());
            assert_eq!(win.len(), 10 * 8 * 8);
            // Affinity slot = world rank * slot size.
            let off = win.local_ptr(env.world_rank(), 80);
            win.store(env, off, &[env.world_rank() as u8; 80]);
            env.barrier(ctx.shmem());
            // Every on-node rank sees every on-node write in the shared copy.
            let all = win.load(env, 0, win.len());
            env.barrier(ctx.shmem());
            win.free(env, &ctx);
            all
        });
        // Node 0 (ranks 0..5) sees slots 0..5 filled; node 1 sees 5..8.
        for r in 0..5 {
            for s in 0..5 {
                assert_eq!(out[r][s * 80], s as u8, "node0 rank {r} slot {s}");
            }
            assert_eq!(out[r][5 * 80], 0, "node0 does not see node1 writes");
        }
        for r in 5..8 {
            for s in 5..8 {
                assert_eq!(out[r][s * 80], s as u8, "node1 rank {r} slot {s}");
            }
        }
    }

    #[test]
    fn single_copy_cheaper_than_p2p() {
        // The design claim: a hybrid store charges less virtual time than
        // an on-node p2p message of the same size.
        let out = run_nodes(&[2], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let win = ctx.alloc_shared(env, 1024, 8, 1);
            env.harness_sync(&w);
            let t0 = env.vclock();
            if env.world_rank() == 0 {
                win.store(env, 0, &[1u8; 8192]);
            }
            let store_cost = env.vclock() - t0;
            env.harness_sync(&w);
            let t1 = env.vclock();
            if env.world_rank() == 0 {
                env.send(&w, 1, crate::mpi::USER_TAG_BASE, &[1u8; 8192]);
            } else {
                let _ = env.recv(&w, Some(0), crate::mpi::USER_TAG_BASE);
            }
            env.harness_sync(&w);
            let p2p_cost = env.vclock() - t1;
            env.barrier(ctx.shmem());
            win.free(env, &ctx);
            (store_cost, p2p_cost)
        });
        let (store, p2p) = out[0];
        assert!(store < p2p, "store {store} must beat p2p {p2p}");
        assert!(store > 0.0);
    }

    #[test]
    #[should_panic(expected = "affinity slot out of window")]
    fn local_ptr_bounds_checked() {
        let hy = HyWin { win: Arc::new(SharedWindow::allocate(&[64])), raw: None, epoch: 0, total: 64 };
        hy.local_ptr(8, 8); // slot 8 of 8-byte slots ends at 72 > 64
    }
}
