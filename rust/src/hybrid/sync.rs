//! Node-level synchronization schemes (§4.5), generalized to the
//! multi-leader session layer.
//!
//! Two patterns appear in the hybrid collectives:
//!
//! - **red sync** — a full collective synchronization among the node's
//!   ranks (everyone waits for everyone): `MPI_Barrier` on the node
//!   communicator. Required before a leader may consume its children's
//!   window writes.
//! - **yellow sync** — a *release*: children wait only for their node's
//!   leaders (leader → children). A barrier here would make children
//!   handshake each other pointlessly (§4.5); the paper's optimization is
//!   the **spinning** method — a shared status counter the leader
//!   increments (`status++` + `MPI_Win_sync`), children polling with the
//!   equality-only exit condition MPI's one-byte-change rule permits.
//!
//! With `k > 1` leaders per node ([`HybridCtx`](super::ctx::HybridCtx)),
//! the release gains one
//! extra step: the node's leaders synchronize among themselves (a small
//! intra-node barrier over the leader group) so every leader's bridge
//! stripe is published before the *primary* leader (leader 0) posts the
//! single status flag. With `k = 1` this degenerates to exactly the
//! paper's release — no leader barrier, one post — so single-leader
//! virtual time is bit-identical to the pre-session code.
//!
//! In happens-before terms (DESIGN.md §6): red sync is a full
//! synchronization (everyone's clock joins everyone's), while yellow
//! sync is a one-way **release edge** — post joins the leader's clock
//! into the flag, each observing child acquires it, and *nothing* flows
//! from children back to the leader. The race detector
//! ([`analysis::race`](crate::analysis::race)) models exactly this
//! asymmetry, which is what lets it flag a leader re-staging the next
//! epoch while a child still reads the previous one.

#[cfg(test)]
use super::ctx::HybridCtx;
#[cfg(test)]
use super::shmem::HyWin;
#[cfg(test)]
use crate::mpi::env::ProcEnv;

/// How the yellow (leader→children) sync point is implemented.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SyncScheme {
    /// `MPI_Barrier(shmem_comm)` — the unoptimized variant of §5.2.3/4.
    Barrier,
    /// The §4.5 spinning status flag — the optimized variant.
    Spin,
}

/// Red sync: full node barrier (all ranks of the node communicator).
///
/// Since the split-phase redesign (DESIGN.md §5e) the collectives no
/// longer call this directly — their compiled schedules carry
/// `Arrive(Node)`/`Await(Node)` stage pairs on the handle's
/// window-private [`SyncGroup`](crate::mpi::sync::SyncGroup), which
/// charge the identical barrier law. Kept as the reference
/// implementation the sync-scheme tests exercise standalone.
#[cfg(test)]
pub(crate) fn red_sync(env: &mut ProcEnv, ctx: &HybridCtx) {
    env.barrier(ctx.shmem());
}

/// Yellow sync, both sides: leaders publish, children observe.
///
/// - `Barrier`: one node barrier orders every leader's writes against
///   every reader — leaders and children alike.
/// - `Spin`: with `k > 1` the node's leaders first barrier among
///   themselves (ordering leaders 1..k's stripes before the post), then
///   leader 0 increments the status flag; children poll it. Leaders other
///   than 0 only advance their epoch — the leader barrier already
///   ordered them past the release point.
///
/// Like [`red_sync`], superseded in production by the schedules'
/// `YellowPost`/`YellowWait` (and `Barrier`-scheme `Arrive`/`Await`)
/// stages, which charge identically; kept for the standalone tests.
#[cfg(test)]
pub(crate) fn complete(env: &mut ProcEnv, ctx: &HybridCtx, win: &mut HyWin, scheme: SyncScheme) {
    match scheme {
        SyncScheme::Barrier => env.barrier(ctx.shmem()),
        SyncScheme::Spin => match ctx.leader_index() {
            Some(j) => {
                if let Some(leaders) = ctx.leaders() {
                    env.barrier(leaders);
                }
                win.epoch += 1;
                if j == 0 {
                    env.spin_post(&win.win, 0);
                }
            }
            None => {
                win.epoch += 1;
                let target = win.epoch;
                env.spin_wait(&win.win, 0, target);
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;
    use crate::hybrid::LeaderPolicy;

    #[test]
    fn spin_release_orders_leader_writes() {
        let out = run_nodes(&[6], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
            let mut win = ctx.alloc_shared(env, 8, 1, 1);
            for round in 1..=3u8 {
                if ctx.is_leader() {
                    win.store(env, 0, &[round; 8]);
                }
                complete(env, &ctx, &mut win, SyncScheme::Spin);
                let seen = win.load(env, 0, 8);
                assert_eq!(seen, vec![round; 8], "round {round}");
                red_sync(env, &ctx); // don't let the leader race ahead
            }
            let v = env.vclock();
            win.free(env, &ctx);
            v
        });
        assert!(out.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn multi_leader_release_orders_every_leaders_writes() {
        // Leaders 0 and 1 each write their half; children must observe
        // both after one spin release.
        let out = run_nodes(&[6], |env| {
            let w = env.world();
            let ctx = HybridCtx::create(env, &w, LeaderPolicy::Leaders(2));
            assert_eq!(ctx.leaders_per_node(), 2);
            let mut win = ctx.alloc_shared(env, 8, 1, 2);
            for round in 1..=3u8 {
                if let Some(j) = ctx.leader_index() {
                    win.store(env, j * 8, &[round; 8]);
                }
                complete(env, &ctx, &mut win, SyncScheme::Spin);
                let seen = win.load(env, 0, 16);
                assert_eq!(seen, vec![round; 16], "round {round}");
                red_sync(env, &ctx);
            }
            let v = env.vclock();
            win.free(env, &ctx);
            v
        });
        assert!(out.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn spin_is_cheaper_than_barrier_for_children() {
        // §4.5's claim: substituting the yellow sync with a barrier causes
        // unnecessary child↔child handshaking. Compare charged times.
        let cost = |scheme: SyncScheme| {
            run_nodes(&[16], move |env| {
                let w = env.world();
                let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
                let mut win = ctx.alloc_shared(env, 8, 1, 1);
                env.harness_sync(&w);
                let t0 = env.vclock();
                for _ in 0..10 {
                    complete(env, &ctx, &mut win, scheme);
                }
                let dt = env.vclock() - t0;
                env.barrier(ctx.shmem());
                win.free(env, &ctx);
                dt
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        let spin = cost(SyncScheme::Spin);
        let barrier = cost(SyncScheme::Barrier);
        assert!(spin < barrier, "spin {spin} must undercut barrier {barrier}");
    }
}
