//! Node-level synchronization schemes (§4.5).
//!
//! Two patterns appear in the hybrid collectives:
//!
//! - **red sync** — a full collective synchronization among the node's
//!   ranks (everyone waits for everyone): `MPI_Barrier` on the node
//!   communicator. Required before a leader may consume its children's
//!   window writes.
//! - **yellow sync** — a *release*: children wait only for their leader
//!   (leader → children). A barrier here would make children handshake
//!   each other pointlessly (§4.5); the paper's optimization is the
//!   **spinning** method — a shared status counter the leader increments
//!   (`status++` + `MPI_Win_sync`), children polling with the
//!   equality-only exit condition MPI's one-byte-change rule permits.

use super::package::CommPackage;
use super::shmem::HyWin;
use crate::mpi::env::ProcEnv;

/// How the yellow (leader→children) sync point is implemented.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SyncScheme {
    /// `MPI_Barrier(shmem_comm)` — the unoptimized variant of §5.2.3/4.
    Barrier,
    /// The §4.5 spinning status flag — the optimized variant.
    Spin,
}

/// Red sync: full node barrier (all ranks of the node communicator).
pub fn red_sync(env: &mut ProcEnv, pkg: &CommPackage) {
    env.barrier(&pkg.shmem);
}

/// Yellow sync, leader side: release the children.
pub fn release(env: &mut ProcEnv, pkg: &CommPackage, win: &mut HyWin, scheme: SyncScheme) {
    match scheme {
        SyncScheme::Barrier => env.barrier(&pkg.shmem),
        SyncScheme::Spin => {
            win.epoch += 1;
            env.spin_post(&win.win, 0);
        }
    }
}

/// Yellow sync, child side: wait for the leader's release.
pub fn await_release(env: &mut ProcEnv, pkg: &CommPackage, win: &mut HyWin, scheme: SyncScheme) {
    match scheme {
        SyncScheme::Barrier => env.barrier(&pkg.shmem),
        SyncScheme::Spin => {
            win.epoch += 1;
            let target = win.epoch;
            env.spin_wait(&win.win, 0, target);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coll::testutil::run_nodes;

    #[test]
    fn spin_release_orders_leader_writes() {
        let out = run_nodes(&[6], |env| {
            let w = env.world();
            let pkg = CommPackage::create(env, &w);
            let mut win = pkg.alloc_shared(env, 8, 1, 1);
            for round in 1..=3u8 {
                if pkg.is_leader() {
                    win.store(env, 0, &[round; 8]);
                    release(env, &pkg, &mut win, SyncScheme::Spin);
                } else {
                    await_release(env, &pkg, &mut win, SyncScheme::Spin);
                }
                let seen = win.load(env, 0, 8);
                assert_eq!(seen, vec![round; 8], "round {round}");
                red_sync(env, &pkg); // don't let the leader race ahead
            }
            let v = env.vclock();
            win.free(env, &pkg);
            v
        });
        assert!(out.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn spin_is_cheaper_than_barrier_for_children() {
        // §4.5's claim: substituting the yellow sync with a barrier causes
        // unnecessary child↔child handshaking. Compare charged times.
        let cost = |scheme: SyncScheme| {
            run_nodes(&[16], move |env| {
                let w = env.world();
                let pkg = CommPackage::create(env, &w);
                let mut win = pkg.alloc_shared(env, 8, 1, 1);
                env.harness_sync(&w);
                let t0 = env.vclock();
                for _ in 0..10 {
                    if pkg.is_leader() {
                        release(env, &pkg, &mut win, scheme);
                    } else {
                        await_release(env, &pkg, &mut win, scheme);
                    }
                }
                let dt = env.vclock() - t0;
                env.barrier(&pkg.shmem);
                win.free(env, &pkg);
                dt
            })
            .into_iter()
            .fold(0.0f64, f64::max)
        };
        let spin = cost(SyncScheme::Spin);
        let barrier = cost(SyncScheme::Barrier);
        assert!(spin < barrier, "spin {spin} must undercut barrier {barrier}");
    }
}
