//! BPMF — Bayesian Probabilistic Matrix Factorization (§5.3.3, Fig. 19).
//!
//! Gibbs sampling over compound factors U and target factors V. Each
//! iteration has two sampling regions (compounds, then targets); a region
//! samples this rank's shard of items from the gathered factors of the
//! *other* side, then ends with three regular allgathers — factor rows,
//! hyperparameter statistics and a residual scalar (the paper's 80 000 B /
//! 800 B / 8 B messages at the 1-node, 24-rank configuration).
//!
//! Synthetic data replaces chembl_20 (unavailable): per-item observation
//! lists with a fixed per-item budget, deterministic per item — so *every
//! variant computes bit-identical factors* and the checksum cross-validates
//! pure vs hybrid vs OpenMP (allgather layout differences included).
//!
//! The pure-MPI baseline uses the SMP-aware hierarchical allgather (the
//! cray-mpich behaviour on Hazel Hen, where the paper ran BPMF); it still
//! replicates the full factor matrices in every rank and pays on-node
//! staging copies — the two costs `Wrapper_Hy_Allgather` removes.

use super::compute::{bpmf_batch, Backend};
use super::ompsim::OmpModel;
use super::{KernelReport, RankStats, Variant};
use crate::coll::{CollOp, Flavor, PlanCache, PlanKey};
use crate::coordinator::{ClusterSpec, SimCluster};
use crate::hybrid::SyncScheme;
use crate::mpi::env::ProcEnv;
use crate::mpi::Datatype;
use crate::util::{from_bytes, to_bytes, Rng};

/// BPMF configuration.
#[derive(Clone, Copy, Debug)]
pub struct BpmfCfg {
    /// Total compounds (the paper's 1-node config ⇒ 1000/rank ⇒ 80 000 B
    /// factor messages at 24 ranks with K = 10).
    pub compounds: usize,
    /// Total targets (small side; 800 B-class messages).
    pub targets: usize,
    /// Latent dimension.
    pub k: usize,
    /// Observations per item (padded; matches the AOT artifact shape).
    pub nnz: usize,
    /// Sampling iterations (paper: 20).
    pub iters: usize,
    pub variant: Variant,
    pub backend: Backend,
    pub threads: usize,
}

impl BpmfCfg {
    /// Paper-shaped config scaled by `scale` (1.0 = the full 24 000×240).
    pub fn paper(scale: f64, variant: Variant, backend: Backend, threads: usize) -> BpmfCfg {
        BpmfCfg {
            compounds: ((24_000.0 * scale) as usize).max(96),
            targets: 240,
            k: 10,
            nnz: 32,
            iters: 20,
            variant,
            backend,
            threads,
        }
    }
}

/// Preferred compute batch (matches the `bpmf_b64_n32_k10` artifact);
/// shrinks for small shards so padding never inflates compute.
const BATCH: usize = 64;

fn batch_for(per: usize) -> usize {
    BATCH.min(per.next_power_of_two().max(8))
}

/// Stats message: 100 doubles (the paper's 800 B allgather).
const STATS_DOUBLES: usize = 100;

/// Deterministic observation (index into the other side, rating).
fn obs(side: usize, item: usize, slot: usize, other_count: usize) -> (usize, f64) {
    let mut rng = Rng::new(((side as u64) << 40) ^ ((item as u64) << 8) ^ slot as u64 ^ 0xB9F);
    (rng.below(other_count), rng.range_f64(-2.0, 2.0))
}

/// Deterministic per-(side, item, iter, dim) Gibbs noise — identical in
/// every variant regardless of sharding.
fn noise(side: usize, item: usize, iter: usize, dim: usize) -> f64 {
    let mut rng = Rng::new(
        0x517E ^ ((side as u64) << 50) ^ ((item as u64) << 20) ^ ((iter as u64) << 6) ^ dim as u64,
    );
    rng.normal()
}

/// Initial factor value.
fn init_factor(side: usize, item: usize, dim: usize) -> f64 {
    let mut rng = Rng::new(0xFAC ^ ((side as u64) << 40) ^ ((item as u64) << 8) ^ dim as u64);
    rng.normal() * 0.1
}

#[derive(Clone, Copy)]
struct Shard {
    lo: usize,
    /// Padded items per rank (uniform ⇒ regular allgather applies).
    per: usize,
    /// Real (unpadded) item count on this side.
    total: usize,
}

impl Shard {
    fn new(total: usize, p: usize, me: usize) -> Shard {
        let per = total.div_ceil(p);
        Shard { lo: me * per, per, total }
    }
}

pub fn run(spec: ClusterSpec, cfg: BpmfCfg) -> KernelReport {
    let nnodes = spec.nnodes();
    let report = SimCluster::new(spec).run(move |env| rank_program(env, cfg));
    KernelReport::reduce(cfg.variant, nnodes, report)
}

fn rank_program(env: &mut ProcEnv, cfg: BpmfCfg) -> RankStats {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let k = cfg.k;

    let shards = [Shard::new(cfg.compounds, p, me), Shard::new(cfg.targets, p, me)];
    // Full factor-table element counts per side (padded rows included).
    let table_elems = [shards[0].per * p * k, shards[1].per * p * k];

    // ---- per-variant state -------------------------------------------
    // One plan cache carries every allgather of the sampler. Plans are
    // built (and their windows allocated / hierarchy split) once, here;
    // the 2·iters sampling regions then execute against cached plans.
    // The two factor tables are tagged by side — they may have equal
    // sizes and must not share a window.
    // BPMF has no split-phase port (its allgathers gate the very next
    // batch); HybridOverlap runs the blocking hybrid path.
    let hybrid = cfg.variant.is_hybrid();
    let flavor = if hybrid { Flavor::hybrid(SyncScheme::Spin) } else { Flavor::Hier };
    let mut plans = PlanCache::new();
    let side_msg = [shards[0].per * k * 8, shards[1].per * k * 8];
    for side in 0..2 {
        plans.plan_tagged(
            env, &w, CollOp::Allgather, side_msg[side], Datatype::U8, None, flavor, side as u32,
        );
    }
    // The two small allgathers (stats + residual): in the paper's hybrid
    // BPMF all three allgathers per region go through
    // Wrapper_Hy_Allgather.
    plans.plan_tagged(env, &w, CollOp::Allgather, STATS_DOUBLES * 8, Datatype::U8, None, flavor, 2);
    plans.plan_tagged(env, &w, CollOp::Allgather, 8, Datatype::U8, None, flavor, 3);

    // Pure/OpenMP: per side, the rank's replicated factor table.
    let mut locals: Vec<Vec<f64>> = Vec::new();

    let full_init = |side: usize| -> Vec<f64> {
        (0..table_elems[side]).map(|t| init_factor(side, t / k, t % k)).collect()
    };
    if hybrid {
        // Seed the shared factor tables in place (the node's single copy,
        // via the plan's window — `Wrapper_Get_localpointer` surface).
        let ctx = plans.hybrid_ctx(env, &w, 1).expect("hybrid plans build a session context");
        for side in 0..2 {
            let key =
                PlanKey::new(&w, CollOp::Allgather, side_msg[side], Datatype::U8, None, flavor, side as u32);
            let win = plans.window_of(&key).expect("hybrid allgather plan is window-backed");
            if ctx.is_leader() {
                win.win.write(0, to_bytes(&full_init(side)));
            }
        }
        let shmem = ctx.shmem().clone();
        env.barrier(&shmem); // initial tables visible node-wide
    } else {
        for side in 0..2 {
            locals.push(full_init(side));
        }
    }
    // BPMF's sampling loop is control-heavy; the paper's fine-grained
    // MPI+OpenMP port parallelizes it poorly (Fig. 19 shows it clearly
    // worst) — a larger serial fraction than the dense-loop kernels.
    let omp = OmpModel { threads: cfg.threads, serial_frac: 0.15, ..OmpModel::new(cfg.threads) };

    let alpha = 2.0;
    let lam0 = vec![1.0f64; k];
    let mut stats = RankStats::default();
    env.harness_sync(&w);
    let t_start = env.vclock();

    for iter in 0..cfg.iters {
        for side in 0..2 {
            let other = 1 - side;
            let shard = shards[side];
            let other_total = shards[other].total;

            // ---- sample my shard from the other side's factors --------
            let t0 = env.vclock();
            let batch = batch_for(shard.per);
            let nb = shard.per.div_ceil(batch);
            let mut new_vals = vec![0.0f64; nb * batch * k];
            {
                // Hybrid reads the single shared copy in place; pure reads
                // its private replica.
                let other_view: &[f64] = if hybrid {
                    from_bytes(
                        plans
                            .allgather_view_tagged(&w, flavor, other as u32, side_msg[other], table_elems[other] * 8)
                            .expect("factor-table plan is window-backed"),
                    )
                } else {
                    &locals[other]
                };
                let mut v = vec![0.0f64; batch * cfg.nnz * k];
                let mut wgt = vec![0.0f64; batch * cfg.nnz];
                let mut eps = vec![0.0f64; batch * k];
                for b in 0..nb {
                    env.compute_timed(|| {
                        for bi in 0..batch {
                            let item = shard.lo + b * batch + bi;
                            let active = item < shard.total && item < shard.lo + shard.per;
                            for s in 0..cfg.nnz {
                                let dst = &mut v[(bi * cfg.nnz + s) * k..(bi * cfg.nnz + s + 1) * k];
                                if active {
                                    let (idx, val) = obs(side, item, s, other_total);
                                    dst.copy_from_slice(&other_view[idx * k..(idx + 1) * k]);
                                    wgt[bi * cfg.nnz + s] = val;
                                } else {
                                    dst.fill(0.0);
                                    wgt[bi * cfg.nnz + s] = 0.0;
                                }
                            }
                            for d in 0..k {
                                eps[bi * k + d] = if active { noise(side, item, iter, d) } else { 0.0 };
                            }
                        }
                    });
                    let out = &mut new_vals[b * batch * k..(b + 1) * batch * k];
                    if cfg.variant == Variant::MpiOpenMp {
                        if cfg.backend == Backend::Modeled {
                            omp.charge_modeled(
                                env,
                                1,
                                super::compute::modeled_bpmf_us(batch, cfg.nnz, k),
                                || {
                                    crate::kernels::native::bpmf_posterior(
                                        &v, &wgt, alpha, &lam0, &eps, batch, cfg.nnz, k, out,
                                    )
                                },
                            );
                        } else {
                            omp.charge(env, 1, || {
                                crate::kernels::native::bpmf_posterior(
                                    &v, &wgt, alpha, &lam0, &eps, batch, cfg.nnz, k, out,
                                )
                            });
                        }
                    } else {
                        bpmf_batch(env, cfg.backend, &v, &wgt, alpha, &lam0, &eps, batch, cfg.nnz, k, out);
                    }
                }
            }
            stats.comp_us += env.vclock() - t0;

            // ---- the three allgathers ---------------------------------
            env.harness_sync(&w); // skew-free comm measurement (see poisson.rs)
            let t1 = env.vclock();
            let mine = &new_vals[..shard.per * k];
            let stats_msg = vec![me as f64; STATS_DOUBLES];
            let norm_msg = [mine.iter().map(|x| x * x).sum::<f64>()];
            if hybrid {
                // Result stays in the plan's shared window (recv: None) —
                // the next sampling region reads it in place.
                plans.allgather_tagged(env, &w, flavor, side as u32, to_bytes(mine), None);
                plans.allgather_tagged(env, &w, flavor, 2, to_bytes(&stats_msg), None);
                plans.allgather_tagged(env, &w, flavor, 3, to_bytes(&norm_msg), None);
            } else {
                let mut out = vec![0u8; side_msg[side] * p];
                plans.allgather_tagged(env, &w, flavor, side as u32, to_bytes(mine), Some(&mut out));
                locals[side].copy_from_slice(from_bytes(&out));
                let mut sink = vec![0u8; STATS_DOUBLES * 8 * p];
                plans.allgather_tagged(env, &w, flavor, 2, to_bytes(&stats_msg), Some(&mut sink));
                let mut sink2 = vec![0u8; 8 * p];
                plans.allgather_tagged(env, &w, flavor, 3, to_bytes(&norm_msg), Some(&mut sink2));
            }
            stats.comm_us += env.vclock() - t1;
        }
        stats.iters += 1;
    }
    stats.total_us = env.vclock() - t_start;

    // Checksum: my shard's real (unpadded) factor values on both sides.
    let mut sum = 0.0;
    for side in 0..2 {
        let shard = shards[side];
        let view: &[f64] = if hybrid {
            from_bytes(
                plans
                    .allgather_view_tagged(&w, flavor, side as u32, side_msg[side], table_elems[side] * 8)
                    .expect("factor-table plan is window-backed"),
            )
        } else {
            &locals[side]
        };
        let hi = shard.total.min(shard.lo + shard.per);
        for item in shard.lo..hi.max(shard.lo) {
            sum += view[item * k..(item + 1) * k].iter().sum::<f64>();
        }
    }
    stats.checksum = sum;

    plans.free(env);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Preset;

    fn spec(nodes: usize, per: usize) -> ClusterSpec {
        let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.max(1));
        s.nodes = vec![per; nodes];
        s
    }

    fn tiny(variant: Variant) -> BpmfCfg {
        BpmfCfg {
            compounds: 256,
            targets: 64,
            k: 6,
            nnz: 8,
            iters: 2,
            variant,
            backend: Backend::Native,
            threads: 4,
        }
    }

    #[test]
    fn variants_compute_identical_factors() {
        let pure = run(spec(2, 4), tiny(Variant::PureMpi));
        let hy = run(spec(2, 4), tiny(Variant::HybridMpiMpi));
        let omp = run(spec(8, 1), tiny(Variant::MpiOpenMp));
        assert!(pure.checksum.is_finite() && pure.checksum != 0.0);
        assert!(
            (pure.checksum - hy.checksum).abs() < 1e-9,
            "pure {} vs hybrid {}",
            pure.checksum,
            hy.checksum
        );
        assert!(
            (pure.checksum - omp.checksum).abs() < 1e-9,
            "pure {} vs openmp {}",
            pure.checksum,
            omp.checksum
        );
    }

    #[test]
    fn hybrid_allgather_cheaper() {
        let pure = run(spec(2, 8), tiny(Variant::PureMpi));
        let hy = run(spec(2, 8), tiny(Variant::HybridMpiMpi));
        assert!(
            hy.comm_us < pure.comm_us,
            "hybrid allgather {} must beat pure {}",
            hy.comm_us,
            pure.comm_us
        );
    }

    #[test]
    fn message_sizes_match_paper_at_one_node() {
        // 24 ranks, 24 000 compounds, K = 10 ⇒ 1000·10·8 = 80 000 B.
        let cfg = BpmfCfg::paper(1.0, Variant::PureMpi, Backend::Native, 1);
        let shard = Shard::new(cfg.compounds, 24, 0);
        assert_eq!(shard.per * cfg.k * 8, 80_000);
    }
}
