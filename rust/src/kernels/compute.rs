//! Compute backend dispatch: PJRT (AOT JAX/Pallas artifacts) or native.
//!
//! Every call runs the real computation and charges the measured thread
//! CPU time (× the preset scale) to the caller's virtual clock. The PJRT
//! and native paths produce numerically identical results (asserted by the
//! runtime tests), so variant comparisons are backend-independent.

use crate::mpi::env::ProcEnv;
use crate::runtime::{F64Input, SharedRuntime};

/// Which engine executes the kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifacts via the PJRT CPU client; falls back to native per
    /// call when the needed shape has no artifact.
    Pjrt,
    /// Pure rust compute paths.
    Native,
    /// Real native computation, but the *charged* virtual time is the
    /// deterministic flop model below — used by the figure generators so
    /// every variant is charged identical compute (the paper's premise:
    /// "unequal parallelism will not be the reason for the performance
    /// benefits", §3.2.3) and host scheduling noise cannot leak into the
    /// comparison.
    Modeled,
    /// No computation at all — only the deterministic flop model is
    /// charged. For engine-scale shapes (hundreds of ranks) where the
    /// *communication* structure is under test and actually running the
    /// arithmetic on one host core would take hours: kernel results
    /// (checksums) are meaningless, payload motion and modeled time stay
    /// real. Variant comparisons remain valid because every variant
    /// skips the same work and is charged the same model.
    Phantom,
}

/// Modeled per-core throughput (flops/µs): a 2.5 GHz Haswell core doing
/// ~0.6 flops/cycle on these unblocked f64 loops.
pub const MODELED_FLOPS_PER_US: f64 = 1500.0;

/// Modeled time of the SUMMA block accumulate (2·e³ flops).
pub fn modeled_matmul_us(edge: usize) -> f64 {
    2.0 * (edge as f64).powi(3) / MODELED_FLOPS_PER_US
}

/// Modeled time of one red-black sweep (≈7 flops/point incl. the delta).
pub fn modeled_sweep_us(rows: usize, n: usize) -> f64 {
    7.0 * (rows * n) as f64 / MODELED_FLOPS_PER_US
}

/// Modeled time of one BPMF posterior batch
/// (per item: 2·nnz·k² Gram + 2·nnz·k linear + k³ factor/solves).
pub fn modeled_bpmf_us(batch: usize, nnz: usize, k: usize) -> f64 {
    let per_item = 2.0 * (nnz * k * k) as f64 + 2.0 * (nnz * k) as f64 + (k * k * k) as f64;
    batch as f64 * per_item / MODELED_FLOPS_PER_US
}

impl Backend {
    /// PJRT when artifacts are discoverable, native otherwise.
    pub fn auto() -> Backend {
        if SharedRuntime::global().is_some() {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }

    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "pjrt" => Some(Backend::Pjrt),
            "native" => Some(Backend::Native),
            "modeled" => Some(Backend::Modeled),
            "phantom" => Some(Backend::Phantom),
            "auto" => Some(Backend::auto()),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::Native => "native",
            Backend::Modeled => "modeled",
            Backend::Phantom => "phantom",
        }
    }
}

/// `c += a @ b` on square `edge×edge` blocks (the SUMMA core phase).
pub fn summa_block(env: &mut ProcEnv, backend: Backend, a: &[f64], b: &[f64], c: &mut [f64], edge: usize) {
    let artifact = format!("summa{edge}");
    match backend {
        Backend::Pjrt if SharedRuntime::global().is_some_and(|rt| rt.available(&artifact)) => {
            let rt = SharedRuntime::global().unwrap();
            let dims = [edge as i64, edge as i64];
            let out = env.compute_timed(|| {
                rt.exec_f64(
                    &artifact,
                    &[F64Input::new(a, &dims), F64Input::new(b, &dims), F64Input::new(c, &dims)],
                )
                .expect("summa artifact execution")
            });
            c.copy_from_slice(&out[0]);
        }
        Backend::Modeled => {
            crate::kernels::native::matmul_acc(a, b, c, edge, edge, edge);
            env.compute(modeled_matmul_us(edge));
        }
        Backend::Phantom => {
            env.compute(modeled_matmul_us(edge));
        }
        _ => {
            env.compute_timed(|| crate::kernels::native::matmul_acc(a, b, c, edge, edge, edge));
        }
    }
}

/// One red-black sweep on a halo-padded strip; returns the local max delta.
pub fn poisson_sweep(env: &mut ProcEnv, backend: Backend, strip: &mut [f64], rp2: usize, n: usize) -> f64 {
    let artifact = format!("poisson_r{}_n{}", rp2 - 2, n);
    match backend {
        Backend::Pjrt if SharedRuntime::global().is_some_and(|rt| rt.available(&artifact)) => {
            let rt = SharedRuntime::global().unwrap();
            let dims = [rp2 as i64, n as i64];
            let out = env.compute_timed(|| {
                rt.exec_f64(&artifact, &[F64Input::new(strip, &dims)]).expect("poisson artifact")
            });
            strip.copy_from_slice(&out[0]);
            out[1][0]
        }
        Backend::Modeled => {
            let d = crate::kernels::native::rb_sweep(strip, rp2, n);
            env.compute(modeled_sweep_us(rp2 - 2, n));
            d
        }
        Backend::Phantom => {
            env.compute(modeled_sweep_us(rp2 - 2, n));
            // No arithmetic: report a "still changing" delta so iteration
            // counts are driven purely by max_iters (engine-scale benches
            // fix the iteration count anyway).
            f64::INFINITY
        }
        _ => env.compute_timed(|| crate::kernels::native::rb_sweep(strip, rp2, n)),
    }
}

/// BPMF posterior batch sample.
#[allow(clippy::too_many_arguments)]
pub fn bpmf_batch(
    env: &mut ProcEnv,
    backend: Backend,
    v: &[f64],
    w: &[f64],
    alpha: f64,
    lam0: &[f64],
    noise: &[f64],
    batch: usize,
    nnz: usize,
    k: usize,
    out: &mut [f64],
) {
    let artifact = format!("bpmf_b{batch}_n{nnz}_k{k}");
    match backend {
        Backend::Pjrt if SharedRuntime::global().is_some_and(|rt| rt.available(&artifact)) => {
            let rt = SharedRuntime::global().unwrap();
            let result = env.compute_timed(|| {
                rt.exec_f64(
                    &artifact,
                    &[
                        F64Input::new(v, &[batch as i64, nnz as i64, k as i64]),
                        F64Input::new(w, &[batch as i64, nnz as i64]),
                        F64Input::new(&[alpha], &[]),
                        F64Input::new(lam0, &[k as i64]),
                        F64Input::new(noise, &[batch as i64, k as i64]),
                    ],
                )
                .expect("bpmf artifact")
            });
            out.copy_from_slice(&result[0]);
        }
        Backend::Modeled => {
            crate::kernels::native::bpmf_posterior(v, w, alpha, lam0, noise, batch, nnz, k, out);
            env.compute(modeled_bpmf_us(batch, nnz, k));
        }
        Backend::Phantom => {
            env.compute(modeled_bpmf_us(batch, nnz, k));
        }
        _ => {
            env.compute_timed(|| {
                crate::kernels::native::bpmf_posterior(v, w, alpha, lam0, noise, batch, nnz, k, out)
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ClusterSpec, Preset, SimCluster};

    #[test]
    fn backends_agree_on_summa_block() {
        if SharedRuntime::global().is_none() {
            eprintln!("skipping backend-parity test: no artifacts");
            return;
        }
        let spec = ClusterSpec::preset(Preset::VulcanSb, 1);
        let out = SimCluster::new(spec).run(|env| {
            if env.world_rank() != 0 {
                return vec![];
            }
            let n = 64usize;
            let a: Vec<f64> = (0..n * n).map(|i| ((i % 17) as f64) * 0.1).collect();
            let b: Vec<f64> = (0..n * n).map(|i| ((i % 11) as f64) - 5.0).collect();
            let mut c1: Vec<f64> = (0..n * n).map(|i| (i % 3) as f64).collect();
            let mut c2 = c1.clone();
            summa_block(env, Backend::Pjrt, &a, &b, &mut c1, n);
            summa_block(env, Backend::Native, &a, &b, &mut c2, n);
            c1.iter().zip(&c2).map(|(x, y)| (x - y).abs()).collect()
        });
        let diffs = &out.outputs[0];
        assert!(!diffs.is_empty());
        assert!(diffs.iter().all(|&d| d < 1e-9), "max diff {:?}", diffs.iter().cloned().fold(0.0, f64::max));
    }

    #[test]
    fn compute_charges_vtime() {
        let spec = ClusterSpec::preset(Preset::VulcanSb, 1);
        let out = SimCluster::new(spec).run(|env| {
            if env.world_rank() != 0 {
                return 0.0;
            }
            let n = 32usize;
            let a = vec![1.0f64; n * n];
            let b = vec![1.0f64; n * n];
            let mut c = vec![0.0f64; n * n];
            let t0 = env.vclock();
            summa_block(env, Backend::Native, &a, &b, &mut c, n);
            assert_eq!(c[0], n as f64);
            env.vclock() - t0
        });
        assert!(out.outputs[0] > 0.0);
    }
}
