//! The paper's three case-study kernels (§5.3) — SUMMA, 2D Poisson, BPMF —
//! each in the three implementations the paper compares: pure MPI, hybrid
//! MPI+MPI (our wrappers), and hybrid MPI+OpenMP (fine-grained loop
//! parallelism, modelled by [`ompsim`]).
//!
//! Compute is real: either the AOT-compiled JAX/Pallas artifacts through
//! PJRT ([`compute::Backend::Pjrt`]) or the bit-equivalent native rust
//! paths ([`native`]); virtual time charges the measured thread CPU time.

pub mod bpmf;
pub mod compute;
pub mod native;
pub mod ompsim;
pub mod poisson;
pub mod summa;

pub use compute::Backend;
pub use ompsim::OmpModel;

/// Per-rank outcome of a kernel fault-recovery drill
/// ([`summa::recovery_drill`], [`poisson::recovery_drill`]): the
/// kernel's communication skeleton run to completion through
/// [`crate::hybrid::HybridCtx::run_resilient`] under a fault plan.
#[derive(Clone, Debug)]
pub struct DrillOutcome {
    /// `false`: this rank was a scheduled casualty and retired
    /// cooperatively (`Resilience::Died`).
    pub finished: bool,
    /// Workload checksum, recomputed from scratch on every attempt —
    /// all finishing ranks must agree (the drill callers assert it).
    pub checksum: f64,
    /// Recovery epochs this rank ran (empty on a clean run).
    pub epochs: Vec<crate::hybrid::EpochReport>,
}

/// Which of the paper's three implementations to run (plus the
/// split-phase overlap variant of DESIGN.md §5e).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Standard MPI collectives, one rank per core.
    PureMpi,
    /// The paper's hybrid MPI+MPI wrappers, one rank per core
    /// (blocking `start`/`wait` pairs).
    HybridMpiMpi,
    /// Hybrid MPI+MPI through the split-phase `HyReq` surface: SUMMA
    /// prefetches the next panel's broadcast under the dgemm, Poisson
    /// overlaps the halo exchange with the interior sweep. Identical
    /// math and results to [`Variant::HybridMpiMpi`]; strictly less
    /// modeled time once communication has anything to hide behind.
    HybridOverlap,
    /// One rank per node + OpenMP fine-grained loop parallelism.
    MpiOpenMp,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::PureMpi => "pure-mpi",
            Variant::HybridMpiMpi => "mpi+mpi",
            Variant::HybridOverlap => "mpi+mpi-overlap",
            Variant::MpiOpenMp => "mpi+openmp",
        }
    }

    /// Is this one of the hybrid MPI+MPI variants (blocking or overlap)?
    pub fn is_hybrid(&self) -> bool {
        matches!(self, Variant::HybridMpiMpi | Variant::HybridOverlap)
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "pure-mpi" | "mpi" => Some(Variant::PureMpi),
            "mpi+mpi" | "hybrid" => Some(Variant::HybridMpiMpi),
            "mpi+mpi-overlap" | "overlap" => Some(Variant::HybridOverlap),
            "mpi+openmp" | "openmp" => Some(Variant::MpiOpenMp),
            _ => None,
        }
    }
}

/// Per-rank timing decomposition of a kernel run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankStats {
    /// Virtual µs spent in computation.
    pub comp_us: f64,
    /// Virtual µs spent in the collective(s) under study.
    pub comm_us: f64,
    /// Total virtual µs of the timed region.
    pub total_us: f64,
    /// Iterations/phases executed.
    pub iters: usize,
    /// Workload-defined checksum for cross-variant validation.
    pub checksum: f64,
}

/// Cluster-level kernel report (reduced over ranks).
#[derive(Clone, Debug)]
pub struct KernelReport {
    pub variant: Variant,
    pub world: usize,
    pub nnodes: usize,
    /// Max over ranks of the timed-region total (the kernel's makespan).
    pub total_us: f64,
    /// Max over ranks of compute time.
    pub comp_us: f64,
    /// Max over ranks of collective time.
    pub comm_us: f64,
    pub iters: usize,
    pub checksum: f64,
    pub wall: std::time::Duration,
}

impl KernelReport {
    pub fn reduce(
        variant: Variant,
        nnodes: usize,
        report: crate::coordinator::RunReport<RankStats>,
    ) -> KernelReport {
        let world = report.outputs.len();
        let max = |f: fn(&RankStats) -> f64| report.outputs.iter().map(f).fold(0.0, f64::max);
        KernelReport {
            variant,
            world,
            nnodes,
            total_us: max(|s| s.total_us),
            comp_us: max(|s| s.comp_us),
            comm_us: max(|s| s.comm_us),
            iters: report.outputs[0].iters,
            checksum: report.outputs.iter().map(|s| s.checksum).sum(),
            wall: report.wall,
        }
    }
}
