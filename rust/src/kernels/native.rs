//! Native rust compute paths — bit-compatible twins of the Pallas kernels
//! (python/compile/kernels/). They serve as the fallback backend when
//! artifacts are absent and as the verification oracle for the PJRT path.

/// `c += a @ b` for row-major `a (m×kk)`, `b (kk×n)`, `c (m×n)`.
/// i-k-j loop order: streams `b` rows, keeps `c` rows hot.
pub fn matmul_acc(a: &[f64], b: &[f64], c: &mut [f64], m: usize, kk: usize, n: usize) {
    assert_eq!(a.len(), m * kk);
    assert_eq!(b.len(), kk * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * kk..(i + 1) * kk];
        let crow = &mut c[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            let brow = &b[k * n..(k + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// Red pass (i+j even) over `rows` of a halo-padded strip, reading
/// neighbour values from the `old` snapshot. Split out of [`rb_sweep`] so
/// the overlap variant of the Poisson kernel can sweep the
/// halo-independent interior rows while the halo messages are in flight
/// and bolt on rows 1 and `rp2 − 2` once they arrive — the pass reads
/// *only* the snapshot, so any row partition produces bit-identical
/// values.
pub fn red_pass(strip: &mut [f64], old: &[f64], n: usize, rows: std::ops::Range<usize>) {
    for i in rows {
        for j in 1..n - 1 {
            if (i + j) % 2 == 0 {
                strip[i * n + j] = 0.25
                    * (old[(i - 1) * n + j] + old[(i + 1) * n + j] + old[i * n + j - 1] + old[i * n + j + 1]);
            }
        }
    }
}

/// Black pass (i+j odd) over all owned rows, reading from the
/// post-red-pass snapshot `red`.
pub fn black_pass(strip: &mut [f64], red: &[f64], rp2: usize, n: usize) {
    for i in 1..rp2 - 1 {
        for j in 1..n - 1 {
            if (i + j) % 2 == 1 {
                strip[i * n + j] = 0.25
                    * (red[(i - 1) * n + j] + red[(i + 1) * n + j] + red[i * n + j - 1] + red[i * n + j + 1]);
            }
        }
    }
}

/// Max |delta| over the owned rows against the pre-sweep snapshot.
pub fn max_delta(strip: &[f64], old: &[f64], rp2: usize, n: usize) -> f64 {
    let mut delta = 0.0f64;
    for i in 1..rp2 - 1 {
        for j in 0..n {
            delta = delta.max((strip[i * n + j] - old[i * n + j]).abs());
        }
    }
    delta
}

/// Red-black Gauss-Seidel sweep on a halo-padded strip (`rp2` rows × `n`
/// cols, rows 0 and rp2−1 are halos, cols 0 and n−1 fixed boundary).
/// Updates in place; returns max |delta| over the owned rows — exactly the
/// semantics of `stencil_pallas.rb_sweep`. Composed from the split passes
/// above, so the overlap kernel's phased execution is bit-identical by
/// construction.
pub fn rb_sweep(strip: &mut [f64], rp2: usize, n: usize) -> f64 {
    assert_eq!(strip.len(), rp2 * n);
    let old: Vec<f64> = strip.to_vec();
    red_pass(strip, &old, n, 1..rp2 - 1);
    let red: Vec<f64> = strip.to_vec();
    black_pass(strip, &red, rp2, n);
    max_delta(strip, &old, rp2, n)
}

/// In-place Cholesky of a k×k SPD matrix (lower triangle result).
pub fn cholesky(a: &mut [f64], k: usize) {
    assert_eq!(a.len(), k * k);
    for i in 0..k {
        for j in 0..=i {
            let mut s = a[i * k + j];
            for t in 0..j {
                s -= a[i * k + t] * a[j * k + t];
            }
            if i == j {
                assert!(s > 0.0, "matrix not positive definite at {i}");
                a[i * k + j] = s.sqrt();
            } else {
                a[i * k + j] = s / a[j * k + j];
            }
        }
        for j in i + 1..k {
            a[i * k + j] = 0.0;
        }
    }
}

/// Solve `L y = b` (lower triangular), in place into `b`.
pub fn trisolve_lower(l: &[f64], b: &mut [f64], k: usize) {
    for i in 0..k {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * k + j] * b[j];
        }
        b[i] = s / l[i * k + i];
    }
}

/// Solve `L^T x = y` (upper triangular via the lower factor), in place.
pub fn trisolve_upper_t(l: &[f64], b: &mut [f64], k: usize) {
    for i in (0..k).rev() {
        let mut s = b[i];
        for j in i + 1..k {
            s -= l[j * k + i] * b[j];
        }
        b[i] = s / l[i * k + i];
    }
}

/// BPMF posterior sample for one batch — the native twin of
/// `model.bpmf_posterior`:
/// `Λ = diag(lam0) + α·Σ v vᵀ`, `b = α·Σ w v`,
/// `sample = Λ⁻¹ b + L⁻ᵀ ε` with `L = chol(Λ)`.
///
/// `v`: (batch·nnz·k), `w`: (batch·nnz), `noise`: (batch·k); output
/// (batch·k).
#[allow(clippy::too_many_arguments)]
pub fn bpmf_posterior(
    v: &[f64],
    w: &[f64],
    alpha: f64,
    lam0: &[f64],
    noise: &[f64],
    batch: usize,
    nnz: usize,
    k: usize,
    out: &mut [f64],
) {
    assert_eq!(v.len(), batch * nnz * k);
    assert_eq!(w.len(), batch * nnz);
    assert_eq!(lam0.len(), k);
    assert_eq!(noise.len(), batch * k);
    assert_eq!(out.len(), batch * k);
    let mut lam = vec![0.0f64; k * k];
    let mut b = vec![0.0f64; k];
    for i in 0..batch {
        lam.fill(0.0);
        for d in 0..k {
            lam[d * k + d] = lam0[d];
        }
        b.fill(0.0);
        for nz in 0..nnz {
            let vrow = &v[(i * nnz + nz) * k..(i * nnz + nz + 1) * k];
            let wv = w[i * nnz + nz];
            for r in 0..k {
                let avr = alpha * vrow[r];
                for c in 0..=r {
                    lam[r * k + c] += avr * vrow[c];
                }
                b[r] += alpha * wv * vrow[r];
            }
        }
        // Symmetrize upper from lower before factorization.
        for r in 0..k {
            for c in r + 1..k {
                lam[r * k + c] = lam[c * k + r];
            }
        }
        cholesky(&mut lam, k);
        // mu = Λ⁻¹ b : L y = b ; Lᵀ mu = y.
        trisolve_lower(&lam, &mut b, k);
        trisolve_upper_t(&lam, &mut b, k);
        // perturbation: Lᵀ p = ε.
        let o = &mut out[i * k..(i + 1) * k];
        o.copy_from_slice(&noise[i * k..(i + 1) * k]);
        trisolve_upper_t(&lam, o, k);
        for d in 0..k {
            o[d] += b[d];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [1.0; 4];
        matmul_acc(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [20.0, 23.0, 44.0, 51.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // (1x3) @ (3x2)
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut c = [0.0, 0.0];
        matmul_acc(&a, &b, &mut c, 1, 3, 2);
        assert_eq!(c, [4.0, 5.0]);
    }

    #[test]
    fn rb_sweep_laplace_converges() {
        let n = 16;
        let mut grid = vec![1.0f64; n * n];
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                grid[i * n + j] = 0.0;
            }
        }
        let mut delta = f64::INFINITY;
        for _ in 0..300 {
            delta = rb_sweep(&mut grid, n, n);
        }
        assert!(delta < 1e-4, "delta {delta}");
        for v in &grid {
            assert!((v - 1.0).abs() < 2e-2);
        }
    }

    #[test]
    fn rb_sweep_preserves_halo_and_boundary() {
        let (rp2, n) = (6, 12);
        let mut strip: Vec<f64> = (0..rp2 * n).map(|i| (i % 7) as f64).collect();
        let orig = strip.clone();
        rb_sweep(&mut strip, rp2, n);
        for j in 0..n {
            assert_eq!(strip[j], orig[j], "top halo");
            assert_eq!(strip[(rp2 - 1) * n + j], orig[(rp2 - 1) * n + j], "bottom halo");
        }
        for i in 0..rp2 {
            assert_eq!(strip[i * n], orig[i * n], "left boundary");
            assert_eq!(strip[i * n + n - 1], orig[i * n + n - 1], "right boundary");
        }
    }

    #[test]
    fn cholesky_and_solves_roundtrip() {
        // A = M Mᵀ + I is SPD.
        let k = 4;
        let m = [1.0, 0.0, 0.0, 0.0, 2.0, 3.0, 0.0, 0.0, 4.0, 5.0, 6.0, 0.0, 1.0, 1.0, 1.0, 2.0];
        let mut a = vec![0.0; k * k];
        for i in 0..k {
            for j in 0..k {
                for t in 0..k {
                    a[i * k + j] += m[i * k + t] * m[j * k + t];
                }
            }
            a[i * k + i] += 1.0;
        }
        let a0 = a.clone();
        cholesky(&mut a, k);
        // Solve A x = e_1 via the two triangular solves; check residual.
        let mut x = vec![0.0; k];
        x[0] = 1.0;
        trisolve_lower(&a, &mut x, k);
        trisolve_upper_t(&a, &mut x, k);
        for i in 0..k {
            let r: f64 = (0..k).map(|j| a0[i * k + j] * x[j]).sum();
            let want = if i == 0 { 1.0 } else { 0.0 };
            assert!((r - want).abs() < 1e-10, "residual row {i}: {r}");
        }
    }

    #[test]
    fn bpmf_posterior_zero_noise_solves_normal_equations() {
        let (batch, nnz, k) = (3, 5, 4);
        let mut v = vec![0.0; batch * nnz * k];
        let mut w = vec![0.0; batch * nnz];
        for (i, x) in v.iter_mut().enumerate() {
            *x = ((i * 29 + 7) % 13) as f64 * 0.3 - 1.5;
        }
        for (i, x) in w.iter_mut().enumerate() {
            *x = ((i * 17 + 3) % 7) as f64 * 0.5 - 1.0;
        }
        let alpha = 2.0;
        let lam0 = vec![1.5; k];
        let noise = vec![0.0; batch * k];
        let mut out = vec![0.0; batch * k];
        bpmf_posterior(&v, &w, alpha, &lam0, &noise, batch, nnz, k, &mut out);
        // Check Λ x = b for item 0 by direct computation.
        let mut lam = vec![0.0; k * k];
        let mut b = vec![0.0; k];
        for d in 0..k {
            lam[d * k + d] = lam0[d];
        }
        for nz in 0..nnz {
            let vr = &v[nz * k..(nz + 1) * k];
            for r in 0..k {
                for c in 0..k {
                    lam[r * k + c] += alpha * vr[r] * vr[c];
                }
                b[r] += alpha * w[nz] * vr[r];
            }
        }
        for r in 0..k {
            let lhs: f64 = (0..k).map(|c| lam[r * k + c] * out[c]).sum();
            assert!((lhs - b[r]).abs() < 1e-9, "row {r}: {lhs} vs {}", b[r]);
        }
    }
}
