//! The MPI+OpenMP compute model (§3.1, §5.3).
//!
//! The paper's MPI+OpenMP baselines use *fine-grained* loop parallelism:
//! one MPI process per node spawns `m` threads inside the computational
//! loops. Its performance is governed by Amdahl's law plus per-region
//! fork/join overhead — the "extra overheads from shared memory threading"
//! the paper cites ([6–8]) for why this hybrid often fails to beat pure
//! MPI even though it communicates less.
//!
//! We execute the node's whole computation for real on the host (one rank
//! per node) and charge:
//!
//! `T_charged = T_cpu·(s + (1−s)/m) + r·fork_join`
//!
//! where `s` is the serial fraction outside the parallel loops, `m` the
//! thread count, and `r` the number of parallel regions entered.

use crate::mpi::env::{thread_cpu_us, ProcEnv};

/// Fine-grained OpenMP cost model for one node's rank.
#[derive(Clone, Copy, Debug)]
pub struct OmpModel {
    /// Threads per node (= cores per node in all paper configs).
    pub threads: usize,
    /// Serial fraction of the computational region (loop setup, scalar
    /// sections the fine-grained approach does not parallelize).
    pub serial_frac: f64,
    /// Fork/join cost per parallel region (µs).
    pub fork_join_us: f64,
}

impl OmpModel {
    /// Defaults matched to the paper's observations (the MPI+OpenMP
    /// compute bars in Figs. 17–19 exceed the pure-MPI ones).
    pub fn new(threads: usize) -> OmpModel {
        OmpModel { threads, serial_frac: 0.06, fork_join_us: 1.5 }
    }

    /// Parallel-efficiency multiplier applied to measured CPU time.
    pub fn scale(&self) -> f64 {
        self.serial_frac + (1.0 - self.serial_frac) / self.threads as f64
    }

    /// Run `f` (the node's whole compute for `regions` parallel regions),
    /// charging the modelled parallel time to the virtual clock.
    pub fn charge<R>(&self, env: &mut ProcEnv, regions: usize, f: impl FnOnce() -> R) -> R {
        let t0 = thread_cpu_us();
        let r = f();
        let dt = (thread_cpu_us() - t0).max(0.0);
        let charged = dt * env.state().compute_scale * self.scale() + regions as f64 * self.fork_join_us;
        env.advance(charged);
        r
    }

    /// Like [`OmpModel::charge`] but with a deterministic serial-time model
    /// (`serial_us`) instead of measured CPU time — pairs with
    /// [`Backend::Modeled`](super::compute::Backend::Modeled).
    pub fn charge_modeled<R>(&self, env: &mut ProcEnv, regions: usize, serial_us: f64, f: impl FnOnce() -> R) -> R {
        let r = f();
        env.advance(serial_us * self.scale() + regions as f64 * self.fork_join_us);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ClusterSpec, Preset, SimCluster};

    #[test]
    fn scale_behaves_like_amdahl() {
        let m16 = OmpModel::new(16);
        let m1 = OmpModel::new(1);
        assert!(m16.scale() < 1.0 / 8.0 + 0.07);
        assert!((m1.scale() - 1.0).abs() < 1e-12);
        // More threads never slower (in the scale factor).
        assert!(OmpModel::new(24).scale() < m16.scale());
    }

    #[test]
    fn charge_scales_measured_cpu() {
        let spec = ClusterSpec::preset(Preset::VulcanSb, 1);
        let out = SimCluster::new(spec).run(|env| {
            if env.world_rank() != 0 {
                return (0.0, 0.0);
            }
            let work = || {
                let mut acc = 0u64;
                for i in 0..2_000_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc)
            };
            let t0 = env.vclock();
            env.compute_timed(work);
            let serial = env.vclock() - t0;
            let t1 = env.vclock();
            OmpModel::new(16).charge(env, 1, work);
            let parallel = env.vclock() - t1;
            (serial, parallel)
        });
        let (serial, parallel) = out.outputs[0];
        assert!(parallel < serial, "16 threads must be charged less: {parallel} vs {serial}");
        // But not better than perfectly linear + overhead floor.
        assert!(parallel > serial / 16.0);
    }
}
