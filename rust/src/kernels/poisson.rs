//! 2D Poisson solver (§5.3.2, Fig. 18).
//!
//! A square Laplace problem (boundary value 1, interior 0) decomposed by
//! rows: each iteration runs a red-black Gauss-Seidel sweep on the local
//! strip (the Pallas/native stencil kernel), exchanges halo rows with the
//! adjacent ranks (plain `MPI_Send`/`MPI_Recv` in *all* variants — the
//! paper's hybrid only replaces the collective), and allreduces the global
//! maximum update delta (8 B — the small-message allreduce regime of
//! Figs. 14–16) until convergence.

use super::compute::{poisson_sweep, Backend};
use super::ompsim::OmpModel;
use super::{KernelReport, RankStats, Variant};
use crate::coll::{CollOp, Flavor, PlanCache};
use crate::coordinator::{ClusterSpec, SimCluster};
use crate::hybrid::SyncScheme;
use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::{Datatype, ReduceOp};
use crate::util::{cast_slice, to_bytes};

/// Poisson configuration.
#[derive(Clone, Copy, Debug)]
pub struct PoissonCfg {
    /// Grid edge (n × n interior points, f64).
    pub n: usize,
    /// Convergence threshold on the global max delta.
    pub tol: f64,
    /// Iteration cap (the paper iterates to convergence; we cap so every
    /// config/variant runs the same bounded work — documented deviation).
    pub max_iters: usize,
    pub variant: Variant,
    pub backend: Backend,
    pub threads: usize,
}

impl PoissonCfg {
    pub fn paper(n: usize, variant: Variant, backend: Backend, threads: usize) -> PoissonCfg {
        PoissonCfg { n, tol: 1e-4, max_iters: 200, variant, backend, threads }
    }
}

/// Run the solver; spec must give `p | n` rows-per-rank.
pub fn run(spec: ClusterSpec, cfg: PoissonCfg) -> KernelReport {
    let nnodes = spec.nnodes();
    let report = SimCluster::new(spec).run(move |env| rank_program(env, cfg));
    KernelReport::reduce(cfg.variant, nnodes, report)
}

fn rank_program(env: &mut ProcEnv, cfg: PoissonCfg) -> RankStats {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let n = cfg.n;
    assert_eq!(n % p, 0, "grid rows {n} must divide by ranks {p}");
    let rows = n / p;
    let rp2 = rows + 2;

    // Strip with halo rows; boundary value 1 at the outer frame.
    let mut strip = vec![0.0f64; rp2 * n];
    for j in 0..n {
        if me == 0 {
            strip[j] = 1.0; // global top boundary lives in rank 0's halo
        }
        if me == p - 1 {
            strip[(rp2 - 1) * n + j] = 1.0; // global bottom boundary
        }
    }
    for i in 0..rp2 {
        strip[i * n] = 1.0;
        strip[i * n + n - 1] = 1.0;
    }

    // Collective plans, built once before the loop (the Table-2 one-off
    // wrapper setup for the hybrid variant, the tuned-algorithm
    // resolution for the pure ones). The 8 B max-allreduce of every
    // iteration then runs against the cached plan: no re-splitting, no
    // window re-allocation, no re-planning.
    let flavor = match cfg.variant {
        Variant::HybridMpiMpi => Flavor::hybrid(SyncScheme::Spin),
        _ => Flavor::Pure,
    };
    let mut plans = PlanCache::new();
    plans.plan(env, &w, CollOp::Allreduce, 8, Datatype::F64, Some(ReduceOp::Max), flavor);
    let omp = OmpModel { threads: cfg.threads, ..OmpModel::new(cfg.threads) };
    let halo_tag = env.next_coll_tag(&w, opcode::HALO);

    let mut stats = RankStats::default();
    env.harness_sync(&w);
    let t_start = env.vclock();

    for _ in 0..cfg.max_iters {
        // ---- halo exchange + sweep (the "Gauss-Seidel module") --------
        let t0 = env.vclock();
        if p > 1 {
            // Exchange with up (me-1) and down (me+1); boundary ranks keep
            // their fixed halo rows.
            let top_row = strip[n..2 * n].to_vec();
            let bottom_row = strip[rows * n..(rows + 1) * n].to_vec();
            if me > 0 {
                env.send(&w, me - 1, halo_tag, to_bytes(&top_row));
            }
            if me + 1 < p {
                env.send(&w, me + 1, halo_tag, to_bytes(&bottom_row));
            }
            if me + 1 < p {
                let mut buf = vec![0u8; n * 8];
                env.recv_into(&w, Some(me + 1), halo_tag, &mut buf);
                strip[(rp2 - 1) * n..rp2 * n].copy_from_slice(&cast_slice::<f64>(&buf));
            }
            if me > 0 {
                let mut buf = vec![0u8; n * 8];
                env.recv_into(&w, Some(me - 1), halo_tag, &mut buf);
                strip[..n].copy_from_slice(&cast_slice::<f64>(&buf));
            }
        }
        let local_delta = if cfg.variant == Variant::MpiOpenMp {
            if cfg.backend == Backend::Modeled {
                omp.charge_modeled(env, 2, super::compute::modeled_sweep_us(rows, n), || {
                    crate::kernels::native::rb_sweep(&mut strip, rp2, n)
                })
            } else {
                omp.charge(env, 2, || crate::kernels::native::rb_sweep(&mut strip, rp2, n))
            }
        } else {
            poisson_sweep(env, cfg.backend, &mut strip, rp2, n)
        };
        stats.comp_us += env.vclock() - t0;

        // ---- the 8-byte max-allreduce (the measured collective) -------
        // Align clocks (uncharged) so comm_us measures the collective
        // itself, not the compute skew of the slowest rank — the skew
        // still shows up in total_us, attributed to neither bucket.
        env.harness_sync(&w);
        let t1 = env.vclock();
        let mut buf = to_bytes(&[local_delta]).to_vec();
        plans.allreduce(env, &w, flavor, Datatype::F64, ReduceOp::Max, &mut buf);
        let global_delta = cast_slice::<f64>(&buf)[0];
        stats.comm_us += env.vclock() - t1;
        stats.iters += 1;

        if global_delta < cfg.tol {
            break;
        }
        // Hybrid: ranks must not overwrite their input slots while a slow
        // sibling still reads G — the next store targets a different slot
        // region than G, but the red sync inside the next allreduce
        // handle's wait (method 2) or the reduce (method 1) orders it.
        // For method-2 the barrier precedes leader reads, so per-slot
        // writes are safe.
    }
    stats.total_us = env.vclock() - t_start;
    stats.checksum = strip[n..(rows + 1) * n].iter().sum();

    plans.free(env);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Preset;

    fn spec(nodes: usize, per: usize) -> ClusterSpec {
        let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.max(1));
        s.nodes = vec![per; nodes];
        s
    }

    #[test]
    fn variants_agree_and_converge() {
        let n = 32;
        let mut checksums = Vec::new();
        for (variant, nodes, per) in [
            (Variant::PureMpi, 2, 4),
            (Variant::HybridMpiMpi, 2, 4),
            (Variant::MpiOpenMp, 8, 1),
        ] {
            let cfg = PoissonCfg {
                n,
                tol: 1e-3,
                max_iters: 500,
                variant,
                backend: Backend::Native,
                threads: 4,
            };
            let rep = run(spec(nodes, per), cfg);
            assert!(rep.iters < 500, "{variant:?} should converge, ran {}", rep.iters);
            checksums.push((variant, rep.iters, rep.checksum));
        }
        // Same math in every variant: identical iteration counts and sums.
        let (_, i0, c0) = checksums[0];
        for &(v, i, c) in &checksums {
            assert_eq!(i, i0, "{v:?} iterations");
            assert!((c - c0).abs() < 1e-9, "{v:?} checksum {c} vs {c0}");
        }
    }

    #[test]
    fn hybrid_allreduce_cheaper_for_small_messages() {
        let n = 32;
        let cfg = |variant| PoissonCfg {
            n,
            tol: 0.0, // never converge -> fixed 50 iterations
            max_iters: 50,
            variant,
            backend: Backend::Native,
            threads: 1,
        };
        let pure = run(spec(2, 8), cfg(Variant::PureMpi));
        let hy = run(spec(2, 8), cfg(Variant::HybridMpiMpi));
        assert_eq!(pure.iters, 50);
        assert!(
            hy.comm_us < pure.comm_us,
            "hybrid 8B allreduce {} must beat pure {}",
            hy.comm_us,
            pure.comm_us
        );
    }

    #[test]
    fn solution_approaches_boundary_value() {
        let cfg = PoissonCfg {
            n: 16,
            tol: 1e-6,
            max_iters: 2000,
            variant: Variant::PureMpi,
            backend: Backend::Native,
            threads: 1,
        };
        let rep = run(spec(1, 4), cfg);
        // Interior sum -> n*n (all ones) as the Laplace solution is u = 1.
        assert!((rep.checksum - 256.0).abs() < 1.0, "checksum {}", rep.checksum);
    }
}
