//! 2D Poisson solver (§5.3.2, Fig. 18).
//!
//! A square Laplace problem (boundary value 1, interior 0) decomposed by
//! rows: each iteration runs a red-black Gauss-Seidel sweep on the local
//! strip (the Pallas/native stencil kernel), exchanges halo rows with the
//! adjacent ranks (plain `MPI_Send`/`MPI_Recv` in *all* variants — the
//! paper's hybrid only replaces the collective), and allreduces the global
//! maximum update delta (8 B — the small-message allreduce regime of
//! Figs. 14–16) until convergence.

use super::compute::{modeled_sweep_us, poisson_sweep, Backend};
use super::native::{black_pass, max_delta, red_pass};
use super::ompsim::OmpModel;
use super::{DrillOutcome, KernelReport, RankStats, Variant};
use crate::coll::{CollOp, Flavor, PlanCache};
use crate::coordinator::{ClusterSpec, SimCluster};
use crate::hybrid::{AllreduceMethod, HybridCtx, LeaderPolicy, Resilience, RetryPolicy, SyncScheme};
use crate::mpi::env::{opcode, ProcEnv};
use crate::mpi::{Datatype, ReduceOp};
use crate::util::{cast_slice, to_bytes};

/// Poisson configuration.
#[derive(Clone, Copy, Debug)]
pub struct PoissonCfg {
    /// Grid edge (n × n interior points, f64).
    pub n: usize,
    /// Convergence threshold on the global max delta.
    pub tol: f64,
    /// Iteration cap (the paper iterates to convergence; we cap so every
    /// config/variant runs the same bounded work — documented deviation).
    pub max_iters: usize,
    pub variant: Variant,
    pub backend: Backend,
    pub threads: usize,
}

impl PoissonCfg {
    pub fn paper(n: usize, variant: Variant, backend: Backend, threads: usize) -> PoissonCfg {
        PoissonCfg { n, tol: 1e-4, max_iters: 200, variant, backend, threads }
    }
}

/// Run the solver; spec must give `p | n` rows-per-rank.
pub fn run(spec: ClusterSpec, cfg: PoissonCfg) -> KernelReport {
    let nnodes = spec.nnodes();
    let report = SimCluster::new(spec).run(move |env| rank_program(env, cfg));
    KernelReport::reduce(cfg.variant, nnodes, report)
}

fn rank_program(env: &mut ProcEnv, cfg: PoissonCfg) -> RankStats {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let n = cfg.n;
    assert_eq!(n % p, 0, "grid rows {n} must divide by ranks {p}");
    let rows = n / p;
    let rp2 = rows + 2;

    // Strip with halo rows; boundary value 1 at the outer frame.
    let mut strip = vec![0.0f64; rp2 * n];
    for j in 0..n {
        if me == 0 {
            strip[j] = 1.0; // global top boundary lives in rank 0's halo
        }
        if me == p - 1 {
            strip[(rp2 - 1) * n + j] = 1.0; // global bottom boundary
        }
    }
    for i in 0..rp2 {
        strip[i * n] = 1.0;
        strip[i * n + n - 1] = 1.0;
    }

    if cfg.variant == Variant::HybridOverlap {
        return overlap_iterations(env, cfg, strip, rows, n);
    }

    // Collective plans, built once before the loop (the Table-2 one-off
    // wrapper setup for the hybrid variant, the tuned-algorithm
    // resolution for the pure ones). The 8 B max-allreduce of every
    // iteration then runs against the cached plan: no re-splitting, no
    // window re-allocation, no re-planning.
    let flavor = match cfg.variant {
        Variant::HybridMpiMpi => Flavor::hybrid(SyncScheme::Spin),
        _ => Flavor::Pure,
    };
    let mut plans = PlanCache::new();
    plans.plan(env, &w, CollOp::Allreduce, 8, Datatype::F64, Some(ReduceOp::Max), flavor);
    let omp = OmpModel { threads: cfg.threads, ..OmpModel::new(cfg.threads) };
    let halo_tag = env.next_coll_tag(&w, opcode::HALO);

    let mut stats = RankStats::default();
    env.harness_sync(&w);
    let t_start = env.vclock();

    for _ in 0..cfg.max_iters {
        // ---- halo exchange + sweep (the "Gauss-Seidel module") --------
        let t0 = env.vclock();
        if p > 1 {
            // Exchange with up (me-1) and down (me+1); boundary ranks keep
            // their fixed halo rows.
            let top_row = strip[n..2 * n].to_vec();
            let bottom_row = strip[rows * n..(rows + 1) * n].to_vec();
            if me > 0 {
                env.send(&w, me - 1, halo_tag, to_bytes(&top_row));
            }
            if me + 1 < p {
                env.send(&w, me + 1, halo_tag, to_bytes(&bottom_row));
            }
            if me + 1 < p {
                let mut buf = vec![0u8; n * 8];
                env.recv_into(&w, Some(me + 1), halo_tag, &mut buf);
                strip[(rp2 - 1) * n..rp2 * n].copy_from_slice(&cast_slice::<f64>(&buf));
            }
            if me > 0 {
                let mut buf = vec![0u8; n * 8];
                env.recv_into(&w, Some(me - 1), halo_tag, &mut buf);
                strip[..n].copy_from_slice(&cast_slice::<f64>(&buf));
            }
        }
        let local_delta = if cfg.variant == Variant::MpiOpenMp {
            if cfg.backend == Backend::Modeled {
                omp.charge_modeled(env, 2, super::compute::modeled_sweep_us(rows, n), || {
                    crate::kernels::native::rb_sweep(&mut strip, rp2, n)
                })
            } else {
                omp.charge(env, 2, || crate::kernels::native::rb_sweep(&mut strip, rp2, n))
            }
        } else {
            poisson_sweep(env, cfg.backend, &mut strip, rp2, n)
        };
        stats.comp_us += env.vclock() - t0;

        // ---- the 8-byte max-allreduce (the measured collective) -------
        // Align clocks (uncharged) so comm_us measures the collective
        // itself, not the compute skew of the slowest rank — the skew
        // still shows up in total_us, attributed to neither bucket.
        env.harness_sync(&w);
        let t1 = env.vclock();
        let mut buf = to_bytes(&[local_delta]).to_vec();
        plans.allreduce(env, &w, flavor, Datatype::F64, ReduceOp::Max, &mut buf);
        let global_delta = cast_slice::<f64>(&buf)[0];
        stats.comm_us += env.vclock() - t1;
        stats.iters += 1;

        if global_delta < cfg.tol {
            break;
        }
        // Hybrid: ranks must not overwrite their input slots while a slow
        // sibling still reads G — the next store targets a different slot
        // region than G, but the red sync inside the next allreduce
        // handle's wait (method 2) or the reduce (method 1) orders it.
        // For method-2 the barrier precedes leader reads, so per-slot
        // writes are safe.
    }
    stats.total_us = env.vclock() - t_start;
    stats.checksum = strip[n..(rows + 1) * n].iter().sum();

    plans.free(env);
    stats
}

/// The split-phase iteration loop ([`Variant::HybridOverlap`],
/// DESIGN.md §5e): per iteration the halo *sends* go out first (their
/// payloads are last sweep's boundary rows, ready immediately), the
/// halo-independent interior red rows sweep while those messages are in
/// flight, and only then are the halo rows received and the two
/// halo-adjacent red rows plus the black pass finished. Because every
/// pass reads a snapshot (see [`red_pass`]), the phased order is
/// bit-identical to the blocking `rb_sweep` — same deltas, same
/// iteration count, same checksum — while the halo latency hides under
/// the interior sweep. The 8 B max-allreduce runs on a split-phase
/// session handle.
fn overlap_iterations(
    env: &mut ProcEnv,
    cfg: PoissonCfg,
    mut strip: Vec<f64>,
    rows: usize,
    n: usize,
) -> RankStats {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let rp2 = rows + 2;
    let full_us = modeled_sweep_us(rows, n);
    // Flop-model split of one sweep: red ≈ 3/7, black ≈ 3/7, delta ≈ 1/7
    // of the 7 flops/point; phase A covers the interior share of the red
    // pass. A + B always sum to the blocking sweep's charge, so the
    // variants stay charge-comparable point for point.
    let interior_rows = rows.saturating_sub(2);
    let phase_a_us = full_us * (3.0 / 7.0) * (interior_rows as f64 / rows as f64);
    let phase_b_us = full_us - phase_a_us;

    let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
    let mut ar = ctx.allreduce_init(
        env, Datatype::F64, ReduceOp::Max, 8, AllreduceMethod::Tuned, SyncScheme::Spin,
    );
    let halo_tag = env.next_coll_tag(&w, opcode::HALO);

    let mut stats = RankStats::default();
    env.harness_sync(&w);
    let t_start = env.vclock();

    for _ in 0..cfg.max_iters {
        // ---- halo sends first: payloads are last iteration's rows -----
        let t0 = env.vclock();
        let mut old: Vec<f64> = strip.to_vec();
        if p > 1 {
            if me > 0 {
                env.send(&w, me - 1, halo_tag, to_bytes(&strip[n..2 * n]));
            }
            if me + 1 < p {
                env.send(&w, me + 1, halo_tag, to_bytes(&strip[rows * n..(rows + 1) * n]));
            }
        }
        stats.comm_us += env.vclock() - t0;

        // ---- phase A: halo-independent interior red rows 2..rp2−2 -----
        let t1 = env.vclock();
        match cfg.backend {
            Backend::Phantom => env.compute(phase_a_us),
            Backend::Modeled => {
                red_pass(&mut strip, &old, n, 2..rp2.saturating_sub(2));
                env.compute(phase_a_us);
            }
            _ => {
                env.compute_timed(|| red_pass(&mut strip, &old, n, 2..rp2.saturating_sub(2)));
            }
        }
        stats.comp_us += env.vclock() - t1;

        // ---- halos arrive (overlapped with phase A above) -------------
        let t2 = env.vclock();
        if p > 1 {
            let mut buf = vec![0u8; n * 8];
            if me + 1 < p {
                env.recv_into(&w, Some(me + 1), halo_tag, &mut buf);
                strip[(rp2 - 1) * n..rp2 * n].copy_from_slice(&cast_slice::<f64>(&buf));
                old[(rp2 - 1) * n..rp2 * n].copy_from_slice(&cast_slice::<f64>(&buf));
            }
            if me > 0 {
                env.recv_into(&w, Some(me - 1), halo_tag, &mut buf);
                strip[..n].copy_from_slice(&cast_slice::<f64>(&buf));
                old[..n].copy_from_slice(&cast_slice::<f64>(&buf));
            }
        }
        stats.comm_us += env.vclock() - t2;

        // ---- phase B: halo-adjacent red rows, black pass, delta -------
        let t3 = env.vclock();
        let local_delta = match cfg.backend {
            Backend::Phantom => {
                env.compute(phase_b_us);
                f64::INFINITY
            }
            Backend::Modeled => {
                let d = finish_sweep(&mut strip, &old, rp2, n);
                env.compute(phase_b_us);
                d
            }
            _ => env.compute_timed(|| finish_sweep(&mut strip, &old, rp2, n)),
        };
        stats.comp_us += env.vclock() - t3;

        // ---- the 8 B max-allreduce on the session handle --------------
        env.harness_sync(&w);
        let t4 = env.vclock();
        ar.start_allreduce(env, to_bytes(&[local_delta]));
        let g = ar.wait(env);
        let global_delta = cast_slice::<f64>(&ar.window().expect("handle live").load(env, g, 8))[0];
        stats.comm_us += env.vclock() - t4;
        stats.iters += 1;

        if global_delta < cfg.tol {
            break;
        }
    }
    stats.total_us = env.vclock() - t_start;
    stats.checksum = strip[n..(rows + 1) * n].iter().sum();

    env.barrier(ctx.shmem());
    ar.free(env);
    stats
}

/// The Poisson chaos drill (DESIGN.md fault model): the solver's
/// collective skeleton — a modeled sweep followed by the 8 B residual
/// max-allreduce, per round — run to completion through
/// [`HybridCtx::run_resilient`] under the spec's fault plan. Scheduled
/// casualties retire cooperatively at the next round boundary (or the
/// driver's own checkpoints) once their death time arrives; survivors
/// detect, shrink, rebuild the persistent handle and restart. Every
/// attempt recomputes the checksum (the sum of the weighted global
/// residuals) from round 0, so all finishing ranks agree on the final
/// survivor set. Returns the makespan and the per-rank
/// [`DrillOutcome`]s.
pub fn recovery_drill(spec: ClusterSpec, rounds: usize) -> (f64, Vec<DrillOutcome>) {
    let rep = SimCluster::new(spec).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut h = ctx.allreduce_init(
            env, Datatype::F64, ReduceOp::Max, 8, AllreduceMethod::Tuned, SyncScheme::Spin,
        );
        let out = ctx.run_resilient(
            env,
            &mut [&mut h],
            None,
            RetryPolicy::default(),
            |env, cx, hs| {
                let mut checksum = 0.0f64;
                for it in 0..rounds {
                    if env.rank_dead() {
                        return Ok(None);
                    }
                    env.compute(300.0); // the round's red-black sweep (modeled)
                    let me_w = cx.parent().world_of(cx.parent().rank());
                    let local = (me_w + 1) as f64 * 0.5 / (it + 1) as f64;
                    hs[0].start_allreduce(env, to_bytes(&[local]));
                    hs[0].try_wait(env)?;
                    let g = hs[0].result_view(8).expect("hybrid handles are window-backed");
                    checksum += cast_slice::<f64>(g)[0] * (it + 1) as f64;
                }
                Ok(Some(checksum))
            },
        );
        match out {
            Resilience::Completed { value, epochs, .. } => {
                DrillOutcome { finished: true, checksum: value, epochs }
            }
            Resilience::Died => DrillOutcome { finished: false, checksum: 0.0, epochs: Vec::new() },
            Resilience::Exhausted { last, .. } => {
                panic!("Poisson recovery drill exhausted its retry budget: {last}")
            }
        }
    });
    (rep.max_vtime_us(), rep.outputs)
}

/// Phase B of the phased sweep: the two halo-adjacent red rows (1 and
/// `rp2 − 2`; on 1- and 2-row strips this is the whole red pass), then
/// the black pass from the completed red snapshot, then the delta
/// against the pre-sweep snapshot — composing to exactly
/// [`crate::kernels::native::rb_sweep`].
fn finish_sweep(strip: &mut [f64], old: &[f64], rp2: usize, n: usize) -> f64 {
    red_pass(strip, old, n, 1..2.min(rp2 - 1));
    if rp2 > 3 {
        red_pass(strip, old, n, rp2 - 2..rp2 - 1);
    }
    let red: Vec<f64> = strip.to_vec();
    black_pass(strip, &red, rp2, n);
    max_delta(strip, old, rp2, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Preset;

    fn spec(nodes: usize, per: usize) -> ClusterSpec {
        let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.max(1));
        s.nodes = vec![per; nodes];
        s
    }

    #[test]
    fn variants_agree_and_converge() {
        let n = 32;
        let mut checksums = Vec::new();
        for (variant, nodes, per) in [
            (Variant::PureMpi, 2, 4),
            (Variant::HybridMpiMpi, 2, 4),
            (Variant::MpiOpenMp, 8, 1),
        ] {
            let cfg = PoissonCfg {
                n,
                tol: 1e-3,
                max_iters: 500,
                variant,
                backend: Backend::Native,
                threads: 4,
            };
            let rep = run(spec(nodes, per), cfg);
            assert!(rep.iters < 500, "{variant:?} should converge, ran {}", rep.iters);
            checksums.push((variant, rep.iters, rep.checksum));
        }
        // Same math in every variant: identical iteration counts and sums.
        let (_, i0, c0) = checksums[0];
        for &(v, i, c) in &checksums {
            assert_eq!(i, i0, "{v:?} iterations");
            assert!((c - c0).abs() < 1e-9, "{v:?} checksum {c} vs {c0}");
        }
    }

    #[test]
    fn hybrid_allreduce_cheaper_for_small_messages() {
        let n = 32;
        let cfg = |variant| PoissonCfg {
            n,
            tol: 0.0, // never converge -> fixed 50 iterations
            max_iters: 50,
            variant,
            backend: Backend::Native,
            threads: 1,
        };
        let pure = run(spec(2, 8), cfg(Variant::PureMpi));
        let hy = run(spec(2, 8), cfg(Variant::HybridMpiMpi));
        assert_eq!(pure.iters, 50);
        assert!(
            hy.comm_us < pure.comm_us,
            "hybrid 8B allreduce {} must beat pure {}",
            hy.comm_us,
            pure.comm_us
        );
    }

    #[test]
    fn solution_approaches_boundary_value() {
        let cfg = PoissonCfg {
            n: 16,
            tol: 1e-6,
            max_iters: 2000,
            variant: Variant::PureMpi,
            backend: Backend::Native,
            threads: 1,
        };
        let rep = run(spec(1, 4), cfg);
        // Interior sum -> n*n (all ones) as the Laplace solution is u = 1.
        assert!((rep.checksum - 256.0).abs() < 1.0, "checksum {}", rep.checksum);
    }
}
