//! SUMMA — Scalable Universal Matrix Multiplication (§5.3.1, Fig. 17).
//!
//! `C = A·B` on a √p × √p process grid: in phase k every grid row
//! broadcasts its block of A's k-th block-column along the row
//! communicator and every grid column broadcasts B's k-th block-row along
//! the column communicator, then each rank accumulates the local product.
//! Two broadcasts per phase — "a typical example of supporting multiple
//! communicators in our design".
//!
//! Variants: pure MPI (`MPI_Bcast` on the sub-communicators), hybrid
//! MPI+MPI (`Wrapper_Hy_Bcast` with per-sub-communicator `comm_package`s,
//! windows and translation tables), and MPI+OpenMP (one rank per node,
//! fine-grained loop parallelism via [`OmpModel`]).

use super::compute::{summa_block, Backend};
use super::ompsim::OmpModel;
use super::{DrillOutcome, KernelReport, RankStats, Variant};
use crate::coll::{CollOp, Flavor, PlanCache};
use crate::coordinator::{ClusterSpec, SimCluster};
use crate::hybrid::{HyColl, HybridCtx, LeaderPolicy, Resilience, RetryPolicy, RootPolicy, SyncScheme};
use crate::mpi::env::ProcEnv;
use crate::mpi::{Communicator, Datatype};
use crate::util::{from_bytes, to_bytes};

/// SUMMA configuration.
#[derive(Clone, Copy, Debug)]
pub struct SummaCfg {
    /// Global matrix edge (n × n, f64).
    pub n: usize,
    pub variant: Variant,
    pub backend: Backend,
    /// Threads per node for the OpenMP variant.
    pub threads: usize,
}

/// Deterministic global matrix entries.
fn a_entry(i: usize, j: usize) -> f64 {
    ((i * 31 + j * 17) % 23) as f64 * 0.5 - 5.0
}

fn b_entry(i: usize, j: usize) -> f64 {
    ((i * 13 + j * 7) % 19) as f64 * 0.25 - 2.0
}

fn isqrt(p: usize) -> usize {
    let q = (p as f64).sqrt().round() as usize;
    assert_eq!(q * q, p, "SUMMA needs a square process count, got {p}");
    q
}

/// Run SUMMA on a cluster. For [`Variant::MpiOpenMp`] pass a spec with one
/// rank per node (the launcher does this).
pub fn run(spec: ClusterSpec, cfg: SummaCfg) -> KernelReport {
    let nnodes = spec.nnodes();
    let report = SimCluster::new(spec).run(move |env| rank_program(env, cfg));
    KernelReport::reduce(cfg.variant, nnodes, report)
}

fn rank_program(env: &mut ProcEnv, cfg: SummaCfg) -> RankStats {
    let w = env.world();
    let p = w.size();
    let me = w.rank();
    let q = isqrt(p);
    assert_eq!(cfg.n % q, 0, "matrix edge {} must divide by grid edge {q}", cfg.n);
    let nb = cfg.n / q;
    let (row, col) = (me / q, me % q);
    let row_comm = env.split(&w, row as i64, col as i64).unwrap();
    let col_comm = env.split(&w, col as i64, row as i64).unwrap();

    // Local blocks.
    let my_a: Vec<f64> = (0..nb * nb)
        .map(|t| a_entry(row * nb + t / nb, col * nb + t % nb))
        .collect();
    let my_b: Vec<f64> = (0..nb * nb)
        .map(|t| b_entry(row * nb + t / nb, col * nb + t % nb))
        .collect();
    let mut c = vec![0.0f64; nb * nb];
    let blk = nb * nb * 8;

    if cfg.variant == Variant::HybridOverlap {
        return overlap_phases(env, cfg, &row_comm, &col_comm, q, nb, &my_a, &my_b, &mut c);
    }

    // Collective plans, built once before the phase loop — "a typical
    // example of supporting multiple communicators in our design": one
    // bcast plan per sub-communicator, each owning its comm package,
    // shared window and translation tables (hybrid) or its resolved
    // tuned algorithm (pure). The q phases then execute against the
    // cached plans: no per-phase window allocation or table rebuild.
    let flavor = match cfg.variant {
        Variant::HybridMpiMpi => Flavor::hybrid(SyncScheme::Spin),
        _ => Flavor::Pure,
    };
    let mut plans = PlanCache::new();
    plans.plan(env, &row_comm, CollOp::Bcast, blk, Datatype::U8, None, flavor);
    plans.plan(env, &col_comm, CollOp::Bcast, blk, Datatype::U8, None, flavor);
    let omp = OmpModel { threads: cfg.threads, ..OmpModel::new(cfg.threads) };

    let mut stats = RankStats::default();
    env.harness_sync(&w);
    let t_start = env.vclock();

    let mut abuf = vec![0.0f64; nb * nb];
    let mut bbuf = vec![0.0f64; nb * nb];
    for k in 0..q {
        // ---- the two broadcasts (the measured collective) -------------
        env.harness_sync(&w); // skew-free comm measurement (see poisson.rs)
        let t0 = env.vclock();
        match cfg.variant {
            Variant::HybridMpiMpi => {
                // Roots pass their payload; the other ranks pass no
                // buffer and read the node's shared copy in place below.
                let a_root = k; // row_comm rank k owns block-column k
                if row_comm.rank() == a_root {
                    abuf.copy_from_slice(&my_a);
                    let ab = crate::util::cast_slice_mut(&mut abuf);
                    plans.bcast(env, &row_comm, flavor, a_root, blk, Some(ab));
                } else {
                    plans.bcast(env, &row_comm, flavor, a_root, blk, None);
                }
                let b_root = k;
                if col_comm.rank() == b_root {
                    bbuf.copy_from_slice(&my_b);
                    let bb = crate::util::cast_slice_mut(&mut bbuf);
                    plans.bcast(env, &col_comm, flavor, b_root, blk, Some(bb));
                } else {
                    plans.bcast(env, &col_comm, flavor, b_root, blk, None);
                }
            }
            _ => {
                if row_comm.rank() == k {
                    abuf.copy_from_slice(&my_a);
                }
                plans.bcast(env, &row_comm, flavor, k, blk, Some(crate::util::cast_slice_mut(&mut abuf)));
                if col_comm.rank() == k {
                    bbuf.copy_from_slice(&my_b);
                }
                plans.bcast(env, &col_comm, flavor, k, blk, Some(crate::util::cast_slice_mut(&mut bbuf)));
            }
        }
        stats.comm_us += env.vclock() - t0;

        // ---- local accumulate -----------------------------------------
        let t1 = env.vclock();
        match cfg.variant {
            Variant::HybridMpiMpi => {
                // Children read the shared copies in place (no extra
                // on-node copies — the design's point).
                let a: &[f64] = from_bytes(plans.bcast_view(&row_comm, flavor, blk).unwrap());
                let b: &[f64] = from_bytes(plans.bcast_view(&col_comm, flavor, blk).unwrap());
                summa_block(env, cfg.backend, a, b, &mut c, nb);
            }
            Variant::MpiOpenMp => {
                if cfg.backend == Backend::Modeled {
                    omp.charge_modeled(env, 1, super::compute::modeled_matmul_us(nb), || {
                        crate::kernels::native::matmul_acc(&abuf, &bbuf, &mut c, nb, nb, nb)
                    });
                } else {
                    omp.charge(env, 1, || {
                        crate::kernels::native::matmul_acc(&abuf, &bbuf, &mut c, nb, nb, nb)
                    });
                }
            }
            _ => {
                summa_block(env, cfg.backend, &abuf, &bbuf, &mut c, nb);
            }
        }
        stats.comp_us += env.vclock() - t1;
        stats.iters += 1;

        // Hybrid: the next phase's roots will overwrite both shared
        // windows; all readers must be done first (red sync across the
        // grid — covers both the row and column windows).
        if cfg.variant == Variant::HybridMpiMpi && k + 1 < q {
            env.barrier(&w);
        }
    }
    stats.total_us = env.vclock() - t_start;
    stats.checksum = c.iter().sum();

    plans.free(env);
    stats
}

/// The split-phase SUMMA inner loop ([`Variant::HybridOverlap`],
/// DESIGN.md §5e): two pipelined persistent bcast handles per
/// sub-communicator (double-buffered windows), and in phase `k` the
/// phase-`k+1` broadcasts are *started* — the roots' bridge chunks going
/// onto the wire inside `start` — before the phase-`k` dgemm runs, so
/// every other rank's `wait` at the top of phase `k+1` finds the panels
/// already in flight (or arrived). Same math, same per-phase barrier
/// count and bit-identical `C` as the blocking hybrid variant; strictly
/// less modeled time once the panel transfer has a dgemm to hide under.
///
/// Roots rotate per phase, so the handles use [`RootPolicy::PerStart`]
/// (the strict `Fixed` mode suits repeated same-root broadcasts).
#[allow(clippy::too_many_arguments)]
fn overlap_phases(
    env: &mut ProcEnv,
    cfg: SummaCfg,
    row_comm: &Communicator,
    col_comm: &Communicator,
    q: usize,
    nb: usize,
    my_a: &[f64],
    my_b: &[f64],
    c: &mut [f64],
) -> RankStats {
    let w = env.world();
    let blk = nb * nb * 8;
    /// Bridge pipelining depth of the prefetched panel broadcasts.
    const DEPTH: usize = 4;
    let row_ctx = HybridCtx::create(env, row_comm, LeaderPolicy::Single);
    let col_ctx = HybridCtx::create(env, col_comm, LeaderPolicy::Single);
    let mk = |env: &mut ProcEnv, ctx: &std::rc::Rc<HybridCtx>| {
        ctx.bcast_init_split(env, blk, SyncScheme::Spin, RootPolicy::PerStart, DEPTH)
    };
    let mut ra: [HyColl; 2] = [mk(env, &row_ctx), mk(env, &row_ctx)];
    let mut cb: [HyColl; 2] = [mk(env, &col_ctx), mk(env, &col_ctx)];
    let start_phase = |env: &mut ProcEnv, ra: &mut HyColl, cb: &mut HyColl, k: usize| {
        // Row/col rank k own block-column/-row k of A/B.
        let a_arg = (row_comm.rank() == k).then(|| to_bytes(my_a));
        ra.start_bcast(env, k, a_arg);
        let b_arg = (col_comm.rank() == k).then(|| to_bytes(my_b));
        cb.start_bcast(env, k, b_arg);
    };

    let mut stats = RankStats::default();
    env.harness_sync(&w);
    let t_start = env.vclock();

    start_phase(env, &mut ra[0], &mut cb[0], 0);
    for k in 0..q {
        let h = k % 2;
        // Complete phase k's broadcasts — overlapped with phase k−1's
        // dgemm on every rank that isn't this phase's root side.
        env.harness_sync(&w);
        let t0 = env.vclock();
        ra[h].wait(env);
        cb[h].wait(env);
        stats.comm_us += env.vclock() - t0;

        if k + 1 < q {
            // The `(k+1) % 2` windows were last read by phase k−1's
            // dgemm, which every rank finished before its phase-k wait;
            // one world barrier (same per-phase count as the blocking
            // variant) orders the reuse, then prefetch phase k+1.
            env.barrier(&w);
            start_phase(env, &mut ra[(k + 1) % 2], &mut cb[(k + 1) % 2], k + 1);
        }

        let t1 = env.vclock();
        let a: &[f64] = from_bytes(ra[h].result_view(blk).expect("window live"));
        let b: &[f64] = from_bytes(cb[h].result_view(blk).expect("window live"));
        summa_block(env, cfg.backend, a, b, c, nb);
        stats.comp_us += env.vclock() - t1;
        stats.iters += 1;
    }
    stats.total_us = env.vclock() - t_start;
    stats.checksum = c.iter().sum();

    env.barrier(&w);
    for h in [ra, cb].iter_mut().flatten() {
        h.free(env);
    }
    stats
}

/// The SUMMA chaos drill (DESIGN.md fault model): the kernel's
/// communication skeleton — a panel broadcast per phase with a modeled
/// dgemm slice between them — run to completion through
/// [`HybridCtx::run_resilient`] under the spec's fault plan.
///
/// With [`RootPolicy::PerStart`] the roots rotate per phase (the SUMMA
/// shape); with [`RootPolicy::reelect`] the root is pinned and the
/// drill re-queries `root_policy().fixed_root()` every phase, so after
/// a rebuild it broadcasts from wherever the election hook moved the
/// root — killing the pinned root exercises dead-root re-election
/// mid-steady-state. Scheduled casualties retire cooperatively at the
/// next phase boundary (or the driver's own checkpoints) once their
/// death time arrives. Every attempt recomputes the checksum from
/// phase 0, so all finishing ranks agree on the final survivor set no
/// matter how many recovery epochs ran. Returns the makespan and the
/// per-rank [`DrillOutcome`]s.
pub fn recovery_drill(
    spec: ClusterSpec,
    phases: usize,
    panel: usize,
    policy: RootPolicy,
) -> (f64, Vec<DrillOutcome>) {
    let rep = SimCluster::new(spec).run(move |env| {
        let w = env.world();
        let ctx = HybridCtx::create(env, &w, LeaderPolicy::Single);
        let mut h = ctx.bcast_init_split(env, panel, SyncScheme::Spin, policy, 1);
        let out = ctx.run_resilient(
            env,
            &mut [&mut h],
            None,
            RetryPolicy::default(),
            |env, cx, hs| {
                let mut checksum = 0.0f64;
                for k in 0..phases {
                    if env.rank_dead() {
                        return Ok(None);
                    }
                    let root = match hs[0].root_policy().fixed_root() {
                        Some(r) => r,
                        None => k % cx.parent().size(),
                    };
                    let root_w = cx.parent().world_of(root);
                    let fill = ((root_w * 31 + k * 7) % 251) as u8;
                    let payload = (cx.parent().rank() == root).then(|| vec![fill; panel]);
                    hs[0].start_bcast(env, root, payload.as_deref());
                    hs[0].try_wait(env)?;
                    let b = hs[0].result_view(panel).expect("hybrid handles are window-backed")[0];
                    checksum += f64::from(b) * (k + 1) as f64;
                    env.compute(500.0); // the phase's dgemm slice (modeled)
                }
                Ok(Some(checksum))
            },
        );
        match out {
            Resilience::Completed { value, epochs, .. } => {
                DrillOutcome { finished: true, checksum: value, epochs }
            }
            Resilience::Died => DrillOutcome { finished: false, checksum: 0.0, epochs: Vec::new() },
            Resilience::Exhausted { last, .. } => {
                panic!("SUMMA recovery drill exhausted its retry budget: {last}")
            }
        }
    });
    (rep.max_vtime_us(), rep.outputs)
}

/// The verification oracle: checksum of the full `C = A·B` for edge `n`.
pub fn expected_checksum(n: usize) -> f64 {
    // sum(C) = Σ_k (Σ_i a_entry(i,k)) · … no — sum(C) = Σ_{i,j,k} a(i,k)b(k,j)
    //        = Σ_k (Σ_i a(i,k)) (Σ_j b(k,j)).
    let mut total = 0.0;
    for k in 0..n {
        let sa: f64 = (0..n).map(|i| a_entry(i, k)).sum();
        let sb: f64 = (0..n).map(|j| b_entry(k, j)).sum();
        total += sa * sb;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Preset;

    fn spec(nodes: usize, per: usize) -> ClusterSpec {
        let mut s = ClusterSpec::preset(Preset::VulcanSb, nodes.max(1));
        s.nodes = vec![per; nodes];
        s
    }

    #[test]
    fn all_variants_compute_the_same_product() {
        let n = 64;
        let want = expected_checksum(n);
        for (variant, nodes, per) in [
            (Variant::PureMpi, 2, 2),    // 4 ranks, 2x2 grid
            (Variant::HybridMpiMpi, 2, 2),
            (Variant::MpiOpenMp, 4, 1),  // 4 nodes x 1 rank
        ] {
            let cfg = SummaCfg { n, variant, backend: Backend::Native, threads: 4 };
            let rep = run(spec(nodes, per), cfg);
            assert!(
                (rep.checksum - want).abs() < 1e-6 * want.abs().max(1.0),
                "{variant:?}: {} vs {want}",
                rep.checksum
            );
            assert_eq!(rep.iters, 2);
            assert!(rep.total_us > 0.0);
            assert!(rep.comp_us > 0.0);
        }
    }

    #[test]
    fn hybrid_bcast_cheaper_than_pure() {
        let n = 128; // 512 KB-class broadcasts at 2x2? 64x64 blocks = 32 KB
        let pure = run(
            spec(2, 8),
            SummaCfg { n, variant: Variant::PureMpi, backend: Backend::Native, threads: 1 },
        );
        let hy = run(
            spec(2, 8),
            SummaCfg { n, variant: Variant::HybridMpiMpi, backend: Backend::Native, threads: 1 },
        );
        assert!((pure.checksum - hy.checksum).abs() < 1e-6);
        assert!(
            hy.comm_us < pure.comm_us,
            "hybrid bcast {} must beat pure {}",
            hy.comm_us,
            pure.comm_us
        );
    }

    #[test]
    #[should_panic(expected = "square process count")]
    fn non_square_grid_rejected() {
        run(
            spec(1, 3),
            SummaCfg { n: 6, variant: Variant::PureMpi, backend: Backend::Native, threads: 1 },
        );
    }
}
