//! # hympi — Collectives in hybrid MPI+MPI code
//!
//! A full reproduction of *"Collectives in hybrid MPI+MPI code: design,
//! practice and performance"* (Zhou, Gracia, Zhou, Schneider — HLRS, 2020).
//!
//! The paper proposes collective communication operations (allgather,
//! broadcast, allreduce) designed for the **hybrid MPI+MPI** programming
//! model: within a shared-memory node, all ranks share *one* copy of the
//! collective's result inside an MPI-3 shared-memory window; only one
//! *leader* rank per node takes part in the across-node collective over a
//! *bridge* communicator, and the remaining *children* access the result
//! via direct load/store under explicit node-level synchronization.
//!
//! Because the paper's testbeds (a Cray XC40 and a NEC InfiniBand cluster)
//! are not available, the library ships its own substrate: [`mpi`] is a
//! **simulated multi-node MPI cluster** in which every rank is a real OS
//! thread with a virtual clock; payloads really move (results are
//! bit-checked) while latency is charged by a calibrated LogGP/α-β network
//! model ([`mpi::net`]). On top of it:
//!
//! - [`coll`] — the *pure MPI* tuned collective baselines (binomial /
//!   split-binary-tree / pipeline broadcast, ring / recursive-doubling /
//!   Bruck allgather, recursive-doubling / Rabenseifner allreduce) with
//!   Open-MPI-style message-size switch points,
//! - [`hybrid`] — the paper's contribution as a **session API**: one
//!   [`hybrid::HybridCtx`] per communicator (with `k ≥ 1` leaders per
//!   node striping the bridge across NIC lanes — arXiv 2007.06892) and
//!   persistent [`hybrid::HyColl`] handles for the collectives of
//!   §4.2–§4.4 with the synchronization schemes of §4.5 (barrier vs.
//!   status-flag spinning),
//! - [`analysis`] — the correctness-analysis subsystem: a static
//!   verifier over the compiled stage schedules (deadlock, barrier
//!   arity, send/recv matching, bounds) and a vector-clock
//!   happens-before race detector over shared-window accesses,
//! - [`select`] — the UCC-style algorithm-selection subsystem: every
//!   hard-coded algorithm choice routed through one [`select::Selector`]
//!   layer, with a candidate registry (closed-form α-β cost per viable
//!   algorithm), an online autotuner (cost-model or race at `*_init`),
//!   and a versioned persisted tuning table (`TUNING.json`),
//! - [`coordinator`] — cluster presets, rank placement, the thread-per-rank
//!   engine, the OSU-style measurement harness and report writers,
//! - [`runtime`] — a PJRT client (via the `xla` crate) that loads the
//!   AOT-compiled JAX/Pallas compute kernels from `artifacts/*.hlo.txt`,
//! - [`kernels`] — the paper's three case studies (SUMMA, 2D Poisson
//!   solver, BPMF) in all three variants (pure MPI, hybrid MPI+MPI,
//!   hybrid MPI+OpenMP),
//! - [`figures`] — one generator per table/figure of the paper's
//!   evaluation section (Table 1–2, Fig. 12–19),
//! - [`util`] — self-contained RNG, statistics, a criterion-style bench
//!   harness and a property-testing helper (the build is fully offline, so
//!   these substrates are implemented here rather than pulled in).

// Style decisions the CI clippy gate should not fight: indexed loops are
// the idiom of block/displacement collective math throughout this crate,
// and the MPI-shaped call surfaces legitimately carry many parameters.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_div_ceil)]

pub mod analysis;
pub mod coll;
pub mod coordinator;
pub mod figures;
pub mod hybrid;
pub mod kernels;
pub mod mpi;
pub mod runtime;
pub mod select;
pub mod util;

/// Crate-wide result alias (backed by [`util::Error`]; the default build
/// carries no external crates).
pub type Result<T> = std::result::Result<T, util::Error>;
