//! `hympi` — the launcher.
//!
//! ```text
//! hympi figures <name|all> [--out DIR] [--scale X] [--fast]
//! hympi microbench <allgather|bcast|allreduce|reduce-scatter|gather|scatter>
//!                  [--preset P] [--nodes N] [--bytes B] [--leaders K] [--fast]
//!                  [--bcast-small-max B] [--bcast-medium-max B] [--bcast-seg B]
//!                  [--pipeline-seg B] [--allreduce-small-max B]
//!                  [--allgather-small-max B] [--allreduce-method-max B]
//! hympi kernel <summa|poisson|bpmf> [--variant V] [--nodes N] [--n N]
//!              [--backend B] [--scale X]
//! hympi info
//! ```
//!
//! (Argument parsing is hand-rolled: the build is fully offline and the
//! surface is small.)

use hympi::coordinator::{ClusterSpec, Preset};
use hympi::figures::{self, FigOpts};
use hympi::hybrid::SyncScheme;
use hympi::kernels::{self, Backend, Variant};

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

/// Apply the `--bcast-small-max` family of threshold flags: if any is
/// present, install a [`StaticSelector`](hympi::select::StaticSelector)
/// over the overridden tables so the whole run — `Auto` arms included —
/// uses them. Flags stack on top of any `HYMPI_*` env overrides.
fn apply_tuning_flags(args: &[String]) {
    let mut t = hympi::coll::Tuning::from_env();
    let mut any = false;
    let mut set = |name: &str, slot: &mut usize| {
        if let Some(v) = opt(args, name).and_then(|v| v.parse::<usize>().ok()) {
            *slot = v;
            any = true;
        }
    };
    set("--bcast-small-max", &mut t.bcast_small_max);
    set("--bcast-medium-max", &mut t.bcast_medium_max);
    set("--bcast-seg", &mut t.bcast_seg);
    set("--pipeline-seg", &mut t.pipeline_seg);
    set("--allreduce-small-max", &mut t.allreduce_small_max);
    set("--allgather-small-max", &mut t.allgather_small_max);
    set("--allreduce-method-max", &mut t.allreduce_method_max);
    if any {
        hympi::select::install(std::sync::Arc::new(hympi::select::StaticSelector::new(t)));
    }
}

fn usage() -> ! {
    eprintln!(
        "usage:\n  hympi figures <table1|table2|fig12..fig19|all> [--out DIR] [--scale X] [--fast]\n  \
         hympi microbench <allgather|bcast|allreduce|reduce-scatter|gather|scatter> [--preset vulcan-sb|vulcan-hsw|hazelhen] [--nodes N] [--bytes B] [--leaders K] [--fast] [--bcast-small-max B] [--bcast-medium-max B] [--bcast-seg B] [--pipeline-seg B] [--allreduce-small-max B] [--allgather-small-max B] [--allreduce-method-max B]\n  \
         hympi kernel <summa|poisson|bpmf> [--variant pure-mpi|mpi+mpi|mpi+mpi-overlap|mpi+openmp] [--nodes N] [--n N] [--backend auto|pjrt|native|modeled|phantom] [--scale X]\n  \
         hympi info"
    );
    std::process::exit(2);
}

fn main() -> hympi::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("figures") => {
            let name = args.get(1).map(|s| s.as_str()).unwrap_or("all");
            let opts = FigOpts {
                out_dir: opt(&args, "--out").unwrap_or("reports").to_string(),
                scale: opt(&args, "--scale").and_then(|v| v.parse().ok()).unwrap_or(1.0),
                fast: flag(&args, "--fast"),
            };
            if name == "all" {
                figures::run_all(&opts)?;
            } else {
                figures::run(name, &opts)?;
            }
        }
        Some("microbench") => {
            let op = args.get(1).map(|s| s.as_str()).unwrap_or_else(|| usage());
            let preset = Preset::parse(opt(&args, "--preset").unwrap_or("vulcan-sb"))
                .unwrap_or_else(|| usage());
            let nodes: usize = opt(&args, "--nodes").and_then(|v| v.parse().ok()).unwrap_or(2);
            let bytes: usize = opt(&args, "--bytes").and_then(|v| v.parse().ok()).unwrap_or(800);
            let leaders: usize = opt(&args, "--leaders").and_then(|v| v.parse().ok()).unwrap_or(1);
            let fast = flag(&args, "--fast");
            apply_tuning_flags(&args);
            let spec = || ClusterSpec::preset(preset, nodes);
            use hympi::coll::{CollOp, Flavor};
            use hympi::figures::common as mb;
            let coll_op = match op {
                "allgather" => CollOp::Allgather,
                "bcast" => CollOp::Bcast,
                "allreduce" => CollOp::Allreduce,
                "reduce-scatter" => CollOp::ReduceScatter,
                "gather" => CollOp::Gather,
                "scatter" => CollOp::Scatter,
                _ => usage(),
            };
            let pure = mb::drive_report(spec(), fast, coll_op, bytes, Flavor::Pure);
            let hy = mb::drive_report(
                spec(),
                fast,
                coll_op,
                bytes,
                Flavor::hybrid_k(SyncScheme::Spin, leaders),
            );
            println!(
                "{op} on {} x {} ({} B, {} leader(s)/node): MPI {:.2} us | hybrid {:.2} us | speedup {:+.1}%",
                nodes,
                preset.cores_per_node(),
                bytes,
                leaders,
                pure.mean_us,
                hy.mean_us,
                (pure.mean_us - hy.mean_us) / pure.mean_us * 100.0
            );
            println!(
                "  plan cache: pure {} hits / {} misses | hybrid {} hits / {} misses",
                pure.plan_hits, pure.plan_misses, hy.plan_hits, hy.plan_misses
            );
        }
        Some("kernel") => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or_else(|| usage());
            let variant = Variant::parse(opt(&args, "--variant").unwrap_or("mpi+mpi"))
                .unwrap_or_else(|| usage());
            let nodes: usize = opt(&args, "--nodes").and_then(|v| v.parse().ok()).unwrap_or(2);
            let backend =
                Backend::parse(opt(&args, "--backend").unwrap_or("auto")).unwrap_or_else(|| usage());
            let scale: f64 = opt(&args, "--scale").and_then(|v| v.parse().ok()).unwrap_or(1.0);
            let preset = Preset::parse(opt(&args, "--preset").unwrap_or("vulcan-sb"))
                .unwrap_or_else(|| usage());
            let spec = if variant == Variant::MpiOpenMp {
                let mut s = ClusterSpec::preset(preset, nodes);
                s.nodes = vec![1; nodes];
                s
            } else {
                ClusterSpec::preset(preset, nodes)
            };
            let threads = preset.cores_per_node();
            let rep = match which {
                "summa" => {
                    let n: usize = opt(&args, "--n").and_then(|v| v.parse().ok()).unwrap_or(512);
                    kernels::summa::run(spec, kernels::summa::SummaCfg { n, variant, backend, threads })
                }
                "poisson" => {
                    let n: usize = opt(&args, "--n").and_then(|v| v.parse().ok()).unwrap_or(256);
                    kernels::poisson::run(
                        spec,
                        kernels::poisson::PoissonCfg::paper(n, variant, backend, threads),
                    )
                }
                "bpmf" => kernels::bpmf::run(
                    spec,
                    kernels::bpmf::BpmfCfg::paper(scale, variant, backend, threads),
                ),
                _ => usage(),
            };
            println!(
                "{which} [{}] on {} nodes: comp {:.1} us | comm {:.1} us | total {:.1} us | iters {} | checksum {:.6e} | wall {:?}",
                rep.variant.name(),
                rep.nnodes,
                rep.comp_us,
                rep.comm_us,
                rep.total_us,
                rep.iters,
                rep.checksum,
                rep.wall,
            );
        }
        Some("info") => {
            println!("hympi — hybrid MPI+MPI collectives reproduction");
            println!("presets: vulcan-sb (16c/IB), vulcan-hsw (24c/IB), hazelhen (24c/Aries)");
            match hympi::runtime::SharedRuntime::global() {
                Some(_) => println!("artifacts: found (PJRT backend available)"),
                None => println!("artifacts: NOT found — run `make artifacts` (native fallback active)"),
            }
        }
        _ => usage(),
    }
    Ok(())
}
