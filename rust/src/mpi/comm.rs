//! Communicators: ordered process groups with a private message context.
//!
//! A [`Communicator`] is a cheap handle (id + shared member table). Rank
//! order inside a split communicator follows MPI semantics: sorted by the
//! `(key, world_rank)` pair supplied to `split`.

use std::sync::Arc;

/// Color value meaning "give me no communicator" (`MPI_UNDEFINED`).
pub const UNDEFINED: i64 = -1;

/// An ordered group of world ranks with a unique message context id.
#[derive(Clone, Debug)]
pub struct Communicator {
    id: u64,
    /// World rank per communicator rank, in communicator-rank order.
    members: Arc<Vec<usize>>,
    /// This process's rank within the communicator.
    my_rank: usize,
    /// Whether the group spans more than one shared-memory node
    /// (precomputed — selects the barrier cost tier).
    spans_nodes: bool,
}

impl Communicator {
    pub(crate) fn new(id: u64, members: Arc<Vec<usize>>, my_rank: usize, spans_nodes: bool) -> Communicator {
        debug_assert_eq!(members[my_rank], members[my_rank]); // bounds check
        Communicator { id, members, my_rank, spans_nodes }
    }

    /// The world communicator over `world` ranks, for rank `me` (id 0).
    pub(crate) fn world(world: usize, me: usize, spans_nodes: bool) -> Communicator {
        Communicator {
            id: 0,
            members: Arc::new((0..world).collect()),
            my_rank: me,
            spans_nodes,
        }
    }

    /// Context id (unique per communicator across the cluster).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// My rank within this communicator (`MPI_Comm_rank`).
    pub fn rank(&self) -> usize {
        self.my_rank
    }

    /// Number of members (`MPI_Comm_size`).
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// World rank of communicator rank `r`.
    pub fn world_of(&self, r: usize) -> usize {
        self.members[r]
    }

    /// Communicator rank of world rank `w`, if a member.
    pub fn rank_of_world(&self, w: usize) -> Option<usize> {
        // Member tables are small and this is not on the data path;
        // linear scan keeps the handle allocation-free.
        self.members.iter().position(|&m| m == w)
    }

    /// Member table in communicator-rank order.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    pub(crate) fn members_arc(&self) -> Arc<Vec<usize>> {
        self.members.clone()
    }

    /// Does the group span multiple shared-memory nodes?
    pub fn spans_nodes(&self) -> bool {
        self.spans_nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_comm_identity_mapping() {
        let c = Communicator::world(8, 3, true);
        assert_eq!(c.id(), 0);
        assert_eq!(c.size(), 8);
        assert_eq!(c.rank(), 3);
        assert_eq!(c.world_of(5), 5);
        assert_eq!(c.rank_of_world(6), Some(6));
    }

    #[test]
    fn split_comm_mapping() {
        let members = Arc::new(vec![4usize, 9, 17]);
        let c = Communicator::new(3, members, 1, false);
        assert_eq!(c.size(), 3);
        assert_eq!(c.world_of(0), 4);
        assert_eq!(c.world_of(2), 17);
        assert_eq!(c.rank_of_world(9), Some(1));
        assert_eq!(c.rank_of_world(5), None);
        assert!(!c.spans_nodes());
    }
}
