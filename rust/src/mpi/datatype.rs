//! Element datatypes for collective payloads (`MPI_Datatype` analogue).

/// Supported element types. The paper's benchmarks use doubles throughout;
/// the reduction machinery supports the full set for generality.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Datatype {
    U8,
    I32,
    I64,
    F32,
    F64,
}

impl Datatype {
    /// Size of one element in bytes (`MPI_Type_size`).
    pub const fn size(&self) -> usize {
        match self {
            Datatype::U8 => 1,
            Datatype::I32 | Datatype::F32 => 4,
            Datatype::I64 | Datatype::F64 => 8,
        }
    }

    /// Number of elements in `bytes` bytes; panics on remainder.
    pub fn count(&self, bytes: usize) -> usize {
        let sz = self.size();
        assert_eq!(bytes % sz, 0, "{bytes} bytes is not a whole number of {self:?}");
        bytes / sz
    }

    pub const fn name(&self) -> &'static str {
        match self {
            Datatype::U8 => "u8",
            Datatype::I32 => "i32",
            Datatype::I64 => "i64",
            Datatype::F32 => "f32",
            Datatype::F64 => "f64",
        }
    }
}

impl std::fmt::Display for Datatype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(Datatype::U8.size(), 1);
        assert_eq!(Datatype::I32.size(), 4);
        assert_eq!(Datatype::F32.size(), 4);
        assert_eq!(Datatype::I64.size(), 8);
        assert_eq!(Datatype::F64.size(), 8);
    }

    #[test]
    fn count_divides() {
        assert_eq!(Datatype::F64.count(800), 100);
    }

    #[test]
    #[should_panic(expected = "not a whole number")]
    fn count_rejects_remainder() {
        Datatype::F64.count(12);
    }
}
