//! `ProcEnv` — one MPI rank's execution environment.
//!
//! Every rank thread owns a `ProcEnv`: its virtual clock, its handle on the
//! world communicator, and the operations of the MPI-like API (p2p,
//! communicator management, shared windows, barriers, compute charging).
//!
//! ## Two planes
//!
//! - **data plane** (`send`/`recv`/`sendrecv`, window copies, barriers):
//!   real payload motion, virtual-time charged by the [`NetModel`];
//! - **control plane** (`oob_send`/`oob_recv`): used by the *mechanics* of
//!   one-off management operations (communicator splits, window
//!   allocation), whose virtual-time charge instead follows the calibrated
//!   scaling laws of [`MgmtCosts`](super::state::MgmtCosts) (Table 2 of the
//!   paper). This keeps one-off costs faithful to the published
//!   measurements without double-charging message mechanics.

use super::comm::{Communicator, UNDEFINED};
use super::fault::{self, FaultState};
use super::msg::{Matcher, Msg};
use super::net::NetModel;
use super::pool::{BufPool, Payload, PoolBuf};
use super::state::{ClusterState, CommCore};
use super::sync::{BarrierTicket, SyncGroup};
use super::topo::Topology;
use super::win::SharedWindow;
use crate::util::Rng;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

/// Collective/control op codes folded into message tags.
pub mod opcode {
    pub const CTRL_SPLIT: i64 = 1;
    pub const CTRL_WIN: i64 = 2;
    pub const BCAST: i64 = 3;
    pub const ALLGATHER: i64 = 4;
    pub const ALLGATHERV: i64 = 5;
    pub const ALLREDUCE: i64 = 6;
    pub const REDUCE: i64 = 7;
    pub const BARRIER: i64 = 8;
    pub const GATHER: i64 = 9;
    pub const SCATTER: i64 = 10;
    pub const REDSCAT: i64 = 11;
    pub const HALO: i64 = 12;
    /// Survivor agreement during [`HybridCtx::shrink`]
    /// (crate::hybrid::HybridCtx::shrink): epoch-tagged requests
    /// (child → coordinator). Used as a *raw* control tag —
    /// [`ProcEnv::next_coll_tag`] values are `≥ 256`, so raw opcodes
    /// never collide with them.
    pub const CTRL_SHRINK: i64 = 13;
    /// Coordinator → child replies of the shrink agreement. A distinct
    /// tag so a restarted round's requests can never be matched as
    /// stale replies (or vice versa).
    pub const CTRL_SHRINK_ACK: i64 = 14;
}

/// A shared-memory window handle (`MPI_Win` analogue): the shared region
/// plus the registry coordinates needed to free it collectively.
pub struct Win {
    pub win: Arc<SharedWindow>,
    comm_id: u64,
    seq: u64,
}

impl Win {
    /// Collective window free (`MPI_Win_free`): synchronizes the group,
    /// then the group root retires the registry entry.
    pub fn free(self, env: &mut ProcEnv, comm: &Communicator) {
        env.barrier(comm);
        if comm.rank() == 0 {
            env.state.retire_window(self.comm_id, self.seq);
        }
    }
}

/// One rank's execution environment (one per thread).
pub struct ProcEnv {
    rank: usize,
    state: Arc<ClusterState>,
    vclock: f64,
    world: Communicator,
    /// Per-communicator collective sequence numbers (tag disambiguation).
    coll_seq: HashMap<u64, u64>,
    /// Per-communicator window sequence numbers.
    win_seq: HashMap<u64, u64>,
    /// Rank-private memo of per-communicator slots: resolved from the
    /// global registry once (at plan/communicator creation), after which
    /// barriers, window lookups and spin syncs on the hot path do zero
    /// hashmap lookups under a lock. Bypassed in `legacy_fabric` mode to
    /// reproduce the old per-operation registry contention.
    cores: HashMap<u64, Arc<CommCore>>,
    /// Bytes physically copied by this rank (send staging, receive
    /// delivery, window store/load) — debug instrumentation for the
    /// zero-copy tests; independent of virtual-time charging.
    copied: u64,
    /// NIC lane this rank's inter-node sends currently bind to (default
    /// 0 — the pre-multi-lane behaviour). The multi-leader hybrid bridge
    /// rebinds around its bridge step so same-node leaders inject on
    /// distinct lanes ([`NetModel::nic_lanes`]).
    nic_lane: usize,
    /// This rank's derived fault-injection state (skew factor, noise
    /// stream, death schedule), built from the cluster's
    /// [`FaultPlan`](super::fault::FaultPlan) at construction. `None` on
    /// clean runs — every fault hook is then a branch on a dead `Option`.
    fault: Option<FaultState>,
    /// Virtual µs per *modeled* detection round, resolved from the fault
    /// plan's detection-cost model at construction (0 on clean runs).
    detect_cost_us: f64,
    /// Modeled detection rounds noted by `&self` failure paths
    /// ([`ProcEnv::recv_bounded`] panics before any `&mut` charge can
    /// run); the catcher flushes them into the clock via
    /// [`ProcEnv::flush_detection`].
    pending_detect: std::cell::Cell<f64>,
    /// Cumulative detection vtime charged to this rank (µs) — the
    /// time-to-detect component the chaos benches report.
    detect_charged: f64,
}

impl ProcEnv {
    pub fn new(state: Arc<ClusterState>, rank: usize) -> ProcEnv {
        let world = Communicator::world(state.topo.world_size(), rank, state.topo.nnodes() > 1);
        let fault = state.fault.as_ref().map(|p| p.state_for(rank));
        let detect_cost_us = state.fault.as_ref().map_or(0.0, |p| p.resolved_detect_cost_us());
        ProcEnv {
            rank,
            state,
            vclock: 0.0,
            world,
            coll_seq: HashMap::new(),
            win_seq: HashMap::new(),
            cores: HashMap::new(),
            copied: 0,
            nic_lane: 0,
            fault,
            detect_cost_us,
            pending_detect: std::cell::Cell::new(0.0),
            detect_charged: 0.0,
        }
    }

    /// The per-communicator slot, resolved through the rank-private memo
    /// (one global-registry trip per communicator per rank). In
    /// `legacy_fabric` mode every call pays the registry lock + hash, as
    /// the pre-PR3 code did on every operation.
    fn comm_core(&mut self, comm: &Communicator) -> Arc<CommCore> {
        if self.state.legacy_fabric {
            return self.state.comm_core(comm.id());
        }
        if let Some(c) = self.cores.get(&comm.id()) {
            return c.clone();
        }
        let c = self.state.comm_core(comm.id());
        self.cores.insert(comm.id(), c.clone());
        c
    }

    /// The communicator's barrier group, via the memoized slot (one
    /// `OnceLock` load past the memo — no registry lock, no hash under a
    /// lock; the `legacy_fabric` bypass lives in [`ProcEnv::comm_core`]).
    fn sync_group(&mut self, comm: &Communicator) -> Arc<SyncGroup> {
        self.comm_core(comm).sync_group(comm.size())
    }

    // ---- identity & clocks ------------------------------------------------

    /// World rank of this process.
    pub fn world_rank(&self) -> usize {
        self.rank
    }

    /// The world communicator (`MPI_COMM_WORLD`).
    pub fn world(&self) -> Communicator {
        self.world.clone()
    }

    /// Current virtual time (µs).
    pub fn vclock(&self) -> f64 {
        self.vclock
    }

    /// Advance the virtual clock by `us` (modelled local work), then
    /// charge any OS-noise pulses the fault plan scheduled inside the
    /// window the clock just crossed.
    pub fn advance(&mut self, us: f64) {
        debug_assert!(us >= 0.0);
        self.vclock += us;
        self.fault_tick();
    }

    /// Charge a local compute phase of `us` microseconds. Under fault
    /// injection the charge is stretched by this rank's slowdown factor
    /// (skew × straggler) — noise pulses land via [`ProcEnv::advance`].
    pub fn compute(&mut self, us: f64) {
        self.advance(us * self.fault_slowdown());
    }

    /// Run `f` and charge its *thread CPU time* (× the preset's compute
    /// scale) to the virtual clock. Thread CPU time — not wall time — keeps
    /// charging honest when hundreds of rank threads share one host core.
    pub fn compute_timed<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let t0 = thread_cpu_us();
        let r = f();
        let dt = (thread_cpu_us() - t0).max(0.0);
        self.vclock += dt * self.state.compute_scale * self.fault_slowdown();
        self.fault_tick();
        r
    }

    /// Charge one on-node memory copy of `bytes` (the hybrid load/store
    /// path). The copy itself is performed by the caller; this charges
    /// its virtual time and records it in the copy counter.
    pub fn charge_memcpy(&mut self, bytes: usize) {
        self.copied += bytes as u64;
        self.vclock += self.state.net.memcpy(bytes);
    }

    /// Charge element-wise reduction arithmetic over `bytes`.
    pub fn charge_reduce(&mut self, bytes: usize) {
        self.vclock += self.state.net.reduce_cost(bytes);
    }

    pub fn net(&self) -> &NetModel {
        &self.state.net
    }

    /// The NIC lane this rank's inter-node sends currently bind to.
    pub fn nic_lane(&self) -> usize {
        self.nic_lane
    }

    /// Bind this rank's inter-node sends to NIC `lane` (wrapped into the
    /// model's [`NetModel::nic_lanes`]); returns the previous binding so
    /// callers can restore it. Everything defaults to lane 0, which makes
    /// the multi-lane model cost-identical to the old single-NIC model
    /// until someone (the multi-leader bridge) deliberately spreads out.
    pub fn set_nic_lane(&mut self, lane: usize) -> usize {
        let prev = self.nic_lane;
        self.nic_lane = lane % self.state.net.nic_lanes.max(1);
        prev
    }

    /// Run `f` with the NIC binding set to `lane` (wrapped), restoring
    /// the previous binding afterwards — the guard the multi-leader
    /// bridge steps use so no path can leak a non-default lane into
    /// subsequent traffic.
    pub fn with_nic_lane<R>(&mut self, lane: usize, f: impl FnOnce(&mut ProcEnv) -> R) -> R {
        let prev = self.set_nic_lane(lane);
        let r = f(self);
        self.nic_lane = prev;
        r
    }

    pub fn topo(&self) -> &Topology {
        &self.state.topo
    }

    pub fn state(&self) -> &Arc<ClusterState> {
        &self.state
    }

    /// My node id.
    pub fn node(&self) -> usize {
        self.state.topo.node_of(self.rank)
    }

    /// Deterministic per-rank RNG (`salt` distinguishes uses).
    pub fn rng(&self, salt: u64) -> Rng {
        Rng::new((self.rank as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ salt)
    }

    // ---- fault injection ---------------------------------------------------

    /// This rank's compute slowdown factor under the active fault plan
    /// (1.0 on clean runs): deterministic per-rank skew draw × any
    /// straggler factors targeting this rank.
    pub fn fault_slowdown(&self) -> f64 {
        self.fault.as_ref().map_or(1.0, |f| f.slowdown)
    }

    /// Charge every OS-noise pulse scheduled at or before the current
    /// virtual time. Called from each vclock mutation point; pulses are
    /// drawn from the plan's per-rank stream keyed off virtual time, so
    /// the charge is independent of host scheduling (the property the
    /// determinism tests pin down). Noise only — death is *cooperative*,
    /// via [`ProcEnv::rank_dead`] checkpoints, so a rank never goes dead
    /// in the middle of a collective it is still participating in.
    fn fault_tick(&mut self) {
        if let Some(f) = &mut self.fault {
            self.vclock += f.noise_due(self.vclock);
        }
    }

    /// Injection checkpoint for the dead-rank mode: true once this rank's
    /// scheduled death time has passed, in which case the rank is
    /// registered in the shared dead registry (first call wins) and the
    /// caller is expected to stop participating — return from its
    /// closure, post nothing further. Always false on clean runs.
    pub fn rank_dead(&mut self) -> bool {
        let Some(f) = &self.fault else { return false };
        let Some(at) = f.dead_at else { return false };
        if self.vclock < at {
            return false;
        }
        self.state.mark_dead(self.rank, self.vclock);
        true
    }

    /// Lowest-ranked member of `comm` registered in the dead registry
    /// (by world rank), excluding this rank itself. One relaxed load on
    /// clean runs. This is the failure-detection consult: a bounded wait
    /// that expires asks this before deciding between "peer died —
    /// surface [`fault::RankFailed`]" and "just slow — re-arm".
    pub fn failed_peer(&self, comm: &Communicator) -> Option<usize> {
        if !self.state.any_dead() {
            return None;
        }
        comm.members().iter().copied().find(|&w| w != self.rank && self.state.is_dead(w))
    }

    // ---- detection-cost model (ISSUE 8) ------------------------------------
    //
    // Bounded-park expiries are wall-clock events and therefore host-
    // dependent, so charging *actual* expiries would break the bitwise
    // vtime determinism the fault tests pin down. Instead each failure
    // surfacing charges its *modeled* round count: 1 round for a
    // registry-detected death (one detection bound of waiting), the full
    // cascade fuse for a cascade-declared one. Branch identity is
    // determined by the fault plan, not the host, so the charge is
    // deterministic.

    /// Charge `rounds` modeled detection rounds to virtual time (the
    /// fault plan's per-round detection cost; no-op on clean runs).
    pub fn charge_detection(&mut self, rounds: f64) {
        let us = rounds * self.detect_cost_us;
        self.detect_charged += us;
        self.advance(us);
    }

    /// Note `rounds` modeled detection rounds from a `&self` failure
    /// path (the bounded receives panic before any `&mut` charge can
    /// run); whoever catches the typed panic flushes the note into the
    /// clock with [`ProcEnv::flush_detection`].
    pub fn note_detection(&self, rounds: f64) {
        self.pending_detect.set(rounds);
    }

    /// Flush any noted detection rounds into the virtual clock; if
    /// nothing was noted, charge `default_rounds` (the catcher knows a
    /// detection happened even when the panic path could not say how
    /// many bounds it modeled).
    pub fn flush_detection(&mut self, default_rounds: f64) {
        let rounds = self.pending_detect.replace(0.0);
        self.charge_detection(if rounds > 0.0 { rounds } else { default_rounds });
    }

    /// Cumulative detection vtime charged to this rank (µs): the
    /// time-to-detect component of the chaos degradation numbers.
    pub fn detection_vtime_us(&self) -> f64 {
        self.detect_charged
    }

    // ---- payload pool & copy instrumentation -------------------------------

    /// This rank's payload slab pool.
    pub fn pool(&self) -> &Arc<BufPool> {
        &self.state.pools[self.rank]
    }

    /// Borrow a pooled scratch buffer of `len` bytes. Contents are
    /// undefined — write before reading. Returns to the pool on drop.
    pub fn take_buf(&self, len: usize) -> PoolBuf {
        PoolBuf::take(self.pool(), len)
    }

    /// Copy `data` into a pooled payload (in legacy data-plane mode: a
    /// fresh allocation, reproducing the pre-pool behaviour).
    pub fn payload_from(&mut self, data: &[u8]) -> Payload {
        self.copied += data.len() as u64;
        if self.state.legacy_dataplane {
            Payload::from_vec(data.to_vec())
        } else {
            Payload::copy_from(&self.state.pools[self.rank], data)
        }
    }

    /// Is the pre-refactor allocating data plane emulated?
    pub fn legacy_dataplane(&self) -> bool {
        self.state.legacy_dataplane
    }

    /// Record `bytes` physically copied (for copies performed outside the
    /// counted send/recv/memcpy paths, e.g. legacy window round-trips).
    pub fn count_copy(&mut self, bytes: usize) {
        self.copied += bytes as u64;
    }

    /// Bytes physically copied by this rank so far.
    pub fn copied_bytes(&self) -> u64 {
        self.copied
    }

    pub fn reset_copied_bytes(&mut self) {
        self.copied = 0;
    }

    /// Pool takes served from recycled slabs.
    pub fn pool_hits(&self) -> u64 {
        self.pool().hits()
    }

    /// Pool takes that allocated (zero in steady state — the invariant
    /// the `zerocopy` integration test asserts).
    pub fn pool_misses(&self) -> u64 {
        self.pool().misses()
    }

    // ---- tags -------------------------------------------------------------

    /// Allocate the tag for the next collective call on `comm`. All members
    /// call collectives in the same order (an MPI requirement), so the
    /// per-communicator sequence numbers agree across ranks.
    pub fn next_coll_tag(&mut self, comm: &Communicator, op: i64) -> i64 {
        let seq = self.coll_seq.entry(comm.id()).or_insert(0);
        *seq += 1;
        ((*seq as i64) << 8) | op
    }

    // ---- data-plane point-to-point -----------------------------------------

    /// Send `data` to communicator rank `dst` (`MPI_Send`; eager/buffered —
    /// never blocks, matching our rendezvous approximation in DESIGN.md §9).
    /// The payload is staged into a recycled pool slab: one copy, no heap
    /// allocation in steady state.
    pub fn send(&mut self, comm: &Communicator, dst: usize, tag: i64, data: &[u8]) {
        let payload = self.payload_from(data);
        self.send_payload(comm, dst, tag, payload);
    }

    /// Send an owned buffer without copying it (`MPI_Send` with a moved
    /// payload). The vector is adopted as-is — callers that can borrow a
    /// slice should prefer [`ProcEnv::send`] (pooled staging beats a fresh
    /// allocation); callers holding a [`PoolBuf`] should convert it with
    /// `into_payload` and use [`ProcEnv::send_payload`].
    pub fn send_vec(&mut self, comm: &Communicator, dst: usize, tag: i64, data: Vec<u8>) {
        self.send_payload(comm, dst, tag, Payload::from_vec(data));
    }

    /// Send a shared payload (fan-out senders clone the handle, not bytes).
    pub fn send_shared(&mut self, comm: &Communicator, dst: usize, tag: i64, data: &Payload) {
        self.send_payload(comm, dst, tag, data.clone());
    }

    /// Send taking ownership of an already-staged payload (zero-copy).
    pub fn send_payload(&mut self, comm: &Communicator, dst: usize, tag: i64, data: Payload) {
        self.vclock += self.state.net.send_overhead_us;
        let world_dst = comm.world_of(dst);
        // Inter-node messages serialize on the sending node's NIC;
        // `sent_at` is then the wire-injection completion time.
        let same = self.state.topo.same_node(self.rank, world_dst);
        let sent_at = if same {
            self.vclock
        } else {
            self.state.reserve_nic(self.node(), self.nic_lane, self.vclock, data.len())
        };
        self.state.traffic.record(data.len());
        self.state.mailboxes[world_dst].post(Msg {
            src: comm.rank(),
            tag,
            comm: comm.id(),
            sent_at,
            data,
        });
    }

    /// Blocking mailbox receive, bounded under fault injection: on clean
    /// runs this is the plain (indefinitely parking) fabric receive; with
    /// a fault plan active each wait round is capped at the detection
    /// bound, after which the dead registry is consulted. A detected
    /// failure panics with a typed [`fault::RankFailed`] payload — the
    /// pure-MPI call surface has no recoverable error path, but the
    /// hybrid session layer catches exactly this payload inside its work
    /// stages and converts it to the recoverable `Err(RankFailed)`.
    ///
    /// Escalation policy, from strongest evidence to weakest:
    /// - the directed source (or, `ANY_SOURCE`, any member of `comm`) is
    ///   registered dead → fail immediately;
    /// - `data_plane` receives additionally fail on a dead member of
    ///   `comm` even when directed at a live source — a dead member
    ///   revokes the whole communicator;
    /// - receives finally fail after [`fault::cascade_rounds`]
    ///   consecutive expiries (control-plane receives get a doubled
    ///   fuse) while *any* rank anywhere is dead: the expected sender is
    ///   alive but itself stranded behind the failure (it got its own
    ///   `RankFailed` and abandoned the op, or retreated into a recovery
    ///   epoch), so no message is ever coming. Since ISSUE 8 the shrink
    ///   agreement runs on explicit [`ProcEnv::oob_recv_deadline`] waits
    ///   instead of indefinite re-arming, so control-plane traffic no
    ///   longer needs a cascade exemption — only the longer fuse, which
    ///   keeps a rebuild's split/window handshakes from misfiring while
    ///   their (live, participating) root is busy gathering.
    ///
    /// Failure paths note their *modeled* detection rounds (1 for a
    /// registry hit, the fuse length for a cascade) for the catcher to
    /// charge to virtual time — see the detection-cost model above.
    fn recv_bounded(&self, comm: &Communicator, src: Option<usize>, tag: i64, data_plane: bool) -> Msg {
        if self.state.fault.is_none() {
            return self.state.mailboxes[self.rank].recv(Matcher { src, tag, comm: comm.id() });
        }
        let fuse = if data_plane { fault::cascade_rounds() } else { 2 * fault::cascade_rounds() };
        let mut expiries = 0u32;
        loop {
            let deadline = Instant::now() + fault::detect_bound();
            let m = Matcher { src, tag, comm: comm.id() };
            if let Some(msg) = self.state.mailboxes[self.rank].recv_deadline(m, deadline) {
                return msg;
            }
            expiries += 1;
            let failed = match src {
                Some(s) if self.state.is_dead(comm.world_of(s)) => Some(comm.world_of(s)),
                Some(_) if data_plane => self.failed_peer(comm),
                Some(_) => None,
                None => self.failed_peer(comm),
            };
            let cascade = failed.is_none() && expiries >= fuse;
            let failed =
                failed.or_else(|| cascade.then(|| self.state.dead_ranks().first().copied()).flatten());
            if let Some(r) = failed {
                self.note_detection(if cascade { fuse as f64 } else { 1.0 });
                std::panic::panic_any(fault::RankFailed { world_rank: r });
            }
        }
    }

    /// Receive into `out` (must be exactly the payload size — collective
    /// internals always know sizes). Returns the source's communicator rank.
    pub fn recv_into(&mut self, comm: &Communicator, src: Option<usize>, tag: i64, out: &mut [u8]) -> usize {
        let msg = self.recv_bounded(comm, src, tag, true);
        assert_eq!(
            msg.data.len(),
            out.len(),
            "recv buffer size mismatch (tag {tag}, src {:?})",
            msg.src
        );
        self.charge_arrival(comm, &msg);
        out.copy_from_slice(&msg.data);
        self.copied += out.len() as u64;
        msg.src
    }

    /// Receive the payload itself (zero-copy; the slab returns to its
    /// sender's pool when the returned handle drops).
    pub fn recv_payload(&mut self, comm: &Communicator, src: Option<usize>, tag: i64) -> (usize, Payload) {
        let msg = self.recv_bounded(comm, src, tag, true);
        self.charge_arrival(comm, &msg);
        (msg.src, msg.data)
    }

    /// Receive returning a fresh vector (`MPI_Recv` with allocation).
    pub fn recv(&mut self, comm: &Communicator, src: Option<usize>, tag: i64) -> (usize, Vec<u8>) {
        let (src, data) = self.recv_payload(comm, src, tag);
        self.copied += data.len() as u64;
        (src, data.to_vec())
    }

    fn charge_arrival(&mut self, comm: &Communicator, msg: &Msg) {
        let world_src = comm.world_of(msg.src);
        let same = self.state.topo.same_node(self.rank, world_src);
        // Intra-node: staging double copy. Inter-node: the β term was paid
        // at the sender's NIC (`sent_at` = injection done); only the wire
        // latency remains.
        let arrival = if same {
            msg.sent_at + self.state.net.transfer(true, msg.data.len())
        } else {
            msg.sent_at + self.state.net.wire_latency(msg.data.len())
        };
        self.vclock = self.vclock.max(arrival) + self.state.net.recv_overhead_us;
        self.fault_tick();
    }

    /// Non-blocking message probe (`MPI_Iprobe`): is a matching message
    /// already deliverable? Charges nothing — the split-phase progress
    /// engine uses it to decide whether a receive-side bridge chunk can
    /// run without blocking.
    pub fn probe(&self, comm: &Communicator, src: Option<usize>, tag: i64) -> bool {
        self.state.mailboxes[self.rank].probe(Matcher { src, tag, comm: comm.id() })
    }

    /// Combined send+receive (`MPI_Sendrecv`). Safe against cycles because
    /// sends are eager.
    pub fn sendrecv(
        &mut self,
        comm: &Communicator,
        dst: usize,
        send_tag: i64,
        data: &[u8],
        src: Option<usize>,
        recv_tag: i64,
    ) -> (usize, Vec<u8>) {
        self.send(comm, dst, send_tag, data);
        self.recv(comm, src, recv_tag)
    }

    // ---- control plane (uncharged mechanics) -------------------------------

    /// Out-of-band send: moves real bytes, charges nothing. Management
    /// operations use this; their cost is charged by calibrated law.
    /// Control messages bypass the fabric's arrival-ticket counter
    /// entirely ([`Mailbox::post_ctrl`](super::msg::Mailbox::post_ctrl))
    /// — their `ANY_SOURCE` receivers are order-insensitive (split/window
    /// mechanics index replies by source), so the data plane's global
    /// arrival ordering is one atomic it never needed to pay for.
    pub fn oob_send(&self, comm: &Communicator, dst: usize, tag: i64, data: &[u8]) {
        let world_dst = comm.world_of(dst);
        self.state.mailboxes[world_dst].post_ctrl(Msg {
            src: comm.rank(),
            tag,
            comm: comm.id(),
            sent_at: 0.0,
            data: Payload::from_vec(data.to_vec()),
        });
    }

    /// Out-of-band receive (no virtual-time charge). Control-plane
    /// semantics under fault injection: a directed receive fails if
    /// *that source* is registered dead, or — with a doubled cascade
    /// fuse — after sustained silence while any rank anywhere is dead
    /// (the source then abandoned the handshake for a recovery epoch;
    /// see [`ProcEnv::recv_bounded`]'s escalation policy).
    pub fn oob_recv(&self, comm: &Communicator, src: Option<usize>, tag: i64) -> (usize, Vec<u8>) {
        let msg = self.recv_bounded(comm, src, tag, false);
        (msg.src, msg.data.to_vec())
    }

    /// Out-of-band receive with an explicit wall-clock deadline: returns
    /// `None` on expiry with no charge and *no* failure escalation — the
    /// caller owns the consult-registry-and-retry decision. This is the
    /// primitive the epoch-tagged shrink agreement runs on: every one of
    /// its control-plane waits is bounded, so a coordinator death can
    /// never park a survivor indefinitely.
    pub fn oob_recv_deadline(
        &self,
        comm: &Communicator,
        src: Option<usize>,
        tag: i64,
        deadline: Instant,
    ) -> Option<(usize, Vec<u8>)> {
        let m = Matcher { src, tag, comm: comm.id() };
        self.state.mailboxes[self.rank].recv_deadline(m, deadline).map(|msg| (msg.src, msg.data.to_vec()))
    }

    /// Discard every control message currently queued for me that
    /// matches `(comm, src, tag)`; returns how many were dropped.
    /// Owner-side hygiene for restartable protocols
    /// ([`Mailbox::drain`](super::msg::Mailbox::drain)): after an epoch
    /// of the shrink agreement completes, re-sent duplicate requests and
    /// superseded replies are swept so they can never alias a later
    /// epoch's traffic.
    pub fn oob_drain(&self, comm: &Communicator, src: Option<usize>, tag: i64) -> usize {
        let m = Matcher { src, tag, comm: comm.id() };
        self.state.mailboxes[self.rank].drain(m)
    }

    // ---- barrier ------------------------------------------------------------

    /// Finish an arrived sync-group episode under fault injection: each
    /// wait round is capped at the detection bound, after which the dead
    /// registry is consulted; after the control-plane cascade fuse of
    /// continuous silence while any rank anywhere is dead, a stranded
    /// episode is abandoned (a member that retreated into a recovery
    /// epoch never arrives — the death-during-rebuild case). Panics with
    /// the typed [`fault::RankFailed`]; the pure-MPI layers have no
    /// recoverable error path, so the hybrid session layer catches
    /// exactly this payload and converts it to the recoverable
    /// `Err(RankFailed)`.
    fn finish_group_bounded(&self, g: &SyncGroup, t: &BarrierTicket, comm: &Communicator) -> f64 {
        let fuse = 2 * fault::cascade_rounds();
        let mut expiries = 0u32;
        loop {
            match g.finish_deadline(t, Instant::now() + fault::detect_bound()) {
                Some(v) => return v,
                None => {
                    expiries += 1;
                    let failed = self.failed_peer(comm);
                    let cascade = failed.is_none() && expiries >= fuse;
                    let failed = failed.or_else(|| {
                        cascade.then(|| self.state.dead_ranks().first().copied()).flatten()
                    });
                    if let Some(r) = failed {
                        self.note_detection(if cascade { fuse as f64 } else { 1.0 });
                        std::panic::panic_any(fault::RankFailed { world_rank: r });
                    }
                }
            }
        }
    }

    /// `MPI_Barrier`: real synchronization via the communicator's
    /// [`SyncGroup`](super::sync::SyncGroup); virtual cost = dissemination
    /// barrier over the group (`⌈log2 p⌉` rounds at the group's tier).
    pub fn barrier(&mut self, comm: &Communicator) {
        let g = self.sync_group(comm);
        let vmax = if self.state.fault.is_some() {
            // Bounded completion under fault injection: a peer that died
            // before arriving would otherwise park this rank forever.
            let t = g.arrive(self.vclock);
            self.finish_group_bounded(&g, &t, comm)
        } else {
            g.arrive_and_wait(self.vclock)
        };
        self.vclock = vmax + self.state.net.barrier_cost(comm.size(), comm.spans_nodes());
        self.fault_tick();
    }

    /// Align virtual clocks across a communicator *without* charging any
    /// cost (harness-internal; not an MPI operation). Bounded under fault
    /// injection exactly like [`ProcEnv::barrier`].
    pub fn harness_sync(&mut self, comm: &Communicator) {
        let g = self.sync_group(comm);
        self.vclock = if self.state.fault.is_some() {
            let t = g.arrive(self.vclock);
            self.finish_group_bounded(&g, &t, comm)
        } else {
            g.arrive_and_wait(self.vclock)
        };
    }

    /// Complete a split-phase barrier on a private [`SyncGroup`] (the
    /// window-owned groups of the split-phase schedules): charge exactly
    /// what [`ProcEnv::barrier`] charges — `vmax` plus the dissemination
    /// cost over `size` participants — except the clock can only move
    /// forward (a rank that computed past the release keeps its time; in
    /// the drive-to-completion case `vclock ≤ vmax` always holds, so the
    /// charge is bit-identical to the blocking barrier).
    pub fn finish_group_barrier(&mut self, vmax: f64, size: usize, spans_nodes: bool) {
        self.vclock = (vmax + self.state.net.barrier_cost(size, spans_nodes)).max(self.vclock);
        self.fault_tick();
    }

    // ---- communicator management --------------------------------------------

    /// `MPI_Comm_split`. Returns `None` iff `color == UNDEFINED`.
    ///
    /// Mechanics run over the control plane via the group root; the
    /// virtual-time charge is the calibrated Table-2 law
    /// [`MgmtCosts::comm_split_us`](super::state::MgmtCosts::comm_split_us).
    pub fn split(&mut self, comm: &Communicator, color: i64, key: i64) -> Option<Communicator> {
        let tag = self.next_coll_tag(comm, opcode::CTRL_SPLIT);
        let p = comm.size();

        // Gather (color, key) at the group root.
        let mut entry = Vec::with_capacity(24);
        entry.extend_from_slice(&color.to_le_bytes());
        entry.extend_from_slice(&key.to_le_bytes());
        let my_reply: Vec<u8>;
        if comm.rank() == 0 {
            let mut entries: Vec<(i64, i64, usize)> = Vec::with_capacity(p); // (color, key, comm rank)
            entries.push((color, key, 0));
            for _ in 1..p {
                let (src, data) = self.oob_recv(comm, None, tag);
                let c = i64::from_le_bytes(data[0..8].try_into().unwrap());
                let k = i64::from_le_bytes(data[8..16].try_into().unwrap());
                entries.push((c, k, src));
            }
            // Group by color deterministically; order members by (key, world rank).
            let mut groups: BTreeMap<i64, Vec<(i64, usize)>> = BTreeMap::new();
            for (c, k, r) in entries {
                if c != UNDEFINED {
                    groups.entry(c).or_default().push((k, comm.world_of(r)));
                }
            }
            let mut replies: Vec<Option<Vec<u8>>> = vec![None; p];
            for (_color, mut members) in groups {
                members.sort_unstable();
                let world_ranks: Vec<usize> = members.iter().map(|&(_, w)| w).collect();
                let id = self.state.alloc_comm_id();
                let node0 = self.state.topo.node_of(world_ranks[0]);
                let spans = world_ranks.iter().any(|&w| self.state.topo.node_of(w) != node0);
                for (new_rank, &w) in world_ranks.iter().enumerate() {
                    let mut buf = Vec::with_capacity(8 * (4 + world_ranks.len()));
                    buf.extend_from_slice(&id.to_le_bytes());
                    buf.extend_from_slice(&(new_rank as u64).to_le_bytes());
                    buf.extend_from_slice(&(spans as u64).to_le_bytes());
                    buf.extend_from_slice(&(world_ranks.len() as u64).to_le_bytes());
                    for &m in &world_ranks {
                        buf.extend_from_slice(&(m as u64).to_le_bytes());
                    }
                    let r = comm.rank_of_world(w).expect("member of parent");
                    replies[r] = Some(buf);
                }
            }
            for (r, reply) in replies.iter().enumerate() {
                if r == 0 {
                    continue;
                }
                let payload = reply.clone().unwrap_or_default(); // empty = UNDEFINED
                self.oob_send(comm, r, tag + (1 << 32), &payload);
            }
            my_reply = replies[0].clone().unwrap_or_default();
        } else {
            self.oob_send(comm, 0, tag, &entry);
            let (_, data) = self.oob_recv(comm, Some(0), tag + (1 << 32));
            my_reply = data;
        }

        // Synchronize and charge the calibrated split cost. Bounded
        // under fault injection: a rebuild's split must not hang on a
        // member that died (or retreated into a recovery epoch) after
        // the agreement that picked this membership.
        let g = self.sync_group(comm);
        let vmax = if self.state.fault.is_some() {
            let t = g.arrive(self.vclock);
            self.finish_group_bounded(&g, &t, comm)
        } else {
            g.arrive_and_wait(self.vclock)
        };
        self.vclock = vmax + self.state.mgmt.comm_split_us(p);

        if my_reply.is_empty() {
            return None;
        }
        let id = u64::from_le_bytes(my_reply[0..8].try_into().unwrap());
        let my_rank = u64::from_le_bytes(my_reply[8..16].try_into().unwrap()) as usize;
        let spans = u64::from_le_bytes(my_reply[16..24].try_into().unwrap()) != 0;
        let n = u64::from_le_bytes(my_reply[24..32].try_into().unwrap()) as usize;
        let members: Vec<usize> = (0..n)
            .map(|i| u64::from_le_bytes(my_reply[32 + 8 * i..40 + 8 * i].try_into().unwrap()) as usize)
            .collect();
        Some(Communicator::new(id, Arc::new(members), my_rank, spans))
    }

    /// `MPI_Comm_split_type(MPI_COMM_TYPE_SHARED)`: one communicator per
    /// shared-memory node, members ordered by world rank (so the lowest
    /// world rank on the node — the paper's *leader* — gets rank 0).
    pub fn split_type_shared(&mut self, comm: &Communicator) -> Communicator {
        let color = self.state.topo.node_of(self.rank) as i64;
        self.split(comm, color, self.rank as i64).expect("color is never UNDEFINED")
    }

    // ---- shared-memory windows -----------------------------------------------

    /// `MPI_Win_allocate_shared` over `comm` (normally a node-level
    /// communicator): every member contributes `my_bytes`; storage is
    /// contiguous in rank order; rank 0 performs the allocation.
    ///
    /// Charge: the Table-2 "Allocate" base cost (the multi-node saturation
    /// term is charged by the hybrid wrapper, which knows the world size).
    pub fn win_allocate_shared(&mut self, comm: &Communicator, my_bytes: usize) -> Win {
        let seq = {
            let s = self.win_seq.entry(comm.id()).or_insert(0);
            *s += 1;
            *s
        };
        let tag = self.next_coll_tag(comm, opcode::CTRL_WIN);
        let p = comm.size();
        let core = self.comm_core(comm);
        if comm.rank() == 0 {
            let mut sizes = vec![0usize; p];
            sizes[0] = my_bytes;
            for _ in 1..p {
                let (src, data) = self.oob_recv(comm, None, tag);
                sizes[src] = u64::from_le_bytes(data[0..8].try_into().unwrap()) as usize;
            }
            let win = Arc::new(SharedWindow::allocate(&sizes));
            core.publish_window(seq, win);
        } else {
            self.oob_send(comm, 0, tag, &(my_bytes as u64).to_le_bytes());
        }
        // Bounded lookup under fault injection: the publishing leader may
        // have died — or abandoned the allocation for a recovery epoch —
        // before publishing, and a child parked on the condvar would
        // otherwise never learn of it.
        let win = if self.state.fault.is_some() {
            let fuse = 2 * fault::cascade_rounds();
            let mut expiries = 0u32;
            loop {
                if let Some(w) =
                    core.lookup_window_deadline(seq, Instant::now() + fault::detect_bound())
                {
                    break w;
                }
                expiries += 1;
                let failed = self.failed_peer(comm);
                let cascade = failed.is_none() && expiries >= fuse;
                let failed = failed
                    .or_else(|| cascade.then(|| self.state.dead_ranks().first().copied()).flatten());
                if let Some(r) = failed {
                    self.note_detection(if cascade { fuse as f64 } else { 1.0 });
                    std::panic::panic_any(fault::RankFailed { world_rank: r });
                }
            }
        } else {
            core.lookup_window(seq)
        };

        let g = self.sync_group(comm);
        let vmax = if self.state.fault.is_some() {
            let t = g.arrive(self.vclock);
            self.finish_group_bounded(&g, &t, comm)
        } else {
            g.arrive_and_wait(self.vclock)
        };
        self.vclock = vmax + self.state.mgmt.alloc_us(1);
        Win { win, comm_id: comm.id(), seq }
    }

    /// `MPI_Win_sync`: processor memory barrier + its modelled cost.
    pub fn win_sync(&mut self) {
        std::sync::atomic::fence(std::sync::atomic::Ordering::SeqCst);
        self.vclock += self.state.net.win_sync_us;
    }

    // ---- §4.5 spinning synchronization ---------------------------------------

    /// Leader side of the spinning sync: `status++` + `MPI_Win_sync`.
    pub fn spin_post(&mut self, win: &SharedWindow, flag: usize) {
        self.win_sync();
        let release_at = self.vclock + self.state.net.spin_release_us;
        win.flag(flag).post(release_at);
        self.vclock = release_at;
    }

    /// Child side: poll `status == target` (equality only — the paper's
    /// MPI one-byte-polling restriction), `MPI_Win_sync` each iteration.
    pub fn spin_wait(&mut self, win: &SharedWindow, flag: usize, target: u32) {
        let release_vt = win.flag(flag).wait_eq(target);
        std::sync::atomic::fence(std::sync::atomic::Ordering::SeqCst);
        self.vclock = self.vclock.max(release_vt) + self.state.net.spin_poll_us;
    }

    /// Child side of the spinning sync with a hard wall-clock deadline:
    /// the failure-detection variant of [`ProcEnv::spin_wait`]. On
    /// success charges exactly what the blocking wait charges; on
    /// deadline expiry returns `false` with no charge so the caller can
    /// consult the dead registry and either surface
    /// [`fault::RankFailed`] or re-arm.
    pub fn spin_wait_deadline(
        &mut self,
        win: &SharedWindow,
        flag: usize,
        target: u32,
        deadline: Instant,
    ) -> bool {
        match win.flag(flag).wait_eq_deadline(target, deadline) {
            Some(release_vt) => {
                std::sync::atomic::fence(std::sync::atomic::Ordering::SeqCst);
                self.vclock = self.vclock.max(release_vt) + self.state.net.spin_poll_us;
                true
            }
            None => false,
        }
    }

    /// Non-blocking child-side probe of the spinning sync: one poll
    /// iteration. On success charges exactly what [`ProcEnv::spin_wait`]
    /// charges at release observation; on failure charges nothing (the
    /// cost model bills one `spin_poll_us` per *observed* release, as the
    /// blocking path does).
    pub fn spin_try_wait(&mut self, win: &SharedWindow, flag: usize, target: u32) -> bool {
        match win.flag(flag).try_wait_eq(target) {
            Some(release_vt) => {
                std::sync::atomic::fence(std::sync::atomic::Ordering::SeqCst);
                self.vclock = self.vclock.max(release_vt) + self.state.net.spin_poll_us;
                true
            }
            None => false,
        }
    }
}

/// Current thread CPU time in microseconds.
///
/// Bound directly against the C library symbol so the default build needs
/// no external crates (the `libc` crate would only re-export this).
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn thread_cpu_us() -> f64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    extern "C" {
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0);
    ts.tv_sec as f64 * 1e6 + ts.tv_nsec as f64 / 1e3
}

/// Fallback for platforms without a known thread-CPU clock binding:
/// monotonic wall time. Less honest under heavy thread oversubscription
/// (documented deviation; Linux builds use the real per-thread clock).
#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn thread_cpu_us() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::state::MgmtCosts;
    use crate::mpi::Placement;
    use crate::mpi::Topology;

    fn two_node_state() -> Arc<ClusterState> {
        ClusterState::new(
            Topology::new(&[2, 2], Placement::Block),
            NetModel::infiniband(),
            MgmtCosts::vulcan(),
            1.0,
        )
    }

    /// Run a closure per rank on real threads and collect outputs by rank.
    fn run_ranks<R: Send + 'static>(
        state: &Arc<ClusterState>,
        f: impl Fn(&mut ProcEnv) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let f = Arc::new(f);
        let mut handles = Vec::new();
        for r in 0..state.topo.world_size() {
            let state = state.clone();
            let f = f.clone();
            handles.push(std::thread::spawn(move || {
                let mut env = ProcEnv::new(state, r);
                f(&mut env)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn p2p_roundtrip_and_vtime() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            if env.world_rank() == 0 {
                env.send(&w, 3, super::super::USER_TAG_BASE + 1, &[7u8; 100]);
                env.vclock()
            } else if env.world_rank() == 3 {
                let (src, data) = env.recv(&w, Some(0), super::super::USER_TAG_BASE + 1);
                assert_eq!(src, 0);
                assert_eq!(data, vec![7u8; 100]);
                env.vclock()
            } else {
                0.0
            }
        });
        // Receiver's clock ≥ sender overhead + inter-node transfer.
        let net = NetModel::infiniband();
        let min_expected = net.send_overhead_us + net.transfer(false, 100);
        assert!(out[3] >= min_expected, "recv vtime {} < {min_expected}", out[3]);
        // Sender only paid its overhead.
        assert!((out[0] - net.send_overhead_us).abs() < 1e-9);
    }

    #[test]
    fn intranode_transfer_is_cheaper() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            match env.world_rank() {
                0 => {
                    env.send(&w, 1, super::super::USER_TAG_BASE + 2, &[1u8; 4096]);
                    env.send(&w, 2, super::super::USER_TAG_BASE + 2, &[1u8; 4096]);
                    0.0
                }
                1 | 2 => {
                    let (_, _) = env.recv(&w, Some(0), super::super::USER_TAG_BASE + 2);
                    env.vclock()
                }
                _ => 0.0,
            }
        });
        assert!(out[1] < out[2], "same-node recv ({}) must be faster than cross-node ({})", out[1], out[2]);
    }

    #[test]
    fn barrier_aligns_clocks_and_charges() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            env.advance(env.world_rank() as f64 * 10.0); // skew
            let w = env.world();
            env.barrier(&w);
            env.vclock()
        });
        let expect = 30.0 + NetModel::infiniband().barrier_cost(4, true);
        for v in out {
            assert!((v - expect).abs() < 1e-9, "{v} vs {expect}");
        }
    }

    #[test]
    fn split_type_shared_groups_by_node() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            let shm = env.split_type_shared(&w);
            (env.world_rank(), shm.size(), shm.rank(), shm.spans_nodes())
        });
        for (wr, size, rank, spans) in out {
            assert_eq!(size, 2);
            assert_eq!(rank, wr % 2, "block placement: local rank = world rank mod 2");
            assert!(!spans);
        }
    }

    #[test]
    fn split_undefined_returns_none() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            let leader = env.world_rank() % 2 == 0;
            let c = env.split(&w, if leader { 0 } else { UNDEFINED }, env.world_rank() as i64);
            (leader, c.map(|c| (c.size(), c.rank())))
        });
        assert_eq!(out[0], (true, Some((2, 0))));
        assert_eq!(out[1], (false, None));
        assert_eq!(out[2], (true, Some((2, 1))));
        assert_eq!(out[3], (false, None));
    }

    #[test]
    fn window_allocation_shares_storage_on_node() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            let shm = env.split_type_shared(&w);
            let win = env.win_allocate_shared(&shm, 8);
            // Each rank writes its slot, leader posts, everyone reads all.
            let (off, len) = win.win.segment(shm.rank());
            assert_eq!(len, 8);
            win.win.write(off, &[env.world_rank() as u8; 8]);
            env.barrier(&shm);
            let all = win.win.read_vec(0, win.win.len());
            win.free(env, &shm);
            all
        });
        assert_eq!(out[0], vec![0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1, 1, 1]);
        assert_eq!(out[2], vec![2, 2, 2, 2, 2, 2, 2, 2, 3, 3, 3, 3, 3, 3, 3, 3]);
    }

    #[test]
    fn spin_sync_transfers_release_time() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            let shm = env.split_type_shared(&w);
            let win = env.win_allocate_shared(&shm, 8);
            let v = if shm.rank() == 0 {
                env.advance(100.0);
                env.spin_post(&win.win, 0);
                env.vclock()
            } else {
                env.spin_wait(&win.win, 0, 1);
                env.vclock()
            };
            env.barrier(&shm); // keep the window alive until all are done
            win.free(env, &shm);
            v
        });
        // Children observed at/after the leader's release time.
        assert!(out[1] >= out[0], "{} < {}", out[1], out[0]);
        assert!(out[3] >= out[2]);
    }

    #[test]
    fn compute_timed_charges_positive() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let x = env.compute_timed(|| {
                let mut acc = 0u64;
                for i in 0..100_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc)
            });
            std::hint::black_box(x);
            env.vclock()
        });
        for v in out {
            assert!(v > 0.0);
        }
    }

    #[test]
    fn nic_lane_binding_wraps_and_restores() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            assert_eq!(env.nic_lane(), 0);
            let prev = env.set_nic_lane(1);
            assert_eq!(prev, 0);
            let lane = env.nic_lane();
            // Wrapping: binding beyond the model's lane count folds back.
            env.set_nic_lane(env.net().nic_lanes);
            let wrapped = env.nic_lane();
            env.set_nic_lane(prev);
            (lane, wrapped, env.nic_lane())
        });
        for (lane, wrapped, restored) in out {
            assert_eq!(lane, 1);
            assert_eq!(wrapped, 0);
            assert_eq!(restored, 0);
        }
    }

    #[test]
    fn distinct_lanes_overlap_same_lane_serializes() {
        // Rank 0 sends two large cross-node messages: both on lane 0 →
        // the second's injection waits for the first; on distinct lanes →
        // both inject starting at the same busy-from point.
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            let tag = super::super::USER_TAG_BASE + 77;
            match env.world_rank() {
                0 => {
                    // lane 0 then lane 1: no mutual serialization.
                    env.send(&w, 2, tag, &[1u8; 100_000]);
                    env.set_nic_lane(1);
                    env.send(&w, 3, tag, &[1u8; 100_000]);
                    env.set_nic_lane(0);
                    0.0
                }
                2 | 3 => {
                    let (_, _) = env.recv(&w, Some(0), tag);
                    env.vclock()
                }
                _ => 0.0,
            }
        });
        let net = NetModel::infiniband();
        let occ = net.nic_occupancy(100_000);
        // Receiver 3's arrival must not include receiver 2's lane-0
        // occupancy: both finish within ~one occupancy + overheads.
        assert!(
            (out[3] - out[2]).abs() < occ * 0.5,
            "lane-separated sends must overlap: {} vs {}",
            out[2],
            out[3]
        );
    }

    #[test]
    fn sendrecv_ring_does_not_deadlock() {
        let s = two_node_state();
        let out = run_ranks(&s, |env| {
            let w = env.world();
            let me = w.rank();
            let p = w.size();
            let tag = super::super::USER_TAG_BASE + 9;
            let (_, data) = env.sendrecv(&w, (me + 1) % p, tag, &[me as u8], Some((me + p - 1) % p), tag);
            data[0]
        });
        assert_eq!(out, vec![3, 0, 1, 2]);
    }
}
